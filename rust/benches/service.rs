//! Service-layer microbenchmarks: the ask/tell hot path at five levels —
//! the bare adapter (no journal, no socket), a journaled session, the
//! request dispatcher (registry + JSON, no socket), the full loopback
//! TCP round-trip, and batched TCP frames (every epoch tell of a job
//! plus the next ask in one round-trip). The spread between levels is
//! the cost of durability, of serialization, of the wire, and what
//! batching claws back. (The multi-session × multi-worker stress run
//! lives in `pasha bench-json --suite service`.)

use pasha::benchmarks::Benchmark;
use pasha::config::space::SearchSpace;
use pasha::scheduler::asktell::{assignment_from_json, AskTell, TellAck, TrialAssignment};
use pasha::service::{handle_request, run_worker_batched, Client, Registry, Server, Session};
use pasha::spec::ExperimentSpec;
use pasha::util::benchkit::{once, section};
use pasha::util::json::parse;
use std::sync::Arc;

fn spec(budget: usize, seed: u64) -> ExperimentSpec {
    let mut spec = ExperimentSpec::named("lcbench-Fashion-MNIST", "pasha").unwrap();
    spec.stop.config_budget = budget;
    spec.seed = seed;
    spec
}

/// One level of the service stack under test.
trait Port {
    fn ask(&mut self) -> TrialAssignment;
    fn tell(&mut self, trial: usize, epoch: u32, metric: f64) -> TellAck;
}

struct CorePort(AskTell);

impl Port for CorePort {
    fn ask(&mut self) -> TrialAssignment {
        self.0.ask("w0")
    }
    fn tell(&mut self, trial: usize, epoch: u32, metric: f64) -> TellAck {
        self.0.tell(trial, epoch, metric).unwrap()
    }
}

struct SessionPort(Session);

impl Port for SessionPort {
    fn ask(&mut self) -> TrialAssignment {
        self.0.ask("w0").unwrap()
    }
    fn tell(&mut self, trial: usize, epoch: u32, metric: f64) -> TellAck {
        self.0.tell(trial, epoch, metric).unwrap()
    }
}

struct RequestPort<'a> {
    reg: &'a Registry,
    sid: String,
    space: SearchSpace,
}

impl Port for RequestPort<'_> {
    fn ask(&mut self) -> TrialAssignment {
        let req = format!("{{\"cmd\":\"ask\",\"session\":\"{}\",\"worker\":\"w0\"}}", self.sid);
        let resp = handle_request(self.reg, &parse(&req).unwrap());
        assignment_from_json(&self.space, &resp).unwrap()
    }
    fn tell(&mut self, trial: usize, epoch: u32, metric: f64) -> TellAck {
        let req = format!(
            "{{\"cmd\":\"tell\",\"session\":\"{}\",\"trial\":{trial},\
             \"epoch\":{epoch},\"metric\":{metric}}}",
            self.sid
        );
        let resp = handle_request(self.reg, &parse(&req).unwrap());
        TellAck::parse(resp.get("ack").and_then(|v| v.as_str()).unwrap_or("")).unwrap()
    }
}

struct TcpPort {
    client: Client,
    sid: String,
    space: SearchSpace,
}

impl Port for TcpPort {
    fn ask(&mut self) -> TrialAssignment {
        self.client.ask(&self.sid, "w0", &self.space).unwrap()
    }
    fn tell(&mut self, trial: usize, epoch: u32, metric: f64) -> TellAck {
        self.client.tell(&self.sid, trial, epoch, metric).unwrap()
    }
}

/// Drive one session to completion with a single synchronous worker;
/// returns the number of ask+tell operations issued.
fn drive(port: &mut dyn Port, bench: &dyn Benchmark) -> usize {
    let mut ops = 0usize;
    loop {
        ops += 1;
        match port.ask() {
            TrialAssignment::Run(job) => {
                for e in job.from_epoch + 1..=job.milestone {
                    let m = bench.accuracy_at(&job.config, e, 0);
                    ops += 1;
                    if port.tell(job.trial, e, m) == TellAck::Abandon {
                        break;
                    }
                }
            }
            TrialAssignment::Stop(_) | TrialAssignment::Pause(_) => {}
            TrialAssignment::Wait => panic!("single worker never waits"),
            TrialAssignment::Done => return ops,
        }
    }
}

fn report_rate(ops: usize, dt: std::time::Duration) {
    println!("  -> {:.0} ops/s", ops as f64 / dt.as_secs_f64().max(1e-9));
}

fn main() {
    let budget = 48;
    let bench = spec(budget, 0).bench.build().unwrap();

    section("service: ask/tell core (in-process, no journal)");
    let mut core = CorePort(spec(budget, 0).build_core().unwrap());
    let (ops, dt) = once("pasha session, core only", || drive(&mut core, bench.as_ref()));
    report_rate(ops, dt);

    section("service: journaled session (write-ahead log on every op)");
    let dir = std::env::temp_dir().join(format!("pasha-bench-svc-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("bench.jsonl");
    let session = Session::create("bench", spec(budget, 0), Some(&path)).unwrap();
    let mut port = SessionPort(session);
    let (ops, dt) = once("pasha session, journaled", || drive(&mut port, bench.as_ref()));
    report_rate(ops, dt);
    drop(port);
    let (recovered, rdt) = once("journal recovery (full replay)", || {
        Session::recover(&path).unwrap().1.events_replayed
    });
    println!(
        "  -> {recovered} events in {:.3}s ({:.0} events/s)",
        rdt.as_secs_f64(),
        recovered as f64 / rdt.as_secs_f64().max(1e-9)
    );

    section("service: request dispatch (registry + JSON, no socket)");
    let reg = Registry::in_memory();
    let sid = reg.create(spec(budget, 1)).unwrap();
    let mut port = RequestPort {
        reg: &reg,
        sid,
        space: bench.space().clone(),
    };
    let (ops, dt) = once("pasha session, handle_request", || {
        drive(&mut port, bench.as_ref())
    });
    report_rate(ops, dt);

    section("service: full loopback TCP round-trips");
    let server = Server::bind("127.0.0.1:0", Arc::new(Registry::in_memory())).unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let server_thread = std::thread::spawn(move || server.run());
    let mut client = Client::connect(&addr).unwrap();
    let sid = client.create(&spec(budget, 2)).unwrap();
    let mut port = TcpPort {
        client,
        sid,
        space: bench.space().clone(),
    };
    let (ops, dt) = once("pasha session over TCP", || drive(&mut port, bench.as_ref()));
    println!(
        "  -> {:.0} round-trips/s ({:.1} µs/op)",
        ops as f64 / dt.as_secs_f64().max(1e-9),
        dt.as_secs_f64() * 1e6 / ops.max(1) as f64
    );

    section("service: batched TCP frames (one round-trip per job)");
    let mut batch_client = Client::connect(&addr).unwrap();
    let bsid = batch_client.create(&spec(budget, 3)).unwrap();
    let (report, bdt) = once("pasha session over TCP, batched", || {
        run_worker_batched(
            &mut batch_client,
            &bsid,
            "w0",
            bench.as_ref(),
            0,
            std::time::Duration::from_millis(1),
        )
        .unwrap()
    });
    let bops = report.epochs_told as usize + report.frames;
    println!(
        "  -> {:.0} ops/s across {} frames ({:.1} µs/op, {:.1} ops/frame)",
        bops as f64 / bdt.as_secs_f64().max(1e-9),
        report.frames,
        bdt.as_secs_f64() * 1e6 / bops.max(1) as f64,
        bops as f64 / report.frames.max(1) as f64
    );
    batch_client.shutdown().unwrap();
    let _ = server_thread.join();
    let _ = std::fs::remove_dir_all(&dir);
}
