//! Ablation benches for the design choices called out in DESIGN.md:
//!
//! * scheduler family (PASHA vs ASHA vs synchronous SH vs Hyperband) on
//!   the same workload — quantifies both the runtime saved by PASHA and
//!   the synchronization overhead ASHA removes;
//! * ε percentile N (Table 15 doubles as an ablation);
//! * criss-cross eligibility window (top-rung-only curves vs all trials).

use pasha::benchmarks::nasbench201::NasBench201;
use pasha::ranking::noise::estimate_epsilon;
use pasha::report::experiments::{ablation_schedulers, Scale};
use pasha::scheduler::pasha::PashaBuilder;
use pasha::ranking::RankingSpec;
use pasha::tuner::{Tuner, TunerSpec};
use pasha::util::benchkit::{once, section};
use pasha::util::rng::Rng;

fn main() {
    section("Scheduler family (smoke scale)");
    let (table, _) = once("ablation_schedulers", || {
        ablation_schedulers(&Scale::smoke())
    });
    println!("{}", table.to_text());

    section("ε percentile ablation (CIFAR-100, budget=96)");
    let bench = NasBench201::cifar100();
    let spec = TunerSpec {
        config_budget: 96,
        ..Default::default()
    };
    for n in [80.0, 90.0, 95.0, 100.0] {
        let b = PashaBuilder::with_ranking(RankingSpec::NoiseAdaptive { percentile: n });
        let (r, _) = once(&format!("PASHA N={n}%"), || {
            Tuner::run_with(&bench, &b, &spec, 0, 0)
        });
        println!(
            "    -> acc {:.2}%  runtime {:.2}h  max resources {}",
            r.retrain_accuracy,
            r.runtime_seconds / 3600.0,
            r.max_resources
        );
    }

    section("criss-cross eligibility window");
    // Estimate ε from (a) only deep curves vs (b) all curves including
    // short ones — quantifies why §4.2 restricts to the latest rung.
    let mut rng = Rng::new(5);
    let deep: Vec<Vec<f64>> = (0..12)
        .map(|_| {
            let base = rng.uniform(88.0, 94.0);
            (0..81)
                .map(|e| base * (1.0 - (-(e as f64 + 1.0) / 15.0).exp()) + rng.normal() * 0.5)
                .collect()
        })
        .collect();
    let shallow: Vec<Vec<f64>> = (0..64)
        .map(|_| {
            let base = rng.uniform(20.0, 94.0);
            (0..3)
                .map(|e| base * (1.0 - (-(e as f64 + 1.0) / 15.0).exp()) + rng.normal() * 2.0)
                .collect()
        })
        .collect();
    let deep_views: Vec<(usize, &[f64])> = deep
        .iter()
        .enumerate()
        .map(|(i, c)| (i, c.as_slice()))
        .collect();
    let mut all_views = deep_views.clone();
    for (i, c) in shallow.iter().enumerate() {
        all_views.push((100 + i, c.as_slice()));
    }
    let eps_deep = estimate_epsilon(&deep_views, 90.0);
    let eps_all = estimate_epsilon(&all_views, 90.0);
    println!("eps from top-rung curves only : {eps_deep:?}");
    println!("eps from all curves           : {eps_all:?}");
    println!("(top-rung restriction keeps ε tied to near-convergence noise)");
}
