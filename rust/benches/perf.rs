//! Hot-path microbenchmarks (the §Perf targets in EXPERIMENTS.md):
//!
//! * L3 scheduler internals — ε noise estimation, soft-rank consistency,
//!   RBO/RRR, rung promotion, benchmark-oracle queries, whole simulated
//!   tuning runs (events/sec);
//! * GP fit/predict (the MOBSTER searcher's inner loop);
//! * PJRT artifact execution latency (train step / eval / GP-EI / kNN),
//!   when `make artifacts` has run.

#[cfg(feature = "pjrt")]
use pasha::benchmarks::knn::KnnTable;
use pasha::benchmarks::nasbench201::NasBench201;
use pasha::benchmarks::Benchmark;
use pasha::config::space::Config;
use pasha::ranking::noise::estimate_epsilon;
use pasha::ranking::rbo::rbo;
use pasha::ranking::rrr::rrr;
use pasha::ranking::soft::soft_consistent;
use pasha::scheduler::asha::AshaBuilder;
use pasha::scheduler::pasha::PashaBuilder;
use pasha::scheduler::rung::Rung;
use pasha::scheduler::SchedulerBuilder;
use pasha::searcher::gp::Gp;
use pasha::tuner::{Tuner, TunerSpec};
use pasha::util::benchkit::{bench, once, section};
use pasha::util::rng::Rng;

fn synth_curves(n: usize, len: usize, seed: u64) -> Vec<Vec<f64>> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| {
            let base = rng.uniform(80.0, 94.0);
            (0..len)
                .map(|e| base * (1.0 - (-(e as f64 + 1.0) / 20.0).exp()) + rng.normal())
                .collect()
        })
        .collect()
}

fn main() {
    section("L3: ranking-function hot paths");
    // ε estimation over a realistic top-rung population (the dominant
    // per-result cost inside PASHA)
    for (n, len) in [(8usize, 27usize), (16, 81), (32, 200)] {
        let curves = synth_curves(n, len, 42);
        let views: Vec<(usize, &[f64])> = curves
            .iter()
            .enumerate()
            .map(|(i, c)| (i, c.as_slice()))
            .collect();
        bench(&format!("epsilon_estimate n={n} len={len}"), || {
            std::hint::black_box(estimate_epsilon(&views, 90.0));
        });
    }
    let ranked: Vec<(usize, f64)> = (0..32).map(|i| (i, 100.0 - i as f64)).collect();
    bench("soft_consistent n=32", || {
        std::hint::black_box(soft_consistent(&ranked, &ranked, 0.5));
    });
    let ids: Vec<usize> = (0..32).collect();
    bench("rbo n=32 p=0.5", || {
        std::hint::black_box(rbo(&ids, &ids, 0.5));
    });
    bench("rrr n=32 p=0.5", || {
        std::hint::black_box(rrr(&ranked, &ranked, 0.5, true));
    });

    section("L3: rung promotion");
    let mut rung = Rung::default();
    for t in 0..256 {
        rung.record(t, (t * 37 % 101) as f64);
    }
    bench("promotable scan n=256", || {
        std::hint::black_box(rung.promotable(3));
    });

    section("Benchmark-oracle queries (per-epoch evaluator cost)");
    let nb = NasBench201::cifar10();
    let cfg = Config::cat(4242);
    bench("nasbench201 accuracy_at", || {
        std::hint::black_box(nb.accuracy_at(&cfg, 97, 0));
    });
    let pd1 = pasha::benchmarks::pd1::Pd1::wmt();
    let mut rng = Rng::new(1);
    let pd1_cfg = pd1.space().sample(&mut rng);
    bench("pd1 accuracy_at (1-NN + curve)", || {
        std::hint::black_box(pd1.accuracy_at(&pd1_cfg, 100, 0));
    });
    let table = pd1.knn_table();
    let q = [0.3, 0.4, 0.5, 0.6];
    bench("knn nearest (512×4, rust)", || {
        std::hint::black_box(table.nearest(&q));
    });

    section("Whole tuning runs (simulated, budget=64, 4 workers)");
    let spec = TunerSpec {
        config_budget: 64,
        ..Default::default()
    };
    for (name, builder) in [
        ("ASHA", &AshaBuilder::default() as &dyn SchedulerBuilder),
        ("PASHA", &PashaBuilder::default()),
    ] {
        let (r, dt) = once(&format!("tune {name} cifar10 budget=64"), || {
            Tuner::run_with(&nb, builder, &spec, 0, 0)
        });
        println!(
            "    -> {} jobs, {} epochs, {:.0} sim-seconds ({:.0} jobs/sec wall)",
            r.jobs,
            r.total_epochs,
            r.runtime_seconds,
            r.jobs as f64 / dt.as_secs_f64()
        );
    }

    section("GP searcher inner loop");
    let mut rng = Rng::new(3);
    let x: Vec<Vec<f64>> = (0..64)
        .map(|_| (0..4).map(|_| rng.next_f64()).collect())
        .collect();
    let y: Vec<f64> = x.iter().map(|p| (3.0 * p[0]).sin() + p[1]).collect();
    bench("gp fit n=64 d=4", || {
        std::hint::black_box(Gp::fit(&x, &y, 0.25, 1.0, 1e-3));
    });
    let gp = Gp::fit(&x, &y, 0.25, 1.0, 1e-3).unwrap();
    bench("gp predict n=64", || {
        std::hint::black_box(gp.predict(&[0.2, 0.4, 0.6, 0.8]));
    });

    pjrt_benches(&mut rng, &x, &y, &q);
}

/// PJRT artifact benches — only meaningful when the crate is built with
/// the `pjrt` feature (the `xla` dependency) and `make artifacts` ran.
#[cfg(feature = "pjrt")]
fn pjrt_benches(rng: &mut Rng, x: &[Vec<f64>], y: &[f64], q: &[f64; 4]) {
    section("PJRT artifact execution (L1/L2 via runtime)");
    if !pasha::runtime::artifact::artifacts_available() {
        println!("artifacts not built — run `make artifacts` for PJRT benches");
        return;
    }
    let engine = pasha::runtime::artifact::Engine::cpu().expect("pjrt");
    let (knn_art, _) = once("compile knn artifact", || {
        pasha::runtime::knn::KnnArtifact::load(&engine).unwrap()
    });
    let mut big = KnnTable::new(4);
    for i in 0..512 {
        let v = i as f64 / 512.0;
        big.push(&[v, 1.0 - v, v * v, 0.5]);
    }
    bench("knn nearest (512×4, PJRT artifact)", || {
        std::hint::black_box(knn_art.nearest(&big, q).unwrap());
    });
    let (gp_art, _) = once("compile gp_ei artifact", || {
        pasha::runtime::gp::GpEiArtifact::load(&engine).unwrap()
    });
    let cand: Vec<Vec<f64>> = (0..64)
        .map(|_| (0..4).map(|_| rng.next_f64()).collect())
        .collect();
    bench("gp_ei n=64 m=64 (PJRT artifact)", || {
        std::hint::black_box(gp_art.run(x, y, &cand, 1.0, 0.25, 1.0, 1e-3).unwrap());
    });
    let spec = pasha::benchmarks::realtrain::RealTrainSpec {
        hidden: 64,
        max_epochs: 4,
        data_seed: 0,
    };
    let (trainer, _) = once("compile mlp train+eval artifacts (h=64)", || {
        pasha::runtime::trainer::MlpTrainer::new(&engine, spec).unwrap()
    });
    use pasha::config::space::ParamValue as P;
    let tcfg = Config::new(vec![
        P::Float(0.1),
        P::Float(0.1),
        P::Float(1.0),
        P::Float(0.8),
    ]);
    let mut trial = 0usize;
    bench("mlp train 1 epoch (32 steps + eval, PJRT)", || {
        trial += 1;
        std::hint::black_box(trainer.train_epochs(trial, &tcfg, 0, 1).unwrap());
        trainer.release(trial);
    });
    let params = pasha::runtime::trainer::init_params(64, 0);
    bench("mlp eval (1024×32, PJRT)", || {
        std::hint::black_box(trainer.evaluate(&params).unwrap());
    });
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_benches(_rng: &mut Rng, _x: &[Vec<f64>], _y: &[f64], _q: &[f64; 4]) {
    section("PJRT artifact execution (L1/L2 via runtime)");
    println!("built without the `pjrt` feature — skipping artifact benches");
}
