//! End-to-end benches: one per paper table. Each regenerates the table
//! at smoke scale (full scale is `pasha table <n> --scale paper`),
//! printing the rows and the wall time of the whole experiment — the
//! "does the experiment pipeline run fast enough to iterate on" signal.
//!
//! Run a subset with e.g. `cargo bench --bench tables -- table1 table13`.

use pasha::benchmarks::nasbench201::Nb201Dataset;
use pasha::report::experiments::{self, Scale};
use pasha::util::benchkit::{once, section};

fn scale() -> Scale {
    Scale::smoke()
}

fn wants(filter: &[String], name: &str) -> bool {
    filter.is_empty() || filter.iter().any(|f| name.contains(f.as_str()))
}

fn main() {
    let filter: Vec<String> = std::env::args()
        .skip(1)
        .filter(|a| !a.starts_with('-'))
        .collect();
    let sc = scale();

    let print = |tables: Vec<pasha::util::table::Table>| {
        for t in &tables {
            println!("{}", t.to_text());
        }
    };

    if wants(&filter, "table1") {
        section("Table 1 — NASBench201 main results");
        let (tables, _) = once("table1 (3 datasets × 4 approaches, smoke)", || {
            experiments::table1(&sc)
        });
        print(tables);
    }
    if wants(&filter, "table2") {
        section("Table 2 — reduction factors (CIFAR-100)");
        let (tables, _) = once("table2 (eta 2/4)", || experiments::table2(&sc));
        print(tables);
    }
    if wants(&filter, "table3") {
        section("Table 3 — MOBSTER vs PASHA BO");
        let (tables, _) = once("table3 (GP searcher, 3 datasets)", || {
            experiments::table3(&sc)
        });
        print(tables);
    }
    if wants(&filter, "table4") {
        section("Table 4 — ranking functions (CIFAR-100 selection)");
        let (t, _) = once("table4 (19 ranking variants)", || {
            experiments::table_rankings(Nb201Dataset::Cifar100, &sc, 4)
        });
        println!("{}", t.to_text());
    }
    if wants(&filter, "table5") || wants(&filter, "table7") {
        section("Table 5/7 — PD1 (WMT + ImageNet) with k-epoch baselines");
        let (tables, _) = once("table5 (2 tasks × 7 approaches)", || {
            experiments::table5(&sc)
        });
        print(tables);
    }
    if wants(&filter, "table6") {
        section("Table 6 — NASBench201 extra baselines");
        let (tables, _) = once("table6", || experiments::table6(&sc));
        print(tables);
    }
    if wants(&filter, "table8") {
        section("Table 8 — reduction factors (all datasets)");
        let (tables, _) = once("table8", || experiments::table8(&sc));
        print(tables);
    }
    if wants(&filter, "table9") {
        section("Table 9 — ranking functions (CIFAR-10)");
        let (t, _) = once("table9", || {
            experiments::table_rankings(Nb201Dataset::Cifar10, &sc, 9)
        });
        println!("{}", t.to_text());
    }
    if wants(&filter, "table10") {
        section("Table 10 — ranking functions (CIFAR-100)");
        let (t, _) = once("table10", || {
            experiments::table_rankings(Nb201Dataset::Cifar100, &sc, 10)
        });
        println!("{}", t.to_text());
    }
    if wants(&filter, "table11") {
        section("Table 11 — ranking functions (ImageNet16-120)");
        let (t, _) = once("table11", || {
            experiments::table_rankings(Nb201Dataset::ImageNet16_120, &sc, 11)
        });
        println!("{}", t.to_text());
    }
    if wants(&filter, "table12") {
        section("Table 12 — PD1 ranking functions");
        let (tables, _) = once("table12", || experiments::table12(&sc));
        print(tables);
    }
    if wants(&filter, "table13") {
        section("Table 13 — LCBench (34 datasets)");
        let (t, _) = once("table13 (34 datasets × ASHA/PASHA)", || {
            experiments::table13(&sc, 34)
        });
        println!("{}", t.to_text());
    }
    if wants(&filter, "table14") {
        section("Table 14 — variable maximum resources");
        let (tables, _) = once("table14 (3 datasets × 200/50 epochs)", || {
            experiments::table14(&sc)
        });
        print(tables);
    }
    if wants(&filter, "table15") {
        section("Table 15 — ε percentile N");
        let (tables, _) = once("table15 (N ∈ 100/95/90/80)", || {
            experiments::table15(&sc)
        });
        print(tables);
    }
}
