//! Integration tests: the qualitative *shape* of the paper's headline
//! results must hold end-to-end through the full tuner stack (searcher ×
//! scheduler × surrogate benchmark × discrete-event executor) at reduced
//! repetition scale.

use pasha::benchmarks::lcbench::LcBench;
use pasha::benchmarks::nasbench201::{NasBench201, Nb201Dataset};
use pasha::benchmarks::pd1::Pd1;
use pasha::benchmarks::Benchmark;
use pasha::ranking::RankingSpec;
use pasha::scheduler::asha::AshaBuilder;
use pasha::scheduler::baselines::{FixedEpochBuilder, RandomBaselineBuilder};
use pasha::scheduler::pasha::PashaBuilder;
use pasha::scheduler::SchedulerBuilder;
use pasha::tuner::{Tuner, TuneResult, TunerSpec};
use pasha::util::stats::mean;

fn spec(budget: usize) -> TunerSpec {
    TunerSpec {
        config_budget: budget,
        ..Default::default()
    }
}

fn runs(
    bench: &dyn Benchmark,
    b: &dyn SchedulerBuilder,
    budget: usize,
    seeds: std::ops::Range<u64>,
) -> Vec<TuneResult> {
    seeds
        .map(|s| Tuner::run_with(bench, b, &spec(budget), s, s % 3))
        .collect()
}

fn acc(rs: &[TuneResult]) -> f64 {
    mean(&rs.iter().map(|r| r.retrain_accuracy).collect::<Vec<_>>())
}

fn runtime(rs: &[TuneResult]) -> f64 {
    mean(&rs.iter().map(|r| r.runtime_seconds).collect::<Vec<_>>())
}

/// Table 1 shape: PASHA ≈ ASHA accuracy, ≥1.5× speedup, one-epoch and
/// random baselines strictly ordered below, across all three datasets.
#[test]
fn table1_shape_all_datasets() {
    for ds in [
        Nb201Dataset::Cifar10,
        Nb201Dataset::Cifar100,
        Nb201Dataset::ImageNet16_120,
    ] {
        let bench = NasBench201::new(ds);
        let asha = runs(&bench, &AshaBuilder::default(), 128, 0..4);
        let pasha = runs(&bench, &PashaBuilder::default(), 128, 0..4);
        let one_ep = runs(&bench, &FixedEpochBuilder { epochs: 1 }, 128, 0..4);
        let random = runs(&bench, &RandomBaselineBuilder, 128, 0..4);

        let speedup = runtime(&asha) / runtime(&pasha);
        assert!(
            speedup >= 1.5,
            "{}: PASHA speedup {speedup:.2} < 1.5",
            bench.name()
        );
        assert!(
            (acc(&asha) - acc(&pasha)).abs() < 3.0,
            "{}: accuracy parity broken: asha {:.2} pasha {:.2}",
            bench.name(),
            acc(&asha),
            acc(&pasha)
        );
        assert!(
            acc(&random) + 5.0 < acc(&one_ep),
            "{}: random must be far below one-epoch",
            bench.name()
        );
        assert!(
            acc(&one_ep) <= acc(&asha) + 1.0,
            "{}: one-epoch must not beat ASHA: {:.2} vs {:.2}",
            bench.name(),
            acc(&one_ep),
            acc(&asha)
        );
        // PASHA's whole point: it stops well below the safety net
        let pasha_max = mean(&pasha.iter().map(|r| r.max_resources as f64).collect::<Vec<_>>());
        assert!(
            pasha_max < 100.0,
            "{}: PASHA max resources {pasha_max} should be far below 200",
            bench.name()
        );
    }
}

/// Table 2/8 shape: the speedup persists across reduction factors.
#[test]
fn reduction_factor_shape() {
    let bench = NasBench201::cifar100();
    for eta in [2u32, 4] {
        // full N=256: smaller budgets cannot fill the η=4 rung pyramid
        let asha = runs(&bench, &AshaBuilder { r_min: 1, eta }, 256, 0..5);
        let pasha = runs(
            &bench,
            &PashaBuilder {
                r_min: 1,
                eta,
                ranking: RankingSpec::default(),
            },
            256,
            0..5,
        );
        // η=2 gives PASHA more decision points (paper: 4.2x); η=4 fewer
        // (paper: 2.8x; our surrogate yields a weaker but still >1 factor)
        let floor = if eta == 2 { 1.3 } else { 1.1 };
        let speedup = runtime(&asha) / runtime(&pasha);
        assert!(speedup > floor, "eta={eta}: speedup {speedup:.2}");
        assert!((acc(&asha) - acc(&pasha)).abs() < 3.5, "eta={eta}");
    }
}

/// Table 5 shape: WMT (8 rung levels) gives a much larger PASHA speedup
/// than PD1-ImageNet (6 levels), and both beat 2×/1× respectively.
#[test]
fn pd1_speedup_grows_with_rung_count() {
    let wmt = Pd1::wmt();
    let inet = Pd1::imagenet();
    let wmt_speedup = runtime(&runs(&wmt, &AshaBuilder::default(), 256, 0..3))
        / runtime(&runs(&wmt, &PashaBuilder::default(), 256, 0..3));
    let inet_speedup = runtime(&runs(&inet, &AshaBuilder::default(), 256, 0..3))
        / runtime(&runs(&inet, &PashaBuilder::default(), 256, 0..3));
    // paper: 15.5x on WMT vs 1.9x on ImageNet. Our surrogate preserves
    // the ordering and a >1.8x WMT factor (the absolute gap depends on how
    // deep ASHA's promotion pyramid happens to reach per seed).
    assert!(
        wmt_speedup + 0.3 > inet_speedup,
        "wmt {wmt_speedup:.1} vs imagenet {inet_speedup:.1}"
    );
    assert!(wmt_speedup > 1.8, "wmt speedup {wmt_speedup:.1}");
    assert!(inet_speedup > 1.1, "imagenet speedup {inet_speedup:.1}");
}

/// Table 13 / Appendix D shape: LCBench's 50-epoch budget (5 rung
/// levels) limits PASHA to modest speedups — and accuracy stays on par.
#[test]
fn lcbench_modest_speedup() {
    let mut speedups = Vec::new();
    for name in ["Fashion-MNIST", "Higgs", "Adult"] {
        let bench = LcBench::new(name);
        let asha = runs(&bench, &AshaBuilder::default(), 96, 0..3);
        let pasha = runs(&bench, &PashaBuilder::default(), 96, 0..3);
        let s = runtime(&asha) / runtime(&pasha);
        assert!(
            (acc(&asha) - acc(&pasha)).abs() < 4.0,
            "{name}: accuracy parity"
        );
        speedups.push(s);
    }
    let avg = mean(&speedups);
    assert!(
        avg < 3.0,
        "LCBench speedups should be modest, got avg {avg:.1} ({speedups:?})"
    );
    assert!(avg > 0.8, "PASHA should not be slower: {avg:.1}");
}

/// Table 14 shape: more epochs (more rungs) ⇒ larger PASHA speedup.
#[test]
fn speedup_grows_with_max_epochs() {
    let b200 = NasBench201::with_max_epochs(Nb201Dataset::Cifar100, 200);
    let b50 = NasBench201::with_max_epochs(Nb201Dataset::Cifar100, 50);
    let s200 = runtime(&runs(&b200, &AshaBuilder::default(), 96, 0..3))
        / runtime(&runs(&b200, &PashaBuilder::default(), 96, 0..3));
    let s50 = runtime(&runs(&b50, &AshaBuilder::default(), 96, 0..3))
        / runtime(&runs(&b50, &PashaBuilder::default(), 96, 0..3));
    assert!(
        s200 > s50,
        "200-epoch speedup {s200:.1} must exceed 50-epoch {s50:.1}"
    );
}

/// Table 4 shape: direct ranking ≈ no early stop (max resources near R),
/// noise-adaptive stops early.
#[test]
fn direct_ranking_defaults_to_asha() {
    let bench = NasBench201::cifar100();
    let direct = runs(
        &bench,
        &PashaBuilder::with_ranking(RankingSpec::Direct),
        128,
        0..3,
    );
    let adaptive = runs(&bench, &PashaBuilder::default(), 128, 0..3);
    let d_max = mean(&direct.iter().map(|r| r.max_resources as f64).collect::<Vec<_>>());
    let a_max = mean(&adaptive.iter().map(|r| r.max_resources as f64).collect::<Vec<_>>());
    assert!(
        d_max > a_max,
        "direct {d_max:.0} must use more resources than adaptive {a_max:.0}"
    );
    assert!(d_max > 80.0, "direct ranking should grow far: {d_max:.0}");
}

/// The tuner's protocol invariants (§5.1) hold for every scheduler.
#[test]
fn protocol_invariants() {
    let bench = NasBench201::cifar10();
    let builders: Vec<Box<dyn SchedulerBuilder>> = vec![
        Box::new(AshaBuilder::default()),
        Box::new(PashaBuilder::default()),
        Box::new(FixedEpochBuilder { epochs: 3 }),
        Box::new(RandomBaselineBuilder),
    ];
    for b in &builders {
        let r = Tuner::run_with(&bench, b.as_ref(), &spec(64), 0, 0);
        assert_eq!(r.configs_sampled, 64, "{}", b.name());
        assert!(r.max_resources <= bench.max_epochs());
        assert!(r.best_config.is_some());
        assert!(
            (0.0..=100.0).contains(&r.retrain_accuracy),
            "{}: retrain {:.2}",
            b.name(),
            r.retrain_accuracy
        );
    }
}
