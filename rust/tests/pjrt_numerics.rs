//! Cross-layer numerics: the AOT-compiled JAX/Pallas artifacts executed
//! through PJRT must agree with independent pure-Rust reimplementations.
//! Skipped gracefully (with a note) before `make artifacts`; the whole
//! suite only exists when the crate is built with the `pjrt` feature.
#![cfg(feature = "pjrt")]

use pasha::benchmarks::realtrain::{Dataset, RealTrainSpec, CLASSES, FEATURES, VAL_N};
use pasha::config::space::{Config, ParamValue as P};
use pasha::runtime::artifact::{artifacts_available, Engine};
use pasha::runtime::trainer::{init_params, MlpTrainer};

fn skip() -> bool {
    if !artifacts_available() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return true;
    }
    false
}

/// Pure-Rust forward pass of the MLP (independent of the HLO graph).
fn rust_forward(params: &[Vec<f32>], hidden: usize, x: &[f32]) -> Vec<f32> {
    let lin = |x: &[f32], w: &[f32], b: &[f32], i: usize, o: usize, relu: bool| {
        let rows = x.len() / i;
        let mut y = vec![0f32; rows * o];
        for r in 0..rows {
            for c in 0..o {
                let mut acc = b[c];
                for k in 0..i {
                    acc += x[r * i + k] * w[k * o + c];
                }
                y[r * o + c] = if relu { acc.max(0.0) } else { acc };
            }
        }
        y
    };
    let h1 = lin(x, &params[0], &params[1], FEATURES, hidden, true);
    let h2 = lin(&h1, &params[2], &params[3], hidden, hidden, true);
    lin(&h2, &params[4], &params[5], hidden, CLASSES, false)
}

#[test]
fn eval_step_accuracy_matches_rust_forward() {
    if skip() {
        return;
    }
    let engine = Engine::cpu().unwrap();
    let spec = RealTrainSpec {
        hidden: 64,
        max_epochs: 3,
        data_seed: 0,
    };
    let trainer = MlpTrainer::new(&engine, spec).unwrap();
    let params = init_params(64, 42);
    let (loss, acc) = trainer.evaluate(&params).unwrap();
    assert!(loss > 0.0);

    // independent Rust forward over the same validation set
    let ds = Dataset::generate(0);
    let logits = rust_forward(&params, 64, &ds.val_x);
    let mut correct = 0usize;
    for r in 0..VAL_N {
        let row = &logits[r * CLASSES..(r + 1) * CLASSES];
        let pred = row
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        if pred as i32 == ds.val_y[r] {
            correct += 1;
        }
    }
    let rust_acc = 100.0 * correct as f64 / VAL_N as f64;
    assert!(
        (acc - rust_acc).abs() < 0.5,
        "PJRT acc {acc:.2} vs rust forward acc {rust_acc:.2}"
    );
}

#[test]
fn training_monotonically_learns_separable_task() {
    if skip() {
        return;
    }
    let engine = Engine::cpu().unwrap();
    let spec = RealTrainSpec {
        hidden: 128,
        max_epochs: 8,
        data_seed: 1,
    };
    let trainer = MlpTrainer::new(&engine, spec).unwrap();
    let config = Config::new(vec![
        P::Float(0.1),
        P::Float(0.1),
        P::Float(1.0),
        P::Float(0.8),
    ]);
    let accs = trainer.train_epochs(0, &config, 0, 5).unwrap();
    assert_eq!(accs.len(), 5);
    assert!(accs[4] > 80.0, "h128 should learn the blobs task: {accs:?}");
    // broadly increasing (allow small wobbles)
    assert!(accs[4] + 2.0 > accs[0]);
}

#[test]
fn hidden_variants_all_compile_and_run() {
    if skip() {
        return;
    }
    let engine = Engine::cpu().unwrap();
    for hidden in [64usize, 128, 256] {
        let spec = RealTrainSpec {
            hidden,
            max_epochs: 2,
            data_seed: 0,
        };
        let trainer = MlpTrainer::new(&engine, spec).unwrap();
        let params = init_params(hidden, 0);
        let (loss, acc) = trainer.evaluate(&params).unwrap();
        assert!(loss.is_finite() && (0.0..=100.0).contains(&acc), "h{hidden}");
    }
}

#[test]
fn momentum_semantics_match_rust_update() {
    if skip() {
        return;
    }
    // Run one PJRT train step with lr=0: parameters must stay identical
    // even with nonzero momentum input state.
    let engine = Engine::cpu().unwrap();
    let spec = RealTrainSpec {
        hidden: 64,
        max_epochs: 1,
        data_seed: 0,
    };
    let trainer = MlpTrainer::new(&engine, spec).unwrap();
    let frozen = Config::new(vec![
        // lr lower bound of the space; schedule floor keeps it ~1e-5
        P::Float(1e-5),
        P::Float(0.5),
        P::Float(1.0),
        P::Float(0.5),
    ]);
    let before = init_params(64, 7);
    let accs = trainer.train_epochs(9, &frozen, 0, 1).unwrap();
    assert_eq!(accs.len(), 1);
    // with lr ≈ 1e-5 the parameters barely move: accuracy ≈ untrained
    let (_, acc0) = trainer.evaluate(&before).unwrap();
    assert!(
        (accs[0] - acc0).abs() < 12.0,
        "tiny-lr epoch moved accuracy too far: {acc0:.1} -> {:.1}",
        accs[0]
    );
}
