//! Failure injection: the tuner must survive degenerate evaluators —
//! NaN metrics (diverged training), zero-cost jobs, constant metrics
//! (total ties) — and still terminate with sane output.

use pasha::benchmarks::Benchmark;
use pasha::config::space::{Config, SearchSpace};
use pasha::executor::sim::run_sim;
use pasha::executor::{Advance, Evaluator, SurrogateEvaluator};
use pasha::scheduler::asha::AshaBuilder;
use pasha::scheduler::pasha::PashaBuilder;
use pasha::scheduler::SchedulerBuilder;
use pasha::searcher::random::RandomSearcher;
use pasha::TrialId;

/// Evaluator where a fraction of trials "diverge" to NaN.
struct NanEvaluator {
    nan_every: usize,
}

impl Evaluator for NanEvaluator {
    fn advance(&mut self, trial: TrialId, _c: &Config, from: u32, to: u32) -> Advance {
        let diverged = trial % self.nan_every == 0;
        let accs = (from + 1..=to)
            .map(|e| {
                if diverged {
                    f64::NAN
                } else {
                    50.0 + (trial % 10) as f64 + e as f64 * 0.01
                }
            })
            .collect();
        Advance {
            accs,
            cost_seconds: (to - from) as f64,
        }
    }
}

/// Evaluator with identical metrics for every trial (total ties).
struct ConstantEvaluator;

impl Evaluator for ConstantEvaluator {
    fn advance(&mut self, _t: TrialId, _c: &Config, from: u32, to: u32) -> Advance {
        Advance {
            accs: (from + 1..=to).map(|_| 42.0).collect(),
            cost_seconds: (to - from) as f64,
        }
    }
}

fn run_with(
    builder: &dyn SchedulerBuilder,
    evaluator: &mut dyn Evaluator,
    budget: usize,
) -> (pasha::executor::sim::SimStats, Box<dyn pasha::scheduler::Scheduler>) {
    let space = SearchSpace::nas(10_000);
    let mut scheduler = builder.build(81, 0);
    let mut searcher = RandomSearcher::new(0);
    let stats = run_sim(
        scheduler.as_mut(),
        &mut searcher,
        &space,
        budget,
        4,
        evaluator,
    );
    (stats, scheduler)
}

#[test]
fn nan_metrics_do_not_poison_best() {
    for builder in [
        &AshaBuilder::default() as &dyn SchedulerBuilder,
        &PashaBuilder::default(),
    ] {
        let (stats, sched) = run_with(builder, &mut NanEvaluator { nan_every: 3 }, 48);
        assert_eq!(stats.configs_sampled, 48);
        let best = sched.best().expect("must still pick a best");
        assert!(
            best.metric.is_finite(),
            "{}: best metric must be finite, got {}",
            sched.name(),
            best.metric
        );
    }
}

#[test]
fn all_nan_still_terminates() {
    let (stats, sched) = run_with(
        &PashaBuilder::default(),
        &mut NanEvaluator { nan_every: 1 },
        24,
    );
    assert_eq!(stats.configs_sampled, 24);
    // nothing finite: best falls back to the first trial
    let best = sched.best().unwrap();
    assert_eq!(best.trial, 0);
}

#[test]
fn constant_metrics_terminate_with_stable_ranking() {
    // Total ties: soft ranking sees a perfectly consistent ranking, so
    // PASHA must stop at the initial cap rather than looping.
    let (stats, sched) = run_with(&PashaBuilder::default(), &mut ConstantEvaluator, 48);
    assert_eq!(stats.configs_sampled, 48);
    assert!(
        sched.max_resources_used() <= 9,
        "ties must not trigger growth: {}",
        sched.max_resources_used()
    );
}

#[test]
fn zero_config_budget_is_a_noop() {
    let bench = pasha::benchmarks::nasbench201::NasBench201::cifar10();
    let mut evaluator = SurrogateEvaluator {
        bench: &bench,
        bench_seed: 0,
    };
    let space = bench.space().clone();
    let mut scheduler = PashaBuilder::default().build(bench.max_epochs(), 0);
    let mut searcher = RandomSearcher::new(0);
    let stats = run_sim(scheduler.as_mut(), &mut searcher, &space, 0, 4, &mut evaluator);
    assert_eq!(stats.jobs, 0);
    assert!(scheduler.best().is_none());
}

#[test]
fn single_worker_and_many_workers_agree_on_sampled_configs() {
    let bench = pasha::benchmarks::nasbench201::NasBench201::cifar10();
    let space = bench.space().clone();
    let count = |workers: usize| {
        let mut evaluator = SurrogateEvaluator {
            bench: &bench,
            bench_seed: 0,
        };
        let mut scheduler = AshaBuilder::default().build(bench.max_epochs(), 0);
        let mut searcher = RandomSearcher::new(3);
        run_sim(
            scheduler.as_mut(),
            &mut searcher,
            &space,
            32,
            workers,
            &mut evaluator,
        )
        .configs_sampled
    };
    assert_eq!(count(1), 32);
    assert_eq!(count(16), 32);
}
