//! End-to-end tests for the ask/tell tuning service.
//!
//! * **Journal recovery property** — run a multi-worker session to
//!   completion, journaling every mutating op; truncate the journal at
//!   many points (whole-event and mid-line); recover; replay the
//!   remainder of the reference op trace and require every subsequent
//!   `ask` response to be byte-identical, and the final incumbent to
//!   match the uninterrupted run exactly. Covers ASHA, PASHA, the
//!   stopping-type variants (mid-rung kills with pauses pending and jobs
//!   in flight) and a BO-searcher session.
//! * **TCP equivalence** — `serve` + `worker` over localhost must land
//!   on the same incumbent as the in-process `Tuner::run` for the same
//!   seeds.
//! * **Snapshot equivalence** — cut the journal at any event index:
//!   recovery from (snapshot + tail) and from the full journal must
//!   produce byte-identical subsequent asks and the same final
//!   incumbent, for every scheduler family and the BO searcher.
//! * **Torn-snapshot fuzzing** — truncate the snapshot sidecar at every
//!   byte boundary: recovery falls back to the prior snapshot (or full
//!   replay), never panics, and the `RecoveryReport` accounting stays
//!   exact.
//! * **Batched-wire equivalence** — the same op sequence issued in
//!   `batch` frames and singly must leave byte-identical journals and
//!   the same incumbent.
//! * **Observability conservation** — stress a multi-worker session,
//!   scrape the `stats` wire op and the Prometheus endpoint against the
//!   live server, and require the counters to conserve against the
//!   journal on disk (acked asks == journaled ask events, fsyncs ≤
//!   events + 1, in-flight drains to 0 at shutdown).
//! * **Metrics inertness** — identical sessions with the metrics gate
//!   on and off must leave byte-identical journals.
//! * **Replication & failover** — `serve --replicate` streams every
//!   durable commit group to a `follow` process whose journal directory
//!   stays a byte-identical mirror; SIGKILL the leader mid-tune and the
//!   promoted follower completes the session through the `route`
//!   session router with byte-identical asks and the same incumbent.
//!   Randomized kill points prove (snapshot + tail) and full-replay
//!   recovery agree byte-for-byte from both the leader's and the
//!   follower's directory.
//! * **Worker-lease expiry** — a worker that dies mid-job is expired by
//!   the per-shard liveness tick and its job re-assigned verbatim to
//!   the next asking worker; a forced shutdown drain honors its
//!   configured deadline without losing acked-and-durable ops.

use pasha::benchmarks::Benchmark;
use pasha::scheduler::asktell::{assignment_json, config_from_json, TellAck, TrialAssignment};
use pasha::service::journal::snapshot_path;
use pasha::service::{
    run_worker, run_worker_batched, Client, Registry, Server, Session, SessionOptions,
};
use pasha::spec::{ExperimentSpec, SearcherSpec};
use pasha::tuner::Tuner;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

fn tmp_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("pasha-e2e-{}-{name}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// One step of the deterministic reference trace.
#[derive(Clone, Debug)]
enum Op {
    /// `ask` by `worker`, with the canonical response bytes.
    Ask { worker: usize, resp: String },
    /// `tell(trial, epoch, metric)` by some worker, with the ack.
    Tell {
        trial: usize,
        epoch: u32,
        metric: f64,
        ack: TellAck,
    },
}

/// A recorded op plus the number of journal events written up to and
/// including it (the alignment key between trace and journal lines).
struct Traced {
    op: Op,
    events_after: usize,
}

fn worker_name(w: usize) -> String {
    format!("w{w}")
}

/// Drive `session` to completion with `workers` round-robin synchronous
/// workers (one op per worker per round), recording every op. The
/// round-robin order makes the whole trace a pure function of the
/// session spec, while still interleaving jobs so kills land mid-rung
/// with work in flight.
fn drive_traced(
    session: &mut Session,
    bench: &dyn Benchmark,
    bench_seed: u64,
    workers: usize,
) -> Vec<Traced> {
    let mut trace = Vec::new();
    let mut jobs: Vec<Option<(pasha::scheduler::Job, u32)>> = vec![None; workers];
    let mut done = vec![false; workers];
    while !done.iter().all(|&d| d) {
        for w in 0..workers {
            if done[w] {
                continue;
            }
            match jobs[w].take() {
                None => {
                    let assignment = session.ask(&worker_name(w)).unwrap();
                    let resp = assignment_json(&assignment).to_string_compact();
                    // events_journaled is the exact journal line count
                    // (minus the create header) — the alignment key
                    trace.push(Traced {
                        op: Op::Ask { worker: w, resp },
                        events_after: session.events_journaled(),
                    });
                    match assignment {
                        TrialAssignment::Run(job) => {
                            let from = job.from_epoch;
                            jobs[w] = Some((job, from + 1));
                        }
                        TrialAssignment::Done => done[w] = true,
                        _ => {}
                    }
                }
                Some((job, epoch)) => {
                    let metric = bench.accuracy_at(&job.config, epoch, bench_seed);
                    let ack = session.tell(job.trial, epoch, metric).unwrap();
                    trace.push(Traced {
                        op: Op::Tell {
                            trial: job.trial,
                            epoch,
                            metric,
                            ack,
                        },
                        events_after: session.events_journaled(),
                    });
                    if ack == TellAck::Continue {
                        jobs[w] = Some((job, epoch + 1));
                    }
                }
            }
        }
    }
    trace
}

/// Replay the trace tail on a recovered session, asserting byte-identical
/// ask responses and identical tell acks. Returns the number of asks
/// compared.
fn replay_tail(session: &mut Session, tail: &[&Traced], label: &str) -> usize {
    let mut asks = 0usize;
    for t in tail {
        match &t.op {
            Op::Ask { worker, resp } => {
                let replayed = session.ask(&worker_name(*worker)).unwrap();
                let replayed = assignment_json(&replayed).to_string_compact();
                assert_eq!(&replayed, resp, "{label}: ask #{asks} diverged after recovery");
                asks += 1;
            }
            Op::Tell {
                trial,
                epoch,
                metric,
                ack,
            } => {
                let replayed = session.tell(*trial, *epoch, *metric).unwrap();
                assert_eq!(replayed, *ack, "{label}: tell ack diverged after recovery");
            }
        }
    }
    asks
}

fn spec_for(scheduler: &str, searcher: SearcherSpec, budget: usize) -> ExperimentSpec {
    let mut spec = ExperimentSpec::named("lcbench-Fashion-MNIST", scheduler).unwrap();
    spec.searcher = searcher;
    spec.seed = 5;
    spec.stop.config_budget = budget;
    spec
}

/// The recovery property for one session spec: every cut of the journal
/// recovers to a state whose continuation is byte-identical to the
/// uninterrupted run.
fn check_recovery(label: &str, spec: ExperimentSpec, workers: usize) {
    let dir = tmp_dir(label);
    let path = dir.join("session.jsonl");
    let bench = spec.bench.build().unwrap();

    let mut live = Session::create("s0", spec.clone(), Some(&path)).unwrap();
    let trace = drive_traced(&mut live, bench.as_ref(), spec.bench_seed, workers);
    let best_full = live.core_ref().best().expect("session found an incumbent");
    drop(live);

    let lines: Vec<String> = std::fs::read_to_string(&path)
        .unwrap()
        .lines()
        .map(|l| l.to_string())
        .collect();
    let total_events = lines.len() - 1; // minus the create header
    assert!(total_events > 20, "{label}: workload too small to cut");

    // Whole-event cuts across the run, denser around the middle, plus a
    // couple of mid-line byte cuts (crash artifacts).
    let mut cuts: Vec<usize> = (0..8).map(|i| 1 + i * total_events / 8).collect();
    cuts.push(total_events); // recover the completed journal too
    let mut saw_pause_mid_rung = false;
    for (i, &cut) in cuts.iter().enumerate() {
        let cut_path = dir.join(format!("cut-{i}.jsonl"));
        let mut content = lines[..=cut].join("\n");
        content.push('\n');
        if i % 3 == 1 && cut < total_events {
            // torn final append: recovery must drop the partial line
            let partial = &lines[cut + 1][..lines[cut + 1].len() / 2];
            content.push_str(partial);
        }
        std::fs::write(&cut_path, &content).unwrap();

        let (mut recovered, report) = Session::recover(&cut_path).unwrap();
        assert_eq!(report.events_replayed, cut, "{label}: replay count at cut {cut}");
        let core = recovered.core_ref();
        if core.stats().paused_trials > 0 && core.in_flight_count() > 0 {
            saw_pause_mid_rung = true;
        }
        let tail: Vec<&Traced> = trace.iter().filter(|t| t.events_after > cut).collect();
        let asks = replay_tail(&mut recovered, &tail, label);
        if cut < total_events {
            assert!(asks > 0, "{label}: cut {cut} left no asks to compare");
        }
        // after the full tail, the incumbent must match exactly
        let best = recovered.core_ref().best().expect("recovered incumbent");
        assert_eq!(best.trial, best_full.trial, "{label}: best trial");
        assert_eq!(
            best.metric.to_bits(),
            best_full.metric.to_bits(),
            "{label}: best metric"
        );
        assert_eq!(best.config, best_full.config, "{label}: best config");
    }
    if label.contains("pasha-stop") {
        assert!(
            saw_pause_mid_rung,
            "{label}: no cut landed mid-rung with a pause pending — \
             the scenario the journal must survive"
        );
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn recovery_asha() {
    check_recovery("asha", spec_for("asha", SearcherSpec::Random, 32), 3);
}

#[test]
fn recovery_pasha() {
    check_recovery("pasha", spec_for("pasha", SearcherSpec::Random, 32), 3);
}

#[test]
fn recovery_asha_stop() {
    check_recovery("asha-stop", spec_for("asha-stop", SearcherSpec::Random, 32), 3);
}

#[test]
fn recovery_pasha_stop_mid_rung_pause() {
    // The stopping-type PASHA session: kills land while trials are
    // paused at the resource cap and other jobs are mid-flight.
    check_recovery("pasha-stop", spec_for("pasha-stop", SearcherSpec::Random, 48), 3);
}

#[test]
fn recovery_lce() {
    // Learning-curve extrapolation: the per-trial fit state is rebuilt
    // bit-exactly from replayed curves (fitting is deterministic), so
    // extrapolated stop/promote decisions — and therefore asks — must
    // stay byte-identical at every cut.
    check_recovery("lce", spec_for("lce", SearcherSpec::Random, 48), 3);
}

#[test]
fn recovery_bo_searcher() {
    // Model-based searcher: the GP's state is rebuilt through replayed
    // on_report calls, so ask responses stay byte-identical.
    check_recovery("bo", spec_for("pasha", SearcherSpec::bo_default(), 16), 2);
}

/// The snapshot-equivalence property for one session spec: at every cut
/// of the journal, recovery from (snapshot + tail) and recovery from the
/// full journal must reach the same state — byte-identical subsequent
/// asks, identical tell acks, identical final incumbent — and the
/// snapshot path must replay only post-snapshot events.
fn check_snapshot_equivalence(
    label: &str,
    spec: ExperimentSpec,
    workers: usize,
    interval: usize,
) {
    let dir = tmp_dir(&format!("snapeq-{label}"));
    let path = dir.join("session.jsonl");
    let bench = spec.bench.build().unwrap();

    // Snapshots on, compaction off: the full journal stays available, so
    // any cut index can be reconstructed alongside its sidecar prefix.
    let options = SessionOptions {
        snapshot_every: Some(interval),
        compact_on_snapshot: false,
        ..SessionOptions::default()
    };
    let mut live = Session::create_with("s0", spec.clone(), Some(&path), options).unwrap();
    let trace = drive_traced(&mut live, bench.as_ref(), spec.bench_seed, workers);
    let best_full = live.core_ref().best().expect("session found an incumbent");
    let snapshot_points = live.snapshots().to_vec();
    drop(live);
    assert!(
        snapshot_points.len() >= 2,
        "{label}: workload too small for several snapshots: {snapshot_points:?}"
    );

    let lines: Vec<String> = std::fs::read_to_string(&path)
        .unwrap()
        .lines()
        .map(|l| l.to_string())
        .collect();
    let snap_lines: Vec<String> = std::fs::read_to_string(snapshot_path(&path))
        .unwrap()
        .lines()
        .map(|l| l.to_string())
        .collect();
    // coverage of each sidecar line, aligned with snap_lines
    let covered: Vec<usize> = snap_lines
        .iter()
        .map(|l| {
            pasha::util::json::parse(l).unwrap().get("events").unwrap().as_f64().unwrap() as usize
        })
        .collect();
    let total_events = lines.len() - 1;

    let mut cuts: Vec<usize> = (0..6).map(|i| 1 + i * total_events / 6).collect();
    cuts.push(total_events);
    let mut used_snapshot = false;
    for (i, &cut) in cuts.iter().enumerate() {
        let mut content = lines[..=cut].join("\n");
        content.push('\n');
        // the snapshot+tail variant: journal cut plus the sidecar records
        // durable by that point
        let snap_cut_path = dir.join(format!("snapcut-{i}.jsonl"));
        std::fs::write(&snap_cut_path, &content).unwrap();
        let sidecar: Vec<&String> = snap_lines
            .iter()
            .zip(&covered)
            .filter(|&(_, &events)| events <= cut)
            .map(|(l, _)| l)
            .collect();
        let sidecar_content = sidecar.iter().map(|l| format!("{l}\n")).collect::<String>();
        std::fs::write(snapshot_path(&snap_cut_path), sidecar_content).unwrap();
        // the full-replay variant: same journal bytes, no sidecar
        let full_cut_path = dir.join(format!("fullcut-{i}.jsonl"));
        std::fs::write(&full_cut_path, &content).unwrap();

        let (mut via_snap, snap_report) = Session::recover(&snap_cut_path).unwrap();
        let (mut via_full, full_report) = Session::recover(&full_cut_path).unwrap();
        assert_eq!(full_report.snapshot_events, 0, "{label}: no sidecar, no snapshot");
        assert_eq!(full_report.events_replayed, cut, "{label}: full replay at cut {cut}");
        let best_durable = covered.iter().filter(|&&e| e <= cut).max().copied();
        match best_durable {
            Some(expected) => {
                used_snapshot = true;
                assert_eq!(
                    snap_report.snapshot_events, expected,
                    "{label}: newest durable snapshot used at cut {cut}"
                );
                assert_eq!(
                    snap_report.events_replayed,
                    cut - expected,
                    "{label}: O(tail) — only post-snapshot events replayed"
                );
            }
            None => {
                assert_eq!(snap_report.snapshot_events, 0, "{label}: nothing durable yet");
                assert_eq!(snap_report.events_replayed, cut);
            }
        }

        // identical continuation from both recoveries, against the
        // uninterrupted run's reference trace
        let tail: Vec<&Traced> = trace.iter().filter(|t| t.events_after > cut).collect();
        let asks_snap = replay_tail(&mut via_snap, &tail, &format!("{label}/snap"));
        let asks_full = replay_tail(&mut via_full, &tail, &format!("{label}/full"));
        assert_eq!(asks_snap, asks_full, "{label}: same asks compared");
        for (which, session) in [("snap", &via_snap), ("full", &via_full)] {
            let best = session.core_ref().best().expect("recovered incumbent");
            assert_eq!(best.trial, best_full.trial, "{label}/{which}: best trial");
            assert_eq!(
                best.metric.to_bits(),
                best_full.metric.to_bits(),
                "{label}/{which}: best metric"
            );
            assert_eq!(best.config, best_full.config, "{label}/{which}: best config");
        }
    }
    assert!(used_snapshot, "{label}: no cut exercised snapshot recovery");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn snapshot_equivalence_asha() {
    check_snapshot_equivalence("asha", spec_for("asha", SearcherSpec::Random, 32), 3, 20);
}

#[test]
fn snapshot_equivalence_pasha() {
    check_snapshot_equivalence("pasha", spec_for("pasha", SearcherSpec::Random, 32), 3, 20);
}

#[test]
fn snapshot_equivalence_asha_stop() {
    check_snapshot_equivalence(
        "asha-stop",
        spec_for("asha-stop", SearcherSpec::Random, 32),
        3,
        20,
    );
}

#[test]
fn snapshot_equivalence_pasha_stop() {
    check_snapshot_equivalence(
        "pasha-stop",
        spec_for("pasha-stop", SearcherSpec::Random, 48),
        3,
        20,
    );
}

#[test]
fn snapshot_equivalence_lce() {
    // The snapshot carries every curve fit f64-bit-exactly; recovery from
    // snapshot+tail and from full replay must agree byte for byte.
    check_snapshot_equivalence("lce", spec_for("lce", SearcherSpec::Random, 48), 3, 20);
}

#[test]
fn snapshot_equivalence_bo_searcher() {
    // The GP searcher's state (RNG stream, folded + pending observations)
    // must survive the snapshot for asks to stay byte-identical.
    check_snapshot_equivalence("bo", spec_for("pasha", SearcherSpec::bo_default(), 16), 2, 12);
}

#[test]
fn torn_snapshot_fuzz_every_byte() {
    // Truncate the snapshot sidecar at EVERY byte boundary. Whatever
    // survives, recovery must pick the newest intact snapshot (or fall
    // back to full replay), never panic, and account exactly.
    let spec = spec_for("asha", SearcherSpec::Random, 8);
    let dir = tmp_dir("snapfuzz");
    let path = dir.join("session.jsonl");
    let bench = spec.bench.build().unwrap();
    let options = SessionOptions {
        snapshot_every: Some(12),
        compact_on_snapshot: false,
        ..SessionOptions::default()
    };
    let mut live = Session::create_with("s0", spec.clone(), Some(&path), options).unwrap();
    let trace = drive_traced(&mut live, bench.as_ref(), spec.bench_seed, 2);
    let total = live.events_total();
    let snapshot_points = live.snapshots().to_vec();
    let best = live.core_ref().best().unwrap();
    drop(live);
    assert_eq!(total, trace.last().unwrap().events_after);
    assert!(snapshot_points.len() >= 2, "need several snapshots: {snapshot_points:?}");

    let snap_path = snapshot_path(&path);
    let bytes = std::fs::read(&snap_path).unwrap();
    for cut in 0..=bytes.len() {
        std::fs::write(&snap_path, &bytes[..cut]).unwrap();
        let (recovered, report) = Session::recover_readonly(&path)
            .unwrap_or_else(|e| panic!("cut {cut}: recovery failed: {e}"));
        assert!(
            report.snapshot_events == 0 || snapshot_points.contains(&report.snapshot_events),
            "cut {cut}: snapshot_events {} not a real snapshot point",
            report.snapshot_events
        );
        assert_eq!(
            report.events_replayed,
            total - report.snapshot_events,
            "cut {cut}: tail accounting"
        );
        let rbest = recovered.core_ref().best().unwrap();
        assert_eq!(rbest.trial, best.trial, "cut {cut}");
        assert_eq!(rbest.metric.to_bits(), best.metric.to_bits(), "cut {cut}");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn batched_wire_equivalence() {
    // The same logical op sequence issued singly and in batch frames
    // must leave byte-identical journals (modulo the session id in the
    // create header) and land on the same incumbent. ASHA + single
    // worker keeps the op sequence identical between the two drivers
    // (promotion-type schedulers never cancel, so the batched driver
    // never overshoots an abandoned job).
    let mut spec = ExperimentSpec::named("lcbench-Fashion-MNIST", "asha").unwrap();
    spec.seed = 2;
    spec.stop.config_budget = 16;
    let dir = tmp_dir("batchwire");
    let registry = Registry::with_journal_dir(dir.clone()).unwrap();
    let server = Server::bind("127.0.0.1:0", Arc::new(registry)).unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let server_thread = std::thread::spawn(move || server.run());

    let bench = spec.bench.build().unwrap();
    let mut client = Client::connect(&addr).unwrap();
    let single_id = client.create(&spec).unwrap();
    let single = run_worker(
        &mut client,
        &single_id,
        "w0",
        bench.as_ref(),
        spec.bench_seed,
        Duration::from_millis(1),
    )
    .unwrap();
    let batched_id = client.create(&spec).unwrap();
    let batched = run_worker_batched(
        &mut client,
        &batched_id,
        "w0",
        bench.as_ref(),
        spec.bench_seed,
        Duration::from_millis(1),
    )
    .unwrap();
    client.shutdown().unwrap();
    server_thread.join().unwrap().unwrap();

    assert_eq!(single.jobs_completed, batched.jobs_completed);
    assert_eq!(single.epochs_told, batched.epochs_told);
    assert!(batched.frames > 0);
    assert!(
        (batched.frames as u64) < batched.epochs_told,
        "frames {} must undercut per-op round-trips {}",
        batched.frames,
        batched.epochs_told
    );

    let read = |id: &str| -> Vec<String> {
        std::fs::read_to_string(dir.join(format!("{id}.jsonl")))
            .unwrap()
            .lines()
            .map(|l| l.to_string())
            .collect()
    };
    let single_lines = read(&single_id);
    let batched_lines = read(&batched_id);
    assert_eq!(
        single_lines[1..],
        batched_lines[1..],
        "journal bytes identical past the create header"
    );

    let (a, _) = Session::recover(&dir.join(format!("{single_id}.jsonl"))).unwrap();
    let (b, _) = Session::recover(&dir.join(format!("{batched_id}.jsonl"))).unwrap();
    let (ba, bb) = (a.core_ref().best().unwrap(), b.core_ref().best().unwrap());
    assert_eq!(ba.trial, bb.trial);
    assert_eq!(ba.metric.to_bits(), bb.metric.to_bits());
    assert_eq!(ba.config, bb.config);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn recover_readonly_at_snapshot_boundary_replays_nothing() {
    // Regression for the O(history) readonly path: a journal compacted
    // so it ends exactly at a snapshot boundary must not re-scan (or
    // re-apply) pre-snapshot events — the report proves O(tail) with an
    // empty tail.
    let spec = spec_for("asha", SearcherSpec::Random, 12);
    let dir = tmp_dir("snapboundary");
    let path = dir.join("session.jsonl");
    let bench = spec.bench.build().unwrap();
    let options = SessionOptions::snapshot_every(10);
    let mut live = Session::create_with("s0", spec.clone(), Some(&path), options).unwrap();
    let trace = drive_traced(&mut live, bench.as_ref(), spec.bench_seed, 2);
    let total = live.events_total();
    assert_eq!(total, trace.last().unwrap().events_after);
    let best = live.core_ref().best().unwrap();
    live.compact_now().unwrap();
    drop(live);

    let (recovered, report) = Session::recover_readonly(&path).unwrap();
    assert_eq!(report.snapshot_events, total, "snapshot covers the whole history");
    assert_eq!(report.events_replayed, 0, "no pre-snapshot events re-applied");
    assert_eq!(report.events_skipped, 0, "no pre-snapshot events even on disk");
    let rbest = recovered.core_ref().best().unwrap();
    assert_eq!(rbest.metric.to_bits(), best.metric.to_bits());
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn large_session_recovery_replays_only_post_snapshot_tail() {
    // The acceptance bar: a session with >= 10k journaled events must
    // recover by replaying only the post-snapshot tail, bounded by the
    // snapshot interval and the rotation lag — not the whole history.
    let interval = 1000usize;
    let mut spec = ExperimentSpec::named("lcbench-Fashion-MNIST", "asha").unwrap();
    spec.seed = 9;
    spec.stop.config_budget = 2600;
    let dir = tmp_dir("large");
    let path = dir.join("session.jsonl");
    let bench = spec.bench.build().unwrap();
    let options = SessionOptions::snapshot_every(interval);
    let mut live = Session::create_with("s0", spec.clone(), Some(&path), options).unwrap();
    loop {
        match live.ask("w0").unwrap() {
            TrialAssignment::Run(job) => {
                for e in job.from_epoch + 1..=job.milestone {
                    let m = bench.accuracy_at(&job.config, e, spec.bench_seed);
                    if live.tell(job.trial, e, m).unwrap() == TellAck::Abandon {
                        break;
                    }
                }
            }
            TrialAssignment::Stop(_) | TrialAssignment::Pause(_) => {}
            TrialAssignment::Wait => panic!("single worker never waits"),
            TrialAssignment::Done => break,
        }
    }
    let total = live.events_total();
    let best = live.core_ref().best().unwrap();
    drop(live);
    assert!(total >= 10_000, "workload too small: {total} events");

    let (recovered, report) = Session::recover(&path).unwrap();
    assert!(report.snapshot_events > 0, "snapshot recovery engaged");
    assert_eq!(report.snapshot_events + report.events_replayed, total);
    assert!(
        report.events_replayed < interval + 1,
        "replayed {} of {total}: tail must stay within one interval",
        report.events_replayed
    );
    assert!(
        report.events_skipped <= interval,
        "rotation lag keeps at most one interval of pre-snapshot tail, got {}",
        report.events_skipped
    );
    let rbest = recovered.core_ref().best().unwrap();
    assert_eq!(rbest.trial, best.trial);
    assert_eq!(rbest.metric.to_bits(), best.metric.to_bits());
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn tcp_session_matches_inprocess_tuner() {
    // The acceptance bar: a full simulated LCBench session over real TCP
    // lands on the same incumbent as Tuner::run for the same seeds.
    let mut spec = ExperimentSpec::named("lcbench-Fashion-MNIST", "pasha").unwrap();
    spec.seed = 3;
    spec.stop.config_budget = 24;
    let dir = tmp_dir("tcp");
    let registry = Registry::with_journal_dir(dir.clone()).unwrap();
    let server = Server::bind("127.0.0.1:0", Arc::new(registry)).unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let server_thread = std::thread::spawn(move || server.run());

    let bench = spec.bench.build().unwrap();
    let mut client = Client::connect(&addr).unwrap();
    let sid = client.create(&spec).unwrap();
    let report = run_worker(
        &mut client,
        &sid,
        "w0",
        bench.as_ref(),
        spec.bench_seed,
        Duration::from_millis(1),
    )
    .unwrap();
    assert!(report.jobs_completed > 0);
    let status = client.status(&sid).unwrap();
    let served_best = status.get("best_metric").unwrap().as_f64().unwrap();
    let served_config = config_from_json(
        bench.space(),
        status.get("best_config").expect("best config in status"),
    )
    .unwrap();

    // the served session's own spec, lowered to a single in-process
    // worker, must reproduce the incumbent bit-for-bit
    let mut inproc_spec = spec.clone();
    inproc_spec.exec.workers = 1;
    let inproc = Tuner::run(&inproc_spec).unwrap();
    assert_eq!(
        served_best.to_bits(),
        inproc.best_metric.to_bits(),
        "served {} vs in-process {}",
        served_best,
        inproc.best_metric
    );
    assert_eq!(Some(served_config.clone()), inproc.best_config);
    let served_retrain = bench.retrain_accuracy(&served_config, spec.bench_seed);
    assert_eq!(served_retrain.to_bits(), inproc.retrain_accuracy.to_bits());

    // the journal the server wrote must replay cleanly, to the same best
    let journal = dir.join(format!("{sid}.jsonl"));
    let (recovered, _) = Session::recover(&journal).unwrap();
    let best = recovered.core_ref().best().unwrap();
    assert_eq!(best.metric.to_bits(), served_best.to_bits());

    client.shutdown().unwrap();
    server_thread.join().unwrap().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn tcp_many_workers_drain_one_session() {
    // Concurrency smoke: several TCP workers share one session; the run
    // drains, every worker exits on Done, and the incumbent is sane.
    let mut spec = ExperimentSpec::named("lcbench-Fashion-MNIST", "asha").unwrap();
    spec.seed = 1;
    spec.stop.config_budget = 16;
    let server = Server::bind("127.0.0.1:0", Arc::new(Registry::in_memory())).unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let server_thread = std::thread::spawn(move || server.run());

    let bench = spec.bench.build().unwrap();
    let mut control = Client::connect(&addr).unwrap();
    let sid = control.create(&spec).unwrap();
    let reports: Vec<pasha::service::WorkerReport> = std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for w in 0..4 {
            let addr = addr.as_str();
            let sid = sid.as_str();
            let bench = &bench;
            handles.push(scope.spawn(move || {
                let mut client = Client::connect(addr).unwrap();
                run_worker(
                    &mut client,
                    sid,
                    &format!("w{w}"),
                    bench.as_ref(),
                    0,
                    Duration::from_millis(1),
                )
                .unwrap()
            }));
        }
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    let total_jobs: usize = reports.iter().map(|r| r.jobs_completed).sum();
    assert!(total_jobs >= 16, "all configs trained: {total_jobs}");
    let status = control.status(&sid).unwrap();
    assert_eq!(status.get("in_flight").unwrap().as_f64(), Some(0.0), "drained");
    assert!(status.get("best_metric").unwrap().as_f64().unwrap() > 0.0);
    control.shutdown().unwrap();
    server_thread.join().unwrap().unwrap();
}

/// Tests specific to the sharded event-driven core (`Server::run` on
/// Unix): shutdown drain across connections, slow-client backpressure,
/// and auto-assigned per-connection worker ids.
#[cfg(unix)]
mod eventloop_e2e {
    use super::*;
    use std::io::{BufRead, BufReader, Write};
    use std::net::TcpStream;

    #[test]
    fn shutdown_drains_inflight_ops_on_other_connections() {
        // `shutdown` on one connection must not drop work accepted on
        // others: every op the server has read is answered and
        // journaled before the `bye` is released and the listener
        // closes.
        let spec = spec_for("asha", SearcherSpec::Random, 40);
        let dir = tmp_dir("drain");
        let registry = Registry::with_journal_dir(dir.clone()).unwrap();
        let server = Server::bind("127.0.0.1:0", Arc::new(registry)).unwrap();
        let addr = server.local_addr().unwrap().to_string();
        let server_thread = std::thread::spawn(move || server.run());

        let mut control = Client::connect(&addr).unwrap();
        let sid = control.create(&spec).unwrap();

        // Pipeline 32 asks in a single write on a second connection and
        // read only the first response; the rest are still queued when
        // shutdown arrives.
        let writer = TcpStream::connect(&addr).unwrap();
        let mut reader = BufReader::new(writer.try_clone().unwrap());
        let mut frame = String::new();
        for w in 0..32 {
            frame.push_str(&format!(
                "{{\"cmd\":\"ask\",\"session\":\"{sid}\",\"worker\":\"w{w}\"}}\n"
            ));
        }
        (&writer).write_all(frame.as_bytes()).unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("\"ok\":true"), "first ask failed: {line}");
        // give the event loop a few ticks to ingest the residual bytes —
        // drain covers ops the server has *read*, not bytes in flight
        std::thread::sleep(Duration::from_millis(150));

        // blocks until the drained `bye`
        control.shutdown().unwrap();

        for i in 1..32 {
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            assert!(
                line.contains("\"ok\":true"),
                "ask #{i} lost in shutdown: {line:?}"
            );
        }
        server_thread.join().unwrap().unwrap();

        // every acked ask made it into the journal before the exit
        let journal = std::fs::read_to_string(dir.join(format!("{sid}.jsonl"))).unwrap();
        let asks = journal.lines().filter(|l| l.contains("\"ev\":\"ask\"")).count();
        assert_eq!(asks, 32, "all acked asks journaled");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn slow_client_backpressure_bounds_buffering_and_keeps_service_live() {
        // A client that pipelines requests and never reads responses
        // must jam against the server's write-queue caps instead of
        // growing server memory without bound — and must not wedge
        // service for well-behaved connections.
        let spec = spec_for("asha", SearcherSpec::Random, 12);
        let server = Server::bind("127.0.0.1:0", Arc::new(Registry::in_memory())).unwrap();
        let addr = server.local_addr().unwrap().to_string();
        let server_thread = std::thread::spawn(move || server.run());

        let mut control = Client::connect(&addr).unwrap();
        let sid = control.create(&spec).unwrap();

        let stalled = TcpStream::connect(&addr).unwrap();
        stalled.set_nonblocking(true).unwrap();
        let req = format!("{{\"cmd\":\"status\",\"session\":\"{sid}\"}}\n");
        let req = req.as_bytes();
        const CAP: usize = 64 * 1024 * 1024;
        let mut written = 0usize;
        let mut idle = 0u32;
        while written < CAP {
            match (&stalled).write(req) {
                Ok(0) => break,
                Ok(n) => {
                    written += n;
                    idle = 0;
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    idle += 1;
                    if idle > 100 {
                        break; // ~1s of zero progress: the pipe is jammed
                    }
                    std::thread::sleep(Duration::from_millis(10));
                }
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(e) => panic!("stalled writer failed: {e}"),
            }
        }
        assert!(
            written < CAP,
            "backpressure never engaged: server absorbed {written} bytes unread"
        );

        // the jammed connection must not block other clients: a worker
        // on a fresh connection drives the session to completion
        let bench = spec.bench.build().unwrap();
        let mut worker = Client::connect(&addr).unwrap();
        let report = run_worker(
            &mut worker,
            &sid,
            "w0",
            bench.as_ref(),
            spec.bench_seed,
            Duration::from_millis(1),
        )
        .unwrap();
        assert!(report.jobs_completed > 0, "service stayed live under backpressure");

        drop(stalled);
        std::thread::sleep(Duration::from_millis(100));
        control.shutdown().unwrap();
        server_thread.join().unwrap().unwrap();
    }

    #[test]
    fn bare_ask_gets_unique_per_connection_worker_id() {
        // An `ask` without a `worker` field is attributed to an
        // auto-assigned per-connection id, so two anonymous connections
        // never collide in the journal.
        let spec = spec_for("asha", SearcherSpec::Random, 8);
        let dir = tmp_dir("autoworker");
        let registry = Registry::with_journal_dir(dir.clone()).unwrap();
        let server = Server::bind("127.0.0.1:0", Arc::new(registry)).unwrap();
        let addr = server.local_addr().unwrap().to_string();
        let server_thread = std::thread::spawn(move || server.run());

        let mut control = Client::connect(&addr).unwrap();
        let sid = control.create(&spec).unwrap();

        let bare_ask = |addr: &str| {
            let mut conn = TcpStream::connect(addr).unwrap();
            conn.write_all(format!("{{\"cmd\":\"ask\",\"session\":\"{sid}\"}}\n").as_bytes())
                .unwrap();
            let mut reader = BufReader::new(conn);
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            assert!(line.contains("\"ok\":true"), "bare ask failed: {line}");
        };
        bare_ask(&addr);
        bare_ask(&addr);
        control.shutdown().unwrap();
        server_thread.join().unwrap().unwrap();

        let journal = std::fs::read_to_string(dir.join(format!("{sid}.jsonl"))).unwrap();
        let mut workers = Vec::new();
        for l in journal.lines() {
            let ev = pasha::util::json::parse(l).unwrap();
            if ev.get("ev").and_then(|v| v.as_str()) == Some("ask") {
                workers.push(ev.get("worker").unwrap().as_str().unwrap().to_string());
            }
        }
        assert_eq!(workers.len(), 2, "both asks journaled");
        assert!(
            workers.iter().all(|w| w.starts_with("conn-")),
            "auto ids use the conn- prefix: {workers:?}"
        );
        assert_ne!(workers[0], workers[1], "per-connection ids are unique");
        let _ = std::fs::remove_dir_all(&dir);
    }
}

/// Observability E2E: the `stats` wire op and Prometheus endpoint
/// against a live stressed server, conservation invariants between the
/// metrics registry and the journal on disk, and proof that the metrics
/// gate never changes journal bytes. Both tests touch the process-global
/// metrics gate, so they serialize on one lock.
#[cfg(unix)]
mod obs_e2e {
    use super::*;
    use pasha::util::json::Json;
    use std::io::{Read, Write};
    use std::net::TcpStream;
    use std::sync::{Mutex, MutexGuard};

    fn obs_gate() -> MutexGuard<'static, ()> {
        static GATE: Mutex<()> = Mutex::new(());
        GATE.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// The `value` of the instrument `name` with `labels[key] == value`
    /// in a `stats` snapshot, if present.
    fn inst_value(snap: &Json, name: &str, key: &str, label: &str) -> Option<f64> {
        snap.get("instruments")?
            .as_arr()?
            .iter()
            .find(|i| {
                i.get("name").and_then(|n| n.as_str()) == Some(name)
                    && i.get("labels")
                        .and_then(|l| l.get(key))
                        .and_then(|v| v.as_str())
                        == Some(label)
            })?
            .get("value")?
            .as_f64()
    }

    #[test]
    fn stats_and_prometheus_conserve_against_journal_under_stress() {
        let _gate = obs_gate();
        pasha::obs::set_enabled(true);
        let dir = tmp_dir("obs-conserve");
        let registry = Arc::new(Registry::with_journal_dir(dir.clone()).unwrap());
        // Session-labeled instruments are process-global and every
        // fresh registry numbers sessions from s0000, so parallel tests
        // in this binary would share our counters. Burn ids so the
        // measured session's labels are unique process-wide.
        for _ in 0..40 {
            registry.create(spec_for("asha", SearcherSpec::Random, 1)).unwrap();
        }
        let server = Server::bind("127.0.0.1:0", registry)
            .unwrap()
            .metrics_addr("127.0.0.1:0")
            .unwrap();
        let addr = server.local_addr().unwrap().to_string();
        let maddr = server.metrics_local_addr().unwrap();
        let server_thread = std::thread::spawn(move || server.run());

        let spec = spec_for("pasha", SearcherSpec::Random, 32);
        let bench = spec.bench.build().unwrap();
        let mut control = Client::connect(&addr).unwrap();
        let sid = control.create(&spec).unwrap();
        std::thread::scope(|scope| {
            for w in 0..4 {
                let addr = addr.as_str();
                let sid = sid.as_str();
                let bench = &bench;
                let bench_seed = spec.bench_seed;
                scope.spawn(move || {
                    let mut client = Client::connect(addr).unwrap();
                    run_worker(
                        &mut client,
                        sid,
                        &format!("w{w}"),
                        bench.as_ref(),
                        bench_seed,
                        Duration::from_millis(1),
                    )
                    .unwrap()
                });
            }
        });

        // Prometheus scrape over plain HTTP, against the live server.
        let mut msock = TcpStream::connect(maddr).unwrap();
        msock
            .write_all(b"GET /metrics HTTP/1.1\r\nHost: pasha\r\n\r\n")
            .unwrap();
        let mut scrape = String::new();
        msock.read_to_string(&mut scrape).unwrap(); // Connection: close
        assert!(scrape.starts_with("HTTP/1.1 200 OK"), "scrape status: {scrape:.60}");
        for needle in [
            "# TYPE pasha_net_accepts_total counter",
            "pasha_net_requests_total",
            "pasha_journal_events_total",
            "_bucket{", // at least one histogram series rendered
        ] {
            assert!(scrape.contains(needle), "scrape missing {needle:?}");
        }
        assert!(
            scrape.contains(&format!("addr=\"{addr}\"")),
            "serve metrics carry the listen-address label"
        );

        // `stats` wire op: the snapshot the server reports about itself.
        let snap = control.stats().unwrap();
        let journaled_asks = inst_value(&snap, "pasha_sched_asks_journaled_total", "session", &sid)
            .expect("per-session journaled-ask counter in snapshot");
        let asks_total = inst_value(&snap, "pasha_sched_asks_total", "session", &sid)
            .expect("per-session ask counter in snapshot");
        let cap_epochs = inst_value(&snap, "pasha_max_resource_epochs", "session", &sid)
            .expect("PASHA resource-cap gauge in snapshot");
        assert!(asks_total >= journaled_asks, "Wait/Done asks never journal");
        assert!(cap_epochs >= 1.0, "progressive cap engaged: {cap_epochs}");
        // The only op in flight while the snapshot is taken is the
        // `stats` request itself: the workers have read every response.
        assert_eq!(
            inst_value(&snap, "pasha_net_inflight_ops", "addr", &addr),
            Some(1.0),
            "quiesced server counts only the stats op itself"
        );

        control.shutdown().unwrap();
        server_thread.join().unwrap().unwrap();

        // Conservation against the journal on disk (complete after the
        // server's final group-commit flush).
        let journal = std::fs::read_to_string(dir.join(format!("{sid}.jsonl"))).unwrap();
        let ask_lines = journal.lines().filter(|l| l.contains("\"ev\":\"ask\"")).count();
        assert!(ask_lines > 0, "stress run journaled work");
        assert_eq!(
            journaled_asks as usize, ask_lines,
            "acked asks == scheduler journaled-ask counter == journal ask events"
        );
        let sl: &[(&str, &str)] = &[("session", &sid)];
        let events = pasha::obs::counter("pasha_journal_events_total", sl).get();
        let fsyncs = pasha::obs::counter("pasha_journal_fsyncs_total", sl).get();
        assert!(
            events as usize >= ask_lines,
            "journal event counter covers ask events: {events} < {ask_lines}"
        );
        assert!(
            fsyncs <= events + 1,
            "group commit batches fsyncs: {fsyncs} syncs for {events} events"
        );
        assert_eq!(
            pasha::obs::gauge("pasha_net_inflight_ops", &[("addr", &addr)]).get(),
            0,
            "in-flight ops drain to 0 after shutdown"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn lce_resource_cap_reaches_the_gauge_and_fit_counters_scrape() {
        let _gate = obs_gate();
        pasha::obs::set_enabled(true);
        let registry = Arc::new(Registry::in_memory());
        // unique session labels process-wide (see the conservation test)
        for _ in 0..40 {
            registry.create(spec_for("asha", SearcherSpec::Random, 1)).unwrap();
        }
        let server = Server::bind("127.0.0.1:0", registry)
            .unwrap()
            .metrics_addr("127.0.0.1:0")
            .unwrap();
        let addr = server.local_addr().unwrap().to_string();
        let maddr = server.metrics_local_addr().unwrap();
        let server_thread = std::thread::spawn(move || server.run());

        let mut spec = spec_for("lce", SearcherSpec::Random, 48);
        // 3-point histories fit, so rung-1 completions produce fits even
        // before the cap grows — the counter assert below is determined
        spec.set("scheduler.min_points=3").unwrap();
        let bench = spec.bench.build().unwrap();
        let mut control = Client::connect(&addr).unwrap();
        let sid = control.create(&spec).unwrap();
        std::thread::scope(|scope| {
            for w in 0..2 {
                let addr = addr.as_str();
                let sid = sid.as_str();
                let bench = &bench;
                let bench_seed = spec.bench_seed;
                scope.spawn(move || {
                    let mut client = Client::connect(addr).unwrap();
                    run_worker(
                        &mut client,
                        sid,
                        &format!("w{w}"),
                        bench.as_ref(),
                        bench_seed,
                        Duration::from_millis(1),
                    )
                    .unwrap()
                });
            }
        });

        // The gauge must reflect lce's PASHA-style growing cap — at least
        // the initial cap of one growth level (r_min·eta = 3 epochs),
        // never the 1-epoch base rung a broken propagation would report.
        let snap = control.stats().unwrap();
        let cap_epochs = inst_value(&snap, "pasha_max_resource_epochs", "session", &sid)
            .expect("lce resource-cap gauge in snapshot");
        assert!(cap_epochs >= 3.0, "lce cap gauge engaged: {cap_epochs}");
        assert!(
            pasha::obs::counter("pasha_sched_curve_fits", &[]).get() > 0,
            "served lce session fitted learning curves"
        );

        // And the Prometheus exposition carries the curve-fit instruments.
        let mut msock = TcpStream::connect(maddr).unwrap();
        msock
            .write_all(b"GET /metrics HTTP/1.1\r\nHost: pasha\r\n\r\n")
            .unwrap();
        let mut scrape = String::new();
        msock.read_to_string(&mut scrape).unwrap();
        assert!(scrape.starts_with("HTTP/1.1 200 OK"), "scrape status: {scrape:.60}");
        for needle in [
            "pasha_sched_curve_fits",
            "pasha_sched_extrapolated_stops",
            "pasha_sched_fit_residual_milli",
            "pasha_max_resource_epochs",
        ] {
            assert!(scrape.contains(needle), "scrape missing {needle:?}");
        }

        control.shutdown().unwrap();
        server_thread.join().unwrap().unwrap();
    }

    #[test]
    fn metrics_gate_does_not_change_journal_bytes() {
        let _gate = obs_gate();
        let dir = tmp_dir("obs-byteid");
        let spec = spec_for("pasha", SearcherSpec::Random, 16);
        let bench = spec.bench.build().unwrap();
        let run = |name: &str, enabled: bool| -> Vec<u8> {
            pasha::obs::set_enabled(enabled);
            let path = dir.join(format!("{name}.jsonl"));
            let mut live = Session::create("byteid", spec.clone(), Some(&path)).unwrap();
            drive_traced(&mut live, bench.as_ref(), spec.bench_seed, 3);
            drop(live);
            std::fs::read(&path).unwrap()
        };
        let on = run("on", true);
        let off = run("off", false);
        pasha::obs::set_enabled(true);
        assert!(!on.is_empty(), "instrumented run journaled nothing");
        assert_eq!(on, off, "metrics gate must never reach the journal bytes");
        let _ = std::fs::remove_dir_all(&dir);
    }
}

/// Replication, lease-expiry, forced-drain, and leader-failover E2E
/// (`serve --replicate`, `follow`, `route`). In-process where the
/// property allows it; across real process boundaries — SIGKILL
/// included — where it does not.
#[cfg(unix)]
mod replication_e2e {
    use super::*;
    use pasha::service::replica;
    use pasha::spec::RouteSpec;
    use std::process::{Child, Command, Stdio};
    use std::time::Instant;

    fn wait_for(mut cond: impl FnMut() -> bool, ms: u64, what: &str) {
        let deadline = Instant::now() + Duration::from_millis(ms);
        while Instant::now() < deadline {
            if cond() {
                return;
            }
            std::thread::sleep(Duration::from_millis(20));
        }
        panic!("timed out waiting for {what}");
    }

    fn pasha_bin() -> Command {
        Command::new(env!("CARGO_BIN_EXE_pasha"))
    }

    /// A loopback address with a port the OS just proved free.
    fn free_addr() -> String {
        let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let a = l.local_addr().unwrap().to_string();
        drop(l);
        a
    }

    fn connect_when_up(addr: &str) -> Client {
        let deadline = Instant::now() + Duration::from_secs(20);
        loop {
            match Client::connect(addr) {
                Ok(c) => return c,
                Err(e) => {
                    if Instant::now() >= deadline {
                        panic!("connect {addr}: {e}");
                    }
                    std::thread::sleep(Duration::from_millis(40));
                }
            }
        }
    }

    /// Drive an in-process session to completion with one worker,
    /// recording the canonical encoding of every ask response.
    fn drive_solo_recording(
        session: &mut Session,
        bench: &dyn Benchmark,
        bench_seed: u64,
    ) -> Vec<String> {
        let mut asks = Vec::new();
        loop {
            let a = session.ask("w0").unwrap();
            asks.push(assignment_json(&a).to_string_compact());
            match a {
                TrialAssignment::Run(job) => {
                    for e in job.from_epoch + 1..=job.milestone {
                        let m = bench.accuracy_at(&job.config, e, bench_seed);
                        if session.tell(job.trial, e, m).unwrap() == TellAck::Abandon {
                            break;
                        }
                    }
                }
                TrialAssignment::Stop(_) | TrialAssignment::Pause(_) => {}
                TrialAssignment::Wait => panic!("single worker never waits"),
                TrialAssignment::Done => return asks,
            }
        }
    }

    /// The canonical continuation of a crashed session: expire the dead
    /// workers' leases (the promotion runbook's first step), then drive
    /// to completion recording every ask plus the final incumbent.
    fn crashed_continuation(
        session: &mut Session,
        bench: &dyn Benchmark,
        bench_seed: u64,
    ) -> (usize, Vec<String>, Option<(usize, u64)>) {
        let expired = session.expire_workers().unwrap();
        let asks = drive_solo_recording(session, bench, bench_seed);
        let best = session
            .core_ref()
            .best()
            .map(|b| (b.trial, b.metric.to_bits()));
        (expired, asks, best)
    }

    /// A follower attached to an in-process leader mirrors every
    /// session journal byte-for-byte, including one created mid-stream,
    /// and the mirror recovers to the same incumbent.
    #[test]
    fn follower_mirrors_leader_journals_byte_for_byte() {
        let ldir = tmp_dir("mirror-l");
        let fdir = tmp_dir("mirror-f");
        let registry = Registry::with_journal_dir(ldir.clone()).unwrap();
        let server = Server::bind("127.0.0.1:0", Arc::new(registry))
            .unwrap()
            .replicate_addr("127.0.0.1:0")
            .unwrap();
        let addr = server.local_addr().unwrap().to_string();
        let raddr = server.replicate_local_addr().unwrap().to_string();
        let server_thread = std::thread::spawn(move || server.run());

        let follow_dir = fdir.clone();
        let follower = std::thread::spawn(move || replica::follow(&raddr, &follow_dir));

        let spec = spec_for("asha", SearcherSpec::Random, 12);
        let bench = spec.bench.build().unwrap();
        let mut client = Client::connect(&addr).unwrap();
        let sid = client.create(&spec).unwrap();
        wait_for(
            || fdir.join(format!("{sid}.jsonl")).exists(),
            15_000,
            "follower subscription",
        );
        run_worker(
            &mut client,
            &sid,
            "w0",
            bench.as_ref(),
            spec.bench_seed,
            Duration::from_millis(1),
        )
        .unwrap();
        // a session created mid-stream rides the same subscription
        let sid2 = client.create(&spec).unwrap();
        run_worker(
            &mut client,
            &sid2,
            "w0",
            bench.as_ref(),
            spec.bench_seed,
            Duration::from_millis(1),
        )
        .unwrap();
        client.shutdown().unwrap();
        server_thread.join().unwrap().unwrap();
        let report = follower.join().unwrap().unwrap();
        assert!(report.bytes > 0, "frames flowed: {report:?}");
        assert_eq!(report.journals, 2, "both sessions replicated: {report:?}");

        for id in [&sid, &sid2] {
            let name = format!("{id}.jsonl");
            let l = std::fs::read(ldir.join(&name)).unwrap();
            let f = std::fs::read(fdir.join(&name)).unwrap();
            assert!(!l.is_empty(), "leader journaled {name}");
            assert_eq!(l, f, "{name}: follower copy is byte-identical");
            let (a, _) = Session::recover_readonly(&ldir.join(&name)).unwrap();
            let (b, _) = Session::recover_readonly(&fdir.join(&name)).unwrap();
            let ba = a.core_ref().best().unwrap();
            let bb = b.core_ref().best().unwrap();
            assert_eq!(ba.trial, bb.trial, "{name}: same incumbent trial");
            let (ma, mb) = (ba.metric.to_bits(), bb.metric.to_bits());
            assert_eq!(ma, mb, "{name}: same incumbent metric");
        }
        for d in [&ldir, &fdir] {
            let _ = std::fs::remove_dir_all(d);
        }
    }

    /// A worker that goes silent mid-job under `--shards` is expired by
    /// the per-shard liveness tick — with no client op to piggyback on —
    /// and its exact job is re-assigned to the next asking worker.
    #[test]
    fn worker_lease_expiry_requeues_dead_workers_job_under_shards() {
        let dir = tmp_dir("lease");
        let opts = SessionOptions::default();
        let registry = Registry::with_journal_dir_sharded(dir.clone(), opts, 4).unwrap();
        let server = Server::bind("127.0.0.1:0", Arc::new(registry))
            .unwrap()
            .worker_lease(Duration::from_millis(250));
        let addr = server.local_addr().unwrap().to_string();
        let server_thread = std::thread::spawn(move || server.run());

        let spec = spec_for("asha", SearcherSpec::Random, 8);
        let bench = spec.bench.build().unwrap();
        let space = bench.space().clone();
        let mut control = Client::connect(&addr).unwrap();
        let sid = control.create(&spec).unwrap();

        // w0 takes a job, then its process "dies" (drops the conn)
        let mut w0 = Client::connect(&addr).unwrap();
        let job = match w0.ask(&sid, "w0", &space).unwrap() {
            TrialAssignment::Run(job) => job,
            other => panic!("expected a job for w0, got {other:?}"),
        };
        drop(w0);

        // the shard's liveness tick journals the expiry on its own
        let journal_path = dir.join(format!("{sid}.jsonl"));
        wait_for(
            || {
                std::fs::read_to_string(&journal_path)
                    .map(|j| {
                        j.lines().any(|l| {
                            l.contains("\"ev\":\"expire\"") && l.contains("\"worker\":\"w0\"")
                        })
                    })
                    .unwrap_or(false)
            },
            15_000,
            "lease expiry to be journaled",
        );

        // deterministic re-assignment: the next asking worker receives
        // the identical job the dead worker held
        let retry = match control.ask(&sid, "w1", &space).unwrap() {
            TrialAssignment::Run(job) => job,
            other => panic!("expected the re-queued job for w1, got {other:?}"),
        };
        assert_eq!(retry, job, "dead worker's job re-assigned verbatim");

        control.shutdown().unwrap();
        server_thread.join().unwrap().unwrap();
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// A client that pipelines requests but never reads responses jams
    /// its connection; shutdown must be bounded by the configured drain
    /// deadline — not the jam — and every acked op stays durable.
    #[test]
    fn forced_drain_honors_deadline_and_keeps_acked_ops_durable() {
        use std::io::Write;
        let dir = tmp_dir("forcedrain");
        let registry = Registry::with_journal_dir(dir.clone()).unwrap();
        let server = Server::bind("127.0.0.1:0", Arc::new(registry))
            .unwrap()
            .drain_deadline(Duration::from_millis(300));
        let addr = server.local_addr().unwrap().to_string();
        let server_thread = std::thread::spawn(move || server.run());

        let spec = spec_for("asha", SearcherSpec::Random, 40);
        let bench = spec.bench.build().unwrap();
        let space = bench.space().clone();
        let mut control = Client::connect(&addr).unwrap();
        let sid = control.create(&spec).unwrap();

        // acked-and-durable work on a well-behaved connection
        let mut acked = 0usize;
        for _ in 0..6 {
            let a = control.ask(&sid, "wb", &space).unwrap();
            if !matches!(a, TrialAssignment::Wait | TrialAssignment::Done) {
                acked += 1;
            }
        }
        assert!(acked > 0, "no journaled asks to check durability with");

        let stalled = std::net::TcpStream::connect(&addr).unwrap();
        stalled.set_nonblocking(true).unwrap();
        let req = format!("{{\"cmd\":\"status\",\"session\":\"{sid}\"}}\n");
        let req = req.as_bytes();
        let mut written = 0usize;
        let mut idle = 0u32;
        while written < 4 * 1024 * 1024 {
            match (&stalled).write(req) {
                Ok(0) => break,
                Ok(n) => {
                    written += n;
                    idle = 0;
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    idle += 1;
                    if idle > 50 {
                        break;
                    }
                    std::thread::sleep(Duration::from_millis(10));
                }
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(e) => panic!("stalled writer failed: {e}"),
            }
        }
        assert!(written > 0, "jammed connection wrote nothing");
        // let the server answer into the (now jammed) write queue
        std::thread::sleep(Duration::from_millis(300));

        let t0 = Instant::now();
        control.shutdown().unwrap();
        server_thread.join().unwrap().unwrap();
        let waited = t0.elapsed();
        assert!(
            waited < Duration::from_secs(4),
            "configured 300ms drain deadline not honored: took {waited:?}"
        );
        drop(stalled);

        // every acked ask reached the journal before its response
        let journal = std::fs::read_to_string(dir.join(format!("{sid}.jsonl"))).unwrap();
        let asks = journal
            .lines()
            .filter(|l| l.contains("\"ev\":\"ask\""))
            .count();
        assert!(
            asks >= acked,
            "forced drain lost acked asks: journal holds {asks} ask events, acked {acked}"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Cross-process crash-recovery property: SIGKILL a replicating
    /// leader at randomized commit-group boundaries; the follower's copy
    /// is a byte prefix of the leader's, and for BOTH directories a
    /// (snapshot + tail) recovery and a full-replay recovery continue
    /// the session byte-identically to the same incumbent.
    #[test]
    fn sigkill_crash_recovery_agrees_from_leader_and_follower_dirs() {
        // fixed-seed LCG: deterministic in CI, spread across the run
        let mut lcg: u64 = 0x2545_F491_4F6C_DD1D;
        let mut kill_points = Vec::new();
        for _ in 0..3 {
            lcg = lcg
                .wrapping_mul(6364136223846793005)
                .wrapping_add(1442695040888963407);
            kill_points.push(5 + ((lcg >> 33) as usize % 14)); // asks 5..=18
        }

        let spec = spec_for("asha", SearcherSpec::Random, 16);
        let bench = spec.bench.build().unwrap();
        let space = bench.space().clone();

        for (i, &kill_at) in kill_points.iter().enumerate() {
            let ldir = tmp_dir(&format!("crash-l{i}"));
            let fdir = tmp_dir(&format!("crash-f{i}"));
            let scratch = tmp_dir(&format!("crash-s{i}"));
            let addr = free_addr();
            let raddr = free_addr();
            let mut leader = pasha_bin()
                .args([
                    "serve",
                    "--addr",
                    &addr,
                    "--journal-dir",
                    ldir.to_str().unwrap(),
                    "--replicate",
                    &raddr,
                ])
                .stdout(Stdio::null())
                .stderr(Stdio::null())
                .spawn()
                .unwrap();
            let mut client = connect_when_up(&addr);
            let fdir_arg = fdir.to_str().unwrap().to_string();
            let mut follower = pasha_bin()
                .args(["follow", &raddr, "--journal-dir", &fdir_arg])
                .stdout(Stdio::null())
                .stderr(Stdio::null())
                .spawn()
                .unwrap();
            let sid = client.create(&spec).unwrap();
            wait_for(
                || fdir.join(format!("{sid}.jsonl")).exists(),
                15_000,
                "follower subscription",
            );

            // drive to the kill point; the client is synchronous, so a
            // SIGKILL between ops lands between commit groups
            let mut asks = 0usize;
            loop {
                let a = client.ask(&sid, "w0", &space).unwrap();
                asks += 1;
                if asks >= kill_at {
                    break;
                }
                match a {
                    TrialAssignment::Run(job) => {
                        for e in job.from_epoch + 1..=job.milestone {
                            let m = bench.accuracy_at(&job.config, e, spec.bench_seed);
                            if client.tell(&sid, job.trial, e, m).unwrap() == TellAck::Abandon {
                                break;
                            }
                        }
                    }
                    TrialAssignment::Stop(_) | TrialAssignment::Pause(_) => {}
                    TrialAssignment::Wait => panic!("single worker never waits"),
                    TrialAssignment::Done => break,
                }
            }
            leader.kill().unwrap();
            leader.wait().unwrap();
            follower.wait().unwrap();

            let lbytes = std::fs::read(ldir.join(format!("{sid}.jsonl"))).unwrap();
            let fbytes = std::fs::read(fdir.join(format!("{sid}.jsonl"))).unwrap();
            assert!(
                fbytes.len() <= lbytes.len() && lbytes[..fbytes.len()] == fbytes[..],
                "iteration {i}: follower diverged from the leader's journal"
            );

            for (which, src) in [("leader", &ldir), ("follower", &fdir)] {
                let full_path = scratch.join(format!("{which}-full.jsonl"));
                std::fs::copy(src.join(format!("{sid}.jsonl")), &full_path).unwrap();
                let snap_path = scratch.join(format!("{which}-snap.jsonl"));
                std::fs::copy(src.join(format!("{sid}.jsonl")), &snap_path).unwrap();
                {
                    // snapshot the crashed state, then recover from it
                    let (mut s, _) = Session::recover(&snap_path).unwrap();
                    s.compact_now().unwrap();
                }
                let (mut via_full, _) = Session::recover(&full_path).unwrap();
                let (mut via_snap, rep) = Session::recover(&snap_path).unwrap();
                assert!(
                    rep.snapshot_events > 0,
                    "iteration {i}/{which}: snapshot recovery engaged"
                );
                let full = crashed_continuation(&mut via_full, bench.as_ref(), spec.bench_seed);
                let snap = crashed_continuation(&mut via_snap, bench.as_ref(), spec.bench_seed);
                assert_eq!(full, snap, "iteration {i}/{which}: snapshot+tail vs full replay");
            }
            for d in [&ldir, &fdir, &scratch] {
                let _ = std::fs::remove_dir_all(d);
            }
        }
    }

    /// The tentpole: SIGKILL the leader mid-tune, promote the follower's
    /// journal directory, and finish the session through the session
    /// router — the complete ask stream and the incumbent must be
    /// byte-identical to an uninterrupted run.
    #[test]
    fn leader_sigkill_failover_through_router_matches_uninterrupted_run() {
        let ldir = tmp_dir("failover-l");
        let fdir = tmp_dir("failover-f");
        let scratch = tmp_dir("failover-s");
        let spec = spec_for("asha", SearcherSpec::Random, 16);
        let bench = spec.bench.build().unwrap();
        let space = bench.space().clone();

        // the uninterrupted reference run, in process
        let mut reference = Session::create("ref", spec.clone(), None).unwrap();
        let ref_asks = drive_solo_recording(&mut reference, bench.as_ref(), spec.bench_seed);
        let ref_best = reference.core_ref().best().expect("reference incumbent");
        let kill_after = ref_asks.len() / 2;
        assert!(kill_after > 2, "workload too small to kill mid-tune");

        let leader_addr = free_addr();
        let repl_addr = free_addr();
        let mut leader = pasha_bin()
            .args([
                "serve",
                "--addr",
                &leader_addr,
                "--journal-dir",
                ldir.to_str().unwrap(),
                "--replicate",
                &repl_addr,
            ])
            .stdout(Stdio::null())
            .stderr(Stdio::null())
            .spawn()
            .unwrap();
        drop(connect_when_up(&leader_addr));
        let fdir_arg = fdir.to_str().unwrap().to_string();
        let mut follower = pasha_bin()
            .args(["follow", &repl_addr, "--journal-dir", &fdir_arg])
            .stdout(Stdio::null())
            .stderr(Stdio::null())
            .spawn()
            .unwrap();

        // the worker talks to the router, never to a backend directly
        let table_path = scratch.join("route.json");
        RouteSpec::new(vec![leader_addr.clone()]).save(&table_path).unwrap();
        let router_listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let router_addr = router_listener.local_addr().unwrap().to_string();
        let tpath = table_path.clone();
        let router = std::thread::spawn(move || replica::route(router_listener, &tpath));

        let mut client = Client::connect(&router_addr).unwrap();
        let sid = client.create(&spec).unwrap();
        wait_for(
            || fdir.join(format!("{sid}.jsonl")).exists(),
            15_000,
            "follower subscription",
        );

        let mut asks: Vec<String> = Vec::new();
        let mut promoted: Option<Child> = None;
        loop {
            let a = client.ask(&sid, "w0", &space).unwrap();
            asks.push(assignment_json(&a).to_string_compact());
            match a {
                TrialAssignment::Run(job) => {
                    for e in job.from_epoch + 1..=job.milestone {
                        let m = bench.accuracy_at(&job.config, e, spec.bench_seed);
                        if client.tell(&sid, job.trial, e, m).unwrap() == TellAck::Abandon {
                            break;
                        }
                    }
                }
                TrialAssignment::Stop(_) | TrialAssignment::Pause(_) => {}
                TrialAssignment::Wait => panic!("single worker never waits"),
                TrialAssignment::Done => break,
            }
            if promoted.is_none() && asks.len() >= kill_after {
                // quiesce (follower caught up between commit groups),
                // then SIGKILL the leader
                let lpath = ldir.join(format!("{sid}.jsonl"));
                let fpath = fdir.join(format!("{sid}.jsonl"));
                wait_for(
                    || match (std::fs::read(&lpath), std::fs::read(&fpath)) {
                        (Ok(l), Ok(f)) => l == f,
                        _ => false,
                    },
                    15_000,
                    "replication to quiesce",
                );
                leader.kill().unwrap();
                leader.wait().unwrap();
                follower.wait().unwrap();
                // promotion runbook: serve the follower's directory at a
                // new address, then swap it into the routing table; the
                // live worker connection rides the router's retry loop
                // across the gap
                let promoted_addr = free_addr();
                promoted = Some(
                    pasha_bin()
                        .args([
                            "serve",
                            "--addr",
                            &promoted_addr,
                            "--journal-dir",
                            fdir.to_str().unwrap(),
                        ])
                        .stdout(Stdio::null())
                        .stderr(Stdio::null())
                        .spawn()
                        .unwrap(),
                );
                drop(connect_when_up(&promoted_addr));
                RouteSpec::new(vec![promoted_addr]).save(&table_path).unwrap();
            }
        }
        let mut promoted = promoted.expect("the session outlived the kill point");

        assert_eq!(asks.len(), ref_asks.len(), "same number of asks");
        assert_eq!(asks, ref_asks, "ask stream byte-identical across failover");
        let status = client.status(&sid).unwrap();
        let served_best = status.get("best_metric").unwrap().as_f64().unwrap();
        assert_eq!(
            served_best.to_bits(),
            ref_best.metric.to_bits(),
            "incumbent survives the failover bit-for-bit"
        );

        // a sessionless shutdown broadcasts through the router to the
        // promoted backend and then stops the router itself
        client.shutdown().unwrap();
        router.join().unwrap().unwrap();
        promoted.wait().unwrap();
        for d in [&ldir, &fdir, &scratch] {
            let _ = std::fs::remove_dir_all(d);
        }
    }
}
