//! End-to-end tests for the ask/tell tuning service.
//!
//! * **Journal recovery property** — run a multi-worker session to
//!   completion, journaling every mutating op; truncate the journal at
//!   many points (whole-event and mid-line); recover; replay the
//!   remainder of the reference op trace and require every subsequent
//!   `ask` response to be byte-identical, and the final incumbent to
//!   match the uninterrupted run exactly. Covers ASHA, PASHA, the
//!   stopping-type variants (mid-rung kills with pauses pending and jobs
//!   in flight) and a BO-searcher session.
//! * **TCP equivalence** — `serve` + `worker` over localhost must land
//!   on the same incumbent as the in-process `Tuner::run` for the same
//!   seeds.

use pasha::benchmarks::Benchmark;
use pasha::scheduler::asktell::{assignment_json, config_from_json, TellAck, TrialAssignment};
use pasha::service::{run_worker, Client, Registry, Server, Session, SessionSpec};
use pasha::tuner::{bench_from_name, scheduler_from_name, SearcherKind, Tuner, TunerSpec};
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

fn tmp_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("pasha-e2e-{}-{name}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// One step of the deterministic reference trace.
#[derive(Clone, Debug)]
enum Op {
    /// `ask` by `worker`, with the canonical response bytes.
    Ask { worker: usize, resp: String },
    /// `tell(trial, epoch, metric)` by some worker, with the ack.
    Tell {
        trial: usize,
        epoch: u32,
        metric: f64,
        ack: TellAck,
    },
}

/// A recorded op plus the number of journal events written up to and
/// including it (the alignment key between trace and journal lines).
struct Traced {
    op: Op,
    events_after: usize,
}

fn worker_name(w: usize) -> String {
    format!("w{w}")
}

/// Drive `session` to completion with `workers` round-robin synchronous
/// workers (one op per worker per round), recording every op. The
/// round-robin order makes the whole trace a pure function of the
/// session spec, while still interleaving jobs so kills land mid-rung
/// with work in flight.
fn drive_traced(
    session: &mut Session,
    bench: &dyn Benchmark,
    bench_seed: u64,
    workers: usize,
) -> Vec<Traced> {
    let mut trace = Vec::new();
    let mut jobs: Vec<Option<(pasha::scheduler::Job, u32)>> = vec![None; workers];
    let mut done = vec![false; workers];
    while !done.iter().all(|&d| d) {
        for w in 0..workers {
            if done[w] {
                continue;
            }
            match jobs[w].take() {
                None => {
                    let assignment = session.ask(&worker_name(w)).unwrap();
                    let resp = assignment_json(&assignment).to_string_compact();
                    // events_journaled is the exact journal line count
                    // (minus the create header) — the alignment key
                    trace.push(Traced {
                        op: Op::Ask { worker: w, resp },
                        events_after: session.events_journaled(),
                    });
                    match assignment {
                        TrialAssignment::Run(job) => {
                            let from = job.from_epoch;
                            jobs[w] = Some((job, from + 1));
                        }
                        TrialAssignment::Done => done[w] = true,
                        _ => {}
                    }
                }
                Some((job, epoch)) => {
                    let metric = bench.accuracy_at(&job.config, epoch, bench_seed);
                    let ack = session.tell(job.trial, epoch, metric).unwrap();
                    trace.push(Traced {
                        op: Op::Tell {
                            trial: job.trial,
                            epoch,
                            metric,
                            ack,
                        },
                        events_after: session.events_journaled(),
                    });
                    if ack == TellAck::Continue {
                        jobs[w] = Some((job, epoch + 1));
                    }
                }
            }
        }
    }
    trace
}

/// Replay the trace tail on a recovered session, asserting byte-identical
/// ask responses and identical tell acks. Returns the number of asks
/// compared.
fn replay_tail(session: &mut Session, tail: &[&Traced], label: &str) -> usize {
    let mut asks = 0usize;
    for t in tail {
        match &t.op {
            Op::Ask { worker, resp } => {
                let replayed = session.ask(&worker_name(*worker)).unwrap();
                let replayed = assignment_json(&replayed).to_string_compact();
                assert_eq!(&replayed, resp, "{label}: ask #{asks} diverged after recovery");
                asks += 1;
            }
            Op::Tell {
                trial,
                epoch,
                metric,
                ack,
            } => {
                let replayed = session.tell(*trial, *epoch, *metric).unwrap();
                assert_eq!(replayed, *ack, "{label}: tell ack diverged after recovery");
            }
        }
    }
    asks
}

fn spec_for(scheduler: &str, searcher: SearcherKind, budget: usize) -> SessionSpec {
    SessionSpec {
        bench: "lcbench-Fashion-MNIST".into(),
        scheduler: scheduler.into(),
        searcher,
        seed: 5,
        bench_seed: 0,
        config_budget: budget,
        ..SessionSpec::default()
    }
}

/// The recovery property for one session spec: every cut of the journal
/// recovers to a state whose continuation is byte-identical to the
/// uninterrupted run.
fn check_recovery(label: &str, spec: SessionSpec, workers: usize) {
    let dir = tmp_dir(label);
    let path = dir.join("session.jsonl");
    let bench = bench_from_name(&spec.bench).unwrap();

    let mut live = Session::create("s0", spec.clone(), Some(&path)).unwrap();
    let trace = drive_traced(&mut live, bench.as_ref(), spec.bench_seed, workers);
    let best_full = live.core_ref().best().expect("session found an incumbent");
    drop(live);

    let lines: Vec<String> = std::fs::read_to_string(&path)
        .unwrap()
        .lines()
        .map(|l| l.to_string())
        .collect();
    let total_events = lines.len() - 1; // minus the create header
    assert!(total_events > 20, "{label}: workload too small to cut");

    // Whole-event cuts across the run, denser around the middle, plus a
    // couple of mid-line byte cuts (crash artifacts).
    let mut cuts: Vec<usize> = (0..8).map(|i| 1 + i * total_events / 8).collect();
    cuts.push(total_events); // recover the completed journal too
    let mut saw_pause_mid_rung = false;
    for (i, &cut) in cuts.iter().enumerate() {
        let cut_path = dir.join(format!("cut-{i}.jsonl"));
        let mut content = lines[..=cut].join("\n");
        content.push('\n');
        if i % 3 == 1 && cut < total_events {
            // torn final append: recovery must drop the partial line
            let partial = &lines[cut + 1][..lines[cut + 1].len() / 2];
            content.push_str(partial);
        }
        std::fs::write(&cut_path, &content).unwrap();

        let (mut recovered, report) = Session::recover(&cut_path).unwrap();
        assert_eq!(report.events_replayed, cut, "{label}: replay count at cut {cut}");
        let core = recovered.core_ref();
        if core.stats().paused_trials > 0 && core.in_flight_count() > 0 {
            saw_pause_mid_rung = true;
        }
        let tail: Vec<&Traced> = trace.iter().filter(|t| t.events_after > cut).collect();
        let asks = replay_tail(&mut recovered, &tail, label);
        if cut < total_events {
            assert!(asks > 0, "{label}: cut {cut} left no asks to compare");
        }
        // after the full tail, the incumbent must match exactly
        let best = recovered.core_ref().best().expect("recovered incumbent");
        assert_eq!(best.trial, best_full.trial, "{label}: best trial");
        assert_eq!(
            best.metric.to_bits(),
            best_full.metric.to_bits(),
            "{label}: best metric"
        );
        assert_eq!(best.config, best_full.config, "{label}: best config");
    }
    if label.contains("pasha-stop") {
        assert!(
            saw_pause_mid_rung,
            "{label}: no cut landed mid-rung with a pause pending — \
             the scenario the journal must survive"
        );
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn recovery_asha() {
    check_recovery("asha", spec_for("asha", SearcherKind::Random, 32), 3);
}

#[test]
fn recovery_pasha() {
    check_recovery("pasha", spec_for("pasha", SearcherKind::Random, 32), 3);
}

#[test]
fn recovery_asha_stop() {
    check_recovery("asha-stop", spec_for("asha-stop", SearcherKind::Random, 32), 3);
}

#[test]
fn recovery_pasha_stop_mid_rung_pause() {
    // The stopping-type PASHA session: kills land while trials are
    // paused at the resource cap and other jobs are mid-flight.
    check_recovery("pasha-stop", spec_for("pasha-stop", SearcherKind::Random, 48), 3);
}

#[test]
fn recovery_bo_searcher() {
    // Model-based searcher: the GP's state is rebuilt through replayed
    // on_report calls, so ask responses stay byte-identical.
    check_recovery("bo", spec_for("pasha", SearcherKind::Bo, 16), 2);
}

#[test]
fn tcp_session_matches_inprocess_tuner() {
    // The acceptance bar: a full simulated LCBench session over real TCP
    // lands on the same incumbent as Tuner::run for the same seeds.
    let spec = SessionSpec {
        bench: "lcbench-Fashion-MNIST".into(),
        scheduler: "pasha".into(),
        searcher: SearcherKind::Random,
        seed: 3,
        bench_seed: 0,
        config_budget: 24,
        ..SessionSpec::default()
    };
    let dir = tmp_dir("tcp");
    let registry = Registry::with_journal_dir(dir.clone()).unwrap();
    let server = Server::bind("127.0.0.1:0", Arc::new(registry)).unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let server_thread = std::thread::spawn(move || server.run());

    let bench = bench_from_name(&spec.bench).unwrap();
    let mut client = Client::connect(&addr).unwrap();
    let sid = client.create(&spec).unwrap();
    let report = run_worker(
        &mut client,
        &sid,
        "w0",
        bench.as_ref(),
        spec.bench_seed,
        Duration::from_millis(1),
    )
    .unwrap();
    assert!(report.jobs_completed > 0);
    let status = client.status(&sid).unwrap();
    let served_best = status.get("best_metric").unwrap().as_f64().unwrap();
    let served_config = config_from_json(
        bench.space(),
        status.get("best_config").expect("best config in status"),
    )
    .unwrap();

    let tuner_spec = TunerSpec {
        workers: 1,
        config_budget: spec.config_budget,
        searcher: SearcherKind::Random,
        extra_stop: Vec::new(),
    };
    let builder = scheduler_from_name(&spec.scheduler, spec.eta, spec.config_budget).unwrap();
    let inproc = Tuner::run(bench.as_ref(), builder.as_ref(), &tuner_spec, spec.seed, 0);
    assert_eq!(
        served_best.to_bits(),
        inproc.best_metric.to_bits(),
        "served {} vs in-process {}",
        served_best,
        inproc.best_metric
    );
    assert_eq!(Some(served_config.clone()), inproc.best_config);
    let served_retrain = bench.retrain_accuracy(&served_config, spec.bench_seed);
    assert_eq!(served_retrain.to_bits(), inproc.retrain_accuracy.to_bits());

    // the journal the server wrote must replay cleanly, to the same best
    let journal = dir.join(format!("{sid}.jsonl"));
    let (recovered, _) = Session::recover(&journal).unwrap();
    let best = recovered.core_ref().best().unwrap();
    assert_eq!(best.metric.to_bits(), served_best.to_bits());

    client.shutdown().unwrap();
    server_thread.join().unwrap().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn tcp_many_workers_drain_one_session() {
    // Concurrency smoke: several TCP workers share one session; the run
    // drains, every worker exits on Done, and the incumbent is sane.
    let spec = SessionSpec {
        bench: "lcbench-Fashion-MNIST".into(),
        scheduler: "asha".into(),
        searcher: SearcherKind::Random,
        seed: 1,
        config_budget: 16,
        ..SessionSpec::default()
    };
    let server = Server::bind("127.0.0.1:0", Arc::new(Registry::in_memory())).unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let server_thread = std::thread::spawn(move || server.run());

    let bench = bench_from_name(&spec.bench).unwrap();
    let mut control = Client::connect(&addr).unwrap();
    let sid = control.create(&spec).unwrap();
    let reports: Vec<pasha::service::WorkerReport> = std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for w in 0..4 {
            let addr = addr.as_str();
            let sid = sid.as_str();
            let bench = &bench;
            handles.push(scope.spawn(move || {
                let mut client = Client::connect(addr).unwrap();
                run_worker(
                    &mut client,
                    sid,
                    &format!("w{w}"),
                    bench.as_ref(),
                    0,
                    Duration::from_millis(1),
                )
                .unwrap()
            }));
        }
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    let total_jobs: usize = reports.iter().map(|r| r.jobs_completed).sum();
    assert!(total_jobs >= 16, "all configs trained: {total_jobs}");
    let status = control.status(&sid).unwrap();
    assert_eq!(status.get("in_flight").unwrap().as_f64(), Some(0.0), "drained");
    assert!(status.get("best_metric").unwrap().as_f64().unwrap() > 0.0);
    control.shutdown().unwrap();
    server_thread.join().unwrap().unwrap();
}
