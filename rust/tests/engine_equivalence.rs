//! Integration tests for the event-driven engine refactor:
//!
//! 1. the stopping-type ASHA/PASHA variants reproduce the promotion-type
//!    accuracy-vs-runtime shape on NASBench201/CIFAR-100;
//! 2. cancellation never leaks results — a trial's recorded curve covers
//!    exactly its delivered milestones, and halted runs keep partial
//!    state consistent;
//! 3. the parallel experiment-grid driver yields results identical to
//!    the serial reference, in the same order.

use pasha::benchmarks::nasbench201::NasBench201;
use pasha::benchmarks::pd1::Pd1;
use pasha::scheduler::asha::AshaBuilder;
use pasha::scheduler::pasha::PashaBuilder;
use pasha::scheduler::stopping::{StopAshaBuilder, StopPashaBuilder};
use pasha::scheduler::SchedulerBuilder;
use pasha::tuner::{StopSpec, TuneResult, Tuner, TunerSpec};
use pasha::util::stats::mean;

fn spec(budget: usize) -> TunerSpec {
    TunerSpec {
        config_budget: budget,
        ..Default::default()
    }
}

fn mean_over_seeds(
    bench: &dyn pasha::benchmarks::Benchmark,
    builder: &dyn SchedulerBuilder,
    budget: usize,
    f: impl Fn(&TuneResult) -> f64,
) -> f64 {
    let rs: Vec<f64> = (0..3u64)
        .map(|s| f(&Tuner::run_with(bench, builder, &spec(budget), s, 0)))
        .collect();
    mean(&rs)
}

#[test]
fn stopping_variants_reproduce_paper_shape_on_cifar100() {
    let bench = NasBench201::cifar100();
    let acc = |b: &dyn SchedulerBuilder| mean_over_seeds(&bench, b, 64, |r| r.retrain_accuracy);
    let rt = |b: &dyn SchedulerBuilder| mean_over_seeds(&bench, b, 64, |r| r.runtime_seconds);

    let asha_acc = acc(&AshaBuilder::default());
    let astop_acc = acc(&StopAshaBuilder::default());
    let pasha_acc = acc(&PashaBuilder::default());
    let pstop_acc = acc(&StopPashaBuilder::default());
    // Accuracy parity across all four variants (paper Table 1 band).
    for (name, a) in [
        ("ASHA-stop", astop_acc),
        ("PASHA", pasha_acc),
        ("PASHA-stop", pstop_acc),
    ] {
        assert!(
            (asha_acc - a).abs() < 3.0,
            "{name} accuracy {a:.2} vs ASHA {asha_acc:.2}"
        );
    }
    // The PASHA-over-ASHA runtime saving holds within each decision mode.
    let asha_rt = rt(&AshaBuilder::default());
    let pasha_rt = rt(&PashaBuilder::default());
    let astop_rt = rt(&StopAshaBuilder::default());
    let pstop_rt = rt(&StopPashaBuilder::default());
    assert!(
        pasha_rt < asha_rt,
        "promotion: pasha {pasha_rt:.0}s vs asha {asha_rt:.0}s"
    );
    assert!(
        pstop_rt < astop_rt,
        "stopping: pasha-stop {pstop_rt:.0}s vs asha-stop {astop_rt:.0}s"
    );
}

#[test]
fn stopping_pasha_caps_resources_like_promotion_pasha() {
    let bench = NasBench201::cifar100();
    let max_r = |b: &dyn SchedulerBuilder| {
        mean_over_seeds(&bench, b, 64, |r| r.max_resources as f64)
    };
    // Both PASHA variants must stay below their fixed-R counterparts.
    assert!(max_r(&PashaBuilder::default()) <= max_r(&AshaBuilder::default()));
    assert!(max_r(&StopPashaBuilder::default()) <= max_r(&StopAshaBuilder::default()));
}

#[test]
fn cancelled_work_never_reaches_trial_state() {
    // Truncate an ASHA run hard with a clock budget: in-flight jobs are
    // cancelled at the halt. Every trial's curve must still cover exactly
    // its delivered epochs (a leaked cancellation segment would desync
    // curve length from trained_epochs, and ShCore::record would panic
    // on the gap in debug builds).
    let bench = NasBench201::cifar10();
    let full = Tuner::run_with(&bench, &AshaBuilder::default(), &spec(48), 0, 0);
    assert!(full.cancelled_jobs == 0);
    let s = TunerSpec {
        extra_stop: vec![StopSpec::ClockBudget(full.runtime_seconds * 0.3)],
        ..spec(48)
    };
    let cut = Tuner::run_with(&bench, &AshaBuilder::default(), &s, 0, 0);
    assert!(cut.cancelled_jobs > 0, "halt must cancel in-flight work");
    assert!(cut.runtime_seconds <= full.runtime_seconds * 0.3 + 1e-9);
    assert!(cut.total_epochs < full.total_epochs);
    // Stopping-type run: stopped trials stay frozen at their last
    // delivered milestone.
    let st = Tuner::run_with(&bench, &StopAshaBuilder::default(), &spec(48), 0, 0);
    assert!(st.stopped_trials > 0);
    assert_eq!(st.configs_sampled, 48);
}

#[test]
fn parallel_grid_matches_serial_reference_across_benchmarks() {
    let sched_seeds = [0u64, 1, 2];
    let bench_seeds = [0u64, 1];
    let s = spec(24);

    let nas = NasBench201::cifar10();
    let pasha = PashaBuilder::default();
    let serial = Tuner::run_repeated_serial(&nas, &pasha, &s, &sched_seeds, &bench_seeds);
    let parallel = Tuner::run_repeated_with(&nas, &pasha, &s, &sched_seeds, &bench_seeds);
    assert_eq!(serial, parallel, "NASBench201 grid must be reproducible");

    let pd1 = Pd1::wmt();
    let pstop = StopPashaBuilder::default();
    let serial = Tuner::run_repeated_serial(&pd1, &pstop, &s, &sched_seeds, &[0]);
    let parallel = Tuner::run_repeated_with(&pd1, &pstop, &s, &sched_seeds, &[0]);
    assert_eq!(serial, parallel, "PD1 stopping-type grid must be reproducible");

    // Order is (sched_seed-major, bench_seed-minor): rows with the same
    // bench seed but different scheduler seeds must differ.
    assert_eq!(serial.len(), 3);
    assert!(
        serial[0].best_config != serial[1].best_config
            || serial[0].runtime_seconds != serial[1].runtime_seconds,
        "different scheduler seeds must explore differently"
    );
}
