//! The `ExperimentSpec` compatibility gates.
//!
//! * **Golden schema fixture** — specs constructed in code must serialize
//!   byte-for-byte to `tests/fixtures/spec_v2.golden.jsonl`. Any schema
//!   drift (a renamed field, a changed default, a reordered key) fails
//!   here before it can corrupt journals in the wild.
//! * **Round-trip property** — for randomly generated specs,
//!   parse(serialize(spec)) == spec and serialize∘parse is byte-stable.
//! * **v1 journal fixtures** — committed PR 3/4-era `ev_create` journals
//!   must migrate through `ExperimentSpec::from_json` and recover; a
//!   full generated v1 journal must recover with the byte-identical-ask
//!   verification recovery performs on every replayed event.
//! * **Legacy CLI equivalence** — for each legacy flag combination, the
//!   lowered spec must produce a `TuneResult` bit-identical to part-wise
//!   construction with the knobs the old factories hardcoded.

use pasha::curvefit::ModelChoice;
use pasha::ranking::RankingSpec;
use pasha::scheduler::asktell::{TellAck, TrialAssignment};
use pasha::searcher::bo::BoConfig;
use pasha::service::journal::ev_create;
use pasha::service::Session;
use pasha::spec::{
    apply_flag_overrides, BenchSpec, DecisionMode, ExecBackendKind, ExecSpec, ExperimentSpec,
    SchedulerSpec, SearcherSpec, StopRules, WarmStartSpec, WarmTrial,
};
use pasha::tuner::{StopSpec, Tuner, TunerSpec};
use pasha::util::json::{parse, Json};
use pasha::util::ptest::{check, Gen};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

fn fixture(name: &str) -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests/fixtures")
        .join(name)
}

fn tmp_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("pasha-specrt-{}-{name}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// The specs pinned by the golden fixture, in file order.
fn golden_specs() -> Vec<ExperimentSpec> {
    let default = ExperimentSpec::default();
    let kitchen_sink = ExperimentSpec {
        bench: BenchSpec::new("pd1-wmt"),
        scheduler: SchedulerSpec::Asha {
            r_min: 2,
            eta: 4,
            mode: DecisionMode::Stop,
        },
        searcher: SearcherSpec::bo_default(),
        exec: ExecSpec {
            workers: 8,
            backend: ExecBackendKind::Pool,
        },
        stop: StopRules {
            config_budget: 64,
            epoch_budget: Some(4000),
            time_budget: Some(3600.5),
        },
        seed: 42,
        bench_seed: 7,
    };
    let rbo = ExperimentSpec {
        bench: BenchSpec::new("lcbench-Fashion-MNIST"),
        scheduler: SchedulerSpec::Pasha {
            r_min: 1,
            eta: 3,
            mode: DecisionMode::Promote,
            ranking: RankingSpec::Rbo { p: 0.9, t: 0.5 },
        },
        stop: StopRules {
            config_budget: 32,
            ..Default::default()
        },
        seed: 5,
        ..ExperimentSpec::default()
    };
    let lce = ExperimentSpec {
        bench: BenchSpec::new("nas-cifar100"),
        scheduler: SchedulerSpec::Lce {
            r_min: 2,
            eta: 4,
            model: ModelChoice::Exp,
            min_points: 6,
            stop_quantile: 0.25,
            confidence: 0.8,
        },
        stop: StopRules {
            config_budget: 48,
            ..Default::default()
        },
        seed: 9,
        ..ExperimentSpec::default()
    };
    vec![default, kitchen_sink, rbo, lce]
}

#[test]
fn golden_schema_fixture_pins_the_wire_format() {
    let golden = std::fs::read_to_string(fixture("spec_v2.golden.jsonl")).unwrap();
    let lines: Vec<&str> = golden.lines().collect();
    let specs = golden_specs();
    assert_eq!(lines.len(), specs.len(), "fixture line count");
    for (i, (spec, line)) in specs.iter().zip(&lines).enumerate() {
        assert_eq!(
            &spec.to_json().to_string_compact(),
            line,
            "golden spec #{i} drifted — the v2 wire schema changed; if this is \
             intentional, bump the spec version and regenerate the fixture"
        );
        // and the pinned bytes parse back to the same spec
        let back = ExperimentSpec::from_json(&parse(line).unwrap()).unwrap();
        assert_eq!(&back, spec, "golden spec #{i} re-parse");
    }
}

fn gen_ranking(g: &mut Gen) -> RankingSpec {
    match g.usize(0, 8) {
        0 => RankingSpec::NoiseAdaptive {
            percentile: g.f64(1.0, 100.0),
        },
        1 => RankingSpec::Direct,
        2 => RankingSpec::SoftFixed {
            epsilon: g.f64(0.0, 5.0),
        },
        3 => RankingSpec::SoftSigma {
            mult: g.f64(0.1, 4.0),
        },
        4 => RankingSpec::SoftMeanGap,
        5 => RankingSpec::SoftMedianGap,
        6 => RankingSpec::Rbo {
            p: g.f64(0.05, 1.0),
            t: g.f64(0.0, 1.0),
        },
        7 => RankingSpec::Rrr {
            p: g.f64(0.05, 1.0),
            t: g.f64(0.0, 0.5),
        },
        _ => RankingSpec::Arrr {
            p: g.f64(0.05, 1.0),
            t: g.f64(0.0, 0.5),
        },
    }
}

fn gen_spec(g: &mut Gen) -> ExperimentSpec {
    let benches = [
        "nas-cifar10",
        "nas-cifar100",
        "nas-imagenet16",
        "pd1-wmt",
        "pd1-imagenet",
        "lcbench-Fashion-MNIST",
    ];
    let bench = BenchSpec::new(benches[g.usize(0, benches.len() - 1)]);
    let r_min = g.usize(1, 4) as u32;
    let eta = g.usize(2, 5) as u32;
    let scheduler = match g.usize(0, 6) {
        0 => SchedulerSpec::Asha {
            r_min,
            eta,
            mode: if g.bool() {
                DecisionMode::Promote
            } else {
                DecisionMode::Stop
            },
        },
        1 => SchedulerSpec::Pasha {
            r_min,
            eta,
            mode: if g.bool() {
                DecisionMode::Promote
            } else {
                DecisionMode::Stop
            },
            ranking: gen_ranking(g),
        },
        2 => SchedulerSpec::Sh { r_min, eta },
        3 => SchedulerSpec::Hyperband { r_min, eta },
        4 => SchedulerSpec::FixedEpoch {
            epochs: g.usize(1, 10) as u32,
        },
        5 => SchedulerSpec::Lce {
            r_min,
            eta,
            model: match g.usize(0, 2) {
                0 => ModelChoice::Power,
                1 => ModelChoice::Exp,
                _ => ModelChoice::Auto,
            },
            min_points: g.usize(3, 12) as u32,
            stop_quantile: g.f64(0.05, 0.95),
            confidence: g.f64(0.05, 0.95),
        },
        _ => SchedulerSpec::RandomBaseline,
    };
    let searcher = if g.bool() {
        SearcherSpec::Random
    } else {
        let config = BoConfig {
            min_points: g.usize(1, 16),
            num_candidates: g.usize(1, 256),
            random_fraction: g.f64(0.0, 1.0),
            lengthscale: g.f64(0.01, 2.0),
            signal_var: g.f64(0.1, 4.0),
            noise_var: g.f64(1e-6, 0.1),
        };
        // warm starts round-trip in both states: an unresolved store
        // reference and a sealed spec with embedded observations
        let warm_start = match g.usize(0, 2) {
            0 => None,
            1 => Some(WarmStartSpec::new("prior/trials.jsonl", g.usize(1, 64))),
            _ => {
                let mut ws = WarmStartSpec::new("prior/trials.jsonl", g.usize(1, 64));
                ws.trials = Some(
                    (0..g.usize(0, 3))
                        .map(|_| WarmTrial {
                            config: vec![g.f64(0.0, 10.0), g.f64(0.0, 10.0)],
                            epoch: g.usize(1, 50) as u32,
                            metric: g.f64(0.0, 100.0),
                        })
                        .collect(),
                );
                Some(ws)
            }
        };
        SearcherSpec::Bo { config, warm_start }
    };
    ExperimentSpec {
        bench,
        scheduler,
        searcher,
        exec: ExecSpec {
            workers: g.usize(1, 16),
            backend: if g.bool() {
                ExecBackendKind::Sim
            } else {
                ExecBackendKind::Pool
            },
        },
        stop: StopRules {
            config_budget: g.usize(1, 4096),
            epoch_budget: if g.bool() {
                Some(g.usize(1, 100_000) as u64)
            } else {
                None
            },
            time_budget: if g.bool() {
                Some(g.f64(0.001, 1e6))
            } else {
                None
            },
        },
        // < 2^32 so the f64 wire representation is exact
        seed: g.u64() >> 32,
        bench_seed: g.u64() >> 32,
    }
}

#[test]
fn parse_serialize_parse_is_byte_identical_for_random_specs() {
    check("spec round-trip", 300, |g| {
        let spec = gen_spec(g);
        spec.validate().unwrap_or_else(|e| panic!("generated spec invalid: {e}"));
        let first = spec.to_json().to_string_compact();
        let parsed = ExperimentSpec::from_json(&parse(&first).unwrap())
            .unwrap_or_else(|e| panic!("parse failed for {first}: {e}"));
        assert_eq!(parsed, spec, "value round-trip for {first}");
        let second = parsed.to_json().to_string_compact();
        assert_eq!(second, first, "byte round-trip");
    });
}

/// The v1 JSON encoding old journal headers carry (what
/// `SessionSpec::to_json` produced before the redesign).
fn v1_spec_json(spec: &ExperimentSpec) -> Json {
    let mut o = Json::obj();
    o.set("bench", spec.bench.name.as_str())
        .set("scheduler", spec.scheduler.wire_name())
        .set("eta", spec.scheduler.eta().unwrap_or(3))
        .set("searcher", spec.searcher.wire_name())
        .set("seed", spec.seed as f64)
        .set("bench_seed", spec.bench_seed as f64)
        .set("config_budget", spec.stop.config_budget);
    if let Some(e) = spec.stop.epoch_budget {
        o.set("epoch_budget", e as f64);
    }
    o
}

#[test]
fn committed_v1_fixture_journals_migrate_and_recover() {
    for (name, id, scheduler, replayed) in [
        ("v1_create_asha.jsonl", "v1-asha", "asha", 0usize),
        ("v1_events.jsonl", "v1-events", "pasha", 3usize),
    ] {
        // copy the fixture out of the repo so nothing can touch it
        let dir = tmp_dir(name);
        let path = dir.join("journal.jsonl");
        std::fs::copy(fixture(name), &path).unwrap();
        let (session, report) = Session::recover_readonly(&path)
            .unwrap_or_else(|e| panic!("{name}: v1 journal failed to recover: {e}"));
        assert_eq!(session.id, id, "{name}");
        assert_eq!(report.events_replayed, replayed, "{name}");
        assert_eq!(report.truncated_bytes, 0, "{name}");
        // the header migrated to the legacy knobs
        assert_eq!(session.spec.bench.name, "lcbench-Fashion-MNIST", "{name}");
        assert_eq!(session.spec.scheduler.wire_name(), scheduler, "{name}");
        assert_eq!(session.spec.scheduler.r_min(), Some(1), "{name}");
        assert_eq!(
            session.spec.scheduler.ranking().cloned(),
            if scheduler == "pasha" {
                Some(RankingSpec::default())
            } else {
                None
            },
            "{name}: the implicit v1 ranking is the paper default"
        );
        assert_eq!(session.spec.stop.config_budget, 8, "{name}");
        let _ = std::fs::remove_dir_all(&dir);
    }
}

#[test]
fn generated_v1_journal_recovers_byte_identically() {
    // Write a complete session journal, then rewrite its header to the
    // exact v1 encoding. Recovery re-derives the core from the migrated
    // spec and verifies every replayed ask byte-for-byte against what
    // was acknowledged — which is precisely the v1-compatibility
    // guarantee: same bytes in, same decisions out.
    for scheduler in ["asha", "pasha", "pasha-stop"] {
        let dir = tmp_dir(&format!("v1gen-{scheduler}"));
        let path = dir.join("session.jsonl");
        let mut spec = ExperimentSpec::named("lcbench-Fashion-MNIST", scheduler).unwrap();
        spec.stop.config_budget = 8;
        spec.seed = 4;
        let bench = spec.bench.build().unwrap();
        let mut live = Session::create("v1gen", spec.clone(), Some(&path)).unwrap();
        loop {
            match live.ask("w0").unwrap() {
                TrialAssignment::Run(job) => {
                    for e in job.from_epoch + 1..=job.milestone {
                        let m = bench.accuracy_at(&job.config, e, spec.bench_seed);
                        if live.tell(job.trial, e, m).unwrap() == TellAck::Abandon {
                            break;
                        }
                    }
                }
                TrialAssignment::Stop(_) | TrialAssignment::Pause(_) => {}
                TrialAssignment::Wait => panic!("single worker never waits"),
                TrialAssignment::Done => break,
            }
        }
        let best = live.core_ref().best().unwrap();
        drop(live);

        // swap the v2 header for the v1 bytes of the same spec
        let text = std::fs::read_to_string(&path).unwrap();
        let mut lines: Vec<&str> = text.lines().collect();
        let v1_header = ev_create("v1gen", &v1_spec_json(&spec)).to_string_compact();
        lines[0] = &v1_header;
        std::fs::write(&path, lines.join("\n") + "\n").unwrap();

        let (recovered, report) = Session::recover_readonly(&path)
            .unwrap_or_else(|e| panic!("{scheduler}: v1-headed journal refused: {e}"));
        assert!(report.events_replayed > 10, "{scheduler}: whole history replayed");
        assert_eq!(recovered.spec, spec, "{scheduler}: migration is lossless");
        let rbest = recovered.core_ref().best().unwrap();
        assert_eq!(rbest.trial, best.trial, "{scheduler}");
        assert_eq!(rbest.metric.to_bits(), best.metric.to_bits(), "{scheduler}");
        let _ = std::fs::remove_dir_all(&dir);
    }
}

#[test]
fn lce_is_v2_only_in_both_directions() {
    // Emission abstains: no v1 wire shape can carry the scheduler, so
    // status responses must not lie to pre-redesign workers.
    let spec = ExperimentSpec::named("lcbench-Fashion-MNIST", "lce").unwrap();
    assert!(spec.to_v1_compat_json().is_none(), "no v1 shape can carry lce");
    // And a v1 payload naming it is rejected with the field cited, not
    // silently migrated into a session no legacy client could have made.
    let err = ExperimentSpec::from_json(&v1_spec_json(&spec)).unwrap_err();
    assert!(err.contains("field 'scheduler'"), "{err}");
}

fn flags(pairs: &[(&str, &str)]) -> HashMap<String, String> {
    pairs
        .iter()
        .map(|(k, v)| (k.to_string(), v.to_string()))
        .collect()
}

#[test]
fn legacy_cli_flag_combinations_lower_bit_identically() {
    use pasha::tuner::SearcherKind;

    // Each case is (CLI flags as the old `pasha run` accepted them, the
    // part-wise construction with the knobs the legacy factories
    // hardcoded: r_min = 1, the default ranking).
    let bench_name = "lcbench-Fashion-MNIST";
    let schedulers = [
        "asha",
        "pasha",
        "asha-stop",
        "pasha-stop",
        "sh",
        "hyperband",
        "1-epoch",
        "random",
    ];
    for scheduler in schedulers {
        for searcher in ["random", "bo"] {
            if searcher == "bo" && scheduler != "pasha" {
                continue; // one BO case keeps the matrix fast
            }
            let budget = 12usize;
            let seed = 3u64;
            let eta = 3u32;

            // New path: the CLI lowering.
            let mut spec = ExperimentSpec::default();
            apply_flag_overrides(
                &mut spec,
                &flags(&[
                    ("bench", bench_name),
                    ("scheduler", scheduler),
                    ("budget", "12"),
                    ("seed", "3"),
                    ("eta", "3"),
                    ("searcher", searcher),
                    ("workers", "4"),
                ]),
            )
            .unwrap();
            let new = Tuner::run(&spec).unwrap();

            // Old path: part-wise construction with the legacy knobs.
            let bench = BenchSpec::new(bench_name).build().unwrap();
            let builder = SchedulerSpec::from_name(scheduler, 1, eta, RankingSpec::default())
                .unwrap()
                .builder(budget)
                .unwrap();
            let kind = SearcherKind::parse(searcher).unwrap();
            let tspec = TunerSpec {
                workers: 4,
                config_budget: budget,
                searcher: kind.to_spec(),
                extra_stop: Vec::new(),
            };
            let old = Tuner::run_with(bench.as_ref(), builder.as_ref(), &tspec, seed, 0);

            assert_eq!(
                new, old,
                "flag combination --scheduler {scheduler} --searcher {searcher} \
                 must lower bit-identically"
            );
        }
    }

    // Stopping-budget flags lower into the same rule set, in order.
    let mut spec = ExperimentSpec::default();
    apply_flag_overrides(
        &mut spec,
        &flags(&[
            ("bench", bench_name),
            ("scheduler", "asha"),
            ("budget", "16"),
            ("seed", "1"),
            ("epoch-budget", "60"),
            ("time-budget", "50000"),
        ]),
    )
    .unwrap();
    let new = Tuner::run(&spec).unwrap();
    let bench = BenchSpec::new(bench_name).build().unwrap();
    let builder = SchedulerSpec::from_name("asha", 1, 3, RankingSpec::default())
        .unwrap()
        .builder(16)
        .unwrap();
    let tspec = TunerSpec {
        workers: 4,
        config_budget: 16,
        searcher: SearcherSpec::Random,
        extra_stop: vec![StopSpec::EpochBudget(60), StopSpec::ClockBudget(50000.0)],
    };
    let old = Tuner::run_with(bench.as_ref(), builder.as_ref(), &tspec, 1, 0);
    assert_eq!(new, old, "budget flags must lower bit-identically");
}

#[test]
fn v1_wire_create_and_v2_wire_create_build_identical_sessions() {
    // A v1 client and a v2 client describing the same experiment must
    // land on sessions whose ask streams are identical.
    let mut spec = ExperimentSpec::named("lcbench-Fashion-MNIST", "pasha").unwrap();
    spec.stop.config_budget = 6;
    spec.seed = 2;
    let v1 = ExperimentSpec::from_json(&v1_spec_json(&spec)).unwrap();
    let v2 = ExperimentSpec::from_json(&spec.to_json()).unwrap();
    assert_eq!(v1, v2);
    let mut a = Session::create("a", v1, None).unwrap();
    let mut b = Session::create("b", v2, None).unwrap();
    for _ in 0..40 {
        let ra = a.ask("w0").unwrap();
        let rb = b.ask("w0").unwrap();
        assert_eq!(ra, rb);
        match ra {
            TrialAssignment::Run(job) => {
                for e in job.from_epoch + 1..=job.milestone {
                    let ack_a = a.tell(job.trial, e, 50.0 + e as f64).unwrap();
                    let ack_b = b.tell(job.trial, e, 50.0 + e as f64).unwrap();
                    assert_eq!(ack_a, ack_b);
                    if ack_a == TellAck::Abandon {
                        break;
                    }
                }
            }
            TrialAssignment::Done => break,
            _ => {}
        }
    }
}
