//! Persistent trial store: completed trials as reusable artifacts.
//!
//! Every completed tuning run discards knowledge that Zappella &
//! Archambeau (arXiv 2103.16111) show is worth keeping: tuning problems
//! recur, and prior trials warm-start the next run on the same (or a
//! related) task. This module is the results half of that story — the
//! spec half is the versioned [`crate::spec::ExperimentSpec`]:
//!
//! * [`TrialStore`] — an append-only JSONL file of [`TrialRecord`]s with
//!   the same torn-tail discipline as the service journal (one shared
//!   implementation: [`crate::util::jsonl`]). Appends are self-repairing,
//!   a torn final line is dropped on read, mid-file corruption is an
//!   error, and [`TrialStore::gc`] deduplicates with an atomic rewrite.
//! * [`spec_fingerprint`] — the canonical task key: a 64-bit hash over
//!   the benchmark name, the search-space structure, and the fidelity
//!   schedule (`r_min`, `eta`). Deliberately **invariant** to searcher,
//!   seeds, exec, and stop-rule fields, so related runs (same task,
//!   different searcher/seed/budget) hash to the same fingerprint and can
//!   share trials.
//! * [`resolve_warm_start`] — seals a `warm_start: {from, max_trials}`
//!   reference on a spec into embedded prior observations
//!   ([`crate::spec::WarmTrial`]), rank-ordered by prior performance.
//!   Sealing happens once, before a run or session is created: after it,
//!   the spec is self-contained, so journal replay and snapshot recovery
//!   are independent of later store mutations.
//! * [`ingest`] — records a finished run's trials under the spec's
//!   fingerprint. At-least-once semantics: a crash between run completion
//!   and ingestion can duplicate records; `gc` collapses them.

use crate::config::space::SearchSpace;
use crate::scheduler::TrialInfo;
use crate::spec::{ExperimentSpec, WarmTrial};
use crate::util::json::Json;
use crate::util::jsonl;
use std::io;
use std::path::{Path, PathBuf};

/// Where (and whether) to persist completed trials. Kept out of
/// [`ExperimentSpec`] on purpose: the store location is operational
/// context, not experiment identity — two runs writing to different
/// stores are still the same experiment.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct StoreSpec {
    pub path: PathBuf,
}

impl StoreSpec {
    pub fn new(path: impl Into<PathBuf>) -> StoreSpec {
        StoreSpec { path: path.into() }
    }
}

/// One completed trial: a configuration observed at a resource level.
#[derive(Clone, Debug, PartialEq)]
pub struct TrialRecord {
    /// Task key ([`spec_fingerprint`]) this trial belongs to.
    pub fingerprint: String,
    /// Benchmark name, for human-readable `store ls` output.
    pub bench: String,
    /// Positional configuration values (the [`crate::scheduler::asktell::config_json`]
    /// number encoding; the search space supplies the value kinds).
    pub config: Vec<f64>,
    /// Epochs trained when `metric` was observed (1-based).
    pub epoch: u32,
    /// Observed validation accuracy (%) at `epoch`.
    pub metric: f64,
}

impl TrialRecord {
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("bench", self.bench.as_str())
            .set("config", self.config.clone())
            .set("epoch", self.epoch)
            .set("fp", self.fingerprint.as_str())
            .set("metric", self.metric);
        o
    }

    pub fn from_json(j: &Json) -> Result<TrialRecord, String> {
        let s = |k: &str| -> Result<String, String> {
            j.get(k)
                .and_then(|v| v.as_str())
                .map(|v| v.to_string())
                .ok_or_else(|| format!("trial record missing string field '{k}'"))
        };
        let n = |k: &str| -> Result<f64, String> {
            j.get(k)
                .and_then(|v| v.as_f64())
                .ok_or_else(|| format!("trial record missing numeric field '{k}'"))
        };
        let config = j
            .get("config")
            .and_then(|v| v.as_arr())
            .ok_or("trial record missing array field 'config'")?
            .iter()
            .map(|v| v.as_f64().ok_or("trial config values must be numbers"))
            .collect::<Result<Vec<f64>, _>>()?;
        let epoch = n("epoch")?;
        if epoch < 1.0 || epoch.fract() != 0.0 {
            return Err(format!("trial epoch must be a positive integer, got {epoch}"));
        }
        Ok(TrialRecord {
            fingerprint: s("fp")?,
            bench: s("bench")?,
            config,
            epoch: epoch as u32,
            metric: n("metric")?,
        })
    }
}

// ---------------------------------------------------------------------------
// Spec fingerprint: the canonical task key.
// ---------------------------------------------------------------------------

/// FNV-1a 64-bit hash: tiny, dependency-free, stable across platforms.
fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Fingerprint the *task*: benchmark + search-space structure + fidelity
/// schedule. Two specs that differ only in searcher, seeds, exec backend,
/// decision mode, ranking, or stop rules produce the same fingerprint —
/// their trials are mutually reusable. Changing the benchmark, any
/// search-space domain, `r_min`, or `eta` changes it.
pub fn fingerprint_parts(bench: &str, space: &SearchSpace, r_min: u32, eta: u32) -> String {
    let domains: Vec<Json> = space
        .params
        .iter()
        .map(|(name, d)| {
            use crate::config::space::Domain;
            let parts: Vec<Json> = match *d {
                Domain::Float { lo, hi } => vec!["f".into(), lo.into(), hi.into()],
                Domain::LogFloat { lo, hi } => vec!["lf".into(), lo.into(), hi.into()],
                Domain::Int { lo, hi } => vec!["i".into(), lo.into(), hi.into()],
                Domain::LogInt { lo, hi } => vec!["li".into(), lo.into(), hi.into()],
                Domain::Categorical { n } => vec!["c".into(), n.into()],
            };
            let mut o = Json::obj();
            o.set(name, Json::Arr(parts));
            o
        })
        .collect();
    let mut payload = Json::obj();
    payload
        .set("bench", bench)
        .set("eta", eta)
        .set("r_min", r_min)
        .set("space", Json::Arr(domains));
    format!("{:016x}", fnv1a64(payload.to_string_compact().as_bytes()))
}

/// [`fingerprint_parts`] for a full spec: the benchmark is built to
/// obtain its search space; schedulers without a rung ladder (fixed-epoch
/// and random baselines) take the paper defaults `r_min = 1`, `eta = 3`.
pub fn spec_fingerprint(spec: &ExperimentSpec) -> Result<String, String> {
    let bench = spec.bench.build()?;
    Ok(fingerprint_parts(
        &spec.bench.name,
        bench.space(),
        spec.scheduler.r_min().unwrap_or(1),
        spec.scheduler.eta().unwrap_or(3),
    ))
}

// ---------------------------------------------------------------------------
// The store file.
// ---------------------------------------------------------------------------

/// Outcome of a [`TrialStore::gc`] pass.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct GcReport {
    pub kept: usize,
    pub dropped: usize,
}

/// Append-only JSONL trial store. Opening is lazy (no filesystem access
/// until a read or append); concurrent appenders are safe at the
/// whole-line level thanks to the self-repairing append discipline.
pub struct TrialStore {
    path: PathBuf,
}

impl TrialStore {
    pub fn open(path: impl Into<PathBuf>) -> TrialStore {
        TrialStore { path: path.into() }
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Append records, creating the file (and parents) if needed.
    pub fn append(&self, records: &[TrialRecord]) -> io::Result<()> {
        for r in records {
            jsonl::append_line(&self.path, &r.to_json())?;
        }
        crate::obs::counter("pasha_store_records_appended_total", &[])
            .add(records.len() as u64);
        Ok(())
    }

    /// Read every whole record. A torn final line is dropped (crash
    /// artifact); a record that is valid JSON but the wrong shape, or
    /// unparseable mid-file, is corruption ([`io::ErrorKind::InvalidData`]).
    pub fn read_all(&self) -> io::Result<Vec<TrialRecord>> {
        let read = jsonl::read_jsonl(&self.path)?;
        crate::obs::counter("pasha_store_reads_total", &[]).inc();
        read.records
            .iter()
            .map(|j| {
                TrialRecord::from_json(j).map_err(|e| {
                    io::Error::new(
                        io::ErrorKind::InvalidData,
                        format!("corrupt trial store {}: {e}", self.path.display()),
                    )
                })
            })
            .collect()
    }

    /// Records matching one task fingerprint.
    pub fn for_fingerprint(&self, fp: &str) -> io::Result<Vec<TrialRecord>> {
        Ok(self
            .read_all()?
            .into_iter()
            .filter(|r| r.fingerprint == fp)
            .collect())
    }

    /// Deduplicate and rewrite atomically. The key is
    /// `(fingerprint, config, epoch)`; the *last* record wins (later
    /// appends supersede earlier ones), and surviving records keep their
    /// original relative order, so gc is deterministic.
    pub fn gc(&self) -> io::Result<GcReport> {
        let records = self.read_all()?;
        let key = |r: &TrialRecord| {
            format!(
                "{}|{}|{}",
                r.fingerprint,
                Json::from(r.config.clone()).to_string_compact(),
                r.epoch
            )
        };
        let mut last: std::collections::HashMap<String, usize> = std::collections::HashMap::new();
        for (i, r) in records.iter().enumerate() {
            last.insert(key(r), i);
        }
        let kept: Vec<&TrialRecord> = records
            .iter()
            .enumerate()
            .filter(|(i, r)| last[&key(r)] == *i)
            .map(|(_, r)| r)
            .collect();
        let report = GcReport {
            kept: kept.len(),
            dropped: records.len() - kept.len(),
        };
        let lines: Vec<Json> = kept.iter().map(|r| r.to_json()).collect();
        jsonl::rewrite_atomic(&self.path, &lines)?;
        crate::obs::counter("pasha_store_gc_dropped_total", &[]).add(report.dropped as u64);
        Ok(report)
    }
}

// ---------------------------------------------------------------------------
// Ingestion and warm-start resolution.
// ---------------------------------------------------------------------------

/// Map a finished run's trials to store records: each trial that reported
/// at least one epoch contributes its deepest observation.
pub fn records_from_trials(
    fingerprint: &str,
    bench: &str,
    trials: &[TrialInfo],
) -> Vec<TrialRecord> {
    trials
        .iter()
        .filter(|t| !t.curve.is_empty())
        .map(|t| TrialRecord {
            fingerprint: fingerprint.to_string(),
            bench: bench.to_string(),
            config: t.config.values.iter().map(|v| v.as_f64()).collect(),
            epoch: t.curve.len() as u32,
            metric: *t.curve.last().expect("filtered non-empty"),
        })
        .collect()
}

/// Record a finished run's trials under the spec's fingerprint. Returns
/// the number of records appended.
pub fn ingest(
    store: &StoreSpec,
    spec: &ExperimentSpec,
    trials: &[TrialInfo],
) -> Result<usize, String> {
    let fp = spec_fingerprint(spec)?;
    let records = records_from_trials(&fp, &spec.bench.name, trials);
    TrialStore::open(&store.path)
        .append(&records)
        .map_err(|e| format!("trial store append {}: {e}", store.path.display()))?;
    Ok(records.len())
}

/// Select the prior observations a warm start should carry: fingerprint
/// match, budget-matched (`epoch <= max_epochs`), deduplicated per
/// configuration keeping the deepest (then best) observation, and
/// rank-ordered by prior performance — best metric first, deeper
/// observations breaking ties. The order is the BO searcher's initial
/// design order, so it is fully deterministic (final tie-break on the
/// canonical config bytes).
pub fn select_warm_trials(
    records: &[TrialRecord],
    fp: &str,
    max_epochs: u32,
    max_trials: usize,
) -> Vec<WarmTrial> {
    use std::cmp::Ordering;
    let config_key = |r: &TrialRecord| Json::from(r.config.clone()).to_string_compact();
    let mut best: std::collections::BTreeMap<String, &TrialRecord> =
        std::collections::BTreeMap::new();
    for r in records {
        if r.fingerprint != fp
            || r.epoch < 1
            || r.epoch > max_epochs
            || !r.metric.is_finite()
            || r.config.iter().any(|x| !x.is_finite())
        {
            continue;
        }
        let k = config_key(r);
        let better = match best.get(&k) {
            None => true,
            Some(prev) => (r.epoch, r.metric) > (prev.epoch, prev.metric),
        };
        if better {
            best.insert(k, r);
        }
    }
    let mut survivors: Vec<&TrialRecord> = best.into_values().collect();
    survivors.sort_by(|a, b| {
        b.metric
            .partial_cmp(&a.metric)
            .unwrap_or(Ordering::Equal)
            .then(b.epoch.cmp(&a.epoch))
            .then(config_key(a).cmp(&config_key(b)))
    });
    survivors.truncate(max_trials);
    survivors
        .into_iter()
        .map(|r| WarmTrial {
            config: r.config.clone(),
            epoch: r.epoch,
            metric: r.metric,
        })
        .collect()
}

/// Seal an unresolved `warm_start: {from, max_trials}` reference into
/// embedded prior observations. No-op (returns 0) when the spec has no
/// warm start or it is already sealed. After sealing, the spec is
/// self-contained: building it never touches the store again, so
/// warm-started sessions recover and replay byte-identically regardless
/// of later store writes.
pub fn resolve_warm_start(spec: &mut ExperimentSpec) -> Result<usize, String> {
    let (from, max_trials) = match spec.searcher.warm_start() {
        Some(ws) if ws.trials.is_none() => (ws.from.clone(), ws.max_trials),
        _ => return Ok(0),
    };
    let fp = spec_fingerprint(spec)?;
    let max_epochs = spec.bench.build()?.max_epochs();
    let records = TrialStore::open(&from)
        .read_all()
        .map_err(|e| format!("warm-start store {from}: {e}"))?;
    let trials = select_warm_trials(&records, &fp, max_epochs, max_trials);
    let n = trials.len();
    spec.searcher.seal_warm_start(trials);
    Ok(n)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spec::{ExecBackendKind, ExperimentSpec, SearcherSpec, StopRules};
    use crate::util::ptest::{check, Gen};

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("pasha-store-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    fn rec(fp: &str, config: &[f64], epoch: u32, metric: f64) -> TrialRecord {
        TrialRecord {
            fingerprint: fp.to_string(),
            bench: "lcbench-Fashion-MNIST".to_string(),
            config: config.to_vec(),
            epoch,
            metric,
        }
    }

    #[test]
    fn record_json_round_trip() {
        let r = rec("abc123", &[1.0, 0.25, 3.0], 9, 87.5);
        let j = r.to_json();
        let back = TrialRecord::from_json(&crate::util::json::parse(&j.to_string_compact()).unwrap())
            .unwrap();
        assert_eq!(back, r);
        assert!(TrialRecord::from_json(&Json::obj()).is_err());
    }

    #[test]
    fn store_append_read_gc() {
        let path = tmp("roundtrip.jsonl");
        let _ = std::fs::remove_file(&path);
        let store = TrialStore::open(&path);
        store
            .append(&[
                rec("fp1", &[0.5], 1, 50.0),
                rec("fp1", &[0.5], 1, 55.0), // duplicate key, later wins
                rec("fp2", &[0.5], 1, 60.0),
                rec("fp1", &[0.7], 2, 70.0),
            ])
            .unwrap();
        assert_eq!(store.read_all().unwrap().len(), 4);
        assert_eq!(store.for_fingerprint("fp1").unwrap().len(), 3);
        let report = store.gc().unwrap();
        assert_eq!(report, GcReport { kept: 3, dropped: 1 });
        let after = store.read_all().unwrap();
        assert_eq!(after.len(), 3);
        assert_eq!(after[0].metric, 55.0, "last duplicate wins");
        // gc is idempotent
        assert_eq!(store.gc().unwrap(), GcReport { kept: 3, dropped: 0 });
    }

    #[test]
    fn torn_byte_fuzz_reads_a_whole_prefix() {
        // The journal fuzz discipline applied to the store: cut the file
        // at every byte boundary; the reader must return a whole-record
        // prefix (never an error, never a partial record), and appending
        // afterwards must self-repair.
        let path = tmp("fuzz.jsonl");
        let _ = std::fs::remove_file(&path);
        let store = TrialStore::open(&path);
        let records: Vec<TrialRecord> = (0..5)
            .map(|i| rec("fpf", &[i as f64, 0.125 * i as f64], i + 1, 50.0 + i as f64))
            .collect();
        store.append(&records).unwrap();
        let full = std::fs::read(&path).unwrap();
        for cut in 0..=full.len() {
            std::fs::write(&path, &full[..cut]).unwrap();
            let got = store.read_all().unwrap();
            assert!(got.len() <= records.len(), "cut {cut}");
            assert_eq!(got[..], records[..got.len()], "cut {cut}: prefix property");
            // repair: append over the torn tail, then the prefix + new
            // record read back whole
            store.append(&[rec("fpf", &[9.0], 1, 99.0)]).unwrap();
            let repaired = store.read_all().unwrap();
            assert_eq!(repaired.last().unwrap().metric, 99.0, "cut {cut}");
        }
    }

    #[test]
    fn mid_file_corruption_is_an_error() {
        let path = tmp("corrupt.jsonl");
        std::fs::write(
            &path,
            format!(
                "{}\nnot json\n{}\n",
                rec("a", &[1.0], 1, 1.0).to_json().to_string_compact(),
                rec("a", &[2.0], 1, 2.0).to_json().to_string_compact()
            ),
        )
        .unwrap();
        let err = TrialStore::open(&path).read_all().unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
    }

    fn spec_for(bench: &str, scheduler: &str) -> ExperimentSpec {
        ExperimentSpec::named(bench, scheduler).unwrap()
    }

    #[test]
    fn fingerprint_invariance_property() {
        // Invariant under searcher/seed/exec/stop/mode/ranking changes;
        // sensitive to bench, search-space, and r_min/eta changes.
        check("fingerprint invariance", 60, |g: &mut Gen| {
            let benches = ["lcbench-Fashion-MNIST", "nas-cifar10", "pd1-wmt"];
            let bench = benches[g.usize(0, benches.len() - 1)];
            let scheds = ["asha", "pasha", "asha-stop", "pasha-stop"];
            let base = spec_for(bench, scheds[g.usize(0, scheds.len() - 1)]);
            let fp = spec_fingerprint(&base).unwrap();

            // searcher / seed / exec / stop changes: same fingerprint
            let mut varied = base.clone();
            varied.searcher = if g.bool() {
                SearcherSpec::Random
            } else {
                SearcherSpec::bo_default()
            };
            varied.seed = g.u64() >> 32;
            varied.bench_seed = g.u64() >> 32;
            varied.exec.workers = g.usize(1, 16);
            varied.exec.backend = if g.bool() {
                ExecBackendKind::Sim
            } else {
                ExecBackendKind::Pool
            };
            varied.stop = StopRules {
                config_budget: g.usize(1, 512),
                epoch_budget: if g.bool() { Some(77) } else { None },
                time_budget: None,
            };
            assert_eq!(spec_fingerprint(&varied).unwrap(), fp, "invariant fields");

            // a different scheduler *family* with the same ladder: same task
            for other in scheds {
                let same_task = spec_for(bench, other);
                assert_eq!(spec_fingerprint(&same_task).unwrap(), fp, "{other}");
            }

            // bench change: different fingerprint
            let other_bench = benches[(benches.iter().position(|b| *b == bench).unwrap() + 1)
                % benches.len()];
            assert_ne!(spec_fingerprint(&spec_for(other_bench, "asha")).unwrap(), fp);
        });
    }

    #[test]
    fn fingerprint_distinguishes_ladder_and_space() {
        let space = SearchSpace::lcbench();
        let base = fingerprint_parts("lcbench-Fashion-MNIST", &space, 1, 3);
        assert_ne!(fingerprint_parts("lcbench-Fashion-MNIST", &space, 2, 3), base);
        assert_ne!(fingerprint_parts("lcbench-Fashion-MNIST", &space, 1, 4), base);
        // any domain perturbation changes the key
        let wider = SearchSpace::new()
            .add("num_layers", crate::config::space::Domain::Int { lo: 1, hi: 6 });
        let narrow = SearchSpace::new()
            .add("num_layers", crate::config::space::Domain::Int { lo: 1, hi: 5 });
        assert_ne!(
            fingerprint_parts("x", &wider, 1, 3),
            fingerprint_parts("x", &narrow, 1, 3)
        );
        // and the hex shape is stable
        assert_eq!(base.len(), 16);
        assert!(base.chars().all(|c| c.is_ascii_hexdigit()));
    }

    #[test]
    fn warm_selection_ranks_and_budget_matches() {
        let records = vec![
            rec("fp", &[1.0], 3, 70.0),
            rec("fp", &[2.0], 9, 90.0),
            rec("fp", &[2.0], 3, 60.0),  // shallower duplicate of [2.0]: dropped
            rec("fp", &[3.0], 27, 80.0), // over the epoch budget: dropped
            rec("fp", &[4.0], 9, 85.0),
            rec("other", &[5.0], 1, 99.0), // wrong task: dropped
            rec("fp", &[6.0], 1, f64::NAN), // non-finite: dropped
        ];
        let sel = select_warm_trials(&records, "fp", 9, 8);
        let metrics: Vec<f64> = sel.iter().map(|t| t.metric).collect();
        assert_eq!(metrics, vec![90.0, 85.0, 70.0], "rank-ordered, best first");
        assert_eq!(sel[0].config, vec![2.0]);
        // max_trials truncates from the bottom
        let top = select_warm_trials(&records, "fp", 9, 2);
        assert_eq!(top.len(), 2);
        assert_eq!(top[1].metric, 85.0);
    }

    #[test]
    fn resolve_seals_the_spec_once() {
        let path = tmp("resolve.jsonl");
        let _ = std::fs::remove_file(&path);
        let mut spec = spec_for("lcbench-Fashion-MNIST", "pasha");
        let fp = spec_fingerprint(&spec).unwrap();
        TrialStore::open(&path)
            .append(&[
                rec(&fp, &[3.0, 256.0, 64.0, 0.01, 0.001, 0.5, 0.2], 9, 88.0),
                rec(&fp, &[2.0, 128.0, 32.0, 0.02, 0.002, 0.6, 0.1], 9, 82.0),
            ])
            .unwrap();
        // no warm start: no-op
        assert_eq!(resolve_warm_start(&mut spec).unwrap(), 0);
        spec.searcher = SearcherSpec::bo_warm(path.to_string_lossy().as_ref(), 8);
        assert_eq!(resolve_warm_start(&mut spec).unwrap(), 2);
        let sealed = spec.searcher.warm_start().unwrap().trials.clone().unwrap();
        assert_eq!(sealed.len(), 2);
        assert_eq!(sealed[0].metric, 88.0);
        // already sealed: no-op even if the store grows
        TrialStore::open(&path)
            .append(&[rec(&fp, &[1.0, 64.0, 16.0, 0.03, 0.003, 0.7, 0.3], 9, 95.0)])
            .unwrap();
        assert_eq!(resolve_warm_start(&mut spec).unwrap(), 0);
        assert_eq!(
            spec.searcher.warm_start().unwrap().trials.clone().unwrap().len(),
            2,
            "sealed specs never re-read the store"
        );
        // a missing store is an explicit error, not an empty warm start
        let mut missing = spec_for("lcbench-Fashion-MNIST", "pasha");
        missing.searcher = SearcherSpec::bo_warm("/nonexistent/store.jsonl", 8);
        assert!(resolve_warm_start(&mut missing).is_err());
    }

    #[test]
    fn ingest_records_completed_trials() {
        use crate::config::space::Config;
        let path = tmp("ingest.jsonl");
        let _ = std::fs::remove_file(&path);
        let spec = spec_for("nas-cifar10", "asha");
        let mut done = TrialInfo::new(Config::cat(7));
        done.curve = vec![40.0, 55.0, 61.0];
        let empty = TrialInfo::new(Config::cat(3)); // never reported: skipped
        let n = ingest(&StoreSpec::new(&path), &spec, &[done, empty]).unwrap();
        assert_eq!(n, 1);
        let records = TrialStore::open(&path).read_all().unwrap();
        assert_eq!(records.len(), 1);
        assert_eq!(records[0].fingerprint, spec_fingerprint(&spec).unwrap());
        assert_eq!(records[0].bench, "nas-cifar10");
        assert_eq!(records[0].config, vec![7.0]);
        assert_eq!(records[0].epoch, 3);
        assert_eq!(records[0].metric, 61.0);
    }
}
