//! `pasha` — launcher CLI for the PASHA reproduction.
//!
//! Subcommands (hand-rolled parser; the offline image has no `clap`):
//!
//! ```text
//! pasha run    [--spec exp.json] [--set key.path=value ...] [--bench <name>]
//!              [--scheduler <name>] [--budget N] [--seed S] [--r-min R]
//!              [--ranking soft:0.025|plain|rbo:0.9|...] [--epoch-budget E]
//!              [--time-budget SECONDS]
//! pasha table  <id>  [--scale paper|smoke] [--out results/]
//! pasha figure <1..5> [--out results/]
//! pasha report [--scale paper|smoke] [--out results/]   # everything
//! pasha bench-json [--suite engine|service|transfer|ablations|all] [--out FILE]
//! pasha serve  [--addr A] [--journal-dir DIR] [--snapshot-interval N] [--store FILE]
//!              [--io-threads N] [--shards N] [--legacy-threaded] [--metrics-addr A]
//!              [--replicate A] [--worker-lease SECONDS]
//! pasha follow ADDR --journal-dir DIR                    # replication follower
//! pasha route  [--addr A] --table route.json             # session router
//! pasha worker --addr A (--session ID | --create ...) [--expire] [--batch]
//! pasha store  <ls|gc|export> --store FILE [--fingerprint FP] [--out FILE]
//! pasha sessions --addr A                                # list sessions
//! pasha stats  --addr A [--check] [--journal-dir DIR]    # metrics snapshot
//! pasha recover --journal FILE                           # journal check
//! pasha compact --journal FILE                           # snapshot + truncate
//! pasha e2e    [--budget N] [--hidden H]                # real PJRT training
//! pasha artifacts-check                                  # PJRT smoke test
//! ```

use pasha::benchmarks::nasbench201::{NasBench201, Nb201Dataset};
use pasha::benchmarks::Benchmark;
use pasha::report::{experiments, figures};
use pasha::scheduler::asha::AshaBuilder;
use pasha::scheduler::asktell::config_from_json;
use pasha::scheduler::pasha::PashaBuilder;
use pasha::service::{
    run_worker, run_worker_batched, Client, Registry, Server, Session, SessionOptions,
};
use pasha::spec::{apply_flag_overrides, BenchSpec, ExperimentSpec, SPEC_FLAGS};
use pasha::store::{self, StoreSpec, TrialStore};
use pasha::tuner::{Tuner, TunerSpec};
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.is_empty() {
        usage();
        std::process::exit(2);
    }
    let (cmd, rest) = (args[0].as_str(), &args[1..]);
    let (flags, sets) = parse_flags(rest);
    let result = match cmd {
        "run" => cmd_run(&flags, &sets),
        "table" => cmd_table(rest.first().map(|s| s.as_str()), &flags),
        "figure" => cmd_figure(rest.first().map(|s| s.as_str()), &flags),
        "report" => cmd_report(&flags),
        "bench-json" => cmd_bench_json(&flags),
        "serve" => cmd_serve(&flags),
        "follow" => cmd_follow(rest.first().map(|s| s.as_str()), &flags),
        "route" => cmd_route(&flags),
        "worker" => cmd_worker(&flags, &sets),
        "store" => cmd_store(rest.first().map(|s| s.as_str()), &flags),
        "sessions" => cmd_sessions(&flags),
        "stats" => cmd_stats(&flags),
        "recover" => cmd_recover(&flags),
        "compact" => cmd_compact(&flags),
        "e2e" => cmd_e2e(&flags),
        "artifacts-check" => cmd_artifacts_check(),
        "help" | "--help" | "-h" => {
            usage();
            Ok(())
        }
        other => {
            pasha::log_error!("unknown command '{other}'");
            usage();
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        pasha::log_error!("{e}");
        std::process::exit(1);
    }
}

fn usage() {
    eprintln!(
        "pasha — Progressive ASHA reproduction (Bohdal et al., ICLR 2023)

USAGE:
  pasha run    [--spec exp.json] [--set key.path=value ...]
               [--bench <nas-cifar10|nas-cifar100|nas-imagenet16|pd1-wmt|pd1-imagenet|lcbench-<name>>]
               [--scheduler <asha|pasha|asha-stop|pasha-stop|lce|sh|hyperband|1-epoch|random>]
               [--budget N] [--seed S] [--eta E] [--r-min R]
               [--ranking plain|noisy[:PCT]|soft:EPS|sigma:MULT|mean-gap|median-gap|rbo:P[,T]|rrr:P[,T]|arrr:P[,T]]
               [--searcher random|bo] [--workers W] [--backend sim|pool]
               [--epoch-budget E] [--time-budget SECONDS]
               [--store trials.jsonl] [--warm-start trials.jsonl] [--warm-start-max N]
               # every flag lowers into one versioned ExperimentSpec (see README)
  pasha table  <1|2|3|4|5|6|8|9|10|11|12|13|14|15|ablation|stopping> [--scale paper|smoke] [--out DIR]
  pasha figure <1|2|3|4|5> [--out DIR]
  pasha report [--scale paper|smoke] [--out DIR]
  pasha bench-json [--suite engine|service|transfer|ablations|all] [--out FILE]
               # service suite: [--sessions N] [--workers M] [--budget B]
               #                [--mode event|threaded|both] [--gate BASELINE.json]
  pasha serve  [--addr 127.0.0.1:7171] [--journal-dir DIR] [--snapshot-interval N]
               [--store trials.jsonl] [--io-threads N] [--shards N] [--legacy-threaded]
               [--metrics-addr 127.0.0.1:9091]   # Prometheus text endpoint
               [--replicate 127.0.0.1:7272]      # ship commit groups to followers
               [--worker-lease SECONDS]          # expire silent workers (0 = off)
  pasha follow HOST:PORT --journal-dir DIR  # byte-identical journal copy
  pasha route  [--addr 127.0.0.1:7170] --table route.json  # session router
  pasha worker --addr HOST:PORT (--session ID | --create [--spec exp.json] [--bench B]
               [--scheduler S] [--budget N] [--seed S] [--eta E] [--r-min R] [--ranking ...]
               [--searcher random|bo] [--epoch-budget E] [--warm-start trials.jsonl]
               [--set key.path=value ...])
               [--worker-id W] [--expire] [--batch] [--shutdown]
  pasha store  ls --store trials.jsonl            # fingerprint summary
  pasha store  gc --store trials.jsonl            # dedup + compact in place
  pasha store  export --store trials.jsonl [--fingerprint FP] [--out FILE]
  pasha sessions --addr HOST:PORT
  pasha stats  --addr HOST:PORT [--check] [--journal-dir DIR]
               # metrics snapshot; --check enforces conservation invariants,
               # --journal-dir reconciles counters against a journal copy
  pasha recover --journal FILE             # verify a session journal replays cleanly
  pasha compact --journal FILE             # snapshot + truncate a session journal
  pasha e2e    [--budget N] [--hidden 64|128|256] [--workers W]
  pasha artifacts-check"
    );
}

/// Parse `--name value` pairs. `--set key=value` may repeat, so its
/// occurrences are collected separately in order.
fn parse_flags(args: &[String]) -> (HashMap<String, String>, Vec<String>) {
    let mut flags = HashMap::new();
    let mut sets = Vec::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(name) = args[i].strip_prefix("--") {
            if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                if name == "set" {
                    sets.push(args[i + 1].clone());
                } else {
                    flags.insert(name.to_string(), args[i + 1].clone());
                }
                i += 2;
            } else if name == "set" {
                // a dangling --set surfaces as a clear "--set expects
                // key.path=value" error instead of an unknown flag
                sets.push(String::new());
                i += 1;
            } else {
                flags.insert(name.to_string(), "true".to_string());
                i += 1;
            }
        } else {
            i += 1;
        }
    }
    (flags, sets)
}

/// Spec-lowering commands reject flags they do not understand — the
/// same strictness the spec parser applies to keys, so a typo like
/// `--rmin` cannot silently fall back to a default.
fn reject_unknown_flags(
    flags: &HashMap<String, String>,
    extra_allowed: &[&str],
) -> Result<(), String> {
    for name in flags.keys() {
        if !SPEC_FLAGS.contains(&name.as_str()) && !extra_allowed.contains(&name.as_str()) {
            let recognized: Vec<&str> = SPEC_FLAGS
                .iter()
                .chain(extra_allowed.iter())
                .copied()
                .collect();
            return Err(format!(
                "unknown flag --{name} (recognized: --set, --{})",
                recognized.join(", --")
            ));
        }
    }
    Ok(())
}

/// Resolve the experiment spec a command describes: start from `base`
/// (or a `--spec FILE`), lower every recognized flag onto it, then apply
/// the `--set key.path=value` overrides in order.
fn resolve_spec(
    base: ExperimentSpec,
    flags: &HashMap<String, String>,
    sets: &[String],
) -> Result<ExperimentSpec, String> {
    let mut spec = match flags.get("spec") {
        None => base,
        Some(path) => {
            let text = std::fs::read_to_string(path).map_err(|e| format!("--spec {path}: {e}"))?;
            let json =
                pasha::util::json::parse(&text).map_err(|e| format!("--spec {path}: {e}"))?;
            ExperimentSpec::from_json(&json).map_err(|e| format!("--spec {path}: {e}"))?
        }
    };
    apply_flag_overrides(&mut spec, flags)?;
    for assignment in sets {
        spec.set(assignment)?;
    }
    Ok(spec)
}

fn flag<T: std::str::FromStr>(flags: &HashMap<String, String>, name: &str, default: T) -> T {
    flags
        .get(name)
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn out_dir(flags: &HashMap<String, String>) -> PathBuf {
    PathBuf::from(
        flags
            .get("out")
            .cloned()
            .unwrap_or_else(|| "results".to_string()),
    )
}

fn scale(flags: &HashMap<String, String>) -> experiments::Scale {
    match flags.get("scale").map(|s| s.as_str()) {
        Some("smoke") => experiments::Scale::smoke(),
        _ => experiments::Scale::paper(),
    }
}

fn cmd_run(flags: &HashMap<String, String>, sets: &[String]) -> Result<(), String> {
    reject_unknown_flags(flags, &["store"])?;
    let mut spec = resolve_spec(ExperimentSpec::default(), flags, sets)?;
    // print the reproduction line *before* running (and before sealing —
    // the unsealed reference form is the reproducible recipe), so an
    // interrupted run still leaves it in the log
    println!("spec             : {}", spec.to_json().to_string_compact());
    let t0 = std::time::Instant::now();
    let r = match flags.get("store") {
        // --store: seal any warm start, run, and record the finished
        // trials back into the store for later transfers
        Some(path) => {
            let (r, ingested) = Tuner::run_stored(&spec, &StoreSpec::new(path))?;
            println!("trial store      : {path} (+{ingested} trials)");
            r
        }
        None => {
            let embedded = store::resolve_warm_start(&mut spec)?;
            if embedded > 0 {
                println!("warm start       : {embedded} prior trials embedded");
            }
            Tuner::run(&spec)?
        }
    };
    println!("benchmark        : {}", spec.bench.name);
    println!("scheduler        : {}", r.scheduler_name);
    println!("configs sampled  : {}", r.configs_sampled);
    println!("jobs executed    : {}", r.jobs);
    println!("epochs trained   : {}", r.total_epochs);
    if r.stopped_trials > 0 || r.cancelled_jobs > 0 {
        println!(
            "stopped trials   : {} ({} jobs cancelled in flight)",
            r.stopped_trials, r.cancelled_jobs
        );
    }
    println!("max resources    : {} epochs", r.max_resources);
    println!(
        "tuning runtime   : {:.2}h (simulated)",
        r.runtime_seconds / 3600.0
    );
    println!("best val metric  : {:.2}", r.best_metric);
    println!("retrain accuracy : {:.2}%", r.retrain_accuracy);
    if let Some(c) = &r.best_config {
        println!("best config      : {c}");
    }
    println!("(wall time: {:.2}s)", t0.elapsed().as_secs_f64());
    Ok(())
}

fn write_tables(
    tables: &[pasha::util::table::Table],
    dir: &PathBuf,
    stem: &str,
) -> Result<(), String> {
    std::fs::create_dir_all(dir).map_err(|e| e.to_string())?;
    let mut md = String::new();
    for t in tables {
        println!("{}", t.to_text());
        md.push_str(&t.to_markdown());
        md.push('\n');
    }
    let path = dir.join(format!("{stem}.md"));
    std::fs::write(&path, md).map_err(|e| e.to_string())?;
    println!("wrote {}", path.display());
    Ok(())
}

fn cmd_table(id: Option<&str>, flags: &HashMap<String, String>) -> Result<(), String> {
    let id = id.ok_or("table id required")?;
    let sc = scale(flags);
    let dir = out_dir(flags);
    let tables = match id {
        "1" => experiments::table1(&sc),
        "2" => experiments::table2(&sc),
        "3" => experiments::table3(&sc),
        "4" => vec![experiments::table_rankings(Nb201Dataset::Cifar100, &sc, 4)],
        "5" | "7" => experiments::table5(&sc),
        "6" => experiments::table6(&sc),
        "8" => experiments::table8(&sc),
        "9" => vec![experiments::table_rankings(Nb201Dataset::Cifar10, &sc, 9)],
        "10" => vec![experiments::table_rankings(Nb201Dataset::Cifar100, &sc, 10)],
        "11" => vec![experiments::table_rankings(
            Nb201Dataset::ImageNet16_120,
            &sc,
            11,
        )],
        "12" => experiments::table12(&sc),
        "13" => vec![experiments::table13(&sc, 34)],
        "14" => experiments::table14(&sc),
        "15" => experiments::table15(&sc),
        "ablation" => vec![experiments::ablation_schedulers(&sc)],
        "stopping" => vec![experiments::ablation_stopping(&sc)],
        other => return Err(format!("unknown table '{other}'")),
    };
    write_tables(&tables, &dir, &format!("table{id}"))
}

fn cmd_figure(id: Option<&str>, flags: &HashMap<String, String>) -> Result<(), String> {
    let id = id.ok_or("figure id required")?;
    let dir = out_dir(flags);
    std::fs::create_dir_all(&dir).map_err(|e| e.to_string())?;
    let budget: usize = flag(flags, "budget", 256);
    let (name, content) = match id {
        "1" => ("figure1.txt".to_string(), figures::figure1(budget)),
        "2" => (
            "figure2.txt".to_string(),
            figures::figure2(&[93.9, 93.8, 93.2, 93.1, 91.0], 0.15),
        ),
        "3" => (
            "figure3_cifar10.csv".to_string(),
            figures::figure3(Nb201Dataset::Cifar10, 0),
        ),
        "4" => (
            "figure4_cifar10.csv".to_string(),
            figures::figure4(Nb201Dataset::Cifar10, 0),
        ),
        "5" => {
            for ds in [
                Nb201Dataset::Cifar10,
                Nb201Dataset::Cifar100,
                Nb201Dataset::ImageNet16_120,
            ] {
                let csv = figures::figure5(ds, budget);
                let p = dir.join(format!(
                    "figure5_{}.csv",
                    NasBench201::new(ds).name().replace('/', "_")
                ));
                std::fs::write(&p, csv).map_err(|e| e.to_string())?;
                println!("wrote {}", p.display());
            }
            return Ok(());
        }
        other => return Err(format!("unknown figure '{other}'")),
    };
    let p = dir.join(name);
    std::fs::write(&p, &content).map_err(|e| e.to_string())?;
    if content.len() < 4000 {
        println!("{content}");
    }
    println!("wrote {}", p.display());
    Ok(())
}

fn cmd_report(flags: &HashMap<String, String>) -> Result<(), String> {
    for id in [
        "1", "2", "3", "4", "5", "6", "8", "9", "10", "11", "12", "13", "14", "15", "ablation",
    ] {
        println!("=== table {id} ===");
        cmd_table(Some(id), flags)?;
    }
    for id in ["1", "2", "3", "4", "5"] {
        println!("=== figure {id} ===");
        cmd_figure(Some(id), flags)?;
    }
    Ok(())
}

/// Performance records (`BENCH_*.json`): `--suite engine` (default) for
/// the in-process engine, `--suite service` for the TCP ask/tell loop,
/// `--suite transfer` for cold-vs-warm-start resource-to-target runs,
/// `--suite ablations` for the PASHA/ASHA/lce scheduler head-to-head,
/// `--suite all` for all of them.
fn cmd_bench_json(flags: &HashMap<String, String>) -> Result<(), String> {
    match flags.get("suite").map(|s| s.as_str()).unwrap_or("engine") {
        "engine" => bench_engine(flags),
        "service" => bench_service(flags, flags.get("out").cloned()),
        "transfer" => bench_transfer(flags, flags.get("out").cloned()),
        "ablations" => bench_ablations(flags, flags.get("out").cloned()),
        "all" => {
            bench_engine(flags)?;
            // `all` keeps each suite's default file name to avoid clobbering
            bench_service(flags, None)?;
            bench_transfer(flags, None)?;
            bench_ablations(flags, None)
        }
        other => Err(format!("unknown bench suite '{other}'")),
    }
}

/// Warm-start transfer benchmark: for each task family, a source run
/// populates a trial store, then a target task (same space, different
/// benchmark seed) is tuned cold vs warm and the epochs each needs to
/// reach a shared target metric are compared. Written as
/// `BENCH_transfer.json`, with a seal-once/run-twice determinism check.
fn bench_transfer(flags: &HashMap<String, String>, out: Option<String>) -> Result<(), String> {
    use pasha::spec::SearcherSpec;
    use pasha::util::json::Json;

    let out_path = PathBuf::from(out.unwrap_or_else(|| "BENCH_transfer.json".to_string()));
    let budget: usize = flag(flags, "budget", 24);
    let dir = std::env::temp_dir().join(format!("pasha-bench-transfer-{}", std::process::id()));
    std::fs::create_dir_all(&dir).map_err(|e| e.to_string())?;

    // Drive a spec to completion on one synchronous worker, recording the
    // incumbent after every told epoch: (cumulative epochs, best metric).
    let trajectory = |spec: &ExperimentSpec| -> Result<Vec<(u64, f64)>, String> {
        use pasha::scheduler::asktell::{TellAck, TrialAssignment};
        let bench = spec.bench.build()?;
        let mut at = spec.build_core()?;
        let mut track = Vec::new();
        let mut epochs = 0u64;
        loop {
            match at.ask("w0") {
                TrialAssignment::Run(job) => {
                    for e in job.from_epoch + 1..=job.milestone {
                        let m = bench.accuracy_at(&job.config, e, spec.bench_seed);
                        epochs += 1;
                        let ack = at.tell(job.trial, e, m).map_err(|e| e.to_string())?;
                        if let Some(b) = at.best() {
                            track.push((epochs, b.metric));
                        }
                        if ack == TellAck::Abandon {
                            break;
                        }
                    }
                }
                TrialAssignment::Stop(_) | TrialAssignment::Pause(_) => {}
                TrialAssignment::Wait => return Err("single worker must never wait".into()),
                TrialAssignment::Done => return Ok(track),
            }
        }
    };
    let epochs_to = |track: &[(u64, f64)], target: f64| -> Option<u64> {
        track.iter().find(|(_, m)| *m >= target).map(|(e, _)| *e)
    };

    let mut pairs = Vec::new();
    let mut all_deterministic = true;
    let mut all_warm_win = true;
    for bench_name in ["lcbench-Fashion-MNIST", "nas-cifar10"] {
        let store_path = dir.join(format!("{bench_name}.jsonl"));
        let _ = std::fs::remove_file(&store_path);
        let store = StoreSpec::new(&store_path);

        // Source task: BO under PASHA, trials recorded into the store.
        let mut source = ExperimentSpec::named(bench_name, "pasha")?;
        source.stop.config_budget = budget;
        source.searcher = SearcherSpec::bo_default();
        let (_, ingested) = Tuner::run_stored(&source, &store)?;

        // Target task: same family, different benchmark seed — cold BO
        // vs BO warm-started from the source task's observations.
        let mut cold = source.clone();
        cold.seed = 1;
        cold.bench_seed = 1;
        let mut warm = cold.clone();
        warm.searcher = SearcherSpec::bo_warm(
            store_path.to_str().ok_or("non-utf8 store path")?,
            budget / 2,
        );
        let embedded = store::resolve_warm_start(&mut warm)?;

        let cold_track = trajectory(&cold)?;
        let warm_track = trajectory(&warm)?;
        let cold_final = cold_track.last().map(|&(_, m)| m).unwrap_or(f64::NAN);
        let warm_final = warm_track.last().map(|&(_, m)| m).unwrap_or(f64::NAN);
        // Shared target: the weaker of the two final incumbents, so both
        // trajectories are guaranteed to cross it.
        let target = cold_final.min(warm_final);
        let cold_epochs = epochs_to(&cold_track, target).unwrap_or(u64::MAX);
        let warm_epochs = epochs_to(&warm_track, target).unwrap_or(u64::MAX);

        // Determinism: the sealed warm spec must reproduce bit-identically.
        let r1 = Tuner::run(&warm)?;
        let r2 = Tuner::run(&warm)?;
        let deterministic = r1 == r2;
        all_deterministic &= deterministic;
        all_warm_win &= warm_epochs <= cold_epochs;

        println!(
            "{bench_name}: target {target:.2} — cold {cold_epochs} epochs vs warm \
             {warm_epochs} epochs ({embedded} prior trials, {ingested} ingested, \
             deterministic={deterministic})"
        );
        let mut p = Json::obj();
        p.set("bench", bench_name)
            .set("ingested", ingested)
            .set("embedded_trials", embedded)
            .set("target_metric", target)
            .set("cold_epochs_to_target", cold_epochs as f64)
            .set("warm_epochs_to_target", warm_epochs as f64)
            .set(
                "speedup",
                cold_epochs as f64 / (warm_epochs as f64).max(1.0),
            )
            .set("cold_final_best", cold_final)
            .set("warm_final_best", warm_final)
            .set("warm_deterministic", deterministic);
        pairs.push(p);
    }
    let _ = std::fs::remove_dir_all(&dir);

    let mut root = Json::obj();
    root.set("benchmark", "transfer")
        .set("config_budget", budget)
        .set("pairs", Json::Arr(pairs))
        .set("all_deterministic", all_deterministic)
        .set("warm_never_slower", all_warm_win);
    std::fs::write(&out_path, root.to_string_pretty()).map_err(|e| e.to_string())?;
    println!("wrote {}", out_path.display());
    if !all_deterministic {
        return Err("sealed warm-start run was not deterministic".into());
    }
    Ok(())
}

/// Scheduler ablation benchmark: PASHA vs ASHA vs learning-curve
/// extrapolation (`lce`) head to head on both tabular benchmarks
/// (LCBench and NASBench201), one synchronous worker each, recording
/// epochs to a shared target accuracy, total consumed epochs, and final
/// regret into `BENCH_ablations.json`. Fails (nonzero exit) when `lce`
/// consumes more total epochs than ASHA on either benchmark — the
/// efficiency claim CI gates on.
fn bench_ablations(flags: &HashMap<String, String>, out: Option<String>) -> Result<(), String> {
    use pasha::scheduler::asktell::{TellAck, TrialAssignment};
    use pasha::util::json::Json;

    let out_path = PathBuf::from(out.unwrap_or_else(|| "BENCH_ablations.json".to_string()));
    let budget: usize = flag(flags, "budget", 32);

    // Same single-worker incumbent trajectory the transfer suite drives:
    // (cumulative epochs consumed, best metric so far) after every tell.
    let trajectory = |spec: &ExperimentSpec| -> Result<Vec<(u64, f64)>, String> {
        let bench = spec.bench.build()?;
        let mut at = spec.build_core()?;
        let mut track = Vec::new();
        let mut epochs = 0u64;
        loop {
            match at.ask("w0") {
                TrialAssignment::Run(job) => {
                    for e in job.from_epoch + 1..=job.milestone {
                        let m = bench.accuracy_at(&job.config, e, spec.bench_seed);
                        epochs += 1;
                        let ack = at.tell(job.trial, e, m).map_err(|e| e.to_string())?;
                        if let Some(b) = at.best() {
                            track.push((epochs, b.metric));
                        }
                        if ack == TellAck::Abandon {
                            break;
                        }
                    }
                }
                TrialAssignment::Stop(_) | TrialAssignment::Pause(_) => {}
                TrialAssignment::Wait => return Err("single worker must never wait".into()),
                TrialAssignment::Done => return Ok(track),
            }
        }
    };
    let epochs_to = |track: &[(u64, f64)], target: f64| -> Option<u64> {
        track.iter().find(|(_, m)| *m >= target).map(|(e, _)| *e)
    };

    let mut benches = Vec::new();
    let mut lce_at_or_below_asha = true;
    let mut gate_lines = Vec::new();
    for bench_name in ["lcbench-Fashion-MNIST", "nas-cifar10"] {
        let mut tracks = Vec::new();
        for sched in ["pasha", "asha", "lce"] {
            let mut spec = ExperimentSpec::named(bench_name, sched)?;
            spec.stop.config_budget = budget;
            tracks.push((sched, trajectory(&spec)?));
        }
        let finals: Vec<f64> = tracks
            .iter()
            .map(|(_, t)| t.last().map(|&(_, m)| m).unwrap_or(f64::NAN))
            .collect();
        // Shared target: the weakest final incumbent, so every arm is
        // guaranteed to cross it; regret is against the strongest.
        let target = finals.iter().copied().fold(f64::INFINITY, f64::min);
        let best_overall = finals.iter().copied().fold(f64::NEG_INFINITY, f64::max);
        let mut arms = Vec::new();
        let mut totals: HashMap<&str, u64> = HashMap::new();
        for ((sched, track), final_best) in tracks.iter().zip(&finals) {
            let total = track.last().map(|&(e, _)| e).unwrap_or(0);
            let to_target = epochs_to(track, target).unwrap_or(u64::MAX);
            totals.insert(*sched, total);
            println!(
                "{bench_name}/{sched}: {total} epochs consumed, {to_target} to target \
                 {target:.2}, final {final_best:.2} (regret {:.2})",
                best_overall - final_best
            );
            let mut a = Json::obj();
            a.set("scheduler", *sched)
                .set("total_epochs", total as f64)
                .set("epochs_to_target", to_target as f64)
                .set("final_best", *final_best)
                .set("final_regret", best_overall - final_best);
            arms.push(a);
        }
        let (lce_total, asha_total) = (totals["lce"], totals["asha"]);
        if lce_total > asha_total {
            lce_at_or_below_asha = false;
            gate_lines.push(format!(
                "{bench_name}: lce consumed {lce_total} epochs vs asha {asha_total}"
            ));
        }
        let mut b = Json::obj();
        b.set("bench", bench_name)
            .set("target_metric", target)
            .set("arms", Json::Arr(arms));
        benches.push(b);
    }

    let mut root = Json::obj();
    root.set("benchmark", "ablations")
        .set("config_budget", budget)
        .set("benches", Json::Arr(benches))
        .set("lce_total_at_or_below_asha", lce_at_or_below_asha);
    std::fs::write(&out_path, root.to_string_pretty()).map_err(|e| e.to_string())?;
    println!("wrote {}", out_path.display());
    if !lce_at_or_below_asha {
        return Err(format!(
            "ablation gate failed — lce must not consume more epochs than asha: {}",
            gate_lines.join("; ")
        ));
    }
    Ok(())
}

/// Record the engine's performance trajectory: serial-vs-parallel
/// experiment-grid wall time (with a result-identity check) and raw
/// simulator throughput, written as `BENCH_engine.json`.
fn bench_engine(flags: &HashMap<String, String>) -> Result<(), String> {
    use pasha::util::json::Json;
    use pasha::util::parallel::available_threads;
    use std::time::Instant;

    let out_path = PathBuf::from(
        flags
            .get("out")
            .cloned()
            .unwrap_or_else(|| "BENCH_engine.json".to_string()),
    );
    let builder = PashaBuilder::default();
    let spec = TunerSpec {
        config_budget: 64,
        ..Default::default()
    };
    let sched_seeds: Vec<u64> = (0..4).collect();
    let bench_seeds: Vec<u64> = (0..3).collect();
    let runs = sched_seeds.len() * bench_seeds.len();
    let threads = available_threads();

    // Each timed pass gets a fresh benchmark instance: NASBench201 caches
    // fitted curves internally, so reusing one instance would hand the
    // second pass a hot cache and skew the comparison.
    let bench_serial = NasBench201::cifar100();
    let t0 = Instant::now();
    let serial =
        Tuner::run_repeated_serial(&bench_serial, &builder, &spec, &sched_seeds, &bench_seeds);
    let serial_s = t0.elapsed().as_secs_f64();
    let bench_parallel = NasBench201::cifar100();
    let t1 = Instant::now();
    let parallel =
        Tuner::run_repeated_with(&bench_parallel, &builder, &spec, &sched_seeds, &bench_seeds);
    let parallel_s = t1.elapsed().as_secs_f64();
    let identical = serial == parallel;

    // Raw simulator throughput: jobs pushed through the event loop / sec,
    // again on a cold benchmark instance.
    let bench_sim = NasBench201::cifar100();
    let t2 = Instant::now();
    let mut sim_jobs = 0usize;
    for seed in 0..4u64 {
        let r = Tuner::run_with(&bench_sim, &AshaBuilder::default(), &spec, seed, 0);
        sim_jobs += r.jobs;
    }
    let sim_s = t2.elapsed().as_secs_f64();

    let mut grid = Json::obj();
    grid.set("runs", runs)
        .set("threads", threads)
        .set("serial_seconds", serial_s)
        .set("parallel_seconds", parallel_s)
        .set("speedup", serial_s / parallel_s.max(1e-9))
        .set("identical_results", identical);
    let mut sim = Json::obj();
    sim.set("jobs", sim_jobs)
        .set("seconds", sim_s)
        .set("jobs_per_sec", sim_jobs as f64 / sim_s.max(1e-9));
    let mut root = Json::obj();
    root.set("benchmark", "engine")
        .set("grid", grid)
        .set("sim_throughput", sim);
    std::fs::write(&out_path, root.to_string_pretty()).map_err(|e| e.to_string())?;
    println!(
        "grid: {runs} runs — serial {serial_s:.2}s vs parallel {parallel_s:.2}s \
         ({:.1}x on {threads} threads, identical={identical})",
        serial_s / parallel_s.max(1e-9)
    );
    println!(
        "sim throughput: {sim_jobs} jobs in {sim_s:.2}s ({:.0} jobs/sec)",
        sim_jobs as f64 / sim_s.max(1e-9)
    );
    println!("wrote {}", out_path.display());
    if !identical {
        return Err("parallel grid diverged from serial reference".into());
    }
    Ok(())
}

/// Connect with retries: the thread-per-connection baseline's accept
/// backlog overflows under a simultaneous connect storm, so stress
/// clients tolerate transient refusals.
fn connect_retry(addr: &str) -> Result<Client, String> {
    let mut last = String::new();
    for _ in 0..250 {
        match Client::connect(addr) {
            Ok(c) => return Ok(c),
            Err(e) => {
                last = e.to_string();
                std::thread::sleep(Duration::from_millis(20));
            }
        }
    }
    Err(format!("connect {addr}: {last}"))
}

/// Loopback stress suite for the ask/tell service: N sessions × M
/// total worker connections over localhost TCP, run against BOTH serve
/// loops — the sharded event-driven core (`event`) and the original
/// thread-per-connection baseline (`threaded`) — recording ops/sec and
/// ask/tell latency percentiles for each plus the old-vs-new speedup
/// into `BENCH_service.json`. Also runs the acceptance oracles on an
/// event-served journaled registry: single-worker determinism against
/// the in-process tuner, and batched-vs-unbatched framing cost.
/// `--gate FILE` compares the event path against a committed baseline
/// and fails on a >2x regression in ops/sec or ask p99.
fn bench_service(flags: &HashMap<String, String>, out: Option<String>) -> Result<(), String> {
    use pasha::scheduler::asktell::{TellAck, TrialAssignment};
    use pasha::util::json::Json;
    use pasha::util::stats::percentile;
    use std::time::Instant;

    let out_path = PathBuf::from(out.unwrap_or_else(|| "BENCH_service.json".to_string()));
    let n_sessions: usize = flag(flags, "sessions", 64);
    let n_workers: usize = flag(flags, "workers", 512);
    let budget: usize = flag(flags, "budget", 8);
    let mode = flags
        .get("mode")
        .cloned()
        .unwrap_or_else(|| "both".to_string());
    let (run_event, run_legacy) = match mode.as_str() {
        "both" => (true, true),
        "event" => (true, false),
        "threaded" => (false, true),
        other => return Err(format!("unknown --mode '{other}' (event, threaded, both)")),
    };
    let bench_name = "lcbench-Fashion-MNIST";
    let bench = BenchSpec::new(bench_name).build()?;

    let spec_for = |seed: u64| {
        let mut s = ExperimentSpec::named(bench_name, "pasha").expect("bench name");
        s.stop.config_budget = budget;
        s.seed = seed;
        s
    };

    // One full stress pass against the chosen serve loop, on a fresh
    // in-memory registry so both paths measure the service core itself.
    // Workers are distributed round-robin over the sessions, one TCP
    // connection each, timing every synchronous round-trip.
    let stress = |legacy: bool| -> Result<(f64, Vec<f64>, Vec<f64>), String> {
        let registry = Arc::new(Registry::in_memory());
        let server = Server::bind("127.0.0.1:0", registry).map_err(|e| e.to_string())?;
        let addr = server.local_addr().map_err(|e| e.to_string())?.to_string();
        let server_thread = std::thread::spawn(move || {
            if legacy {
                server.run_threaded()
            } else {
                server.run()
            }
        });
        let mut control = connect_retry(&addr)?;
        let mut session_ids = Vec::new();
        for s in 0..n_sessions {
            session_ids.push(control.create(&spec_for(s as u64)).map_err(|e| e.to_string())?);
        }
        let t0 = Instant::now();
        let per_thread: Vec<Result<(Vec<f64>, Vec<f64>), String>> =
            std::thread::scope(|scope| {
                let mut handles = Vec::new();
                for w in 0..n_workers {
                    let sid = session_ids[w % n_sessions].as_str();
                    let bench = &bench;
                    let addr = addr.as_str();
                    handles.push(scope.spawn(move || {
                        let mut client = connect_retry(addr)?;
                        let wid = format!("w{w}");
                        let space = bench.space().clone();
                        let mut asks = Vec::new();
                        let mut tells = Vec::new();
                        loop {
                            let t = Instant::now();
                            let a = client.ask(sid, &wid, &space).map_err(|e| e.to_string())?;
                            asks.push(t.elapsed().as_secs_f64() * 1e6);
                            match a {
                                TrialAssignment::Run(job) => {
                                    for e in job.from_epoch + 1..=job.milestone {
                                        let m = bench.accuracy_at(&job.config, e, 0);
                                        let t = Instant::now();
                                        let ack = client
                                            .tell(sid, job.trial, e, m)
                                            .map_err(|e| e.to_string())?;
                                        tells.push(t.elapsed().as_secs_f64() * 1e6);
                                        if ack == TellAck::Abandon {
                                            break;
                                        }
                                    }
                                }
                                TrialAssignment::Stop(_) | TrialAssignment::Pause(_) => {}
                                TrialAssignment::Wait => {
                                    std::thread::sleep(Duration::from_millis(1))
                                }
                                TrialAssignment::Done => return Ok((asks, tells)),
                            }
                        }
                    }));
                }
                handles
                    .into_iter()
                    .map(|h| h.join().expect("worker thread"))
                    .collect()
            });
        let wall = t0.elapsed().as_secs_f64();
        control.shutdown().map_err(|e| e.to_string())?;
        let _ = server_thread.join();
        let mut ask_us = Vec::new();
        let mut tell_us = Vec::new();
        for r in per_thread {
            let (a, t) = r?;
            ask_us.extend(a);
            tell_us.extend(t);
        }
        Ok((wall, ask_us, tell_us))
    };

    let lat = |v: &[f64]| -> (f64, f64) {
        if v.is_empty() {
            (0.0, 0.0)
        } else {
            (percentile(v, 50.0), percentile(v, 99.0))
        }
    };
    let mode_json = |wall: f64, ask_us: &[f64], tell_us: &[f64]| -> Json {
        let ops = ask_us.len() + tell_us.len();
        let (ask_p50, ask_p99) = lat(ask_us);
        let (tell_p50, tell_p99) = lat(tell_us);
        let mut ask_j = Json::obj();
        ask_j.set("count", ask_us.len()).set("p50_us", ask_p50).set("p99_us", ask_p99);
        let mut tell_j = Json::obj();
        tell_j.set("count", tell_us.len()).set("p50_us", tell_p50).set("p99_us", tell_p99);
        let mut m = Json::obj();
        m.set("wall_seconds", wall)
            .set("ops", ops)
            .set("ops_per_sec", ops as f64 / wall.max(1e-9))
            .set("ask", ask_j)
            .set("tell", tell_j);
        m
    };
    let report_mode = |name: &str, wall: f64, ask_us: &[f64], tell_us: &[f64]| {
        let ops = ask_us.len() + tell_us.len();
        let (ask_p50, ask_p99) = lat(ask_us);
        let (tell_p50, tell_p99) = lat(tell_us);
        println!(
            "{name}: {n_sessions} sessions x {n_workers} workers, {ops} ops in {wall:.2}s \
             ({:.0} ops/s); ask p50/p99 {ask_p50:.0}/{ask_p99:.0}us, \
             tell p50/p99 {tell_p50:.0}/{tell_p99:.0}us",
            ops as f64 / wall.max(1e-9)
        );
    };

    let event = if run_event { Some(stress(false)?) } else { None };
    let legacy = if run_legacy { Some(stress(true)?) } else { None };

    // Acceptance oracles, on an event-served *journaled* registry so the
    // measured path includes group commit and the WAL end to end.
    let dir = std::env::temp_dir().join(format!("pasha-bench-service-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let registry = Registry::with_journal_dir(dir.clone()).map_err(|e| e.to_string())?;
    let server = Server::bind("127.0.0.1:0", Arc::new(registry)).map_err(|e| e.to_string())?;
    let addr = server.local_addr().map_err(|e| e.to_string())?.to_string();
    let server_thread = std::thread::spawn(move || server.run());
    let mut control = connect_retry(&addr)?;

    // Determinism: a fresh single-worker session over TCP must land on
    // the same incumbent as Tuner::run with the same seeds.
    let solo_spec = spec_for(0);
    let solo_id = control.create(&solo_spec).map_err(|e| e.to_string())?;
    run_worker(
        &mut control,
        &solo_id,
        "solo",
        bench.as_ref(),
        solo_spec.bench_seed,
        Duration::from_millis(1),
    )
    .map_err(|e| e.to_string())?;
    let solo_status = control.status(&solo_id).map_err(|e| e.to_string())?;
    let served_best = solo_status
        .get("best_metric")
        .and_then(|v| v.as_f64())
        .unwrap_or(f64::NAN);
    let mut inproc_spec = spec_for(0);
    inproc_spec.exec.workers = 1;
    let inproc = Tuner::run(&inproc_spec)?;
    let matches = served_best.to_bits() == inproc.best_metric.to_bits();

    // Batched vs unbatched framing on identical single-worker sessions:
    // a frame of N ops must cost at or below one unbatched round-trip
    // per op (the acceptance bar for the batch protocol).
    let poll = Duration::from_millis(1);
    let ub_id = control.create(&spec_for(7)).map_err(|e| e.to_string())?;
    let unbatched = run_worker(&mut control, &ub_id, "w0", bench.as_ref(), 0, poll)
        .map_err(|e| e.to_string())?;
    let b_id = control.create(&spec_for(7)).map_err(|e| e.to_string())?;
    let batched = run_worker_batched(&mut control, &b_id, "w0", bench.as_ref(), 0, poll)
        .map_err(|e| e.to_string())?;
    let (unbatched_us, batched_us, frames) = (unbatched.op_us, batched.op_us, batched.frames);
    control.shutdown().map_err(|e| e.to_string())?;
    let _ = server_thread.join();
    let _ = std::fs::remove_dir_all(&dir);

    let (ub_p50, ub_p99) = lat(&unbatched_us);
    let (b_p50, b_p99) = lat(&batched_us);
    let mut unbatched_j = Json::obj();
    unbatched_j
        .set("count", unbatched_us.len())
        .set("p50_us", ub_p50)
        .set("p99_us", ub_p99);
    let mut batched_j = Json::obj();
    batched_j
        .set("count", batched_us.len())
        .set("frames", frames)
        .set("p50_us", b_p50)
        .set("p99_us", b_p99);

    // Metrics record for the bench file: journaling and backpressure
    // counters from this process's obs registry, with the commit-group
    // size distribution merged (bucket-wise) across the journaled
    // sessions the oracle phase just drove.
    fn bucket_quantile(buckets: &[u64; pasha::obs::HISTO_BUCKETS], q: f64) -> f64 {
        let n: u64 = buckets.iter().sum();
        if n == 0 {
            return 0.0;
        }
        let target = ((q * n as f64).ceil() as u64).max(1);
        let mut cum = 0u64;
        for (i, &c) in buckets.iter().enumerate() {
            cum += c;
            if cum >= target {
                return pasha::obs::bucket_bound(i) as f64;
            }
        }
        pasha::obs::bucket_bound(pasha::obs::HISTO_BUCKETS - 1) as f64
    }
    let mut group_buckets = [0u64; pasha::obs::HISTO_BUCKETS];
    let mut commit_groups = 0u64;
    for sid in [&solo_id, &ub_id, &b_id] {
        let h = pasha::obs::histogram(
            "pasha_journal_commit_group_events",
            &[("session", sid.as_str())],
        );
        for (b, v) in group_buckets.iter_mut().zip(h.buckets()) {
            *b += v;
        }
        commit_groups += h.count();
    }
    let snap = pasha::obs::snapshot_json();
    let agg_of = |name: &str| -> f64 {
        snap.get("aggregate")
            .and_then(|a| a.get(name))
            .and_then(|v| v.as_f64())
            .unwrap_or(0.0)
    };
    let (group_p50, group_p99) = (
        bucket_quantile(&group_buckets, 0.5),
        bucket_quantile(&group_buckets, 0.99),
    );
    let mut metrics_j = Json::obj();
    metrics_j
        .set("journal_fsyncs", agg_of("pasha_journal_fsyncs_total"))
        .set("journal_events", agg_of("pasha_journal_events_total"))
        .set("commit_groups", commit_groups as f64)
        .set("commit_group_events_p50", group_p50)
        .set("commit_group_events_p99", group_p99)
        .set(
            "backpressure_pauses",
            agg_of("pasha_net_backpressure_pauses_total"),
        );
    println!(
        "metrics: {} fsyncs over {} journal events, commit-group p50/p99 \
         {group_p50:.0}/{group_p99:.0} events, {} backpressure pauses",
        agg_of("pasha_journal_fsyncs_total"),
        agg_of("pasha_journal_events_total"),
        agg_of("pasha_net_backpressure_pauses_total"),
    );

    let mut root = Json::obj();
    root.set("benchmark", "service")
        .set("sessions", n_sessions)
        .set("workers", n_workers)
        .set("config_budget", budget)
        .set("unbatched_per_op", unbatched_j)
        .set("batched_per_op", batched_j)
        .set("batched_speedup_p50", ub_p50 / b_p50.max(1e-9))
        .set("batched_at_or_below_unbatched", b_p50 <= ub_p50)
        .set("single_worker_matches_inprocess", matches)
        .set("metrics", metrics_j);
    if let Some((wall, ask_us, tell_us)) = &event {
        report_mode("event", *wall, ask_us, tell_us);
        root.set("event", mode_json(*wall, ask_us, tell_us));
    }
    if let Some((wall, ask_us, tell_us)) = &legacy {
        report_mode("threaded", *wall, ask_us, tell_us);
        root.set("threaded", mode_json(*wall, ask_us, tell_us));
    }
    if let (Some((ew, ea, et)), Some((lw, la, lt))) = (&event, &legacy) {
        let ev_rate = (ea.len() + et.len()) as f64 / ew.max(1e-9);
        let th_rate = (la.len() + lt.len()) as f64 / lw.max(1e-9);
        let speedup = ev_rate / th_rate.max(1e-9);
        root.set("speedup_ops_per_sec", speedup);
        println!("event vs threaded: {speedup:.1}x ops/sec");
    }
    println!(
        "wire framing: unbatched p50 {ub_p50:.0}us/op vs batched p50 {b_p50:.0}us/op \
         over {frames} frames ({:.1}x)",
        ub_p50 / b_p50.max(1e-9)
    );
    println!("single-worker incumbent matches in-process tuner: {matches}");
    std::fs::write(&out_path, root.to_string_pretty()).map_err(|e| e.to_string())?;
    println!("wrote {}", out_path.display());
    if !matches {
        return Err("served session diverged from in-process Tuner::run".into());
    }

    // Regression gate: the event path must hold within 2x of the
    // committed baseline (same reduced scale in CI).
    if let Some(gate_path) = flags.get("gate") {
        let (wall, ask_us, tell_us) = event
            .as_ref()
            .ok_or("--gate needs the event mode (use --mode event or both)")?;
        let ops_per_sec = (ask_us.len() + tell_us.len()) as f64 / wall.max(1e-9);
        let (_, ask_p99) = lat(ask_us);
        let text = std::fs::read_to_string(gate_path)
            .map_err(|e| format!("--gate {gate_path}: {e}"))?;
        let base = pasha::util::json::parse(&text).map_err(|e| format!("--gate {gate_path}: {e}"))?;
        let base_event = base
            .get("event")
            .ok_or_else(|| format!("--gate {gate_path}: missing 'event' section"))?;
        let base_ops = base_event
            .get("ops_per_sec")
            .and_then(|v| v.as_f64())
            .ok_or_else(|| format!("--gate {gate_path}: missing event.ops_per_sec"))?;
        let base_p99 = base_event
            .get("ask")
            .and_then(|a| a.get("p99_us"))
            .and_then(|v| v.as_f64())
            .ok_or_else(|| format!("--gate {gate_path}: missing event.ask.p99_us"))?;
        println!(
            "gate: ops/sec {ops_per_sec:.0} vs baseline {base_ops:.0} (floor {:.0}), \
             ask p99 {ask_p99:.0}us vs baseline {base_p99:.0}us (ceiling {:.0}us)",
            base_ops / 2.0,
            base_p99 * 2.0
        );
        if ops_per_sec < base_ops / 2.0 {
            return Err(format!(
                "service stress regression: {ops_per_sec:.0} ops/sec is below half the \
                 committed baseline ({base_ops:.0})"
            ));
        }
        if ask_p99 > base_p99 * 2.0 {
            return Err(format!(
                "service stress regression: ask p99 {ask_p99:.0}us is above twice the \
                 committed baseline ({base_p99:.0}us)"
            ));
        }
    }
    Ok(())
}

fn cmd_serve(flags: &HashMap<String, String>) -> Result<(), String> {
    let addr = flags
        .get("addr")
        .cloned()
        .unwrap_or_else(|| "127.0.0.1:7171".to_string());
    let mut options = match flags.get("snapshot-interval") {
        Some(v) => {
            let n: usize = v
                .parse()
                .map_err(|_| format!("invalid --snapshot-interval '{v}' (expected events)"))?;
            if n == 0 {
                return Err("--snapshot-interval must be >= 1".into());
            }
            SessionOptions::snapshot_every(n)
        }
        None => SessionOptions::default(),
    };
    // --store: completed sessions record their trials here, and new
    // sessions' warm-start references are sealed from it
    options.store = flags.get("store").map(StoreSpec::new);
    let shards: usize = flag(flags, "shards", pasha::service::registry::default_shards());
    if shards == 0 {
        return Err("--shards must be >= 1".into());
    }
    let io_threads: usize = flag(
        flags,
        "io-threads",
        pasha::service::server::DEFAULT_IO_THREADS,
    );
    let registry = match flags.get("journal-dir") {
        Some(d) => Registry::with_journal_dir_sharded(PathBuf::from(d), options, shards)
            .map_err(|e| e.to_string())?,
        None => Registry::in_memory_sharded(options, shards),
    };
    for (id, rep) in registry.recovered() {
        println!(
            "recovered session {id}: snapshot at event {} + {} replayed \
             ({} skipped, {} torn bytes dropped)",
            rep.snapshot_events, rep.events_replayed, rep.events_skipped, rep.truncated_bytes
        );
    }
    let legacy = flags.contains_key("legacy-threaded");
    let mut server = Server::bind(&addr, Arc::new(registry))
        .map_err(|e| e.to_string())?
        .io_threads(io_threads);
    if let Some(maddr) = flags.get("metrics-addr") {
        if legacy {
            return Err("--metrics-addr needs the event-driven serve loop \
                        (drop --legacy-threaded)"
                .into());
        }
        server = server
            .metrics_addr(maddr)
            .map_err(|e| format!("--metrics-addr {maddr}: {e}"))?;
    }
    if let Some(raddr) = flags.get("replicate") {
        if legacy {
            return Err("--replicate needs the event-driven serve loop \
                        (drop --legacy-threaded)"
                .into());
        }
        server = server
            .replicate_addr(raddr)
            .map_err(|e| format!("--replicate {raddr}: {e}"))?;
    }
    if let Some(lease) = flags.get("worker-lease") {
        let secs: f64 = lease
            .parse()
            .map_err(|_| format!("invalid --worker-lease '{lease}' (expected seconds)"))?;
        if !secs.is_finite() || secs < 0.0 {
            return Err(format!("invalid --worker-lease '{lease}' (expected seconds)"));
        }
        if legacy && secs > 0.0 {
            return Err("--worker-lease needs the event-driven serve loop \
                        (drop --legacy-threaded)"
                .into());
        }
        if secs > 0.0 {
            server = server.worker_lease(Duration::from_secs_f64(secs));
        }
    }
    println!(
        "pasha serve: listening on {} ({})",
        server.local_addr().map_err(|e| e.to_string())?,
        if legacy {
            "thread-per-connection".to_string()
        } else {
            format!("{io_threads} io threads, {shards} session shards")
        }
    );
    if let Some(maddr) = server.metrics_local_addr() {
        println!("pasha serve: Prometheus metrics on http://{maddr}/metrics");
    }
    if let Some(raddr) = server.replicate_local_addr() {
        println!("pasha serve: replication listener on {raddr} (attach `pasha follow`)");
    }
    if legacy {
        server.run_threaded().map_err(|e| e.to_string())
    } else {
        server.run().map_err(|e| e.to_string())
    }
}

/// `pasha follow ADDR --journal-dir DIR` — subscribe to a leader's
/// replication listener and maintain a byte-identical copy of every
/// session journal (and snapshot sidecar) under DIR. Each durable commit
/// group is fsynced locally before it is acked. Runs until the leader
/// closes the connection — clean shutdown or crash, the copy is durable
/// either way — then prints a JSON report (groups, rebases, bytes) for
/// scripts to capture. Promote the copy with
/// `pasha serve --journal-dir DIR`.
fn cmd_follow(addr: Option<&str>, flags: &HashMap<String, String>) -> Result<(), String> {
    let addr = addr
        .filter(|a| !a.starts_with("--"))
        .map(str::to_string)
        .or_else(|| flags.get("addr").cloned())
        .ok_or("need the leader's replication address: pasha follow HOST:PORT --journal-dir DIR")?;
    let dir = flags.get("journal-dir").ok_or("need --journal-dir DIR")?;
    eprintln!("pasha follow: tailing {addr} into {dir}");
    let report = pasha::service::replica::follow(&addr, std::path::Path::new(dir))
        .map_err(|e| format!("follow {addr}: {e}"))?;
    println!("{}", report.to_json().to_string_pretty());
    Ok(())
}

/// `pasha route [--addr A] --table route.json` — serve the session
/// router: each worker request line forwards to the backend its session
/// id hashes to (the registry's FNV-1a placement rule, so the mapping is
/// stable across router restarts). On backend failure the table is
/// re-read and the upstream re-dialed, so rewriting the table to point
/// at a promoted follower heals in-flight connections. A sessionless
/// `shutdown` broadcasts to every backend and stops the router.
fn cmd_route(flags: &HashMap<String, String>) -> Result<(), String> {
    let addr = flags
        .get("addr")
        .cloned()
        .unwrap_or_else(|| "127.0.0.1:7170".to_string());
    let table = flags
        .get("table")
        .ok_or("need --table FILE (a versioned RouteSpec backend list)")?;
    let listener =
        std::net::TcpListener::bind(&addr).map_err(|e| format!("bind {addr}: {e}"))?;
    let spec = pasha::spec::RouteSpec::load(std::path::Path::new(table))
        .map_err(|e| format!("--table {table}: {e}"))?;
    println!(
        "pasha route: listening on {} over {} backend(s) in {table}",
        listener.local_addr().map_err(|e| e.to_string())?,
        spec.backends.len()
    );
    pasha::service::replica::route(listener, std::path::Path::new(table))
        .map_err(|e| e.to_string())
}

fn cmd_worker(flags: &HashMap<String, String>, sets: &[String]) -> Result<(), String> {
    let addr = flags
        .get("addr")
        .cloned()
        .unwrap_or_else(|| "127.0.0.1:7171".to_string());
    let worker_id = flags.get("worker-id").cloned().unwrap_or_else(|| "w0".to_string());
    let mut client = Client::connect(&addr).map_err(|e| e.to_string())?;
    let session = match flags.get("session") {
        Some(id) => {
            // attaching to an existing session: spec-lowering flags
            // would be silently dead and typos must not pass, so only
            // the worker control flags are accepted here
            let control = ["addr", "worker-id", "session", "expire", "batch", "shutdown"];
            for name in flags.keys() {
                if control.contains(&name.as_str()) {
                    continue;
                }
                if SPEC_FLAGS.contains(&name.as_str()) {
                    return Err(format!(
                        "--{name} describes a new session's spec; it has no effect with \
                         --session (use --create to apply it)"
                    ));
                }
                return Err(format!(
                    "unknown flag --{name} (with --session: --{})",
                    control.join(", --")
                ));
            }
            if !sets.is_empty() {
                return Err(
                    "--set describes a new session's spec; it has no effect with \
                     --session (use --create to apply it)"
                        .into(),
                );
            }
            id.clone()
        }
        None if flags.contains_key("create") => {
            reject_unknown_flags(
                flags,
                &["addr", "worker-id", "create", "expire", "batch", "shutdown"],
            )?;
            // worker-created smoke sessions default smaller than `run`
            let mut base = ExperimentSpec::named("lcbench-Fashion-MNIST", "pasha")?;
            base.stop.config_budget = 32;
            let mut spec = resolve_spec(base, flags, sets)?;
            // seal --warm-start here, where the store file lives: the
            // server only sees the embedded observations
            let embedded = store::resolve_warm_start(&mut spec)?;
            if embedded > 0 {
                println!("warm start: {embedded} prior trials embedded");
            }
            let id = client.create(&spec).map_err(|e| e.to_string())?;
            println!("created session {id}");
            id
        }
        None => return Err("need --session ID or --create".into()),
    };
    // Rejoining a session whose previous workers died with the server?
    // --expire re-queues their orphaned in-flight jobs first.
    if flags.contains_key("expire") {
        let expired = client.expire(&session).map_err(|e| e.to_string())?;
        println!("expired {expired} orphaned in-flight jobs");
    }
    // The session's spec names the benchmark this worker must evaluate.
    let status = client.status(&session).map_err(|e| e.to_string())?;
    let spec_json = status.get("spec").ok_or("status response missing spec")?;
    let spec = ExperimentSpec::from_json(spec_json)?;
    let bench = spec.bench.build()?;
    let t0 = std::time::Instant::now();
    // --batch ships each job's tells + the next ask as one wire frame
    let poll = Duration::from_millis(20);
    let seed = spec.bench_seed;
    let report = if flags.contains_key("batch") {
        run_worker_batched(&mut client, &session, &worker_id, bench.as_ref(), seed, poll)
    } else {
        run_worker(&mut client, &session, &worker_id, bench.as_ref(), seed, poll)
    }
    .map_err(|e| e.to_string())?;
    let status = client.status(&session).map_err(|e| e.to_string())?;
    let frames = if report.frames > 0 {
        format!(", {} wire frames", report.frames)
    } else {
        String::new()
    };
    println!(
        "session {session} drained: {} jobs, {} epochs told, {} abandoned{frames} ({:.2}s wall)",
        report.jobs_completed,
        report.epochs_told,
        report.jobs_abandoned,
        t0.elapsed().as_secs_f64()
    );
    if let Some(m) = status.get("best_metric").and_then(|v| v.as_f64()) {
        println!("best val metric  : {m:.2}");
        if let Some(cfg_json) = status.get("best_config") {
            let config = config_from_json(bench.space(), cfg_json)?;
            let retrain = bench.retrain_accuracy(&config, spec.bench_seed);
            println!("retrain accuracy : {retrain:.2}%  (config {config})");
        }
    }
    if flags.contains_key("shutdown") {
        client.shutdown().map_err(|e| e.to_string())?;
        println!("server shut down");
    }
    Ok(())
}

/// `pasha store <ls|gc|export>` — inspect and maintain a trial store.
fn cmd_store(sub: Option<&str>, flags: &HashMap<String, String>) -> Result<(), String> {
    let sub = sub.ok_or("need a subcommand: store <ls|gc|export>")?;
    let path = flags.get("store").ok_or("need --store FILE")?;
    let store = TrialStore::open(path);
    match sub {
        "ls" => {
            let records = store.read_all().map_err(|e| e.to_string())?;
            // one line per fingerprint: where the records came from and
            // how much signal a warm start could draw from them
            let mut groups: std::collections::BTreeMap<&str, (usize, &str, u32, f64)> =
                std::collections::BTreeMap::new();
            for r in &records {
                let g = groups
                    .entry(r.fingerprint.as_str())
                    .or_insert((0, r.bench.as_str(), 0, f64::NEG_INFINITY));
                g.0 += 1;
                g.2 = g.2.max(r.epoch);
                g.3 = g.3.max(r.metric);
            }
            println!("{} records, {} fingerprints in {path}", records.len(), groups.len());
            for (fp, (n, bench, max_epoch, best)) in groups {
                println!("  {fp}  {n:>5} trials  {bench}  max_epoch={max_epoch}  best={best:.2}");
            }
            Ok(())
        }
        "gc" => {
            let report = store.gc().map_err(|e| e.to_string())?;
            println!(
                "gc {path}: kept {} records, dropped {} duplicates",
                report.kept, report.dropped
            );
            Ok(())
        }
        "export" => {
            let records = store.read_all().map_err(|e| e.to_string())?;
            let filtered: Vec<_> = match flags.get("fingerprint") {
                Some(fp) => records.into_iter().filter(|r| &r.fingerprint == fp).collect(),
                None => records,
            };
            let mut text = String::new();
            for r in &filtered {
                text.push_str(&r.to_json().to_string_compact());
                text.push('\n');
            }
            match flags.get("out") {
                Some(out) => {
                    std::fs::write(out, &text).map_err(|e| e.to_string())?;
                    println!("wrote {} records to {out}", filtered.len());
                }
                None => print!("{text}"),
            }
            Ok(())
        }
        other => Err(format!("unknown store subcommand '{other}' (ls, gc, export)")),
    }
}

fn cmd_sessions(flags: &HashMap<String, String>) -> Result<(), String> {
    let addr = flags
        .get("addr")
        .cloned()
        .unwrap_or_else(|| "127.0.0.1:7171".to_string());
    let mut client = Client::connect(&addr).map_err(|e| e.to_string())?;
    let statuses = client.sessions().map_err(|e| e.to_string())?;
    println!("{}", pasha::report::service::sessions_table(&statuses).to_text());
    Ok(())
}

/// `pasha stats --addr HOST:PORT [--check] [--journal-dir DIR]` — fetch
/// and print a live server's metrics snapshot over the read-only `stats`
/// wire op. `--check` additionally enforces the conservation invariants
/// the instrumentation guarantees and exits non-zero on any violation:
/// per session, every journaled ask is backed by a journal event
/// (`asks_journaled <= journal_events`), the scheduler saw at least as
/// many asks as were journaled, and fsyncs never exceed appends (+1 for
/// the conservative sync a freshly opened journal issues); globally,
/// no in-flight gauge has gone negative.
///
/// `--journal-dir DIR` reconciles the server's counters against a
/// journal directory — typically a follower's replicated copy: per
/// session journal, the literal ask events on disk must not exceed
/// `pasha_sched_asks_journaled_total` (compaction can fold disk events
/// into the snapshot, so the copy may trail the monotonic counter, never
/// lead it). The counter resets with the server process, so reconcile
/// against a leader that created its sessions this lifetime.
fn cmd_stats(flags: &HashMap<String, String>) -> Result<(), String> {
    let addr = flags
        .get("addr")
        .cloned()
        .unwrap_or_else(|| "127.0.0.1:7171".to_string());
    let mut client = Client::connect(&addr).map_err(|e| e.to_string())?;
    let snap = client.stats().map_err(|e| e.to_string())?;
    println!("{}", snap.to_string_pretty());
    let check = flags.contains_key("check");
    let journal_dir = flags.get("journal-dir").map(PathBuf::from);
    if !check && journal_dir.is_none() {
        return Ok(());
    }
    let instruments = snap
        .get("instruments")
        .and_then(|v| v.as_arr())
        .ok_or("stats snapshot missing 'instruments'")?;
    // name -> session label -> value (counters and gauges)
    let mut by_session: HashMap<(String, String), f64> = HashMap::new();
    let mut sessions = std::collections::BTreeSet::new();
    let mut violations = Vec::new();
    for inst in instruments {
        let name = inst.get("name").and_then(|v| v.as_str()).unwrap_or("");
        let value = inst.get("value").and_then(|v| v.as_f64()).unwrap_or(0.0);
        if name == "pasha_net_inflight_ops" || name == "pasha_shard_queue_depth" {
            if value < 0.0 {
                violations.push(format!("{name} is negative ({value})"));
            }
            continue;
        }
        let session = inst
            .get("labels")
            .and_then(|l| l.get("session"))
            .and_then(|v| v.as_str());
        if let Some(sid) = session {
            sessions.insert(sid.to_string());
            by_session.insert((name.to_string(), sid.to_string()), value);
        }
    }
    let get = |name: &str, sid: &str| -> Option<f64> {
        by_session.get(&(name.to_string(), sid.to_string())).copied()
    };
    if check {
        for sid in &sessions {
            let asks = get("pasha_sched_asks_total", sid);
            let journaled = get("pasha_sched_asks_journaled_total", sid);
            if let (Some(a), Some(j)) = (asks, journaled) {
                if j > a {
                    violations.push(format!(
                        "session {sid}: {j} journaled asks exceed {a} scheduler asks"
                    ));
                }
            }
            let events = get("pasha_journal_events_total", sid);
            if let (Some(j), Some(ev)) = (journaled, events) {
                if j > ev {
                    violations.push(format!(
                        "session {sid}: {j} journaled asks exceed {ev} journal events"
                    ));
                }
            }
            if let (Some(f), Some(ev)) = (get("pasha_journal_fsyncs_total", sid), events) {
                if f > ev + 1.0 {
                    violations.push(format!(
                        "session {sid}: {f} fsyncs exceed {ev} journal events (+1)"
                    ));
                }
            }
        }
    }
    if let Some(dir) = &journal_dir {
        let dir_asks = count_journal_asks(dir)?;
        if dir_asks.is_empty() {
            println!("journal-dir {}: no *.jsonl session journals", dir.display());
        }
        for (sid, n) in &dir_asks {
            match get("pasha_sched_asks_journaled_total", sid) {
                Some(j) => {
                    println!(
                        "journal-dir {sid}: {n} ask events on disk vs {j} journaled by the \
                         server (lag {} asks)",
                        (j - *n as f64).max(0.0)
                    );
                    if (*n as f64) > j {
                        violations.push(format!(
                            "session {sid}: journal copy holds {n} ask events but the \
                             server journaled only {j} this lifetime"
                        ));
                    }
                }
                None => violations.push(format!(
                    "session {sid}: journal copy present in {} but the server reports \
                     no journaled-ask counter for it",
                    dir.display()
                )),
            }
        }
    }
    if violations.is_empty() {
        if check {
            println!(
                "check: conservation invariants hold across {} session(s)",
                sessions.len()
            );
        }
        if journal_dir.is_some() {
            println!("check: journal copy is consistent with the server's counters");
        }
        Ok(())
    } else {
        Err(format!(
            "metrics conservation violated:\n  {}",
            violations.join("\n  ")
        ))
    }
}

/// Count literal `{"ev":"ask",...}` events per session journal
/// (`<session>.jsonl`) in `dir`. Torn or non-JSON trailing lines are
/// skipped, matching the journal reader's whole-event-prefix tolerance;
/// snapshot sidecars (`*.jsonl.snap`) are not journals and are ignored.
fn count_journal_asks(
    dir: &std::path::Path,
) -> Result<std::collections::BTreeMap<String, u64>, String> {
    let mut out = std::collections::BTreeMap::new();
    let entries =
        std::fs::read_dir(dir).map_err(|e| format!("--journal-dir {}: {e}", dir.display()))?;
    for entry in entries {
        let path = entry.map_err(|e| e.to_string())?.path();
        if !path.extension().map(|x| x == "jsonl").unwrap_or(false) {
            continue;
        }
        let sid = match path.file_stem().and_then(|s| s.to_str()) {
            Some(s) => s.to_string(),
            None => continue,
        };
        let text =
            std::fs::read_to_string(&path).map_err(|e| format!("{}: {e}", path.display()))?;
        let mut asks = 0u64;
        for line in text.lines() {
            if let Ok(v) = pasha::util::json::parse(line) {
                if v.get("ev").and_then(|e| e.as_str()) == Some("ask") {
                    asks += 1;
                }
            }
        }
        out.insert(sid, asks);
    }
    Ok(out)
}

/// Verify a session journal replays cleanly (CI's non-recoverable-journal
/// gate): exits non-zero if recovery fails. Read-only — never truncates
/// or re-opens the file, so it is safe to run against a live server's
/// journal directory.
fn cmd_recover(flags: &HashMap<String, String>) -> Result<(), String> {
    let path = flags.get("journal").ok_or("need --journal FILE")?;
    let (session, report) = Session::recover_readonly(std::path::Path::new(path))
        .map_err(|e| format!("{path}: {e}"))?;
    if report.snapshot_events > 0 {
        println!(
            "journal {path}: session '{}' restored snapshot at event {} and \
             replayed {} tail events ({} skipped, {} torn bytes dropped)",
            session.id,
            report.snapshot_events,
            report.events_replayed,
            report.events_skipped,
            report.truncated_bytes
        );
    } else {
        println!(
            "journal {path}: session '{}' replayed {} events ({} torn bytes dropped)",
            session.id, report.events_replayed, report.truncated_bytes
        );
    }
    println!(
        "{}",
        pasha::report::service::sessions_table(&[session.status()]).to_text()
    );
    Ok(())
}

/// Snapshot + truncate a session journal in place: recovery afterwards
/// restores the snapshot and replays nothing. Only run this on a journal
/// no server currently owns (the tail rewrite would race a live
/// appender).
fn cmd_compact(flags: &HashMap<String, String>) -> Result<(), String> {
    let path = flags.get("journal").ok_or("need --journal FILE")?;
    let path = std::path::Path::new(path);
    let size = |p: &std::path::Path| std::fs::metadata(p).map(|m| m.len()).unwrap_or(0);
    let snap_path = pasha::service::journal::snapshot_path(path);
    let before = size(path) + size(&snap_path);
    let (mut session, report) =
        Session::recover(path).map_err(|e| format!("{}: {e}", path.display()))?;
    session.compact_now().map_err(|e| e.to_string())?;
    let events = session.events_total();
    drop(session);
    let after = size(path) + size(&snap_path);
    println!(
        "compacted {}: {} events -> snapshot (replayed {} on the way in); \
         {} bytes -> {} bytes (journal + sidecar)",
        path.display(),
        events,
        report.events_replayed,
        before,
        after
    );
    // prove the result is immediately recoverable, tail-free
    let (_, check) = Session::recover_readonly(path).map_err(|e| e.to_string())?;
    println!(
        "verified: recovery now restores the snapshot at event {} and replays {} events",
        check.snapshot_events, check.events_replayed
    );
    Ok(())
}

#[cfg(feature = "pjrt")]
fn cmd_e2e(flags: &HashMap<String, String>) -> Result<(), String> {
    let budget: usize = flag(flags, "budget", 24);
    let hidden: usize = flag(flags, "hidden", 64);
    let workers: usize = flag(flags, "workers", 4);
    pasha::e2e::run_e2e(budget, hidden, workers).map_err(|e| e.to_string())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_e2e(_flags: &HashMap<String, String>) -> Result<(), String> {
    Err("built without the `pjrt` feature — rebuild with `--features pjrt`".into())
}

#[cfg(feature = "pjrt")]
fn cmd_artifacts_check() -> Result<(), String> {
    use pasha::runtime::artifact::{artifacts_available, artifacts_dir, Engine};
    println!("artifacts dir: {}", artifacts_dir().display());
    if !artifacts_available() {
        return Err("artifacts not built — run `make artifacts`".into());
    }
    let engine = Engine::cpu().map_err(|e| e.to_string())?;
    println!("PJRT platform: {}", engine.platform_name());
    for name in [
        "mlp_train_h64",
        "mlp_eval_h64",
        "gp_ei_n64_d4_m64",
        "knn_n512_d4_q4",
    ] {
        engine
            .load_named(name)
            .map_err(|e| format!("{name}: {e}"))?;
        println!("compiled {name}: OK");
    }
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_artifacts_check() -> Result<(), String> {
    Err("built without the `pjrt` feature — rebuild with `--features pjrt`".into())
}
