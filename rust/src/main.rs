//! `pasha` — launcher CLI for the PASHA reproduction.
//!
//! Subcommands (hand-rolled parser; the offline image has no `clap`):
//!
//! ```text
//! pasha run    --bench <name> --scheduler <name> [--budget N] [--seed S]
//!              [--epoch-budget E] [--time-budget SECONDS]
//! pasha table  <id>  [--scale paper|smoke] [--out results/]
//! pasha figure <1..5> [--out results/]
//! pasha report [--scale paper|smoke] [--out results/]   # everything
//! pasha bench-json [--out FILE]                          # engine perf record
//! pasha e2e    [--budget N] [--hidden H]                # real PJRT training
//! pasha artifacts-check                                  # PJRT smoke test
//! ```

use pasha::benchmarks::lcbench::LcBench;
use pasha::benchmarks::nasbench201::{NasBench201, Nb201Dataset};
use pasha::benchmarks::pd1::Pd1;
use pasha::benchmarks::Benchmark;
use pasha::report::{experiments, figures};
use pasha::scheduler::asha::AshaBuilder;
use pasha::scheduler::baselines::{FixedEpochBuilder, RandomBaselineBuilder};
use pasha::scheduler::hyperband::HyperbandBuilder;
use pasha::scheduler::pasha::PashaBuilder;
use pasha::scheduler::sh::SyncShBuilder;
use pasha::scheduler::stopping::{StopAshaBuilder, StopPashaBuilder};
use pasha::scheduler::SchedulerBuilder;
use pasha::tuner::{SearcherKind, StopSpec, Tuner, TunerSpec};
use std::collections::HashMap;
use std::path::PathBuf;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.is_empty() {
        usage();
        std::process::exit(2);
    }
    let (cmd, rest) = (args[0].as_str(), &args[1..]);
    let flags = parse_flags(rest);
    let result = match cmd {
        "run" => cmd_run(&flags),
        "table" => cmd_table(rest.first().map(|s| s.as_str()), &flags),
        "figure" => cmd_figure(rest.first().map(|s| s.as_str()), &flags),
        "report" => cmd_report(&flags),
        "bench-json" => cmd_bench_json(&flags),
        "e2e" => cmd_e2e(&flags),
        "artifacts-check" => cmd_artifacts_check(),
        "help" | "--help" | "-h" => {
            usage();
            Ok(())
        }
        other => {
            eprintln!("unknown command '{other}'");
            usage();
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn usage() {
    eprintln!(
        "pasha — Progressive ASHA reproduction (Bohdal et al., ICLR 2023)

USAGE:
  pasha run    --bench <nas-cifar10|nas-cifar100|nas-imagenet16|pd1-wmt|pd1-imagenet|lcbench-<name>>
               --scheduler <asha|pasha|asha-stop|pasha-stop|sh|hyperband|1-epoch|random>
               [--budget N] [--seed S] [--eta E] [--searcher random|bo] [--workers W]
               [--epoch-budget E] [--time-budget SECONDS]
  pasha table  <1|2|3|4|5|6|8|9|10|11|12|13|14|15|ablation|stopping> [--scale paper|smoke] [--out DIR]
  pasha figure <1|2|3|4|5> [--out DIR]
  pasha report [--scale paper|smoke] [--out DIR]
  pasha bench-json [--out FILE]            # serial-vs-parallel grid + sim throughput
  pasha e2e    [--budget N] [--hidden 64|128|256] [--workers W]
  pasha artifacts-check"
    );
}

fn parse_flags(args: &[String]) -> HashMap<String, String> {
    let mut flags = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(name) = args[i].strip_prefix("--") {
            if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                flags.insert(name.to_string(), args[i + 1].clone());
                i += 2;
            } else {
                flags.insert(name.to_string(), "true".to_string());
                i += 1;
            }
        } else {
            i += 1;
        }
    }
    flags
}

fn flag<T: std::str::FromStr>(flags: &HashMap<String, String>, name: &str, default: T) -> T {
    flags
        .get(name)
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn out_dir(flags: &HashMap<String, String>) -> PathBuf {
    PathBuf::from(
        flags
            .get("out")
            .cloned()
            .unwrap_or_else(|| "results".to_string()),
    )
}

fn scale(flags: &HashMap<String, String>) -> experiments::Scale {
    match flags.get("scale").map(|s| s.as_str()) {
        Some("smoke") => experiments::Scale::smoke(),
        _ => experiments::Scale::paper(),
    }
}

fn make_bench(name: &str) -> Result<Box<dyn Benchmark>, String> {
    Ok(match name {
        "nas-cifar10" => Box::new(NasBench201::cifar10()),
        "nas-cifar100" => Box::new(NasBench201::cifar100()),
        "nas-imagenet16" => Box::new(NasBench201::imagenet16()),
        "pd1-wmt" => Box::new(Pd1::wmt()),
        "pd1-imagenet" => Box::new(Pd1::imagenet()),
        other => {
            if let Some(ds) = other.strip_prefix("lcbench-") {
                Box::new(LcBench::new(ds))
            } else {
                return Err(format!("unknown benchmark '{other}'"));
            }
        }
    })
}

fn make_scheduler(
    name: &str,
    eta: u32,
    budget: usize,
) -> Result<Box<dyn SchedulerBuilder>, String> {
    Ok(match name {
        "asha" => Box::new(AshaBuilder { r_min: 1, eta }),
        "pasha" => Box::new(PashaBuilder {
            r_min: 1,
            eta,
            ranking: Default::default(),
        }),
        "asha-stop" => Box::new(StopAshaBuilder { r_min: 1, eta }),
        "pasha-stop" => Box::new(StopPashaBuilder {
            r_min: 1,
            eta,
            ranking: Default::default(),
        }),
        "sh" => Box::new(SyncShBuilder {
            r_min: 1,
            eta,
            n0: budget,
        }),
        "hyperband" => Box::new(HyperbandBuilder { r_min: 1, eta }),
        "1-epoch" => Box::new(FixedEpochBuilder { epochs: 1 }),
        "random" => Box::new(RandomBaselineBuilder),
        other => return Err(format!("unknown scheduler '{other}'")),
    })
}

fn cmd_run(flags: &HashMap<String, String>) -> Result<(), String> {
    let bench_name = flags
        .get("bench")
        .cloned()
        .unwrap_or_else(|| "nas-cifar10".into());
    let sched_name = flags
        .get("scheduler")
        .cloned()
        .unwrap_or_else(|| "pasha".into());
    let budget: usize = flag(flags, "budget", 256);
    let seed: u64 = flag(flags, "seed", 0);
    let eta: u32 = flag(flags, "eta", 3);
    let workers: usize = flag(flags, "workers", 4);
    let searcher = match flags.get("searcher").map(|s| s.as_str()) {
        Some("bo") => SearcherKind::Bo,
        _ => SearcherKind::Random,
    };
    let bench = make_bench(&bench_name)?;
    let builder = make_scheduler(&sched_name, eta, budget)?;
    let mut extra_stop = Vec::new();
    if let Some(v) = flags.get("epoch-budget") {
        let e: u64 = v
            .parse()
            .map_err(|_| format!("invalid --epoch-budget '{v}' (expected an integer)"))?;
        extra_stop.push(StopSpec::EpochBudget(e));
    }
    if let Some(v) = flags.get("time-budget") {
        let s: f64 = v
            .parse()
            .map_err(|_| format!("invalid --time-budget '{v}' (expected seconds)"))?;
        extra_stop.push(StopSpec::ClockBudget(s));
    }
    let spec = TunerSpec {
        workers,
        config_budget: budget,
        searcher,
        extra_stop,
    };
    let t0 = std::time::Instant::now();
    let r = Tuner::run(bench.as_ref(), builder.as_ref(), &spec, seed, 0);
    println!("benchmark        : {}", bench.name());
    println!("scheduler        : {}", r.scheduler_name);
    println!("configs sampled  : {}", r.configs_sampled);
    println!("jobs executed    : {}", r.jobs);
    println!("epochs trained   : {}", r.total_epochs);
    if r.stopped_trials > 0 || r.cancelled_jobs > 0 {
        println!(
            "stopped trials   : {} ({} jobs cancelled in flight)",
            r.stopped_trials, r.cancelled_jobs
        );
    }
    println!("max resources    : {} epochs", r.max_resources);
    println!(
        "tuning runtime   : {:.2}h (simulated)",
        r.runtime_seconds / 3600.0
    );
    println!("best val metric  : {:.2}", r.best_metric);
    println!("retrain accuracy : {:.2}%", r.retrain_accuracy);
    if let Some(c) = &r.best_config {
        println!("best config      : {c}");
    }
    println!("(wall time: {:.2}s)", t0.elapsed().as_secs_f64());
    Ok(())
}

fn write_tables(
    tables: &[pasha::util::table::Table],
    dir: &PathBuf,
    stem: &str,
) -> Result<(), String> {
    std::fs::create_dir_all(dir).map_err(|e| e.to_string())?;
    let mut md = String::new();
    for t in tables {
        println!("{}", t.to_text());
        md.push_str(&t.to_markdown());
        md.push('\n');
    }
    let path = dir.join(format!("{stem}.md"));
    std::fs::write(&path, md).map_err(|e| e.to_string())?;
    println!("wrote {}", path.display());
    Ok(())
}

fn cmd_table(id: Option<&str>, flags: &HashMap<String, String>) -> Result<(), String> {
    let id = id.ok_or("table id required")?;
    let sc = scale(flags);
    let dir = out_dir(flags);
    let tables = match id {
        "1" => experiments::table1(&sc),
        "2" => experiments::table2(&sc),
        "3" => experiments::table3(&sc),
        "4" => vec![experiments::table_rankings(Nb201Dataset::Cifar100, &sc, 4)],
        "5" | "7" => experiments::table5(&sc),
        "6" => experiments::table6(&sc),
        "8" => experiments::table8(&sc),
        "9" => vec![experiments::table_rankings(Nb201Dataset::Cifar10, &sc, 9)],
        "10" => vec![experiments::table_rankings(Nb201Dataset::Cifar100, &sc, 10)],
        "11" => vec![experiments::table_rankings(
            Nb201Dataset::ImageNet16_120,
            &sc,
            11,
        )],
        "12" => experiments::table12(&sc),
        "13" => vec![experiments::table13(&sc, 34)],
        "14" => experiments::table14(&sc),
        "15" => experiments::table15(&sc),
        "ablation" => vec![experiments::ablation_schedulers(&sc)],
        "stopping" => vec![experiments::ablation_stopping(&sc)],
        other => return Err(format!("unknown table '{other}'")),
    };
    write_tables(&tables, &dir, &format!("table{id}"))
}

fn cmd_figure(id: Option<&str>, flags: &HashMap<String, String>) -> Result<(), String> {
    let id = id.ok_or("figure id required")?;
    let dir = out_dir(flags);
    std::fs::create_dir_all(&dir).map_err(|e| e.to_string())?;
    let budget: usize = flag(flags, "budget", 256);
    let (name, content) = match id {
        "1" => ("figure1.txt".to_string(), figures::figure1(budget)),
        "2" => (
            "figure2.txt".to_string(),
            figures::figure2(&[93.9, 93.8, 93.2, 93.1, 91.0], 0.15),
        ),
        "3" => (
            "figure3_cifar10.csv".to_string(),
            figures::figure3(Nb201Dataset::Cifar10, 0),
        ),
        "4" => (
            "figure4_cifar10.csv".to_string(),
            figures::figure4(Nb201Dataset::Cifar10, 0),
        ),
        "5" => {
            for ds in [
                Nb201Dataset::Cifar10,
                Nb201Dataset::Cifar100,
                Nb201Dataset::ImageNet16_120,
            ] {
                let csv = figures::figure5(ds, budget);
                let p = dir.join(format!(
                    "figure5_{}.csv",
                    NasBench201::new(ds).name().replace('/', "_")
                ));
                std::fs::write(&p, csv).map_err(|e| e.to_string())?;
                println!("wrote {}", p.display());
            }
            return Ok(());
        }
        other => return Err(format!("unknown figure '{other}'")),
    };
    let p = dir.join(name);
    std::fs::write(&p, &content).map_err(|e| e.to_string())?;
    if content.len() < 4000 {
        println!("{content}");
    }
    println!("wrote {}", p.display());
    Ok(())
}

fn cmd_report(flags: &HashMap<String, String>) -> Result<(), String> {
    for id in [
        "1", "2", "3", "4", "5", "6", "8", "9", "10", "11", "12", "13", "14", "15", "ablation",
    ] {
        println!("=== table {id} ===");
        cmd_table(Some(id), flags)?;
    }
    for id in ["1", "2", "3", "4", "5"] {
        println!("=== figure {id} ===");
        cmd_figure(Some(id), flags)?;
    }
    Ok(())
}

/// Record the engine's performance trajectory: serial-vs-parallel
/// experiment-grid wall time (with a result-identity check) and raw
/// simulator throughput, written as `BENCH_engine.json`.
fn cmd_bench_json(flags: &HashMap<String, String>) -> Result<(), String> {
    use pasha::util::json::Json;
    use pasha::util::parallel::available_threads;
    use std::time::Instant;

    let out_path = PathBuf::from(
        flags
            .get("out")
            .cloned()
            .unwrap_or_else(|| "BENCH_engine.json".to_string()),
    );
    let builder = PashaBuilder::default();
    let spec = TunerSpec {
        config_budget: 64,
        ..Default::default()
    };
    let sched_seeds: Vec<u64> = (0..4).collect();
    let bench_seeds: Vec<u64> = (0..3).collect();
    let runs = sched_seeds.len() * bench_seeds.len();
    let threads = available_threads();

    // Each timed pass gets a fresh benchmark instance: NASBench201 caches
    // fitted curves internally, so reusing one instance would hand the
    // second pass a hot cache and skew the comparison.
    let bench_serial = NasBench201::cifar100();
    let t0 = Instant::now();
    let serial =
        Tuner::run_repeated_serial(&bench_serial, &builder, &spec, &sched_seeds, &bench_seeds);
    let serial_s = t0.elapsed().as_secs_f64();
    let bench_parallel = NasBench201::cifar100();
    let t1 = Instant::now();
    let parallel =
        Tuner::run_repeated(&bench_parallel, &builder, &spec, &sched_seeds, &bench_seeds);
    let parallel_s = t1.elapsed().as_secs_f64();
    let identical = serial == parallel;

    // Raw simulator throughput: jobs pushed through the event loop / sec,
    // again on a cold benchmark instance.
    let bench_sim = NasBench201::cifar100();
    let t2 = Instant::now();
    let mut sim_jobs = 0usize;
    for seed in 0..4u64 {
        let r = Tuner::run(&bench_sim, &AshaBuilder::default(), &spec, seed, 0);
        sim_jobs += r.jobs;
    }
    let sim_s = t2.elapsed().as_secs_f64();

    let mut grid = Json::obj();
    grid.set("runs", runs)
        .set("threads", threads)
        .set("serial_seconds", serial_s)
        .set("parallel_seconds", parallel_s)
        .set("speedup", serial_s / parallel_s.max(1e-9))
        .set("identical_results", identical);
    let mut sim = Json::obj();
    sim.set("jobs", sim_jobs)
        .set("seconds", sim_s)
        .set("jobs_per_sec", sim_jobs as f64 / sim_s.max(1e-9));
    let mut root = Json::obj();
    root.set("benchmark", "engine")
        .set("grid", grid)
        .set("sim_throughput", sim);
    std::fs::write(&out_path, root.to_string_pretty()).map_err(|e| e.to_string())?;
    println!(
        "grid: {runs} runs — serial {serial_s:.2}s vs parallel {parallel_s:.2}s \
         ({:.1}x on {threads} threads, identical={identical})",
        serial_s / parallel_s.max(1e-9)
    );
    println!(
        "sim throughput: {sim_jobs} jobs in {sim_s:.2}s ({:.0} jobs/sec)",
        sim_jobs as f64 / sim_s.max(1e-9)
    );
    println!("wrote {}", out_path.display());
    if !identical {
        return Err("parallel grid diverged from serial reference".into());
    }
    Ok(())
}

#[cfg(feature = "pjrt")]
fn cmd_e2e(flags: &HashMap<String, String>) -> Result<(), String> {
    let budget: usize = flag(flags, "budget", 24);
    let hidden: usize = flag(flags, "hidden", 64);
    let workers: usize = flag(flags, "workers", 4);
    pasha::e2e::run_e2e(budget, hidden, workers).map_err(|e| e.to_string())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_e2e(_flags: &HashMap<String, String>) -> Result<(), String> {
    Err("built without the `pjrt` feature — rebuild with `--features pjrt`".into())
}

#[cfg(feature = "pjrt")]
fn cmd_artifacts_check() -> Result<(), String> {
    use pasha::runtime::artifact::{artifacts_available, artifacts_dir, Engine};
    println!("artifacts dir: {}", artifacts_dir().display());
    if !artifacts_available() {
        return Err("artifacts not built — run `make artifacts`".into());
    }
    let engine = Engine::cpu().map_err(|e| e.to_string())?;
    println!("PJRT platform: {}", engine.platform_name());
    for name in [
        "mlp_train_h64",
        "mlp_eval_h64",
        "gp_ei_n64_d4_m64",
        "knn_n512_d4_q4",
    ] {
        engine
            .load_named(name)
            .map_err(|e| format!("{name}: {e}"))?;
        println!("compiled {name}: OK");
    }
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_artifacts_check() -> Result<(), String> {
    Err("built without the `pjrt` feature — rebuild with `--features pjrt`".into())
}
