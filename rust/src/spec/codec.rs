//! Strict JSON codec for the v2 [`ExperimentSpec`] wire format.
//!
//! Serialization is deterministic: object keys are alphabetically sorted
//! (the [`Json`] writer's `BTreeMap` ordering), integers print without a
//! fraction, and `to_string_compact` output is byte-stable — which is
//! what the committed golden fixtures in `tests/fixtures/` pin down.
//!
//! Parsing is strict through [`Fields`]: every recognized key is marked
//! as consumed, and any leftover key is an error naming the full field
//! path (`unknown field 'scheduler.modee'`). Values are type- and
//! range-checked with errors that also name the field. Omitted keys take
//! the documented defaults — strictness is about rejecting what we do
//! *not* understand, not about forcing every knob to be spelled out.

use super::{
    BenchSpec, DecisionMode, ExecBackendKind, ExecSpec, ExperimentSpec, SchedulerSpec,
    SearcherSpec, StopRules, WarmStartSpec, WarmTrial, SPEC_VERSION,
    WARM_START_DEFAULT_MAX_TRIALS,
};
use crate::curvefit::ModelChoice;
use crate::ranking::RankingSpec;
use crate::searcher::bo::BoConfig;
use crate::util::json::Json;
use std::collections::{BTreeMap, BTreeSet};

/// A strict view over one JSON object: tracks which keys were consumed
/// so [`Fields::finish`] can reject the rest by name.
pub(crate) struct Fields<'a> {
    /// Dotted path prefix for error messages (`""` at the top level,
    /// `"scheduler."` inside the scheduler object, …).
    prefix: String,
    map: &'a BTreeMap<String, Json>,
    seen: BTreeSet<&'a str>,
}

impl<'a> Fields<'a> {
    pub(crate) fn new(j: &'a Json, prefix: &str) -> Result<Fields<'a>, String> {
        match j {
            Json::Obj(map) => Ok(Fields {
                prefix: prefix.to_string(),
                map,
                seen: BTreeSet::new(),
            }),
            _ => Err(format!(
                "field '{}': must be an object",
                prefix.trim_end_matches('.')
            )),
        }
    }

    fn path(&self, key: &str) -> String {
        format!("{}{key}", self.prefix)
    }

    /// Mark `key` consumed and fetch it. `null` counts as absent.
    fn take(&mut self, key: &'a str) -> Option<&'a Json> {
        self.seen.insert(key);
        match self.map.get(key) {
            Some(Json::Null) | None => None,
            Some(v) => Some(v),
        }
    }

    pub(crate) fn opt_str(&mut self, key: &'a str) -> Result<Option<String>, String> {
        match self.take(key) {
            None => Ok(None),
            Some(Json::Str(s)) => Ok(Some(s.clone())),
            Some(_) => Err(format!("field '{}': must be a string", self.path(key))),
        }
    }

    pub(crate) fn str_or(&mut self, key: &'a str, default: &str) -> Result<String, String> {
        Ok(self.opt_str(key)?.unwrap_or_else(|| default.to_string()))
    }

    pub(crate) fn opt_f64(&mut self, key: &'a str) -> Result<Option<f64>, String> {
        match self.take(key) {
            None => Ok(None),
            Some(Json::Num(n)) => Ok(Some(*n)),
            Some(_) => Err(format!("field '{}': must be a number", self.path(key))),
        }
    }

    pub(crate) fn f64_or(&mut self, key: &'a str, default: f64) -> Result<f64, String> {
        Ok(self.opt_f64(key)?.unwrap_or(default))
    }

    fn integer(&self, key: &str, v: f64) -> Result<u64, String> {
        if v.is_finite() && v >= 0.0 && v.fract() == 0.0 && v <= 2f64.powi(53) {
            Ok(v as u64)
        } else {
            Err(format!(
                "field '{}': must be a non-negative integer (got {v})",
                self.path(key)
            ))
        }
    }

    pub(crate) fn opt_u64(&mut self, key: &'a str) -> Result<Option<u64>, String> {
        match self.opt_f64(key)? {
            None => Ok(None),
            Some(v) => Ok(Some(self.integer(key, v)?)),
        }
    }

    pub(crate) fn u64_or(&mut self, key: &'a str, default: u64) -> Result<u64, String> {
        Ok(self.opt_u64(key)?.unwrap_or(default))
    }

    pub(crate) fn u32_or(&mut self, key: &'a str, default: u32) -> Result<u32, String> {
        match self.opt_u64(key)? {
            None => Ok(default),
            Some(v) if v <= u32::MAX as u64 => Ok(v as u32),
            Some(v) => Err(format!(
                "field '{}': {v} is out of range for a 32-bit integer",
                self.path(key)
            )),
        }
    }

    pub(crate) fn usize_or(&mut self, key: &'a str, default: usize) -> Result<usize, String> {
        Ok(self.u64_or(key, default as u64)? as usize)
    }

    /// Consume a nested object, returning `None` when absent.
    pub(crate) fn opt_obj(&mut self, key: &'a str) -> Result<Option<Fields<'a>>, String> {
        match self.take(key) {
            None => Ok(None),
            Some(v) => Fields::new(v, &format!("{}.", self.path(key))).map(Some),
        }
    }

    /// Consume an array-valued key, returning `None` when absent.
    pub(crate) fn opt_arr(&mut self, key: &'a str) -> Result<Option<&'a [Json]>, String> {
        match self.take(key) {
            None => Ok(None),
            Some(Json::Arr(v)) => Ok(Some(v)),
            Some(_) => Err(format!("field '{}': must be an array", self.path(key))),
        }
    }

    /// Error on every key that was present but never consumed.
    pub(crate) fn finish(self) -> Result<(), String> {
        let unknown: Vec<String> = self
            .map
            .keys()
            .filter(|k| !self.seen.contains(k.as_str()))
            .map(|k| format!("'{}{}'", self.prefix, k))
            .collect();
        if unknown.is_empty() {
            Ok(())
        } else {
            let expected: Vec<&str> = self.seen.iter().copied().collect();
            Err(format!(
                "unknown field {} (expected one of: {})",
                unknown.join(", "),
                expected.join(", ")
            ))
        }
    }
}

pub(crate) fn to_json(spec: &ExperimentSpec) -> Json {
    let mut o = Json::obj();
    o.set("version", SPEC_VERSION)
        .set("bench", bench_to_json(&spec.bench))
        .set("scheduler", scheduler_to_json(&spec.scheduler))
        .set("searcher", searcher_to_json(&spec.searcher))
        .set("exec", exec_to_json(&spec.exec))
        .set("stop", stop_to_json(&spec.stop))
        .set("seed", spec.seed as f64)
        .set("bench_seed", spec.bench_seed as f64);
    o
}

pub(crate) fn from_v2_json(j: &Json) -> Result<ExperimentSpec, String> {
    let mut f = Fields::new(j, "")?;
    let version = f.u32_or("version", SPEC_VERSION)?;
    if version != SPEC_VERSION {
        return Err(format!(
            "field 'version': unsupported spec version {version} (this build reads v1 and v2)"
        ));
    }
    let bench = match f.opt_obj("bench")? {
        None => BenchSpec::new("nas-cifar10"),
        Some(b) => bench_from_fields(b)?,
    };
    let scheduler = match f.opt_obj("scheduler")? {
        None => ExperimentSpec::default().scheduler,
        Some(s) => scheduler_from_fields(s)?,
    };
    let searcher = match f.opt_obj("searcher")? {
        None => SearcherSpec::Random,
        Some(s) => searcher_from_fields(s)?,
    };
    let exec = match f.opt_obj("exec")? {
        None => ExecSpec::default(),
        Some(e) => exec_from_fields(e)?,
    };
    let stop = match f.opt_obj("stop")? {
        None => StopRules::default(),
        Some(s) => stop_from_fields(s)?,
    };
    let seed = f.u64_or("seed", 0)?;
    let bench_seed = f.u64_or("bench_seed", 0)?;
    f.finish()?;
    Ok(ExperimentSpec {
        bench,
        scheduler,
        searcher,
        exec,
        stop,
        seed,
        bench_seed,
    })
}

fn bench_to_json(b: &BenchSpec) -> Json {
    let mut o = Json::obj();
    o.set("name", b.name.as_str());
    o
}

fn bench_from_fields(mut f: Fields) -> Result<BenchSpec, String> {
    let name = f.str_or("name", "nas-cifar10")?;
    f.finish()?;
    Ok(BenchSpec::new(&name))
}

fn scheduler_to_json(s: &SchedulerSpec) -> Json {
    let mut o = Json::obj();
    match s {
        SchedulerSpec::Asha { r_min, eta, mode } => {
            o.set("name", "asha")
                .set("mode", mode.as_str())
                .set("r_min", *r_min)
                .set("eta", *eta);
        }
        SchedulerSpec::Pasha {
            r_min,
            eta,
            mode,
            ranking,
        } => {
            o.set("name", "pasha")
                .set("mode", mode.as_str())
                .set("r_min", *r_min)
                .set("eta", *eta)
                .set("ranking", ranking_to_json(ranking));
        }
        SchedulerSpec::Lce {
            r_min,
            eta,
            model,
            min_points,
            stop_quantile,
            confidence,
        } => {
            // always stopping-type: no `mode` key on the wire
            o.set("name", "lce")
                .set("r_min", *r_min)
                .set("eta", *eta)
                .set("model", model.as_str())
                .set("min_points", *min_points)
                .set("stop_quantile", *stop_quantile)
                .set("confidence", *confidence);
        }
        SchedulerSpec::Sh { r_min, eta } => {
            o.set("name", "sh").set("r_min", *r_min).set("eta", *eta);
        }
        SchedulerSpec::Hyperband { r_min, eta } => {
            o.set("name", "hyperband")
                .set("r_min", *r_min)
                .set("eta", *eta);
        }
        SchedulerSpec::FixedEpoch { epochs } => {
            o.set("name", "1-epoch").set("epochs", *epochs);
        }
        SchedulerSpec::RandomBaseline => {
            o.set("name", "random");
        }
    }
    o
}

fn scheduler_from_fields(mut f: Fields) -> Result<SchedulerSpec, String> {
    let name = f.str_or("name", "pasha")?;
    // `asha-stop`-style names carry their mode; an explicit `mode` key
    // must not contradict them.
    let (base, name_mode) = match name.as_str() {
        "asha-stop" => ("asha", Some(DecisionMode::Stop)),
        "pasha-stop" => ("pasha", Some(DecisionMode::Stop)),
        other => (other, None),
    };
    let explicit_mode = f.opt_str("mode")?;
    let has_explicit_mode = explicit_mode.is_some();
    let mode = match (name_mode, explicit_mode) {
        (Some(_), Some(_)) => {
            return Err(format!(
                "field 'scheduler.mode': conflicts with scheduler name '{name}' \
                 (use name 'asha'/'pasha' with an explicit mode)"
            ));
        }
        (Some(m), None) => m,
        (None, Some(s)) => DecisionMode::parse(&s).ok_or_else(|| {
            format!("field 'scheduler.mode': expected 'promote' or 'stop' (got '{s}')")
        })?,
        (None, None) => DecisionMode::Promote,
    };
    let spec = match base {
        "asha" => SchedulerSpec::Asha {
            r_min: f.u32_or("r_min", 1)?,
            eta: f.u32_or("eta", 3)?,
            mode,
        },
        "pasha" => {
            let ranking = match f.opt_obj("ranking")? {
                None => RankingSpec::default(),
                Some(r) => ranking_from_fields(r)?,
            };
            SchedulerSpec::Pasha {
                r_min: f.u32_or("r_min", 1)?,
                eta: f.u32_or("eta", 3)?,
                mode,
                ranking,
            }
        }
        "lce" => {
            // lce is always stopping-type; the mode key is meaningless
            // for it in either spelling, so reject it outright.
            if has_explicit_mode {
                return Err(
                    "field 'scheduler.mode': 'lce' is always stopping-type and takes no mode"
                        .to_string(),
                );
            }
            let model_name = f.str_or("model", "auto")?;
            let model = ModelChoice::parse(&model_name).ok_or_else(|| {
                format!(
                    "field 'scheduler.model': expected 'auto', 'power', or 'exp' \
                     (got '{model_name}')"
                )
            })?;
            SchedulerSpec::Lce {
                r_min: f.u32_or("r_min", 1)?,
                eta: f.u32_or("eta", 3)?,
                model,
                min_points: f.u32_or("min_points", 4)?,
                stop_quantile: f.f64_or("stop_quantile", 0.5)?,
                confidence: f.f64_or("confidence", 0.9)?,
            }
        }
        "sh" => SchedulerSpec::Sh {
            r_min: f.u32_or("r_min", 1)?,
            eta: f.u32_or("eta", 3)?,
        },
        "hyperband" => SchedulerSpec::Hyperband {
            r_min: f.u32_or("r_min", 1)?,
            eta: f.u32_or("eta", 3)?,
        },
        "1-epoch" => SchedulerSpec::FixedEpoch {
            epochs: f.u32_or("epochs", 1)?,
        },
        "random" => SchedulerSpec::RandomBaseline,
        other => return Err(format!("field 'scheduler.name': unknown scheduler '{other}'")),
    };
    if mode == DecisionMode::Stop && !matches!(base, "asha" | "pasha") {
        return Err(format!(
            "field 'scheduler.mode': '{base}' has no stopping variant"
        ));
    }
    f.finish()?;
    Ok(spec)
}

pub(crate) fn ranking_to_json(r: &RankingSpec) -> Json {
    let mut o = Json::obj();
    match *r {
        RankingSpec::NoiseAdaptive { percentile } => {
            o.set("kind", "noisy").set("percentile", percentile);
        }
        RankingSpec::Direct => {
            o.set("kind", "plain");
        }
        RankingSpec::SoftFixed { epsilon } => {
            o.set("kind", "soft").set("epsilon", epsilon);
        }
        RankingSpec::SoftSigma { mult } => {
            o.set("kind", "sigma").set("mult", mult);
        }
        RankingSpec::SoftMeanGap => {
            o.set("kind", "mean-gap");
        }
        RankingSpec::SoftMedianGap => {
            o.set("kind", "median-gap");
        }
        RankingSpec::Rbo { p, t } => {
            o.set("kind", "rbo").set("p", p).set("t", t);
        }
        RankingSpec::Rrr { p, t } => {
            o.set("kind", "rrr").set("p", p).set("t", t);
        }
        RankingSpec::Arrr { p, t } => {
            o.set("kind", "arrr").set("p", p).set("t", t);
        }
    }
    o
}

fn ranking_from_fields(mut f: Fields) -> Result<RankingSpec, String> {
    let kind = f.str_or("kind", "noisy")?;
    let spec = match kind.as_str() {
        "noisy" => RankingSpec::NoiseAdaptive {
            percentile: f.f64_or("percentile", 90.0)?,
        },
        "plain" => RankingSpec::Direct,
        "soft" => RankingSpec::SoftFixed {
            epsilon: f.f64_or("epsilon", 0.0)?,
        },
        "sigma" => RankingSpec::SoftSigma {
            mult: f.f64_or("mult", 2.0)?,
        },
        "mean-gap" => RankingSpec::SoftMeanGap,
        "median-gap" => RankingSpec::SoftMedianGap,
        "rbo" => RankingSpec::Rbo {
            p: f.f64_or("p", 0.5)?,
            t: f.f64_or("t", 0.5)?,
        },
        "rrr" => RankingSpec::Rrr {
            p: f.f64_or("p", 0.5)?,
            t: f.f64_or("t", 0.05)?,
        },
        "arrr" => RankingSpec::Arrr {
            p: f.f64_or("p", 1.0)?,
            t: f.f64_or("t", 0.05)?,
        },
        other => {
            return Err(format!(
                "field 'scheduler.ranking.kind': unknown ranking function '{other}' \
                 (expected noisy, plain, soft, sigma, mean-gap, median-gap, rbo, rrr, arrr)"
            ));
        }
    };
    f.finish()?;
    Ok(spec)
}

fn searcher_to_json(s: &SearcherSpec) -> Json {
    let mut o = Json::obj();
    match s {
        SearcherSpec::Random => {
            o.set("name", "random");
        }
        SearcherSpec::Bo { config: cfg, warm_start } => {
            o.set("name", "bo")
                .set("min_points", cfg.min_points)
                .set("num_candidates", cfg.num_candidates)
                .set("random_fraction", cfg.random_fraction)
                .set("lengthscale", cfg.lengthscale)
                .set("signal_var", cfg.signal_var)
                .set("noise_var", cfg.noise_var);
            // absent when None, so pre-warm-start payload bytes are
            // unchanged (the golden fixtures pin this)
            if let Some(ws) = warm_start {
                o.set("warm_start", warm_start_to_json(ws));
            }
        }
    }
    o
}

fn warm_start_to_json(ws: &WarmStartSpec) -> Json {
    let mut o = Json::obj();
    o.set("from", ws.from.as_str())
        .set("max_trials", ws.max_trials);
    if let Some(trials) = &ws.trials {
        o.set(
            "trials",
            Json::Arr(
                trials
                    .iter()
                    .map(|t| {
                        let mut e = Json::obj();
                        e.set("config", t.config.clone())
                            .set("epoch", t.epoch)
                            .set("metric", t.metric);
                        e
                    })
                    .collect(),
            ),
        );
    }
    o
}

fn searcher_from_fields(mut f: Fields) -> Result<SearcherSpec, String> {
    let name = f.str_or("name", "random")?;
    let spec = match name.as_str() {
        "random" => SearcherSpec::Random,
        "bo" => {
            let d = BoConfig::default();
            let config = BoConfig {
                min_points: f.usize_or("min_points", d.min_points)?,
                num_candidates: f.usize_or("num_candidates", d.num_candidates)?,
                random_fraction: f.f64_or("random_fraction", d.random_fraction)?,
                lengthscale: f.f64_or("lengthscale", d.lengthscale)?,
                signal_var: f.f64_or("signal_var", d.signal_var)?,
                noise_var: f.f64_or("noise_var", d.noise_var)?,
            };
            let warm_start = match f.opt_obj("warm_start")? {
                None => None,
                Some(w) => Some(warm_start_from_fields(w)?),
            };
            SearcherSpec::Bo { config, warm_start }
        }
        other => return Err(format!("field 'searcher.name': unknown searcher '{other}'")),
    };
    f.finish()?;
    Ok(spec)
}

fn warm_start_from_fields(mut f: Fields) -> Result<WarmStartSpec, String> {
    let from = f
        .opt_str("from")?
        .ok_or("field 'searcher.warm_start.from': a store path is required")?;
    let max_trials = f.usize_or("max_trials", WARM_START_DEFAULT_MAX_TRIALS)?;
    let trials = match f.opt_arr("trials")? {
        None => None,
        Some(arr) => {
            let mut out = Vec::with_capacity(arr.len());
            for (i, t) in arr.iter().enumerate() {
                let prefix = format!("searcher.warm_start.trials[{i}].");
                let mut tf = Fields::new(t, &prefix)?;
                let config = tf
                    .opt_arr("config")?
                    .ok_or_else(|| format!("field '{prefix}config': is required"))?
                    .iter()
                    .map(|v| {
                        v.as_f64()
                            .ok_or_else(|| format!("field '{prefix}config': must be numbers"))
                    })
                    .collect::<Result<Vec<f64>, String>>()?;
                let epoch = tf.u32_or("epoch", 1)?;
                let metric = tf
                    .opt_f64("metric")?
                    .ok_or_else(|| format!("field '{prefix}metric': is required"))?;
                tf.finish()?;
                out.push(WarmTrial {
                    config,
                    epoch,
                    metric,
                });
            }
            Some(out)
        }
    };
    f.finish()?;
    Ok(WarmStartSpec {
        from,
        max_trials,
        trials,
    })
}

fn exec_to_json(e: &ExecSpec) -> Json {
    let mut o = Json::obj();
    o.set("workers", e.workers).set("backend", e.backend.as_str());
    o
}

fn exec_from_fields(mut f: Fields) -> Result<ExecSpec, String> {
    let backend_name = f.str_or("backend", "sim")?;
    let backend = ExecBackendKind::parse(&backend_name).ok_or_else(|| {
        format!("field 'exec.backend': expected 'sim' or 'pool' (got '{backend_name}')")
    })?;
    let workers = f.usize_or("workers", 4)?;
    f.finish()?;
    Ok(ExecSpec { workers, backend })
}

fn stop_to_json(s: &StopRules) -> Json {
    let mut o = Json::obj();
    o.set("config_budget", s.config_budget);
    if let Some(e) = s.epoch_budget {
        o.set("epoch_budget", e as f64);
    }
    if let Some(t) = s.time_budget {
        o.set("time_budget", t);
    }
    o
}

fn stop_from_fields(mut f: Fields) -> Result<StopRules, String> {
    let rules = StopRules {
        config_budget: f.usize_or("config_budget", 256)?,
        epoch_budget: f.opt_u64("epoch_budget")?,
        time_budget: f.opt_f64("time_budget")?,
    };
    f.finish()?;
    Ok(rules)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::parse;

    #[test]
    fn unknown_keys_are_rejected_with_paths() {
        let j = parse(r#"{"version":2,"stop":{"confg_budget":64}}"#).unwrap();
        let err = ExperimentSpec::from_json(&j).unwrap_err();
        assert!(err.contains("'stop.confg_budget'"), "{err}");

        let j = parse(r#"{"version":2,"scheduler":{"name":"pasha","modee":"stop"}}"#).unwrap();
        let err = ExperimentSpec::from_json(&j).unwrap_err();
        assert!(err.contains("'scheduler.modee'"), "{err}");

        let j = parse(r#"{"version":2,"extra":1}"#).unwrap();
        let err = ExperimentSpec::from_json(&j).unwrap_err();
        assert!(err.contains("'extra'"), "{err}");
    }

    #[test]
    fn bad_types_and_versions_are_rejected() {
        let j = parse(r#"{"version":3}"#).unwrap();
        let err = ExperimentSpec::from_json(&j).unwrap_err();
        assert!(err.contains("version"), "{err}");

        let j = parse(r#"{"version":2,"seed":-1}"#).unwrap();
        let err = ExperimentSpec::from_json(&j).unwrap_err();
        assert!(err.contains("'seed'"), "{err}");

        let j = parse(r#"{"version":2,"bench":"nas-cifar10"}"#).unwrap();
        let err = ExperimentSpec::from_json(&j).unwrap_err();
        assert!(err.contains("'bench'"), "{err}");

        let j = parse(r#"{"version":2,"stop":{"config_budget":1.5}}"#).unwrap();
        let err = ExperimentSpec::from_json(&j).unwrap_err();
        assert!(err.contains("'stop.config_budget'"), "{err}");
    }

    #[test]
    fn partial_v2_payloads_take_defaults() {
        let j = parse(r#"{"version":2,"bench":{"name":"pd1-wmt"}}"#).unwrap();
        let spec = ExperimentSpec::from_json(&j).unwrap();
        assert_eq!(spec.bench.name, "pd1-wmt");
        assert_eq!(spec.stop.config_budget, 256);
        assert_eq!(spec.scheduler.wire_name(), "pasha");
        assert_eq!(spec.exec.workers, 4);
    }

    #[test]
    fn stop_suffix_names_and_mode_key_agree() {
        let j = parse(r#"{"version":2,"scheduler":{"name":"asha-stop"}}"#).unwrap();
        let spec = ExperimentSpec::from_json(&j).unwrap();
        assert_eq!(spec.scheduler.wire_name(), "asha-stop");

        let j = parse(r#"{"version":2,"scheduler":{"name":"asha","mode":"stop"}}"#).unwrap();
        let spec2 = ExperimentSpec::from_json(&j).unwrap();
        assert_eq!(spec.scheduler, spec2.scheduler);

        let j =
            parse(r#"{"version":2,"scheduler":{"name":"asha-stop","mode":"stop"}}"#).unwrap();
        let err = ExperimentSpec::from_json(&j).unwrap_err();
        assert!(err.contains("scheduler.mode"), "{err}");

        let j = parse(r#"{"version":2,"scheduler":{"name":"sh","mode":"stop"}}"#).unwrap();
        let err = ExperimentSpec::from_json(&j).unwrap_err();
        assert!(err.contains("no stopping variant"), "{err}");
    }

    #[test]
    fn lce_round_trips_and_rejects_mode_in_any_spelling() {
        let j = parse(
            r#"{"version":2,"scheduler":{"name":"lce","r_min":2,"eta":4,"model":"exp",
                "min_points":6,"stop_quantile":0.25,"confidence":0.8}}"#,
        )
        .unwrap();
        let spec = ExperimentSpec::from_json(&j).unwrap();
        assert_eq!(
            spec.scheduler,
            SchedulerSpec::Lce {
                r_min: 2,
                eta: 4,
                model: ModelChoice::Exp,
                min_points: 6,
                stop_quantile: 0.25,
                confidence: 0.8,
            }
        );
        let bytes = spec.to_json().to_string_compact();
        let back = ExperimentSpec::from_json(&parse(&bytes).unwrap()).unwrap();
        assert_eq!(back, spec);
        assert_eq!(back.to_json().to_string_compact(), bytes);

        // defaults when knobs are omitted
        let j = parse(r#"{"version":2,"scheduler":{"name":"lce"}}"#).unwrap();
        let spec = ExperimentSpec::from_json(&j).unwrap();
        assert_eq!(
            spec.scheduler,
            SchedulerSpec::Lce {
                r_min: 1,
                eta: 3,
                model: ModelChoice::Auto,
                min_points: 4,
                stop_quantile: 0.5,
                confidence: 0.9,
            }
        );

        // lce carries no DecisionMode: even mode=promote is an error
        for mode in ["promote", "stop"] {
            let j = parse(&format!(
                r#"{{"version":2,"scheduler":{{"name":"lce","mode":"{mode}"}}}}"#
            ))
            .unwrap();
            let err = ExperimentSpec::from_json(&j).unwrap_err();
            assert!(err.contains("scheduler.mode"), "{err}");
        }

        let j = parse(r#"{"version":2,"scheduler":{"name":"lce","model":"cubic"}}"#).unwrap();
        let err = ExperimentSpec::from_json(&j).unwrap_err();
        assert!(err.contains("scheduler.model"), "{err}");
    }

    #[test]
    fn warm_start_round_trips_in_both_states() {
        // unresolved reference
        let mut spec = ExperimentSpec::default();
        spec.searcher = SearcherSpec::bo_warm("trials.jsonl", 8);
        let j = spec.to_json();
        assert_eq!(ExperimentSpec::from_json(&j).unwrap(), spec);

        // sealed form with embedded trials
        spec.searcher.seal_warm_start(vec![
            WarmTrial {
                config: vec![3.0],
                epoch: 9,
                metric: 88.5,
            },
            WarmTrial {
                config: vec![1.0],
                epoch: 3,
                metric: 70.0,
            },
        ]);
        let j = spec.to_json();
        let back = ExperimentSpec::from_json(&j).unwrap();
        assert_eq!(back, spec);
        // and the sealed bytes are deterministic
        assert_eq!(back.to_json().to_string_compact(), j.to_string_compact());

        // a BO searcher without warm start serializes without the key
        let plain = ExperimentSpec {
            searcher: SearcherSpec::bo_default(),
            ..ExperimentSpec::default()
        };
        assert!(plain.to_json().get("searcher").unwrap().get("warm_start").is_none());

        // strictness inside the warm-start object
        let j = parse(
            r#"{"version":2,"searcher":{"name":"bo","warm_start":{"from":"s.jsonl","max_trails":4}}}"#,
        )
        .unwrap();
        let err = ExperimentSpec::from_json(&j).unwrap_err();
        assert!(err.contains("'searcher.warm_start.max_trails'"), "{err}");
        let j = parse(r#"{"version":2,"searcher":{"name":"bo","warm_start":{}}}"#).unwrap();
        let err = ExperimentSpec::from_json(&j).unwrap_err();
        assert!(err.contains("searcher.warm_start.from"), "{err}");
        let j = parse(
            r#"{"version":2,"searcher":{"name":"bo",
                "warm_start":{"from":"s.jsonl","trials":[{"epoch":1,"metric":5}]}}}"#,
        )
        .unwrap();
        let err = ExperimentSpec::from_json(&j).unwrap_err();
        assert!(err.contains("trials[0].config"), "{err}");
        // warm_start on the random searcher is an unknown field
        let j = parse(
            r#"{"version":2,"searcher":{"name":"random","warm_start":{"from":"s.jsonl"}}}"#,
        )
        .unwrap();
        let err = ExperimentSpec::from_json(&j).unwrap_err();
        assert!(err.contains("'searcher.warm_start'"), "{err}");
    }

    #[test]
    fn every_ranking_kind_round_trips() {
        let kinds = [
            RankingSpec::NoiseAdaptive { percentile: 90.0 },
            RankingSpec::Direct,
            RankingSpec::SoftFixed { epsilon: 0.025 },
            RankingSpec::SoftSigma { mult: 2.0 },
            RankingSpec::SoftMeanGap,
            RankingSpec::SoftMedianGap,
            RankingSpec::Rbo { p: 0.9, t: 0.5 },
            RankingSpec::Rrr { p: 0.5, t: 0.05 },
            RankingSpec::Arrr { p: 1.0, t: 0.05 },
        ];
        for r in kinds {
            let j = ranking_to_json(&r);
            let f = Fields::new(&j, "scheduler.ranking.").unwrap();
            let back = ranking_from_fields(f).unwrap();
            assert_eq!(r, back, "{}", j.to_string_compact());
        }
    }
}
