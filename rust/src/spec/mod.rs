//! The declarative experiment specification — the single construction
//! path for every way of running PASHA.
//!
//! An [`ExperimentSpec`] is a versioned, JSON-round-trippable description
//! of one experiment: which benchmark ([`BenchSpec`]), which decision
//! policy with *all* of the paper's knobs ([`SchedulerSpec`]: `r_min`,
//! η, the ranking function, promote-vs-stop mode), which proposal
//! strategy ([`SearcherSpec`], including the BO hyperparameters), how to
//! execute ([`ExecSpec`]: workers, sim/pool backend), and when to stop
//! ([`StopRules`]). The CLI (`pasha run --spec exp.json`), the in-process
//! tuner ([`crate::tuner::Tuner::run`]), and the tuning service's
//! `create` command all lower into this one type, so an experiment is a
//! durable, diffable artifact rather than a combination of code paths.
//!
//! Parsing is *strict*: unknown keys and out-of-range values are errors
//! that name the offending field (see [`ExperimentSpec::from_json`]).
//! The wire format is versioned — `"version": 2` is the current schema;
//! v1 payloads (the flat `SessionSpec` shape of earlier journals) are
//! detected by the absence of a `version` key and migrated losslessly,
//! so every existing journal and snapshot recovers byte-identically.

mod cli;
mod codec;
mod route;
mod v1;

pub use cli::{apply_flag_overrides, parse_ranking, SPEC_FLAGS};
pub use route::{RouteSpec, ROUTE_VERSION};

use crate::benchmarks::lcbench::{self, LcBench};
use crate::benchmarks::nasbench201::NasBench201;
use crate::benchmarks::pd1::Pd1;
use crate::benchmarks::Benchmark;
use crate::config::space::SearchSpace;
use crate::curvefit::ModelChoice;
use crate::executor::engine::{ConfigBudget, EpochBudget, StoppingRule};
use crate::ranking::RankingSpec;
use crate::scheduler::asha::AshaBuilder;
use crate::scheduler::asktell::{config_from_json, AskTell};
use crate::scheduler::baselines::{FixedEpochBuilder, RandomBaselineBuilder};
use crate::scheduler::hyperband::HyperbandBuilder;
use crate::scheduler::lce::LceBuilder;
use crate::scheduler::pasha::PashaBuilder;
use crate::scheduler::sh::SyncShBuilder;
use crate::scheduler::stopping::{StopAshaBuilder, StopPashaBuilder};
use crate::scheduler::SchedulerBuilder;
use crate::searcher::bo::{BoConfig, BoSearcher};
use crate::searcher::random::RandomSearcher;
use crate::searcher::Searcher;
use crate::util::json::Json;
use crate::util::rng::mix;

/// Current wire-format version written by [`ExperimentSpec::to_json`].
pub const SPEC_VERSION: u32 = 2;

/// Which benchmark substrate an experiment runs against, by wire name
/// (`nas-cifar10`, `pd1-wmt`, `lcbench-<dataset>`, …).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BenchSpec {
    pub name: String,
}

impl BenchSpec {
    pub fn new(name: &str) -> BenchSpec {
        BenchSpec {
            name: name.to_string(),
        }
    }

    /// Check the name resolves without constructing the benchmark.
    pub fn validate(&self) -> Result<(), String> {
        match self.name.as_str() {
            "nas-cifar10" | "nas-cifar100" | "nas-imagenet16" | "pd1-wmt" | "pd1-imagenet" => {
                Ok(())
            }
            other => match other.strip_prefix("lcbench-") {
                Some(ds) if lcbench::DATASETS.iter().any(|(n, _)| *n == ds) => Ok(()),
                Some(ds) => Err(format!(
                    "field 'bench.name': unknown LCBench dataset '{ds}'"
                )),
                None => Err(format!("field 'bench.name': unknown benchmark '{other}'")),
            },
        }
    }

    /// Construct the benchmark this spec names.
    pub fn build(&self) -> Result<Box<dyn Benchmark>, String> {
        self.validate()?;
        Ok(match self.name.as_str() {
            "nas-cifar10" => Box::new(NasBench201::cifar10()),
            "nas-cifar100" => Box::new(NasBench201::cifar100()),
            "nas-imagenet16" => Box::new(NasBench201::imagenet16()),
            "pd1-wmt" => Box::new(Pd1::wmt()),
            "pd1-imagenet" => Box::new(Pd1::imagenet()),
            other => {
                // validate() established the lcbench- prefix and dataset
                let ds = other.strip_prefix("lcbench-").expect("validated");
                Box::new(LcBench::new(ds))
            }
        })
    }
}

/// Whether a successive-halving scheduler *promotes* survivors rung by
/// rung (the ASHA/PASHA default) or *stops* the losers in place while
/// survivors train through (the `-stop` variants, Li et al.'s stopping
/// semantics).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DecisionMode {
    Promote,
    Stop,
}

impl DecisionMode {
    pub fn as_str(&self) -> &'static str {
        match self {
            DecisionMode::Promote => "promote",
            DecisionMode::Stop => "stop",
        }
    }

    pub fn parse(s: &str) -> Option<DecisionMode> {
        match s {
            "promote" => Some(DecisionMode::Promote),
            "stop" => Some(DecisionMode::Stop),
            _ => None,
        }
    }
}

/// The decision policy: which scheduler runs, with every paper knob
/// exposed — `r_min`, the reduction factor η, the ranking function
/// (PASHA §4 / Appendix C), and promote-vs-stop mode.
#[derive(Clone, Debug, PartialEq)]
pub enum SchedulerSpec {
    /// Asynchronous successive halving (Li et al. 2020).
    Asha {
        r_min: u32,
        eta: u32,
        mode: DecisionMode,
    },
    /// Progressive ASHA (the paper's contribution, Algorithm 1).
    Pasha {
        r_min: u32,
        eta: u32,
        mode: DecisionMode,
        ranking: RankingSpec,
    },
    /// Learning-curve extrapolation: stopping-type scheduling on
    /// extrapolated rank under a PASHA-style growing cap, backed by the
    /// [`crate::curvefit`] subsystem. Always stopping-type — the variant
    /// carries no [`DecisionMode`].
    Lce {
        r_min: u32,
        eta: u32,
        /// Curve family to fit (`power` / `exp` / `auto`).
        model: ModelChoice,
        /// Minimum finite history points before a fit is trusted.
        min_points: u32,
        /// Peer-prediction quantile below which a confident loser stops.
        stop_quantile: f64,
        /// One-sided confidence of the optimistic prediction band.
        confidence: f64,
    },
    /// Synchronous successive halving; its initial cohort size is the
    /// experiment's configuration budget.
    Sh { r_min: u32, eta: u32 },
    /// Hyperband over synchronous SH brackets.
    Hyperband { r_min: u32, eta: u32 },
    /// Every configuration trained for a fixed number of epochs.
    FixedEpoch { epochs: u32 },
    /// Random search at full resources (the paper's weakest baseline).
    RandomBaseline,
}

impl SchedulerSpec {
    /// Resolve a scheduler wire name (`asha`, `pasha-stop`, `sh`, …) with
    /// explicit knobs. The `-stop` suffix selects [`DecisionMode::Stop`];
    /// `ranking` only applies to the PASHA variants.
    pub fn from_name(
        name: &str,
        r_min: u32,
        eta: u32,
        ranking: RankingSpec,
    ) -> Result<SchedulerSpec, String> {
        Ok(match name {
            "asha" => SchedulerSpec::Asha {
                r_min,
                eta,
                mode: DecisionMode::Promote,
            },
            "asha-stop" => SchedulerSpec::Asha {
                r_min,
                eta,
                mode: DecisionMode::Stop,
            },
            "pasha" => SchedulerSpec::Pasha {
                r_min,
                eta,
                mode: DecisionMode::Promote,
                ranking,
            },
            "pasha-stop" => SchedulerSpec::Pasha {
                r_min,
                eta,
                mode: DecisionMode::Stop,
                ranking,
            },
            "lce" => SchedulerSpec::Lce {
                r_min,
                eta,
                model: ModelChoice::Auto,
                min_points: 4,
                stop_quantile: 0.5,
                confidence: 0.9,
            },
            "sh" => SchedulerSpec::Sh { r_min, eta },
            "hyperband" => SchedulerSpec::Hyperband { r_min, eta },
            "1-epoch" => SchedulerSpec::FixedEpoch { epochs: 1 },
            "random" => SchedulerSpec::RandomBaseline,
            other => return Err(format!("unknown scheduler '{other}'")),
        })
    }

    /// Re-derive this spec under a (possibly different) wire name,
    /// carrying over every knob the new family shares — `r_min`, η, the
    /// ranking function, and the fixed-epoch count. What `--scheduler`
    /// over a loaded spec and `--set scheduler.name=…` both lower to.
    pub fn renamed(&self, name: &str) -> Result<SchedulerSpec, String> {
        let mut next = SchedulerSpec::from_name(
            name,
            self.r_min().unwrap_or(1),
            self.eta().unwrap_or(3),
            self.ranking().cloned().unwrap_or_default(),
        )?;
        if let (
            SchedulerSpec::FixedEpoch { epochs },
            SchedulerSpec::FixedEpoch { epochs: current },
        ) = (&mut next, self)
        {
            *epochs = *current;
        }
        if let (
            SchedulerSpec::Lce {
                model,
                min_points,
                stop_quantile,
                confidence,
                ..
            },
            SchedulerSpec::Lce {
                model: cur_model,
                min_points: cur_min,
                stop_quantile: cur_q,
                confidence: cur_conf,
                ..
            },
        ) = (&mut next, self)
        {
            *model = *cur_model;
            *min_points = *cur_min;
            *stop_quantile = *cur_q;
            *confidence = *cur_conf;
        }
        Ok(next)
    }

    /// The CLI/wire name this spec round-trips through (`-stop` folded
    /// back into the name).
    pub fn wire_name(&self) -> &'static str {
        match self {
            SchedulerSpec::Asha {
                mode: DecisionMode::Promote,
                ..
            } => "asha",
            SchedulerSpec::Asha {
                mode: DecisionMode::Stop,
                ..
            } => "asha-stop",
            SchedulerSpec::Pasha {
                mode: DecisionMode::Promote,
                ..
            } => "pasha",
            SchedulerSpec::Pasha {
                mode: DecisionMode::Stop,
                ..
            } => "pasha-stop",
            SchedulerSpec::Lce { .. } => "lce",
            SchedulerSpec::Sh { .. } => "sh",
            SchedulerSpec::Hyperband { .. } => "hyperband",
            SchedulerSpec::FixedEpoch { .. } => "1-epoch",
            SchedulerSpec::RandomBaseline => "random",
        }
    }

    /// `r_min` where the scheduler has one.
    pub fn r_min(&self) -> Option<u32> {
        match *self {
            SchedulerSpec::Asha { r_min, .. }
            | SchedulerSpec::Pasha { r_min, .. }
            | SchedulerSpec::Lce { r_min, .. }
            | SchedulerSpec::Sh { r_min, .. }
            | SchedulerSpec::Hyperband { r_min, .. } => Some(r_min),
            _ => None,
        }
    }

    /// η where the scheduler has one.
    pub fn eta(&self) -> Option<u32> {
        match *self {
            SchedulerSpec::Asha { eta, .. }
            | SchedulerSpec::Pasha { eta, .. }
            | SchedulerSpec::Lce { eta, .. }
            | SchedulerSpec::Sh { eta, .. }
            | SchedulerSpec::Hyperband { eta, .. } => Some(eta),
            _ => None,
        }
    }

    /// The ranking function (PASHA variants only).
    pub fn ranking(&self) -> Option<&RankingSpec> {
        match self {
            SchedulerSpec::Pasha { ranking, .. } => Some(ranking),
            _ => None,
        }
    }

    pub fn validate(&self) -> Result<(), String> {
        if let Some(r_min) = self.r_min() {
            if r_min < 1 {
                return Err("field 'scheduler.r_min': must be >= 1".into());
            }
        }
        if let Some(eta) = self.eta() {
            if eta < 2 {
                return Err(format!("field 'scheduler.eta': must be >= 2 (got {eta})"));
            }
        }
        if let SchedulerSpec::FixedEpoch { epochs } = *self {
            if epochs < 1 {
                return Err("field 'scheduler.epochs': must be >= 1".into());
            }
        }
        if let SchedulerSpec::Lce {
            min_points,
            stop_quantile,
            confidence,
            ..
        } = *self
        {
            if min_points < 3 {
                return Err(format!(
                    "field 'scheduler.min_points': must be >= 3 (got {min_points})"
                ));
            }
            if !(stop_quantile.is_finite() && stop_quantile > 0.0 && stop_quantile < 1.0) {
                return Err(format!(
                    "field 'scheduler.stop_quantile': must be in (0, 1) (got {stop_quantile})"
                ));
            }
            if !(confidence.is_finite() && confidence > 0.0 && confidence < 1.0) {
                return Err(format!(
                    "field 'scheduler.confidence': must be in (0, 1) (got {confidence})"
                ));
            }
        }
        if let Some(ranking) = self.ranking() {
            validate_ranking(ranking)?;
        }
        Ok(())
    }

    /// Build the concrete [`SchedulerBuilder`]. `config_budget` sizes the
    /// synchronous-SH cohort; the other schedulers ignore it.
    pub fn builder(&self, config_budget: usize) -> Result<Box<dyn SchedulerBuilder>, String> {
        self.validate()?;
        Ok(match self.clone() {
            SchedulerSpec::Asha {
                r_min,
                eta,
                mode: DecisionMode::Promote,
            } => Box::new(AshaBuilder { r_min, eta }),
            SchedulerSpec::Asha {
                r_min,
                eta,
                mode: DecisionMode::Stop,
            } => Box::new(StopAshaBuilder { r_min, eta }),
            SchedulerSpec::Pasha {
                r_min,
                eta,
                mode: DecisionMode::Promote,
                ranking,
            } => Box::new(PashaBuilder {
                r_min,
                eta,
                ranking,
            }),
            SchedulerSpec::Pasha {
                r_min,
                eta,
                mode: DecisionMode::Stop,
                ranking,
            } => Box::new(StopPashaBuilder {
                r_min,
                eta,
                ranking,
            }),
            SchedulerSpec::Lce {
                r_min,
                eta,
                model,
                min_points,
                stop_quantile,
                confidence,
            } => Box::new(LceBuilder {
                r_min,
                eta,
                model,
                min_points: min_points as usize,
                stop_quantile,
                confidence,
            }),
            SchedulerSpec::Sh { r_min, eta } => Box::new(SyncShBuilder {
                r_min,
                eta,
                n0: config_budget,
            }),
            SchedulerSpec::Hyperband { r_min, eta } => Box::new(HyperbandBuilder { r_min, eta }),
            SchedulerSpec::FixedEpoch { epochs } => Box::new(FixedEpochBuilder { epochs }),
            SchedulerSpec::RandomBaseline => Box::new(RandomBaselineBuilder),
        })
    }
}

fn validate_ranking(r: &RankingSpec) -> Result<(), String> {
    let finite = |v: f64, field: &str| -> Result<(), String> {
        if v.is_finite() {
            Ok(())
        } else {
            Err(format!("field '{field}': must be finite"))
        }
    };
    match *r {
        RankingSpec::NoiseAdaptive { percentile } => {
            finite(percentile, "scheduler.ranking.percentile")?;
            if !(0.0..=100.0).contains(&percentile) {
                return Err(format!(
                    "field 'scheduler.ranking.percentile': must be in [0, 100] (got {percentile})"
                ));
            }
        }
        RankingSpec::Direct | RankingSpec::SoftMeanGap | RankingSpec::SoftMedianGap => {}
        RankingSpec::SoftFixed { epsilon } => {
            finite(epsilon, "scheduler.ranking.epsilon")?;
            if epsilon < 0.0 {
                return Err(format!(
                    "field 'scheduler.ranking.epsilon': must be >= 0 (got {epsilon})"
                ));
            }
        }
        RankingSpec::SoftSigma { mult } => {
            finite(mult, "scheduler.ranking.mult")?;
            if mult <= 0.0 {
                return Err(format!(
                    "field 'scheduler.ranking.mult': must be > 0 (got {mult})"
                ));
            }
        }
        RankingSpec::Rbo { p, t } | RankingSpec::Rrr { p, t } | RankingSpec::Arrr { p, t } => {
            finite(p, "scheduler.ranking.p")?;
            finite(t, "scheduler.ranking.t")?;
            if !(p > 0.0 && p <= 1.0) {
                return Err(format!(
                    "field 'scheduler.ranking.p': must be in (0, 1] (got {p})"
                ));
            }
        }
    }
    Ok(())
}

/// The proposal strategy, including its hyperparameters.
#[derive(Clone, Debug, PartialEq)]
pub enum SearcherSpec {
    /// Uniform sampling (the paper's main experiments).
    Random,
    /// MOBSTER-style GP + EI with explicit tuning constants, optionally
    /// warm-started from a persistent trial store.
    Bo {
        config: BoConfig,
        warm_start: Option<WarmStartSpec>,
    },
}

/// Default cap on embedded warm-start trials.
pub const WARM_START_DEFAULT_MAX_TRIALS: usize = 32;

/// Prior observations bootstrapping the BO searcher. Two states: an
/// unresolved *reference* to a trial store (`trials: None` — what
/// `--warm-start PATH` lowers to) and the *sealed* form with the selected
/// observations embedded (`trials: Some(..)` — what
/// `store::resolve_warm_start` produces). Only sealed specs build.
/// Sealing happens once, before a run or session is created, so journals
/// and snapshots are self-contained and recovery never re-reads the
/// store.
#[derive(Clone, Debug, PartialEq)]
pub struct WarmStartSpec {
    /// Path of the trial store the prior observations come from.
    pub from: String,
    /// Cap on the number of embedded trials.
    pub max_trials: usize,
    /// The sealed observations, rank-ordered best-first (this is the BO
    /// initial-design order); `None` while still a reference.
    pub trials: Option<Vec<WarmTrial>>,
}

impl WarmStartSpec {
    /// An unresolved reference to the store at `from`.
    pub fn new(from: &str, max_trials: usize) -> WarmStartSpec {
        WarmStartSpec {
            from: from.to_string(),
            max_trials,
            trials: None,
        }
    }
}

/// One embedded prior observation: positional configuration values (in
/// search-space order), the epoch it was observed at, and its metric.
#[derive(Clone, Debug, PartialEq)]
pub struct WarmTrial {
    pub config: Vec<f64>,
    pub epoch: u32,
    pub metric: f64,
}

impl SearcherSpec {
    /// Resolve a searcher wire name (BO gets the default
    /// hyperparameters) — the one parser every construction path shares.
    pub fn from_name(name: &str) -> Result<SearcherSpec, String> {
        match name {
            "random" => Ok(SearcherSpec::Random),
            "bo" => Ok(SearcherSpec::bo_default()),
            other => Err(format!("unknown searcher '{other}' (expected random|bo)")),
        }
    }

    /// BO with the default hyperparameters and no warm start.
    pub fn bo_default() -> SearcherSpec {
        SearcherSpec::Bo {
            config: BoConfig::default(),
            warm_start: None,
        }
    }

    /// BO (default hyperparameters) warm-started from the store at
    /// `from` — an unresolved reference until sealed.
    pub fn bo_warm(from: &str, max_trials: usize) -> SearcherSpec {
        SearcherSpec::Bo {
            config: BoConfig::default(),
            warm_start: Some(WarmStartSpec::new(from, max_trials)),
        }
    }

    pub fn wire_name(&self) -> &'static str {
        match self {
            SearcherSpec::Random => "random",
            SearcherSpec::Bo { .. } => "bo",
        }
    }

    /// The warm-start section, if any.
    pub fn warm_start(&self) -> Option<&WarmStartSpec> {
        match self {
            SearcherSpec::Bo {
                warm_start: Some(ws),
                ..
            } => Some(ws),
            _ => None,
        }
    }

    /// Seal the warm-start reference with the selected observations
    /// (no-op without a warm-start section).
    pub fn seal_warm_start(&mut self, trials: Vec<WarmTrial>) {
        if let SearcherSpec::Bo {
            warm_start: Some(ws),
            ..
        } = self
        {
            ws.trials = Some(trials);
        }
    }

    pub fn validate(&self) -> Result<(), String> {
        let SearcherSpec::Bo {
            config: cfg,
            warm_start,
        } = self
        else {
            return Ok(());
        };
        if cfg.min_points < 1 {
            return Err("field 'searcher.min_points': must be >= 1".into());
        }
        if cfg.num_candidates < 1 {
            return Err("field 'searcher.num_candidates': must be >= 1".into());
        }
        if !(0.0..=1.0).contains(&cfg.random_fraction) {
            return Err(format!(
                "field 'searcher.random_fraction': must be in [0, 1] (got {})",
                cfg.random_fraction
            ));
        }
        for (v, field) in [
            (cfg.lengthscale, "searcher.lengthscale"),
            (cfg.signal_var, "searcher.signal_var"),
            (cfg.noise_var, "searcher.noise_var"),
        ] {
            if !(v.is_finite() && v > 0.0) {
                return Err(format!("field '{field}': must be > 0 (got {v})"));
            }
        }
        if let Some(ws) = warm_start {
            if ws.from.is_empty() {
                return Err(
                    "field 'searcher.warm_start.from': must be a non-empty store path".into(),
                );
            }
            if ws.max_trials < 1 {
                return Err("field 'searcher.warm_start.max_trials': must be >= 1".into());
            }
            for (i, t) in ws.trials.iter().flatten().enumerate() {
                if t.epoch < 1 {
                    return Err(format!(
                        "field 'searcher.warm_start.trials[{i}].epoch': must be >= 1"
                    ));
                }
                if !t.metric.is_finite() || t.config.iter().any(|v| !v.is_finite()) {
                    return Err(format!(
                        "field 'searcher.warm_start.trials[{i}]': values must be finite"
                    ));
                }
            }
        }
        Ok(())
    }

    /// Build the searcher for a repetition with scheduler seed
    /// `sched_seed` — the exact seed derivations `Tuner::run` has always
    /// used, so a served session reproduces the in-process run. The
    /// space decodes embedded warm-start configurations; an unresolved
    /// warm-start reference is an error (seal it first).
    pub fn build(
        &self,
        space: &SearchSpace,
        sched_seed: u64,
    ) -> Result<Box<dyn Searcher>, String> {
        Ok(match self {
            SearcherSpec::Random => Box::new(RandomSearcher::new(mix(&[sched_seed, 0x5EA2C4]))),
            SearcherSpec::Bo { config, warm_start } => {
                let mut bo = BoSearcher::with_config(mix(&[sched_seed, 0xB0]), config.clone());
                if let Some(ws) = warm_start {
                    let trials = ws.trials.as_ref().ok_or_else(|| {
                        "field 'searcher.warm_start': unresolved store reference (seal it \
                         with store::resolve_warm_start before building)"
                            .to_string()
                    })?;
                    let mut prior = Vec::with_capacity(trials.len());
                    for (i, t) in trials.iter().enumerate() {
                        let config = config_from_json(space, &Json::from(t.config.clone()))
                            .map_err(|e| {
                                format!("field 'searcher.warm_start.trials[{i}].config': {e}")
                            })?;
                        prior.push((config, t.epoch, t.metric));
                    }
                    bo.warm_start(prior);
                }
                Box::new(bo)
            }
        })
    }
}

/// Where trials physically execute for in-process runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExecBackendKind {
    /// The deterministic virtual-clock simulator (default).
    Sim,
    /// A wall-clock `std::thread` pool; results depend on completion
    /// order, so runs are not bit-reproducible.
    Pool,
}

impl ExecBackendKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            ExecBackendKind::Sim => "sim",
            ExecBackendKind::Pool => "pool",
        }
    }

    pub fn parse(s: &str) -> Option<ExecBackendKind> {
        match s {
            "sim" => Some(ExecBackendKind::Sim),
            "pool" => Some(ExecBackendKind::Pool),
            _ => None,
        }
    }
}

/// Execution shape: how many parallel workers, on which backend.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ExecSpec {
    /// Parallel asynchronous workers (paper: 4).
    pub workers: usize,
    pub backend: ExecBackendKind,
}

impl Default for ExecSpec {
    fn default() -> Self {
        ExecSpec {
            workers: 4,
            backend: ExecBackendKind::Sim,
        }
    }
}

impl ExecSpec {
    pub fn validate(&self) -> Result<(), String> {
        if self.workers < 1 {
            return Err("field 'exec.workers': must be >= 1".into());
        }
        Ok(())
    }
}

/// When the experiment stops: the paper's N-configuration budget, plus
/// optional epoch (drain semantics) and clock (halt semantics) budgets.
#[derive(Clone, Debug, PartialEq)]
pub struct StopRules {
    /// Candidate configurations to sample (paper: N = 256).
    pub config_budget: usize,
    /// Stop dispatching once this many epochs have been launched;
    /// in-flight work drains.
    pub epoch_budget: Option<u64>,
    /// Halt (cancelling in-flight work) once the clock passes this many
    /// seconds (virtual on the simulator, wall on the pool).
    pub time_budget: Option<f64>,
}

impl Default for StopRules {
    fn default() -> Self {
        StopRules {
            config_budget: 256,
            epoch_budget: None,
            time_budget: None,
        }
    }
}

impl StopRules {
    pub fn validate(&self) -> Result<(), String> {
        // Integers ride the JSON wire as f64; past 2^53 they serialize
        // inexactly and a journaled session could never be re-parsed.
        // Zero budgets stay legal: the pre-redesign CLI accepted them
        // (`--budget 0` terminates immediately with no best config) and
        // legacy journals may carry them.
        const MAX_EXACT: u64 = 1 << 53;
        if self.config_budget as u64 > MAX_EXACT {
            return Err("field 'stop.config_budget': must be <= 2^53".into());
        }
        if let Some(e) = self.epoch_budget {
            if e > MAX_EXACT {
                return Err("field 'stop.epoch_budget': must be <= 2^53".into());
            }
        }
        if let Some(t) = self.time_budget {
            if !(t.is_finite() && t > 0.0) {
                return Err(format!(
                    "field 'stop.time_budget': must be > 0 seconds (got {t})"
                ));
            }
        }
        Ok(())
    }
}

/// One complete, versioned experiment description — see the module docs.
#[derive(Clone, Debug, PartialEq)]
pub struct ExperimentSpec {
    pub bench: BenchSpec,
    pub scheduler: SchedulerSpec,
    pub searcher: SearcherSpec,
    pub exec: ExecSpec,
    pub stop: StopRules,
    /// Scheduler/searcher seed (one repetition's `sched_seed`).
    pub seed: u64,
    /// Benchmark training seed workers evaluate with.
    pub bench_seed: u64,
}

impl Default for ExperimentSpec {
    /// The paper's protocol defaults: PASHA (noise-adaptive soft ranking,
    /// r = 1, η = 3) on NASBench201/CIFAR-10, random search, 4 simulated
    /// workers, N = 256.
    fn default() -> Self {
        ExperimentSpec {
            bench: BenchSpec::new("nas-cifar10"),
            scheduler: SchedulerSpec::Pasha {
                r_min: 1,
                eta: 3,
                mode: DecisionMode::Promote,
                ranking: RankingSpec::default(),
            },
            searcher: SearcherSpec::Random,
            exec: ExecSpec::default(),
            stop: StopRules::default(),
            seed: 0,
            bench_seed: 0,
        }
    }
}

impl ExperimentSpec {
    /// A spec for `bench` × `scheduler` (wire names) with every other
    /// knob at its default — the common construction in tests and tools.
    pub fn named(bench: &str, scheduler: &str) -> Result<ExperimentSpec, String> {
        let spec = ExperimentSpec {
            bench: BenchSpec::new(bench),
            scheduler: SchedulerSpec::from_name(scheduler, 1, 3, RankingSpec::default())?,
            ..ExperimentSpec::default()
        };
        spec.bench.validate()?;
        Ok(spec)
    }

    pub fn validate(&self) -> Result<(), String> {
        self.bench.validate()?;
        self.scheduler.validate()?;
        self.searcher.validate()?;
        self.exec.validate()?;
        self.stop.validate()?;
        // Seeds ride the JSON wire as numbers; beyond 2^53 they would
        // serialize inexactly and a journaled session could never be
        // re-parsed, so reject them up front.
        for (v, field) in [(self.seed, "seed"), (self.bench_seed, "bench_seed")] {
            if v > 1u64 << 53 {
                return Err(format!(
                    "field '{field}': must be <= 2^53 (seeds are exact JSON integers)"
                ));
            }
        }
        Ok(())
    }

    /// Serialize to the versioned v2 wire format (deterministic key
    /// order; what journals, snapshots, and `--spec` files carry).
    pub fn to_json(&self) -> Json {
        codec::to_json(self)
    }

    /// Serialize to the legacy v1 (flat) wire shape when the spec uses
    /// only knobs a pre-redesign client understood (`r_min = 1`, the
    /// default ranking and BO hyperparameters, default exec, no time
    /// budget); `None` otherwise. Session `status` responses prefer this
    /// form so old workers keep interoperating during a rolling upgrade.
    pub fn to_v1_compat_json(&self) -> Option<Json> {
        v1::to_v1_json(self)
    }

    /// Parse a spec. Strict: unknown keys and out-of-range values are
    /// errors naming the field. A payload without a `"version"` key is
    /// read as the legacy v1 (flat `SessionSpec`) shape and migrated.
    pub fn from_json(j: &Json) -> Result<ExperimentSpec, String> {
        let spec = if j.get("version").is_none() {
            v1::from_v1_json(j)?
        } else {
            codec::from_v2_json(j)?
        };
        spec.validate()?;
        Ok(spec)
    }

    /// Apply one `key.path=value` override (the CLI's `--set`). The
    /// value is parsed as JSON when possible (numbers, booleans,
    /// objects) and as a bare string otherwise; `scheduler.ranking`
    /// additionally accepts the CLI shorthand (`soft:0.025`, `rbo:0.9`,
    /// `plain`, …). The result is re-parsed strictly, so a typo'd path
    /// is an error naming the field.
    ///
    /// Paths that select an enum variant (`scheduler.name`,
    /// `searcher.name`, `scheduler.ranking.kind`) rebuild the whole
    /// sub-spec, carrying over the knobs the new variant shares —
    /// otherwise stale sibling keys from the old variant would fail the
    /// strict re-parse.
    pub fn set(&mut self, assignment: &str) -> Result<(), String> {
        let (path, value) = assignment
            .split_once('=')
            .ok_or_else(|| format!("--set expects key.path=value, got '{assignment}'"))?;
        let mut keys: Vec<&str> = path.split('.').collect();
        if keys.iter().any(|k| k.is_empty()) {
            return Err(format!("--set path '{path}' has an empty segment"));
        }
        match keys.as_slice() {
            ["scheduler", "name"] => {
                self.scheduler = self.scheduler.renamed(value)?;
                return self.validate();
            }
            ["searcher", "name"] => {
                self.searcher = SearcherSpec::from_name(value)
                    .map_err(|e| format!("field 'searcher.name': {e}"))?;
                return self.validate();
            }
            _ => {}
        }
        let vjson = if matches!(keys.as_slice(), ["scheduler", "ranking", "kind"]) {
            // replace the whole ranking object so knobs of the old kind
            // don't linger into the strict re-parse; the new kind's
            // parameters take their defaults
            keys.truncate(2);
            let mut o = Json::obj();
            o.set("kind", value);
            o
        } else if keys.last() == Some(&"ranking") {
            match parse_ranking(value) {
                Ok(r) => codec::ranking_to_json(&r),
                Err(_) => scalar_json(value),
            }
        } else {
            scalar_json(value)
        };
        let mut root = self.to_json();
        let mut cur = &mut root;
        for k in &keys[..keys.len() - 1] {
            cur = match cur {
                Json::Obj(m) => m.entry(k.to_string()).or_insert_with(Json::obj),
                _ => return Err(format!("field '{k}' in '{path}' is not an object")),
            };
        }
        match cur {
            Json::Obj(m) => {
                m.insert(keys[keys.len() - 1].to_string(), vjson);
            }
            _ => return Err(format!("field '{path}' is not settable (parent not an object)")),
        }
        *self = ExperimentSpec::from_json(&root)?;
        Ok(())
    }

    /// Build the deterministic ask/tell core this spec describes (the
    /// tuning service's session engine). Uses the same scheduler and
    /// searcher derivations as [`crate::tuner::Tuner::run`], so a
    /// single-worker session reproduces the in-process run exactly.
    pub fn build_core(&self) -> Result<AskTell, String> {
        self.validate()?;
        if self.stop.time_budget.is_some() {
            return Err(
                "field 'stop.time_budget': not supported for served (ask/tell) sessions".into(),
            );
        }
        // A served session is driven by however many external workers
        // connect; a non-default exec section would be silently dead
        // configuration, so refuse it rather than mislead.
        if self.exec != ExecSpec::default() {
            return Err(
                "field 'exec': served (ask/tell) sessions are driven by external workers — \
                 exec applies to in-process runs only (drop it or keep the defaults)"
                    .into(),
            );
        }
        let bench = self.bench.build()?;
        let builder = self.scheduler.builder(self.stop.config_budget)?;
        let scheduler = builder.build(bench.max_epochs(), self.seed);
        let searcher = self.searcher.build(bench.space(), self.seed)?;
        let mut rules: Vec<Box<dyn StoppingRule>> =
            vec![Box::new(ConfigBudget(self.stop.config_budget))];
        if let Some(e) = self.stop.epoch_budget {
            rules.push(Box::new(EpochBudget(e)));
        }
        Ok(AskTell::new(
            scheduler,
            searcher,
            bench.space().clone(),
            rules,
        ))
    }
}

fn scalar_json(value: &str) -> Json {
    crate::util::json::parse(value).unwrap_or_else(|_| Json::Str(value.to_string()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_spec_validates_and_round_trips() {
        let spec = ExperimentSpec::default();
        spec.validate().unwrap();
        let j = spec.to_json();
        assert_eq!(j.get("version").and_then(|v| v.as_f64()), Some(2.0));
        let back = ExperimentSpec::from_json(&j).unwrap();
        assert_eq!(spec, back);
    }

    #[test]
    fn named_resolves_wire_names() {
        let spec = ExperimentSpec::named("lcbench-Fashion-MNIST", "asha-stop").unwrap();
        assert_eq!(spec.scheduler.wire_name(), "asha-stop");
        assert!(ExperimentSpec::named("nope", "asha").is_err());
        assert!(ExperimentSpec::named("nas-cifar10", "nope").is_err());
    }

    #[test]
    fn bench_validation_names_the_field() {
        let err = BenchSpec::new("lcbench-NotADataset").validate().unwrap_err();
        assert!(err.contains("bench.name"), "{err}");
        assert!(err.contains("NotADataset"), "{err}");
        BenchSpec::new("lcbench-Fashion-MNIST").validate().unwrap();
    }

    #[test]
    fn out_of_range_errors_name_the_field() {
        let mut spec = ExperimentSpec::default();
        spec.stop.config_budget = 1 << 54; // inexact past the f64 wire
        let err = spec.validate().unwrap_err();
        assert!(err.contains("stop.config_budget"), "{err}");
        // the degenerate-but-legal legacy case stays accepted
        spec.stop.config_budget = 0;
        spec.validate().unwrap();

        let sched = SchedulerSpec::Asha {
            r_min: 1,
            eta: 1,
            mode: DecisionMode::Promote,
        };
        let err = sched.validate().unwrap_err();
        assert!(err.contains("scheduler.eta"), "{err}");

        let sched = SchedulerSpec::Pasha {
            r_min: 1,
            eta: 3,
            mode: DecisionMode::Promote,
            ranking: RankingSpec::SoftFixed { epsilon: -1.0 },
        };
        let err = sched.validate().unwrap_err();
        assert!(err.contains("scheduler.ranking.epsilon"), "{err}");

        // a seed beyond exact-f64 range could be journaled but never
        // re-parsed — rejected before it can be created at all
        let mut spec = ExperimentSpec::default();
        spec.seed = (1u64 << 53) + 2;
        let err = spec.validate().unwrap_err();
        assert!(err.contains("'seed'"), "{err}");
    }

    #[test]
    fn set_overrides_and_rejects_typos() {
        let mut spec = ExperimentSpec::default();
        spec.set("stop.config_budget=64").unwrap();
        assert_eq!(spec.stop.config_budget, 64);
        spec.set("scheduler.eta=4").unwrap();
        assert_eq!(spec.scheduler.eta(), Some(4));
        spec.set("scheduler.ranking=soft:0.025").unwrap();
        assert_eq!(
            spec.scheduler.ranking(),
            Some(&RankingSpec::SoftFixed { epsilon: 0.025 })
        );
        spec.set("bench.name=pd1-wmt").unwrap();
        assert_eq!(spec.bench.name, "pd1-wmt");
        let err = spec.set("stop.confg_budget=64").unwrap_err();
        assert!(err.contains("confg_budget"), "{err}");
        let err = spec.set("scheduler.eta=1").unwrap_err();
        assert!(err.contains("scheduler.eta"), "{err}");
        let err = spec.set("nonsense").unwrap_err();
        assert!(err.contains("key.path=value"), "{err}");
    }

    #[test]
    fn set_switches_enum_variants_cleanly() {
        // switching scheduler family keeps the shared knobs and drops
        // the ones the new family lacks (no stale-key parse errors)
        let mut spec = ExperimentSpec::default();
        spec.set("scheduler.eta=4").unwrap();
        spec.set("scheduler.name=asha-stop").unwrap();
        assert_eq!(
            spec.scheduler,
            SchedulerSpec::Asha {
                r_min: 1,
                eta: 4,
                mode: DecisionMode::Stop,
            }
        );
        // and back: pasha regains a ranking (the default)
        spec.set("scheduler.name=pasha").unwrap();
        assert_eq!(spec.scheduler.ranking(), Some(&RankingSpec::default()));
        assert_eq!(spec.scheduler.eta(), Some(4));
        assert!(spec.set("scheduler.name=sgd").is_err());

        // searcher family switches both ways
        spec.set("searcher.name=bo").unwrap();
        assert!(matches!(spec.searcher, SearcherSpec::Bo { .. }));
        spec.set("searcher.min_points=8").unwrap();
        spec.set("searcher.name=random").unwrap();
        assert_eq!(spec.searcher, SearcherSpec::Random);
        assert!(spec.set("searcher.name=gradient").is_err());

        // ranking-kind switches rebuild the ranking object from defaults
        spec.set("scheduler.ranking=rbo:0.9").unwrap();
        spec.set("scheduler.ranking.kind=plain").unwrap();
        assert_eq!(spec.scheduler.ranking(), Some(&RankingSpec::Direct));
        spec.set("scheduler.ranking.kind=soft").unwrap();
        assert_eq!(
            spec.scheduler.ranking(),
            Some(&RankingSpec::SoftFixed { epsilon: 0.0 })
        );

        // lce: family switch drops the ranking, keeps r_min/η, and its
        // curve-fit knobs are reachable through --set paths
        spec.set("scheduler.name=lce").unwrap();
        spec.set("scheduler.model=exp").unwrap();
        spec.set("scheduler.min_points=6").unwrap();
        spec.set("scheduler.stop_quantile=0.25").unwrap();
        assert_eq!(
            spec.scheduler,
            SchedulerSpec::Lce {
                r_min: 1,
                eta: 4,
                model: ModelChoice::Exp,
                min_points: 6,
                stop_quantile: 0.25,
                confidence: 0.9,
            }
        );
        let err = spec.set("scheduler.model=cubic").unwrap_err();
        assert!(err.contains("scheduler.model"), "{err}");
        let err = spec.set("scheduler.min_points=1").unwrap_err();
        assert!(err.contains("scheduler.min_points"), "{err}");
        // and back out: the curve-fit keys don't leak into pasha
        spec.set("scheduler.name=pasha").unwrap();
        assert_eq!(spec.scheduler.ranking(), Some(&RankingSpec::default()));
    }

    #[test]
    fn builder_names_match_legacy_factories() {
        let budget = 16;
        for (name, want) in [
            ("asha", "ASHA"),
            ("pasha", "PASHA"),
            ("asha-stop", "ASHA-stop"),
            ("pasha-stop", "PASHA-stop"),
            ("lce", "LCE-stop"),
            ("sh", "SuccessiveHalving"),
            ("hyperband", "Hyperband"),
            ("1-epoch", "One-epoch baseline"),
            ("random", "Random baseline"),
        ] {
            let spec = SchedulerSpec::from_name(name, 1, 3, RankingSpec::default()).unwrap();
            let built = spec.builder(budget).unwrap();
            assert_eq!(built.name(), want, "wire name {name}");
            assert_eq!(spec.wire_name(), name);
        }
    }

    #[test]
    fn build_core_rejects_in_process_only_knobs() {
        let spec = ExperimentSpec {
            stop: StopRules {
                time_budget: Some(10.0),
                ..StopRules::default()
            },
            ..ExperimentSpec::default()
        };
        let err = spec.build_core().unwrap_err();
        assert!(err.contains("stop.time_budget"), "{err}");

        let mut spec = ExperimentSpec::default();
        spec.exec.workers = 8;
        let err = spec.build_core().unwrap_err();
        assert!(err.contains("'exec'"), "{err}");
    }

    #[test]
    fn warm_start_specs_validate_and_seal() {
        let mut spec = ExperimentSpec::default();
        spec.searcher = SearcherSpec::bo_warm("store.jsonl", 8);
        spec.validate().unwrap();
        // unresolved references refuse to build
        let err = spec.build_core().unwrap_err();
        assert!(err.contains("unresolved"), "{err}");
        // sealed — even with zero matching trials — builds fine
        spec.searcher.seal_warm_start(vec![]);
        spec.build_core().unwrap();
        // an embedded trial is decoded against the benchmark's space
        spec.searcher.seal_warm_start(vec![WarmTrial {
            config: vec![3.0],
            epoch: 2,
            metric: 80.0,
        }]);
        spec.build_core().unwrap();
        // wrong arity errors by field
        spec.searcher.seal_warm_start(vec![WarmTrial {
            config: vec![3.0, 1.0],
            epoch: 2,
            metric: 80.0,
        }]);
        let err = spec.build_core().unwrap_err();
        assert!(err.contains("warm_start.trials[0].config"), "{err}");
        // invalid warm-start sections are named
        spec.searcher = SearcherSpec::bo_warm("", 8);
        let err = spec.validate().unwrap_err();
        assert!(err.contains("warm_start.from"), "{err}");
        spec.searcher = SearcherSpec::bo_warm("s.jsonl", 0);
        let err = spec.validate().unwrap_err();
        assert!(err.contains("warm_start.max_trials"), "{err}");
        spec.searcher = SearcherSpec::bo_warm("s.jsonl", 4);
        spec.searcher.seal_warm_start(vec![WarmTrial {
            config: vec![1.0],
            epoch: 0,
            metric: 1.0,
        }]);
        let err = spec.validate().unwrap_err();
        assert!(err.contains("trials[0].epoch"), "{err}");
    }

    #[test]
    fn renamed_carries_shared_knobs() {
        let one_epoch = SchedulerSpec::FixedEpoch { epochs: 5 };
        // same family: the epoch count survives a no-op rename
        assert_eq!(one_epoch.renamed("1-epoch").unwrap(), one_epoch);
        // cross-family renames carry r_min/eta/ranking
        let pasha = SchedulerSpec::Pasha {
            r_min: 2,
            eta: 4,
            mode: DecisionMode::Promote,
            ranking: RankingSpec::Rbo { p: 0.9, t: 0.5 },
        };
        assert_eq!(
            pasha.renamed("asha-stop").unwrap(),
            SchedulerSpec::Asha {
                r_min: 2,
                eta: 4,
                mode: DecisionMode::Stop,
            }
        );
        assert_eq!(pasha.renamed("pasha").unwrap(), pasha);

        // lce: same-family renames keep the curve-fit knobs, cross-family
        // renames into lce take the curve-fit defaults but carry r_min/η
        let lce = SchedulerSpec::Lce {
            r_min: 2,
            eta: 4,
            model: ModelChoice::Exp,
            min_points: 6,
            stop_quantile: 0.25,
            confidence: 0.8,
        };
        assert_eq!(lce.renamed("lce").unwrap(), lce);
        assert_eq!(
            lce.renamed("asha").unwrap(),
            SchedulerSpec::Asha {
                r_min: 2,
                eta: 4,
                mode: DecisionMode::Promote,
            }
        );
        assert_eq!(
            pasha.renamed("lce").unwrap(),
            SchedulerSpec::Lce {
                r_min: 2,
                eta: 4,
                model: ModelChoice::Auto,
                min_points: 4,
                stop_quantile: 0.5,
                confidence: 0.9,
            }
        );
    }

    #[test]
    fn lce_knobs_validate_by_field() {
        let mk = |min_points, stop_quantile, confidence| SchedulerSpec::Lce {
            r_min: 1,
            eta: 3,
            model: ModelChoice::Auto,
            min_points,
            stop_quantile,
            confidence,
        };
        mk(4, 0.5, 0.9).validate().unwrap();
        let err = mk(2, 0.5, 0.9).validate().unwrap_err();
        assert!(err.contains("scheduler.min_points"), "{err}");
        let err = mk(4, 1.0, 0.9).validate().unwrap_err();
        assert!(err.contains("scheduler.stop_quantile"), "{err}");
        let err = mk(4, f64::NAN, 0.9).validate().unwrap_err();
        assert!(err.contains("scheduler.stop_quantile"), "{err}");
        let err = mk(4, 0.5, 0.0).validate().unwrap_err();
        assert!(err.contains("scheduler.confidence"), "{err}");
    }
}
