//! The session-routing table: which serving backends exist, in what
//! order — the `ExecSpec`-style plain-struct-plus-versioned-codec that
//! `pasha route` reads (and re-reads during failover).
//!
//! A [`RouteSpec`] file is a tiny JSON document:
//!
//! ```json
//! {"version":1,"backends":["127.0.0.1:7171","127.0.0.1:7271"]}
//! ```
//!
//! Placement is *positional*: session `sid` is served by
//! `backends[fnv1a64(sid) % len]` (see
//! [`crate::service::replica::backend_for`]), so editing an entry
//! in place — the promotion runbook's "swap the dead leader's address
//! for the promoted follower's" — re-routes exactly that backend's
//! sessions and nothing else. Reordering or resizing the list reshuffles
//! placement and is only safe with no sessions in flight.

use crate::util::json::{self, Json};
use std::path::Path;

/// Current wire-format version written by [`RouteSpec::to_json`].
pub const ROUTE_VERSION: u32 = 1;

/// A validated routing table: one `host:port` per serving backend.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RouteSpec {
    pub backends: Vec<String>,
}

impl RouteSpec {
    pub fn new(backends: Vec<String>) -> RouteSpec {
        RouteSpec { backends }
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.backends.is_empty() {
            return Err("field 'backends': must list at least one backend".into());
        }
        for (i, b) in self.backends.iter().enumerate() {
            if b.trim().is_empty() {
                return Err(format!("field 'backends[{i}]': must not be empty"));
            }
            if !b.contains(':') {
                return Err(format!("field 'backends[{i}]': expected host:port, got {b:?}"));
            }
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        let backends = self.backends.iter().map(|b| Json::Str(b.clone())).collect();
        o.set("version", ROUTE_VERSION as f64)
            .set("backends", Json::Arr(backends));
        o
    }

    /// Strict parse: unknown keys, a missing/foreign version, and
    /// malformed entries are named errors, same stance as
    /// [`crate::spec::ExperimentSpec::from_json`].
    pub fn from_json(v: &Json) -> Result<RouteSpec, String> {
        let Json::Obj(pairs) = v else {
            return Err("routing table must be a JSON object".into());
        };
        for (k, _) in pairs {
            if k != "version" && k != "backends" {
                return Err(format!("unknown field '{k}' in routing table"));
            }
        }
        let version = v
            .get("version")
            .and_then(|x| x.as_f64())
            .ok_or("field 'version': required")?;
        if version != ROUTE_VERSION as f64 {
            return Err(format!("field 'version': expected {ROUTE_VERSION}, got {version}"));
        }
        let Some(Json::Arr(items)) = v.get("backends") else {
            return Err("field 'backends': required array".into());
        };
        let mut backends = Vec::with_capacity(items.len());
        for (i, item) in items.iter().enumerate() {
            match item.as_str() {
                Some(s) => backends.push(s.to_string()),
                None => return Err(format!("field 'backends[{i}]': must be a string")),
            }
        }
        let spec = RouteSpec { backends };
        spec.validate()?;
        Ok(spec)
    }

    /// Read and validate a table file.
    pub fn load(path: &Path) -> Result<RouteSpec, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("cannot read routing table {}: {e}", path.display()))?;
        let v = json::parse(text.trim())
            .map_err(|e| format!("routing table {}: {e}", path.display()))?;
        Self::from_json(&v).map_err(|e| format!("routing table {}: {e}", path.display()))
    }

    /// Write the table (one line, trailing newline) — what the failover
    /// runbook edits and the e2e rewrites at promotion time.
    pub fn save(&self, path: &Path) -> Result<(), String> {
        self.validate()?;
        let mut line = self.to_json().to_string_compact();
        line.push('\n');
        std::fs::write(path, line)
            .map_err(|e| format!("cannot write routing table {}: {e}", path.display()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_and_validation() {
        let spec = RouteSpec::new(vec!["127.0.0.1:7171".into(), "127.0.0.1:7271".into()]);
        spec.validate().unwrap();
        let back = RouteSpec::from_json(&spec.to_json()).unwrap();
        assert_eq!(back, spec);

        assert!(RouteSpec::new(vec![]).validate().is_err(), "empty table");
        assert!(
            RouteSpec::new(vec!["noport".into()]).validate().is_err(),
            "host:port enforced"
        );

        let bad = json::parse("{\"version\":1,\"backends\":[\"a:1\"],\"extra\":0}").unwrap();
        assert!(RouteSpec::from_json(&bad).unwrap_err().contains("extra"));
        let wrong_v = json::parse("{\"version\":9,\"backends\":[\"a:1\"]}").unwrap();
        assert!(RouteSpec::from_json(&wrong_v).is_err());
    }

    #[test]
    fn save_load_roundtrip() {
        let path = std::env::temp_dir().join(format!("pasha-route-{}.json", std::process::id()));
        let spec = RouteSpec::new(vec!["127.0.0.1:7171".into()]);
        spec.save(&path).unwrap();
        assert_eq!(RouteSpec::load(&path).unwrap(), spec);
        let _ = std::fs::remove_file(&path);
    }
}
