//! Migration from the v1 wire format — the flat `SessionSpec` shape that
//! PR 3/4-era journals and clients carry.
//!
//! v1 → v2 field mapping (also documented in the README):
//!
//! | v1 (flat)            | v2                                          |
//! |----------------------|---------------------------------------------|
//! | `bench` (string)     | `bench.name`                                |
//! | `scheduler` (string) | `scheduler.name` (+ `mode` for `-stop`)     |
//! | `eta`                | `scheduler.eta`                             |
//! | *(implicit)* `r=1`   | `scheduler.r_min = 1`                       |
//! | *(implicit)* ranking | `scheduler.ranking = {kind: noisy, 90}`     |
//! | `searcher` (string)  | `searcher.name` (BO hyperparameters default)|
//! | `seed`               | `seed`                                      |
//! | `bench_seed`         | `bench_seed`                                |
//! | `config_budget`      | `stop.config_budget`                        |
//! | `epoch_budget`       | `stop.epoch_budget`                         |
//! | *(implicit)* workers | `exec = {workers: 4, backend: sim}`         |
//!
//! The implicit values are exactly what the legacy
//! `tuner::scheduler_from_name` / `searcher_for` factories hardcoded, so
//! a migrated spec builds a byte-identical ask/tell core — every v1
//! journal and snapshot recovers unchanged.
//!
//! Parsing is strict (unlike the original `SessionSpec::from_json`,
//! which silently fell back to defaults): a typo'd key such as
//! `confg_budget` is an error naming the field.

use super::codec::Fields;
use super::{BenchSpec, ExecSpec, ExperimentSpec, SchedulerSpec, SearcherSpec, StopRules};
use crate::ranking::RankingSpec;
use crate::searcher::bo::BoConfig;
use crate::util::json::Json;

/// Serialize to the legacy v1 wire shape, when the spec is exactly
/// representable there: `r_min = 1`, the default ranking, default BO
/// hyperparameters, the default execution shape, and no time budget —
/// i.e. everything a pre-redesign client could have asked for. Returns
/// `None` for specs that use v2-only knobs. Session `status` responses
/// use this so pre-redesign workers keep interoperating with sessions
/// they could have created themselves.
pub(crate) fn to_v1_json(spec: &ExperimentSpec) -> Option<Json> {
    if spec.exec != ExecSpec::default() || spec.stop.time_budget.is_some() {
        return None;
    }
    let representable_scheduler = match &spec.scheduler {
        SchedulerSpec::Asha { r_min, .. }
        | SchedulerSpec::Sh { r_min, .. }
        | SchedulerSpec::Hyperband { r_min, .. } => *r_min == 1,
        SchedulerSpec::Pasha { r_min, ranking, .. } => {
            *r_min == 1 && *ranking == RankingSpec::default()
        }
        SchedulerSpec::FixedEpoch { epochs } => *epochs == 1,
        SchedulerSpec::RandomBaseline => true,
        // no v1 client ever spoke learning-curve extrapolation
        SchedulerSpec::Lce { .. } => false,
    };
    let representable_searcher = match &spec.searcher {
        SearcherSpec::Random => true,
        // warm starts are v2-only: a v1 client could neither express nor
        // rebuild one
        SearcherSpec::Bo { config, warm_start } => {
            *config == BoConfig::default() && warm_start.is_none()
        }
    };
    if !(representable_scheduler && representable_searcher) {
        return None;
    }
    let mut o = Json::obj();
    o.set("bench", spec.bench.name.as_str())
        .set("scheduler", spec.scheduler.wire_name())
        .set("eta", spec.scheduler.eta().unwrap_or(3))
        .set("searcher", spec.searcher.wire_name())
        .set("seed", spec.seed as f64)
        .set("bench_seed", spec.bench_seed as f64)
        .set("config_budget", spec.stop.config_budget);
    if let Some(e) = spec.stop.epoch_budget {
        o.set("epoch_budget", e as f64);
    }
    Some(o)
}

pub(crate) fn from_v1_json(j: &Json) -> Result<ExperimentSpec, String> {
    let mut f = Fields::new(j, "")?;
    let bench = f.str_or("bench", "nas-cifar10")?;
    let scheduler_name = f.str_or("scheduler", "pasha")?;
    let eta = f.u32_or("eta", 3)?;
    let searcher_name = f.str_or("searcher", "random")?;
    let seed = f.u64_or("seed", 0)?;
    let bench_seed = f.u64_or("bench_seed", 0)?;
    let config_budget = f.usize_or("config_budget", 256)?;
    let epoch_budget = f.opt_u64("epoch_budget")?;
    f.finish()?;
    let searcher = SearcherSpec::from_name(&searcher_name)
        .map_err(|e| format!("field 'searcher': {e}"))?;
    // `lce` post-dates the v1 wire format: `from_name` would happily
    // build it, but no legacy client could have created such a session,
    // so a v1 payload naming it is a corrupt/mislabeled document.
    if scheduler_name == "lce" {
        return Err(
            "field 'scheduler': 'lce' is a v2-only scheduler (send a v2 spec with \
             \"version\":2)"
                .to_string(),
        );
    }
    // r_min = 1 and the default (noise-adaptive) ranking are what the
    // legacy factories hardcoded for every v1 session.
    let scheduler = SchedulerSpec::from_name(&scheduler_name, 1, eta, RankingSpec::default())
        .map_err(|e| format!("field 'scheduler': {e}"))?;
    Ok(ExperimentSpec {
        bench: BenchSpec::new(&bench),
        scheduler,
        searcher,
        exec: ExecSpec::default(),
        stop: StopRules {
            config_budget,
            epoch_budget,
            time_budget: None,
        },
        seed,
        bench_seed,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spec::DecisionMode;
    use crate::util::json::parse;

    #[test]
    fn v1_payloads_migrate_with_legacy_defaults() {
        let j = parse(
            r#"{"bench":"lcbench-Fashion-MNIST","scheduler":"pasha-stop","eta":4,
                "searcher":"bo","seed":7,"bench_seed":1,"config_budget":99,
                "epoch_budget":1234}"#,
        )
        .unwrap();
        let spec = ExperimentSpec::from_json(&j).unwrap();
        assert_eq!(spec.bench.name, "lcbench-Fashion-MNIST");
        assert_eq!(
            spec.scheduler,
            SchedulerSpec::Pasha {
                r_min: 1,
                eta: 4,
                mode: DecisionMode::Stop,
                ranking: RankingSpec::default(),
            }
        );
        assert_eq!(spec.searcher, SearcherSpec::bo_default());
        assert_eq!(spec.seed, 7);
        assert_eq!(spec.bench_seed, 1);
        assert_eq!(spec.stop.config_budget, 99);
        assert_eq!(spec.stop.epoch_budget, Some(1234));
        assert_eq!(spec.stop.time_budget, None);
        assert_eq!(spec.exec.workers, 4);
    }

    #[test]
    fn v1_missing_fields_take_defaults_but_typos_error() {
        // sparse payloads keep working (old journals may omit fields)...
        let sparse = parse(r#"{"bench":"nas-cifar100"}"#).unwrap();
        let spec = ExperimentSpec::from_json(&sparse).unwrap();
        assert_eq!(spec.bench.name, "nas-cifar100");
        assert_eq!(spec.stop.config_budget, 256);
        assert!(spec.stop.epoch_budget.is_none());
        // ...but a typo'd key is no longer a silent default
        let typo = parse(r#"{"bench":"nas-cifar10","confg_budget":64}"#).unwrap();
        let err = ExperimentSpec::from_json(&typo).unwrap_err();
        assert!(err.contains("'confg_budget'"), "{err}");

        let bad = parse(r#"{"searcher":"gradient"}"#).unwrap();
        let err = ExperimentSpec::from_json(&bad).unwrap_err();
        assert!(err.contains("gradient"), "{err}");
    }

    #[test]
    fn v1_compat_emission_round_trips_or_abstains() {
        // representable spec: v1 bytes parse back to the same spec
        let mut spec = ExperimentSpec::named("lcbench-Fashion-MNIST", "pasha-stop").unwrap();
        spec.stop.config_budget = 40;
        spec.stop.epoch_budget = Some(99);
        spec.seed = 6;
        let v1 = spec.to_v1_compat_json().expect("v1-representable");
        assert_eq!(ExperimentSpec::from_json(&v1).unwrap(), spec);

        // v2-only knobs abstain instead of lying to old clients
        let mut v2_only = spec.clone();
        v2_only.set("scheduler.r-min=2").unwrap_err(); // typo'd path still errors
        v2_only.set("scheduler.r_min=2").unwrap();
        assert!(v2_only.to_v1_compat_json().is_none(), "r_min=2 is v2-only");
        let mut v2_only = spec.clone();
        v2_only.set("scheduler.ranking=soft:0.5").unwrap();
        assert!(v2_only.to_v1_compat_json().is_none(), "non-default ranking");
        let mut v2_only = spec.clone();
        v2_only.stop.time_budget = Some(10.0);
        assert!(v2_only.to_v1_compat_json().is_none(), "time budget");
        let mut v2_only = spec.clone();
        v2_only.searcher = SearcherSpec::bo_warm("s.jsonl", 4);
        assert!(v2_only.to_v1_compat_json().is_none(), "warm start is v2-only");
        let mut v2_only = spec.clone();
        v2_only.set("scheduler.name=lce").unwrap();
        assert!(v2_only.to_v1_compat_json().is_none(), "lce is v2-only");
        let mut v2_only = spec;
        v2_only.exec.workers = 2;
        assert!(v2_only.to_v1_compat_json().is_none(), "non-default exec");
    }

    #[test]
    fn v1_payload_cannot_name_lce() {
        // a versionless (v1) document claiming the v2-only scheduler is
        // mislabeled, not migratable — the error cites the field
        let j = parse(r#"{"bench":"nas-cifar10","scheduler":"lce","eta":3}"#).unwrap();
        let err = ExperimentSpec::from_json(&j).unwrap_err();
        assert!(err.contains("field 'scheduler'"), "{err}");
        assert!(err.contains("v2-only"), "{err}");
    }

    #[test]
    fn v1_and_v2_forms_of_the_same_spec_compare_equal() {
        let v1 = parse(
            r#"{"bench":"lcbench-Fashion-MNIST","scheduler":"asha","eta":3,
                "searcher":"random","seed":0,"bench_seed":0,"config_budget":8}"#,
        )
        .unwrap();
        let migrated = ExperimentSpec::from_json(&v1).unwrap();
        let reparsed = ExperimentSpec::from_json(&migrated.to_json()).unwrap();
        assert_eq!(migrated, reparsed);
    }
}
