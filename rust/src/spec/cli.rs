//! Lowering of CLI flags into an [`ExperimentSpec`] — shared by
//! `pasha run` and `pasha worker --create` so every flag combination and
//! every spec file land on the same construction path (and so the
//! equivalence of the two is testable from the library).

use super::{
    BenchSpec, ExecBackendKind, ExperimentSpec, SchedulerSpec, SearcherSpec, WarmStartSpec,
    WARM_START_DEFAULT_MAX_TRIALS,
};
use crate::ranking::RankingSpec;
use crate::searcher::bo::BoConfig;
use std::collections::HashMap;

/// The canonical set of CLI flags that lower into an [`ExperimentSpec`]:
/// everything [`apply_flag_overrides`] understands, plus `spec` (the
/// `--spec FILE` loader the CLI front-end handles). Commands validate
/// their flag sets against this one list so it cannot drift from the
/// lowering code next to it.
pub const SPEC_FLAGS: &[&str] = &[
    "spec",
    "bench",
    "scheduler",
    "r-min",
    "eta",
    "ranking",
    "searcher",
    "budget",
    "seed",
    "bench-seed",
    "workers",
    "backend",
    "epoch-budget",
    "time-budget",
    "warm-start",
    "warm-start-max",
];

/// Parse the `--ranking` shorthand into a [`RankingSpec`]:
///
/// ```text
/// plain | noisy | noisy:PCT | soft:EPS | sigma:MULT | mean-gap |
/// median-gap | rbo:P | rbo:P,T | rrr:P,T | arrr:P,T
/// ```
pub fn parse_ranking(s: &str) -> Result<RankingSpec, String> {
    let (kind, args) = match s.split_once(':') {
        Some((k, a)) => (k, Some(a)),
        None => (s, None),
    };
    let one = |args: Option<&str>, what: &str| -> Result<f64, String> {
        let a = args.ok_or_else(|| format!("ranking '{kind}' needs :{what}"))?;
        a.parse::<f64>()
            .map_err(|_| format!("ranking '{kind}': invalid {what} '{a}'"))
    };
    let pair = |args: Option<&str>, d0: f64, d1: f64| -> Result<(f64, f64), String> {
        match args {
            None => Ok((d0, d1)),
            Some(a) => {
                let mut it = a.splitn(2, ',');
                let p = it
                    .next()
                    .unwrap_or("")
                    .parse::<f64>()
                    .map_err(|_| format!("ranking '{kind}': invalid p in '{a}'"))?;
                let t = match it.next() {
                    None => d1,
                    Some(t) => t
                        .parse::<f64>()
                        .map_err(|_| format!("ranking '{kind}': invalid t in '{a}'"))?,
                };
                Ok((p, t))
            }
        }
    };
    let spec = match kind {
        "plain" | "direct" => RankingSpec::Direct,
        "noisy" => RankingSpec::NoiseAdaptive {
            percentile: match args {
                None => 90.0,
                Some(_) => one(args, "percentile")?,
            },
        },
        "soft" => RankingSpec::SoftFixed {
            epsilon: one(args, "epsilon")?,
        },
        "sigma" => RankingSpec::SoftSigma {
            mult: one(args, "multiple")?,
        },
        "mean-gap" => RankingSpec::SoftMeanGap,
        "median-gap" => RankingSpec::SoftMedianGap,
        "rbo" => {
            let (p, t) = pair(args, 0.5, 0.5)?;
            RankingSpec::Rbo { p, t }
        }
        "rrr" => {
            let (p, t) = pair(args, 0.5, 0.05)?;
            RankingSpec::Rrr { p, t }
        }
        "arrr" => {
            let (p, t) = pair(args, 1.0, 0.05)?;
            RankingSpec::Arrr { p, t }
        }
        other => {
            return Err(format!(
                "unknown ranking '{other}' (expected plain, noisy[:PCT], soft:EPS, \
                 sigma:MULT, mean-gap, median-gap, rbo:P[,T], rrr:P[,T], arrr:P[,T])"
            ));
        }
    };
    Ok(spec)
}

fn num_flag<T: std::str::FromStr>(
    flags: &HashMap<String, String>,
    name: &str,
) -> Result<Option<T>, String> {
    match flags.get(name) {
        None => Ok(None),
        Some(v) => v
            .parse::<T>()
            .map(Some)
            .map_err(|_| format!("invalid --{name} '{v}'")),
    }
}

/// Apply every recognized CLI flag onto `spec`, in place. Flags compose
/// with whatever the spec already holds (e.g. from `--spec exp.json`):
/// `--eta 4` alone re-derives the scheduler with its current name,
/// `r_min`, and ranking. The result is validated.
///
/// Recognized flags: `bench`, `scheduler`, `r-min`, `eta`, `ranking`,
/// `searcher`, `budget`, `seed`, `bench-seed`, `workers`, `backend`,
/// `epoch-budget`, `time-budget`.
pub fn apply_flag_overrides(
    spec: &mut ExperimentSpec,
    flags: &HashMap<String, String>,
) -> Result<(), String> {
    if let Some(b) = flags.get("bench") {
        spec.bench = BenchSpec::new(b);
    }
    let name = flags.get("scheduler").map(String::as_str);
    let r_min: Option<u32> = num_flag(flags, "r-min")?;
    let eta: Option<u32> = num_flag(flags, "eta")?;
    let ranking = match flags.get("ranking") {
        None => None,
        Some(r) => Some(parse_ranking(r)?),
    };
    if name.is_some() || r_min.is_some() || eta.is_some() || ranking.is_some() {
        // rename first (carries every shared knob, including a
        // fixed-epoch count), then overlay the explicitly-flagged knobs
        let renamed = match name {
            Some(n) => spec.scheduler.renamed(n)?,
            None => spec.scheduler.clone(),
        };
        let r_min = r_min.or_else(|| renamed.r_min()).unwrap_or(1);
        let eta = eta.or_else(|| renamed.eta()).unwrap_or(3);
        let ranking = ranking
            .or_else(|| renamed.ranking().cloned())
            .unwrap_or_default();
        spec.scheduler = match renamed {
            // no r_min/eta/ranking to overlay on these families
            SchedulerSpec::FixedEpoch { .. } | SchedulerSpec::RandomBaseline => renamed,
            // overlay r_min/eta without resetting the curve-fit knobs
            // (those are spec-file/`--set` territory, not flags)
            SchedulerSpec::Lce {
                model,
                min_points,
                stop_quantile,
                confidence,
                ..
            } => SchedulerSpec::Lce {
                r_min,
                eta,
                model,
                min_points,
                stop_quantile,
                confidence,
            },
            other => SchedulerSpec::from_name(other.wire_name(), r_min, eta, ranking)?,
        };
        // A flag the selected family cannot honor is an error, not dead
        // configuration. (`--eta` stays accepted-and-ignored for the
        // baselines: the legacy CLI always threaded it through.)
        if flags.contains_key("ranking") && spec.scheduler.ranking().is_none() {
            return Err(format!(
                "--ranking applies to the PASHA variants only (scheduler '{}' \
                 has no ranking function)",
                spec.scheduler.wire_name()
            ));
        }
        if flags.contains_key("r-min") && spec.scheduler.r_min().is_none() {
            return Err(format!(
                "--r-min does not apply to scheduler '{}'",
                spec.scheduler.wire_name()
            ));
        }
    }
    if let Some(s) = flags.get("searcher") {
        spec.searcher = SearcherSpec::from_name(s)?;
    }
    if let Some(path) = flags.get("warm-start") {
        let max = num_flag::<usize>(flags, "warm-start-max")?
            .unwrap_or(WARM_START_DEFAULT_MAX_TRIALS);
        let ws = Some(WarmStartSpec::new(path, max));
        spec.searcher = match spec.searcher.clone() {
            // warm starting implies a model-based searcher: plain random
            // sampling has no state to bootstrap, so it upgrades to BO
            // with the default hyperparameters
            SearcherSpec::Random => SearcherSpec::Bo {
                config: BoConfig::default(),
                warm_start: ws,
            },
            SearcherSpec::Bo { config, .. } => SearcherSpec::Bo {
                config,
                warm_start: ws,
            },
        };
    } else if flags.contains_key("warm-start-max") {
        return Err("--warm-start-max requires --warm-start".into());
    }
    if let Some(b) = num_flag::<usize>(flags, "budget")? {
        spec.stop.config_budget = b;
    }
    if let Some(s) = num_flag::<u64>(flags, "seed")? {
        spec.seed = s;
    }
    if let Some(s) = num_flag::<u64>(flags, "bench-seed")? {
        spec.bench_seed = s;
    }
    if let Some(w) = num_flag::<usize>(flags, "workers")? {
        spec.exec.workers = w;
    }
    if let Some(b) = flags.get("backend") {
        spec.exec.backend = ExecBackendKind::parse(b)
            .ok_or_else(|| format!("invalid --backend '{b}' (expected sim|pool)"))?;
    }
    if let Some(e) = num_flag::<u64>(flags, "epoch-budget")? {
        spec.stop.epoch_budget = Some(e);
    }
    if let Some(t) = num_flag::<f64>(flags, "time-budget")? {
        spec.stop.time_budget = Some(t);
    }
    spec.validate()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spec::DecisionMode;

    fn flags(pairs: &[(&str, &str)]) -> HashMap<String, String> {
        pairs
            .iter()
            .map(|(k, v)| (k.to_string(), v.to_string()))
            .collect()
    }

    #[test]
    fn ranking_shorthand_covers_the_paper_family() {
        assert_eq!(parse_ranking("plain").unwrap(), RankingSpec::Direct);
        assert_eq!(
            parse_ranking("noisy").unwrap(),
            RankingSpec::NoiseAdaptive { percentile: 90.0 }
        );
        assert_eq!(
            parse_ranking("noisy:75").unwrap(),
            RankingSpec::NoiseAdaptive { percentile: 75.0 }
        );
        assert_eq!(
            parse_ranking("soft:0.025").unwrap(),
            RankingSpec::SoftFixed { epsilon: 0.025 }
        );
        assert_eq!(
            parse_ranking("sigma:2").unwrap(),
            RankingSpec::SoftSigma { mult: 2.0 }
        );
        assert_eq!(parse_ranking("mean-gap").unwrap(), RankingSpec::SoftMeanGap);
        assert_eq!(
            parse_ranking("rbo:0.9").unwrap(),
            RankingSpec::Rbo { p: 0.9, t: 0.5 }
        );
        assert_eq!(
            parse_ranking("rbo:0.9,0.4").unwrap(),
            RankingSpec::Rbo { p: 0.9, t: 0.4 }
        );
        assert_eq!(
            parse_ranking("rrr:0.5,0.05").unwrap(),
            RankingSpec::Rrr { p: 0.5, t: 0.05 }
        );
        assert!(parse_ranking("soft").is_err());
        assert!(parse_ranking("wibble").is_err());
    }

    #[test]
    fn flags_lower_onto_the_spec() {
        let mut spec = ExperimentSpec::default();
        apply_flag_overrides(
            &mut spec,
            &flags(&[
                ("bench", "nas-cifar100"),
                ("scheduler", "pasha-stop"),
                ("r-min", "2"),
                ("eta", "4"),
                ("ranking", "soft:0.025"),
                ("searcher", "bo"),
                ("budget", "64"),
                ("seed", "5"),
                ("workers", "2"),
                ("epoch-budget", "500"),
            ]),
        )
        .unwrap();
        assert_eq!(spec.bench.name, "nas-cifar100");
        assert_eq!(
            spec.scheduler,
            SchedulerSpec::Pasha {
                r_min: 2,
                eta: 4,
                mode: DecisionMode::Stop,
                ranking: RankingSpec::SoftFixed { epsilon: 0.025 },
            }
        );
        assert!(matches!(spec.searcher, SearcherSpec::Bo { .. }));
        assert_eq!(spec.stop.config_budget, 64);
        assert_eq!(spec.seed, 5);
        assert_eq!(spec.exec.workers, 2);
        assert_eq!(spec.stop.epoch_budget, Some(500));
    }

    #[test]
    fn partial_scheduler_flags_compose_with_current_state() {
        let mut spec = ExperimentSpec::default();
        spec.set("scheduler.ranking=rbo:0.9").unwrap();
        // --eta alone must keep the name and ranking already in the spec
        apply_flag_overrides(&mut spec, &flags(&[("eta", "4")])).unwrap();
        assert_eq!(spec.scheduler.wire_name(), "pasha");
        assert_eq!(spec.scheduler.eta(), Some(4));
        assert_eq!(
            spec.scheduler.ranking(),
            Some(&RankingSpec::Rbo { p: 0.9, t: 0.5 })
        );
    }

    #[test]
    fn warm_start_flags_lower_to_a_reference() {
        // --warm-start alone upgrades random search to warm-started BO
        let mut spec = ExperimentSpec::default();
        apply_flag_overrides(&mut spec, &flags(&[("warm-start", "prior.jsonl")])).unwrap();
        assert_eq!(
            spec.searcher,
            SearcherSpec::bo_warm("prior.jsonl", WARM_START_DEFAULT_MAX_TRIALS)
        );
        // --warm-start composes with --searcher bo and --warm-start-max,
        // and the reference is unresolved (sealing happens at run/create)
        let mut spec = ExperimentSpec::default();
        apply_flag_overrides(
            &mut spec,
            &flags(&[
                ("searcher", "bo"),
                ("warm-start", "prior.jsonl"),
                ("warm-start-max", "5"),
            ]),
        )
        .unwrap();
        assert_eq!(spec.searcher, SearcherSpec::bo_warm("prior.jsonl", 5));
        assert!(spec.searcher.warm_start().unwrap().trials.is_none());
        // --warm-start-max without --warm-start is dead configuration
        let mut spec = ExperimentSpec::default();
        let err =
            apply_flag_overrides(&mut spec, &flags(&[("warm-start-max", "5")])).unwrap_err();
        assert!(err.contains("--warm-start-max"), "{err}");
    }

    #[test]
    fn lce_flags_compose_without_resetting_curve_knobs() {
        use crate::curvefit::ModelChoice;
        // knobs set through the spec surface survive flag overlays
        let mut spec = ExperimentSpec::default();
        spec.set("scheduler.name=lce").unwrap();
        spec.set("scheduler.model=exp").unwrap();
        spec.set("scheduler.min_points=6").unwrap();
        apply_flag_overrides(&mut spec, &flags(&[("r-min", "2"), ("eta", "4")])).unwrap();
        assert_eq!(
            spec.scheduler,
            SchedulerSpec::Lce {
                r_min: 2,
                eta: 4,
                model: ModelChoice::Exp,
                min_points: 6,
                stop_quantile: 0.5,
                confidence: 0.9,
            }
        );
        // and `--scheduler lce` from scratch takes the documented defaults
        let mut spec = ExperimentSpec::default();
        apply_flag_overrides(&mut spec, &flags(&[("scheduler", "lce"), ("eta", "4")]))
            .unwrap();
        assert_eq!(
            spec.scheduler,
            SchedulerSpec::Lce {
                r_min: 1,
                eta: 4,
                model: ModelChoice::Auto,
                min_points: 4,
                stop_quantile: 0.5,
                confidence: 0.9,
            }
        );
        // lce ranks by extrapolation, not a ranking function
        let mut spec = ExperimentSpec::default();
        let err = apply_flag_overrides(
            &mut spec,
            &flags(&[("scheduler", "lce"), ("ranking", "soft:0.5")]),
        )
        .unwrap_err();
        assert!(err.contains("--ranking"), "{err}");
    }

    #[test]
    fn invalid_flags_error_by_name() {
        let mut spec = ExperimentSpec::default();
        let err = apply_flag_overrides(&mut spec, &flags(&[("eta", "x")])).unwrap_err();
        assert!(err.contains("--eta"), "{err}");
        let err = apply_flag_overrides(&mut spec, &flags(&[("eta", "1")])).unwrap_err();
        assert!(err.contains("scheduler.eta"), "{err}");
        let err =
            apply_flag_overrides(&mut spec, &flags(&[("scheduler", "sgd")])).unwrap_err();
        assert!(err.contains("sgd"), "{err}");
    }

    #[test]
    fn flags_the_family_cannot_honor_are_errors() {
        // --ranking on a non-PASHA scheduler would be silently dead
        let mut spec = ExperimentSpec::default();
        let err = apply_flag_overrides(
            &mut spec,
            &flags(&[("scheduler", "asha"), ("ranking", "soft:0.5")]),
        )
        .unwrap_err();
        assert!(err.contains("--ranking"), "{err}");
        // --r-min on the baselines likewise
        let mut spec = ExperimentSpec::default();
        let err = apply_flag_overrides(
            &mut spec,
            &flags(&[("scheduler", "random"), ("r-min", "2")]),
        )
        .unwrap_err();
        assert!(err.contains("--r-min"), "{err}");
        // legacy compat: --eta is still accepted (and unused) there
        let mut spec = ExperimentSpec::default();
        apply_flag_overrides(&mut spec, &flags(&[("scheduler", "1-epoch"), ("eta", "3")]))
            .unwrap();
        assert_eq!(spec.scheduler.wire_name(), "1-epoch");
    }
}
