//! Per-session reporting for the tuning service: render the status
//! objects returned by `sessions`/`status` protocol commands as an
//! aligned table (the `pasha sessions` CLI output).

use crate::util::json::Json;
use crate::util::table::Table;

fn cell_str(status: &Json, key: &str) -> String {
    status
        .get(key)
        .and_then(|v| v.as_str())
        .unwrap_or("-")
        .to_string()
}

fn cell_num(status: &Json, key: &str) -> String {
    match status.get(key).and_then(|v| v.as_f64()) {
        Some(n) if n.fract() == 0.0 => format!("{}", n as i64),
        Some(n) => format!("{n:.2}"),
        None => "-".to_string(),
    }
}

/// One row per session: identity, progress counters, incumbent.
pub fn sessions_table(statuses: &[Json]) -> Table {
    let mut t = Table::new(
        "Registered tuning sessions",
        &[
            "Session", "Bench", "Scheduler", "Configs", "Jobs", "Epochs", "In-flight", "Stopped",
            "Paused", "Failed", "Max res", "Best",
        ],
    );
    for st in statuses {
        // v2 specs carry `bench: {name}`; v1 statuses had a bare string
        let bench_field = st.get("spec").and_then(|s| s.get("bench"));
        let bench = bench_field
            .and_then(|b| b.as_str())
            .or_else(|| {
                bench_field
                    .and_then(|b| b.get("name"))
                    .and_then(|n| n.as_str())
            })
            .unwrap_or("-")
            .to_string();
        let best = match st.get("best_metric").and_then(|v| v.as_f64()) {
            Some(m) => format!("{m:.2}"),
            None => "-".to_string(),
        };
        t.row(&[
            cell_str(st, "id"),
            bench,
            cell_str(st, "scheduler"),
            cell_num(st, "configs_sampled"),
            cell_num(st, "jobs_completed"),
            cell_num(st, "epochs_completed"),
            cell_num(st, "in_flight"),
            cell_num(st, "stopped_trials"),
            cell_num(st, "paused_trials"),
            cell_num(st, "failed_jobs"),
            cell_num(st, "max_resources"),
            best,
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::service::registry::Registry;
    use crate::spec::ExperimentSpec;

    #[test]
    fn renders_live_registry_statuses() {
        let reg = Registry::in_memory();
        let mut spec = ExperimentSpec::named("lcbench-Fashion-MNIST", "asha").unwrap();
        spec.stop.config_budget = 4;
        reg.create(spec.clone()).unwrap();
        reg.create(spec).unwrap();
        let table = sessions_table(&reg.statuses());
        assert_eq!(table.rows.len(), 2);
        let text = table.to_text();
        assert!(text.contains("s0000"), "{text}");
        assert!(text.contains("lcbench-Fashion-MNIST"), "{text}");
        assert!(text.contains("ASHA"), "{text}");
    }

    #[test]
    fn tolerates_missing_fields() {
        let sparse = crate::util::json::parse("{\"id\":\"x\"}").unwrap();
        let table = sessions_table(&[sparse]);
        assert_eq!(table.rows.len(), 1);
        assert!(table.rows[0][1..].iter().any(|c| c == "-"));
    }
}
