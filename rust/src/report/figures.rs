//! Regeneration of the paper's figures as CSV series / text diagrams.
//!
//! * Figure 1 — rank-stabilization trace of a live PASHA run;
//! * Figure 2 — soft-ranking list-of-lists on a concrete example;
//! * Figure 3 — learning curves of the top-3 of 256 sampled configs;
//! * Figure 4 — all 256 learning curves;
//! * Figure 5 — evolution of the estimated ε during tuning.

use crate::benchmarks::nasbench201::{NasBench201, Nb201Dataset, NUM_ARCHS};
use crate::benchmarks::Benchmark;
use crate::config::space::Config;
use crate::ranking::soft::soft_consistent;
use crate::scheduler::pasha::PashaBuilder;
use crate::tuner::{Tuner, TunerSpec};
use crate::util::rng::Rng;
use crate::util::table::series_csv;

/// Figure 1: run PASHA on CIFAR-10 and narrate each top-rung consistency
/// decision (stable → stop growing; unstable → one more rung).
pub fn figure1(budget: usize) -> String {
    let bench = NasBench201::cifar10();
    let spec = TunerSpec {
        config_budget: budget,
        ..Default::default()
    };
    let r = Tuner::run_with(&bench, &PashaBuilder::default(), &spec, 0, 0);
    let mut out = String::new();
    out.push_str("Figure 1 — PASHA rank-stabilization trace (NASBench201/cifar10)\n");
    out.push_str(&format!(
        "configs sampled: {}; growth decisions observed: {}\n",
        r.configs_sampled,
        r.eps_history.len()
    ));
    out.push_str(&format!(
        "final max resources: {} epochs (safety net: {})\n",
        r.max_resources,
        bench.max_epochs()
    ));
    out.push_str(&format!(
        "ranking stabilized => stopped {}x below the ASHA budget\n",
        bench.max_epochs() / r.max_resources.max(1)
    ));
    out
}

/// Figure 2: soft-ranking illustration. Returns the list-of-lists for a
/// concrete set of configuration scores and ε.
pub fn figure2(scores: &[f64], eps: f64) -> String {
    let mut idx: Vec<usize> = (0..scores.len()).collect();
    idx.sort_by(|&a, &b| scores[b].partial_cmp(&scores[a]).unwrap());
    let mut out = String::new();
    out.push_str(&format!(
        "Figure 2 — soft ranking with eps={eps} (scores sorted desc)\n"
    ));
    for (pos, &i) in idx.iter().enumerate() {
        let set: Vec<String> = idx
            .iter()
            .filter(|&&j| (scores[j] - scores[i]).abs() <= eps)
            .map(|&j| format!("c{j}({})", scores[j]))
            .collect();
        out.push_str(&format!("rank {pos}: [{}]\n", set.join(", ")));
    }
    // also demonstrate the consistency check semantics on itself
    let ranked: Vec<(usize, f64)> = idx.iter().map(|&i| (i, scores[i])).collect();
    let consistent = soft_consistent(&ranked, &ranked, eps);
    out.push_str(&format!("self-consistency (sanity): {consistent}\n"));
    out
}

/// Sample 256 architectures the way the experiments do.
fn sample_archs(seed: u64, n: usize) -> Vec<usize> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| rng.below(NUM_ARCHS as u64) as usize).collect()
}

/// Figure 3: per-epoch curves of the top-3 (by final accuracy) of a
/// 256-architecture sample. CSV: epoch, top1, top2, top3.
pub fn figure3(dataset: Nb201Dataset, seed: u64) -> String {
    let bench = NasBench201::new(dataset);
    let archs = sample_archs(seed, 256);
    let mut by_final: Vec<(usize, f64)> = archs
        .iter()
        .map(|&a| (a, bench.retrain_accuracy(&Config::cat(a), 0)))
        .collect();
    by_final.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
    let top3: Vec<usize> = by_final.iter().take(3).map(|&(a, _)| a).collect();
    let epochs: Vec<f64> = (1..=200).map(|e| e as f64).collect();
    let mut cols = vec![epochs];
    for &a in &top3 {
        cols.push(
            (1..=200u32)
                .map(|e| bench.accuracy_at(&Config::cat(a), e, 0))
                .collect(),
        );
    }
    series_csv(&["epoch", "top1", "top2", "top3"], &cols)
}

/// Figure 4: all 256 learning curves. CSV: epoch, c0..c255 (long format
/// would be 51k rows; wide format keeps the file tractable).
pub fn figure4(dataset: Nb201Dataset, seed: u64) -> String {
    let bench = NasBench201::new(dataset);
    let archs = sample_archs(seed, 256);
    let epochs: Vec<f64> = (1..=200).map(|e| e as f64).collect();
    let mut headers: Vec<String> = vec!["epoch".into()];
    let mut cols = vec![epochs];
    for (i, &a) in archs.iter().enumerate() {
        headers.push(format!("c{i}"));
        cols.push(
            (1..=200u32)
                .map(|e| bench.accuracy_at(&Config::cat(a), e, 0))
                .collect(),
        );
    }
    let header_refs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    series_csv(&header_refs, &cols)
}

/// Figure 5: ε evolution during PASHA tuning, one series per dataset.
/// CSV per dataset: update index, epsilon.
pub fn figure5(dataset: Nb201Dataset, budget: usize) -> String {
    let bench = NasBench201::new(dataset);
    let spec = TunerSpec {
        config_budget: budget,
        ..Default::default()
    };
    let r = Tuner::run_with(&bench, &PashaBuilder::default(), &spec, 0, 0);
    let idx: Vec<f64> = (0..r.eps_history.len()).map(|i| i as f64).collect();
    series_csv(&["update", "epsilon"], &[idx, r.eps_history.clone()])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn figure1_trace_mentions_stop() {
        let s = figure1(32);
        assert!(s.contains("final max resources"));
        assert!(s.contains("configs sampled: 32"));
    }

    #[test]
    fn figure2_groups_near_ties() {
        let s = figure2(&[70.0, 69.9, 50.0], 0.5);
        // c0 and c1 are within eps: both appear in rank-0's list
        let first_line = s.lines().nth(1).unwrap();
        assert!(first_line.contains("c0"), "{first_line}");
        assert!(first_line.contains("c1"), "{first_line}");
        assert!(!first_line.contains("c2"), "{first_line}");
    }

    #[test]
    fn figure3_csv_shape() {
        let csv = figure3(Nb201Dataset::Cifar10, 0);
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines[0], "epoch,top1,top2,top3");
        assert_eq!(lines.len(), 201);
        // top1's final accuracy should be near the benchmark ceiling
        let last: Vec<f64> = lines[200]
            .split(',')
            .map(|x| x.parse().unwrap())
            .collect();
        assert!(last[1] > 90.0, "top1 final {}", last[1]);
    }

    #[test]
    fn figure4_has_256_series() {
        let csv = figure4(Nb201Dataset::Cifar10, 0);
        let header = csv.lines().next().unwrap();
        assert_eq!(header.split(',').count(), 257);
    }

    #[test]
    fn figure5_epsilon_series_nonempty() {
        let csv = figure5(Nb201Dataset::Cifar100, 48);
        assert!(csv.lines().count() >= 2, "expected ε updates: {csv}");
    }
}
