//! Experiment registry: regenerates every table and figure of the paper.

pub mod experiments;
pub mod figures;
