//! Experiment registry: regenerates every table and figure of the paper,
//! plus reporting for the tuning service's live sessions.

pub mod experiments;
pub mod figures;
pub mod service;
