//! One function per paper table. Each regenerates the table's rows —
//! same approaches, same datasets, same columns — against the surrogate
//! substrates, at either paper scale (N=256, full seed grids) or a
//! reduced smoke scale for quick runs.
//!
//! Table generation fans the whole `approach × sched_seed × bench_seed`
//! grid across the machine's cores: every cell is an independent
//! deterministic simulation, results are regrouped by index, and the
//! emitted tables are identical to a serial run (the repetitions used to
//! run strictly serially — 15 at a time at paper scale — leaving every
//! other core idle).

use crate::benchmarks::lcbench::LcBench;
use crate::benchmarks::nasbench201::{NasBench201, Nb201Dataset};
use crate::benchmarks::pd1::Pd1;
use crate::benchmarks::Benchmark;
use crate::metrics::Row;
use crate::ranking::RankingSpec;
use crate::scheduler::asha::AshaBuilder;
use crate::scheduler::baselines::{FixedEpochBuilder, RandomBaselineBuilder};
use crate::scheduler::pasha::PashaBuilder;
use crate::scheduler::SchedulerBuilder;
use crate::spec::SearcherSpec;
use crate::tuner::{Tuner, TunerSpec};
use crate::util::parallel::{available_threads, par_map};
use crate::util::table::Table;

/// Repetition/budget scale of an experiment run.
#[derive(Clone, Debug)]
pub struct Scale {
    pub config_budget: usize,
    pub workers: usize,
    pub sched_seeds: Vec<u64>,
    pub bench_seeds_nas: Vec<u64>,
    pub bench_seeds_other: Vec<u64>,
}

impl Scale {
    /// The paper's protocol: N=256 configs, 4 workers, 5 scheduler seeds,
    /// 3 NASBench201 seeds (15 reps) / 1 seed elsewhere (5 reps).
    pub fn paper() -> Scale {
        Scale {
            config_budget: 256,
            workers: 4,
            sched_seeds: (0..5).collect(),
            bench_seeds_nas: (0..3).collect(),
            bench_seeds_other: vec![0],
        }
    }

    /// Reduced scale for smoke runs and CI.
    pub fn smoke() -> Scale {
        Scale {
            config_budget: 64,
            workers: 4,
            sched_seeds: vec![0, 1],
            bench_seeds_nas: vec![0],
            bench_seeds_other: vec![0],
        }
    }

    fn bench_seeds(&self, bench_name: &str) -> &[u64] {
        if bench_name.starts_with("NASBench201") {
            &self.bench_seeds_nas
        } else {
            &self.bench_seeds_other
        }
    }
}

/// An approach = a scheduler builder plus a searcher spec.
pub struct Approach {
    pub builder: Box<dyn SchedulerBuilder>,
    pub searcher: SearcherSpec,
    /// Optional display-name override (e.g. "MOBSTER" for ASHA+BO).
    pub label: Option<String>,
}

impl Approach {
    pub fn new(builder: Box<dyn SchedulerBuilder>) -> Approach {
        Approach {
            builder,
            searcher: SearcherSpec::Random,
            label: None,
        }
    }

    pub fn bo(builder: Box<dyn SchedulerBuilder>, label: &str) -> Approach {
        Approach {
            builder,
            searcher: SearcherSpec::bo_default(),
            label: Some(label.to_string()),
        }
    }

    fn name(&self) -> String {
        self.label.clone().unwrap_or_else(|| self.builder.name())
    }
}

/// The paper's standard baseline set: ASHA, PASHA, one-epoch, random.
pub fn standard_approaches(eta: u32) -> Vec<Approach> {
    vec![
        Approach::new(Box::new(AshaBuilder { r_min: 1, eta })),
        Approach::new(Box::new(PashaBuilder {
            r_min: 1,
            eta,
            ranking: RankingSpec::default(),
        })),
        Approach::new(Box::new(FixedEpochBuilder { epochs: 1 })),
        Approach::new(Box::new(RandomBaselineBuilder)),
    ]
}

/// Run a set of approaches on one benchmark and produce a paper-style
/// table. The first approach is the speedup reference (ASHA convention).
///
/// The full `approach × sched_seed × bench_seed` grid runs as one flat
/// work list over a scoped thread pool — maximum core utilization
/// without nested fan-out — and is regrouped by index afterwards, so the
/// table is byte-identical to a serial run.
pub fn compare(bench: &dyn Benchmark, approaches: &[Approach], scale: &Scale, title: &str) -> Table {
    let mut table = Table::new(
        title,
        &[
            "Approach",
            "Accuracy (%)",
            "Runtime",
            "Speedup factor",
            "Max resources",
        ],
    );
    let bench_seeds = scale.bench_seeds(&bench.name());
    let reps = scale.sched_seeds.len() * bench_seeds.len();
    let specs: Vec<TunerSpec> = approaches
        .iter()
        .map(|a| TunerSpec {
            workers: scale.workers,
            config_budget: scale.config_budget,
            searcher: a.searcher.clone(),
            extra_stop: Vec::new(),
        })
        .collect();
    // Flat grid, contiguous per approach so regrouping is a chunk.
    let mut cells: Vec<(usize, u64, u64)> = Vec::with_capacity(approaches.len() * reps);
    for (ai, _) in approaches.iter().enumerate() {
        for &ss in &scale.sched_seeds {
            for &bs in bench_seeds {
                cells.push((ai, ss, bs));
            }
        }
    }
    let results = par_map(&cells, available_threads(), |_, &(ai, ss, bs)| {
        Tuner::run_with(bench, approaches[ai].builder.as_ref(), &specs[ai], ss, bs)
    });
    let rows: Vec<Row> = results
        .chunks(reps)
        .zip(approaches)
        .map(|(chunk, a)| Row::from_results(&a.name(), chunk))
        .collect();
    let reference = rows[0].runtime.mean();
    for row in &rows {
        table.row(&row.cells(reference));
    }
    table
}

fn nas_all() -> Vec<NasBench201> {
    vec![
        NasBench201::cifar10(),
        NasBench201::cifar100(),
        NasBench201::imagenet16(),
    ]
}

/// Table 1: NASBench201 main results (ASHA/PASHA/one-epoch/random × 3
/// datasets).
pub fn table1(scale: &Scale) -> Vec<Table> {
    nas_all()
        .iter()
        .map(|b| {
            compare(
                b,
                &standard_approaches(3),
                scale,
                &format!("Table 1 — {}", b.name()),
            )
        })
        .collect()
}

/// Table 2: reduction factors η ∈ {2, 4} on CIFAR-100.
pub fn table2(scale: &Scale) -> Vec<Table> {
    let b = NasBench201::cifar100();
    [2u32, 4]
        .iter()
        .map(|&eta| {
            let approaches = vec![
                Approach::new(Box::new(AshaBuilder { r_min: 1, eta })),
                Approach::new(Box::new(PashaBuilder {
                    r_min: 1,
                    eta,
                    ranking: RankingSpec::default(),
                })),
            ];
            compare(
                &b,
                &approaches,
                scale,
                &format!("Table 2 — {} (eta={eta})", b.name()),
            )
        })
        .collect()
}

/// Table 3: Bayesian-optimization searchers — MOBSTER (ASHA+BO) vs
/// PASHA BO, all three NASBench201 datasets.
pub fn table3(scale: &Scale) -> Vec<Table> {
    nas_all()
        .iter()
        .map(|b| {
            let approaches = vec![
                Approach::bo(Box::new(AshaBuilder::default()), "MOBSTER"),
                Approach::bo(Box::new(PashaBuilder::default()), "PASHA BO"),
            ];
            compare(b, &approaches, scale, &format!("Table 3 — {}", b.name()))
        })
        .collect()
}

/// The full ranking-function sweep of Appendix C (Tables 9/10/11; Table 4
/// is the CIFAR-100 selection).
pub fn ranking_function_approaches() -> Vec<Approach> {
    let mut v = vec![
        Approach::new(Box::new(AshaBuilder::default())),
        Approach::new(Box::new(PashaBuilder::default())),
        Approach::new(Box::new(PashaBuilder::with_ranking(RankingSpec::Direct))),
    ];
    for eps in [0.01, 0.02, 0.025, 0.03, 0.05] {
        // NOTE: the paper's ε values are fractions of accuracy-in-[0,1];
        // our metrics are percentages, so scale by 100.
        v.push(Approach::new(Box::new(PashaBuilder::with_ranking(
            RankingSpec::SoftFixed {
                epsilon: eps * 100.0,
            },
        ))));
    }
    for mult in [1.0, 2.0, 3.0] {
        v.push(Approach::new(Box::new(PashaBuilder::with_ranking(
            RankingSpec::SoftSigma { mult },
        ))));
    }
    v.push(Approach::new(Box::new(PashaBuilder::with_ranking(
        RankingSpec::SoftMeanGap,
    ))));
    v.push(Approach::new(Box::new(PashaBuilder::with_ranking(
        RankingSpec::SoftMedianGap,
    ))));
    for p in [1.0, 0.5] {
        v.push(Approach::new(Box::new(PashaBuilder::with_ranking(
            RankingSpec::Rbo { p, t: 0.5 },
        ))));
    }
    for p in [1.0, 0.5] {
        v.push(Approach::new(Box::new(PashaBuilder::with_ranking(
            RankingSpec::Rrr { p, t: 0.05 },
        ))));
    }
    for p in [1.0, 0.5] {
        v.push(Approach::new(Box::new(PashaBuilder::with_ranking(
            RankingSpec::Arrr { p, t: 0.05 },
        ))));
    }
    v.push(Approach::new(Box::new(FixedEpochBuilder { epochs: 1 })));
    v.push(Approach::new(Box::new(RandomBaselineBuilder)));
    v
}

/// Tables 4/9/10/11: ranking functions on one NASBench201 dataset.
pub fn table_rankings(dataset: Nb201Dataset, scale: &Scale, table_no: u32) -> Table {
    let b = NasBench201::new(dataset);
    compare(
        &b,
        &ranking_function_approaches(),
        scale,
        &format!("Table {table_no} — ranking functions, {}", b.name()),
    )
}

/// Table 5/7: PD1 (WMT + ImageNet) with the k-epoch baseline family.
pub fn table5(scale: &Scale) -> Vec<Table> {
    [Pd1::wmt(), Pd1::imagenet()]
        .iter()
        .map(|b| {
            let approaches = vec![
                Approach::new(Box::new(AshaBuilder::default())),
                Approach::new(Box::new(PashaBuilder::default())),
                Approach::new(Box::new(FixedEpochBuilder { epochs: 1 })),
                Approach::new(Box::new(FixedEpochBuilder { epochs: 2 })),
                Approach::new(Box::new(FixedEpochBuilder { epochs: 3 })),
                Approach::new(Box::new(FixedEpochBuilder { epochs: 5 })),
                Approach::new(Box::new(RandomBaselineBuilder)),
            ];
            compare(b, &approaches, scale, &format!("Table 5/7 — {}", b.name()))
        })
        .collect()
}

/// Table 6: NASBench201 with the extra 2/3/5-epoch baselines.
pub fn table6(scale: &Scale) -> Vec<Table> {
    nas_all()
        .iter()
        .map(|b| {
            let approaches = vec![
                Approach::new(Box::new(AshaBuilder::default())),
                Approach::new(Box::new(PashaBuilder::default())),
                Approach::new(Box::new(FixedEpochBuilder { epochs: 1 })),
                Approach::new(Box::new(FixedEpochBuilder { epochs: 2 })),
                Approach::new(Box::new(FixedEpochBuilder { epochs: 3 })),
                Approach::new(Box::new(FixedEpochBuilder { epochs: 5 })),
                Approach::new(Box::new(RandomBaselineBuilder)),
            ];
            compare(b, &approaches, scale, &format!("Table 6 — {}", b.name()))
        })
        .collect()
}

/// Table 8: reduction factors on all three datasets.
pub fn table8(scale: &Scale) -> Vec<Table> {
    let mut out = Vec::new();
    for b in nas_all() {
        for eta in [2u32, 4] {
            let approaches = vec![
                Approach::new(Box::new(AshaBuilder { r_min: 1, eta })),
                Approach::new(Box::new(PashaBuilder {
                    r_min: 1,
                    eta,
                    ranking: RankingSpec::default(),
                })),
            ];
            out.push(compare(
                &b,
                &approaches,
                scale,
                &format!("Table 8 — {} (eta={eta})", b.name()),
            ));
        }
    }
    out
}

/// Table 12: selected ranking functions on PD1.
pub fn table12(scale: &Scale) -> Vec<Table> {
    [Pd1::wmt(), Pd1::imagenet()]
        .iter()
        .map(|b| {
            let approaches = vec![
                Approach::new(Box::new(AshaBuilder::default())),
                Approach::new(Box::new(PashaBuilder::default())),
                Approach::new(Box::new(PashaBuilder::with_ranking(RankingSpec::Direct))),
                Approach::new(Box::new(PashaBuilder::with_ranking(
                    RankingSpec::SoftFixed { epsilon: 2.5 },
                ))),
                Approach::new(Box::new(PashaBuilder::with_ranking(
                    RankingSpec::SoftSigma { mult: 2.0 },
                ))),
                Approach::new(Box::new(PashaBuilder::with_ranking(RankingSpec::Rbo {
                    p: 0.5,
                    t: 0.5,
                }))),
                Approach::new(Box::new(PashaBuilder::with_ranking(RankingSpec::Rrr {
                    p: 0.5,
                    t: 0.05,
                }))),
                Approach::new(Box::new(FixedEpochBuilder { epochs: 1 })),
                Approach::new(Box::new(RandomBaselineBuilder)),
            ];
            compare(b, &approaches, scale, &format!("Table 12 — {}", b.name()))
        })
        .collect()
}

/// Table 13: LCBench — ASHA vs PASHA accuracy + speedup per dataset.
pub fn table13(scale: &Scale, max_datasets: usize) -> Table {
    let mut table = Table::new(
        "Table 13 — LCBench",
        &[
            "Dataset",
            "ASHA accuracy (%)",
            "PASHA accuracy (%)",
            "PASHA speedup",
        ],
    );
    for b in LcBench::all().into_iter().take(max_datasets) {
        let spec = TunerSpec {
            workers: scale.workers,
            config_budget: scale.config_budget,
            searcher: SearcherSpec::Random,
            extra_stop: Vec::new(),
        };
        let asha = Tuner::run_repeated_with(
            &b,
            &AshaBuilder::default(),
            &spec,
            &scale.sched_seeds,
            &scale.bench_seeds_other,
        );
        let pasha = Tuner::run_repeated_with(
            &b,
            &PashaBuilder::default(),
            &spec,
            &scale.sched_seeds,
            &scale.bench_seeds_other,
        );
        let ra = Row::from_results("ASHA", &asha);
        let rp = Row::from_results("PASHA", &pasha);
        let speedup = ra.runtime.mean() / rp.runtime.mean().max(1e-9);
        table.row(&[
            b.name().trim_start_matches("LCBench/").to_string(),
            ra.accuracy.cell(2),
            rp.accuracy.cell(2),
            format!("{:.1}x", speedup),
        ]);
    }
    table
}

/// Table 14: variable maximum resources (200 vs 50 epochs).
pub fn table14(scale: &Scale) -> Vec<Table> {
    let mut out = Vec::new();
    for ds in [
        Nb201Dataset::Cifar10,
        Nb201Dataset::Cifar100,
        Nb201Dataset::ImageNet16_120,
    ] {
        for epochs in [200u32, 50] {
            let b = NasBench201::with_max_epochs(ds, epochs);
            let approaches = vec![
                Approach::new(Box::new(AshaBuilder::default())),
                Approach::new(Box::new(PashaBuilder::default())),
            ];
            out.push(compare(
                &b,
                &approaches,
                scale,
                &format!("Table 14 — {} ({epochs} epochs)", b.name()),
            ));
        }
    }
    out
}

/// Table 15: percentile N ∈ {100, 95, 90, 80} for the ε estimate.
pub fn table15(scale: &Scale) -> Vec<Table> {
    nas_all()
        .iter()
        .map(|b| {
            let mut approaches = vec![Approach::new(Box::new(AshaBuilder::default()))];
            for n in [100.0, 95.0, 90.0, 80.0] {
                approaches.push(Approach {
                    builder: Box::new(PashaBuilder::with_ranking(RankingSpec::NoiseAdaptive {
                        percentile: n,
                    })),
                    searcher: SearcherSpec::Random,
                    label: Some(format!("PASHA N={n}%")),
                });
            }
            approaches.push(Approach::new(Box::new(FixedEpochBuilder { epochs: 1 })));
            approaches.push(Approach::new(Box::new(RandomBaselineBuilder)));
            compare(b, &approaches, scale, &format!("Table 15 — {}", b.name()))
        })
        .collect()
}

/// Promotion-type vs stopping-type ASHA/PASHA (Li et al. 2020 §3.1's
/// two rung-decision modes) on CIFAR-100 — the scenario family the
/// engine's decision layer unlocked.
pub fn ablation_stopping(scale: &Scale) -> Table {
    let b = NasBench201::cifar100();
    let approaches = vec![
        Approach::new(Box::new(AshaBuilder::default())),
        Approach::new(Box::new(PashaBuilder::default())),
        Approach::new(Box::new(crate::scheduler::stopping::StopAshaBuilder::default())),
        Approach::new(Box::new(crate::scheduler::stopping::StopPashaBuilder::default())),
    ];
    compare(
        &b,
        &approaches,
        scale,
        "Ablation — promotion vs stopping variants on NASBench201/cifar100",
    )
}

/// Ablation (DESIGN.md): PASHA vs synchronous SH and Hyperband.
pub fn ablation_schedulers(scale: &Scale) -> Table {
    let b = NasBench201::cifar100();
    let approaches = vec![
        Approach::new(Box::new(AshaBuilder::default())),
        Approach::new(Box::new(PashaBuilder::default())),
        Approach::new(Box::new(crate::scheduler::sh::SyncShBuilder {
            r_min: 1,
            eta: 3,
            n0: scale.config_budget,
        })),
        Approach::new(Box::new(crate::scheduler::hyperband::HyperbandBuilder {
            r_min: 1,
            eta: 3,
        })),
    ];
    compare(
        &b,
        &approaches,
        scale,
        "Ablation — scheduler family on NASBench201/cifar100",
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Scale {
        Scale {
            config_budget: 27,
            workers: 4,
            sched_seeds: vec![0],
            bench_seeds_nas: vec![0],
            bench_seeds_other: vec![0],
        }
    }

    #[test]
    fn table1_smoke_shape() {
        let tables = table1(&tiny());
        assert_eq!(tables.len(), 3);
        for t in &tables {
            assert_eq!(t.rows.len(), 4);
            assert_eq!(t.rows[0][0], "ASHA");
            assert_eq!(t.rows[1][0], "PASHA");
            assert_eq!(t.rows[0][3], "1.0x", "ASHA is the speedup reference");
            assert_eq!(t.rows[3][3], "N/A", "random baseline speedup is N/A");
        }
    }

    #[test]
    fn table2_uses_both_etas() {
        let tables = table2(&tiny());
        assert_eq!(tables.len(), 2);
        assert!(tables[0].title.contains("eta=2"));
        assert!(tables[1].title.contains("eta=4"));
    }

    #[test]
    fn table13_lcbench_rows() {
        let t = table13(&tiny(), 3);
        assert_eq!(t.rows.len(), 3);
        assert_eq!(t.rows[0][0], "APSFailure");
    }

    #[test]
    fn ranking_sweep_has_all_families() {
        let approaches = ranking_function_approaches();
        let names: Vec<String> = approaches.iter().map(|a| a.name()).collect();
        assert!(names.iter().any(|n| n == "PASHA"));
        assert!(names.iter().any(|n| n.contains("direct")));
        assert!(names.iter().any(|n| n.contains("sigma")));
        assert!(names.iter().any(|n| n.contains("RBO")));
        assert!(names.iter().any(|n| n.contains("ARRR")));
        assert!(names.len() >= 19);
    }

    #[test]
    fn stopping_ablation_rows() {
        let t = ablation_stopping(&tiny());
        assert_eq!(t.rows.len(), 4);
        assert_eq!(t.rows[0][0], "ASHA");
        assert_eq!(t.rows[2][0], "ASHA-stop");
        assert_eq!(t.rows[3][0], "PASHA-stop");
    }

    #[test]
    fn table14_truncated_budget_titles() {
        let ts = table14(&tiny());
        assert_eq!(ts.len(), 6);
        assert!(ts[0].title.contains("200 epochs"));
        assert!(ts[1].title.contains("50 epochs"));
    }
}
