//! Orchestration: searcher × scheduler × benchmark × executor.
//!
//! [`Tuner::run`] reproduces the paper's two-phase experimental protocol
//! (§5.1): phase 1 runs the optimizer until N = 256 candidate
//! configurations have been sampled and all dispatched work has drained;
//! phase 2 retrains the best identified configuration from scratch and
//! reports that accuracy. Runtime excludes the retraining (comparable
//! across optimizers) and includes validation evaluation time.

use crate::benchmarks::Benchmark;
use crate::config::space::Config;
use crate::executor::sim::{run_sim, SimStats};
use crate::executor::SurrogateEvaluator;
use crate::scheduler::SchedulerBuilder;
use crate::searcher::bo::BoSearcher;
use crate::searcher::random::RandomSearcher;
use crate::searcher::Searcher;
use crate::util::rng::mix;

/// Which proposal strategy the tuner uses.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum SearcherKind {
    Random,
    /// MOBSTER-style GP+EI (Table 3).
    Bo,
}

/// Experiment-level knobs (paper defaults).
#[derive(Clone, Debug)]
pub struct TunerSpec {
    /// Parallel asynchronous workers (paper: 4).
    pub workers: usize,
    /// Candidate configurations to sample (paper: N = 256).
    pub config_budget: usize,
    pub searcher: SearcherKind,
}

impl Default for TunerSpec {
    fn default() -> Self {
        TunerSpec {
            workers: 4,
            config_budget: 256,
            searcher: SearcherKind::Random,
        }
    }
}

/// Outcome of one tuning repetition.
#[derive(Clone, Debug)]
pub struct TuneResult {
    pub scheduler_name: String,
    pub best_config: Option<Config>,
    /// Best observed validation metric during tuning.
    pub best_metric: f64,
    /// Phase-2 accuracy: retrained from scratch (the tables' "Accuracy").
    pub retrain_accuracy: f64,
    /// Virtual wall-clock seconds of the tuning phase ("Runtime").
    pub runtime_seconds: f64,
    /// Largest number of epochs any configuration was trained
    /// ("Max resources").
    pub max_resources: u32,
    pub configs_sampled: usize,
    pub total_epochs: u64,
    pub jobs: usize,
    /// ε trajectory (Figure 5), when the scheduler records one.
    pub eps_history: Vec<f64>,
}

/// The tuner entry point.
pub struct Tuner;

impl Tuner {
    /// Run one repetition: `sched_seed` drives the searcher's sampling
    /// stream, `bench_seed` selects the benchmark's training seed
    /// (NASBench201 provides 3; the paper averages over both).
    pub fn run(
        bench: &dyn Benchmark,
        builder: &dyn SchedulerBuilder,
        spec: &TunerSpec,
        sched_seed: u64,
        bench_seed: u64,
    ) -> TuneResult {
        let mut scheduler = builder.build(bench.max_epochs(), sched_seed);
        let mut searcher: Box<dyn Searcher> = match spec.searcher {
            SearcherKind::Random => Box::new(RandomSearcher::new(mix(&[sched_seed, 0x5EA2C4]))),
            SearcherKind::Bo => Box::new(BoSearcher::new(mix(&[sched_seed, 0xB0]))),
        };
        let mut evaluator = SurrogateEvaluator {
            bench,
            bench_seed,
        };
        let stats: SimStats = run_sim(
            scheduler.as_mut(),
            searcher.as_mut(),
            bench.space(),
            spec.config_budget,
            spec.workers,
            &mut evaluator,
        );
        let best = scheduler.best();
        let retrain_accuracy = best
            .as_ref()
            .map(|b| bench.retrain_accuracy(&b.config, bench_seed))
            .unwrap_or(f64::NAN);
        TuneResult {
            scheduler_name: builder.name(),
            best_metric: best.as_ref().map(|b| b.metric).unwrap_or(f64::NAN),
            best_config: best.map(|b| b.config),
            retrain_accuracy,
            runtime_seconds: stats.runtime_seconds,
            max_resources: scheduler.max_resources_used(),
            configs_sampled: stats.configs_sampled,
            total_epochs: stats.total_epochs,
            jobs: stats.jobs,
            eps_history: scheduler.epsilon_history().to_vec(),
        }
    }

    /// Run `sched_seeds × bench_seeds` repetitions (the paper's NAS
    /// experiments use 5 scheduler × 3 benchmark seeds = 15).
    pub fn run_repeated(
        bench: &dyn Benchmark,
        builder: &dyn SchedulerBuilder,
        spec: &TunerSpec,
        sched_seeds: &[u64],
        bench_seeds: &[u64],
    ) -> Vec<TuneResult> {
        let mut out = Vec::with_capacity(sched_seeds.len() * bench_seeds.len());
        for &ss in sched_seeds {
            for &bs in bench_seeds {
                out.push(Self::run(bench, builder, spec, ss, bs));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::benchmarks::nasbench201::NasBench201;
    use crate::benchmarks::pd1::Pd1;
    use crate::scheduler::asha::AshaBuilder;
    use crate::scheduler::baselines::{FixedEpochBuilder, RandomBaselineBuilder};
    use crate::scheduler::pasha::PashaBuilder;
    use crate::util::stats;

    fn small_spec() -> TunerSpec {
        TunerSpec {
            workers: 4,
            config_budget: 64,
            searcher: SearcherKind::Random,
        }
    }

    #[test]
    fn asha_vs_pasha_shape_on_cifar100() {
        // The headline claim at reduced scale: PASHA ≈ ASHA accuracy with
        // materially less runtime. (CIFAR-100 — its wide τ spread makes the
        // early-stopping signal robust even at budget 64; CIFAR-10 needs
        // the full N=256 to separate, see tests/paper_shape.rs.)
        let bench = NasBench201::cifar100();
        let spec = small_spec();
        let seeds = [0u64, 1, 2];
        let asha: Vec<TuneResult> = seeds
            .iter()
            .map(|&s| Tuner::run(&bench, &AshaBuilder::default(), &spec, s, 0))
            .collect();
        let pasha: Vec<TuneResult> = seeds
            .iter()
            .map(|&s| Tuner::run(&bench, &PashaBuilder::default(), &spec, s, 0))
            .collect();
        let asha_acc = stats::mean(&asha.iter().map(|r| r.retrain_accuracy).collect::<Vec<_>>());
        let pasha_acc =
            stats::mean(&pasha.iter().map(|r| r.retrain_accuracy).collect::<Vec<_>>());
        let asha_rt = stats::mean(&asha.iter().map(|r| r.runtime_seconds).collect::<Vec<_>>());
        let pasha_rt =
            stats::mean(&pasha.iter().map(|r| r.runtime_seconds).collect::<Vec<_>>());
        assert!(
            (asha_acc - pasha_acc).abs() < 2.5,
            "accuracy parity: asha {asha_acc:.2} pasha {pasha_acc:.2}"
        );
        assert!(
            pasha_rt < asha_rt * 0.75,
            "speedup: pasha {pasha_rt:.0}s vs asha {asha_rt:.0}s"
        );
    }

    #[test]
    fn baselines_ordering_on_cifar100() {
        // random < one-epoch < {ASHA, PASHA} in accuracy (paper Table 1).
        let bench = NasBench201::cifar100();
        let spec = small_spec();
        let acc = |b: &dyn SchedulerBuilder| {
            let rs: Vec<f64> = (0..3)
                .map(|s| Tuner::run(&bench, b, &spec, s, 0).retrain_accuracy)
                .collect();
            stats::mean(&rs)
        };
        let random = acc(&RandomBaselineBuilder);
        let one_epoch = acc(&FixedEpochBuilder { epochs: 1 });
        let asha = acc(&AshaBuilder::default());
        assert!(random < one_epoch, "random {random:.1} < 1ep {one_epoch:.1}");
        assert!(
            one_epoch < asha + 1.0,
            "1ep {one_epoch:.1} below asha {asha:.1}"
        );
    }

    #[test]
    fn budget_and_drain_invariants() {
        let bench = NasBench201::cifar10();
        let spec = small_spec();
        let r = Tuner::run(&bench, &PashaBuilder::default(), &spec, 0, 0);
        assert_eq!(r.configs_sampled, 64);
        assert!(r.max_resources <= bench.max_epochs());
        assert!(r.best_config.is_some());
        assert!(r.retrain_accuracy > 0.0);
    }

    #[test]
    fn run_repeated_produces_grid() {
        let bench = NasBench201::cifar10();
        let spec = TunerSpec {
            config_budget: 16,
            ..small_spec()
        };
        let rs = Tuner::run_repeated(
            &bench,
            &FixedEpochBuilder { epochs: 1 },
            &spec,
            &[0, 1],
            &[0, 1, 2],
        );
        assert_eq!(rs.len(), 6);
    }

    #[test]
    fn bo_searcher_runs_end_to_end() {
        let bench = NasBench201::cifar10();
        let spec = TunerSpec {
            searcher: SearcherKind::Bo,
            config_budget: 32,
            ..small_spec()
        };
        let r = Tuner::run(&bench, &PashaBuilder::default(), &spec, 0, 0);
        assert!(r.retrain_accuracy > 50.0, "BO run sane: {}", r.retrain_accuracy);
    }

    #[test]
    fn pd1_wmt_massive_speedup_shape() {
        // WMT has 8 rung levels: PASHA's early stop must buy a large factor.
        let bench = Pd1::wmt();
        let spec = TunerSpec {
            config_budget: 48,
            ..small_spec()
        };
        let asha = Tuner::run(&bench, &AshaBuilder::default(), &spec, 1, 0);
        let pasha = Tuner::run(&bench, &PashaBuilder::default(), &spec, 1, 0);
        assert!(
            pasha.runtime_seconds * 2.0 < asha.runtime_seconds,
            "pasha {} vs asha {}",
            pasha.runtime_seconds,
            asha.runtime_seconds
        );
        assert!(pasha.max_resources < asha.max_resources);
    }
}
