//! Orchestration: searcher × scheduler × benchmark × engine.
//!
//! [`Tuner::run`] takes a declarative [`ExperimentSpec`] — the one
//! construction path shared with the CLI and the tuning service — and
//! reproduces the paper's two-phase experimental protocol (§5.1):
//! phase 1 runs the optimizer until N = 256 candidate configurations
//! have been sampled and all dispatched work has drained; phase 2
//! retrains the best identified configuration from scratch and reports
//! that accuracy. Runtime excludes the retraining (comparable across
//! optimizers) and includes validation evaluation time.
//!
//! [`Tuner::run_with`] is the lower-level entry point over
//! already-built parts (benchmark + scheduler builder + [`TunerSpec`]),
//! used by the report grid so repetitions can share one benchmark
//! instance. Termination is expressed through the engine's pluggable
//! stopping rules: the classic config budget always applies, and
//! [`StopSpec`] adds epoch/clock budgets on top.
//! [`Tuner::run_repeated_with`] fans the `sched_seeds × bench_seeds`
//! repetition grid across a scoped thread pool — every repetition is an
//! independent deterministic simulation, so the results are identical
//! to the serial driver ([`Tuner::run_repeated_serial`]), just several
//! times faster on multi-core machines.

use crate::benchmarks::Benchmark;
use crate::config::space::Config;
use crate::executor::engine::{ClockBudget, ConfigBudget, EpochBudget, StoppingRule};
use crate::executor::pool::{PoolBackend, SharedSurrogate};
use crate::executor::sim::{SimBackend, SimStats};
use crate::executor::{run_engine, SurrogateEvaluator};
use crate::scheduler::{Scheduler, SchedulerBuilder, TrialInfo};
use crate::searcher::Searcher;
use crate::spec::{ExecBackendKind, ExperimentSpec, SearcherSpec};
use crate::store::{self, StoreSpec};
use crate::util::parallel::{available_threads, par_map};
use std::sync::Arc;

/// Which proposal strategy the tuner uses, by wire name. Kept for the
/// legacy construction paths; [`SearcherSpec`] is the canonical form and
/// additionally carries the BO hyperparameters.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum SearcherKind {
    Random,
    /// MOBSTER-style GP+EI (Table 3).
    Bo,
}

impl SearcherKind {
    pub fn parse(s: &str) -> Option<SearcherKind> {
        match s {
            "random" => Some(SearcherKind::Random),
            "bo" => Some(SearcherKind::Bo),
            _ => None,
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            SearcherKind::Random => "random",
            SearcherKind::Bo => "bo",
        }
    }

    /// The canonical spec this kind lowers to (BO gets the default
    /// hyperparameters, exactly what the legacy factory built).
    pub fn to_spec(&self) -> SearcherSpec {
        match self {
            SearcherKind::Random => SearcherSpec::Random,
            SearcherKind::Bo => SearcherSpec::bo_default(),
        }
    }
}

/// Extra stopping rules layered on top of the config budget (cloneable
/// specs; the engine rules themselves are built per repetition).
#[derive(Clone, Debug, PartialEq)]
pub enum StopSpec {
    /// Stop launching new jobs once this many training epochs have been
    /// dispatched; in-flight work drains to completion.
    EpochBudget(u64),
    /// Halt once the clock (virtual seconds on the simulator) passes
    /// this many seconds.
    ClockBudget(f64),
}

impl StopSpec {
    fn build(&self) -> Box<dyn StoppingRule> {
        match *self {
            StopSpec::EpochBudget(n) => Box::new(EpochBudget(n)),
            StopSpec::ClockBudget(s) => Box::new(ClockBudget(s)),
        }
    }
}

/// Experiment-level knobs for the lower-level [`Tuner::run_with`] entry
/// point (paper defaults). [`ExperimentSpec`] lowers into this.
#[derive(Clone, Debug)]
pub struct TunerSpec {
    /// Parallel asynchronous workers (paper: 4).
    pub workers: usize,
    /// Candidate configurations to sample (paper: N = 256).
    pub config_budget: usize,
    pub searcher: SearcherSpec,
    /// Additional stopping rules (empty = classic N-config protocol).
    pub extra_stop: Vec<StopSpec>,
}

impl Default for TunerSpec {
    fn default() -> Self {
        TunerSpec {
            workers: 4,
            config_budget: 256,
            searcher: SearcherSpec::Random,
            extra_stop: Vec::new(),
        }
    }
}

impl From<&ExperimentSpec> for TunerSpec {
    /// Lower the execution/stopping slice of an experiment spec (same
    /// rule order the CLI has always used: epoch budget, then clock).
    fn from(spec: &ExperimentSpec) -> TunerSpec {
        let mut extra_stop = Vec::new();
        if let Some(e) = spec.stop.epoch_budget {
            extra_stop.push(StopSpec::EpochBudget(e));
        }
        if let Some(t) = spec.stop.time_budget {
            extra_stop.push(StopSpec::ClockBudget(t));
        }
        TunerSpec {
            workers: spec.exec.workers,
            config_budget: spec.stop.config_budget,
            searcher: spec.searcher.clone(),
            extra_stop,
        }
    }
}

impl TunerSpec {
    fn rules(&self) -> Vec<Box<dyn StoppingRule>> {
        let mut rules: Vec<Box<dyn StoppingRule>> =
            vec![Box::new(ConfigBudget(self.config_budget))];
        rules.extend(self.extra_stop.iter().map(|s| s.build()));
        rules
    }
}

/// Outcome of one tuning repetition.
///
/// Equality is bitwise on the float fields (`to_bits`), so two runs that
/// both produced `NaN` placeholders (e.g. truncated before any result)
/// still compare equal — this is what the serial-vs-parallel grid
/// identity checks rely on.
#[derive(Clone, Debug)]
pub struct TuneResult {
    pub scheduler_name: String,
    pub best_config: Option<Config>,
    /// Best observed validation metric during tuning.
    pub best_metric: f64,
    /// Phase-2 accuracy: retrained from scratch (the tables' "Accuracy").
    pub retrain_accuracy: f64,
    /// Virtual wall-clock seconds of the tuning phase ("Runtime").
    pub runtime_seconds: f64,
    /// Largest number of epochs any configuration was trained
    /// ("Max resources").
    pub max_resources: u32,
    pub configs_sampled: usize,
    pub total_epochs: u64,
    pub jobs: usize,
    /// In-flight jobs cancelled (stopping rules / stop decisions).
    pub cancelled_jobs: usize,
    /// Trials terminated by stopping-type scheduler decisions.
    pub stopped_trials: usize,
    /// ε trajectory (Figure 5), when the scheduler records one.
    pub eps_history: Vec<f64>,
}

impl PartialEq for TuneResult {
    fn eq(&self, other: &Self) -> bool {
        fn feq(a: f64, b: f64) -> bool {
            a.to_bits() == b.to_bits()
        }
        self.scheduler_name == other.scheduler_name
            && self.best_config == other.best_config
            && feq(self.best_metric, other.best_metric)
            && feq(self.retrain_accuracy, other.retrain_accuracy)
            && feq(self.runtime_seconds, other.runtime_seconds)
            && self.max_resources == other.max_resources
            && self.configs_sampled == other.configs_sampled
            && self.total_epochs == other.total_epochs
            && self.jobs == other.jobs
            && self.cancelled_jobs == other.cancelled_jobs
            && self.stopped_trials == other.stopped_trials
            && self.eps_history.len() == other.eps_history.len()
            && self
                .eps_history
                .iter()
                .zip(&other.eps_history)
                .all(|(a, b)| feq(*a, *b))
    }
}

/// The tuner entry point.
pub struct Tuner;

impl Tuner {
    /// Run the experiment a spec describes: build the benchmark,
    /// scheduler, and searcher from it, execute one repetition with the
    /// spec's own seeds on the spec's backend, and report the result.
    /// On the default `sim` backend this is deterministic; the `pool`
    /// backend runs on real threads (wall-clock runtime, completion
    /// order not reproducible).
    pub fn run(spec: &ExperimentSpec) -> Result<TuneResult, String> {
        spec.validate()?;
        Self::require_sealed(spec)?;
        let bench = spec.bench.build()?;
        let builder = spec.scheduler.builder(spec.stop.config_budget)?;
        let tspec = TunerSpec::from(spec);
        match spec.exec.backend {
            ExecBackendKind::Sim => Ok(Self::run_with(
                bench.as_ref(),
                builder.as_ref(),
                &tspec,
                spec.seed,
                spec.bench_seed,
            )),
            ExecBackendKind::Pool => Ok(Self::run_on_pool(bench, builder.as_ref(), &tspec, spec)),
        }
    }

    /// The spec-driven repetition grid: one deterministic simulation per
    /// `(sched_seed, bench_seed)` pair, fanned across cores, overriding
    /// the spec's own seeds. Requires the `sim` backend (the pool is not
    /// reproducible, which is the grid's whole contract).
    pub fn run_repeated(
        spec: &ExperimentSpec,
        sched_seeds: &[u64],
        bench_seeds: &[u64],
    ) -> Result<Vec<TuneResult>, String> {
        spec.validate()?;
        Self::require_sealed(spec)?;
        if spec.exec.backend != ExecBackendKind::Sim {
            return Err("field 'exec.backend': repetition grids require the 'sim' backend".into());
        }
        let bench = spec.bench.build()?;
        let builder = spec.scheduler.builder(spec.stop.config_budget)?;
        Ok(Self::run_repeated_with(
            bench.as_ref(),
            builder.as_ref(),
            &TunerSpec::from(spec),
            sched_seeds,
            bench_seeds,
        ))
    }

    /// Run one repetition over already-built parts: `sched_seed` drives
    /// the searcher's sampling stream, `bench_seed` selects the
    /// benchmark's training seed (NASBench201 provides 3; the paper
    /// averages over both).
    pub fn run_with(
        bench: &dyn Benchmark,
        builder: &dyn SchedulerBuilder,
        spec: &TunerSpec,
        sched_seed: u64,
        bench_seed: u64,
    ) -> TuneResult {
        Self::run_with_trials(bench, builder, spec, sched_seed, bench_seed).0
    }

    /// [`Tuner::run_with`] that additionally returns the scheduler's
    /// per-trial records (config, dispatched epochs, learning curve) —
    /// the raw material the trial store ingests after a run.
    pub fn run_with_trials(
        bench: &dyn Benchmark,
        builder: &dyn SchedulerBuilder,
        spec: &TunerSpec,
        sched_seed: u64,
        bench_seed: u64,
    ) -> (TuneResult, Vec<TrialInfo>) {
        let mut scheduler = builder.build(bench.max_epochs(), sched_seed);
        let mut searcher: Box<dyn Searcher> = spec
            .searcher
            .build(bench.space(), sched_seed)
            .expect("searcher spec must build (seal warm starts before run_with)");
        let mut evaluator = SurrogateEvaluator { bench, bench_seed };
        let mut backend = SimBackend::new(spec.workers, &mut evaluator);
        let rules = spec.rules();
        let stats: SimStats = run_engine(
            scheduler.as_mut(),
            searcher.as_mut(),
            bench.space(),
            &rules,
            &mut backend,
        );
        let trials = scheduler.trials().to_vec();
        let result = Self::collect(builder.name(), scheduler, stats, bench, bench_seed);
        (result, trials)
    }

    /// Run a spec against a persistent trial store: unresolved
    /// `searcher.warm_start` references are sealed from the store before
    /// the run, and every completed trial is ingested back into it
    /// afterwards. Returns the result plus the number of trials recorded.
    /// Requires the deterministic `sim` backend — store records feed
    /// later warm starts, which must be reproducible.
    pub fn run_stored(
        spec: &ExperimentSpec,
        store: &StoreSpec,
    ) -> Result<(TuneResult, usize), String> {
        let mut spec = spec.clone();
        store::resolve_warm_start(&mut spec)?;
        spec.validate()?;
        if spec.exec.backend != ExecBackendKind::Sim {
            return Err("field 'exec.backend': store-backed runs require the 'sim' backend".into());
        }
        let bench = spec.bench.build()?;
        let builder = spec.scheduler.builder(spec.stop.config_budget)?;
        let tspec = TunerSpec::from(&spec);
        let (result, trials) = Self::run_with_trials(
            bench.as_ref(),
            builder.as_ref(),
            &tspec,
            spec.seed,
            spec.bench_seed,
        );
        let ingested = store::ingest(store, &spec, &trials)?;
        Ok((result, ingested))
    }

    /// Specs with an unresolved warm-start reference must be sealed
    /// (observations embedded) before a plain run — otherwise a journal
    /// or repetition would silently depend on a mutable file on disk.
    fn require_sealed(spec: &ExperimentSpec) -> Result<(), String> {
        if let Some(ws) = spec.searcher.warm_start() {
            if ws.trials.is_none() {
                return Err(
                    "field 'searcher.warm_start': unresolved store reference (seal it with \
                     store::resolve_warm_start, or use Tuner::run_stored)"
                        .into(),
                );
            }
        }
        Ok(())
    }

    /// One repetition on the wall-clock thread pool (spec backend
    /// `pool`): same surrogate oracle, real `std::thread` workers.
    fn run_on_pool(
        bench: Box<dyn Benchmark>,
        builder: &dyn SchedulerBuilder,
        tspec: &TunerSpec,
        spec: &ExperimentSpec,
    ) -> TuneResult {
        let mut scheduler = builder.build(bench.max_epochs(), spec.seed);
        let mut searcher: Box<dyn Searcher> = tspec
            .searcher
            .build(bench.space(), spec.seed)
            .expect("searcher spec must build (seal warm starts before run)");
        let space = bench.space().clone();
        let shared = Arc::new(SharedSurrogate {
            bench,
            bench_seed: spec.bench_seed,
        });
        let rules = tspec.rules();
        let stats = {
            let mut backend = PoolBackend::spawn(tspec.workers, shared.clone());
            run_engine(
                scheduler.as_mut(),
                searcher.as_mut(),
                &space,
                &rules,
                &mut backend,
            )
        };
        Self::collect(
            builder.name(),
            scheduler,
            stats,
            shared.bench.as_ref(),
            spec.bench_seed,
        )
    }

    /// Phase 2 + bookkeeping: retrain the incumbent and assemble the
    /// result record.
    fn collect(
        scheduler_name: String,
        scheduler: Box<dyn Scheduler>,
        stats: SimStats,
        bench: &dyn Benchmark,
        bench_seed: u64,
    ) -> TuneResult {
        let best = scheduler.best();
        let retrain_accuracy = best
            .as_ref()
            .map(|b| bench.retrain_accuracy(&b.config, bench_seed))
            .unwrap_or(f64::NAN);
        TuneResult {
            scheduler_name,
            best_metric: best.as_ref().map(|b| b.metric).unwrap_or(f64::NAN),
            best_config: best.map(|b| b.config),
            retrain_accuracy,
            runtime_seconds: stats.runtime_seconds,
            max_resources: scheduler.max_resources_used(),
            configs_sampled: stats.configs_sampled,
            total_epochs: stats.total_epochs,
            jobs: stats.jobs,
            cancelled_jobs: stats.cancelled_jobs,
            stopped_trials: stats.stopped_trials,
            eps_history: scheduler.epsilon_history().to_vec(),
        }
    }

    /// The `sched_seeds × bench_seeds` repetition grid over already-built
    /// parts (the paper's NAS experiments use 5 scheduler × 3 benchmark
    /// seeds = 15), fanned out across the machine's cores. Each
    /// repetition is an independent deterministic simulation keyed by
    /// `(sched_seed, bench_seed)`, so the output is identical to
    /// [`Tuner::run_repeated_serial`] in both content and order.
    pub fn run_repeated_with(
        bench: &dyn Benchmark,
        builder: &dyn SchedulerBuilder,
        spec: &TunerSpec,
        sched_seeds: &[u64],
        bench_seeds: &[u64],
    ) -> Vec<TuneResult> {
        let threads = available_threads();
        Self::run_repeated_threads(bench, builder, spec, sched_seeds, bench_seeds, threads)
    }

    /// [`Tuner::run_repeated_with`] with an explicit thread count (1 =
    /// serial execution on the calling thread).
    pub fn run_repeated_threads(
        bench: &dyn Benchmark,
        builder: &dyn SchedulerBuilder,
        spec: &TunerSpec,
        sched_seeds: &[u64],
        bench_seeds: &[u64],
        threads: usize,
    ) -> Vec<TuneResult> {
        let grid: Vec<(u64, u64)> = sched_seeds
            .iter()
            .flat_map(|&ss| bench_seeds.iter().map(move |&bs| (ss, bs)))
            .collect();
        par_map(&grid, threads, |_, &(ss, bs)| {
            Self::run_with(bench, builder, spec, ss, bs)
        })
    }

    /// The reference serial driver: same grid, same order, one thread.
    pub fn run_repeated_serial(
        bench: &dyn Benchmark,
        builder: &dyn SchedulerBuilder,
        spec: &TunerSpec,
        sched_seeds: &[u64],
        bench_seeds: &[u64],
    ) -> Vec<TuneResult> {
        Self::run_repeated_threads(bench, builder, spec, sched_seeds, bench_seeds, 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::benchmarks::nasbench201::NasBench201;
    use crate::benchmarks::pd1::Pd1;
    use crate::ranking::RankingSpec;
    use crate::scheduler::asha::AshaBuilder;
    use crate::scheduler::baselines::{FixedEpochBuilder, RandomBaselineBuilder};
    use crate::scheduler::pasha::PashaBuilder;
    use crate::scheduler::stopping::{StopAshaBuilder, StopPashaBuilder};
    use crate::spec::{BenchSpec, SchedulerSpec};
    use crate::util::stats;

    fn small_spec() -> TunerSpec {
        TunerSpec {
            workers: 4,
            config_budget: 64,
            searcher: SearcherSpec::Random,
            extra_stop: Vec::new(),
        }
    }

    #[test]
    fn asha_vs_pasha_shape_on_cifar100() {
        // The headline claim at reduced scale: PASHA ≈ ASHA accuracy with
        // materially less runtime. (CIFAR-100 — its wide τ spread makes the
        // early-stopping signal robust even at budget 64; CIFAR-10 needs
        // the full N=256 to separate, see tests/paper_shape.rs.)
        let bench = NasBench201::cifar100();
        let spec = small_spec();
        let seeds = [0u64, 1, 2];
        let asha: Vec<TuneResult> = seeds
            .iter()
            .map(|&s| Tuner::run_with(&bench, &AshaBuilder::default(), &spec, s, 0))
            .collect();
        let pasha: Vec<TuneResult> = seeds
            .iter()
            .map(|&s| Tuner::run_with(&bench, &PashaBuilder::default(), &spec, s, 0))
            .collect();
        let asha_acc = stats::mean(&asha.iter().map(|r| r.retrain_accuracy).collect::<Vec<_>>());
        let pasha_acc =
            stats::mean(&pasha.iter().map(|r| r.retrain_accuracy).collect::<Vec<_>>());
        let asha_rt = stats::mean(&asha.iter().map(|r| r.runtime_seconds).collect::<Vec<_>>());
        let pasha_rt =
            stats::mean(&pasha.iter().map(|r| r.runtime_seconds).collect::<Vec<_>>());
        assert!(
            (asha_acc - pasha_acc).abs() < 2.5,
            "accuracy parity: asha {asha_acc:.2} pasha {pasha_acc:.2}"
        );
        assert!(
            pasha_rt < asha_rt * 0.75,
            "speedup: pasha {pasha_rt:.0}s vs asha {asha_rt:.0}s"
        );
    }

    #[test]
    fn baselines_ordering_on_cifar100() {
        // random < one-epoch < {ASHA, PASHA} in accuracy (paper Table 1).
        let bench = NasBench201::cifar100();
        let spec = small_spec();
        let acc = |b: &dyn SchedulerBuilder| {
            let rs: Vec<f64> = (0..3)
                .map(|s| Tuner::run_with(&bench, b, &spec, s, 0).retrain_accuracy)
                .collect();
            stats::mean(&rs)
        };
        let random = acc(&RandomBaselineBuilder);
        let one_epoch = acc(&FixedEpochBuilder { epochs: 1 });
        let asha = acc(&AshaBuilder::default());
        assert!(random < one_epoch, "random {random:.1} < 1ep {one_epoch:.1}");
        assert!(
            one_epoch < asha + 1.0,
            "1ep {one_epoch:.1} below asha {asha:.1}"
        );
    }

    #[test]
    fn budget_and_drain_invariants() {
        let bench = NasBench201::cifar10();
        let spec = small_spec();
        let r = Tuner::run_with(&bench, &PashaBuilder::default(), &spec, 0, 0);
        assert_eq!(r.configs_sampled, 64);
        assert!(r.max_resources <= bench.max_epochs());
        assert!(r.best_config.is_some());
        assert!(r.retrain_accuracy > 0.0);
        assert_eq!(r.cancelled_jobs, 0, "promotion-type never cancels");
    }

    #[test]
    fn spec_run_matches_part_wise_run() {
        // The redesigned entry point: Tuner::run over a declarative spec
        // must be bit-identical to building the parts by hand.
        let spec = ExperimentSpec {
            bench: BenchSpec::new("nas-cifar10"),
            stop: crate::spec::StopRules {
                config_budget: 32,
                ..Default::default()
            },
            seed: 3,
            ..ExperimentSpec::default()
        };
        let from_spec = Tuner::run(&spec).unwrap();
        let bench = NasBench201::cifar10();
        let parts = Tuner::run_with(
            &bench,
            &PashaBuilder::default(),
            &TunerSpec {
                config_budget: 32,
                ..small_spec()
            },
            3,
            0,
        );
        assert_eq!(from_spec, parts);
    }

    #[test]
    fn spec_grid_matches_part_wise_grid() {
        let spec = ExperimentSpec {
            bench: BenchSpec::new("nas-cifar10"),
            stop: crate::spec::StopRules {
                config_budget: 16,
                ..Default::default()
            },
            ..ExperimentSpec::default()
        };
        let from_spec = Tuner::run_repeated(&spec, &[0, 1], &[0]).unwrap();
        let bench = NasBench201::cifar10();
        let parts = Tuner::run_repeated_with(
            &bench,
            &PashaBuilder::default(),
            &TunerSpec {
                config_budget: 16,
                ..small_spec()
            },
            &[0, 1],
            &[0],
        );
        assert_eq!(from_spec, parts);
    }

    #[test]
    fn pool_backend_runs_a_spec_end_to_end() {
        let mut spec = ExperimentSpec {
            bench: BenchSpec::new("nas-cifar10"),
            ..ExperimentSpec::default()
        };
        spec.stop.config_budget = 16;
        spec.exec.backend = ExecBackendKind::Pool;
        spec.exec.workers = 2;
        let r = Tuner::run(&spec).unwrap();
        assert_eq!(r.configs_sampled, 16);
        assert!(r.best_config.is_some());
        assert!(r.retrain_accuracy > 0.0);
        // grids refuse the non-reproducible backend
        let err = Tuner::run_repeated(&spec, &[0], &[0]).unwrap_err();
        assert!(err.contains("exec.backend"), "{err}");
    }

    #[test]
    fn run_repeated_produces_grid() {
        let bench = NasBench201::cifar10();
        let spec = TunerSpec {
            config_budget: 16,
            ..small_spec()
        };
        let rs = Tuner::run_repeated_with(
            &bench,
            &FixedEpochBuilder { epochs: 1 },
            &spec,
            &[0, 1],
            &[0, 1, 2],
        );
        assert_eq!(rs.len(), 6);
    }

    #[test]
    fn parallel_grid_identical_to_serial() {
        // The whole point of the parallel driver: byte-identical
        // TuneResults in the same (sched_seed, bench_seed) order.
        let bench = NasBench201::cifar100();
        let spec = TunerSpec {
            config_budget: 32,
            ..small_spec()
        };
        for builder in [
            &PashaBuilder::default() as &dyn SchedulerBuilder,
            &StopAshaBuilder::default(),
        ] {
            let serial =
                Tuner::run_repeated_serial(&bench, builder, &spec, &[0, 1, 2], &[0, 1]);
            let parallel =
                Tuner::run_repeated_threads(&bench, builder, &spec, &[0, 1, 2], &[0, 1], 4);
            assert_eq!(serial.len(), 6);
            assert_eq!(serial, parallel, "parallel grid must match serial exactly");
        }
    }

    #[test]
    fn stopping_variants_match_promotion_shape() {
        // The stopping-type schedulers must reproduce the paper's
        // accuracy-vs-runtime shape: comparable accuracy, with PASHA-stop
        // cheaper than ASHA-stop (the progressive cap saves epochs under
        // stopping semantics too).
        let bench = NasBench201::cifar100();
        let spec = small_spec();
        let seeds = [0u64, 1, 2];
        let mean_of = |b: &dyn SchedulerBuilder, f: &dyn Fn(&TuneResult) -> f64| {
            let rs: Vec<f64> = seeds
                .iter()
                .map(|&s| f(&Tuner::run_with(&bench, b, &spec, s, 0)))
                .collect();
            stats::mean(&rs)
        };
        let acc = |b: &dyn SchedulerBuilder| mean_of(b, &|r| r.retrain_accuracy);
        let rt = |b: &dyn SchedulerBuilder| mean_of(b, &|r| r.runtime_seconds);
        let asha_acc = acc(&AshaBuilder::default());
        let astop_acc = acc(&StopAshaBuilder::default());
        let pstop_acc = acc(&StopPashaBuilder::default());
        assert!(
            (asha_acc - astop_acc).abs() < 3.0,
            "stopping ASHA accuracy parity: {asha_acc:.2} vs {astop_acc:.2}"
        );
        assert!(
            (astop_acc - pstop_acc).abs() < 3.0,
            "stopping PASHA accuracy parity: {astop_acc:.2} vs {pstop_acc:.2}"
        );
        assert!(
            rt(&StopPashaBuilder::default()) < rt(&StopAshaBuilder::default()),
            "PASHA-stop must be cheaper than ASHA-stop"
        );
    }

    #[test]
    fn clock_budget_truncates_run() {
        let bench = NasBench201::cifar10();
        let full = Tuner::run_with(&bench, &AshaBuilder::default(), &small_spec(), 0, 0);
        let budget = full.runtime_seconds * 0.25;
        let spec = TunerSpec {
            extra_stop: vec![StopSpec::ClockBudget(budget)],
            ..small_spec()
        };
        let cut = Tuner::run_with(&bench, &AshaBuilder::default(), &spec, 0, 0);
        assert!(cut.runtime_seconds <= budget + 1e-9);
        assert!(cut.total_epochs < full.total_epochs);
        assert!(cut.cancelled_jobs > 0, "halt must cancel in-flight work");
        assert!(cut.best_config.is_some(), "partial results still usable");
    }

    #[test]
    fn epoch_budget_truncates_run() {
        let bench = NasBench201::cifar10();
        let spec = TunerSpec {
            extra_stop: vec![StopSpec::EpochBudget(40)],
            ..small_spec()
        };
        let r = Tuner::run_with(&bench, &AshaBuilder::default(), &spec, 0, 0);
        // Drain semantics: dispatch stops once 40 epochs are out; the
        // budget-crossing job and everything in flight still complete
        // (early ASHA jobs are 1–8 epochs, so the overshoot is small)
        // and nothing is cancelled.
        assert!(r.total_epochs >= 40, "budget is reached: {}", r.total_epochs);
        assert!(
            r.total_epochs <= 40 + 30,
            "overshoot bounded by in-flight work: {}",
            r.total_epochs
        );
        assert_eq!(r.cancelled_jobs, 0, "drain never cancels");
    }

    #[test]
    fn bo_searcher_runs_end_to_end() {
        let bench = NasBench201::cifar10();
        let spec = TunerSpec {
            searcher: SearcherKind::Bo.to_spec(),
            config_budget: 32,
            ..small_spec()
        };
        let r = Tuner::run_with(&bench, &PashaBuilder::default(), &spec, 0, 0);
        assert!(r.retrain_accuracy > 50.0, "BO run sane: {}", r.retrain_accuracy);
    }

    #[test]
    fn pd1_wmt_massive_speedup_shape() {
        // WMT has 8 rung levels: PASHA's early stop must buy a large factor.
        let bench = Pd1::wmt();
        let spec = TunerSpec {
            config_budget: 48,
            ..small_spec()
        };
        let asha = Tuner::run_with(&bench, &AshaBuilder::default(), &spec, 1, 0);
        let pasha = Tuner::run_with(&bench, &PashaBuilder::default(), &spec, 1, 0);
        assert!(
            pasha.runtime_seconds * 2.0 < asha.runtime_seconds,
            "pasha {} vs asha {}",
            pasha.runtime_seconds,
            asha.runtime_seconds
        );
        assert!(pasha.max_resources < asha.max_resources);
    }

    #[test]
    fn spec_construction_covers_the_legacy_factories() {
        // The deprecated name-based factories are gone; their behaviour
        // must be fully expressible (and identical) through specs.
        let bench = BenchSpec::new("nas-cifar10").build().unwrap();
        assert_eq!(bench.name(), NasBench201::cifar10().name());
        assert!(BenchSpec::new("nope").build().is_err());
        let spec_builder = SchedulerSpec::from_name("pasha", 1, 3, RankingSpec::default())
            .unwrap()
            .builder(64)
            .unwrap();
        assert_eq!(spec_builder.name(), "PASHA");
        let r1 = Tuner::run_with(&*bench, &PashaBuilder::default(), &small_spec(), 0, 0);
        let r2 = Tuner::run_with(&*bench, &*spec_builder, &small_spec(), 0, 0);
        assert_eq!(r1, r2);
        assert_eq!(SearcherKind::Bo.to_spec(), SearcherSpec::bo_default());
        let s = SearcherSpec::Random.build(bench.space(), 9).unwrap();
        assert_eq!(s.name(), "random-search");
    }

    #[test]
    fn run_stored_warm_start_is_deterministic() {
        let dir = std::env::temp_dir().join(format!("pasha-tuner-store-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("warm_determinism.jsonl");
        let _ = std::fs::remove_file(&path);
        let store = StoreSpec::new(&path);

        // Source run populates the store.
        let mut source = ExperimentSpec::named("nas-cifar10", "pasha").unwrap();
        source.stop.config_budget = 16;
        source.searcher = SearcherSpec::bo_default();
        let (_, n) = Tuner::run_stored(&source, &store).unwrap();
        assert!(n > 0, "source run must record trials");

        // Target spec warm-starts from it. Seal once, run twice: the
        // sealed spec is self-contained, so results are bit-identical
        // even though the store keeps growing.
        let mut target = source.clone();
        target.seed = 1;
        target.searcher = SearcherSpec::bo_warm(path.to_str().unwrap(), 8);

        // Unsealed specs refuse a plain run (they'd depend on disk).
        let err = Tuner::run(&target).unwrap_err();
        assert!(err.contains("unresolved"), "{err}");

        let embedded = store::resolve_warm_start(&mut target).unwrap();
        assert!(embedded > 0, "warm start must embed prior trials");
        let a = Tuner::run(&target).unwrap();
        let b = Tuner::run(&target).unwrap();
        assert_eq!(a, b, "sealed warm-start runs must be deterministic");

        let _ = std::fs::remove_file(&path);
    }
}
