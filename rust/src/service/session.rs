//! One durable tuning session: an ask/tell core plus its write-ahead
//! journal.
//!
//! A [`SessionSpec`] is the wire-serializable recipe (benchmark,
//! scheduler, searcher, seeds, budgets) from which a session's scheduler
//! and searcher are built deterministically — the same derivations as
//! [`crate::tuner::Tuner::run`], so a served session reproduces the
//! in-process run for the same seeds. A [`Session`] wraps the
//! [`AskTell`] core and appends every mutating operation to its journal
//! before acknowledging it; [`Session::recover`] rebuilds a crashed
//! session by replaying the journal against a fresh core, verifying that
//! every replayed `ask` regenerates the exact response that was
//! acknowledged (any divergence means the journal does not belong to
//! this code/seed combination and recovery is refused).

use crate::executor::engine::{ConfigBudget, EpochBudget, StoppingRule};
use crate::scheduler::asktell::{assignment_json, config_json, AskTell, TellAck, TrialAssignment};
use crate::service::journal::{self, ev_ask, ev_create, ev_expire, ev_fail, ev_tell, Journal};
use crate::service::registry::ServiceError;
use crate::tuner::{bench_from_name, scheduler_from_name, searcher_for, SearcherKind};
use crate::util::json::Json;
use crate::TrialId;
use std::path::Path;

/// The serializable recipe for one session.
#[derive(Clone, Debug, PartialEq)]
pub struct SessionSpec {
    /// Benchmark wire name (`lcbench-Fashion-MNIST`, `nas-cifar10`, …):
    /// defines the search space and max epochs here, and tells workers
    /// what to evaluate.
    pub bench: String,
    /// Scheduler wire name (`pasha`, `asha`, `pasha-stop`, …).
    pub scheduler: String,
    pub eta: u32,
    pub searcher: SearcherKind,
    /// Scheduler/searcher seed (the tuner's `sched_seed`).
    pub seed: u64,
    /// Benchmark seed workers should evaluate with.
    pub bench_seed: u64,
    /// The paper's N-configuration budget.
    pub config_budget: usize,
    /// Optional additional epoch budget (drain semantics).
    pub epoch_budget: Option<u64>,
}

impl Default for SessionSpec {
    fn default() -> Self {
        SessionSpec {
            bench: "nas-cifar10".into(),
            scheduler: "pasha".into(),
            eta: 3,
            searcher: SearcherKind::Random,
            seed: 0,
            bench_seed: 0,
            config_budget: 256,
            epoch_budget: None,
        }
    }
}

impl SessionSpec {
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("bench", self.bench.as_str())
            .set("scheduler", self.scheduler.as_str())
            .set("eta", self.eta)
            .set("searcher", self.searcher.as_str())
            .set("seed", self.seed as f64)
            .set("bench_seed", self.bench_seed as f64)
            .set("config_budget", self.config_budget);
        if let Some(e) = self.epoch_budget {
            o.set("epoch_budget", e as f64);
        }
        o
    }

    pub fn from_json(j: &Json) -> Result<SessionSpec, String> {
        let str_field = |key: &str, default: &str| -> String {
            j.get(key)
                .and_then(|v| v.as_str())
                .unwrap_or(default)
                .to_string()
        };
        let num = |key: &str| j.get(key).and_then(|v| v.as_f64());
        let searcher_name = str_field("searcher", "random");
        let searcher = SearcherKind::parse(&searcher_name)
            .ok_or_else(|| format!("unknown searcher '{searcher_name}'"))?;
        Ok(SessionSpec {
            bench: str_field("bench", "nas-cifar10"),
            scheduler: str_field("scheduler", "pasha"),
            eta: num("eta").unwrap_or(3.0) as u32,
            searcher,
            seed: num("seed").unwrap_or(0.0) as u64,
            bench_seed: num("bench_seed").unwrap_or(0.0) as u64,
            config_budget: num("config_budget").unwrap_or(256.0) as usize,
            epoch_budget: num("epoch_budget").map(|e| e as u64),
        })
    }

    /// Build the deterministic ask/tell core this spec describes. Uses
    /// the same scheduler/searcher derivations as `Tuner::run`, so a
    /// single-worker session reproduces the in-process run exactly.
    pub fn build_core(&self) -> Result<AskTell, String> {
        let bench = bench_from_name(&self.bench)?;
        let builder = scheduler_from_name(&self.scheduler, self.eta, self.config_budget)?;
        let scheduler = builder.build(bench.max_epochs(), self.seed);
        let searcher = searcher_for(&self.searcher, self.seed);
        let mut rules: Vec<Box<dyn StoppingRule>> =
            vec![Box::new(ConfigBudget(self.config_budget))];
        if let Some(e) = self.epoch_budget {
            rules.push(Box::new(EpochBudget(e)));
        }
        Ok(AskTell::new(scheduler, searcher, bench.space().clone(), rules))
    }
}

/// What [`Session::recover`] found in the journal.
#[derive(Clone, Debug, Default)]
pub struct RecoveryReport {
    /// Whole events replayed (excluding the `create` header).
    pub events_replayed: usize,
    /// Bytes of a partial trailing line dropped as a crash artifact.
    pub truncated_bytes: usize,
}

/// A registered tuning session: ask/tell core + journal + identity.
pub struct Session {
    pub id: String,
    pub spec: SessionSpec,
    core: AskTell,
    journal: Option<Journal>,
    /// Events appended since creation/recovery (excluding the `create`
    /// header) — the trace↔journal alignment key used by tests.
    events_written: usize,
    /// Set when an acknowledged mutation could not be journaled: the
    /// journal no longer matches the in-memory state, so further
    /// mutations are refused rather than risking a bad recovery.
    poisoned: bool,
}

impl Session {
    /// Create a fresh session, writing the `create` header as the
    /// journal's first event (when a journal path is given).
    pub fn create(
        id: &str,
        spec: SessionSpec,
        journal_path: Option<&Path>,
    ) -> Result<Session, ServiceError> {
        let core = spec.build_core().map_err(ServiceError::Spec)?;
        let journal = match journal_path {
            None => None,
            Some(path) => {
                let mut j = Journal::create(path).map_err(|e| ServiceError::Io(e.to_string()))?;
                j.append(&ev_create(id, &spec.to_json()))
                    .map_err(|e| ServiceError::Io(e.to_string()))?;
                Some(j)
            }
        };
        Ok(Session {
            id: id.to_string(),
            spec,
            core,
            journal,
            events_written: 0,
            poisoned: false,
        })
    }

    /// Rebuild a session from its journal: build a fresh core from the
    /// recorded spec, then replay every event. Replayed `ask`s must
    /// regenerate byte-identical responses; a mismatch aborts recovery.
    /// The journal is truncated to its whole-event prefix and re-opened
    /// for appending — only call this when this process owns the journal
    /// (for a pure check of a file another server may own, use
    /// [`Session::recover_readonly`]).
    pub fn recover(path: &Path) -> Result<(Session, RecoveryReport), ServiceError> {
        Self::recover_impl(path, true)
    }

    /// [`Session::recover`] without touching the file: replays and
    /// verifies, but never truncates or re-opens the journal, so it is
    /// safe against a journal a live server is appending to. The
    /// returned session has no journal attached (mutations after this
    /// are not logged).
    pub fn recover_readonly(path: &Path) -> Result<(Session, RecoveryReport), ServiceError> {
        Self::recover_impl(path, false)
    }

    fn recover_impl(path: &Path, attach: bool) -> Result<(Session, RecoveryReport), ServiceError> {
        let read = journal::read_journal(path).map_err(|e| ServiceError::Io(e.to_string()))?;
        let mut events = read.events.iter();
        let empty = || ServiceError::Journal("empty journal".into());
        let header = events.next().ok_or_else(empty)?;
        if header.get("ev").and_then(|v| v.as_str()) != Some("create") {
            return Err(ServiceError::Journal(
                "journal does not start with a create event".into(),
            ));
        }
        let id = header
            .get("session")
            .and_then(|v| v.as_str())
            .ok_or_else(|| ServiceError::Journal("create event missing session id".into()))?
            .to_string();
        let spec_json = header
            .get("spec")
            .ok_or_else(|| ServiceError::Journal("create event missing spec".into()))?;
        let spec = SessionSpec::from_json(spec_json).map_err(ServiceError::Spec)?;
        let mut session = Session {
            id,
            spec: spec.clone(),
            core: spec.build_core().map_err(ServiceError::Spec)?,
            journal: None,
            events_written: 0,
            poisoned: false,
        };
        let mut replayed = 0usize;
        for (i, ev) in events.enumerate() {
            session.replay_event(ev).map_err(|e| {
                ServiceError::Journal(format!("event {} of {}: {e}", i + 1, path.display()))
            })?;
            replayed += 1;
        }
        if attach {
            session.journal = Some(
                Journal::open_append_at(path, read.valid_len)
                    .map_err(|e| ServiceError::Io(e.to_string()))?,
            );
        }
        // replayed events are already on disk; the counter tracks only
        // what this process appends from here on
        session.events_written = 0;
        Ok((
            session,
            RecoveryReport {
                events_replayed: replayed,
                truncated_bytes: read.truncated_bytes,
            },
        ))
    }

    fn replay_event(&mut self, ev: &Json) -> Result<(), String> {
        match ev.get("ev").and_then(|v| v.as_str()) {
            Some("ask") => {
                let worker = ev
                    .get("worker")
                    .and_then(|v| v.as_str())
                    .ok_or("ask event missing worker")?;
                let recorded = ev.get("resp").ok_or("ask event missing resp")?;
                let replayed = assignment_json(&self.core.ask(worker));
                if replayed != *recorded {
                    return Err(format!(
                        "replay divergence: journal acknowledged {} but replay produced {}",
                        recorded.to_string_compact(),
                        replayed.to_string_compact()
                    ));
                }
                Ok(())
            }
            Some("tell") => {
                let num = |key: &str| -> Result<f64, String> {
                    ev.get(key)
                        .and_then(|v| v.as_f64())
                        .ok_or_else(|| format!("tell event missing '{key}'"))
                };
                let trial = num("trial")? as TrialId;
                let epoch = num("epoch")? as u32;
                // NaN metrics journal as `null`; read them back as NaN.
                let metric = ev.get("metric").and_then(|v| v.as_f64()).unwrap_or(f64::NAN);
                // A tell that errored when live errors identically on
                // replay; both are state no-ops, so ignore the result.
                let _ = self.core.tell(trial, epoch, metric);
                Ok(())
            }
            Some("fail") => {
                let trial = ev
                    .get("trial")
                    .and_then(|v| v.as_f64())
                    .ok_or("fail event missing trial")? as TrialId;
                let _ = self.core.fail(trial);
                Ok(())
            }
            Some("expire") => {
                self.core.expire_workers();
                Ok(())
            }
            other => Err(format!("unknown journal event {other:?}")),
        }
    }

    fn append(&mut self, ev: &Json) -> Result<(), ServiceError> {
        if let Some(j) = self.journal.as_mut() {
            if let Err(e) = j.append(ev) {
                self.poisoned = true;
                return Err(ServiceError::Io(format!(
                    "journal append failed, session '{}' poisoned: {e}",
                    self.id
                )));
            }
        }
        self.events_written += 1;
        Ok(())
    }

    /// Events appended since creation/recovery (journal-less sessions
    /// count the appends they would have made).
    pub fn events_journaled(&self) -> usize {
        self.events_written
    }

    fn check_poisoned(&self) -> Result<(), ServiceError> {
        if self.poisoned {
            Err(ServiceError::Journal(format!(
                "session '{}' is poisoned (an earlier journal append failed)",
                self.id
            )))
        } else {
            Ok(())
        }
    }

    /// Ask for work on behalf of `worker`. Mutating asks are journaled
    /// before being returned — including `Wait` answers that parked a
    /// scheduler-emitted job (the mutation-count check), which must
    /// replay for recovery to stay byte-identical.
    pub fn ask(&mut self, worker: &str) -> Result<TrialAssignment, ServiceError> {
        self.check_poisoned()?;
        let before = self.core.mutation_count();
        let assignment = self.core.ask(worker);
        if assignment.is_mutation() || self.core.mutation_count() != before {
            self.append(&ev_ask(worker, assignment_json(&assignment)))?;
        }
        Ok(assignment)
    }

    /// Report one epoch's metric. Journaled before it is applied, so an
    /// acknowledged tell is always recoverable.
    pub fn tell(
        &mut self,
        trial: TrialId,
        epoch: u32,
        metric: f64,
    ) -> Result<TellAck, ServiceError> {
        self.check_poisoned()?;
        self.append(&ev_tell(trial, epoch, metric))?;
        self.core.tell(trial, epoch, metric).map_err(ServiceError::Session)
    }

    /// A worker reported failure while running `trial`.
    pub fn fail(&mut self, trial: TrialId) -> Result<(), ServiceError> {
        self.check_poisoned()?;
        self.append(&ev_fail(trial))?;
        self.core.fail(trial).map_err(ServiceError::Session)
    }

    /// Retire all in-flight jobs (operator action after worker loss).
    pub fn expire_workers(&mut self) -> Result<usize, ServiceError> {
        self.check_poisoned()?;
        self.append(&ev_expire())?;
        Ok(self.core.expire_workers())
    }

    /// Read-only status summary (what `pasha sessions` renders).
    pub fn status(&self) -> Json {
        let snap = self.core.snapshot();
        let stats = self.core.stats();
        let mut o = Json::obj();
        o.set("id", self.id.as_str())
            .set("spec", self.spec.to_json())
            .set("scheduler", self.core.scheduler_name())
            .set("configs_sampled", snap.configs_sampled)
            .set("jobs_dispatched", snap.jobs_dispatched)
            .set("jobs_completed", snap.jobs_completed)
            .set("epochs_completed", snap.epochs_completed as f64)
            .set("in_flight", self.core.in_flight_count())
            .set("cancelled_jobs", stats.cancelled_jobs)
            .set("failed_jobs", stats.failed_jobs)
            .set("stopped_trials", stats.stopped_trials)
            .set("paused_trials", stats.paused_trials)
            .set("max_resources", self.core.max_resources_used())
            .set("trials", self.core.trials().len());
        match self.core.best() {
            Some(b) => {
                o.set("best_trial", b.trial)
                    .set("best_metric", b.metric)
                    .set("best_config", config_json(&b.config));
            }
            None => {
                o.set("best_metric", Json::Null);
            }
        }
        o
    }

    pub fn core(&mut self) -> &mut AskTell {
        &mut self.core
    }

    pub fn core_ref(&self) -> &AskTell {
        &self.core
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::benchmarks::Benchmark;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("pasha-session-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    fn small_spec() -> SessionSpec {
        SessionSpec {
            bench: "lcbench-Fashion-MNIST".into(),
            scheduler: "asha".into(),
            config_budget: 8,
            ..SessionSpec::default()
        }
    }

    /// Drive a session to completion with one synchronous worker.
    fn drive(session: &mut Session, bench: &dyn Benchmark, bench_seed: u64) {
        loop {
            match session.ask("w0").unwrap() {
                TrialAssignment::Run(job) => {
                    for e in job.from_epoch + 1..=job.milestone {
                        let m = bench.accuracy_at(&job.config, e, bench_seed);
                        if session.tell(job.trial, e, m).unwrap() == TellAck::Abandon {
                            break;
                        }
                    }
                }
                TrialAssignment::Stop(_) | TrialAssignment::Pause(_) => {}
                TrialAssignment::Wait => panic!("single worker never waits"),
                TrialAssignment::Done => return,
            }
        }
    }

    #[test]
    fn spec_json_roundtrip() {
        let spec = SessionSpec {
            bench: "pd1-wmt".into(),
            scheduler: "pasha-stop".into(),
            eta: 4,
            searcher: SearcherKind::Bo,
            seed: 42,
            bench_seed: 7,
            config_budget: 99,
            epoch_budget: Some(1234),
        };
        let j = spec.to_json();
        let back = SessionSpec::from_json(&j).unwrap();
        assert_eq!(spec, back);
        // defaults fill missing fields
        let sparse = crate::util::json::parse("{\"bench\":\"nas-cifar100\"}").unwrap();
        let s = SessionSpec::from_json(&sparse).unwrap();
        assert_eq!(s.bench, "nas-cifar100");
        assert_eq!(s.config_budget, 256);
        assert!(s.epoch_budget.is_none());
    }

    #[test]
    fn full_session_recovers_to_done_state() {
        let path = tmp("full.jsonl");
        let spec = small_spec();
        let bench = bench_from_name(&spec.bench).unwrap();
        let mut s = Session::create("s0", spec.clone(), Some(&path)).unwrap();
        drive(&mut s, bench.as_ref(), spec.bench_seed);
        let best = s.core_ref().best().unwrap();
        drop(s);

        let (mut r, report) = Session::recover(&path).unwrap();
        assert_eq!(report.truncated_bytes, 0);
        assert!(report.events_replayed > 0);
        assert_eq!(r.id, "s0");
        assert_eq!(r.spec, spec);
        let rbest = r.core_ref().best().unwrap();
        assert_eq!(rbest.trial, best.trial);
        assert_eq!(rbest.metric.to_bits(), best.metric.to_bits());
        assert_eq!(r.ask("w0").unwrap(), TrialAssignment::Done);
    }

    #[test]
    fn readonly_recovery_never_touches_the_file() {
        let path = tmp("readonly.jsonl");
        let spec = small_spec();
        let bench = bench_from_name(&spec.bench).unwrap();
        let mut s = Session::create("s0", spec.clone(), Some(&path)).unwrap();
        drive(&mut s, bench.as_ref(), spec.bench_seed);
        drop(s);
        // leave a torn tail in place: readonly recovery must not trim it
        let mut bytes = std::fs::read(&path).unwrap();
        bytes.extend_from_slice(b"{\"ev\":\"tell\",\"tri");
        std::fs::write(&path, &bytes).unwrap();
        let (mut r, report) = Session::recover_readonly(&path).unwrap();
        assert!(report.truncated_bytes > 0);
        assert_eq!(r.ask("w0").unwrap(), TrialAssignment::Done);
        assert_eq!(std::fs::read(&path).unwrap(), bytes, "file untouched");
    }

    #[test]
    fn recovery_detects_foreign_journal() {
        // A journal whose asks were produced under a different seed must
        // be refused, not silently mis-replayed.
        let path_a = tmp("seed-a.jsonl");
        let spec_a = small_spec();
        let bench = bench_from_name(&spec_a.bench).unwrap();
        let mut a = Session::create("sa", spec_a.clone(), Some(&path_a)).unwrap();
        drive(&mut a, bench.as_ref(), spec_a.bench_seed);
        drop(a);
        // swap the header's seed so replay draws different configs
        let text = std::fs::read_to_string(&path_a).unwrap();
        let mut lines: Vec<&str> = text.lines().collect();
        let doctored = lines[0].replace("\"seed\":0", "\"seed\":1");
        lines[0] = &doctored;
        let path_b = tmp("seed-b.jsonl");
        std::fs::write(&path_b, lines.join("\n") + "\n").unwrap();
        let err = match Session::recover(&path_b) {
            Ok(_) => panic!("recovery must fail"),
            Err(e) => e,
        };
        match err {
            ServiceError::Journal(msg) => assert!(msg.contains("divergence"), "{msg}"),
            other => panic!("expected divergence error, got {other:?}"),
        }
    }

    #[test]
    fn status_shape() {
        let mut s = Session::create("s1", small_spec(), None).unwrap();
        let st = s.status();
        assert_eq!(st.get("id").unwrap().as_str(), Some("s1"));
        assert_eq!(st.get("configs_sampled").unwrap().as_f64(), Some(0.0));
        assert_eq!(st.get("best_metric"), Some(&Json::Null));
        // after some work the best appears
        let bench = bench_from_name("lcbench-Fashion-MNIST").unwrap();
        if let TrialAssignment::Run(job) = s.ask("w0").unwrap() {
            for e in job.from_epoch + 1..=job.milestone {
                let m = bench.accuracy_at(&job.config, e, 0);
                s.tell(job.trial, e, m).unwrap();
            }
        } else {
            panic!("expected a job");
        }
        let st = s.status();
        assert!(st.get("best_metric").unwrap().as_f64().is_some());
        assert_eq!(st.get("jobs_completed").unwrap().as_f64(), Some(1.0));
    }

    #[test]
    fn bad_spec_is_rejected() {
        let spec = SessionSpec {
            bench: "no-such-bench".into(),
            ..SessionSpec::default()
        };
        let err = match Session::create("x", spec, None) {
            Ok(_) => panic!("bad spec must fail"),
            Err(e) => e,
        };
        match err {
            ServiceError::Spec(msg) => assert!(msg.contains("no-such-bench")),
            other => panic!("expected spec error, got {other:?}"),
        }
    }
}
