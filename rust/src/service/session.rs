//! One durable tuning session: an ask/tell core plus its write-ahead
//! journal.
//!
//! A session is described by an [`ExperimentSpec`] — the same versioned,
//! wire-serializable recipe the CLI and the in-process tuner use — from
//! which its scheduler and searcher are built deterministically
//! ([`ExperimentSpec::build_core`], the same derivations as
//! [`crate::tuner::Tuner::run`]), so a served session reproduces the
//! in-process run for the same seeds. Journal headers written by older
//! builds carry the flat v1 spec shape; [`ExperimentSpec::from_json`]
//! migrates them, so v1 journals and snapshots recover byte-identically.
//! A [`Session`] wraps the [`AskTell`] core and appends every mutating
//! operation to its journal before acknowledging it;
//! [`Session::recover`] rebuilds a crashed session by replaying the
//! journal against a fresh core, verifying that every replayed `ask`
//! regenerates the exact response that was acknowledged (any divergence
//! means the journal does not belong to this code/seed combination and
//! recovery is refused).

use crate::scheduler::asktell::{assignment_json, config_json, AskTell, TellAck, TrialAssignment};
use crate::service::journal::{
    self, ev_ask, ev_create, ev_create_at, ev_expire, ev_expire_worker, ev_fail, ev_snapshot,
    ev_tell, Journal,
};
use crate::service::registry::ServiceError;
use crate::service::replica::ShipFrame;
use crate::spec::ExperimentSpec;
use crate::store::{self, StoreSpec};
use crate::util::json::Json;
use crate::TrialId;
use std::collections::HashMap;
use std::path::Path;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Snapshot/compaction policy for a durable session.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SessionOptions {
    /// Write a state snapshot after this many journaled events
    /// (`None` = never snapshot; recovery is full journal replay).
    pub snapshot_every: Option<usize>,
    /// After each snapshot, compact the journal tail down to the
    /// *previous* snapshot's boundary and trim the sidecar to the last
    /// two snapshots. The one-snapshot lag means a torn latest snapshot
    /// still recovers from the previous one plus a longer tail.
    pub compact_on_snapshot: bool,
    /// Trial store completed sessions ingest their trials into, and the
    /// source for sealing unresolved `searcher.warm_start` references at
    /// creation (`pasha serve --store`).
    pub store: Option<StoreSpec>,
}

impl Default for SessionOptions {
    fn default() -> Self {
        SessionOptions {
            snapshot_every: None,
            compact_on_snapshot: true,
            store: None,
        }
    }
}

impl SessionOptions {
    /// Snapshot every `events` events with rotation/compaction on — what
    /// `pasha serve --snapshot-interval` uses.
    pub fn snapshot_every(events: usize) -> SessionOptions {
        SessionOptions {
            snapshot_every: Some(events),
            compact_on_snapshot: true,
            store: None,
        }
    }
}

/// What [`Session::recover`] found in the journal.
#[derive(Clone, Debug, Default)]
pub struct RecoveryReport {
    /// Whole events replayed against the core (excluding the `create`
    /// header and any events already covered by the snapshot used).
    pub events_replayed: usize,
    /// Bytes of a partial trailing line dropped as a crash artifact.
    pub truncated_bytes: usize,
    /// Absolute event count covered by the snapshot recovery restored
    /// from (0 = no usable snapshot; full replay).
    pub snapshot_events: usize,
    /// Pre-snapshot events still present in the (uncompacted) tail that
    /// were skipped rather than re-applied.
    pub events_skipped: usize,
}

/// A registered tuning session: ask/tell core + journal + identity.
pub struct Session {
    pub id: String,
    pub spec: ExperimentSpec,
    core: AskTell,
    journal: Option<Journal>,
    /// Events appended since creation/recovery (excluding the `create`
    /// header) — the trace↔journal alignment key used by tests.
    events_written: usize,
    /// Absolute event count since session creation (across restarts and
    /// compactions) — the coordinate system snapshots are keyed by.
    events_total: usize,
    /// Absolute event count at which the current journal tail starts
    /// (the compacted-away prefix; 0 for an uncompacted journal).
    base: usize,
    /// Absolute event counts of trusted durable snapshots, ascending:
    /// ones this session wrote, plus (after recovery) the load-verified
    /// snapshot it restored from. Compaction only ever advances the
    /// journal base to one of these.
    snapshots: Vec<usize>,
    options: SessionOptions,
    /// A snapshot/compaction failure is recorded (and snapshotting
    /// disabled) rather than failing the acknowledged operation — the
    /// journal stays authoritative.
    snapshot_error: Option<String>,
    /// Set when an acknowledged mutation could not be journaled: the
    /// journal no longer matches the in-memory state, so further
    /// mutations are refused rather than risking a bad recovery.
    poisoned: bool,
    /// Group-commit mode is on (the served event loop's setting); kept
    /// here so a journal handle replaced by compaction inherits it.
    group_commit: bool,
    /// Completed trials have been ingested into the options' store (the
    /// ingestion runs once, on the first `Done` answer).
    ingested: bool,
    /// A store-ingestion failure is recorded rather than failing the
    /// acknowledged `Done` — the store is an extract, never authoritative.
    store_error: Option<String>,
    /// `pasha_sched_asks_journaled_total` — asks that produced a journal
    /// event (the mutation-count rule), including replayed ones. The
    /// conservation oracle compares this against the journal's literal
    /// `ask` event count.
    asks_journaled: Option<Arc<crate::obs::Counter>>,
    /// Replication shipping is on: durable journal bytes are retained
    /// after each commit and queued as [`ShipFrame`]s for a follower.
    /// Observe-only — the journal bytes on disk are identical either way.
    shipping: bool,
    /// Frames awaiting collection by the replication layer
    /// ([`Session::drain_ship_frames`]), in the order they must apply.
    ship_queue: Vec<ShipFrame>,
    /// Wall-clock last-seen instant per worker, fed by `ask`/`tell`/`fail`.
    /// Not journaled (recovery starts fresh — post-restart workers are
    /// known gone and handled by the recovery-time expire); used only by
    /// the per-shard lease-expiry tick.
    leases: HashMap<String, Instant>,
}

impl Session {
    /// Create a fresh session, writing the `create` header as the
    /// journal's first event (when a journal path is given).
    pub fn create(
        id: &str,
        spec: ExperimentSpec,
        journal_path: Option<&Path>,
    ) -> Result<Session, ServiceError> {
        Self::create_with(id, spec, journal_path, SessionOptions::default())
    }

    /// [`Session::create`] with an explicit snapshot/compaction policy.
    pub fn create_with(
        id: &str,
        mut spec: ExperimentSpec,
        journal_path: Option<&Path>,
        options: SessionOptions,
    ) -> Result<Session, ServiceError> {
        // Seal unresolved warm-start references before the spec is
        // journaled: the header then embeds the prior observations, so
        // recovery rebuilds the same warm searcher without re-reading a
        // store file that may have changed (or vanished) since.
        store::resolve_warm_start(&mut spec).map_err(ServiceError::Spec)?;
        let core = spec.build_core().map_err(ServiceError::Spec)?;
        let journal = match journal_path {
            None => None,
            Some(path) => {
                // a fresh session must not inherit a stale sidecar
                let _ = std::fs::remove_file(journal::snapshot_path(path));
                let mut j = Journal::create(path).map_err(|e| ServiceError::Io(e.to_string()))?;
                j.append(&ev_create(id, &spec.to_json()))
                    .map_err(|e| ServiceError::Io(e.to_string()))?;
                Some(j)
            }
        };
        let mut session = Session {
            id: id.to_string(),
            spec,
            core,
            journal,
            events_written: 0,
            events_total: 0,
            base: 0,
            snapshots: Vec::new(),
            options,
            snapshot_error: None,
            poisoned: false,
            group_commit: false,
            ingested: false,
            store_error: None,
            asks_journaled: None,
            shipping: false,
            ship_queue: Vec::new(),
            leases: HashMap::new(),
        };
        session.attach_obs();
        Ok(session)
    }

    /// Register this session's observability instruments (scheduler
    /// gauges/counters on the ask/tell core, journal fsync/byte counters)
    /// under a `session=<id>` label. Registration is idempotent per
    /// label set, so recovery and compaction re-attach to the same
    /// instruments. Recording is inert for determinism: nothing here
    /// feeds back into decisions or journal bytes.
    fn attach_obs(&mut self) {
        self.core.attach_obs(&self.id);
        self.asks_journaled = Some(crate::obs::counter(
            "pasha_sched_asks_journaled_total",
            &[("session", &self.id)],
        ));
        if let Some(j) = self.journal.as_mut() {
            j.set_obs(&self.id);
        }
    }

    /// Rebuild a session from its journal: restore the newest usable
    /// snapshot (if the sidecar has one), then replay only the events
    /// past it. Replayed `ask`s must regenerate byte-identical responses;
    /// a mismatch aborts recovery. The journal is truncated to its
    /// whole-event prefix and re-opened for appending — only call this
    /// when this process owns the journal (for a pure check of a file
    /// another server may own, use [`Session::recover_readonly`]).
    pub fn recover(path: &Path) -> Result<(Session, RecoveryReport), ServiceError> {
        Self::recover_impl(path, true, SessionOptions::default())
    }

    /// [`Session::recover`] with an explicit snapshot/compaction policy
    /// for the session's life *after* recovery (recovery itself always
    /// uses any snapshots already on disk).
    pub fn recover_with(
        path: &Path,
        options: SessionOptions,
    ) -> Result<(Session, RecoveryReport), ServiceError> {
        Self::recover_impl(path, true, options)
    }

    /// [`Session::recover`] without touching the files: restores and
    /// verifies, but never truncates, compacts or re-opens the journal,
    /// so it is safe against a journal a live server is appending to.
    /// The returned session has no journal attached (mutations after
    /// this are not logged).
    pub fn recover_readonly(path: &Path) -> Result<(Session, RecoveryReport), ServiceError> {
        Self::recover_impl(path, false, SessionOptions::default())
    }

    fn recover_impl(
        path: &Path,
        attach: bool,
        options: SessionOptions,
    ) -> Result<(Session, RecoveryReport), ServiceError> {
        let read = journal::read_journal(path).map_err(|e| ServiceError::Io(e.to_string()))?;
        let empty = || ServiceError::Journal("empty journal".into());
        let header = read.events.first().ok_or_else(empty)?;
        if header.get("ev").and_then(|v| v.as_str()) != Some("create") {
            return Err(ServiceError::Journal(
                "journal does not start with a create event".into(),
            ));
        }
        let id = header
            .get("session")
            .and_then(|v| v.as_str())
            .ok_or_else(|| ServiceError::Journal("create event missing session id".into()))?
            .to_string();
        let spec_json = header
            .get("spec")
            .ok_or_else(|| ServiceError::Journal("create event missing spec".into()))?;
        let spec = ExperimentSpec::from_json(spec_json).map_err(ServiceError::Spec)?;
        let base = header.get("base").and_then(|v| v.as_f64()).unwrap_or(0.0) as usize;
        let tail = &read.events[1..];

        // Newest usable snapshot first: it must belong to this session
        // (id + spec), cover at least the compacted-away prefix, and its
        // state must load cleanly. Anything else falls back — ultimately
        // to full replay when the tail still starts at event 0.
        let candidates = Self::snapshot_candidates(path, &id, &spec, base);
        let mut core = None;
        let mut snapshot_events = 0usize;
        for (events, state) in candidates.iter().rev() {
            let mut fresh = spec.build_core().map_err(ServiceError::Spec)?;
            if fresh.load_state(state).is_ok() {
                core = Some(fresh);
                snapshot_events = *events;
                break;
            }
        }
        let core = match core {
            Some(c) => c,
            None if base == 0 => spec.build_core().map_err(ServiceError::Spec)?,
            None => {
                return Err(ServiceError::Journal(format!(
                    "journal {} is compacted to event {base} but no usable \
                     snapshot covers it",
                    path.display()
                )));
            }
        };

        // Only the load-verified snapshot may anchor future compaction:
        // recording unverified sidecar records here would let a later
        // rotation compact the journal to a boundary covered only by a
        // snapshot that cannot actually be restored.
        let verified = if snapshot_events > 0 {
            vec![snapshot_events]
        } else {
            Vec::new()
        };
        let mut session = Session {
            id,
            spec,
            core,
            journal: None,
            events_written: 0,
            events_total: (base + tail.len()).max(snapshot_events),
            base,
            snapshots: verified,
            options,
            snapshot_error: None,
            poisoned: false,
            group_commit: false,
            ingested: false,
            store_error: None,
            asks_journaled: None,
            shipping: false,
            ship_queue: Vec::new(),
            leases: HashMap::new(),
        };
        // before replay: replayed events re-increment the same counters a
        // live run would, so post-recovery metrics match the journal
        session.attach_obs();
        let mut replayed = 0usize;
        let mut skipped = 0usize;
        for (i, ev) in tail.iter().enumerate() {
            // absolute index of this event in the session's history
            if base + 1 + i <= snapshot_events {
                skipped += 1;
                continue;
            }
            session.replay_event(ev).map_err(|e| {
                ServiceError::Journal(format!("event {} of {}: {e}", base + 1 + i, path.display()))
            })?;
            replayed += 1;
        }
        if attach {
            let mut j = Journal::open_append_at(path, read.valid_len)
                .map_err(|e| ServiceError::Io(e.to_string()))?;
            j.set_obs(&session.id);
            session.journal = Some(j);
        }
        // replayed events are already on disk; the counter tracks only
        // what this process appends from here on
        session.events_written = 0;
        Ok((
            session,
            RecoveryReport {
                events_replayed: replayed,
                truncated_bytes: read.truncated_bytes,
                snapshot_events,
                events_skipped: skipped,
            },
        ))
    }

    /// Snapshot records usable for recovering this journal, ascending by
    /// coverage: right session, identical spec, coverage at or past the
    /// compacted-away prefix.
    fn snapshot_candidates(
        path: &Path,
        id: &str,
        spec: &ExperimentSpec,
        base: usize,
    ) -> Vec<(usize, Json)> {
        journal::read_snapshots(&journal::snapshot_path(path))
            .into_iter()
            .filter_map(|line| {
                if line.get("ev").and_then(|v| v.as_str()) != Some("snapshot") {
                    return None;
                }
                if line.get("session").and_then(|v| v.as_str()) != Some(id) {
                    return None;
                }
                let line_spec = ExperimentSpec::from_json(line.get("spec")?).ok()?;
                if line_spec != *spec {
                    return None;
                }
                let events = line.get("events").and_then(|v| v.as_f64())? as usize;
                if events < base {
                    return None;
                }
                Some((events, line.get("state")?.clone()))
            })
            .collect::<Vec<(usize, Json)>>()
            .into_iter()
            .collect::<std::collections::BTreeMap<usize, Json>>()
            .into_iter()
            .collect()
    }

    fn replay_event(&mut self, ev: &Json) -> Result<(), String> {
        match ev.get("ev").and_then(|v| v.as_str()) {
            Some("ask") => {
                let worker = ev
                    .get("worker")
                    .and_then(|v| v.as_str())
                    .ok_or("ask event missing worker")?;
                let recorded = ev.get("resp").ok_or("ask event missing resp")?;
                let replayed = assignment_json(&self.core.ask(worker));
                if let Some(c) = &self.asks_journaled {
                    c.inc();
                }
                if replayed != *recorded {
                    return Err(format!(
                        "replay divergence: journal acknowledged {} but replay produced {}",
                        recorded.to_string_compact(),
                        replayed.to_string_compact()
                    ));
                }
                Ok(())
            }
            Some("tell") => {
                let num = |key: &str| -> Result<f64, String> {
                    ev.get(key)
                        .and_then(|v| v.as_f64())
                        .ok_or_else(|| format!("tell event missing '{key}'"))
                };
                let trial = num("trial")? as TrialId;
                let epoch = num("epoch")? as u32;
                // NaN metrics journal as `null`; read them back as NaN.
                let metric = ev.get("metric").and_then(|v| v.as_f64()).unwrap_or(f64::NAN);
                // A tell that errored when live errors identically on
                // replay; both are state no-ops, so ignore the result.
                let _ = self.core.tell(trial, epoch, metric);
                Ok(())
            }
            Some("fail") => {
                let trial = ev
                    .get("trial")
                    .and_then(|v| v.as_f64())
                    .ok_or("fail event missing trial")? as TrialId;
                let _ = self.core.fail(trial);
                Ok(())
            }
            Some("expire") => {
                // with a worker field: one lease expired (the per-shard
                // tick); argless: every worker (the legacy operator op)
                match ev.get("worker").and_then(|v| v.as_str()) {
                    Some(w) => {
                        self.core.expire_worker(w);
                    }
                    None => {
                        self.core.expire_workers();
                    }
                }
                Ok(())
            }
            other => Err(format!("unknown journal event {other:?}")),
        }
    }

    fn append(&mut self, ev: &Json) -> Result<(), ServiceError> {
        if let Some(j) = self.journal.as_mut() {
            if let Err(e) = j.append(ev) {
                self.poisoned = true;
                return Err(ServiceError::Io(format!(
                    "journal append failed, session '{}' poisoned: {e}",
                    self.id
                )));
            }
        }
        self.events_written += 1;
        self.events_total += 1;
        Ok(())
    }

    /// Switch the session's journal into (or out of) group-commit mode
    /// (see `Journal::set_group_commit`). The served event loop turns
    /// this on; standalone and embedded sessions stay write-through.
    pub fn set_group_commit(&mut self, on: bool) -> Result<(), ServiceError> {
        self.group_commit = on;
        if let Some(j) = self.journal.as_mut() {
            if let Err(e) = j.set_group_commit(on) {
                self.poisoned = true;
                return Err(ServiceError::Io(format!(
                    "journal mode switch failed, session '{}' poisoned: {e}",
                    self.id
                )));
            }
        }
        Ok(())
    }

    /// Force the current commit group to disk: one write + one
    /// `sync_all` covering every event journaled since the last commit.
    /// Responses for those ops may only be released after this returns
    /// `Ok`. Failure poisons the session — the ops were applied in
    /// memory but their durability cannot be vouched for.
    pub fn commit_journal(&mut self) -> Result<(), ServiceError> {
        if let Some(j) = self.journal.as_mut() {
            if let Err(e) = j.commit() {
                self.poisoned = true;
                return Err(ServiceError::Io(format!(
                    "journal commit failed, session '{}' poisoned: {e}",
                    self.id
                )));
            }
            // fsync-then-ship: only bytes the commit above made durable
            // are ever handed to the replication layer
            if self.shipping {
                if let Some((base, bytes)) = j.take_shipped() {
                    let name = Self::file_name(j.path());
                    self.ship_queue.push(ShipFrame::group(&name, base, bytes));
                }
            }
        }
        Ok(())
    }

    /// Journal file name used as the replication frame key (`s0000.jsonl`).
    fn file_name(path: &Path) -> String {
        path.file_name()
            .map(|n| n.to_string_lossy().into_owned())
            .unwrap_or_else(|| path.display().to_string())
    }

    /// Turn replication shipping on (or off). Enabling queues full-file
    /// rebase frames for the journal and its snapshot sidecar so a
    /// subscriber starts from an exact byte-level copy, then every
    /// subsequent [`Session::commit_journal`] queues the durable commit
    /// group as an incremental frame. Observe-only: nothing about the
    /// journal's own bytes or fsync schedule changes.
    pub fn set_shipping(&mut self, on: bool) -> Result<(), ServiceError> {
        self.shipping = on;
        if !on {
            self.ship_queue.clear();
            return Ok(());
        }
        self.queue_rebase()
    }

    /// Queue full-file frames positioning a (new) subscriber at the
    /// journal's current durable state. Commits first so the shipped
    /// bytes are exactly the file's; any incremental bytes retained but
    /// not yet taken are folded into the full frame and dropped.
    fn queue_rebase(&mut self) -> Result<(), ServiceError> {
        let Some(path) = self.journal.as_ref().map(|j| j.path().to_path_buf()) else {
            return Ok(());
        };
        self.commit_journal()?;
        let name = Self::file_name(&path);
        if let Some(j) = self.journal.as_mut() {
            j.enable_shipping();
            let _ = j.take_shipped();
        }
        self.ship_queue.retain(|f| f.journal != name);
        let bytes = std::fs::read(&path).map_err(|e| ServiceError::Io(e.to_string()))?;
        self.ship_queue.push(ShipFrame::journal_full(&name, bytes));
        let snap_path = journal::snapshot_path(&path);
        if let Ok(bytes) = std::fs::read(&snap_path) {
            self.ship_queue.push(ShipFrame::snap_full(&name, bytes));
        }
        Ok(())
    }

    /// Drain the frames queued since the last drain, in apply order.
    pub fn drain_ship_frames(&mut self) -> Vec<ShipFrame> {
        std::mem::take(&mut self.ship_queue)
    }

    /// Events appended since creation/recovery (journal-less sessions
    /// count the appends they would have made).
    pub fn events_journaled(&self) -> usize {
        self.events_written
    }

    /// Absolute event count since session creation, across restarts and
    /// compactions.
    pub fn events_total(&self) -> usize {
        self.events_total
    }

    /// Absolute event counts of the trusted snapshots (see the field
    /// docs: written by this session, or load-verified at recovery).
    pub fn snapshots(&self) -> &[usize] {
        &self.snapshots
    }

    /// Write a snapshot if the policy says one is due. Runs *after* the
    /// triggering operation has fully applied, so the captured state is
    /// exactly "all events ≤ `events_total`". Snapshot failures never
    /// fail the acknowledged operation: the journal is authoritative —
    /// the error is recorded and snapshotting disabled.
    fn maybe_snapshot(&mut self) {
        let Some(every) = self.options.snapshot_every else {
            return;
        };
        if self.journal.is_none() {
            return;
        }
        let last = self.snapshots.last().copied().unwrap_or(0);
        if self.events_total < last + every {
            return;
        }
        if let Err(e) = self.write_snapshot() {
            self.snapshot_error = Some(e.to_string());
            self.options.snapshot_every = None;
        }
    }

    /// Append a snapshot record covering every event so far, then (per
    /// policy) rotate: compact the journal tail to the previous
    /// snapshot's boundary and trim the sidecar to the last two records.
    fn write_snapshot(&mut self) -> Result<(), ServiceError> {
        let Some(journal_path) = self.journal.as_ref().map(|j| j.path().to_path_buf()) else {
            return Ok(());
        };
        let Some(state) = self.core.save_state() else {
            // scheduler/searcher without a codec: recovery stays full
            // replay for this session, silently
            self.options.snapshot_every = None;
            return Ok(());
        };
        let snap_path = journal::snapshot_path(&journal_path);
        let record = ev_snapshot(&self.id, self.events_total, &self.spec.to_json(), state);
        journal::append_line(&snap_path, &record).map_err(|e| ServiceError::Io(e.to_string()))?;
        self.snapshots.push(self.events_total);
        if self.options.compact_on_snapshot {
            // lag by one snapshot: if this record is torn on disk, the
            // previous one plus the longer tail still recovers
            if self.snapshots.len() >= 2 {
                let new_base = self.snapshots[self.snapshots.len() - 2];
                self.compact_tail_to(&journal_path, new_base)?;
            }
            self.trim_sidecar(&snap_path, 2)?;
        }
        // ship the sidecar as it finally stands (post-trim), so the
        // follower's copy stays a byte-level mirror
        self.queue_snap_frame(&journal_path, &snap_path);
        Ok(())
    }

    /// Queue a full-sidecar replication frame (no-op when shipping is
    /// off or the sidecar is unreadable — snapshots are an optimization,
    /// the journal frames alone keep the follower recoverable).
    fn queue_snap_frame(&mut self, journal_path: &Path, snap_path: &Path) {
        if !self.shipping {
            return;
        }
        if let Ok(bytes) = std::fs::read(snap_path) {
            let name = Self::file_name(journal_path);
            self.ship_queue.push(ShipFrame::snap_full(&name, bytes));
        }
    }

    /// Rewrite the journal tail atomically so it starts at absolute event
    /// `new_base` (which a durable snapshot must cover), then re-open the
    /// append handle. A crash before the rename leaves the old tail; a
    /// crash after leaves the new one — both recover.
    fn compact_tail_to(&mut self, path: &Path, new_base: usize) -> Result<(), ServiceError> {
        if new_base <= self.base {
            return Ok(());
        }
        let io_err = |e: std::io::Error| ServiceError::Io(e.to_string());
        // push buffered group-commit lines into the file first: the
        // rewrite below re-reads the file from disk and replaces the
        // append handle, so userspace-buffered bytes would be lost
        if let Some(j) = self.journal.as_mut() {
            j.flush().map_err(io_err)?;
        }
        let read = journal::read_journal(path).map_err(io_err)?;
        let tail = &read.events[1..];
        let drop_count = new_base - self.base;
        if drop_count > tail.len() {
            return Err(ServiceError::Journal(format!(
                "cannot compact to event {new_base}: tail only reaches {}",
                self.base + tail.len()
            )));
        }
        let mut lines = Vec::with_capacity(1 + tail.len() - drop_count);
        lines.push(ev_create_at(&self.id, &self.spec.to_json(), new_base));
        lines.extend_from_slice(&tail[drop_count..]);
        journal::rewrite_atomic(path, &lines).map_err(io_err)?;
        let len = std::fs::metadata(path).map_err(io_err)?.len();
        let mut fresh = Journal::open_append_at(path, len).map_err(io_err)?;
        if self.group_commit {
            fresh.set_group_commit(true).map_err(io_err)?;
        }
        fresh.set_obs(&self.id);
        self.journal = Some(fresh);
        self.base = new_base;
        // the rewrite invalidated any follower's byte-level copy; queue a
        // full-file rebase so replication survives handle replacement
        if self.shipping {
            self.queue_rebase()?;
        }
        Ok(())
    }

    /// Keep only the newest `keep` snapshot records in the sidecar.
    fn trim_sidecar(&mut self, snap_path: &Path, keep: usize) -> Result<(), ServiceError> {
        if self.snapshots.len() <= keep {
            return Ok(());
        }
        let cutoff = self.snapshots[self.snapshots.len() - keep];
        let retained: Vec<Json> = journal::read_snapshots(snap_path)
            .into_iter()
            .filter(|line| {
                line.get("events")
                    .and_then(|v| v.as_f64())
                    .map(|e| e as usize >= cutoff)
                    .unwrap_or(false)
            })
            .collect();
        journal::rewrite_atomic(snap_path, &retained)
            .map_err(|e| ServiceError::Io(e.to_string()))?;
        self.snapshots.retain(|&e| e >= cutoff);
        Ok(())
    }

    /// Fully compact this session right now: write a snapshot covering
    /// everything, truncate the journal tail to just the header, and trim
    /// the sidecar to the final two records. What `pasha compact` runs.
    /// Errors if the scheduler/searcher has no snapshot codec.
    pub fn compact_now(&mut self) -> Result<(), ServiceError> {
        let Some(journal_path) = self.journal.as_ref().map(|j| j.path().to_path_buf()) else {
            return Err(ServiceError::Journal(
                "session has no journal attached".into(),
            ));
        };
        let Some(state) = self.core.save_state() else {
            return Err(ServiceError::Journal(format!(
                "scheduler '{}' does not support snapshots",
                self.core.scheduler_name()
            )));
        };
        let snap_path = journal::snapshot_path(&journal_path);
        let record = ev_snapshot(&self.id, self.events_total, &self.spec.to_json(), state);
        journal::append_line(&snap_path, &record).map_err(|e| ServiceError::Io(e.to_string()))?;
        self.snapshots.push(self.events_total);
        self.compact_tail_to(&journal_path, self.events_total)?;
        self.trim_sidecar(&snap_path, 2)?;
        self.queue_snap_frame(&journal_path, &snap_path);
        Ok(())
    }

    fn check_poisoned(&self) -> Result<(), ServiceError> {
        if self.poisoned {
            Err(ServiceError::Journal(format!(
                "session '{}' is poisoned (an earlier journal append failed)",
                self.id
            )))
        } else {
            Ok(())
        }
    }

    /// Ask for work on behalf of `worker`. Mutating asks are journaled
    /// before being returned — including `Wait` answers that parked a
    /// scheduler-emitted job (the mutation-count check), which must
    /// replay for recovery to stay byte-identical.
    pub fn ask(&mut self, worker: &str) -> Result<TrialAssignment, ServiceError> {
        self.check_poisoned()?;
        self.leases.insert(worker.to_string(), Instant::now());
        let before = self.core.mutation_count();
        let assignment = self.core.ask(worker);
        if assignment.is_mutation() || self.core.mutation_count() != before {
            self.append(&ev_ask(worker, assignment_json(&assignment)))?;
            if let Some(c) = &self.asks_journaled {
                c.inc();
            }
            self.maybe_snapshot();
        }
        if matches!(assignment, TrialAssignment::Done) {
            self.maybe_ingest();
        }
        Ok(assignment)
    }

    /// On the first `Done`, record the session's completed trials into
    /// the configured store (if any). Replay during recovery goes through
    /// the core directly, so a recovered-then-re-asked session ingests at
    /// most once more — the store is append-only with at-least-once
    /// semantics, and `gc` deduplicates. Failures never fail the ask.
    fn maybe_ingest(&mut self) {
        if self.ingested || self.store_error.is_some() {
            return;
        }
        let Some(store) = self.options.store.clone() else {
            return;
        };
        match store::ingest(&store, &self.spec, self.core.trials()) {
            Ok(_) => self.ingested = true,
            Err(e) => self.store_error = Some(e),
        }
    }

    /// Report one epoch's metric. Journaled before it is applied, so an
    /// acknowledged tell is always recoverable.
    pub fn tell(
        &mut self,
        trial: TrialId,
        epoch: u32,
        metric: f64,
    ) -> Result<TellAck, ServiceError> {
        self.check_poisoned()?;
        self.touch_lease_of(trial);
        self.append(&ev_tell(trial, epoch, metric))?;
        let ack = self.core.tell(trial, epoch, metric).map_err(ServiceError::Session);
        self.maybe_snapshot();
        ack
    }

    /// A worker reported failure while running `trial`.
    pub fn fail(&mut self, trial: TrialId) -> Result<(), ServiceError> {
        self.check_poisoned()?;
        self.touch_lease_of(trial);
        self.append(&ev_fail(trial))?;
        let r = self.core.fail(trial).map_err(ServiceError::Session);
        self.maybe_snapshot();
        r
    }

    /// Refresh the lease of whichever worker holds `trial` — a `tell`
    /// or `fail` proves that worker alive even though neither op names
    /// it on the wire.
    fn touch_lease_of(&mut self, trial: TrialId) {
        if let Some(w) = self.core.worker_of(trial) {
            let w = w.to_string();
            self.leases.insert(w, Instant::now());
        }
    }

    /// Retire all in-flight jobs (operator action after worker loss).
    pub fn expire_workers(&mut self) -> Result<usize, ServiceError> {
        self.check_poisoned()?;
        self.append(&ev_expire())?;
        let n = self.core.expire_workers();
        self.leases.clear();
        self.maybe_snapshot();
        Ok(n)
    }

    /// Expire one worker's lease: its in-flight jobs re-queue (handed
    /// deterministically to the next asking worker) and its pending
    /// directives drop. Journaled like every other mutation.
    pub fn expire_worker(&mut self, worker: &str) -> Result<usize, ServiceError> {
        self.check_poisoned()?;
        self.append(&ev_expire_worker(worker))?;
        let n = self.core.expire_worker(worker);
        self.leases.remove(worker);
        self.maybe_snapshot();
        Ok(n)
    }

    /// The per-shard liveness tick: expire every worker not seen for
    /// `lease` that still holds work. Workers are expired in name order
    /// so the journal (and therefore replay) is deterministic; idle
    /// stale workers are forgotten without a journal event. A poisoned
    /// session is skipped, not an error — the tick must never kill the
    /// shard loop.
    pub fn expire_stale(&mut self, lease: Duration) -> Result<Vec<String>, ServiceError> {
        if self.poisoned || self.leases.is_empty() {
            return Ok(Vec::new());
        }
        let now = Instant::now();
        let core = &self.core;
        let mut stale: Vec<String> = self
            .leases
            .iter()
            .filter(|(w, t)| now.duration_since(**t) >= lease && core.worker_busy(w))
            .map(|(w, _)| w.clone())
            .collect();
        stale.sort();
        for w in &stale {
            self.expire_worker(w)?;
        }
        self.leases.retain(|_, t| now.duration_since(*t) < lease);
        Ok(stale)
    }

    /// Read-only status summary (what `pasha sessions` renders).
    pub fn status(&self) -> Json {
        let snap = self.core.snapshot();
        let stats = self.core.stats();
        let mut o = Json::obj();
        o.set("id", self.id.as_str())
            // prefer the v1 shape when the spec is representable there,
            // so pre-redesign workers read the right benchmark during a
            // rolling upgrade; v2-only sessions (which old clients could
            // never have created) carry the v2 shape
            .set(
                "spec",
                self.spec
                    .to_v1_compat_json()
                    .unwrap_or_else(|| self.spec.to_json()),
            )
            .set("scheduler", self.core.scheduler_name())
            .set("configs_sampled", snap.configs_sampled)
            .set("jobs_dispatched", snap.jobs_dispatched)
            .set("jobs_completed", snap.jobs_completed)
            .set("epochs_completed", snap.epochs_completed as f64)
            .set("in_flight", self.core.in_flight_count())
            .set("cancelled_jobs", stats.cancelled_jobs)
            .set("failed_jobs", stats.failed_jobs)
            .set("stopped_trials", stats.stopped_trials)
            .set("paused_trials", stats.paused_trials)
            .set("max_resources", self.core.max_resources_used())
            .set("trials", self.core.trials().len())
            .set("events_total", self.events_total)
            .set("snapshots", self.snapshots.len())
            .set(
                "snapshot_events",
                self.snapshots.last().copied().unwrap_or(0),
            );
        if let Some(e) = &self.snapshot_error {
            o.set("snapshot_error", e.as_str());
        }
        if let Some(e) = &self.store_error {
            o.set("store_error", e.as_str());
        }
        match self.core.best() {
            Some(b) => {
                o.set("best_trial", b.trial)
                    .set("best_metric", b.metric)
                    .set("best_config", config_json(&b.config));
            }
            None => {
                o.set("best_metric", Json::Null);
            }
        }
        o
    }

    pub fn core(&mut self) -> &mut AskTell {
        &mut self.core
    }

    pub fn core_ref(&self) -> &AskTell {
        &self.core
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::benchmarks::Benchmark;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("pasha-session-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    fn small_spec() -> ExperimentSpec {
        let mut spec = ExperimentSpec::named("lcbench-Fashion-MNIST", "asha").unwrap();
        spec.stop.config_budget = 8;
        spec
    }

    /// Drive a session to completion with one synchronous worker.
    fn drive(session: &mut Session, bench: &dyn Benchmark, bench_seed: u64) {
        loop {
            match session.ask("w0").unwrap() {
                TrialAssignment::Run(job) => {
                    for e in job.from_epoch + 1..=job.milestone {
                        let m = bench.accuracy_at(&job.config, e, bench_seed);
                        if session.tell(job.trial, e, m).unwrap() == TellAck::Abandon {
                            break;
                        }
                    }
                }
                TrialAssignment::Stop(_) | TrialAssignment::Pause(_) => {}
                TrialAssignment::Wait => panic!("single worker never waits"),
                TrialAssignment::Done => return,
            }
        }
    }

    #[test]
    fn spec_json_roundtrip() {
        let mut spec = ExperimentSpec::named("pd1-wmt", "pasha-stop").unwrap();
        spec.set("scheduler.eta=4").unwrap();
        spec.set("searcher.name=bo").unwrap();
        spec.seed = 42;
        spec.bench_seed = 7;
        spec.stop.config_budget = 99;
        spec.stop.epoch_budget = Some(1234);
        let j = spec.to_json();
        let back = ExperimentSpec::from_json(&j).unwrap();
        assert_eq!(spec, back);
        // sparse v1 payloads (old journal headers) still parse, with the
        // legacy defaults filling the gaps
        let sparse = crate::util::json::parse("{\"bench\":\"nas-cifar100\"}").unwrap();
        let s = ExperimentSpec::from_json(&sparse).unwrap();
        assert_eq!(s.bench.name, "nas-cifar100");
        assert_eq!(s.stop.config_budget, 256);
        assert!(s.stop.epoch_budget.is_none());
    }

    #[test]
    fn full_session_recovers_to_done_state() {
        let path = tmp("full.jsonl");
        let spec = small_spec();
        let bench = spec.bench.build().unwrap();
        let mut s = Session::create("s0", spec.clone(), Some(&path)).unwrap();
        drive(&mut s, bench.as_ref(), spec.bench_seed);
        let best = s.core_ref().best().unwrap();
        drop(s);

        let (mut r, report) = Session::recover(&path).unwrap();
        assert_eq!(report.truncated_bytes, 0);
        assert!(report.events_replayed > 0);
        assert_eq!(r.id, "s0");
        assert_eq!(r.spec, spec);
        let rbest = r.core_ref().best().unwrap();
        assert_eq!(rbest.trial, best.trial);
        assert_eq!(rbest.metric.to_bits(), best.metric.to_bits());
        assert_eq!(r.ask("w0").unwrap(), TrialAssignment::Done);
    }

    #[test]
    fn readonly_recovery_never_touches_the_file() {
        let path = tmp("readonly.jsonl");
        let spec = small_spec();
        let bench = spec.bench.build().unwrap();
        let mut s = Session::create("s0", spec.clone(), Some(&path)).unwrap();
        drive(&mut s, bench.as_ref(), spec.bench_seed);
        drop(s);
        // leave a torn tail in place: readonly recovery must not trim it
        let mut bytes = std::fs::read(&path).unwrap();
        bytes.extend_from_slice(b"{\"ev\":\"tell\",\"tri");
        std::fs::write(&path, &bytes).unwrap();
        let (mut r, report) = Session::recover_readonly(&path).unwrap();
        assert!(report.truncated_bytes > 0);
        assert_eq!(r.ask("w0").unwrap(), TrialAssignment::Done);
        assert_eq!(std::fs::read(&path).unwrap(), bytes, "file untouched");
    }

    #[test]
    fn recovery_detects_foreign_journal() {
        // A journal whose asks were produced under a different seed must
        // be refused, not silently mis-replayed.
        let path_a = tmp("seed-a.jsonl");
        let spec_a = small_spec();
        let bench = spec_a.bench.build().unwrap();
        let mut a = Session::create("sa", spec_a.clone(), Some(&path_a)).unwrap();
        drive(&mut a, bench.as_ref(), spec_a.bench_seed);
        drop(a);
        // swap the header's seed so replay draws different configs
        let text = std::fs::read_to_string(&path_a).unwrap();
        let mut lines: Vec<&str> = text.lines().collect();
        let doctored = lines[0].replace("\"seed\":0", "\"seed\":1");
        lines[0] = &doctored;
        let path_b = tmp("seed-b.jsonl");
        std::fs::write(&path_b, lines.join("\n") + "\n").unwrap();
        let err = match Session::recover(&path_b) {
            Ok(_) => panic!("recovery must fail"),
            Err(e) => e,
        };
        match err {
            ServiceError::Journal(msg) => assert!(msg.contains("divergence"), "{msg}"),
            other => panic!("expected divergence error, got {other:?}"),
        }
    }

    #[test]
    fn status_shape() {
        let mut s = Session::create("s1", small_spec(), None).unwrap();
        let st = s.status();
        assert_eq!(st.get("id").unwrap().as_str(), Some("s1"));
        assert_eq!(st.get("configs_sampled").unwrap().as_f64(), Some(0.0));
        assert_eq!(st.get("best_metric"), Some(&Json::Null));
        // after some work the best appears
        let bench = crate::spec::BenchSpec::new("lcbench-Fashion-MNIST").build().unwrap();
        if let TrialAssignment::Run(job) = s.ask("w0").unwrap() {
            for e in job.from_epoch + 1..=job.milestone {
                let m = bench.accuracy_at(&job.config, e, 0);
                s.tell(job.trial, e, m).unwrap();
            }
        } else {
            panic!("expected a job");
        }
        let st = s.status();
        assert!(st.get("best_metric").unwrap().as_f64().is_some());
        assert_eq!(st.get("jobs_completed").unwrap().as_f64(), Some(1.0));
    }

    #[test]
    fn snapshot_rotation_keeps_recovery_o_tail() {
        let path = tmp("snap-cycle.jsonl");
        let spec = small_spec();
        let bench = spec.bench.build().unwrap();
        let options = SessionOptions::snapshot_every(8);
        let mut s = Session::create_with("s0", spec.clone(), Some(&path), options).unwrap();
        drive(&mut s, bench.as_ref(), spec.bench_seed);
        let total = s.events_total();
        let best = s.core_ref().best().unwrap();
        assert!(s.snapshots().len() >= 2, "rotation keeps the last two");
        assert!(s.snapshots().len() <= 2, "older snapshots are trimmed");
        drop(s);

        let (mut r, report) = Session::recover(&path).unwrap();
        assert!(report.snapshot_events > 0, "recovery used a snapshot");
        assert!(
            report.events_replayed < total,
            "replayed {} of {total}",
            report.events_replayed
        );
        assert_eq!(
            report.snapshot_events + report.events_replayed,
            total,
            "snapshot coverage plus replayed tail is the whole history"
        );
        let rbest = r.core_ref().best().unwrap();
        assert_eq!(rbest.trial, best.trial);
        assert_eq!(rbest.metric.to_bits(), best.metric.to_bits());
        assert_eq!(r.ask("w0").unwrap(), TrialAssignment::Done);
    }

    #[test]
    fn torn_final_snapshot_falls_back_to_previous() {
        let path = tmp("snap-torn.jsonl");
        let spec = small_spec();
        let bench = spec.bench.build().unwrap();
        // compaction off: the full tail stays available for any fallback
        let options = SessionOptions {
            snapshot_every: Some(8),
            compact_on_snapshot: false,
            ..SessionOptions::default()
        };
        let mut s = Session::create_with("s0", spec.clone(), Some(&path), options).unwrap();
        drive(&mut s, bench.as_ref(), spec.bench_seed);
        let total = s.events_total();
        let best = s.core_ref().best().unwrap();
        let snaps = s.snapshots().to_vec();
        assert!(snaps.len() >= 2, "need two snapshots to demonstrate fallback");
        drop(s);

        // tear the final snapshot record mid-line
        let snap_path = journal::snapshot_path(&path);
        let bytes = std::fs::read(&snap_path).unwrap();
        std::fs::write(&snap_path, &bytes[..bytes.len() - 9]).unwrap();
        let (r, report) = Session::recover_readonly(&path).unwrap();
        assert_eq!(
            report.snapshot_events,
            snaps[snaps.len() - 2],
            "previous snapshot used"
        );
        assert_eq!(report.events_replayed, total - report.snapshot_events);
        let rbest = r.core_ref().best().unwrap();
        assert_eq!(rbest.metric.to_bits(), best.metric.to_bits());

        // destroy the sidecar entirely: full replay still works
        std::fs::write(&snap_path, b"garbage\n").unwrap();
        let (_, report) = Session::recover_readonly(&path).unwrap();
        assert_eq!(report.snapshot_events, 0);
        assert_eq!(report.events_replayed, total);
    }

    #[test]
    fn compact_now_truncates_tail_to_header() {
        let path = tmp("compact-now.jsonl");
        let spec = small_spec();
        let bench = spec.bench.build().unwrap();
        let mut s = Session::create("s0", spec.clone(), Some(&path)).unwrap();
        drive(&mut s, bench.as_ref(), spec.bench_seed);
        let total = s.events_total();
        let best = s.core_ref().best().unwrap();
        let before = std::fs::metadata(&path).unwrap().len();
        s.compact_now().unwrap();
        drop(s);
        let after = std::fs::metadata(&path).unwrap().len();
        assert!(after < before, "tail shrank: {before} -> {after}");
        let read = journal::read_journal(&path).unwrap();
        assert_eq!(read.events.len(), 1, "header only");
        let (r, report) = Session::recover_readonly(&path).unwrap();
        assert_eq!(report.snapshot_events, total);
        assert_eq!(report.events_replayed, 0, "nothing to replay past the snapshot");
        assert_eq!(report.events_skipped, 0, "nothing pre-snapshot on disk");
        let rbest = r.core_ref().best().unwrap();
        assert_eq!(rbest.metric.to_bits(), best.metric.to_bits());
    }

    #[test]
    fn group_commit_session_journal_bytes_match_write_through() {
        let path_g = tmp("group-mode.jsonl");
        let path_w = tmp("write-through.jsonl");
        let spec = small_spec();
        let bench = spec.bench.build().unwrap();
        let mut g = Session::create("s0", spec.clone(), Some(&path_g)).unwrap();
        g.set_group_commit(true).unwrap();
        let mut w = Session::create("s0", spec.clone(), Some(&path_w)).unwrap();
        drive(&mut g, bench.as_ref(), spec.bench_seed);
        g.commit_journal().unwrap();
        drive(&mut w, bench.as_ref(), spec.bench_seed);
        drop(g);
        drop(w);
        assert_eq!(
            std::fs::read(&path_g).unwrap(),
            std::fs::read(&path_w).unwrap(),
            "group-commit mode changes when bytes hit disk, never the bytes"
        );
        let (mut r, _) = Session::recover(&path_g).unwrap();
        assert_eq!(r.ask("w0").unwrap(), TrialAssignment::Done);
    }

    #[test]
    fn group_commit_survives_snapshot_compaction() {
        let path = tmp("group-snap.jsonl");
        let spec = small_spec();
        let bench = spec.bench.build().unwrap();
        let mut s = Session::create_with(
            "s0",
            spec.clone(),
            Some(&path),
            SessionOptions::snapshot_every(8),
        )
        .unwrap();
        s.set_group_commit(true).unwrap();
        drive(&mut s, bench.as_ref(), spec.bench_seed);
        let best = s.core_ref().best().unwrap();
        assert!(s.snapshots().len() >= 2, "rotation ran under group mode");
        s.commit_journal().unwrap();
        drop(s);
        let (r, report) = Session::recover(&path).unwrap();
        assert!(report.snapshot_events > 0, "recovery used a snapshot");
        let rbest = r.core_ref().best().unwrap();
        assert_eq!(rbest.metric.to_bits(), best.metric.to_bits());
    }

    #[test]
    fn warm_started_session_ingests_and_recovers_byte_identically() {
        use crate::spec::SearcherSpec;

        let store_path = tmp("session-store.jsonl");
        let _ = std::fs::remove_file(&store_path);
        let options = SessionOptions {
            store: Some(StoreSpec::new(&store_path)),
            ..SessionOptions::default()
        };

        // Cold session with a store attached: reaching Done ingests its
        // completed trials.
        let spec = small_spec();
        let bench = spec.bench.build().unwrap();
        let path_cold = tmp("warm-source.jsonl");
        let mut cold =
            Session::create_with("cold", spec.clone(), Some(&path_cold), options.clone()).unwrap();
        drive(&mut cold, bench.as_ref(), spec.bench_seed);
        drop(cold);
        let recorded = store::TrialStore::open(&store_path).read_all().unwrap();
        assert!(!recorded.is_empty(), "Done must ingest trials");

        // Warm session: creation seals the reference, so the journal
        // header embeds the prior observations.
        let mut warm_spec = spec.clone();
        warm_spec.seed = 1;
        warm_spec.searcher = SearcherSpec::bo_warm(store_path.to_str().unwrap(), 4);
        let path_warm = tmp("warm-target.jsonl");
        let mut warm =
            Session::create_with("warm", warm_spec, Some(&path_warm), options).unwrap();
        let sealed = warm.spec.searcher.warm_start().unwrap();
        let embedded = sealed.trials.as_ref().expect("create seals the spec").len();
        assert!(embedded > 0, "prior trials embedded");
        drive(&mut warm, bench.as_ref(), spec.bench_seed);
        let best = warm.core_ref().best().unwrap();
        drop(warm);

        // Mutate the store after the fact: recovery must not care — the
        // ask-replay byte-identity check passes from the header alone.
        std::fs::remove_file(&store_path).unwrap();
        let (mut r, report) = Session::recover(&path_warm).unwrap();
        assert!(report.events_replayed > 0);
        let rbest = r.core_ref().best().unwrap();
        assert_eq!(rbest.trial, best.trial);
        assert_eq!(rbest.metric.to_bits(), best.metric.to_bits());
        assert_eq!(r.ask("w0").unwrap(), TrialAssignment::Done);

        let _ = std::fs::remove_file(&path_cold);
        let _ = std::fs::remove_file(&path_warm);
    }

    #[test]
    fn bad_spec_is_rejected() {
        let spec = ExperimentSpec {
            bench: crate::spec::BenchSpec::new("no-such-bench"),
            ..ExperimentSpec::default()
        };
        let err = match Session::create("x", spec, None) {
            Ok(_) => panic!("bad spec must fail"),
            Err(e) => e,
        };
        match err {
            ServiceError::Spec(msg) => assert!(msg.contains("no-such-bench")),
            other => panic!("expected spec error, got {other:?}"),
        }
    }
}
