//! Journal streaming replication: ship durable commit groups to a
//! follower process, plus the thin session router that makes N serving
//! processes look like one endpoint to workers.
//!
//! Topology:
//!
//! ```text
//!   workers ──▶ pasha route ──▶ pasha serve --replicate :r  (leader)
//!                                   │  journal fsync, then ship
//!                                   ▼
//!                               pasha follow :r --journal-dir
//!                                   (byte-identical journal copy)
//! ```
//!
//! The unit of replication is the **[`ShipFrame`]**: either one durable
//! commit group (the exact bytes the leader just fsynced, tagged with
//! the file offset they start at) or a full-file rebase (journal or
//! snapshot sidecar) that positions a subscriber at the leader's current
//! byte-level state. The leader ships frames strictly *after* the
//! group's `sync_all` ([`crate::service::journal::Journal::take_shipped`]),
//! so a follower can never hold bytes the leader might lose; the
//! follower appends byte-identically, fsyncs, and acks by file offset.
//!
//! Failover is ordinary recovery: promote the follower by serving its
//! journal directory (`pasha serve --journal-dir <follower-dir>`). The
//! ask-replay byte-identity verification that guards every recovery is
//! the correctness oracle here too, now across a process boundary — a
//! diverged copy refuses to serve rather than serving wrong answers.
//!
//! Everything speaks the service's existing newline-JSON wire: the
//! follower subscribes with `{"cmd":"sub"}` on the leader's replication
//! listener, frames arrive as `{"cmd":"repl",...}` lines, and acks flow
//! back as plain JSON lines. Replication is observe-only for the
//! leader: journal bytes, fsync schedule, and responses are identical
//! with it on or off.

use crate::service::registry::fnv1a64;
use crate::spec::RouteSpec;
use crate::util::json::{self, Json};
use std::collections::HashMap;
use std::fs::{File, OpenOptions};
use std::io::{self, BufRead, BufReader, Seek, SeekFrom, Write};
use std::net::{TcpListener, TcpStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// What one replication frame carries.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShipKind {
    /// One durable commit group: append `bytes` at offset `base` of the
    /// journal file (the follower's copy must be exactly `base` long).
    Group,
    /// Full journal rebase: atomically replace the follower's journal
    /// file with `bytes` (sent at subscribe time and after compaction
    /// rewrites the leader's file).
    JournalFull,
    /// Full snapshot-sidecar rebase: replace `<journal>.snap`.
    SnapFull,
}

impl ShipKind {
    fn as_str(&self) -> &'static str {
        match self {
            ShipKind::Group => "group",
            ShipKind::JournalFull => "journal",
            ShipKind::SnapFull => "snap",
        }
    }

    fn parse(s: &str) -> Option<ShipKind> {
        match s {
            "group" => Some(ShipKind::Group),
            "journal" => Some(ShipKind::JournalFull),
            "snap" => Some(ShipKind::SnapFull),
            _ => None,
        }
    }
}

/// One unit of journal replication (see [`ShipKind`]). `journal` is the
/// bare file name (`s0000.jsonl`) — the follower resolves it inside its
/// own `--journal-dir`, never outside it.
#[derive(Clone, Debug, PartialEq)]
pub struct ShipFrame {
    pub journal: String,
    pub kind: ShipKind,
    /// File offset the bytes apply at (`Group` only; 0 for full frames).
    pub base: u64,
    pub bytes: Vec<u8>,
}

impl ShipFrame {
    pub fn group(journal: &str, base: u64, bytes: Vec<u8>) -> ShipFrame {
        ShipFrame {
            journal: journal.to_string(),
            kind: ShipKind::Group,
            base,
            bytes,
        }
    }

    pub fn journal_full(journal: &str, bytes: Vec<u8>) -> ShipFrame {
        ShipFrame {
            journal: journal.to_string(),
            kind: ShipKind::JournalFull,
            base: 0,
            bytes,
        }
    }

    pub fn snap_full(journal: &str, bytes: Vec<u8>) -> ShipFrame {
        ShipFrame {
            journal: journal.to_string(),
            kind: ShipKind::SnapFull,
            base: 0,
            bytes,
        }
    }

    /// Encode as one `{"cmd":"repl",...}` wire line (newline included).
    /// Journal bytes are UTF-8 JSON text, so they ride inside a JSON
    /// string (newlines and quotes escaped by the encoder) and decode
    /// back byte-exactly.
    pub fn to_line(&self) -> io::Result<String> {
        let data = String::from_utf8(self.bytes.clone()).map_err(|_| {
            io::Error::new(
                io::ErrorKind::InvalidData,
                "journal bytes are not UTF-8 — refusing to ship",
            )
        })?;
        let mut o = Json::obj();
        o.set("cmd", "repl")
            .set("journal", self.journal.as_str())
            .set("kind", self.kind.as_str())
            .set("base", self.base as f64)
            .set("data", data);
        let mut line = o.to_string_compact();
        line.push('\n');
        Ok(line)
    }

    /// Decode a `{"cmd":"repl",...}` wire object.
    pub fn from_json(v: &Json) -> Result<ShipFrame, String> {
        let journal = v
            .get("journal")
            .and_then(|j| j.as_str())
            .ok_or("repl frame missing 'journal'")?;
        let kind = v
            .get("kind")
            .and_then(|k| k.as_str())
            .and_then(ShipKind::parse)
            .ok_or("repl frame has unknown 'kind'")?;
        let base = v.get("base").and_then(|b| b.as_f64()).unwrap_or(0.0);
        if !(base >= 0.0 && base.fract() == 0.0) {
            return Err("repl frame 'base' is not a non-negative integer".into());
        }
        let data = v
            .get("data")
            .and_then(|d| d.as_str())
            .ok_or("repl frame missing 'data'")?;
        Ok(ShipFrame {
            journal: journal.to_string(),
            kind,
            base: base as u64,
            bytes: data.as_bytes().to_vec(),
        })
    }
}

/// Resolve a frame's target file inside `dir`, refusing anything that
/// could escape it (the frame name comes off the network).
fn frame_path(dir: &Path, name: &str) -> io::Result<PathBuf> {
    if name.is_empty()
        || name.contains('/')
        || name.contains('\\')
        || name.contains("..")
        || name.starts_with('.')
    {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("refusing replication frame for suspicious file name {name:?}"),
        ));
    }
    Ok(dir.join(name))
}

/// Atomically replace `path` with `bytes` (tmp file + rename), fsynced.
fn replace_file(path: &Path, bytes: &[u8]) -> io::Result<()> {
    let tmp = PathBuf::from(format!("{}.tmp", path.display()));
    let mut f = File::create(&tmp)?;
    f.write_all(bytes)?;
    f.sync_all()?;
    drop(f);
    std::fs::rename(&tmp, path)
}

/// Apply one frame under `dir`, returning the target file's new length
/// (the offset the follower acks). A `Group` frame whose base does not
/// match the local copy's length is divergence and refuses to apply —
/// the same refuse-rather-than-corrupt stance as recovery's ask-replay
/// check.
pub fn apply_frame(dir: &Path, frame: &ShipFrame) -> io::Result<u64> {
    match frame.kind {
        ShipKind::Group => {
            let path = frame_path(dir, &frame.journal)?;
            let mut f = OpenOptions::new()
                .create(true)
                .read(true)
                .write(true)
                .open(&path)?;
            let len = f.metadata()?.len();
            if len != frame.base {
                return Err(io::Error::new(
                    io::ErrorKind::InvalidData,
                    format!(
                        "replication divergence on {}: local copy is {len} bytes \
                         but the leader shipped a group at offset {}",
                        frame.journal, frame.base
                    ),
                ));
            }
            f.seek(SeekFrom::End(0))?;
            f.write_all(&frame.bytes)?;
            f.sync_all()?;
            Ok(len + frame.bytes.len() as u64)
        }
        ShipKind::JournalFull => {
            let path = frame_path(dir, &frame.journal)?;
            replace_file(&path, &frame.bytes)?;
            Ok(frame.bytes.len() as u64)
        }
        ShipKind::SnapFull => {
            let journal = frame_path(dir, &frame.journal)?;
            let path = crate::service::journal::snapshot_path(&journal);
            replace_file(&path, &frame.bytes)?;
            Ok(frame.bytes.len() as u64)
        }
    }
}

/// What a follower did before the leader connection closed.
#[derive(Clone, Debug, Default)]
pub struct FollowReport {
    /// Frames applied, by kind.
    pub groups: u64,
    pub rebases: u64,
    pub snaps: u64,
    /// Journal bytes received across all frames.
    pub bytes: u64,
    /// Distinct journal files touched.
    pub journals: usize,
}

impl FollowReport {
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("groups", self.groups as f64)
            .set("rebases", self.rebases as f64)
            .set("snaps", self.snaps as f64)
            .set("bytes", self.bytes as f64)
            .set("journals", self.journals as f64);
        o
    }
}

/// Tail a leader's replicated journals into `dir` until the leader
/// closes the connection (normal shutdown or crash — the follower's
/// copy is durable either way; promote it with
/// `pasha serve --journal-dir <dir>`). Subscribes with `{"cmd":"sub"}`,
/// applies every `repl` frame fsynced-before-ack, and acks each with
/// `{"ok":true,"journal":...,"off":N,"total":T}` where `T` is the
/// cumulative byte count (the leader's replication-lag gauge feeds on
/// it).
pub fn follow(addr: &str, dir: &Path) -> io::Result<FollowReport> {
    std::fs::create_dir_all(dir)?;
    let stream = TcpStream::connect(addr)?;
    follow_stream(stream, dir)
}

/// [`follow`] over an already-connected stream (tests drive this
/// directly against an in-process server).
pub fn follow_stream(stream: TcpStream, dir: &Path) -> io::Result<FollowReport> {
    std::fs::create_dir_all(dir)?;
    let mut out = stream.try_clone()?;
    out.write_all(b"{\"cmd\":\"sub\"}\n")?;
    out.flush()?;
    let reader = BufReader::new(stream);
    let mut report = FollowReport::default();
    let mut seen: std::collections::BTreeSet<String> = std::collections::BTreeSet::new();
    for line in reader.lines() {
        let line = match line {
            Ok(l) => l,
            // leader died mid-line: everything acked is already durable
            Err(_) => break,
        };
        if line.trim().is_empty() {
            continue;
        }
        let v = match json::parse(&line) {
            Ok(v) => v,
            // a leader killed mid-write leaves a torn trailing line —
            // the same crash artifact journal recovery tolerates; every
            // whole frame before it is already applied and durable
            Err(_) => break,
        };
        if v.get("cmd").and_then(|c| c.as_str()) != Some("repl") {
            continue; // the sub acknowledgement, or future chatter
        }
        let frame = ShipFrame::from_json(&v)
            .map_err(|e| io::Error::new(io::ErrorKind::InvalidData, e))?;
        let off = apply_frame(dir, &frame)?;
        match frame.kind {
            ShipKind::Group => report.groups += 1,
            ShipKind::JournalFull => report.rebases += 1,
            ShipKind::SnapFull => report.snaps += 1,
        }
        report.bytes += frame.bytes.len() as u64;
        seen.insert(frame.journal.clone());
        report.journals = seen.len();
        let mut ack = Json::obj();
        ack.set("ok", true)
            .set("journal", frame.journal.as_str())
            .set("off", off as f64)
            .set("total", report.bytes as f64);
        let mut ack_line = ack.to_string_compact();
        ack_line.push('\n');
        out.write_all(ack_line.as_bytes())?;
        out.flush()?;
    }
    Ok(report)
}

// ---------------------------------------------------------------------------
// Session router: N serving processes behind one worker-facing endpoint.
// ---------------------------------------------------------------------------

/// Attempts to reach a backend before a forward fails over: the routing
/// table is re-read and the upstream re-dialed between attempts, so a
/// promoted follower (at a new address written into the table) picks up
/// mid-connection — workers just see one slow call.
const ROUTE_RETRIES: usize = 40;
const ROUTE_RETRY_DELAY: Duration = Duration::from_millis(250);

/// The backend index serving `session` under `table` — the same FNV-1a
/// placement rule the registry uses for shards, so the assignment is
/// stable across router restarts. Sessionless requests (and `create`,
/// which mints its id server-side) pin to backend 0.
pub fn backend_for(table: &RouteSpec, session: Option<&str>) -> usize {
    match session {
        Some(sid) if !table.backends.is_empty() => {
            (fnv1a64(sid.as_bytes()) % table.backends.len() as u64) as usize
        }
        _ => 0,
    }
}

/// The session id a request line routes by: its `session` field, or the
/// first op's inside a `batch` frame.
fn route_session(req: &Json) -> Option<String> {
    if let Some(sid) = req.get("session").and_then(|s| s.as_str()) {
        return Some(sid.to_string());
    }
    if req.get("cmd").and_then(|c| c.as_str()) == Some("batch") {
        if let Some(Json::Arr(ops)) = req.get("ops") {
            for op in ops {
                if let Some(sid) = op.get("session").and_then(|s| s.as_str()) {
                    return Some(sid.to_string());
                }
            }
        }
    }
    None
}

struct Upstream {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Upstream {
    fn dial(addr: &str) -> io::Result<Upstream> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true).ok();
        let writer = stream.try_clone()?;
        Ok(Upstream {
            reader: BufReader::new(stream),
            writer,
        })
    }

    /// One request/response round-trip. An empty response line means the
    /// backend closed on us — surfaced as an error so the caller retries.
    fn call(&mut self, line: &str) -> io::Result<String> {
        self.writer.write_all(line.as_bytes())?;
        self.writer.write_all(b"\n")?;
        self.writer.flush()?;
        let mut resp = String::new();
        let n = self.reader.read_line(&mut resp)?;
        if n == 0 {
            return Err(io::Error::new(
                io::ErrorKind::UnexpectedEof,
                "backend closed the connection",
            ));
        }
        Ok(resp.trim_end_matches('\n').to_string())
    }
}

/// Serve the session router: accept worker connections on `listener`
/// and forward each request line to the backend its session id hashes
/// to, re-reading `table_path` and re-dialing on backend failure. A
/// sessionless `shutdown` is broadcast to every backend and then stops
/// the router itself (mirroring how `pasha serve` treats it).
pub fn route(listener: TcpListener, table_path: &Path) -> io::Result<()> {
    // validate the table up front so a typo'd path fails loudly
    RouteSpec::load(table_path).map_err(|e| io::Error::new(io::ErrorKind::InvalidData, e))?;
    let stop = Arc::new(AtomicBool::new(false));
    listener.set_nonblocking(true)?;
    let mut conns: Vec<std::thread::JoinHandle<()>> = Vec::new();
    while !stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _)) => {
                let table = table_path.to_path_buf();
                let stop = Arc::clone(&stop);
                conns.push(std::thread::spawn(move || {
                    let _ = route_conn(stream, &table, &stop);
                }));
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(25));
            }
            Err(e) => return Err(e),
        }
    }
    for c in conns {
        let _ = c.join();
    }
    Ok(())
}

fn route_conn(client: TcpStream, table_path: &Path, stop: &AtomicBool) -> io::Result<()> {
    client.set_nodelay(true).ok();
    let mut out = client.try_clone()?;
    let reader = BufReader::new(client);
    let mut table =
        RouteSpec::load(table_path).map_err(|e| io::Error::new(io::ErrorKind::InvalidData, e))?;
    let mut upstreams: HashMap<usize, Upstream> = HashMap::new();
    for line in reader.lines() {
        let line = match line {
            Ok(l) => l,
            Err(_) => break,
        };
        if line.trim().is_empty() {
            continue;
        }
        let req = match json::parse(&line) {
            Ok(v) => v,
            Err(e) => {
                let mut resp = Json::obj();
                resp.set("ok", false).set("error", format!("bad request: {e}"));
                let mut rl = resp.to_string_compact();
                rl.push('\n');
                out.write_all(rl.as_bytes())?;
                continue;
            }
        };
        let cmd = req.get("cmd").and_then(|c| c.as_str()).unwrap_or("");
        let sid = route_session(&req);
        if cmd == "shutdown" && sid.is_none() {
            // broadcast, reply with the last answer, stop routing
            let mut last = String::from("{\"ok\":true,\"bye\":true}");
            for idx in 0..table.backends.len() {
                if let Ok(resp) = forward(&mut upstreams, &mut table, table_path, idx, &line) {
                    last = resp;
                }
            }
            out.write_all(last.as_bytes())?;
            out.write_all(b"\n")?;
            stop.store(true, Ordering::SeqCst);
            break;
        }
        let idx = backend_for(&table, sid.as_deref());
        let resp = forward(&mut upstreams, &mut table, table_path, idx, &line)?;
        out.write_all(resp.as_bytes())?;
        out.write_all(b"\n")?;
    }
    Ok(())
}

/// Forward one line to backend `idx`, retrying across table re-reads
/// and re-dials. At-least-once on failure: a line whose response was
/// lost is re-sent to the (possibly promoted) backend — callers that
/// quiesce between commit groups (the failover e2e, drained workers)
/// see exactly-once behavior.
fn forward(
    upstreams: &mut HashMap<usize, Upstream>,
    table: &mut RouteSpec,
    table_path: &Path,
    idx: usize,
    line: &str,
) -> io::Result<String> {
    let mut last_err: Option<io::Error> = None;
    for attempt in 0..ROUTE_RETRIES {
        if attempt > 0 {
            std::thread::sleep(ROUTE_RETRY_DELAY);
            // the table may have been rewritten to point at a promoted
            // follower — pick up the new address before re-dialing
            if let Ok(fresh) = RouteSpec::load(table_path) {
                *table = fresh;
            }
            upstreams.remove(&idx);
        }
        let addr = match table.backends.get(idx) {
            Some(a) => a.clone(),
            None => {
                return Err(io::Error::new(
                    io::ErrorKind::InvalidData,
                    format!("routing table has no backend {idx}"),
                ))
            }
        };
        if !upstreams.contains_key(&idx) {
            match Upstream::dial(&addr) {
                Ok(u) => {
                    upstreams.insert(idx, u);
                }
                Err(e) => {
                    last_err = Some(e);
                    continue;
                }
            }
        }
        match upstreams.get_mut(&idx).expect("just inserted").call(line) {
            Ok(resp) => return Ok(resp),
            Err(e) => {
                upstreams.remove(&idx);
                last_err = Some(e);
            }
        }
    }
    Err(last_err.unwrap_or_else(|| {
        io::Error::new(io::ErrorKind::TimedOut, "backend unreachable after retries")
    }))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(name: &str) -> PathBuf {
        let dir =
            std::env::temp_dir().join(format!("pasha-replica-{}-{name}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn frame_wire_roundtrip_preserves_bytes() {
        let bytes = b"{\"ev\":\"tell\",\"trial\":1}\n{\"ev\":\"fail\",\"trial\":2}\n".to_vec();
        let f = ShipFrame::group("s0000.jsonl", 57, bytes.clone());
        let line = f.to_line().unwrap();
        assert!(line.ends_with('\n'));
        let v = json::parse(line.trim_end()).unwrap();
        assert_eq!(v.get("cmd").unwrap().as_str(), Some("repl"));
        let back = ShipFrame::from_json(&v).unwrap();
        assert_eq!(back, f);
        assert_eq!(back.bytes, bytes, "journal bytes survive the wire exactly");
        // full frames too
        for f in [
            ShipFrame::journal_full("s0001.jsonl", b"{\"ev\":\"create\"}\n".to_vec()),
            ShipFrame::snap_full("s0001.jsonl", b"{\"ev\":\"snapshot\"}\n".to_vec()),
        ] {
            let v = json::parse(f.to_line().unwrap().trim_end()).unwrap();
            assert_eq!(ShipFrame::from_json(&v).unwrap(), f);
        }
    }

    #[test]
    fn apply_group_appends_and_acks_offset() {
        let dir = tmp_dir("apply");
        let head = b"{\"ev\":\"create\",\"session\":\"s0\"}\n".to_vec();
        let off = apply_frame(&dir, &ShipFrame::journal_full("s0.jsonl", head.clone())).unwrap();
        assert_eq!(off, head.len() as u64);
        let tail = b"{\"ev\":\"tell\",\"trial\":0}\n".to_vec();
        let off2 = apply_frame(
            &dir,
            &ShipFrame::group("s0.jsonl", head.len() as u64, tail.clone()),
        )
        .unwrap();
        assert_eq!(off2, (head.len() + tail.len()) as u64);
        let mut want = head.clone();
        want.extend_from_slice(&tail);
        assert_eq!(std::fs::read(dir.join("s0.jsonl")).unwrap(), want);
        // a gap or overlap is divergence, refused
        let bad = apply_frame(&dir, &ShipFrame::group("s0.jsonl", 0, tail.clone()));
        assert_eq!(bad.unwrap_err().kind(), io::ErrorKind::InvalidData);
        assert_eq!(
            std::fs::read(dir.join("s0.jsonl")).unwrap(),
            want,
            "refused frame leaves the copy untouched"
        );
        // snapshot sidecar frames land next to the journal
        apply_frame(&dir, &ShipFrame::snap_full("s0.jsonl", b"snap\n".to_vec())).unwrap();
        assert_eq!(std::fs::read(dir.join("s0.jsonl.snap")).unwrap(), b"snap\n");
    }

    #[test]
    fn suspicious_frame_names_are_refused() {
        let dir = tmp_dir("names");
        for name in ["../etc/passwd", "a/b.jsonl", "", ".hidden", "a\\b"] {
            let err = apply_frame(&dir, &ShipFrame::journal_full(name, b"x".to_vec()))
                .expect_err("must refuse");
            assert_eq!(err.kind(), io::ErrorKind::InvalidData, "{name:?}");
        }
    }

    #[test]
    fn backend_placement_is_stable_and_sessionless_pins_to_zero() {
        let table = RouteSpec {
            backends: vec!["a:1".into(), "b:2".into(), "c:3".into()],
        };
        assert_eq!(backend_for(&table, None), 0);
        let mut spread = std::collections::HashSet::new();
        for i in 0..64 {
            let sid = format!("s{i:04}");
            let idx = backend_for(&table, Some(&sid));
            assert!(idx < 3);
            assert_eq!(idx, backend_for(&table, Some(&sid)), "stable placement");
            spread.insert(idx);
        }
        assert!(spread.len() > 1, "sessions spread across backends");
    }

    #[test]
    fn route_session_reads_batch_ops() {
        let req = json::parse(
            "{\"cmd\":\"batch\",\"ops\":[{\"cmd\":\"ask\",\"session\":\"s7\"},\
             {\"cmd\":\"tell\",\"session\":\"s7\"}]}",
        )
        .unwrap();
        assert_eq!(route_session(&req).as_deref(), Some("s7"));
        let plain = json::parse("{\"cmd\":\"ask\",\"session\":\"s1\"}").unwrap();
        assert_eq!(route_session(&plain).as_deref(), Some("s1"));
        let none = json::parse("{\"cmd\":\"ping\"}").unwrap();
        assert_eq!(route_session(&none), None);
    }
}
