//! Dependency-free TCP server: newline-delimited JSON over
//! `std::net::TcpListener`.
//!
//! One request per line, one response per line, responses in request
//! order per connection. [`Server::run`] serves with the sharded
//! event-driven core (`service::eventloop`): a few I/O threads multiplex
//! all connections over readiness polling (`util::poll`), sessions are
//! processed by their owning shard workers, and journal writes group-
//! commit. [`Server::run_threaded`] keeps the original
//! thread-per-connection loop — it is the "old path" baseline the
//! stress suite compares against, and the fallback on non-Unix targets.
//!
//! Wire protocol (requests; all responses carry `"ok": true|false`):
//!
//! ```text
//! {"cmd":"ping"}
//! {"cmd":"create","spec":{...ExperimentSpec...}}     -> {"ok":true,"session":"s0000"}
//! {"cmd":"ask","session":"s0000","worker":"w0"}      -> {"ok":true,"type":"run",...}
//! {"cmd":"tell","session":"s0000","trial":3,"epoch":1,"metric":57.5}
//!                                                    -> {"ok":true,"ack":"continue"}
//! {"cmd":"fail","session":"s0000","trial":3}         -> {"ok":true}
//! {"cmd":"expire","session":"s0000"}                 -> {"ok":true,"expired":2}
//! {"cmd":"expire","session":"s0000","worker":"w1"}   -> {"ok":true,"expired":1}
//! {"cmd":"status","session":"s0000"}                 -> {"ok":true,"status":{...}}
//! {"cmd":"sessions"}                                 -> {"ok":true,"sessions":[...]}
//! {"cmd":"stats"}                                    -> {"ok":true,"stats":{...}}
//! {"cmd":"close","session":"s0000"}                  -> {"ok":true}
//! {"cmd":"batch","ops":[{...},{...}]}                -> {"ok":true,"results":[...]}
//! {"cmd":"shutdown"}                                 -> {"ok":true,"bye":true}
//! ```
//!
//! Field rules: `trial` and `epoch` must be non-negative integers —
//! negative, fractional, or non-finite numbers are rejected with a
//! structured error rather than silently truncated. `worker` on `ask`
//! is optional: when omitted, the server substitutes a process-unique
//! per-connection identity (`conn-<n>`), so two clients that both skip
//! the field can never collide in lease accounting (a shared name would
//! make their in-flight jobs indistinguishable to `expire`).
//!
//! `batch` executes its ops strictly in order and returns one result per
//! op (each with its own `ok` flag — a failed op never aborts the frame).
//! The ops go through the same per-session dispatch as singly-issued
//! requests, so journal bytes and scheduler state are identical to the
//! unbatched path; the frame just collapses N network round-trips into
//! one. `batch` and `shutdown` cannot be nested inside a frame.
//!
//! `shutdown` (on the event-driven path) stops accepting and reading,
//! lets every already-received op on every connection finish — journal
//! groups committed, responses delivered — and only then answers
//! `{"ok":true,"bye":true}` and closes the listener. Slow clients get
//! backpressure: past a soft cap of queued response bytes the server
//! stops reading that connection; past a hard cap it drops it.

use crate::scheduler::asktell::assignment_json;
use crate::service::registry::{Registry, ServiceError};
use crate::spec::ExperimentSpec;
use crate::util::json::{parse, Json};
use crate::TrialId;
use std::io::{self, BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Handle one parsed request against the registry. Pure apart from the
/// registry mutation — unit-testable without a socket. `shutdown`
/// requests are handled by the caller (they need the serve loop).
/// Callers holding a connection should run [`apply_worker_default`]
/// first; a bare `handle_request` with no `worker` falls back to the
/// legacy `"anonymous"` identity.
pub fn handle_request(registry: &Registry, req: &Json) -> Json {
    match dispatch(registry, req) {
        Ok(mut resp) => {
            resp.set("ok", true);
            resp
        }
        Err(e) => {
            let mut resp = Json::obj();
            resp.set("ok", false).set("error", e.to_string());
            resp
        }
    }
}

fn field<'a>(req: &'a Json, key: &str) -> Result<&'a Json, ServiceError> {
    req.get(key)
        .ok_or_else(|| ServiceError::Request(format!("missing field '{key}'")))
}

fn str_field<'a>(req: &'a Json, key: &str) -> Result<&'a str, ServiceError> {
    field(req, key)?
        .as_str()
        .ok_or_else(|| ServiceError::Request(format!("field '{key}' must be a string")))
}

fn num_field(req: &Json, key: &str) -> Result<f64, ServiceError> {
    field(req, key)?
        .as_f64()
        .ok_or_else(|| ServiceError::Request(format!("field '{key}' must be a number")))
}

/// Largest f64 whose every integer neighbour is exactly representable
/// (2^53): the ceiling for wire-carried ids.
const MAX_SAFE_INT: f64 = 9007199254740992.0;

/// A non-negative integer field. JSON numbers arrive as f64, and the
/// old `as usize` cast silently truncated — `"trial": 3.7` became trial
/// 3 and `-1` became 0, corrupting lease accounting without a trace.
/// Reject anything negative, fractional, non-finite, or out of range
/// with a structured error instead.
fn uint_field(req: &Json, key: &str, max: f64) -> Result<u64, ServiceError> {
    let raw = num_field(req, key)?;
    if !raw.is_finite() || raw.fract() != 0.0 || raw < 0.0 || raw > max {
        return Err(ServiceError::Request(format!(
            "field '{key}' must be a non-negative integer (got {raw})"
        )));
    }
    Ok(raw as u64)
}

/// The process-unique identity minted for each accepted connection and
/// substituted into `ask` ops that omit `worker`.
pub(crate) fn next_conn_worker_id() -> String {
    static NEXT_CONN_WORKER: AtomicU64 = AtomicU64::new(0);
    format!("conn-{}", NEXT_CONN_WORKER.fetch_add(1, Ordering::Relaxed))
}

/// Fill the connection's auto-assigned worker id into `ask` ops that
/// omit `worker` — both top-level and inside `batch` frames. An
/// explicitly named worker is never overridden.
pub(crate) fn apply_worker_default(req: &mut Json, worker: &str) {
    match req.get("cmd").and_then(|c| c.as_str()) {
        Some("ask") => {
            if req.get("worker").is_none() {
                req.set("worker", worker);
            }
        }
        Some("batch") => {
            if let Json::Obj(map) = req {
                if let Some(Json::Arr(ops)) = map.get_mut("ops") {
                    for op in ops.iter_mut() {
                        if op.get("cmd").and_then(|c| c.as_str()) == Some("ask")
                            && op.get("worker").is_none()
                        {
                            op.set("worker", worker);
                        }
                    }
                }
            }
        }
        _ => {}
    }
}

fn dispatch(registry: &Registry, req: &Json) -> Result<Json, ServiceError> {
    let cmd = str_field(req, "cmd")?;
    let mut resp = Json::obj();
    match cmd {
        "ping" => {
            resp.set("pong", true);
        }
        "create" => {
            let spec =
                ExperimentSpec::from_json(field(req, "spec")?).map_err(ServiceError::Spec)?;
            let id = registry.create(spec)?;
            resp.set("session", id);
        }
        "ask" => {
            let sid = str_field(req, "session")?;
            let worker = str_field(req, "worker").unwrap_or("anonymous");
            let assignment = registry.with_session(sid, |s| s.ask(worker))??;
            resp = assignment_json(&assignment);
        }
        "tell" => {
            let sid = str_field(req, "session")?;
            let trial = uint_field(req, "trial", MAX_SAFE_INT)? as TrialId;
            let epoch = uint_field(req, "epoch", u32::MAX as f64)? as u32;
            // a diverged worker may legitimately report NaN
            let metric = req.get("metric").and_then(|v| v.as_f64()).unwrap_or(f64::NAN);
            let ack = registry.with_session(sid, |s| s.tell(trial, epoch, metric))??;
            resp.set("ack", ack.as_str());
        }
        "fail" => {
            let sid = str_field(req, "session")?;
            let trial = uint_field(req, "trial", MAX_SAFE_INT)? as TrialId;
            registry.with_session(sid, |s| s.fail(trial))??;
        }
        "expire" => {
            let sid = str_field(req, "session")?;
            // `worker` narrows the expiry to one identity (what the
            // lease tick and targeted recovery use); omitting it keeps
            // the legacy everyone-at-once semantics.
            let expired = match req.get("worker").and_then(|w| w.as_str()) {
                Some(worker) => {
                    let worker = worker.to_string();
                    registry.with_session(sid, move |s| s.expire_worker(&worker))??
                }
                None => registry.with_session(sid, |s| s.expire_workers())??,
            };
            resp.set("expired", expired);
        }
        "status" => {
            let sid = str_field(req, "session")?;
            let status = registry.with_session(sid, |s| s.status())?;
            resp.set("status", status);
        }
        "sessions" => {
            resp.set("sessions", registry.statuses());
        }
        // Read-only snapshot of the process metrics registry
        // ([`crate::obs`]). Needs no session, mutates nothing, and is
        // safe to poll from monitoring at any frequency.
        "stats" => {
            resp.set("stats", crate::obs::snapshot_json());
        }
        "close" => {
            registry.close(str_field(req, "session")?)?;
        }
        "batch" => {
            let ops = field(req, "ops")?
                .as_arr()
                .ok_or_else(|| ServiceError::Request("field 'ops' must be an array".into()))?;
            let results: Vec<Json> = ops
                .iter()
                .map(|op| match op.get("cmd").and_then(|c| c.as_str()) {
                    // frame-control commands cannot nest: `batch` would
                    // recurse unboundedly and `shutdown` needs the serve
                    // loop, which only sees top-level commands
                    Some("batch") | Some("shutdown") => {
                        let mut r = Json::obj();
                        r.set("ok", false)
                            .set("error", "command not allowed inside a batch");
                        r
                    }
                    _ => handle_request(registry, op),
                })
                .collect();
            resp.set("results", Json::Arr(results));
        }
        "shutdown" => {
            resp.set("bye", true);
        }
        // replication handshakes belong on the dedicated listener
        "sub" | "repl" => {
            return Err(ServiceError::Request(
                "replication commands go to the --replicate listener, not the serve port".into(),
            ));
        }
        other => {
            return Err(ServiceError::Request(format!("unknown cmd '{other}'")));
        }
    }
    Ok(resp)
}

/// Default number of I/O threads for the event-driven serve loop.
pub const DEFAULT_IO_THREADS: usize = 2;

/// A bound-but-not-yet-running server.
pub struct Server {
    listener: TcpListener,
    registry: Arc<Registry>,
    shutdown: Arc<AtomicBool>,
    io_threads: usize,
    metrics: Option<TcpListener>,
    replicate: Option<TcpListener>,
    worker_lease: Option<Duration>,
    drain_deadline: Option<Duration>,
}

impl Server {
    /// Bind `addr` (e.g. `127.0.0.1:7171`, or port `0` for an ephemeral
    /// port — query it with [`Server::local_addr`]).
    pub fn bind(addr: &str, registry: Arc<Registry>) -> io::Result<Server> {
        let listener = TcpListener::bind(addr)?;
        Ok(Server {
            listener,
            registry,
            shutdown: Arc::new(AtomicBool::new(false)),
            io_threads: DEFAULT_IO_THREADS,
            metrics: None,
            replicate: None,
            worker_lease: None,
            drain_deadline: None,
        })
    }

    /// Override the I/O thread count for [`Server::run`] (builder-style).
    pub fn io_threads(mut self, n: usize) -> Server {
        self.io_threads = n.max(1);
        self
    }

    /// Also bind `addr` as a plain-HTTP Prometheus exposition endpoint
    /// (`serve --metrics-addr`). Served off I/O thread 0's readiness
    /// poller — no extra thread. Event-driven path only; the
    /// thread-per-connection fallback ignores it.
    pub fn metrics_addr(mut self, addr: &str) -> io::Result<Server> {
        self.metrics = Some(TcpListener::bind(addr)?);
        Ok(self)
    }

    /// Local address of the metrics endpoint, if one was bound.
    pub fn metrics_local_addr(&self) -> Option<SocketAddr> {
        self.metrics.as_ref().and_then(|m| m.local_addr().ok())
    }

    /// Also bind `addr` as the replication listener (`serve
    /// --replicate`): `pasha follow` subscribers connect here and
    /// receive every durable commit group ([`crate::service::replica`]).
    /// Served off I/O thread 0's readiness poller, like the metrics
    /// endpoint. Event-driven path only; [`Server::run_threaded`]
    /// ignores it.
    pub fn replicate_addr(mut self, addr: &str) -> io::Result<Server> {
        self.replicate = Some(TcpListener::bind(addr)?);
        Ok(self)
    }

    /// Local address of the replication listener, if one was bound.
    pub fn replicate_local_addr(&self) -> Option<SocketAddr> {
        self.replicate.as_ref().and_then(|r| r.local_addr().ok())
    }

    /// Expire a worker's in-flight jobs when it has not asked or told
    /// for `lease` (`serve --worker-lease`): each shard worker sweeps
    /// its sessions periodically, journaling the expiry like a
    /// client-driven `expire`. Event-driven path only.
    pub fn worker_lease(mut self, lease: Duration) -> Server {
        self.worker_lease = Some(lease);
        self
    }

    /// Override how long a shutdown drain waits for slow clients before
    /// force-closing them (default 5s). Committed responses are still
    /// released and flushed when the deadline fires.
    pub fn drain_deadline(mut self, deadline: Duration) -> Server {
        self.drain_deadline = Some(deadline);
        self
    }

    pub fn local_addr(&self) -> io::Result<SocketAddr> {
        self.listener.local_addr()
    }

    /// A flag that stops the serve loop when set (the `shutdown`
    /// command sets it; embedders may too).
    pub fn shutdown_flag(&self) -> Arc<AtomicBool> {
        self.shutdown.clone()
    }

    /// Serve with the sharded event-driven core until shutdown: I/O
    /// threads multiplex all connections over readiness polling, shard
    /// workers own the sessions, journals group-commit. Returns once a
    /// `shutdown` request (or the external flag) has drained every
    /// in-flight op and flushed every connection.
    #[cfg(unix)]
    pub fn run(self) -> io::Result<()> {
        use crate::service::eventloop::{self, RunCfg};
        eventloop::run(
            self.listener,
            self.registry,
            self.shutdown,
            RunCfg {
                io_threads: self.io_threads,
                metrics: self.metrics,
                replicate: self.replicate,
                worker_lease: self.worker_lease,
                drain_deadline: self.drain_deadline.unwrap_or(eventloop::DRAIN_DEADLINE),
            },
        )
    }

    /// Non-Unix fallback: the readiness poller needs Unix fds, so serve
    /// with the thread-per-connection loop instead.
    #[cfg(not(unix))]
    pub fn run(self) -> io::Result<()> {
        self.run_threaded()
    }

    /// The original thread-per-connection serve loop: non-blocking
    /// accept with a 10ms retry sleep, one scoped thread per
    /// connection, 100ms read-timeout polling. Kept as the measured baseline for
    /// `bench-json --suite service` ("old path") and as the non-Unix
    /// fallback.
    pub fn run_threaded(self) -> io::Result<()> {
        self.listener.set_nonblocking(true)?;
        let registry = &self.registry;
        let shutdown = &self.shutdown;
        std::thread::scope(|scope| {
            while !shutdown.load(Ordering::SeqCst) {
                match self.listener.accept() {
                    Ok((stream, _peer)) => {
                        scope.spawn(move || {
                            if let Err(e) = handle_connection(stream, registry, shutdown) {
                                // A dropped connection is routine; log and move on.
                                crate::log_warn!("serve: connection error: {e}");
                            }
                        });
                    }
                    Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                        std::thread::sleep(Duration::from_millis(10));
                    }
                    Err(e) => {
                        crate::log_warn!("serve: accept error: {e}");
                        std::thread::sleep(Duration::from_millis(50));
                    }
                }
            }
        });
        Ok(())
    }
}

/// Read newline-delimited requests off one connection until EOF or
/// shutdown, answering each on the same stream.
fn handle_connection(
    stream: TcpStream,
    registry: &Registry,
    shutdown: &AtomicBool,
) -> io::Result<()> {
    stream.set_read_timeout(Some(Duration::from_millis(100)))?;
    let worker_id = next_conn_worker_id();
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    loop {
        if shutdown.load(Ordering::SeqCst) {
            return Ok(());
        }
        // `line` is NOT cleared across timeouts: a request arriving
        // slowly may be split over several read_line calls, each timing
        // out with a partial prefix already consumed into the buffer.
        match reader.read_line(&mut line) {
            Ok(0) => return Ok(()), // EOF: client hung up
            Ok(_) if !line.ends_with('\n') => return Ok(()), // EOF mid-request
            Ok(_) => {
                let trimmed = line.trim();
                if trimmed.is_empty() {
                    line.clear();
                    continue;
                }
                let resp = match parse(trimmed) {
                    Ok(mut req) => {
                        apply_worker_default(&mut req, &worker_id);
                        let resp = handle_request(registry, &req);
                        if req.get("cmd").and_then(|c| c.as_str()) == Some("shutdown") {
                            shutdown.store(true, Ordering::SeqCst);
                        }
                        resp
                    }
                    Err(e) => {
                        let mut r = Json::obj();
                        r.set("ok", false).set("error", format!("bad json: {e}"));
                        r
                    }
                };
                line.clear();
                let mut out = resp.to_string_compact();
                out.push('\n');
                writer.write_all(out.as_bytes())?;
            }
            Err(e)
                if e.kind() == io::ErrorKind::WouldBlock
                    || e.kind() == io::ErrorKind::TimedOut =>
            {
                continue; // read timeout: re-check the shutdown flag
            }
            Err(e) => return Err(e),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spec::ExperimentSpec;

    fn reg_with_session() -> (Registry, String) {
        let reg = Registry::in_memory();
        let mut spec = ExperimentSpec::named("lcbench-Fashion-MNIST", "asha").unwrap();
        spec.stop.config_budget = 4;
        let id = reg.create(spec).unwrap();
        (reg, id)
    }

    fn req(s: &str) -> Json {
        parse(s).unwrap()
    }

    #[test]
    fn create_accepts_v2_and_v1_specs_and_rejects_typos() {
        let reg = Registry::in_memory();
        // v2 wire format
        let v2 = "{\"cmd\":\"create\",\"spec\":{\"version\":2,\
                   \"bench\":{\"name\":\"lcbench-Fashion-MNIST\"},\
                   \"scheduler\":{\"name\":\"asha\"},\
                   \"stop\":{\"config_budget\":4}}}";
        let r = handle_request(&reg, &req(v2));
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(true), "{r}");
        // legacy v1 flat payloads still create sessions
        let v1 = "{\"cmd\":\"create\",\"spec\":{\"bench\":\"lcbench-Fashion-MNIST\",\
                   \"scheduler\":\"asha\",\"config_budget\":4}}";
        let r = handle_request(&reg, &req(v1));
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(true), "{r}");
        // a typo'd key is a structured error naming the field, not a
        // silently-defaulted session
        let typo = "{\"cmd\":\"create\",\"spec\":{\"bench\":\"lcbench-Fashion-MNIST\",\
                     \"confg_budget\":4}}";
        let r = handle_request(&reg, &req(typo));
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(false));
        let msg = r.get("error").unwrap().as_str().unwrap();
        assert!(msg.contains("confg_budget"), "{msg}");
        assert_eq!(reg.len(), 2, "only the two valid creates registered");
    }

    #[test]
    fn ping_and_unknown_cmd() {
        let reg = Registry::in_memory();
        let r = handle_request(&reg, &req("{\"cmd\":\"ping\"}"));
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(r.get("pong").unwrap().as_bool(), Some(true));
        let r = handle_request(&reg, &req("{\"cmd\":\"frobnicate\"}"));
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(false));
        let r = handle_request(&reg, &req("{}"));
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(false));
    }

    #[test]
    fn create_ask_tell_cycle_over_requests() {
        let reg = Registry::in_memory();
        let create = "{\"cmd\":\"create\",\"spec\":{\"bench\":\"lcbench-Fashion-MNIST\",\
                      \"scheduler\":\"asha\",\"config_budget\":2}}";
        let r = handle_request(&reg, &req(create));
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(true));
        let sid = r.get("session").unwrap().as_str().unwrap().to_string();

        let ask = format!("{{\"cmd\":\"ask\",\"session\":\"{sid}\",\"worker\":\"w0\"}}");
        let r = handle_request(&reg, &req(&ask));
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(r.get("type").unwrap().as_str(), Some("run"));
        let trial = r.get("trial").unwrap().as_f64().unwrap() as usize;
        let milestone = r.get("milestone").unwrap().as_f64().unwrap() as u32;

        for e in 1..=milestone {
            let tell = format!(
                "{{\"cmd\":\"tell\",\"session\":\"{sid}\",\"trial\":{trial},\
                 \"epoch\":{e},\"metric\":{}}}",
                50.0 + e as f64
            );
            let r = handle_request(&reg, &req(&tell));
            assert_eq!(r.get("ok").unwrap().as_bool(), Some(true), "{r}");
            let want = if e == milestone { "job-complete" } else { "continue" };
            assert_eq!(r.get("ack").unwrap().as_str(), Some(want));
        }

        let status = format!("{{\"cmd\":\"status\",\"session\":\"{sid}\"}}");
        let r = handle_request(&reg, &req(&status));
        let st = r.get("status").unwrap();
        assert_eq!(st.get("jobs_completed").unwrap().as_f64(), Some(1.0));
    }

    #[test]
    fn tell_and_fail_reject_non_integer_trial_and_epoch() {
        let (reg, id) = reg_with_session();
        let cases = [
            format!("{{\"cmd\":\"tell\",\"session\":\"{id}\",\"trial\":3.7,\"epoch\":1,\"metric\":1}}"),
            format!("{{\"cmd\":\"tell\",\"session\":\"{id}\",\"trial\":-1,\"epoch\":1,\"metric\":1}}"),
            format!("{{\"cmd\":\"tell\",\"session\":\"{id}\",\"trial\":0,\"epoch\":1.5,\"metric\":1}}"),
            format!("{{\"cmd\":\"tell\",\"session\":\"{id}\",\"trial\":0,\"epoch\":-2,\"metric\":1}}"),
            format!("{{\"cmd\":\"tell\",\"session\":\"{id}\",\"trial\":0,\"epoch\":1e300,\"metric\":1}}"),
            format!("{{\"cmd\":\"fail\",\"session\":\"{id}\",\"trial\":2.5}}"),
            format!("{{\"cmd\":\"fail\",\"session\":\"{id}\",\"trial\":-3}}"),
        ];
        for case in &cases {
            let r = handle_request(&reg, &req(case));
            assert_eq!(r.get("ok").unwrap().as_bool(), Some(false), "{case}");
            let msg = r.get("error").unwrap().as_str().unwrap();
            assert!(msg.contains("non-negative integer"), "{case} -> {msg}");
        }
        // integers written with a fractional-free float spelling pass
        // field validation (JSON has no integer type on the wire)
        let ok_shape = format!(
            "{{\"cmd\":\"tell\",\"session\":\"{id}\",\"trial\":7.0,\"epoch\":1,\"metric\":1}}"
        );
        let r = handle_request(&reg, &req(&ok_shape));
        let msg = r.get("error").unwrap().as_str().unwrap();
        assert!(
            !msg.contains("non-negative integer"),
            "7.0 is an integer; the failure must be the unknown trial, got: {msg}"
        );
    }

    #[test]
    fn worker_default_fills_only_missing_ask_fields() {
        let mut ask = req("{\"cmd\":\"ask\",\"session\":\"s0000\"}");
        apply_worker_default(&mut ask, "conn-9");
        assert_eq!(ask.get("worker").unwrap().as_str(), Some("conn-9"));

        let mut named = req("{\"cmd\":\"ask\",\"session\":\"s0000\",\"worker\":\"w3\"}");
        apply_worker_default(&mut named, "conn-9");
        assert_eq!(named.get("worker").unwrap().as_str(), Some("w3"));

        let mut frame = req(
            "{\"cmd\":\"batch\",\"ops\":[\
             {\"cmd\":\"ask\",\"session\":\"s0000\"},\
             {\"cmd\":\"ask\",\"session\":\"s0000\",\"worker\":\"w3\"},\
             {\"cmd\":\"tell\",\"session\":\"s0000\",\"trial\":0,\"epoch\":1,\"metric\":1}]}",
        );
        apply_worker_default(&mut frame, "conn-9");
        let ops = frame.get("ops").unwrap().as_arr().unwrap();
        assert_eq!(ops[0].get("worker").unwrap().as_str(), Some("conn-9"));
        assert_eq!(ops[1].get("worker").unwrap().as_str(), Some("w3"));
        assert!(ops[2].get("worker").is_none(), "non-ask ops untouched");

        // a non-ask top-level request is untouched
        let mut status = req("{\"cmd\":\"status\",\"session\":\"s0000\"}");
        apply_worker_default(&mut status, "conn-9");
        assert!(status.get("worker").is_none());

        // minted ids are process-unique
        let a = next_conn_worker_id();
        let b = next_conn_worker_id();
        assert_ne!(a, b);
        assert!(a.starts_with("conn-") && b.starts_with("conn-"));
    }

    #[test]
    fn errors_are_structured() {
        let (reg, id) = reg_with_session();
        let r = handle_request(&reg, &req("{\"cmd\":\"ask\",\"session\":\"nope\"}"));
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(false));
        assert!(r.get("error").unwrap().as_str().unwrap().contains("nope"));
        // tell for a trial never asked
        let tell = format!(
            "{{\"cmd\":\"tell\",\"session\":\"{id}\",\"trial\":7,\"epoch\":1,\"metric\":1}}"
        );
        let r = handle_request(&reg, &req(&tell));
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(false));
        // sessions listing still works
        let r = handle_request(&reg, &req("{\"cmd\":\"sessions\"}"));
        assert_eq!(r.get("sessions").unwrap().as_arr().unwrap().len(), 1);
        // close, then the session is gone
        let close = format!("{{\"cmd\":\"close\",\"session\":\"{id}\"}}");
        let closed = handle_request(&reg, &req(&close));
        assert_eq!(closed.get("ok").unwrap().as_bool(), Some(true));
        let r = handle_request(&reg, &req(&close));
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(false));
    }

    #[test]
    fn batch_executes_ops_in_order_with_per_op_results() {
        let (reg, id) = reg_with_session();
        // one frame: ask, three tells toward the milestone, bad op, ask
        let ask = format!("{{\"cmd\":\"ask\",\"session\":\"{id}\",\"worker\":\"w0\"}}");
        let first = handle_request(&reg, &req(&ask));
        let trial = first.get("trial").unwrap().as_f64().unwrap() as usize;
        let milestone = first.get("milestone").unwrap().as_f64().unwrap() as u32;
        let mut ops = Vec::new();
        for e in 1..=milestone {
            ops.push(req(&format!(
                "{{\"cmd\":\"tell\",\"session\":\"{id}\",\"trial\":{trial},\
                 \"epoch\":{e},\"metric\":{}}}",
                60.0 + e as f64
            )));
        }
        let bad = "{\"cmd\":\"tell\",\"session\":\"nope\",\"trial\":0,\"epoch\":1,\"metric\":1}";
        ops.push(req(bad));
        ops.push(req(&ask));
        let mut frame = Json::obj();
        frame.set("cmd", "batch").set("ops", Json::Arr(ops));
        let resp = handle_request(&reg, &frame);
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true));
        let results = resp.get("results").unwrap().as_arr().unwrap();
        assert_eq!(results.len(), milestone as usize + 2);
        // tells progressed in order: continue… then job-complete
        for e in 0..milestone as usize {
            let want = if e + 1 == milestone as usize {
                "job-complete"
            } else {
                "continue"
            };
            assert_eq!(results[e].get("ack").unwrap().as_str(), Some(want), "op {e}");
        }
        // the bad op failed without aborting the frame
        assert_eq!(
            results[milestone as usize].get("ok").unwrap().as_bool(),
            Some(false)
        );
        // the trailing ask executed after the tells
        assert_eq!(
            results[milestone as usize + 1].get("ok").unwrap().as_bool(),
            Some(true)
        );
        // nested frame-control ops are refused per-op
        let mut nested = Json::obj();
        nested.set("cmd", "batch").set(
            "ops",
            Json::Arr(vec![req("{\"cmd\":\"shutdown\"}"), req("{\"cmd\":\"ping\"}")]),
        );
        let resp = handle_request(&reg, &nested);
        let results = resp.get("results").unwrap().as_arr().unwrap();
        assert_eq!(results[0].get("ok").unwrap().as_bool(), Some(false));
        assert_eq!(results[1].get("ok").unwrap().as_bool(), Some(true));
    }

    #[test]
    fn expire_with_worker_field_targets_one_identity() {
        let (reg, id) = reg_with_session();
        let ask_w0 = format!("{{\"cmd\":\"ask\",\"session\":\"{id}\",\"worker\":\"w0\"}}");
        let ask_w1 = format!("{{\"cmd\":\"ask\",\"session\":\"{id}\",\"worker\":\"w1\"}}");
        let a0 = handle_request(&reg, &req(&ask_w0));
        assert_eq!(a0.get("type").unwrap().as_str(), Some("run"));
        let a1 = handle_request(&reg, &req(&ask_w1));
        assert_eq!(a1.get("type").unwrap().as_str(), Some("run"));
        // expire only w0: exactly its one job re-queues
        let expire = format!("{{\"cmd\":\"expire\",\"session\":\"{id}\",\"worker\":\"w0\"}}");
        let r = handle_request(&reg, &req(&expire));
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(true), "{r}");
        assert_eq!(r.get("expired").unwrap().as_f64(), Some(1.0));
        // w0's trial is re-offered; w1's job is untouched and its tell
        // still lands
        let again = handle_request(&reg, &req(&ask_w0));
        assert_eq!(again.get("type").unwrap().as_str(), Some("run"));
        assert_eq!(again.get("trial"), a0.get("trial"));
        let t1 = a1.get("trial").unwrap().as_f64().unwrap() as usize;
        let tell = format!(
            "{{\"cmd\":\"tell\",\"session\":\"{id}\",\"trial\":{t1},\"epoch\":1,\"metric\":55}}"
        );
        let r = handle_request(&reg, &req(&tell));
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(true), "{r}");
    }

    #[test]
    fn expire_requeues_in_flight_work() {
        let (reg, id) = reg_with_session();
        let ask = format!("{{\"cmd\":\"ask\",\"session\":\"{id}\",\"worker\":\"w0\"}}");
        let first = handle_request(&reg, &req(&ask));
        assert_eq!(first.get("type").unwrap().as_str(), Some("run"));
        let expire = format!("{{\"cmd\":\"expire\",\"session\":\"{id}\"}}");
        let r = handle_request(&reg, &req(&expire));
        assert_eq!(r.get("expired").unwrap().as_f64(), Some(1.0));
        // the same trial is offered again
        let again = handle_request(&reg, &req(&ask));
        assert_eq!(again.get("type").unwrap().as_str(), Some("run"));
        assert_eq!(again.get("trial"), first.get("trial"));
    }
}
