//! The sharded event-driven serve core behind [`crate::service::Server::run`].
//!
//! ```text
//!             ┌────────────────────────── process ──────────────────────────┐
//!  clients ──▶│ io thread 0 (epoll: listener + conns) ──┐                   │
//!  clients ──▶│ io thread 1 (epoll: conns)            ──┤ bounded channels  │
//!             │        ▲  mailbox + wake pipe           ▼                   │
//!             │        │                     shard worker 0 ── sessions A,C │
//!             │        └─────────────────────shard worker 1 ── sessions B,D │
//!             │         (responses flow back)           │  group commit     │
//!             └─────────────────────────────────────────┴───────────────────┘
//! ```
//!
//! * **I/O threads** own the sockets. Each runs a level-triggered
//!   readiness loop ([`crate::util::poll`]): non-blocking accept (thread
//!   0), non-blocking reads into a per-connection buffer, non-blocking
//!   writes out of a per-connection queue. No read timeouts, no
//!   thread-per-connection — a sleeping connection costs one epoll
//!   registration, not a thread.
//! * **Shard workers** own the sessions. Every parsed request is routed
//!   by `hash(session_id) % shards` ([`Registry::shard_of`]) over a
//!   bounded channel, so all ops for one session execute on one thread
//!   in arrival order — single-owner actors, no per-session lock
//!   contention. Sessionless ops (`ping`, `create`, `sessions`) round-
//!   robin. Batch frames are routed by the first session named in their
//!   ops and execute their ops in order on that shard.
//! * **Group commit.** A shard worker drains a batch of queued ops,
//!   applies them (journal lines buffer in userspace), then issues one
//!   `write` + one `sync_all` per touched session for the whole group
//!   ([`Registry::commit_session`]). Responses are released to the I/O
//!   threads only after their group's commit, so an acknowledged op is
//!   a durable op; if the commit fails, every would-be-acknowledged
//!   response in the group is rewritten into an error.
//! * **Ordered responses.** Requests are answered in per-connection
//!   request order even when they complete on different shards: each
//!   parsed line gets a sequence number and completed responses wait in
//!   a reorder buffer until their turn.
//! * **Backpressure.** Past [`SOFT_WRITE_CAP`] queued response bytes
//!   (or [`MAX_INFLIGHT_PER_CONN`] unanswered ops) the server stops
//!   reading from that connection — pipelined ops already accepted keep
//!   flowing, the socket's kernel buffer then the client's send path
//!   fill up, and a slow reader throttles only itself. Past
//!   [`HARD_WRITE_CAP`] the connection is dropped.
//! * **Shutdown drain.** A `shutdown` request stops all accepting and
//!   reading, finishes every op already received on every connection
//!   (committed and answered), then releases the `{"bye":true}`
//!   response and exits once all connections are flushed (bounded by a
//!   deadline for clients that stopped reading; responses already
//!   committed are still released and flushed when the deadline fires).
//! * **Worker-lease expiry.** With `--worker-lease`, each shard worker
//!   sweeps its own sessions on a periodic tick and expires workers
//!   whose lease lapsed mid-job ([`Registry::expire_stale_shard`]) —
//!   the expiry is journaled, committed, and replicated exactly like a
//!   client-driven mutation, and the dead worker's jobs re-queue.
//! * **Replication.** With `--replicate`, a second listener (also on
//!   io thread 0's poller) accepts `pasha follow` subscribers: after a
//!   `{"cmd":"sub"}` handshake the registry starts retaining durable
//!   commit-group bytes, and every tick drains them to all subscribers
//!   ([`crate::service::replica`]). Shipping is strictly post-fsync and
//!   observe-only — journal bytes and responses are identical with
//!   replication on or off.

use crate::obs::{self, trace};
use crate::service::registry::{Registry, ServiceError};
use crate::service::replica::ShipKind;
use crate::service::server::{apply_worker_default, handle_request, next_conn_worker_id};
use crate::util::json::{parse, Json};
use crate::util::poll::{Event, Poller};
use std::collections::{BTreeMap, BTreeSet, HashMap, VecDeque};
use std::io::{self, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::unix::io::AsRawFd;
use std::os::unix::net::UnixStream;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender, TryRecvError};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Queued-response bytes past which reads from a connection pause.
const SOFT_WRITE_CAP: usize = 256 * 1024;
/// Queued-response bytes past which a connection is dropped outright.
const HARD_WRITE_CAP: usize = 4 * 1024 * 1024;
/// Unanswered ops per connection past which reads pause.
const MAX_INFLIGHT_PER_CONN: usize = 256;
/// A single request line larger than this drops the connection.
const MAX_LINE_BYTES: usize = 8 * 1024 * 1024;
/// Depth of each shard's op channel (senders block past this).
const SHARD_QUEUE_DEPTH: usize = 4096;
/// Max ops a shard folds into one commit group.
const SHARD_GROUP_MAX: usize = 128;
/// Poll timeout: the latency floor for cross-thread work delivered
/// between wakeup bytes (mailboxes are also drained on every tick).
const POLL_TIMEOUT: Duration = Duration::from_millis(25);
/// Default for [`RunCfg::drain_deadline`]: how long a shutdown drain
/// waits for clients to read their tails.
pub(crate) const DRAIN_DEADLINE: Duration = Duration::from_secs(5);
/// Queued replication bytes past which a non-reading subscriber is
/// dropped (it re-subscribes and gets a full rebase).
const REPL_WRITE_CAP: usize = 64 * 1024 * 1024;
/// How long the final drain waits for subscriber sockets to take the
/// last shipped frames before closing.
const REPL_FLUSH_DEADLINE: Duration = Duration::from_secs(2);

/// Everything [`run`] needs beyond the listener/registry/shutdown trio,
/// bundled so adding a serve knob does not ripple a signature change
/// through [`crate::service::server::Server`].
pub(crate) struct RunCfg {
    /// I/O threads multiplexing connections (min 1).
    pub(crate) io_threads: usize,
    /// Prometheus exposition listener (`serve --metrics-addr`).
    pub(crate) metrics: Option<TcpListener>,
    /// Replication-subscriber listener (`serve --replicate`).
    pub(crate) replicate: Option<TcpListener>,
    /// Expire a worker's in-flight jobs when it has not asked or told
    /// for this long (`serve --worker-lease`); `None` disables the tick.
    pub(crate) worker_lease: Option<Duration>,
    /// How long a shutdown drain waits before force-closing stragglers.
    pub(crate) drain_deadline: Duration,
}

const TOKEN_LISTENER: usize = 0;
const TOKEN_WAKE: usize = 1;
/// Connection tokens are process-unique ids counting up from here, so
/// a late shard response can never be delivered to a recycled slot.
const TOKEN_CONN_BASE: u64 = 2;

static NEXT_CONN_ID: AtomicU64 = AtomicU64::new(TOKEN_CONN_BASE);

/// One parsed request in flight from an I/O thread to a shard worker.
struct Op {
    /// Index of the I/O thread owning the connection.
    io: usize,
    conn: u64,
    /// Per-connection sequence for in-order response release.
    seq: u64,
    req: Json,
}

/// Work delivered to an I/O thread by shard workers or the acceptor.
enum IoMsg {
    /// A completed op's serialized response line (newline included).
    Done { conn: u64, seq: u64, line: Vec<u8> },
    /// A freshly accepted connection handed over for ownership.
    Conn(TcpStream),
}

/// An I/O thread's inbox plus the pipe that interrupts its poll.
struct Mailbox {
    q: Mutex<VecDeque<IoMsg>>,
    /// Write end of the wake pipe; the owning thread polls the read end.
    wake: UnixStream,
}

impl Mailbox {
    fn push(&self, msg: IoMsg) {
        self.q.lock().expect("mailbox lock").push_back(msg);
        self.wake();
    }

    fn wake(&self) {
        // A full pipe is fine: the thread is already due to wake, and
        // every loop tick drains the mailbox regardless.
        let _ = (&self.wake).write(&[1u8]);
    }

    fn drain(&self) -> Vec<IoMsg> {
        let mut q = self.q.lock().expect("mailbox lock");
        q.drain(..).collect()
    }
}

/// Serve-loop telemetry ([`crate::obs`]), labeled by the listen address
/// so concurrent servers in one process (tests, multi-port deployments)
/// keep separate series. All recording is observe-only: journal bytes,
/// RNG streams, and scheduling decisions are untouched whether metrics
/// are on, off, or absent.
struct EvObs {
    addr: String,
    /// `pasha_net_accepts_total` — connections accepted.
    accepts: Arc<obs::Counter>,
    /// `pasha_net_conns_closed_total` — connections retired for any
    /// reason (EOF, error, write-cap kill, drain).
    closed: Arc<obs::Counter>,
    /// `pasha_net_bytes_read_total` / `pasha_net_bytes_written_total`.
    bytes_in: Arc<obs::Counter>,
    bytes_out: Arc<obs::Counter>,
    /// `pasha_net_requests_total` — request lines parsed (including
    /// ones answered inline with a parse error).
    requests: Arc<obs::Counter>,
    /// `pasha_net_backpressure_pauses_total` — reads paused because a
    /// connection hit the in-flight or queued-bytes cap.
    pauses: Arc<obs::Counter>,
    /// `pasha_net_inflight_ops` — ops routed to shards, not yet
    /// answered (mirrors `Shared::in_flight`; drains to 0 at shutdown).
    inflight: Arc<obs::Gauge>,
    /// `pasha_io_poll_wait_us` — time each io thread spent blocked in
    /// the poller per tick.
    poll_wait_us: Arc<obs::Histogram>,
    /// `pasha_io_dispatch_us` — time spent servicing readiness events
    /// per non-idle tick.
    dispatch_us: Arc<obs::Histogram>,
    /// `pasha_shard_queue_depth` per shard — ops queued to the shard
    /// channel and not yet picked up.
    queue_depth: Vec<Arc<obs::Gauge>>,
}

impl EvObs {
    fn new(addr: String, n_shards: usize) -> EvObs {
        let l: &[(&str, &str)] = &[("addr", &addr)];
        EvObs {
            accepts: obs::counter("pasha_net_accepts_total", l),
            closed: obs::counter("pasha_net_conns_closed_total", l),
            bytes_in: obs::counter("pasha_net_bytes_read_total", l),
            bytes_out: obs::counter("pasha_net_bytes_written_total", l),
            requests: obs::counter("pasha_net_requests_total", l),
            pauses: obs::counter("pasha_net_backpressure_pauses_total", l),
            inflight: obs::gauge("pasha_net_inflight_ops", l),
            poll_wait_us: obs::histogram("pasha_io_poll_wait_us", l),
            dispatch_us: obs::histogram("pasha_io_dispatch_us", l),
            queue_depth: (0..n_shards)
                .map(|s| {
                    obs::gauge(
                        "pasha_shard_queue_depth",
                        &[("addr", &addr), ("shard", &s.to_string())],
                    )
                })
                .collect(),
            addr,
        }
    }
}

/// State shared by all I/O threads and shard workers.
struct Shared {
    registry: Arc<Registry>,
    shutdown: Arc<AtomicBool>,
    /// Set by the first `shutdown` request (or the external flag):
    /// stop accepting and reading, finish what was received.
    draining: AtomicBool,
    /// Ops routed to shards and not yet answered, across all conns.
    in_flight: AtomicUsize,
    /// I/O threads that have finished parsing their buffered bytes
    /// after `draining` was raised; the bye releases at `n_io`.
    parse_done: AtomicUsize,
    n_io: usize,
    mailboxes: Vec<Arc<Mailbox>>,
    /// Worker-lease duration for the shard workers' expiry tick.
    worker_lease: Option<Duration>,
    /// Shutdown-drain force-close deadline.
    drain_deadline: Duration,
    obs: EvObs,
}

/// One client connection, owned by exactly one I/O thread.
struct Conn {
    stream: TcpStream,
    /// Bytes read but not yet parsed into request lines.
    rbuf: Vec<u8>,
    /// Bytes queued to the socket, drained from `out_pos`.
    out: Vec<u8>,
    out_pos: usize,
    /// Responses completed out of order, waiting for their turn.
    pending: BTreeMap<u64, Vec<u8>>,
    pending_bytes: usize,
    /// Sequence assigned to the next parsed request.
    next_seq: u64,
    /// Sequence whose response is released next.
    next_release: u64,
    /// Ops routed to shards and not yet completed.
    in_flight: usize,
    read_paused: bool,
    read_closed: bool,
    want_read: bool,
    want_write: bool,
    /// Auto-assigned identity for `ask` ops that omit `worker`.
    worker_id: String,
    /// Sequence reserved for this connection's `shutdown` response.
    shutdown_seq: Option<u64>,
}

impl Conn {
    fn new(stream: TcpStream) -> Conn {
        Conn {
            stream,
            rbuf: Vec::new(),
            out: Vec::new(),
            out_pos: 0,
            pending: BTreeMap::new(),
            pending_bytes: 0,
            next_seq: 0,
            next_release: 0,
            in_flight: 0,
            read_paused: false,
            read_closed: false,
            want_read: true,
            want_write: false,
            worker_id: next_conn_worker_id(),
            shutdown_seq: None,
        }
    }

    /// Response bytes queued for this connection (socket queue plus
    /// reorder buffer) — the quantity backpressure caps.
    fn queued_bytes(&self) -> usize {
        (self.out.len() - self.out_pos) + self.pending_bytes
    }

    fn fully_flushed(&self) -> bool {
        self.in_flight == 0
            && self.pending.is_empty()
            && self.out_pos == self.out.len()
            && self.shutdown_seq.is_none()
    }
}

/// Serve until shutdown. Entered from [`crate::service::Server::run`];
/// turns group commit on for the registry's journals while serving.
/// `metrics_listener` (from `serve --metrics-addr`) is a plain-HTTP
/// Prometheus exposition endpoint multiplexed onto io thread 0's
/// poller — no extra thread, no dependency.
pub(crate) fn run(
    listener: TcpListener,
    registry: Arc<Registry>,
    shutdown: Arc<AtomicBool>,
    cfg: RunCfg,
) -> io::Result<()> {
    let RunCfg {
        io_threads,
        metrics: metrics_listener,
        replicate: repl_listener,
        worker_lease,
        drain_deadline,
    } = cfg;
    listener.set_nonblocking(true)?;
    if let Some(m) = &metrics_listener {
        m.set_nonblocking(true)?;
    }
    if let Some(r) = &repl_listener {
        r.set_nonblocking(true)?;
    }
    let n_io = io_threads.max(1);
    registry
        .set_group_commit(true)
        .map_err(|e| io::Error::other(e.to_string()))?;

    // Wake pipes and mailboxes, one per I/O thread.
    let mut wake_rxs = Vec::with_capacity(n_io);
    let mut mailboxes = Vec::with_capacity(n_io);
    for _ in 0..n_io {
        let (wake_tx, wake_rx) = UnixStream::pair()?;
        wake_tx.set_nonblocking(true)?;
        wake_rx.set_nonblocking(true)?;
        mailboxes.push(Arc::new(Mailbox {
            q: Mutex::new(VecDeque::new()),
            wake: wake_tx,
        }));
        wake_rxs.push(wake_rx);
    }
    // Pollers built up front so setup errors surface here, not inside
    // a detached thread.
    let mut pollers = Vec::with_capacity(n_io);
    for (i, wake_rx) in wake_rxs.iter().enumerate() {
        let poller = Poller::new()?;
        poller.register(wake_rx.as_raw_fd(), TOKEN_WAKE, true, false)?;
        if i == 0 {
            poller.register(listener.as_raw_fd(), TOKEN_LISTENER, true, false)?;
        }
        pollers.push(poller);
    }

    let n_shards = registry.n_shards();
    let addr = listener
        .local_addr()
        .map(|a| a.to_string())
        .unwrap_or_else(|_| "unknown".into());
    let shared = Shared {
        registry: registry.clone(),
        shutdown,
        draining: AtomicBool::new(false),
        in_flight: AtomicUsize::new(0),
        parse_done: AtomicUsize::new(0),
        n_io,
        mailboxes,
        worker_lease,
        drain_deadline,
        obs: EvObs::new(addr, n_shards),
    };
    let mut txs: Vec<SyncSender<Op>> = Vec::with_capacity(n_shards);
    let mut rxs: Vec<Receiver<Op>> = Vec::with_capacity(n_shards);
    for _ in 0..n_shards {
        let (tx, rx) = sync_channel(SHARD_QUEUE_DEPTH);
        txs.push(tx);
        rxs.push(rx);
    }

    let result = std::thread::scope(|scope| {
        let shared_ref = &shared;
        for (s, rx) in rxs.into_iter().enumerate() {
            scope.spawn(move || shard_worker(shared_ref, s, rx));
        }
        let mut io_handles = Vec::with_capacity(n_io);
        let mut wake_iter = wake_rxs.into_iter();
        let mut metrics = metrics_listener;
        let mut repl = repl_listener;
        for (i, poller) in pollers.into_iter().enumerate() {
            let wake_rx = wake_iter.next().expect("one wake pipe per io thread");
            let txs_own = txs.clone();
            let listener_ref = if i == 0 { Some(&listener) } else { None };
            // the metrics and replication endpoints ride on io thread
            // 0's poller
            let metrics_own = if i == 0 { metrics.take() } else { None };
            let repl_own = if i == 0 { repl.take() } else { None };
            io_handles.push(scope.spawn(move || {
                io_loop(
                    i,
                    shared_ref,
                    txs_own,
                    listener_ref,
                    metrics_own,
                    repl_own,
                    wake_rx,
                    poller,
                )
            }));
        }
        // Once every I/O thread (each holding a clone) exits, the shard
        // channels disconnect and the workers return.
        drop(txs);
        let mut res = Ok(());
        for h in io_handles {
            match h.join() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => {
                    crate::log_warn!("serve: io thread error: {e}");
                    if res.is_ok() {
                        res = Err(e);
                    }
                }
                Err(_) => {
                    if res.is_ok() {
                        res = Err(io::Error::other("io thread panicked"));
                    }
                }
            }
        }
        res
    });
    // Back to write-through mode; commits any buffered residue.
    if let Err(e) = registry.set_group_commit(false) {
        crate::log_warn!("serve: final journal commit failed: {e}");
    }
    trace::flush();
    result
}

/// A shard worker: the single owner of every session routed to it.
/// Drains a group of ops, applies them, commits each touched session's
/// journal once, then releases the group's responses. With a worker
/// lease configured it also runs this shard's liveness tick: waiting
/// for ops is bounded by `recv_timeout`, and both the idle timeout and
/// a lapsed interval under load sweep the shard's sessions for stale
/// workers ([`Registry::expire_stale_shard`]).
fn shard_worker(shared: &Shared, shard: usize, rx: Receiver<Op>) {
    let shard_label = shard.to_string();
    let l: &[(&str, &str)] = &[("addr", &shared.obs.addr), ("shard", &shard_label)];
    let ops_total = obs::counter("pasha_shard_ops_total", l);
    let groups_total = obs::counter("pasha_shard_groups_total", l);
    let group_ops = obs::histogram("pasha_shard_group_ops", l);
    let group_us = obs::histogram("pasha_shard_group_us", l);
    let expirations = obs::counter("pasha_worker_lease_expirations_total", l);
    let depth = &shared.obs.queue_depth[shard];
    // Sweep a few times per lease so expiry lands within ~lease/4 of
    // the deadline, bounded to keep idle wakeups and sweep overhead sane.
    let sweep_every = shared
        .worker_lease
        .map(|lease| (lease / 4).clamp(Duration::from_millis(50), Duration::from_secs(1)));
    let mut last_sweep = Instant::now();
    loop {
        let first = match sweep_every {
            Some(tick) => match rx.recv_timeout(tick) {
                Ok(op) => Some(op),
                Err(RecvTimeoutError::Timeout) => None,
                Err(RecvTimeoutError::Disconnected) => return,
            },
            None => match rx.recv() {
                Ok(op) => Some(op),
                Err(_) => return, // all I/O threads gone: server exiting
            },
        };
        if let (Some(lease), Some(tick)) = (shared.worker_lease, sweep_every) {
            if first.is_none() || last_sweep.elapsed() >= tick {
                let expired = shared.registry.expire_stale_shard(shard, lease);
                last_sweep = Instant::now();
                if !expired.is_empty() {
                    for (sid, workers) in &expired {
                        expirations.add(workers.len() as u64);
                        crate::log_warn!(
                            "serve: shard {shard}: expired stale workers {workers:?} \
                             in session {sid}; their jobs re-queue"
                        );
                    }
                    if shared.registry.shipping() {
                        // expiry frames are already in the sink
                        shared.mailboxes[0].wake();
                    }
                }
            }
        }
        let Some(first) = first else { continue };
        depth.add(-1);
        let t0 = Instant::now();
        let mut group = vec![first];
        while group.len() < SHARD_GROUP_MAX {
            match rx.try_recv() {
                Ok(op) => {
                    depth.add(-1);
                    group.push(op);
                }
                Err(TryRecvError::Empty) | Err(TryRecvError::Disconnected) => break,
            }
        }
        let mut touched: BTreeSet<String> = BTreeSet::new();
        let mut responses: Vec<(usize, u64, u64, Json)> = Vec::with_capacity(group.len());
        for op in &group {
            let resp = handle_request(&shared.registry, &op.req);
            collect_sessions(&op.req, &resp, &mut touched);
            responses.push((op.io, op.conn, op.seq, resp));
        }
        // Group commit: one write + one fsync per touched session for
        // the whole group, before any response is released.
        let mut commit_err: Option<String> = None;
        for sid in &touched {
            match shared.registry.commit_session(sid) {
                Ok(()) => {}
                // closed in this very group: close() already committed
                Err(ServiceError::UnknownSession(_)) => {}
                Err(e) => {
                    commit_err = Some(e.to_string());
                    break;
                }
            }
        }
        if let Some(err) = commit_err {
            // Never acknowledge what may not be durable: downgrade every
            // would-be success in the group to a structured error.
            for (_, _, _, resp) in responses.iter_mut() {
                if resp.get("ok").and_then(|v| v.as_bool()) == Some(true) {
                    let mut failed = Json::obj();
                    failed
                        .set("ok", false)
                        .set("error", format!("group commit failed: {err}"));
                    *resp = failed;
                }
            }
        } else if shared.registry.shipping() {
            // Fsync happened above: the group's bytes are durable, so
            // they may ship. Collect them into the sink and nudge io
            // thread 0 (the replication broadcaster).
            let mut collected = 0usize;
            for sid in &touched {
                collected += shared.registry.collect_shipped(sid);
            }
            if collected > 0 {
                shared.mailboxes[0].wake();
            }
        }
        for (io, conn, seq, resp) in responses {
            let mut line = resp.to_string_compact().into_bytes();
            line.push(b'\n');
            shared.mailboxes[io].push(IoMsg::Done { conn, seq, line });
        }
        ops_total.add(group.len() as u64);
        groups_total.inc();
        group_ops.observe(group.len() as u64);
        group_us.observe_us(t0.elapsed());
        if trace::enabled() {
            trace::span("shard", "commit-group", shard as u64, t0, Instant::now());
        }
    }
}

/// Every session a request/response pair may have journaled to: the
/// request's `session`, a `create` response's new id, and both sides
/// of each batch sub-op.
fn collect_sessions(req: &Json, resp: &Json, out: &mut BTreeSet<String>) {
    let mut add = |j: &Json| {
        if let Some(sid) = j.get("session").and_then(|s| s.as_str()) {
            out.insert(sid.to_string());
        }
    };
    add(req);
    add(resp);
    if let Some(ops) = req.get("ops").and_then(|o| o.as_arr()) {
        for op in ops {
            add(op);
        }
    }
    if let Some(results) = resp.get("results").and_then(|r| r.as_arr()) {
        for r in results {
            add(r);
        }
    }
}

/// The shard that must execute `req`: the owner of its session (batch
/// frames route by the first session named in their ops), or round-
/// robin for sessionless ops.
fn route_shard(req: &Json, registry: &Registry, rr: &mut usize) -> usize {
    let sid = req.get("session").and_then(|s| s.as_str()).or_else(|| {
        req.get("ops")
            .and_then(|o| o.as_arr())
            .and_then(|ops| ops.iter().find_map(|op| op.get("session").and_then(|s| s.as_str())))
    });
    match sid {
        Some(sid) => registry.shard_of(sid),
        None => {
            let shard = *rr % registry.n_shards();
            *rr += 1;
            shard
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn io_loop(
    idx: usize,
    shared: &Shared,
    shard_txs: Vec<SyncSender<Op>>,
    listener: Option<&TcpListener>,
    metrics: Option<TcpListener>,
    repl: Option<TcpListener>,
    wake_rx: UnixStream,
    mut poller: Poller,
) -> io::Result<()> {
    let mut conns: HashMap<u64, Conn> = HashMap::new();
    let mut events: Vec<Event> = Vec::new();
    // Stagger the sessionless round-robin start across I/O threads.
    let mut rr = idx;
    let mut next_accept = 0usize;
    let mut drain_deadline: Option<Instant> = None;
    let mut parse_flushed = false;
    // Prometheus scrape connections (separate id space entry in the
    // same poller; tokens come from the shared conn-id counter so they
    // can never collide with request connections).
    let mut mconns: HashMap<u64, MetricsConn> = HashMap::new();
    let metrics_tok = match &metrics {
        Some(m) => {
            let tok = NEXT_CONN_ID.fetch_add(1, Ordering::Relaxed);
            poller.register(m.as_raw_fd(), tok as usize, true, false)?;
            Some(tok)
        }
        None => None,
    };
    // Replication subscribers (`pasha follow`), same pattern: a second
    // listener multiplexed onto this thread's poller, no extra thread.
    let mut rconns: HashMap<u64, ReplConn> = HashMap::new();
    let repl_tok = match &repl {
        Some(r) => {
            let tok = NEXT_CONN_ID.fetch_add(1, Ordering::Relaxed);
            poller.register(r.as_raw_fd(), tok as usize, true, false)?;
            Some(tok)
        }
        None => None,
    };
    let robs = repl.as_ref().map(|_| ReplObs::new(&shared.obs.addr));
    // Journal bytes handed to subscribers so far, the minuend of the
    // lag gauge (local so concurrent in-process servers stay separate).
    let mut shipped_bytes: u64 = 0;

    loop {
        let t_poll = Instant::now();
        poller.poll(&mut events, Some(POLL_TIMEOUT))?;
        let t_work = Instant::now();
        shared
            .obs
            .poll_wait_us
            .observe_us(t_work.duration_since(t_poll));
        let draining =
            shared.draining.load(Ordering::SeqCst) || shared.shutdown.load(Ordering::SeqCst);
        if draining {
            shared.draining.store(true, Ordering::SeqCst);
        }
        let mut to_drop: Vec<u64> = Vec::new();

        for &ev in events.iter() {
            match ev.token {
                TOKEN_LISTENER => {
                    let Some(listener) = listener else { continue };
                    if draining {
                        continue;
                    }
                    accept_all(listener, idx, shared, &poller, &mut conns, &mut next_accept);
                }
                TOKEN_WAKE => drain_wake_pipe(&wake_rx),
                tok => {
                    let id = tok as u64;
                    if metrics_tok == Some(id) {
                        if let Some(m) = &metrics {
                            accept_metrics(m, &poller, &mut mconns);
                        }
                        continue;
                    }
                    if repl_tok == Some(id) {
                        if let Some(r) = &repl {
                            accept_repl(r, &poller, &mut rconns);
                        }
                        continue;
                    }
                    if rconns.contains_key(&id) {
                        let (alive, newly_subscribed) = {
                            let rc = rconns.get_mut(&id).expect("repl conn listed");
                            repl_conn_event(rc, ev)
                        };
                        if newly_subscribed {
                            // First frames are full rebases queued by
                            // set_shipping; the broadcast below ships them.
                            if let Err(e) = shared.registry.set_shipping(true) {
                                crate::log_warn!("serve: cannot enable replication: {e}");
                            }
                        }
                        if alive {
                            let rc = rconns.get_mut(&id).expect("repl conn listed");
                            let want_write = rc.out_pos < rc.out.len();
                            let _ = poller.reregister(
                                rc.stream.as_raw_fd(),
                                id as usize,
                                true,
                                want_write,
                            );
                        } else {
                            drop_repl_conn(id, shared, &poller, &mut rconns);
                        }
                        sync_repl_gauges(&rconns, robs.as_ref(), shipped_bytes);
                        continue;
                    }
                    if let Some(mc) = mconns.get_mut(&id) {
                        if !metrics_conn_event(mc, ev) {
                            let fd = mc.stream.as_raw_fd();
                            let _ = poller.deregister(fd);
                            mconns.remove(&id);
                        } else {
                            let want_write = mc.out_pos < mc.out.len();
                            let _ = poller.reregister(
                                mc.stream.as_raw_fd(),
                                id as usize,
                                !want_write,
                                want_write,
                            );
                        }
                        continue;
                    }
                    let Some(c) = conns.get_mut(&id) else { continue };
                    let mut dead = false;
                    if ev.readable && !draining && !c.read_paused && !c.read_closed {
                        if do_read(c, &shared.obs) {
                            parse_lines(c, id, idx, shared, &shard_txs, &mut rr, false);
                        } else {
                            dead = true;
                        }
                    }
                    if !dead && ev.writable && !do_write(c, &shared.obs) {
                        dead = true;
                    }
                    if dead {
                        to_drop.push(id);
                    }
                }
            }
        }

        // Cross-thread deliveries: completed responses, handed-over conns.
        for msg in shared.mailboxes[idx].drain() {
            match msg {
                IoMsg::Conn(stream) => {
                    if !draining {
                        install_conn(stream, &poller, &mut conns);
                    }
                }
                IoMsg::Done { conn, seq, line } => {
                    // Decrement first: ops for already-dropped conns
                    // must still drain the global gauge.
                    shared.in_flight.fetch_sub(1, Ordering::SeqCst);
                    shared.obs.inflight.add(-1);
                    if let Some(c) = conns.get_mut(&conn) {
                        c.in_flight -= 1;
                        c.pending_bytes += line.len();
                        c.pending.insert(seq, line);
                    }
                }
            }
        }

        // Ship durable commit groups to replication subscribers. Shard
        // workers park post-fsync frames in the registry sink and wake
        // this thread; frames are encoded once and fanned out to every
        // subscriber.
        if repl_tok.is_some() && rconns.values().any(|r| r.subscribed) {
            let frames = shared.registry.drain_ship_sink();
            if !frames.is_empty() {
                let ro = robs.as_ref().expect("repl obs built with repl listener");
                let mut payload: Vec<u8> = Vec::new();
                for frame in &frames {
                    match frame.to_line() {
                        Ok(line) => {
                            if frame.kind == ShipKind::Group {
                                ro.groups.inc();
                            }
                            shipped_bytes += frame.bytes.len() as u64;
                            ro.bytes.add(frame.bytes.len() as u64);
                            payload.extend_from_slice(line.as_bytes());
                        }
                        Err(e) => {
                            crate::log_warn!("serve: cannot encode replication frame: {e}")
                        }
                    }
                }
                let mut dead_subs: Vec<u64> = Vec::new();
                for (&id, rc) in rconns.iter_mut() {
                    if !rc.subscribed {
                        continue;
                    }
                    rc.out.extend_from_slice(&payload);
                    if !repl_flush(rc) || rc.out.len() - rc.out_pos > REPL_WRITE_CAP {
                        dead_subs.push(id);
                    } else {
                        let want_write = rc.out_pos < rc.out.len();
                        let _ = poller.reregister(
                            rc.stream.as_raw_fd(),
                            id as usize,
                            true,
                            want_write,
                        );
                    }
                }
                for id in dead_subs {
                    crate::log_warn!("serve: dropping replication subscriber {id}");
                    drop_repl_conn(id, shared, &poller, &mut rconns);
                }
                sync_repl_gauges(&rconns, robs.as_ref(), shipped_bytes);
            }
        }

        // Maintenance: release in-order responses, flush, apply caps,
        // resume paused reads, retire finished connections.
        let ids: Vec<u64> = conns.keys().copied().collect();
        for id in ids {
            if to_drop.contains(&id) {
                continue;
            }
            let c = conns.get_mut(&id).expect("conn listed");
            release_ready(c);
            if c.out_pos < c.out.len() && !do_write(c, &shared.obs) {
                to_drop.push(id);
                continue;
            }
            if c.queued_bytes() > HARD_WRITE_CAP {
                crate::log_warn!("serve: dropping connection {id}: client not reading responses");
                to_drop.push(id);
                continue;
            }
            if !draining
                && c.read_paused
                && c.in_flight <= MAX_INFLIGHT_PER_CONN / 2
                && c.queued_bytes() <= SOFT_WRITE_CAP / 2
            {
                c.read_paused = false;
                // Bytes buffered while paused may hold complete lines.
                parse_lines(c, id, idx, shared, &shard_txs, &mut rr, false);
                release_ready(c);
            }
            if c.read_closed && c.fully_flushed() {
                to_drop.push(id);
                continue;
            }
            sync_interest(&poller, id, c, draining);
        }
        for id in to_drop {
            if let Some(c) = conns.remove(&id) {
                let _ = poller.deregister(c.stream.as_raw_fd());
                shared.obs.closed.inc();
            }
        }
        if !events.is_empty() {
            shared.obs.dispatch_us.observe_us(t_work.elapsed());
            if trace::enabled() {
                trace::span("eventloop", "tick", idx as u64, t_work, Instant::now());
            }
        }

        if draining {
            if drain_deadline.is_none() {
                drain_deadline = Some(Instant::now() + shared.drain_deadline);
            }
            if !parse_flushed {
                // Honor every op already received: parse the remainder
                // of each read buffer (caps ignored — nothing new is
                // being read, this is a finite backlog).
                let ids: Vec<u64> = conns.keys().copied().collect();
                for id in ids {
                    let c = conns.get_mut(&id).expect("conn listed");
                    parse_lines(c, id, idx, shared, &shard_txs, &mut rr, true);
                }
                parse_flushed = true;
                shared.parse_done.fetch_add(1, Ordering::SeqCst);
            }
            // All threads parsed + nothing in flight ⇒ every received
            // op is committed and answered: release the shutdown acks.
            if shared.parse_done.load(Ordering::SeqCst) == shared.n_io
                && shared.in_flight.load(Ordering::SeqCst) == 0
            {
                for c in conns.values_mut() {
                    if let Some(seq) = c.shutdown_seq.take() {
                        let mut bye = Json::obj();
                        bye.set("bye", true).set("ok", true);
                        let mut line = bye.to_string_compact().into_bytes();
                        line.push(b'\n');
                        c.pending_bytes += line.len();
                        c.pending.insert(seq, line);
                        release_ready(c);
                        let _ = do_write(c, &shared.obs);
                    }
                }
            }
            let all_flushed = conns.values().all(|c| c.fully_flushed());
            let expired = drain_deadline.map(|d| Instant::now() >= d).unwrap_or(false);
            if all_flushed || expired {
                if expired && !all_flushed {
                    // The deadline fired with stragglers unflushed.
                    // Responses sitting in their reorder buffers are for
                    // *committed* groups — dropping them would lose an
                    // acked-or-durable op's answer. Release and push
                    // whatever the sockets will take before force-close.
                    for c in conns.values_mut() {
                        release_ready(c);
                        let _ = do_write(c, &shared.obs);
                    }
                }
                // Ship the drain's own final commit groups (the ops
                // answered above) so a cleanly shut down leader leaves
                // its follower byte-identical.
                finish_repl(shared, &mut rconns, robs.as_ref(), &mut shipped_bytes);
                return Ok(());
            }
        }
    }
}

fn accept_all(
    listener: &TcpListener,
    idx: usize,
    shared: &Shared,
    poller: &Poller,
    conns: &mut HashMap<u64, Conn>,
    next_accept: &mut usize,
) {
    loop {
        match listener.accept() {
            Ok((stream, _peer)) => {
                shared.obs.accepts.inc();
                let target = *next_accept % shared.n_io;
                *next_accept += 1;
                if target == idx {
                    install_conn(stream, poller, conns);
                } else {
                    shared.mailboxes[target].push(IoMsg::Conn(stream));
                }
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => return,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(e) => {
                crate::log_warn!("serve: accept error: {e}");
                return;
            }
        }
    }
}

fn install_conn(stream: TcpStream, poller: &Poller, conns: &mut HashMap<u64, Conn>) {
    if let Err(e) = stream.set_nonblocking(true) {
        crate::log_warn!("serve: rejecting connection: {e}");
        return;
    }
    // One-line request/response turns: latency beats Nagle batching.
    let _ = stream.set_nodelay(true);
    let id = NEXT_CONN_ID.fetch_add(1, Ordering::Relaxed);
    if let Err(e) = poller.register(stream.as_raw_fd(), id as usize, true, false) {
        crate::log_warn!("serve: cannot register connection: {e}");
        return;
    }
    conns.insert(id, Conn::new(stream));
}

fn drain_wake_pipe(wake_rx: &UnixStream) {
    let mut sink = [0u8; 512];
    loop {
        match (&*wake_rx).read(&mut sink) {
            Ok(0) => return,
            Ok(_) => {}
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => return,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(_) => return,
        }
    }
}

/// Read until the socket drains. Returns false when the connection is
/// unusable (I/O error, or a single line exceeding [`MAX_LINE_BYTES`]).
fn do_read(c: &mut Conn, obs: &EvObs) -> bool {
    let mut buf = [0u8; 16 * 1024];
    loop {
        match c.stream.read(&mut buf) {
            Ok(0) => {
                c.read_closed = true;
                return true; // EOF: buffered lines still get answered
            }
            Ok(n) => {
                obs.bytes_in.add(n as u64);
                c.rbuf.extend_from_slice(&buf[..n]);
                if c.rbuf.len() > MAX_LINE_BYTES && !c.rbuf.contains(&b'\n') {
                    crate::log_warn!("serve: dropping connection: unterminated request line");
                    return false;
                }
                if n < buf.len() {
                    return true; // short read: socket drained
                }
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => return true,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(_) => return false,
        }
    }
}

/// Flush the write queue as far as the socket allows. Returns false on
/// an I/O error.
fn do_write(c: &mut Conn, obs: &EvObs) -> bool {
    while c.out_pos < c.out.len() {
        match c.stream.write(&c.out[c.out_pos..]) {
            Ok(0) => return false,
            Ok(n) => {
                obs.bytes_out.add(n as u64);
                c.out_pos += n;
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(_) => return false,
        }
    }
    if c.out_pos == c.out.len() {
        c.out.clear();
        c.out_pos = 0;
    } else if c.out_pos > 64 * 1024 {
        // Reclaim the flushed prefix so the queue cannot creep.
        c.out.drain(..c.out_pos);
        c.out_pos = 0;
    }
    true
}

/// Move every response whose turn has come from the reorder buffer to
/// the write queue.
fn release_ready(c: &mut Conn) {
    while let Some(line) = c.pending.remove(&c.next_release) {
        c.pending_bytes -= line.len();
        c.out.extend_from_slice(&line);
        c.next_release += 1;
    }
}

/// Parse complete lines out of `c.rbuf` and route them: session ops to
/// their owning shard, parse failures answered inline, `shutdown`
/// intercepted (it needs the serve loop). With `force` (drain mode)
/// backpressure caps are ignored — the backlog is finite.
fn parse_lines(
    c: &mut Conn,
    id: u64,
    idx: usize,
    shared: &Shared,
    shard_txs: &[SyncSender<Op>],
    rr: &mut usize,
    force: bool,
) {
    let mut pos = 0usize;
    while pos < c.rbuf.len() {
        if !force
            && (c.in_flight >= MAX_INFLIGHT_PER_CONN || c.queued_bytes() >= SOFT_WRITE_CAP)
        {
            if !c.read_paused {
                shared.obs.pauses.inc();
            }
            c.read_paused = true;
            break;
        }
        let Some(nl) = c.rbuf[pos..].iter().position(|&b| b == b'\n') else {
            break;
        };
        let line = String::from_utf8_lossy(&c.rbuf[pos..pos + nl]).into_owned();
        pos += nl + 1;
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        let seq = c.next_seq;
        c.next_seq += 1;
        shared.obs.requests.inc();
        match parse(trimmed) {
            Ok(mut req) => {
                if req.get("cmd").and_then(|v| v.as_str()) == Some("shutdown") {
                    // Ack only after every received op on every
                    // connection has drained; discard trailing input.
                    c.shutdown_seq = Some(seq);
                    c.read_closed = true;
                    pos = c.rbuf.len();
                    shared.draining.store(true, Ordering::SeqCst);
                    for mb in &shared.mailboxes {
                        mb.wake();
                    }
                    break;
                }
                apply_worker_default(&mut req, &c.worker_id);
                let shard = route_shard(&req, &shared.registry, rr);
                c.in_flight += 1;
                shared.in_flight.fetch_add(1, Ordering::SeqCst);
                shared.obs.inflight.add(1);
                shared.obs.queue_depth[shard].add(1);
                // A full shard queue blocks this I/O thread briefly;
                // the worker is always draining, so this cannot wedge.
                if shard_txs[shard].send(Op { io: idx, conn: id, seq, req }).is_err() {
                    // Shard gone: the server is tearing down.
                    c.in_flight -= 1;
                    shared.in_flight.fetch_sub(1, Ordering::SeqCst);
                    shared.obs.inflight.add(-1);
                    shared.obs.queue_depth[shard].add(-1);
                    let mut r = Json::obj();
                    r.set("ok", false).set("error", "server shutting down");
                    queue_inline(c, seq, &r);
                }
            }
            Err(e) => {
                let mut r = Json::obj();
                r.set("ok", false).set("error", format!("bad json: {e}"));
                queue_inline(c, seq, &r);
            }
        }
    }
    if pos > 0 {
        c.rbuf.drain(..pos);
    }
}

/// Queue a response produced on the I/O thread itself (parse errors):
/// it still flows through the reorder buffer so ordering holds.
fn queue_inline(c: &mut Conn, seq: u64, resp: &Json) {
    let mut line = resp.to_string_compact().into_bytes();
    line.push(b'\n');
    c.pending_bytes += line.len();
    c.pending.insert(seq, line);
}

/// Reconcile the poller's interest set with what the connection can
/// currently make progress on.
fn sync_interest(poller: &Poller, id: u64, c: &mut Conn, draining: bool) {
    let want_read = !draining && !c.read_closed && !c.read_paused;
    let want_write = c.out_pos < c.out.len();
    if (want_read != c.want_read || want_write != c.want_write)
        && poller
            .reregister(c.stream.as_raw_fd(), id as usize, want_read, want_write)
            .is_ok()
    {
        c.want_read = want_read;
        c.want_write = want_write;
    }
}

/// One Prometheus scrape connection ([`run`]'s `metrics_listener`),
/// owned by io thread 0. Deliberately minimal HTTP: read the request
/// head, answer one `text/plain; version=0.0.4` exposition,
/// `Connection: close`. No keep-alive, no routing — every path scrapes.
struct MetricsConn {
    stream: TcpStream,
    rbuf: Vec<u8>,
    out: Vec<u8>,
    out_pos: usize,
}

fn accept_metrics(
    listener: &TcpListener,
    poller: &Poller,
    mconns: &mut HashMap<u64, MetricsConn>,
) {
    loop {
        match listener.accept() {
            Ok((stream, _peer)) => {
                if stream.set_nonblocking(true).is_err() {
                    continue;
                }
                let _ = stream.set_nodelay(true);
                let id = NEXT_CONN_ID.fetch_add(1, Ordering::Relaxed);
                if poller
                    .register(stream.as_raw_fd(), id as usize, true, false)
                    .is_ok()
                {
                    mconns.insert(
                        id,
                        MetricsConn {
                            stream,
                            rbuf: Vec::new(),
                            out: Vec::new(),
                            out_pos: 0,
                        },
                    );
                }
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => return,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(_) => return,
        }
    }
}

/// Advance one scrape connection. Returns false when it should close:
/// response fully flushed, EOF before a complete request head, an I/O
/// error, or an oversized head.
fn metrics_conn_event(mc: &mut MetricsConn, ev: Event) -> bool {
    if ev.readable && mc.out.is_empty() {
        let mut buf = [0u8; 4096];
        loop {
            match mc.stream.read(&mut buf) {
                // EOF before the head completed: abandoned scrape
                Ok(0) => return false,
                Ok(n) => {
                    mc.rbuf.extend_from_slice(&buf[..n]);
                    if mc.rbuf.len() > 16 * 1024 {
                        return false;
                    }
                    if n < buf.len() {
                        break;
                    }
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
                Err(_) => return false,
            }
        }
        let head_done = mc.rbuf.windows(4).any(|w| w == &b"\r\n\r\n"[..])
            || mc.rbuf.windows(2).any(|w| w == &b"\n\n"[..]);
        if head_done {
            let body = obs::render_prometheus();
            mc.out = format!(
                "HTTP/1.1 200 OK\r\n\
                 Content-Type: text/plain; version=0.0.4; charset=utf-8\r\n\
                 Content-Length: {}\r\n\
                 Connection: close\r\n\r\n{}",
                body.len(),
                body
            )
            .into_bytes();
        }
    }
    while mc.out_pos < mc.out.len() {
        match mc.stream.write(&mc.out[mc.out_pos..]) {
            Ok(0) => return false,
            Ok(n) => mc.out_pos += n,
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => return true,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(_) => return false,
        }
    }
    // Still waiting on the request head; a fully flushed response
    // (out non-empty, all written) falls through to close.
    mc.out.is_empty()
}

/// One replication subscriber (`pasha follow`, see
/// [`crate::service::replica`]), owned by io thread 0. Receives the
/// `{"cmd":"sub"}` handshake and per-frame acks; sends encoded
/// [`crate::service::replica::ShipFrame`] lines.
struct ReplConn {
    stream: TcpStream,
    /// Unparsed handshake/ack bytes.
    rbuf: Vec<u8>,
    /// Encoded frames queued to the socket, drained from `out_pos`.
    out: Vec<u8>,
    out_pos: usize,
    /// Whether the `sub` handshake arrived (frames flow only after it).
    subscribed: bool,
    /// Cumulative journal bytes this follower last acked (`total`).
    acked: u64,
}

/// Replication telemetry, labeled like [`EvObs`] by listen address.
struct ReplObs {
    /// `pasha_repl_groups_shipped_total` — commit-group frames shipped.
    groups: Arc<obs::Counter>,
    /// `pasha_repl_bytes_shipped_total` — journal bytes shipped (all
    /// frame kinds).
    bytes: Arc<obs::Counter>,
    /// `pasha_repl_lag_bytes` — bytes shipped but not yet acked by the
    /// slowest subscriber (0 with no subscriber).
    lag: Arc<obs::Gauge>,
    /// `pasha_repl_subscribers` — currently subscribed followers.
    subscribers: Arc<obs::Gauge>,
}

impl ReplObs {
    fn new(addr: &str) -> ReplObs {
        let l: &[(&str, &str)] = &[("addr", addr)];
        ReplObs {
            groups: obs::counter("pasha_repl_groups_shipped_total", l),
            bytes: obs::counter("pasha_repl_bytes_shipped_total", l),
            lag: obs::gauge("pasha_repl_lag_bytes", l),
            subscribers: obs::gauge("pasha_repl_subscribers", l),
        }
    }
}

fn accept_repl(listener: &TcpListener, poller: &Poller, rconns: &mut HashMap<u64, ReplConn>) {
    loop {
        match listener.accept() {
            Ok((stream, _peer)) => {
                if stream.set_nonblocking(true).is_err() {
                    continue;
                }
                let _ = stream.set_nodelay(true);
                let id = NEXT_CONN_ID.fetch_add(1, Ordering::Relaxed);
                if poller
                    .register(stream.as_raw_fd(), id as usize, true, false)
                    .is_ok()
                {
                    rconns.insert(
                        id,
                        ReplConn {
                            stream,
                            rbuf: Vec::new(),
                            out: Vec::new(),
                            out_pos: 0,
                            subscribed: false,
                            acked: 0,
                        },
                    );
                }
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => return,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(_) => return,
        }
    }
}

/// Flush a subscriber's write queue as far as the socket allows.
/// Returns false on an I/O error.
fn repl_flush(rc: &mut ReplConn) -> bool {
    while rc.out_pos < rc.out.len() {
        match rc.stream.write(&rc.out[rc.out_pos..]) {
            Ok(0) => return false,
            Ok(n) => rc.out_pos += n,
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(_) => return false,
        }
    }
    if rc.out_pos == rc.out.len() {
        rc.out.clear();
        rc.out_pos = 0;
    }
    true
}

/// Advance one subscriber on readiness: read handshake/ack lines, then
/// flush pending frames. Returns `(alive, newly_subscribed)`.
fn repl_conn_event(rc: &mut ReplConn, ev: Event) -> (bool, bool) {
    let mut newly_subscribed = false;
    if ev.readable {
        let mut buf = [0u8; 4096];
        loop {
            match rc.stream.read(&mut buf) {
                Ok(0) => return (false, newly_subscribed), // follower left
                Ok(n) => {
                    rc.rbuf.extend_from_slice(&buf[..n]);
                    if rc.rbuf.len() > 64 * 1024 {
                        return (false, newly_subscribed); // ack lines are tiny
                    }
                    if n < buf.len() {
                        break;
                    }
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
                Err(_) => return (false, newly_subscribed),
            }
        }
        while let Some(nl) = rc.rbuf.iter().position(|&b| b == b'\n') {
            let line = String::from_utf8_lossy(&rc.rbuf[..nl]).into_owned();
            rc.rbuf.drain(..=nl);
            let trimmed = line.trim();
            if trimmed.is_empty() {
                continue;
            }
            let Ok(v) = parse(trimmed) else { continue };
            if v.get("cmd").and_then(|c| c.as_str()) == Some("sub") {
                if !rc.subscribed {
                    rc.subscribed = true;
                    newly_subscribed = true;
                }
                // the follower skips non-repl lines, so a plain ack is safe
                rc.out.extend_from_slice(b"{\"ok\":true,\"sub\":true}\n");
            } else if let Some(total) = v.get("total").and_then(|t| t.as_f64()) {
                if total >= 0.0 {
                    rc.acked = total as u64;
                }
            }
        }
    }
    (repl_flush(rc), newly_subscribed)
}

/// Retire a subscriber. When the last subscribed follower goes away,
/// shipping turns off — frames stop accumulating, and a future
/// subscriber restarts from a full rebase.
fn drop_repl_conn(
    id: u64,
    shared: &Shared,
    poller: &Poller,
    rconns: &mut HashMap<u64, ReplConn>,
) {
    if let Some(rc) = rconns.remove(&id) {
        let _ = poller.deregister(rc.stream.as_raw_fd());
    }
    if !rconns.values().any(|r| r.subscribed) && shared.registry.shipping() {
        if let Err(e) = shared.registry.set_shipping(false) {
            crate::log_warn!("serve: cannot disable replication: {e}");
        }
    }
}

fn sync_repl_gauges(rconns: &HashMap<u64, ReplConn>, robs: Option<&ReplObs>, shipped: u64) {
    let Some(ro) = robs else { return };
    let subs = rconns.values().filter(|r| r.subscribed);
    let min_acked = subs.clone().map(|r| r.acked).min();
    ro.subscribers.set(subs.count() as i64);
    ro.lag.set(match min_acked {
        Some(acked) => shipped.saturating_sub(acked) as i64,
        None => 0,
    });
}

/// Final replication flush on drain exit: ship whatever the last commit
/// groups parked in the sink and push it onto the wire (bounded wait —
/// the sockets are non-blocking) so a cleanly shut down leader's
/// follower holds a byte-identical copy. The follower needs no ack
/// round-trip: bytes written before close are delivered, and it applies
/// everything up to EOF.
fn finish_repl(
    shared: &Shared,
    rconns: &mut HashMap<u64, ReplConn>,
    robs: Option<&ReplObs>,
    shipped_bytes: &mut u64,
) {
    if !rconns.values().any(|r| r.subscribed) {
        return;
    }
    let frames = shared.registry.drain_ship_sink();
    let mut payload: Vec<u8> = Vec::new();
    for frame in &frames {
        match frame.to_line() {
            Ok(line) => {
                if let Some(ro) = robs {
                    if frame.kind == ShipKind::Group {
                        ro.groups.inc();
                    }
                    ro.bytes.add(frame.bytes.len() as u64);
                }
                *shipped_bytes += frame.bytes.len() as u64;
                payload.extend_from_slice(line.as_bytes());
            }
            Err(e) => crate::log_warn!("serve: cannot encode replication frame: {e}"),
        }
    }
    let deadline = Instant::now() + REPL_FLUSH_DEADLINE;
    for rc in rconns.values_mut() {
        if !rc.subscribed {
            continue;
        }
        rc.out.extend_from_slice(&payload);
        while rc.out_pos < rc.out.len() && Instant::now() < deadline {
            if !repl_flush(rc) {
                break;
            }
            if rc.out_pos < rc.out.len() {
                std::thread::sleep(Duration::from_millis(5));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn collect_sessions_covers_requests_responses_and_batches() {
        let req = parse(
            "{\"cmd\":\"batch\",\"ops\":[\
             {\"cmd\":\"ask\",\"session\":\"s0001\"},\
             {\"cmd\":\"tell\",\"session\":\"s0002\"}]}",
        )
        .unwrap();
        let resp = parse("{\"ok\":true,\"results\":[{\"ok\":true,\"session\":\"s0003\"}]}").unwrap();
        let mut touched = BTreeSet::new();
        collect_sessions(&req, &resp, &mut touched);
        let got: Vec<&str> = touched.iter().map(|s| s.as_str()).collect();
        assert_eq!(got, vec!["s0001", "s0002", "s0003"]);

        // create: the new id only exists in the response
        let req = parse("{\"cmd\":\"create\",\"spec\":{}}").unwrap();
        let resp = parse("{\"ok\":true,\"session\":\"s0009\"}").unwrap();
        let mut touched = BTreeSet::new();
        collect_sessions(&req, &resp, &mut touched);
        assert!(touched.contains("s0009"));
    }

    #[test]
    fn mailbox_push_wakes_and_drains_in_order() {
        let (wake_tx, wake_rx) = UnixStream::pair().unwrap();
        wake_tx.set_nonblocking(true).unwrap();
        wake_rx.set_nonblocking(true).unwrap();
        let mb = Mailbox {
            q: Mutex::new(VecDeque::new()),
            wake: wake_tx,
        };
        mb.push(IoMsg::Done { conn: 5, seq: 0, line: b"a\n".to_vec() });
        mb.push(IoMsg::Done { conn: 5, seq: 1, line: b"b\n".to_vec() });
        let mut byte = [0u8; 16];
        assert!((&wake_rx).read(&mut byte).unwrap() >= 1, "wake byte arrives");
        let msgs = mb.drain();
        assert_eq!(msgs.len(), 2);
        match (&msgs[0], &msgs[1]) {
            (IoMsg::Done { seq: 0, .. }, IoMsg::Done { seq: 1, .. }) => {}
            _ => panic!("messages drained out of order"),
        }
        assert!(mb.drain().is_empty());
    }

    #[test]
    fn reorder_buffer_releases_in_sequence_only() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let stream = TcpStream::connect(listener.local_addr().unwrap()).unwrap();
        let mut c = Conn::new(stream);
        c.next_seq = 3;
        // out-of-order completions wait for seq 0
        c.pending_bytes += 2;
        c.pending.insert(2, b"c\n".to_vec());
        c.pending_bytes += 2;
        c.pending.insert(1, b"b\n".to_vec());
        release_ready(&mut c);
        assert!(c.out.is_empty(), "nothing releases before seq 0");
        c.pending_bytes += 2;
        c.pending.insert(0, b"a\n".to_vec());
        release_ready(&mut c);
        assert_eq!(&c.out, b"a\nb\nc\n", "in-order burst once the gap fills");
        assert_eq!(c.pending_bytes, 0);
        assert!(c.pending.is_empty());
    }
}
