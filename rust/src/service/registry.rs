//! Sharded multi-session registry: the server's session store, with
//! journal-directory recovery at startup.
//!
//! Sessions are **single-owner actors**: each session id hashes
//! (FNV-1a 64) to one of N shards, and only that shard's worker thread
//! ever touches the session, so the hot path has no per-session mutex
//! contention — the shard maps below are `Mutex`-wrapped only so the
//! registry stays safe for embedders and tests that call in from
//! arbitrary threads (the event loop's shard workers are each the sole
//! steady-state lockers of their own shard). The routing table
//! ([`Registry::shard_of`]) is pure arithmetic: read-mostly, never
//! locked.
//!
//! When a journal directory is configured, the constructor recovers
//! every `*.jsonl` file in it — a restarted server resumes exactly
//! where the crashed one stopped (workers that survived the restart can
//! keep telling into their in-flight jobs; for workers that died with
//! it, `expire` re-queues their jobs).
//!
//! Group commit: [`Registry::set_group_commit`] switches every session
//! journal into buffered mode; the serving shard then calls
//! [`Registry::commit_session`] once per commit group (one `write` +
//! one `sync_all` for the whole group) before any response in the
//! group is released. [`Registry::close`] commits before dropping the
//! session, so no acknowledged-or-about-to-be-acknowledged line is
//! ever discarded.

use crate::service::replica::ShipFrame;
use crate::service::session::{RecoveryReport, Session, SessionOptions};
use crate::spec::ExperimentSpec;
use crate::util::json::Json;
use std::collections::HashMap;
use std::fmt;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// Error type of every service-layer operation.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ServiceError {
    /// No session with that id.
    UnknownSession(String),
    /// Malformed or unbuildable session spec.
    Spec(String),
    /// Journal I/O failure.
    Io(String),
    /// Journal contents unusable (corrupt, foreign, or divergent).
    Journal(String),
    /// A session-level protocol violation (bad tell, unknown trial…).
    Session(String),
    /// Malformed request (wire-level).
    Request(String),
}

impl fmt::Display for ServiceError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServiceError::UnknownSession(id) => write!(f, "unknown session '{id}'"),
            ServiceError::Spec(m) => write!(f, "bad session spec: {m}"),
            ServiceError::Io(m) => write!(f, "journal io: {m}"),
            ServiceError::Journal(m) => write!(f, "journal: {m}"),
            ServiceError::Session(m) => write!(f, "session: {m}"),
            ServiceError::Request(m) => write!(f, "bad request: {m}"),
        }
    }
}

impl std::error::Error for ServiceError {}

/// FNV-1a 64 over the session id: stable across runs and processes
/// (unlike `RandomState`), so a session's shard — and therefore its
/// processing order relative to other ops — is deterministic.
pub(crate) fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// The default shard count: one session-owning worker per available
/// core, within sane bounds.
pub fn default_shards() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .clamp(2, 16)
}

/// The sharded session store.
pub struct Registry {
    dir: Option<PathBuf>,
    options: SessionOptions,
    /// `shards[fnv1a64(id) % shards.len()]` owns session `id`.
    shards: Vec<Mutex<HashMap<String, Session>>>,
    next_id: Mutex<usize>,
    /// Applied to every current and future session journal.
    group_commit: AtomicBool,
    /// Replication shipping on: sessions retain durable commit-group
    /// bytes as [`ShipFrame`]s, drained into `ship_sink`.
    shipping: AtomicBool,
    /// Frames collected from sessions ([`Registry::collect_shipped`]),
    /// awaiting pickup by the replication layer. Per-journal frame order
    /// is preserved: frames enter under the owning shard's lock.
    ship_sink: Mutex<Vec<ShipFrame>>,
    /// Sessions recovered from the journal directory at startup.
    recovered: Vec<(String, RecoveryReport)>,
}

impl Registry {
    /// An in-memory registry (no journals — sessions die with the
    /// process). Used by tests and the loopback stress benchmark.
    pub fn in_memory() -> Registry {
        Self::in_memory_opts(SessionOptions::default())
    }

    /// [`Registry::in_memory`] with an explicit session policy (e.g. a
    /// trial store without a journal directory).
    pub fn in_memory_opts(options: SessionOptions) -> Registry {
        Self::in_memory_sharded(options, default_shards())
    }

    /// [`Registry::in_memory_opts`] with an explicit shard count
    /// (`pasha serve --shards` without a journal directory).
    pub fn in_memory_sharded(options: SessionOptions, n_shards: usize) -> Registry {
        Self::assemble(None, options, n_shards, Vec::new(), 0)
            .expect("in-memory registry cannot fail")
    }

    /// A durable registry journaling into `dir`, recovering every
    /// `*.jsonl` session journal already present (snapshot-aware, but
    /// writing no new snapshots — see [`Registry::with_journal_dir_opts`]).
    pub fn with_journal_dir(dir: PathBuf) -> Result<Registry, ServiceError> {
        Self::with_journal_dir_opts(dir, SessionOptions::default())
    }

    /// [`Registry::with_journal_dir`] with a snapshot/compaction policy
    /// applied to every session (recovered and newly created).
    pub fn with_journal_dir_opts(
        dir: PathBuf,
        options: SessionOptions,
    ) -> Result<Registry, ServiceError> {
        Self::with_journal_dir_sharded(dir, options, default_shards())
    }

    /// [`Registry::with_journal_dir_opts`] with an explicit shard count
    /// (`pasha serve --shards`).
    pub fn with_journal_dir_sharded(
        dir: PathBuf,
        options: SessionOptions,
        n_shards: usize,
    ) -> Result<Registry, ServiceError> {
        std::fs::create_dir_all(&dir).map_err(|e| ServiceError::Io(e.to_string()))?;
        let mut paths: Vec<PathBuf> = std::fs::read_dir(&dir)
            .map_err(|e| ServiceError::Io(e.to_string()))?
            .filter_map(|e| e.ok().map(|e| e.path()))
            .filter(|p| p.extension().map(|x| x == "jsonl").unwrap_or(false))
            .collect();
        paths.sort();
        let mut sessions = Vec::new();
        let mut recovered = Vec::new();
        let mut max_numeric_id = 0usize;
        for path in paths {
            let (session, report) =
                Session::recover_with(&path, options.clone()).map_err(|e| match e {
                    ServiceError::Journal(m) => {
                        ServiceError::Journal(format!("{}: {m}", path.display()))
                    }
                    other => other,
                })?;
            let numeric = session.id.strip_prefix('s').and_then(|s| s.parse::<usize>().ok());
            if let Some(n) = numeric {
                max_numeric_id = max_numeric_id.max(n + 1);
            }
            recovered.push((session.id.clone(), report));
            sessions.push(session);
        }
        Self::assemble(Some(dir), options, n_shards, sessions, max_numeric_id)
            .map(|mut reg| {
                reg.recovered = recovered;
                reg
            })
    }

    fn assemble(
        dir: Option<PathBuf>,
        options: SessionOptions,
        n_shards: usize,
        sessions: Vec<Session>,
        next_id: usize,
    ) -> Result<Registry, ServiceError> {
        let n = n_shards.max(1);
        let mut shards: Vec<Mutex<HashMap<String, Session>>> = Vec::with_capacity(n);
        for _ in 0..n {
            shards.push(Mutex::new(HashMap::new()));
        }
        let reg = Registry {
            dir,
            options,
            shards,
            next_id: Mutex::new(next_id),
            group_commit: AtomicBool::new(false),
            shipping: AtomicBool::new(false),
            ship_sink: Mutex::new(Vec::new()),
            recovered: Vec::new(),
        };
        for session in sessions {
            let shard = reg.shard_of(&session.id);
            reg.shards[shard]
                .lock()
                .expect("shard lock")
                .insert(session.id.clone(), session);
        }
        Ok(reg)
    }

    /// Sessions recovered at startup (id + what replay found).
    pub fn recovered(&self) -> &[(String, RecoveryReport)] {
        &self.recovered
    }

    /// Number of session-owning shards.
    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// The shard that owns `id`. Pure arithmetic on a stable hash —
    /// this is the read-mostly routing table, never a lock.
    pub fn shard_of(&self, id: &str) -> usize {
        (fnv1a64(id.as_bytes()) % self.shards.len() as u64) as usize
    }

    /// Run `f` against the session `id`, on whatever shard owns it.
    /// This is the single session access path: on the served hot path
    /// the caller *is* the owning shard worker, so the lock below is
    /// uncontended by construction.
    pub fn with_session<R>(
        &self,
        id: &str,
        f: impl FnOnce(&mut Session) -> R,
    ) -> Result<R, ServiceError> {
        let mut shard = self.shards[self.shard_of(id)].lock().expect("shard lock");
        match shard.get_mut(id) {
            Some(session) => Ok(f(session)),
            None => Err(ServiceError::UnknownSession(id.to_string())),
        }
    }

    /// Create a new session and return its id.
    pub fn create(&self, spec: ExperimentSpec) -> Result<String, ServiceError> {
        let id = {
            let mut n = self.next_id.lock().expect("registry lock");
            let id = format!("s{:04}", *n);
            *n += 1;
            id
        };
        let journal_path = self.dir.as_ref().map(|d| d.join(format!("{id}.jsonl")));
        let mut session =
            Session::create_with(&id, spec, journal_path.as_deref(), self.options.clone())?;
        if self.group_commit.load(Ordering::SeqCst) {
            session.set_group_commit(true)?;
        }
        if self.shipping.load(Ordering::SeqCst) {
            session.set_shipping(true)?;
        }
        let mut shard = self.shards[self.shard_of(&id)].lock().expect("shard lock");
        let frames = session.drain_ship_frames();
        shard.insert(id.clone(), session);
        if !frames.is_empty() {
            self.ship_sink.lock().expect("ship sink").extend(frames);
        }
        Ok(id)
    }

    /// Switch every session journal (current and future) into or out of
    /// group-commit mode. The event loop turns this on before serving.
    pub fn set_group_commit(&self, on: bool) -> Result<(), ServiceError> {
        self.group_commit.store(on, Ordering::SeqCst);
        for shard in &self.shards {
            let mut shard = shard.lock().expect("shard lock");
            for session in shard.values_mut() {
                session.set_group_commit(on)?;
            }
        }
        Ok(())
    }

    /// Commit session `id`'s current journal group to disk (no-op for
    /// journal-less or write-through sessions). The owning shard calls
    /// this once per commit group, before releasing the group's
    /// responses.
    pub fn commit_session(&self, id: &str) -> Result<(), ServiceError> {
        self.with_session(id, |s| s.commit_journal())?
    }

    /// Turn replication shipping on (or off) for every current and
    /// future session. Enabling queues full-file rebase frames so a
    /// subscriber starts from byte-level copies; they land in the sink
    /// immediately (drain with [`Registry::drain_ship_sink`]).
    pub fn set_shipping(&self, on: bool) -> Result<(), ServiceError> {
        self.shipping.store(on, Ordering::SeqCst);
        for shard in &self.shards {
            let mut shard = shard.lock().expect("shard lock");
            // id order: rebase frames for distinct journals are
            // independent, but a deterministic order keeps runs comparable
            let mut ids: Vec<String> = shard.keys().cloned().collect();
            ids.sort();
            for id in ids {
                let session = shard.get_mut(&id).expect("id just listed");
                session.set_shipping(on)?;
                let frames = session.drain_ship_frames();
                if !frames.is_empty() {
                    self.ship_sink.lock().expect("ship sink").extend(frames);
                }
            }
        }
        if !on {
            self.ship_sink.lock().expect("ship sink").clear();
        }
        Ok(())
    }

    /// Is replication shipping on? (Lock-free fast path for the shard
    /// workers' per-group check.)
    pub fn shipping(&self) -> bool {
        self.shipping.load(Ordering::SeqCst)
    }

    /// Move session `id`'s queued replication frames into the sink,
    /// returning how many moved. Called by the owning shard right after
    /// a successful [`Registry::commit_session`] — the sink lock is
    /// taken while still holding the shard lock, so per-journal frame
    /// order in the sink matches commit order.
    pub fn collect_shipped(&self, id: &str) -> usize {
        if !self.shipping() {
            return 0;
        }
        let mut shard = self.shards[self.shard_of(id)].lock().expect("shard lock");
        let Some(session) = shard.get_mut(id) else {
            return 0; // closed in its own commit group: close() collected
        };
        let frames = session.drain_ship_frames();
        let n = frames.len();
        if n > 0 {
            self.ship_sink.lock().expect("ship sink").extend(frames);
        }
        n
    }

    /// Drain every frame awaiting shipment, in arrival order.
    pub fn drain_ship_sink(&self) -> Vec<ShipFrame> {
        std::mem::take(&mut *self.ship_sink.lock().expect("ship sink"))
    }

    /// Expire stale worker leases on every session owned by `shard`:
    /// the event loop's per-shard liveness tick. Sessions are swept in
    /// id order; each expiry is journaled, committed, and (when
    /// shipping) collected, exactly like a client-driven mutation.
    /// Returns `(session, expired workers)` pairs for tracing.
    pub fn expire_stale_shard(&self, shard: usize, lease: Duration) -> Vec<(String, Vec<String>)> {
        let Some(slot) = self.shards.get(shard) else {
            return Vec::new();
        };
        let mut out = Vec::new();
        let mut guard = slot.lock().expect("shard lock");
        let mut ids: Vec<String> = guard.keys().cloned().collect();
        ids.sort();
        for id in ids {
            let session = guard.get_mut(&id).expect("id just listed");
            let expired = match session.expire_stale(lease) {
                Ok(w) => w,
                Err(_) => continue, // poisoned/io: surfaced on the next op
            };
            if expired.is_empty() {
                continue;
            }
            if session.commit_journal().is_ok() && self.shipping() {
                let frames = session.drain_ship_frames();
                if !frames.is_empty() {
                    self.ship_sink.lock().expect("ship sink").extend(frames);
                }
            }
            out.push((id, expired));
        }
        out
    }

    /// Status summaries of every registered session, id-sorted.
    pub fn statuses(&self) -> Vec<Json> {
        let mut all: Vec<(String, Json)> = Vec::new();
        for shard in &self.shards {
            let shard = shard.lock().expect("shard lock");
            for (id, session) in shard.iter() {
                all.push((id.clone(), session.status()));
            }
        }
        all.sort_by(|a, b| a.0.cmp(&b.0));
        all.into_iter().map(|(_, st)| st).collect()
    }

    /// Drop a session from the registry (its journal file, if any,
    /// stays on disk and can be recovered later). Any buffered journal
    /// group is committed first, so closing never discards lines whose
    /// ops were already applied.
    pub fn close(&self, id: &str) -> Result<(), ServiceError> {
        let mut shard = self.shards[self.shard_of(id)].lock().expect("shard lock");
        match shard.get_mut(id) {
            Some(session) => {
                session.commit_journal()?;
                // frames from that final commit must outlive the session
                let frames = session.drain_ship_frames();
                if !frames.is_empty() {
                    self.ship_sink.lock().expect("ship sink").extend(frames);
                }
                shard.remove(id);
                Ok(())
            }
            None => Err(ServiceError::UnknownSession(id.to_string())),
        }
    }

    pub fn len(&self) -> usize {
        self.shards
            .iter()
            .map(|s| s.lock().expect("shard lock").len())
            .sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::benchmarks::Benchmark;
    use crate::scheduler::asktell::{TellAck, TrialAssignment};

    fn tmp_dir(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("pasha-reg-{}-{name}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn small_spec() -> ExperimentSpec {
        let mut spec = ExperimentSpec::named("lcbench-Fashion-MNIST", "asha").unwrap();
        spec.stop.config_budget = 6;
        spec
    }

    fn drive(reg: &Registry, id: &str, bench: &dyn Benchmark, bench_seed: u64) {
        loop {
            let assignment = reg.with_session(id, |s| s.ask("w0")).unwrap().unwrap();
            match assignment {
                TrialAssignment::Run(job) => {
                    for e in job.from_epoch + 1..=job.milestone {
                        let m = bench.accuracy_at(&job.config, e, bench_seed);
                        let ack = reg
                            .with_session(id, |s| s.tell(job.trial, e, m))
                            .unwrap()
                            .unwrap();
                        if ack == TellAck::Abandon {
                            break;
                        }
                    }
                }
                TrialAssignment::Stop(_) | TrialAssignment::Pause(_) => {}
                TrialAssignment::Wait => panic!("single worker never waits"),
                TrialAssignment::Done => return,
            }
        }
    }

    #[test]
    fn create_access_close_lifecycle() {
        let reg = Registry::in_memory();
        assert!(reg.is_empty());
        let id = reg.create(small_spec()).unwrap();
        assert_eq!(id, "s0000");
        let id2 = reg.create(small_spec()).unwrap();
        assert_eq!(id2, "s0001");
        assert_eq!(reg.len(), 2);
        assert!(reg.with_session(&id, |s| s.events_total()).is_ok());
        match reg.with_session("nope", |_| ()) {
            Err(ServiceError::UnknownSession(missing)) => assert_eq!(missing, "nope"),
            Err(e) => panic!("wrong error {e}"),
            Ok(_) => panic!("unknown id must not resolve"),
        }
        reg.close(&id).unwrap();
        assert_eq!(reg.len(), 1);
        assert!(reg.close(&id).is_err(), "double close is an error");
    }

    #[test]
    fn routing_is_stable_and_in_range() {
        let reg = Registry::in_memory();
        let n = reg.n_shards();
        assert!(n >= 1);
        for i in 0..100 {
            let id = format!("s{i:04}");
            let shard = reg.shard_of(&id);
            assert!(shard < n);
            assert_eq!(shard, reg.shard_of(&id), "routing is deterministic");
        }
        // the spread uses more than one shard (FNV over distinct ids)
        let distinct: std::collections::HashSet<usize> =
            (0..100).map(|i| reg.shard_of(&format!("s{i:04}"))).collect();
        if n > 1 {
            assert!(distinct.len() > 1, "sessions spread across shards");
        }
    }

    #[test]
    fn durable_registry_recovers_all_sessions() {
        let dir = tmp_dir("recover");
        let spec = small_spec();
        let bench = spec.bench.build().unwrap();
        {
            let reg = Registry::with_journal_dir(dir.clone()).unwrap();
            let id_a = reg.create(spec.clone()).unwrap();
            let id_b = reg.create(spec.clone()).unwrap();
            drive(&reg, &id_a, bench.as_ref(), spec.bench_seed);
            // leave id_b mid-session: one job asked and never told
            let first = reg.with_session(&id_b, |s| s.ask("w0")).unwrap().unwrap();
            assert!(matches!(first, TrialAssignment::Run(_)));
        }
        let reg2 = Registry::with_journal_dir(dir).unwrap();
        assert_eq!(reg2.len(), 2);
        assert_eq!(reg2.recovered().len(), 2);
        // ids continue past the recovered ones
        let id_c = reg2.create(spec).unwrap();
        assert_eq!(id_c, "s0002");
        // the completed session is still done
        let done = reg2.with_session("s0000", |s| s.ask("w0")).unwrap().unwrap();
        assert_eq!(done, TrialAssignment::Done);
        // the mid-flight session still has its job in flight
        let in_flight = reg2
            .with_session("s0001", |s| s.core_ref().in_flight_count())
            .unwrap();
        assert_eq!(in_flight, 1);
    }

    #[test]
    fn snapshot_registry_recovers_from_tail() {
        let dir = tmp_dir("snap");
        let spec = small_spec();
        let bench = spec.bench.build().unwrap();
        let options = SessionOptions::snapshot_every(8);
        let total;
        {
            let reg = Registry::with_journal_dir_opts(dir.clone(), options.clone()).unwrap();
            let id = reg.create(spec.clone()).unwrap();
            drive(&reg, &id, bench.as_ref(), spec.bench_seed);
            total = reg.with_session(&id, |s| s.events_total()).unwrap();
        }
        let reg2 = Registry::with_journal_dir_opts(dir, options).unwrap();
        let (_, report) = &reg2.recovered()[0];
        assert!(report.snapshot_events > 0, "snapshot used on restart");
        assert!(report.events_replayed < total);
        let done = reg2.with_session("s0000", |s| s.ask("w0")).unwrap().unwrap();
        assert_eq!(done, TrialAssignment::Done);
    }

    #[test]
    fn group_commit_registry_commits_before_close() {
        let dir = tmp_dir("group-close");
        let spec = small_spec();
        let bench = spec.bench.build().unwrap();
        let reg = Registry::with_journal_dir(dir.clone()).unwrap();
        reg.set_group_commit(true).unwrap();
        let id = reg.create(spec.clone()).unwrap();
        drive(&reg, &id, bench.as_ref(), spec.bench_seed);
        reg.close(&id).unwrap();
        // everything the session acknowledged is on disk: a fresh
        // registry recovers it to the same Done state
        let reg2 = Registry::with_journal_dir(dir).unwrap();
        let done = reg2.with_session(&id, |s| s.ask("w0")).unwrap().unwrap();
        assert_eq!(done, TrialAssignment::Done);
    }

    #[test]
    fn statuses_are_sorted_and_complete() {
        let reg = Registry::in_memory();
        reg.create(small_spec()).unwrap();
        reg.create(small_spec()).unwrap();
        let sts = reg.statuses();
        assert_eq!(sts.len(), 2);
        assert_eq!(sts[0].get("id").unwrap().as_str(), Some("s0000"));
        assert_eq!(sts[1].get("id").unwrap().as_str(), Some("s0001"));
    }
}
