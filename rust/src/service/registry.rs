//! Multi-session registry: the server's shared, thread-safe session
//! store, with journal-directory recovery at startup.
//!
//! Each session lives behind its own `Mutex`, so concurrent clients
//! working different sessions never contend; the registry map itself is
//! only locked for the short lookup/insert. When a journal directory is
//! configured, `Registry::new` recovers every `*.jsonl` file in it —
//! a restarted server resumes exactly where the crashed one stopped
//! (workers that survived the restart can keep telling into their
//! in-flight jobs; for workers that died with it, `expire` re-queues
//! their jobs).

use crate::service::session::{RecoveryReport, Session, SessionOptions};
use crate::spec::ExperimentSpec;
use crate::util::json::Json;
use std::collections::HashMap;
use std::fmt;
use std::path::PathBuf;
use std::sync::{Arc, Mutex};

/// Error type of every service-layer operation.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ServiceError {
    /// No session with that id.
    UnknownSession(String),
    /// Malformed or unbuildable session spec.
    Spec(String),
    /// Journal I/O failure.
    Io(String),
    /// Journal contents unusable (corrupt, foreign, or divergent).
    Journal(String),
    /// A session-level protocol violation (bad tell, unknown trial…).
    Session(String),
    /// Malformed request (wire-level).
    Request(String),
}

impl fmt::Display for ServiceError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServiceError::UnknownSession(id) => write!(f, "unknown session '{id}'"),
            ServiceError::Spec(m) => write!(f, "bad session spec: {m}"),
            ServiceError::Io(m) => write!(f, "journal io: {m}"),
            ServiceError::Journal(m) => write!(f, "journal: {m}"),
            ServiceError::Session(m) => write!(f, "session: {m}"),
            ServiceError::Request(m) => write!(f, "bad request: {m}"),
        }
    }
}

impl std::error::Error for ServiceError {}

/// The shared session store.
pub struct Registry {
    dir: Option<PathBuf>,
    options: SessionOptions,
    sessions: Mutex<HashMap<String, Arc<Mutex<Session>>>>,
    next_id: Mutex<usize>,
    /// Sessions recovered from the journal directory at startup.
    recovered: Vec<(String, RecoveryReport)>,
}

impl Registry {
    /// An in-memory registry (no journals — sessions die with the
    /// process). Used by tests and the loopback stress benchmark.
    pub fn in_memory() -> Registry {
        Self::in_memory_opts(SessionOptions::default())
    }

    /// [`Registry::in_memory`] with an explicit session policy (e.g. a
    /// trial store without a journal directory).
    pub fn in_memory_opts(options: SessionOptions) -> Registry {
        Registry {
            dir: None,
            options,
            sessions: Mutex::new(HashMap::new()),
            next_id: Mutex::new(0),
            recovered: Vec::new(),
        }
    }

    /// A durable registry journaling into `dir`, recovering every
    /// `*.jsonl` session journal already present (snapshot-aware, but
    /// writing no new snapshots — see [`Registry::with_journal_dir_opts`]).
    pub fn with_journal_dir(dir: PathBuf) -> Result<Registry, ServiceError> {
        Self::with_journal_dir_opts(dir, SessionOptions::default())
    }

    /// [`Registry::with_journal_dir`] with a snapshot/compaction policy
    /// applied to every session (recovered and newly created).
    pub fn with_journal_dir_opts(
        dir: PathBuf,
        options: SessionOptions,
    ) -> Result<Registry, ServiceError> {
        std::fs::create_dir_all(&dir).map_err(|e| ServiceError::Io(e.to_string()))?;
        let mut sessions = HashMap::new();
        let mut recovered = Vec::new();
        let mut max_numeric_id = 0usize;
        let mut paths: Vec<PathBuf> = std::fs::read_dir(&dir)
            .map_err(|e| ServiceError::Io(e.to_string()))?
            .filter_map(|e| e.ok().map(|e| e.path()))
            .filter(|p| p.extension().map(|x| x == "jsonl").unwrap_or(false))
            .collect();
        paths.sort();
        for path in paths {
            let (session, report) =
                Session::recover_with(&path, options.clone()).map_err(|e| match e {
                    ServiceError::Journal(m) => {
                        ServiceError::Journal(format!("{}: {m}", path.display()))
                    }
                    other => other,
                })?;
            let numeric = session.id.strip_prefix('s').and_then(|s| s.parse::<usize>().ok());
            if let Some(n) = numeric {
                max_numeric_id = max_numeric_id.max(n + 1);
            }
            recovered.push((session.id.clone(), report));
            sessions.insert(session.id.clone(), Arc::new(Mutex::new(session)));
        }
        Ok(Registry {
            dir: Some(dir),
            options,
            sessions: Mutex::new(sessions),
            next_id: Mutex::new(max_numeric_id),
            recovered,
        })
    }

    /// Sessions recovered at startup (id + what replay found).
    pub fn recovered(&self) -> &[(String, RecoveryReport)] {
        &self.recovered
    }

    /// Create a new session and return its id.
    pub fn create(&self, spec: ExperimentSpec) -> Result<String, ServiceError> {
        let id = {
            let mut n = self.next_id.lock().expect("registry lock");
            let id = format!("s{:04}", *n);
            *n += 1;
            id
        };
        let journal_path = self.dir.as_ref().map(|d| d.join(format!("{id}.jsonl")));
        let session =
            Session::create_with(&id, spec, journal_path.as_deref(), self.options.clone())?;
        self.sessions
            .lock()
            .expect("registry lock")
            .insert(id.clone(), Arc::new(Mutex::new(session)));
        Ok(id)
    }

    /// Look up a session by id.
    pub fn get(&self, id: &str) -> Result<Arc<Mutex<Session>>, ServiceError> {
        self.sessions
            .lock()
            .expect("registry lock")
            .get(id)
            .cloned()
            .ok_or_else(|| ServiceError::UnknownSession(id.to_string()))
    }

    /// Status summaries of every registered session, id-sorted.
    pub fn statuses(&self) -> Vec<Json> {
        let handles: Vec<(String, Arc<Mutex<Session>>)> = {
            let map = self.sessions.lock().expect("registry lock");
            let mut v: Vec<_> = map.iter().map(|(k, s)| (k.clone(), s.clone())).collect();
            v.sort_by(|a, b| a.0.cmp(&b.0));
            v
        };
        handles
            .into_iter()
            .map(|(_, s)| s.lock().expect("session lock").status())
            .collect()
    }

    /// Drop a session from the registry (its journal file, if any, stays
    /// on disk and can be recovered later).
    pub fn close(&self, id: &str) -> Result<(), ServiceError> {
        self.sessions
            .lock()
            .expect("registry lock")
            .remove(id)
            .map(|_| ())
            .ok_or_else(|| ServiceError::UnknownSession(id.to_string()))
    }

    pub fn len(&self) -> usize {
        self.sessions.lock().expect("registry lock").len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::benchmarks::Benchmark;
    use crate::scheduler::asktell::{TellAck, TrialAssignment};

    fn tmp_dir(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("pasha-reg-{}-{name}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn small_spec() -> ExperimentSpec {
        let mut spec = ExperimentSpec::named("lcbench-Fashion-MNIST", "asha").unwrap();
        spec.stop.config_budget = 6;
        spec
    }

    fn drive(session: &Arc<Mutex<Session>>, bench: &dyn Benchmark, bench_seed: u64) {
        loop {
            let assignment = session.lock().unwrap().ask("w0").unwrap();
            match assignment {
                TrialAssignment::Run(job) => {
                    for e in job.from_epoch + 1..=job.milestone {
                        let m = bench.accuracy_at(&job.config, e, bench_seed);
                        let ack = session.lock().unwrap().tell(job.trial, e, m).unwrap();
                        if ack == TellAck::Abandon {
                            break;
                        }
                    }
                }
                TrialAssignment::Stop(_) | TrialAssignment::Pause(_) => {}
                TrialAssignment::Wait => panic!("single worker never waits"),
                TrialAssignment::Done => return,
            }
        }
    }

    #[test]
    fn create_get_close_lifecycle() {
        let reg = Registry::in_memory();
        assert!(reg.is_empty());
        let id = reg.create(small_spec()).unwrap();
        assert_eq!(id, "s0000");
        let id2 = reg.create(small_spec()).unwrap();
        assert_eq!(id2, "s0001");
        assert_eq!(reg.len(), 2);
        assert!(reg.get(&id).is_ok());
        match reg.get("nope") {
            Err(ServiceError::UnknownSession(missing)) => assert_eq!(missing, "nope"),
            Err(e) => panic!("wrong error {e}"),
            Ok(_) => panic!("unknown id must not resolve"),
        }
        reg.close(&id).unwrap();
        assert_eq!(reg.len(), 1);
        assert!(reg.close(&id).is_err(), "double close is an error");
    }

    #[test]
    fn durable_registry_recovers_all_sessions() {
        let dir = tmp_dir("recover");
        let spec = small_spec();
        let bench = spec.bench.build().unwrap();
        {
            let reg = Registry::with_journal_dir(dir.clone()).unwrap();
            let id_a = reg.create(spec.clone()).unwrap();
            let id_b = reg.create(spec.clone()).unwrap();
            drive(&reg.get(&id_a).unwrap(), bench.as_ref(), spec.bench_seed);
            // leave id_b mid-session: one job asked and never told
            let sb = reg.get(&id_b).unwrap();
            let first = sb.lock().unwrap().ask("w0").unwrap();
            assert!(matches!(first, TrialAssignment::Run(_)));
        }
        let reg2 = Registry::with_journal_dir(dir).unwrap();
        assert_eq!(reg2.len(), 2);
        assert_eq!(reg2.recovered().len(), 2);
        // ids continue past the recovered ones
        let id_c = reg2.create(spec).unwrap();
        assert_eq!(id_c, "s0002");
        // the completed session is still done
        let sa = reg2.get("s0000").unwrap();
        assert_eq!(sa.lock().unwrap().ask("w0").unwrap(), TrialAssignment::Done);
        // the mid-flight session still has its job in flight
        let sb = reg2.get("s0001").unwrap();
        assert_eq!(sb.lock().unwrap().core_ref().in_flight_count(), 1);
    }

    #[test]
    fn snapshot_registry_recovers_from_tail() {
        let dir = tmp_dir("snap");
        let spec = small_spec();
        let bench = spec.bench.build().unwrap();
        let options = SessionOptions::snapshot_every(8);
        let total;
        {
            let reg = Registry::with_journal_dir_opts(dir.clone(), options.clone()).unwrap();
            let id = reg.create(spec.clone()).unwrap();
            let s = reg.get(&id).unwrap();
            drive(&s, bench.as_ref(), spec.bench_seed);
            total = s.lock().unwrap().events_total();
        }
        let reg2 = Registry::with_journal_dir_opts(dir, options).unwrap();
        let (_, report) = &reg2.recovered()[0];
        assert!(report.snapshot_events > 0, "snapshot used on restart");
        assert!(report.events_replayed < total);
        let s = reg2.get("s0000").unwrap();
        assert_eq!(
            s.lock().unwrap().ask("w0").unwrap(),
            crate::scheduler::asktell::TrialAssignment::Done
        );
    }

    #[test]
    fn statuses_are_sorted_and_complete() {
        let reg = Registry::in_memory();
        reg.create(small_spec()).unwrap();
        reg.create(small_spec()).unwrap();
        let sts = reg.statuses();
        assert_eq!(sts.len(), 2);
        assert_eq!(sts[0].get("id").unwrap().as_str(), Some("s0000"));
        assert_eq!(sts[1].get("id").unwrap().as_str(), Some("s0001"));
    }
}
