//! Write-ahead journal: one JSONL file per session.
//!
//! Every state-mutating operation (`create`, `ask` with a non-idle
//! response, `tell`, `fail`, `expire`) is appended as one compact JSON
//! line *before* the operation is acknowledged to the client. Recovery
//! ([`crate::service::session::Session::recover`]) replays the events
//! against a freshly-built session; because the ask/tell core is
//! deterministic, replay reconstructs the exact pre-crash state.
//!
//! Crash tolerance: a process dying mid-append leaves a partial final
//! line. [`read_journal`] detects it (no trailing newline, or a line that
//! fails to parse *at the end of the file*) and reports the valid prefix
//! length; [`Journal::open_append_at`] truncates the file back to that
//! prefix before appending, so the journal is always a sequence of whole
//! events. A malformed line in the *middle* of a journal is corruption,
//! not a crash artifact, and is surfaced as an error.
//!
//! Durability: by default writes go straight to the `File` (no
//! userspace buffering), so an acknowledged event has left the process
//! even if it crashes the next instant. The served path goes further:
//! the event loop switches every journal into **group-commit** mode
//! ([`Journal::set_group_commit`]), where appends accumulate in a
//! buffer and the owning shard issues one `write_all` + one `sync_all`
//! per commit group ([`Journal::commit`]) *before any response in the
//! group is released*. Append-before-ack is preserved and strengthened:
//! an acknowledged op is durable against OS/power failure, at the cost
//! of one fsync per commit group instead of one per event. The byte
//! format on disk is identical in both modes — only when bytes hit the
//! file changes.

use crate::util::json::Json;
use crate::util::jsonl;
use std::fs::{File, OpenOptions};
use std::io::{self, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Instant;

/// Session-labeled journal instruments ([`Journal::set_obs`]). Purely
/// observational: recording happens strictly *after* the journaled bytes
/// are formed, so the on-disk format is byte-identical with metrics on,
/// off, or absent.
struct JournalObs {
    /// `pasha_journal_events_total` — events appended (buffered or written).
    events: Arc<crate::obs::Counter>,
    /// `pasha_journal_bytes_total` — bytes appended, newline included.
    bytes: Arc<crate::obs::Counter>,
    /// `pasha_journal_fsyncs_total` — `sync_all` calls actually issued.
    fsyncs: Arc<crate::obs::Counter>,
    /// `pasha_journal_sync_us` — latency of each `sync_all`, µs.
    sync_us: Arc<crate::obs::Histogram>,
    /// `pasha_journal_commit_group_events` — events covered per commit.
    group_size: Arc<crate::obs::Histogram>,
}

/// Append handle for one session's journal file.
pub struct Journal {
    path: PathBuf,
    file: File,
    /// Group-commit mode: appends buffer in `buf` until [`Journal::commit`].
    group: bool,
    buf: Vec<u8>,
    /// Bytes appended since the last successful `sync_all`.
    dirty: bool,
    /// Events appended since the last commit (the commit-group size).
    group_len: u64,
    /// Shipping mode: retain a copy of every byte written to the file so
    /// replication ([`crate::service::replica`]) can stream durable commit
    /// groups to a follower. Observe-only: the file bytes are identical
    /// with shipping on or off.
    ship: bool,
    /// Bytes written to the file since the last [`Journal::take_shipped`].
    shipped: Vec<u8>,
    /// Bytes currently in the file (tracked so shipped frames carry the
    /// exact append offset without an extra metadata syscall).
    file_len: u64,
    obs: Option<JournalObs>,
}

impl Journal {
    /// Create a fresh journal, truncating any existing file.
    pub fn create(path: &Path) -> io::Result<Journal> {
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        let file = OpenOptions::new()
            .create(true)
            .write(true)
            .truncate(true)
            .open(path)?;
        Ok(Journal {
            path: path.to_path_buf(),
            file,
            group: false,
            buf: Vec::new(),
            dirty: false,
            group_len: 0,
            ship: false,
            shipped: Vec::new(),
            file_len: 0,
            obs: None,
        })
    }

    /// Re-open an existing journal for appending, first truncating it to
    /// `valid_len` bytes (the whole-event prefix reported by
    /// [`read_journal`]) so a partial crash line is never appended after.
    pub fn open_append_at(path: &Path, valid_len: u64) -> io::Result<Journal> {
        let file = OpenOptions::new().write(true).open(path)?;
        file.set_len(valid_len)?;
        let mut j = Journal {
            path: path.to_path_buf(),
            file,
            group: false,
            buf: Vec::new(),
            // conservatively dirty: the bytes already in the file (e.g. a
            // compaction rewrite) may not have been fsynced yet, so the
            // next commit must not skip its sync
            dirty: true,
            group_len: 0,
            ship: false,
            shipped: Vec::new(),
            file_len: valid_len,
            obs: None,
        };
        j.file.seek(SeekFrom::End(0))?;
        Ok(j)
    }

    /// Append one event. In write-through mode (the default) the line
    /// reaches the OS before returning; in group-commit mode it buffers
    /// until [`Journal::commit`]. Either way the caller must not
    /// acknowledge the operation if the append (or, in group mode, the
    /// later commit) fails.
    pub fn append(&mut self, event: &Json) -> io::Result<()> {
        let mut line = event.to_string_compact();
        line.push('\n');
        self.dirty = true;
        self.group_len += 1;
        if let Some(o) = &self.obs {
            o.events.inc();
            o.bytes.add(line.len() as u64);
        }
        if self.group {
            self.buf.extend_from_slice(line.as_bytes());
            Ok(())
        } else {
            self.file.write_all(line.as_bytes())?;
            self.file_len += line.len() as u64;
            if self.ship {
                self.shipped.extend_from_slice(line.as_bytes());
            }
            Ok(())
        }
    }

    /// Register this journal's session-labeled instruments. Idempotent
    /// per session id (re-attaching resolves to the same registry
    /// entries, so counters survive handle replacement on compaction).
    pub fn set_obs(&mut self, session: &str) {
        let l: &[(&str, &str)] = &[("session", session)];
        self.obs = Some(JournalObs {
            events: crate::obs::counter("pasha_journal_events_total", l),
            bytes: crate::obs::counter("pasha_journal_bytes_total", l),
            fsyncs: crate::obs::counter("pasha_journal_fsyncs_total", l),
            sync_us: crate::obs::histogram("pasha_journal_sync_us", l),
            group_size: crate::obs::histogram("pasha_journal_commit_group_events", l),
        });
    }

    /// Switch group-commit buffering on or off. Turning it off commits
    /// anything still buffered, so no mode change can lose bytes.
    pub fn set_group_commit(&mut self, on: bool) -> io::Result<()> {
        if !on && self.group {
            self.commit()?;
        }
        self.group = on;
        Ok(())
    }

    /// Are there buffered lines not yet in the file?
    pub fn has_pending(&self) -> bool {
        !self.buf.is_empty()
    }

    /// Push buffered lines into the file *without* forcing them to disk.
    /// Required before anything re-reads the file from the filesystem
    /// (snapshot verification, tail compaction) so the on-disk bytes are
    /// complete.
    pub fn flush(&mut self) -> io::Result<()> {
        if !self.buf.is_empty() {
            self.file.write_all(&self.buf)?;
            self.file_len += self.buf.len() as u64;
            if self.ship {
                self.shipped.extend_from_slice(&self.buf);
            }
            self.buf.clear();
        }
        Ok(())
    }

    /// Group commit: one write + one `sync_all` covering every append
    /// since the last commit. A no-op when nothing is outstanding.
    /// Responses for the covered ops may only be released after this
    /// returns `Ok`.
    pub fn commit(&mut self) -> io::Result<()> {
        self.flush()?;
        if self.dirty {
            let t0 = self.obs.is_some().then(Instant::now);
            self.file.sync_all()?;
            self.dirty = false;
            if let Some(o) = &self.obs {
                o.fsyncs.inc();
                if let Some(t0) = t0 {
                    o.sync_us.observe_us(t0.elapsed());
                }
                if self.group_len > 0 {
                    o.group_size.observe(self.group_len);
                }
            }
        }
        self.group_len = 0;
        Ok(())
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Start retaining a copy of every byte written to the file, for
    /// replication shipping. The caller is expected to ship a full-file
    /// rebase frame first so the follower's copy is positioned exactly at
    /// [`Journal::file_len`].
    pub fn enable_shipping(&mut self) {
        self.ship = true;
    }

    /// Bytes currently in the file (buffered group-mode appends not
    /// included until [`Journal::flush`]).
    pub fn file_len(&self) -> u64 {
        self.file_len
    }

    /// Drain the retained copy of bytes written since the last take,
    /// with the file offset at which they begin. Call only after a
    /// successful [`Journal::commit`] so the returned bytes are durable
    /// — the replication contract is fsync-then-ship.
    pub fn take_shipped(&mut self) -> Option<(u64, Vec<u8>)> {
        if !self.ship || self.shipped.is_empty() {
            return None;
        }
        let bytes = std::mem::take(&mut self.shipped);
        let base = self.file_len - bytes.len() as u64;
        Some((base, bytes))
    }
}

impl Drop for Journal {
    /// Best-effort: never silently discard buffered lines. (The served
    /// path commits explicitly; this covers abnormal unwinds.)
    fn drop(&mut self) {
        let _ = self.flush();
    }
}

/// Result of reading a journal file.
pub struct JournalRead {
    /// Whole events, in append order.
    pub events: Vec<Json>,
    /// Byte length of the whole-event prefix (what a re-opened journal
    /// must be truncated to).
    pub valid_len: u64,
    /// Bytes of a partial trailing line dropped as a crash artifact.
    pub truncated_bytes: usize,
}

/// Read a journal file, tolerating a partial final line. Offsets are
/// byte-accurate (the file is scanned as raw bytes, so a crash that cut a
/// multi-byte character cannot skew `valid_len`). The torn-tail
/// discipline itself lives in [`crate::util::jsonl::read_jsonl`], shared
/// with the trial store ([`crate::store`]).
pub fn read_journal(path: &Path) -> io::Result<JournalRead> {
    let r = jsonl::read_jsonl(path)?;
    Ok(JournalRead {
        events: r.records,
        valid_len: r.valid_len,
        truncated_bytes: r.truncated_bytes,
    })
}

// ---------------------------------------------------------------------------
// Snapshot sidecar: `<journal>.snap` holds whole-state snapshot records,
// one JSON line each, appended after the covered events are durable. The
// journal tail can then be compacted (rewritten atomically) down to the
// events a retained snapshot does not cover — recovery becomes
// O(snapshot + tail) instead of O(history).
// ---------------------------------------------------------------------------

/// The snapshot sidecar path for a journal file (`s0000.jsonl` →
/// `s0000.jsonl.snap`). The `.snap` extension keeps it out of the
/// registry's `*.jsonl` recovery scan.
pub fn snapshot_path(journal: &Path) -> PathBuf {
    PathBuf::from(format!("{}.snap", journal.display()))
}

/// Append one JSON line to `path`, creating the file (and parent
/// directory) if needed. A previous crash can have left a torn final
/// line; the file is first truncated back to its whole-line prefix so
/// the new record can never merge with torn bytes (the sidecar analogue
/// of [`Journal::open_append_at`]). One implementation, shared with the
/// trial store: [`crate::util::jsonl::append_line`].
pub fn append_line(path: &Path, event: &Json) -> io::Result<()> {
    jsonl::append_line(path, event)
}

/// Atomically replace `path` with the given lines: write a sibling
/// `.tmp` file, then rename over the target. A crash before the rename
/// leaves the original untouched; after, the replacement is complete.
/// Used by journal compaction and snapshot-file rotation.
pub fn rewrite_atomic(path: &Path, lines: &[Json]) -> io::Result<()> {
    jsonl::rewrite_atomic(path, lines)
}

/// Read every parseable line of a snapshot sidecar, skipping anything
/// torn or corrupt (snapshots are an optimization — the journal remains
/// the ground truth, so a bad snapshot line is dropped, never fatal).
/// A missing file reads as empty.
pub fn read_snapshots(path: &Path) -> Vec<Json> {
    jsonl::read_jsonl_lenient(path)
}

// Event constructors: the journal schema in one place.

pub fn ev_create(session: &str, spec: &Json) -> Json {
    let mut o = Json::obj();
    o.set("ev", "create")
        .set("session", session)
        .set("spec", spec.clone());
    o
}

/// A `create` header for a compacted journal tail: `base` is the number
/// of events already covered by a snapshot and dropped from this file
/// (the first event line after the header is absolute event `base + 1`).
/// With `base == 0` the encoding is identical to [`ev_create`], so
/// uncompacted journals keep their exact historical bytes.
pub fn ev_create_at(session: &str, spec: &Json, base: usize) -> Json {
    let mut o = ev_create(session, spec);
    if base > 0 {
        o.set("base", base);
    }
    o
}

/// A snapshot record: the serialized ask/tell core state after exactly
/// `events` journaled events (absolute count since session creation).
pub fn ev_snapshot(session: &str, events: usize, spec: &Json, state: Json) -> Json {
    let mut o = Json::obj();
    o.set("ev", "snapshot")
        .set("session", session)
        .set("events", events)
        .set("spec", spec.clone())
        .set("state", state);
    o
}

pub fn ev_ask(worker: &str, resp: Json) -> Json {
    let mut o = Json::obj();
    o.set("ev", "ask").set("worker", worker).set("resp", resp);
    o
}

pub fn ev_tell(trial: usize, epoch: u32, metric: f64) -> Json {
    let mut o = Json::obj();
    o.set("ev", "tell")
        .set("trial", trial)
        .set("epoch", epoch)
        .set("metric", metric);
    o
}

pub fn ev_fail(trial: usize) -> Json {
    let mut o = Json::obj();
    o.set("ev", "fail").set("trial", trial);
    o
}

pub fn ev_expire() -> Json {
    let mut o = Json::obj();
    o.set("ev", "expire");
    o
}

/// Expire a single worker's leases (its in-flight jobs re-park, its
/// pending directives drop). The argless [`ev_expire`] form — expire
/// every worker — is what legacy journals carry; both replay.
pub fn ev_expire_worker(worker: &str) -> Json {
    let mut o = Json::obj();
    o.set("ev", "expire").set("worker", worker);
    o
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("pasha-journal-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn roundtrip_events() {
        let path = tmp("roundtrip.jsonl");
        let mut j = Journal::create(&path).unwrap();
        let evs = [ev_tell(3, 1, 55.25), ev_fail(2), ev_expire()];
        for e in &evs {
            j.append(e).unwrap();
        }
        drop(j);
        let r = read_journal(&path).unwrap();
        assert_eq!(r.events.len(), 3);
        assert_eq!(r.truncated_bytes, 0);
        assert_eq!(r.events[0], evs[0]);
        assert_eq!(r.events[2], evs[2]);
        let file_len = std::fs::metadata(&path).unwrap().len();
        assert_eq!(r.valid_len, file_len, "whole file is valid");
    }

    #[test]
    fn partial_final_line_is_dropped() {
        let path = tmp("partial.jsonl");
        let mut j = Journal::create(&path).unwrap();
        j.append(&ev_tell(0, 1, 10.0)).unwrap();
        j.append(&ev_tell(0, 2, 20.0)).unwrap();
        drop(j);
        let full = std::fs::read(&path).unwrap();
        // cut mid-way through the second line
        let cut = full.len() - 7;
        std::fs::write(&path, &full[..cut]).unwrap();
        let r = read_journal(&path).unwrap();
        assert_eq!(r.events.len(), 1);
        assert!(r.truncated_bytes > 0);
        // re-open truncates the partial tail and appends cleanly
        let mut j = Journal::open_append_at(&path, r.valid_len).unwrap();
        j.append(&ev_fail(9)).unwrap();
        drop(j);
        let r2 = read_journal(&path).unwrap();
        assert_eq!(r2.events.len(), 2);
        assert_eq!(r2.truncated_bytes, 0);
        assert_eq!(r2.events[1], ev_fail(9));
    }

    #[test]
    fn complete_but_unterminated_final_line_is_dropped() {
        // A crash can land exactly at the end of the JSON but before the
        // newline: the line parses, but was never fully acknowledged.
        let path = tmp("noterm.jsonl");
        let mut j = Journal::create(&path).unwrap();
        j.append(&ev_tell(1, 1, 30.0)).unwrap();
        j.append(&ev_tell(1, 2, 31.0)).unwrap();
        drop(j);
        let full = std::fs::read(&path).unwrap();
        std::fs::write(&path, &full[..full.len() - 1]).unwrap();
        let r = read_journal(&path).unwrap();
        assert_eq!(r.events.len(), 1);
        assert!(r.truncated_bytes > 0);
    }

    #[test]
    fn mid_file_corruption_is_an_error() {
        let path = tmp("corrupt.jsonl");
        std::fs::write(&path, "{\"ev\":\"tell\"}\nnot json at all\n{\"ev\":\"fail\"}\n").unwrap();
        let err = read_journal(&path).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
    }

    #[test]
    fn empty_journal_reads_empty() {
        let path = tmp("empty.jsonl");
        Journal::create(&path).unwrap();
        let r = read_journal(&path).unwrap();
        assert!(r.events.is_empty());
        assert_eq!(r.valid_len, 0);
        assert_eq!(r.truncated_bytes, 0);
    }

    #[test]
    fn snapshot_sidecar_read_is_lenient() {
        let jpath = tmp("sidecar.jsonl");
        let path = snapshot_path(&jpath);
        assert!(path.to_string_lossy().ends_with("sidecar.jsonl.snap"));
        let _ = std::fs::remove_file(&path);
        assert!(read_snapshots(&path).is_empty(), "missing file reads empty");
        append_line(&path, &ev_snapshot("s0", 10, &Json::obj(), Json::obj())).unwrap();
        append_line(&path, &ev_snapshot("s0", 20, &Json::obj(), Json::obj())).unwrap();
        assert_eq!(read_snapshots(&path).len(), 2);
        // a torn final append is dropped; earlier whole lines survive
        let mut bytes = std::fs::read(&path).unwrap();
        bytes.extend_from_slice(b"{\"ev\":\"snapshot\",\"events\":30");
        std::fs::write(&path, &bytes).unwrap();
        let snaps = read_snapshots(&path);
        assert_eq!(snaps.len(), 2);
        assert_eq!(snaps[1].get("events").unwrap().as_f64(), Some(20.0));
        // appending over a torn tail truncates it first: the new record
        // must never merge with the torn bytes
        append_line(&path, &ev_snapshot("s0", 40, &Json::obj(), Json::obj())).unwrap();
        let snaps = read_snapshots(&path);
        assert_eq!(snaps.len(), 3, "torn bytes repaired, new record whole");
        assert_eq!(snaps[2].get("events").unwrap().as_f64(), Some(40.0));
        // corrupt middle lines are skipped, not fatal
        std::fs::write(&path, "not json\n{\"ev\":\"snapshot\",\"events\":5}\n").unwrap();
        let snaps = read_snapshots(&path);
        assert_eq!(snaps.len(), 1);
        assert_eq!(snaps[0].get("events").unwrap().as_f64(), Some(5.0));
    }

    #[test]
    fn rewrite_atomic_replaces_content() {
        let path = tmp("rewrite.jsonl");
        std::fs::write(&path, "old line\n").unwrap();
        rewrite_atomic(&path, &[ev_fail(1), ev_fail(2)]).unwrap();
        let r = read_journal(&path).unwrap();
        assert_eq!(r.events.len(), 2);
        assert_eq!(r.events[0], ev_fail(1));
        assert!(!PathBuf::from(format!("{}.tmp", path.display())).exists());
    }

    #[test]
    fn create_at_base_zero_matches_legacy_bytes() {
        let spec = Json::obj();
        assert_eq!(
            ev_create_at("s1", &spec, 0).to_string_compact(),
            ev_create("s1", &spec).to_string_compact()
        );
        let with_base = ev_create_at("s1", &spec, 42);
        assert_eq!(with_base.get("base").unwrap().as_f64(), Some(42.0));
    }

    #[test]
    fn group_commit_buffers_until_commit() {
        let path = tmp("group.jsonl");
        let mut j = Journal::create(&path).unwrap();
        j.set_group_commit(true).unwrap();
        j.append(&ev_tell(0, 1, 1.0)).unwrap();
        j.append(&ev_fail(3)).unwrap();
        assert!(j.has_pending());
        assert_eq!(
            std::fs::metadata(&path).unwrap().len(),
            0,
            "appends buffer in group mode"
        );
        j.commit().unwrap();
        assert!(!j.has_pending());
        assert_eq!(read_journal(&path).unwrap().events.len(), 2);
        // byte format identical to write-through mode
        let wt = tmp("group-wt.jsonl");
        let mut w = Journal::create(&wt).unwrap();
        w.append(&ev_tell(0, 1, 1.0)).unwrap();
        w.append(&ev_fail(3)).unwrap();
        drop(w);
        assert_eq!(std::fs::read(&path).unwrap(), std::fs::read(&wt).unwrap());
        // turning group mode off commits implicitly
        j.append(&ev_expire()).unwrap();
        j.set_group_commit(false).unwrap();
        assert!(!j.has_pending());
        assert_eq!(read_journal(&path).unwrap().events.len(), 3);
    }

    #[test]
    fn drop_flushes_buffered_lines() {
        let path = tmp("group-drop.jsonl");
        let mut j = Journal::create(&path).unwrap();
        j.set_group_commit(true).unwrap();
        j.append(&ev_fail(7)).unwrap();
        drop(j);
        assert_eq!(read_journal(&path).unwrap().events.len(), 1);
    }

    #[test]
    fn shipping_retains_committed_bytes_without_changing_file() {
        let path = tmp("ship.jsonl");
        let mut j = Journal::create(&path).unwrap();
        j.set_group_commit(true).unwrap();
        j.append(&ev_tell(0, 1, 1.0)).unwrap();
        j.commit().unwrap();
        assert!(j.take_shipped().is_none(), "shipping off: nothing retained");
        j.enable_shipping();
        j.append(&ev_tell(0, 2, 2.0)).unwrap();
        j.append(&ev_fail(1)).unwrap();
        j.commit().unwrap();
        let (base, bytes) = j.take_shipped().unwrap();
        let file = std::fs::read(&path).unwrap();
        assert_eq!(base as usize + bytes.len(), file.len());
        assert_eq!(
            &file[base as usize..],
            &bytes[..],
            "shipped bytes are the exact durable file tail"
        );
        assert!(j.take_shipped().is_none(), "drained after take");
        // write-through mode ships too, and the file bytes are identical
        // to an unshipped journal's (observe-only invariant)
        j.set_group_commit(false).unwrap();
        j.append(&ev_expire()).unwrap();
        j.commit().unwrap();
        let (base2, bytes2) = j.take_shipped().unwrap();
        assert_eq!(base2 as usize, file.len());
        let file2 = std::fs::read(&path).unwrap();
        assert_eq!(&file2[base2 as usize..], &bytes2[..]);
    }

    #[test]
    fn expire_worker_event_shape() {
        let e = ev_expire_worker("w3");
        assert_eq!(e.get("ev").unwrap().as_str(), Some("expire"));
        assert_eq!(e.get("worker").unwrap().as_str(), Some("w3"));
        assert!(ev_expire().get("worker").is_none());
    }

    #[test]
    fn event_constructors_shape() {
        let c = ev_create("s0", &Json::obj());
        assert_eq!(c.get("ev").unwrap().as_str(), Some("create"));
        assert_eq!(c.get("session").unwrap().as_str(), Some("s0"));
        let a = ev_ask("w1", Json::obj());
        assert_eq!(a.get("worker").unwrap().as_str(), Some("w1"));
        let t = ev_tell(4, 9, 77.5);
        assert_eq!(t.get("trial").unwrap().as_f64(), Some(4.0));
        assert_eq!(t.get("epoch").unwrap().as_f64(), Some(9.0));
        assert_eq!(t.get("metric").unwrap().as_f64(), Some(77.5));
    }
}
