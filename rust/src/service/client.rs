//! Line-protocol client + the `pasha worker` driver loops.
//!
//! [`Client`] speaks the newline-delimited JSON protocol of
//! [`super::server`] over one `TcpStream`. [`run_worker`] is the worker
//! side of the ask/tell contract: poll for an assignment, train it epoch
//! by epoch against a local [`Benchmark`] evaluator (the simulator — or,
//! with the `pjrt` feature, real training), tell each epoch's metric,
//! and abandon the job the moment the service says so.
//!
//! [`run_worker_batched`] is the same contract over batched frames: all
//! of a job's epoch tells plus the next ask travel as one `batch`
//! request — one syscall round-trip instead of `milestone + 1`. Batching
//! changes framing, not semantics: the ops hit the same per-session
//! dispatch in the same order, so a given op sequence produces the same
//! journal bytes and incumbent whether issued singly or batched (the
//! equivalence `tests/service_e2e.rs` pins down). The one behavioral
//! wrinkle is optimism: if the service cancels a job mid-frame, the
//! frame's remaining tells arrive anyway and are refused as no-ops,
//! where an unbatched worker would have stopped telling — harmless for
//! state, and the right trade when training an epoch is cheap relative
//! to a round-trip (always true for the simulator).

use crate::benchmarks::Benchmark;
use crate::config::space::SearchSpace;
use crate::scheduler::asktell::{assignment_from_json, TellAck, TrialAssignment};
use crate::service::registry::ServiceError;
use crate::spec::ExperimentSpec;
use crate::util::json::{parse, Json};
use crate::TrialId;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

/// One connection to a `pasha serve` instance.
pub struct Client {
    writer: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client, ServiceError> {
        let stream = TcpStream::connect(addr)
            .map_err(|e| ServiceError::Io(format!("connect {addr}: {e}")))?;
        let read_half = stream.try_clone().map_err(|e| ServiceError::Io(e.to_string()))?;
        Ok(Client {
            writer: stream,
            reader: BufReader::new(read_half),
        })
    }

    /// Send one request line, read one response line. Returns the
    /// response object once `"ok": true` is verified.
    pub fn call(&mut self, req: &Json) -> Result<Json, ServiceError> {
        let mut line = req.to_string_compact();
        line.push('\n');
        let io_err = |e: std::io::Error| ServiceError::Io(e.to_string());
        self.writer.write_all(line.as_bytes()).map_err(io_err)?;
        let mut resp_line = String::new();
        self.reader.read_line(&mut resp_line).map_err(io_err)?;
        if resp_line.is_empty() {
            return Err(ServiceError::Io("server closed the connection".into()));
        }
        let resp = parse(resp_line.trim())
            .map_err(|e| ServiceError::Io(format!("bad response: {e}")))?;
        if resp.get("ok").and_then(|v| v.as_bool()) == Some(true) {
            Ok(resp)
        } else {
            let msg = resp.get("error").and_then(|v| v.as_str()).unwrap_or("unknown error");
            Err(ServiceError::Session(msg.to_string()))
        }
    }

    fn cmd(&mut self, name: &str) -> Json {
        let mut o = Json::obj();
        o.set("cmd", name);
        o
    }

    fn session_cmd(&mut self, name: &str, session: &str) -> Json {
        let mut o = self.cmd(name);
        o.set("session", session);
        o
    }

    pub fn ping(&mut self) -> Result<(), ServiceError> {
        let req = self.cmd("ping");
        self.call(&req).map(|_| ())
    }

    pub fn create(&mut self, spec: &ExperimentSpec) -> Result<String, ServiceError> {
        let mut req = self.cmd("create");
        // send the v1 shape whenever the spec is representable there, so
        // a pre-redesign server creates the *right* session instead of
        // silently defaulting object-shaped fields it cannot read;
        // v2-only specs — which an old server could not honor anyway —
        // go as v2
        req.set(
            "spec",
            spec.to_v1_compat_json().unwrap_or_else(|| spec.to_json()),
        );
        let resp = self.call(&req)?;
        let id = resp
            .get("session")
            .and_then(|v| v.as_str())
            .map(|s| s.to_string())
            .ok_or_else(|| ServiceError::Io("create response missing session id".into()))?;
        // Read the session's spec back and compare: a pre-redesign
        // server handed a v2-only payload silently defaults the fields
        // it cannot read — catch that at create time instead of driving
        // the wrong experiment.
        let status = self.status(&id)?;
        let served = status
            .get("spec")
            .ok_or_else(|| ServiceError::Io("status response missing spec".into()))?;
        let served = ExperimentSpec::from_json(served).map_err(ServiceError::Spec)?;
        if &served != spec {
            return Err(ServiceError::Spec(format!(
                "server created session '{id}' with a different spec than requested \
                 (got {}, wanted {}) — a pre-redesign server cannot honor v2-only specs",
                served.to_json().to_string_compact(),
                spec.to_json().to_string_compact()
            )));
        }
        Ok(id)
    }

    pub fn ask(
        &mut self,
        session: &str,
        worker: &str,
        space: &SearchSpace,
    ) -> Result<TrialAssignment, ServiceError> {
        let mut req = self.session_cmd("ask", session);
        req.set("worker", worker);
        let resp = self.call(&req)?;
        assignment_from_json(space, &resp).map_err(ServiceError::Io)
    }

    pub fn tell(
        &mut self,
        session: &str,
        trial: TrialId,
        epoch: u32,
        metric: f64,
    ) -> Result<TellAck, ServiceError> {
        let mut req = self.session_cmd("tell", session);
        req.set("trial", trial).set("epoch", epoch).set("metric", metric);
        let resp = self.call(&req)?;
        let ack = resp.get("ack").and_then(|v| v.as_str()).unwrap_or("");
        TellAck::parse(ack).ok_or_else(|| ServiceError::Io(format!("bad tell ack '{ack}'")))
    }

    pub fn fail(&mut self, session: &str, trial: TrialId) -> Result<(), ServiceError> {
        let mut req = self.session_cmd("fail", session);
        req.set("trial", trial);
        self.call(&req).map(|_| ())
    }

    pub fn status(&mut self, session: &str) -> Result<Json, ServiceError> {
        let req = self.session_cmd("status", session);
        let resp = self.call(&req)?;
        resp.get("status")
            .cloned()
            .ok_or_else(|| ServiceError::Io("status response missing body".into()))
    }

    pub fn sessions(&mut self) -> Result<Vec<Json>, ServiceError> {
        let req = self.cmd("sessions");
        let resp = self.call(&req)?;
        let arr = resp.get("sessions").and_then(|v| v.as_arr()).map(|a| a.to_vec());
        Ok(arr.unwrap_or_default())
    }

    /// Fetch the server's read-only metrics snapshot (the `stats` wire
    /// op, [`crate::obs::snapshot_json`] shape): an `instruments` array
    /// plus an `aggregate` object. Needs no session and mutates nothing.
    pub fn stats(&mut self) -> Result<Json, ServiceError> {
        let req = self.cmd("stats");
        let resp = self.call(&req)?;
        resp.get("stats")
            .cloned()
            .ok_or_else(|| ServiceError::Io("stats response missing body".into()))
    }

    pub fn expire(&mut self, session: &str) -> Result<usize, ServiceError> {
        let req = self.session_cmd("expire", session);
        let resp = self.call(&req)?;
        Ok(resp.get("expired").and_then(|v| v.as_f64()).unwrap_or(0.0) as usize)
    }

    /// Expire one worker's in-flight jobs (the targeted form of
    /// [`Client::expire`]) — its unfinished trials fail and re-queue.
    pub fn expire_worker(&mut self, session: &str, worker: &str) -> Result<usize, ServiceError> {
        let mut req = self.session_cmd("expire", session);
        req.set("worker", worker);
        let resp = self.call(&req)?;
        Ok(resp.get("expired").and_then(|v| v.as_f64()).unwrap_or(0.0) as usize)
    }

    pub fn shutdown(&mut self) -> Result<(), ServiceError> {
        let req = self.cmd("shutdown");
        self.call(&req).map(|_| ())
    }

    /// Send `ops` as one `batch` frame and return the per-op results
    /// (each with its own `ok` flag; a failed op does not abort the
    /// frame). Build ops with [`ask_op`] / [`tell_op`] / [`fail_op`].
    pub fn batch(&mut self, ops: Vec<Json>) -> Result<Vec<Json>, ServiceError> {
        let mut req = self.cmd("batch");
        req.set("ops", Json::Arr(ops));
        let resp = self.call(&req)?;
        resp.get("results")
            .and_then(|r| r.as_arr())
            .map(|a| a.to_vec())
            .ok_or_else(|| ServiceError::Io("batch response missing results".into()))
    }
}

/// An `ask` op for a [`Client::batch`] frame.
pub fn ask_op(session: &str, worker: &str) -> Json {
    let mut o = Json::obj();
    o.set("cmd", "ask").set("session", session).set("worker", worker);
    o
}

/// A `tell` op for a [`Client::batch`] frame.
pub fn tell_op(session: &str, trial: TrialId, epoch: u32, metric: f64) -> Json {
    let mut o = Json::obj();
    o.set("cmd", "tell")
        .set("session", session)
        .set("trial", trial)
        .set("epoch", epoch)
        .set("metric", metric);
    o
}

/// A `fail` op for a [`Client::batch`] frame.
pub fn fail_op(session: &str, trial: TrialId) -> Json {
    let mut o = Json::obj();
    o.set("cmd", "fail").set("session", session).set("trial", trial);
    o
}

/// What one worker did over its lifetime.
#[derive(Clone, Debug, Default)]
pub struct WorkerReport {
    /// Jobs trained to their milestone.
    pub jobs_completed: usize,
    /// Epochs told (committed and abandoned alike).
    pub epochs_told: u64,
    /// Jobs abandoned on a Stop/Pause/fail directive.
    pub jobs_abandoned: usize,
    /// Network round-trips used ([`run_worker_batched`] only; the
    /// unbatched driver leaves it 0). The batching win is
    /// `epochs_told + asks ≫ frames`.
    pub frames: usize,
    /// Per-op wire latency in microseconds: one entry per round-trip for
    /// the unbatched driver, or the frame round-trip amortized over its
    /// ops for the batched driver. What `bench-json --suite service`
    /// reports as the batched-vs-unbatched per-op comparison.
    pub op_us: Vec<f64>,
}

/// Drive one worker against a session until the service reports `Done`:
/// ask → train epoch-by-epoch on `bench` → tell, abandoning jobs the
/// moment the service cancels them. `poll` is the back-off between
/// `Wait` answers.
pub fn run_worker(
    client: &mut Client,
    session: &str,
    worker_id: &str,
    bench: &dyn Benchmark,
    bench_seed: u64,
    poll: Duration,
) -> Result<WorkerReport, ServiceError> {
    let mut report = WorkerReport::default();
    let space = bench.space().clone();
    loop {
        let t = Instant::now();
        let assignment = client.ask(session, worker_id, &space)?;
        report.op_us.push(t.elapsed().as_secs_f64() * 1e6);
        match assignment {
            TrialAssignment::Run(job) => {
                let mut abandoned = false;
                for e in job.from_epoch + 1..=job.milestone {
                    let metric = bench.accuracy_at(&job.config, e, bench_seed);
                    report.epochs_told += 1;
                    let t = Instant::now();
                    let ack = client.tell(session, job.trial, e, metric)?;
                    report.op_us.push(t.elapsed().as_secs_f64() * 1e6);
                    if ack == TellAck::Abandon {
                        abandoned = true;
                        break;
                    }
                }
                if abandoned {
                    report.jobs_abandoned += 1;
                } else {
                    report.jobs_completed += 1;
                }
            }
            // Directives for jobs this worker already abandoned via a
            // tell ack; nothing left to do for them.
            TrialAssignment::Stop(_) | TrialAssignment::Pause(_) => {}
            TrialAssignment::Wait => std::thread::sleep(poll),
            TrialAssignment::Done => return Ok(report),
        }
    }
}

/// [`run_worker`] over batched frames: train the whole assigned job
/// locally, then ship every epoch tell *plus the next ask* as a single
/// `batch` round-trip. See the module docs for the exact equivalence to
/// the unbatched driver.
pub fn run_worker_batched(
    client: &mut Client,
    session: &str,
    worker_id: &str,
    bench: &dyn Benchmark,
    bench_seed: u64,
    poll: Duration,
) -> Result<WorkerReport, ServiceError> {
    let mut report = WorkerReport::default();
    let space = bench.space().clone();
    // each frame ends with an ask; the first frame is that ask alone
    let mut ops = vec![ask_op(session, worker_id)];
    loop {
        let expected = ops.len();
        report.frames += 1;
        let t = Instant::now();
        let results = client.batch(ops)?;
        let per_op = t.elapsed().as_secs_f64() * 1e6 / expected as f64;
        report.op_us.resize(report.op_us.len() + expected, per_op);
        if results.len() != expected {
            return Err(ServiceError::Io(format!(
                "batch returned {} results for {expected} ops",
                results.len()
            )));
        }
        // tell results precede the trailing ask result
        let (tells, ask) = results.split_at(expected - 1);
        let mut abandoned = false;
        for r in tells {
            if abandoned {
                // refusals after an abandon are expected no-ops
                continue;
            }
            if r.get("ok").and_then(|v| v.as_bool()) != Some(true) {
                let msg = r.get("error").and_then(|v| v.as_str()).unwrap_or("unknown error");
                return Err(ServiceError::Session(msg.to_string()));
            }
            report.epochs_told += 1;
            let ack = r.get("ack").and_then(|v| v.as_str()).unwrap_or("");
            match TellAck::parse(ack) {
                Some(TellAck::Abandon) => {
                    abandoned = true;
                    report.jobs_abandoned += 1;
                }
                Some(TellAck::JobComplete) => report.jobs_completed += 1,
                Some(TellAck::Continue) => {}
                None => {
                    return Err(ServiceError::Io(format!("bad tell ack '{ack}'")));
                }
            }
        }
        let ask = &ask[0];
        if ask.get("ok").and_then(|v| v.as_bool()) != Some(true) {
            let msg = ask.get("error").and_then(|v| v.as_str()).unwrap_or("unknown error");
            return Err(ServiceError::Session(msg.to_string()));
        }
        match assignment_from_json(&space, ask).map_err(ServiceError::Io)? {
            TrialAssignment::Run(job) => {
                ops = (job.from_epoch + 1..=job.milestone)
                    .map(|e| {
                        let metric = bench.accuracy_at(&job.config, e, bench_seed);
                        tell_op(session, job.trial, e, metric)
                    })
                    .collect();
                ops.push(ask_op(session, worker_id));
            }
            TrialAssignment::Stop(_) | TrialAssignment::Pause(_) => {
                ops = vec![ask_op(session, worker_id)];
            }
            TrialAssignment::Wait => {
                std::thread::sleep(poll);
                ops = vec![ask_op(session, worker_id)];
            }
            TrialAssignment::Done => return Ok(report),
        }
    }
}
