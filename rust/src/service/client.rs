//! Line-protocol client + the `pasha worker` driver loop.
//!
//! [`Client`] speaks the newline-delimited JSON protocol of
//! [`super::server`] over one `TcpStream`. [`run_worker`] is the worker
//! side of the ask/tell contract: poll for an assignment, train it epoch
//! by epoch against a local [`Benchmark`] evaluator (the simulator — or,
//! with the `pjrt` feature, real training), tell each epoch's metric,
//! and abandon the job the moment the service says so.

use crate::benchmarks::Benchmark;
use crate::config::space::SearchSpace;
use crate::scheduler::asktell::{assignment_from_json, TellAck, TrialAssignment};
use crate::service::registry::ServiceError;
use crate::service::session::SessionSpec;
use crate::util::json::{parse, Json};
use crate::TrialId;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::Duration;

/// One connection to a `pasha serve` instance.
pub struct Client {
    writer: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client, ServiceError> {
        let stream = TcpStream::connect(addr)
            .map_err(|e| ServiceError::Io(format!("connect {addr}: {e}")))?;
        let read_half = stream.try_clone().map_err(|e| ServiceError::Io(e.to_string()))?;
        Ok(Client {
            writer: stream,
            reader: BufReader::new(read_half),
        })
    }

    /// Send one request line, read one response line. Returns the
    /// response object once `"ok": true` is verified.
    pub fn call(&mut self, req: &Json) -> Result<Json, ServiceError> {
        let mut line = req.to_string_compact();
        line.push('\n');
        let io_err = |e: std::io::Error| ServiceError::Io(e.to_string());
        self.writer.write_all(line.as_bytes()).map_err(io_err)?;
        let mut resp_line = String::new();
        self.reader.read_line(&mut resp_line).map_err(io_err)?;
        if resp_line.is_empty() {
            return Err(ServiceError::Io("server closed the connection".into()));
        }
        let resp = parse(resp_line.trim())
            .map_err(|e| ServiceError::Io(format!("bad response: {e}")))?;
        if resp.get("ok").and_then(|v| v.as_bool()) == Some(true) {
            Ok(resp)
        } else {
            let msg = resp.get("error").and_then(|v| v.as_str()).unwrap_or("unknown error");
            Err(ServiceError::Session(msg.to_string()))
        }
    }

    fn cmd(&mut self, name: &str) -> Json {
        let mut o = Json::obj();
        o.set("cmd", name);
        o
    }

    fn session_cmd(&mut self, name: &str, session: &str) -> Json {
        let mut o = self.cmd(name);
        o.set("session", session);
        o
    }

    pub fn ping(&mut self) -> Result<(), ServiceError> {
        let req = self.cmd("ping");
        self.call(&req).map(|_| ())
    }

    pub fn create(&mut self, spec: &SessionSpec) -> Result<String, ServiceError> {
        let mut req = self.cmd("create");
        req.set("spec", spec.to_json());
        let resp = self.call(&req)?;
        resp.get("session")
            .and_then(|v| v.as_str())
            .map(|s| s.to_string())
            .ok_or_else(|| ServiceError::Io("create response missing session id".into()))
    }

    pub fn ask(
        &mut self,
        session: &str,
        worker: &str,
        space: &SearchSpace,
    ) -> Result<TrialAssignment, ServiceError> {
        let mut req = self.session_cmd("ask", session);
        req.set("worker", worker);
        let resp = self.call(&req)?;
        assignment_from_json(space, &resp).map_err(ServiceError::Io)
    }

    pub fn tell(
        &mut self,
        session: &str,
        trial: TrialId,
        epoch: u32,
        metric: f64,
    ) -> Result<TellAck, ServiceError> {
        let mut req = self.session_cmd("tell", session);
        req.set("trial", trial).set("epoch", epoch).set("metric", metric);
        let resp = self.call(&req)?;
        let ack = resp.get("ack").and_then(|v| v.as_str()).unwrap_or("");
        TellAck::parse(ack).ok_or_else(|| ServiceError::Io(format!("bad tell ack '{ack}'")))
    }

    pub fn fail(&mut self, session: &str, trial: TrialId) -> Result<(), ServiceError> {
        let mut req = self.session_cmd("fail", session);
        req.set("trial", trial);
        self.call(&req).map(|_| ())
    }

    pub fn status(&mut self, session: &str) -> Result<Json, ServiceError> {
        let req = self.session_cmd("status", session);
        let resp = self.call(&req)?;
        resp.get("status")
            .cloned()
            .ok_or_else(|| ServiceError::Io("status response missing body".into()))
    }

    pub fn sessions(&mut self) -> Result<Vec<Json>, ServiceError> {
        let req = self.cmd("sessions");
        let resp = self.call(&req)?;
        let arr = resp.get("sessions").and_then(|v| v.as_arr()).map(|a| a.to_vec());
        Ok(arr.unwrap_or_default())
    }

    pub fn expire(&mut self, session: &str) -> Result<usize, ServiceError> {
        let req = self.session_cmd("expire", session);
        let resp = self.call(&req)?;
        Ok(resp.get("expired").and_then(|v| v.as_f64()).unwrap_or(0.0) as usize)
    }

    pub fn shutdown(&mut self) -> Result<(), ServiceError> {
        let req = self.cmd("shutdown");
        self.call(&req).map(|_| ())
    }
}

/// What one worker did over its lifetime.
#[derive(Clone, Debug, Default)]
pub struct WorkerReport {
    /// Jobs trained to their milestone.
    pub jobs_completed: usize,
    /// Epochs told (committed and abandoned alike).
    pub epochs_told: u64,
    /// Jobs abandoned on a Stop/Pause/fail directive.
    pub jobs_abandoned: usize,
}

/// Drive one worker against a session until the service reports `Done`:
/// ask → train epoch-by-epoch on `bench` → tell, abandoning jobs the
/// moment the service cancels them. `poll` is the back-off between
/// `Wait` answers.
pub fn run_worker(
    client: &mut Client,
    session: &str,
    worker_id: &str,
    bench: &dyn Benchmark,
    bench_seed: u64,
    poll: Duration,
) -> Result<WorkerReport, ServiceError> {
    let mut report = WorkerReport::default();
    let space = bench.space().clone();
    loop {
        match client.ask(session, worker_id, &space)? {
            TrialAssignment::Run(job) => {
                let mut abandoned = false;
                for e in job.from_epoch + 1..=job.milestone {
                    let metric = bench.accuracy_at(&job.config, e, bench_seed);
                    report.epochs_told += 1;
                    if client.tell(session, job.trial, e, metric)? == TellAck::Abandon {
                        abandoned = true;
                        break;
                    }
                }
                if abandoned {
                    report.jobs_abandoned += 1;
                } else {
                    report.jobs_completed += 1;
                }
            }
            // Directives for jobs this worker already abandoned via a
            // tell ack; nothing left to do for them.
            TrialAssignment::Stop(_) | TrialAssignment::Pause(_) => {}
            TrialAssignment::Wait => std::thread::sleep(poll),
            TrialAssignment::Done => return Ok(report),
        }
    }
}
