//! The ask/tell tuning service: PASHA as a long-running system.
//!
//! The library's other layers run the optimization *in process*: the
//! engine owns the loop, trials execute on its backends. This module
//! decouples decision-making from execution so external workers — other
//! processes, other machines — drive trials against a central service:
//!
//! Sessions are described by the shared, versioned
//! [`crate::spec::ExperimentSpec`] (re-exported here): the `create`
//! command accepts the v2 wire format and migrates legacy v1 (flat)
//! payloads, and journal headers recover through the same parser.
//!
//! * [`session`] — one durable tuning session: an ask/tell core
//!   ([`crate::scheduler::asktell`]) whose every mutating operation is
//!   appended to a write-ahead journal before acknowledgement, plus
//!   deterministic crash recovery. Recovery restores the newest usable
//!   snapshot ([`crate::scheduler::state`]) and replays only the journal
//!   tail past it — O(tail), not O(history); with no usable snapshot it
//!   falls back to full replay.
//! * [`journal`] — the JSONL write-ahead log: append, truncation-tolerant
//!   read, whole-event-prefix recovery, plus the snapshot sidecar
//!   (`<journal>.snap`) and atomic tail compaction.
//! * [`registry`] — the sharded multi-session store: session ids hash to
//!   single-owner shards, and every journal in a directory is recovered
//!   at startup.
//! * [`server`] — a dependency-free `std::net` TCP server speaking
//!   newline-delimited JSON (`pasha serve`), backed on Unix by the
//!   sharded event-driven core in `eventloop`: a few I/O threads
//!   multiplex every connection over readiness polling
//!   ([`crate::util::poll`]), shard workers own the sessions, and
//!   journal writes group-commit (one fsync per commit group, responses
//!   released only after their group is durable). The original
//!   thread-per-connection loop survives as
//!   [`server::Server::run_threaded`] — the measured baseline of
//!   `bench-json --suite service`.
//! * [`client`] — the matching client plus the `pasha worker` driver
//!   loop that evaluates assignments against a local [`crate::benchmarks`]
//!   substrate.
//!
//! Guarantees, tested end to end:
//!
//! * **Determinism** — a session driven by one worker reproduces
//!   `Tuner::run` exactly (same seeds ⇒ same incumbent).
//! * **Durability** — kill the server at any instant; recovery replays
//!   the journal to a state whose subsequent `ask` stream is
//!   byte-identical to the uninterrupted session's.
//! * **Snapshot equivalence** — recovery from (snapshot + tail) and from
//!   the full journal produce byte-identical continuations; a torn
//!   snapshot falls back to the previous one (or full replay), never to
//!   a wrong state.
//! * **Batching** — `batch` frames execute their ops in order against
//!   the same journal path as singly-issued requests: same journal
//!   bytes, same incumbent, one syscall round-trip for N ops.
//! * **Replication** — [`replica`]: `pasha serve --replicate` streams
//!   every durable commit group (after its fsync) to a `pasha follow`
//!   process that maintains a byte-identical journal copy; killing the
//!   leader and serving the follower's directory completes the session
//!   with byte-identical asks and the same incumbent, and the
//!   `pasha route` session router lets workers ride through the swap.

pub mod client;
#[cfg(unix)]
mod eventloop;
pub mod journal;
pub mod registry;
pub mod replica;
pub mod server;
pub mod session;

pub use crate::spec::ExperimentSpec;
pub use client::{run_worker, run_worker_batched, Client, WorkerReport};
pub use registry::{Registry, ServiceError};
pub use replica::{FollowReport, ShipFrame, ShipKind};
pub use server::{handle_request, Server};
pub use session::{RecoveryReport, Session, SessionOptions};
