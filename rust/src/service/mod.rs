//! The ask/tell tuning service: PASHA as a long-running system.
//!
//! The library's other layers run the optimization *in process*: the
//! engine owns the loop, trials execute on its backends. This module
//! decouples decision-making from execution so external workers — other
//! processes, other machines — drive trials against a central service:
//!
//! * [`session`] — one durable tuning session: an ask/tell core
//!   ([`crate::scheduler::asktell`]) whose every mutating operation is
//!   appended to a write-ahead journal before acknowledgement, plus
//!   deterministic crash recovery by journal replay.
//! * [`journal`] — the JSONL write-ahead log: append, truncation-tolerant
//!   read, whole-event-prefix recovery.
//! * [`registry`] — the thread-safe multi-session store, recovering every
//!   session journal in a directory at startup.
//! * [`server`] — a dependency-free `std::net` TCP server speaking
//!   newline-delimited JSON (`pasha serve`).
//! * [`client`] — the matching client plus the `pasha worker` driver
//!   loop that evaluates assignments against a local [`crate::benchmarks`]
//!   substrate.
//!
//! Guarantees, tested end to end:
//!
//! * **Determinism** — a session driven by one worker reproduces
//!   `Tuner::run` exactly (same seeds ⇒ same incumbent).
//! * **Durability** — kill the server at any instant; recovery replays
//!   the journal to a state whose subsequent `ask` stream is
//!   byte-identical to the uninterrupted session's.

pub mod client;
pub mod journal;
pub mod registry;
pub mod server;
pub mod session;

pub use client::{run_worker, Client, WorkerReport};
pub use registry::{Registry, ServiceError};
pub use server::{handle_request, Server};
pub use session::{RecoveryReport, Session, SessionSpec};
