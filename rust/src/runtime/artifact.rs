//! PJRT engine + artifact registry.
//!
//! [`Engine`] wraps a `PjRtClient` (CPU) and compiles HLO-text artifacts
//! once; [`CompiledArtifact`] is the executable handle used on the hot
//! path. Artifact files live in `artifacts/` (overridable with
//! `PASHA_ARTIFACTS`) and are produced by `make artifacts`
//! (`python/compile/aot.py`), which also writes `manifest.json` recording
//! every artifact's input/output shapes.

use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

/// Directory holding AOT artifacts.
pub fn artifacts_dir() -> PathBuf {
    if let Ok(dir) = std::env::var("PASHA_ARTIFACTS") {
        return PathBuf::from(dir);
    }
    // walk up from cwd so tests work from any crate-relative location
    let mut cur = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
    loop {
        let cand = cur.join("artifacts");
        if cand.is_dir() {
            return cand;
        }
        if !cur.pop() {
            return PathBuf::from("artifacts");
        }
    }
}

/// Are the AOT artifacts available? (Used by tests to skip gracefully
/// before `make artifacts` has run.)
pub fn artifacts_available() -> bool {
    artifacts_dir().join("manifest.json").is_file()
}

/// A PJRT client plus a cache of compiled executables.
pub struct Engine {
    client: xla::PjRtClient,
    cache: Mutex<HashMap<PathBuf, std::sync::Arc<CompiledArtifact>>>,
}

// The PJRT CPU client is thread-safe at the C API level; executions are
// serialized per-artifact by the Mutex in `CompiledArtifact::run`.
unsafe impl Send for Engine {}
unsafe impl Sync for Engine {}

impl Engine {
    /// Create a CPU engine.
    pub fn cpu() -> Result<Engine> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Engine {
            client,
            cache: Mutex::new(HashMap::new()),
        })
    }

    pub fn platform_name(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO-text artifact (cached).
    pub fn load(&self, path: &Path) -> Result<std::sync::Arc<CompiledArtifact>> {
        {
            let cache = self.cache.lock().unwrap();
            if let Some(a) = cache.get(path) {
                return Ok(a.clone());
            }
        }
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .map_err(|e| anyhow!("parse HLO text {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {}: {e:?}", path.display()))?;
        let artifact = std::sync::Arc::new(CompiledArtifact {
            exe: Mutex::new(exe),
            name: path
                .file_stem()
                .map(|s| s.to_string_lossy().into_owned())
                .unwrap_or_default(),
        });
        self.cache
            .lock()
            .unwrap()
            .insert(path.to_path_buf(), artifact.clone());
        Ok(artifact)
    }

    /// Load an artifact by name from the artifacts directory.
    pub fn load_named(&self, name: &str) -> Result<std::sync::Arc<CompiledArtifact>> {
        self.load(&artifacts_dir().join(format!("{name}.hlo.txt")))
    }
}

/// A compiled HLO module ready to execute.
pub struct CompiledArtifact {
    exe: Mutex<xla::PjRtLoadedExecutable>,
    pub name: String,
}

unsafe impl Send for CompiledArtifact {}
unsafe impl Sync for CompiledArtifact {}

impl CompiledArtifact {
    /// Execute with literal inputs; returns the flattened output tuple
    /// (artifacts are lowered with `return_tuple=True`).
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let exe = self.exe.lock().unwrap();
        let result = exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow!("execute {}: {e:?}", self.name))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result {}: {e:?}", self.name))?;
        lit.to_tuple()
            .map_err(|e| anyhow!("untuple result {}: {e:?}", self.name))
    }
}

/// Build an f32 literal of the given shape from a flat slice.
pub fn lit_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    let numel: i64 = dims.iter().product();
    if numel as usize != data.len() {
        return Err(anyhow!("shape {:?} != data len {}", dims, data.len()));
    }
    let v = xla::Literal::vec1(data);
    if dims.len() == 1 {
        Ok(v)
    } else {
        v.reshape(dims).map_err(|e| anyhow!("reshape: {e:?}"))
    }
}

/// Build an i32 literal of the given shape.
pub fn lit_i32(data: &[i32], dims: &[i64]) -> Result<xla::Literal> {
    let numel: i64 = dims.iter().product();
    if numel as usize != data.len() {
        return Err(anyhow!("shape {:?} != data len {}", dims, data.len()));
    }
    let v = xla::Literal::vec1(data);
    if dims.len() == 1 {
        Ok(v)
    } else {
        v.reshape(dims).map_err(|e| anyhow!("reshape: {e:?}"))
    }
}

/// Scalar f32 literal.
pub fn lit_scalar(v: f32) -> xla::Literal {
    xla::Literal::scalar(v)
}

/// Extract an f32 vector from a literal.
pub fn vec_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>().map_err(|e| anyhow!("to_vec f32: {e:?}"))
}

/// Extract a scalar f32.
pub fn scalar_f32(lit: &xla::Literal) -> Result<f32> {
    lit.get_first_element::<f32>()
        .map_err(|e| anyhow!("scalar f32: {e:?}"))
}

/// Extract an i32 vector.
pub fn vec_i32(lit: &xla::Literal) -> Result<Vec<i32>> {
    lit.to_vec::<i32>().map_err(|e| anyhow!("to_vec i32: {e:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_helpers_roundtrip() {
        let l = lit_f32(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).unwrap();
        assert_eq!(vec_f32(&l).unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        let s = lit_scalar(2.5);
        assert_eq!(scalar_f32(&s).unwrap(), 2.5);
        let i = lit_i32(&[1, 2, 3], &[3]).unwrap();
        assert_eq!(vec_i32(&i).unwrap(), vec![1, 2, 3]);
    }

    #[test]
    fn shape_mismatch_rejected() {
        assert!(lit_f32(&[1.0, 2.0], &[3]).is_err());
        assert!(lit_i32(&[1], &[2, 2]).is_err());
    }

    #[test]
    fn engine_compiles_and_runs_builder_computation() {
        // End-to-end PJRT smoke test without artifacts: build a tiny
        // computation with XlaBuilder, compile, execute.
        let engine = match Engine::cpu() {
            Ok(e) => e,
            Err(e) => panic!("PJRT CPU client unavailable: {e}"),
        };
        assert!(!engine.platform_name().is_empty());
        let builder = xla::XlaBuilder::new("smoke");
        let c = builder.constant_r1(&[1.0f32, 2.0]).unwrap();
        let sum = (c + builder.constant_r0(1.0f32).unwrap()).unwrap();
        let comp = sum.build().unwrap();
        let exe = engine.client.compile(&comp).unwrap();
        let out = exe.execute::<xla::Literal>(&[]).unwrap()[0][0]
            .to_literal_sync()
            .unwrap();
        assert_eq!(out.to_vec::<f32>().unwrap(), vec![2.0, 3.0]);
    }

    #[test]
    fn artifacts_dir_resolves() {
        let d = artifacts_dir();
        assert!(d.to_string_lossy().contains("artifacts"));
    }
}
