//! 1-NN lookup through the AOT Pallas pairwise-distance artifact
//! (`knn_n{N}_d{D}_q{Q}.hlo.txt`).
//!
//! The compiled program computes squared Euclidean distances between `Q`
//! queries and an `N`-row reference table (Layer-1 Pallas kernel) and
//! returns per-query argmin index + distance. This is the PJRT-backed
//! twin of [`crate::benchmarks::knn::KnnTable`]; integration tests
//! cross-validate the two.

use super::artifact::{lit_f32, vec_f32, vec_i32, CompiledArtifact, Engine};
use crate::benchmarks::knn::KnnTable;
use anyhow::{anyhow, Result};
use std::sync::Arc;

/// Reference-table size baked into the artifact (== PD1's TABLE_SIZE).
pub const KNN_N: usize = 512;
/// Dimension (the PD1 search space).
pub const KNN_D: usize = 4;
/// Query batch size.
pub const KNN_Q: usize = 4;

/// Handle to the compiled 1-NN artifact.
pub struct KnnArtifact {
    art: Arc<CompiledArtifact>,
}

impl KnnArtifact {
    pub fn load(engine: &Engine) -> Result<KnnArtifact> {
        let art = engine.load_named(&format!("knn_n{KNN_N}_d{KNN_D}_q{KNN_Q}"))?;
        Ok(KnnArtifact { art })
    }

    /// Nearest table row for each query (≤ KNN_Q at a time).
    pub fn nearest_batch(
        &self,
        table: &KnnTable,
        queries: &[Vec<f64>],
    ) -> Result<Vec<(usize, f64)>> {
        if table.dim != KNN_D {
            return Err(anyhow!("table dim {} != {KNN_D}", table.dim));
        }
        if table.len() != KNN_N {
            return Err(anyhow!("table len {} != {KNN_N}", table.len()));
        }
        if queries.is_empty() || queries.len() > KNN_Q {
            return Err(anyhow!("1..={KNN_Q} queries required"));
        }
        let tf: Vec<f32> = table.points.iter().map(|&v| v as f32).collect();
        let mut qf = vec![1e6f32; KNN_Q * KNN_D]; // pad with distant queries
        for (i, q) in queries.iter().enumerate() {
            if q.len() != KNN_D {
                return Err(anyhow!("query dim {} != {KNN_D}", q.len()));
            }
            for d in 0..KNN_D {
                qf[i * KNN_D + d] = q[d] as f32;
            }
        }
        let inputs = vec![
            lit_f32(&tf, &[KNN_N as i64, KNN_D as i64])?,
            lit_f32(&qf, &[KNN_Q as i64, KNN_D as i64])?,
        ];
        let out = self.art.run(&inputs)?;
        if out.len() != 2 {
            return Err(anyhow!("knn returned {} outputs", out.len()));
        }
        let idx = vec_i32(&out[0])?;
        let dist = vec_f32(&out[1])?;
        Ok(queries
            .iter()
            .enumerate()
            .map(|(i, _)| (idx[i] as usize, dist[i] as f64))
            .collect())
    }

    /// Single-query convenience.
    pub fn nearest(&self, table: &KnnTable, query: &[f64]) -> Result<(usize, f64)> {
        Ok(self.nearest_batch(table, &[query.to_vec()])?[0])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::benchmarks::pd1::Pd1;
    use crate::runtime::artifact::artifacts_available;
    use crate::util::rng::Rng;

    #[test]
    fn pjrt_knn_matches_rust_knn_on_pd1_table() {
        if !artifacts_available() {
            eprintln!("skipping: artifacts not built (run `make artifacts`)");
            return;
        }
        let engine = Engine::cpu().unwrap();
        let art = KnnArtifact::load(&engine).unwrap();
        let bench = Pd1::wmt();
        let table = bench.knn_table();
        let mut rng = Rng::new(23);
        for _ in 0..8 {
            let q: Vec<f64> = (0..KNN_D).map(|_| rng.next_f64()).collect();
            let (pj_idx, pj_dist) = art.nearest(table, &q).unwrap();
            let rust_idx = table.nearest(&q);
            // distances can tie within f32 precision; accept either argmin
            let d_rust = table.dist2(&q, rust_idx);
            let d_pjrt = table.dist2(&q, pj_idx);
            assert!(
                (d_rust - d_pjrt).abs() < 1e-5,
                "argmin distance mismatch: {d_rust} vs {d_pjrt}"
            );
            assert!((pj_dist - d_pjrt).abs() < 1e-4);
        }
    }

    #[test]
    fn validates_shapes() {
        if !artifacts_available() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let engine = Engine::cpu().unwrap();
        let art = KnnArtifact::load(&engine).unwrap();
        let small = KnnTable::new(KNN_D);
        assert!(art.nearest(&small, &[0.0; KNN_D]).is_err());
    }
}
