//! GP posterior + expected improvement through the AOT JAX/Pallas
//! artifact (`gp_ei_n{N}_d{D}_m{M}.hlo.txt`).
//!
//! The compiled program computes, for a padded training set of exactly
//! `N` points in `D` dimensions and `M` candidate points: the RBF Gram
//! matrix (Layer-1 Pallas kernel), the Cholesky-free posterior via
//! `solve(K + diag(noise), ·)` (jnp.linalg.solve in L2), posterior
//! mean/variance at the candidates, and EI against `f_best`.
//!
//! Padding: unused training slots carry noise 1e6, making them
//! statistically invisible — the masked posterior matches an unpadded GP
//! to ~1e-5, which `tests/pjrt_numerics.rs` cross-checks against the
//! pure-Rust [`crate::searcher::gp::Gp`].

use super::artifact::{lit_f32, lit_scalar, vec_f32, CompiledArtifact, Engine};
use anyhow::{anyhow, Result};
use std::sync::Arc;

/// Padded training-set size baked into the artifact.
pub const GP_N: usize = 64;
/// Input dimension (the PD1 search space).
pub const GP_D: usize = 4;
/// Candidate batch size.
pub const GP_M: usize = 64;
/// Noise variance assigned to padding slots.
pub const PAD_NOISE: f32 = 1e6;

/// Posterior + EI results for one candidate batch.
#[derive(Clone, Debug)]
pub struct GpEiOut {
    pub ei: Vec<f64>,
    pub mean: Vec<f64>,
    pub var: Vec<f64>,
}

/// Handle to the compiled GP/EI artifact.
pub struct GpEiArtifact {
    art: Arc<CompiledArtifact>,
}

impl GpEiArtifact {
    pub fn load(engine: &Engine) -> Result<GpEiArtifact> {
        let art = engine.load_named(&format!("gp_ei_n{GP_N}_d{GP_D}_m{GP_M}"))?;
        Ok(GpEiArtifact { art })
    }

    /// Evaluate the GP posterior and EI.
    ///
    /// * `x` — up to `GP_N` observed points (unit cube, dim `GP_D`);
    /// * `y` — observed objective values (already standardized by caller);
    /// * `cand` — exactly up to `GP_M` candidates (padded internally);
    /// * `f_best` — incumbent (standardized);
    /// * `lengthscale`, `signal_var`, `noise_var` — RBF hyperparameters.
    #[allow(clippy::too_many_arguments)]
    pub fn run(
        &self,
        x: &[Vec<f64>],
        y: &[f64],
        cand: &[Vec<f64>],
        f_best: f64,
        lengthscale: f64,
        signal_var: f64,
        noise_var: f64,
    ) -> Result<GpEiOut> {
        if x.len() != y.len() {
            return Err(anyhow!("x/y length mismatch"));
        }
        if x.len() > GP_N {
            return Err(anyhow!("too many observations: {} > {GP_N}", x.len()));
        }
        if cand.len() > GP_M {
            return Err(anyhow!("too many candidates: {} > {GP_M}", cand.len()));
        }
        // pad X with distant dummy points + huge noise
        let mut xf = vec![0.0f32; GP_N * GP_D];
        let mut yf = vec![0.0f32; GP_N];
        let mut noise = vec![PAD_NOISE; GP_N];
        for (i, p) in x.iter().enumerate() {
            if p.len() != GP_D {
                return Err(anyhow!("point dim {} != {GP_D}", p.len()));
            }
            for d in 0..GP_D {
                xf[i * GP_D + d] = p[d] as f32;
            }
            yf[i] = y[i] as f32;
            noise[i] = noise_var as f32;
        }
        // park padding points far outside the unit cube so their kernel
        // column is ~0 as well (double protection)
        for i in x.len()..GP_N {
            for d in 0..GP_D {
                xf[i * GP_D + d] = 50.0 + i as f32;
            }
        }
        let mut cf = vec![0.0f32; GP_M * GP_D];
        for (i, p) in cand.iter().enumerate() {
            for d in 0..GP_D {
                cf[i * GP_D + d] = p[d] as f32;
            }
        }
        let inputs = vec![
            lit_f32(&xf, &[GP_N as i64, GP_D as i64])?,
            lit_f32(&yf, &[GP_N as i64])?,
            lit_f32(&noise, &[GP_N as i64])?,
            lit_f32(&cf, &[GP_M as i64, GP_D as i64])?,
            lit_scalar(f_best as f32),
            lit_scalar(lengthscale as f32),
            lit_scalar(signal_var as f32),
        ];
        let out = self.art.run(&inputs)?;
        if out.len() != 3 {
            return Err(anyhow!("gp_ei returned {} outputs", out.len()));
        }
        let take = |v: Vec<f32>, n: usize| v.into_iter().take(n).map(|x| x as f64).collect();
        Ok(GpEiOut {
            ei: take(vec_f32(&out[0])?, cand.len()),
            mean: take(vec_f32(&out[1])?, cand.len()),
            var: take(vec_f32(&out[2])?, cand.len()),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifact::artifacts_available;
    use crate::searcher::gp::{expected_improvement, Gp};
    use crate::util::rng::Rng;

    #[test]
    fn pjrt_gp_matches_pure_rust_gp() {
        if !artifacts_available() {
            eprintln!("skipping: artifacts not built (run `make artifacts`)");
            return;
        }
        let engine = Engine::cpu().unwrap();
        let art = GpEiArtifact::load(&engine).unwrap();
        let mut rng = Rng::new(11);
        let n = 20;
        let x: Vec<Vec<f64>> = (0..n)
            .map(|_| (0..GP_D).map(|_| rng.next_f64()).collect())
            .collect();
        let y: Vec<f64> = x
            .iter()
            .map(|p| (p[0] * 3.0).sin() + 0.5 * p[1])
            .collect();
        let cand: Vec<Vec<f64>> = (0..10)
            .map(|_| (0..GP_D).map(|_| rng.next_f64()).collect())
            .collect();
        let (ls, sv, nv) = (0.3, 1.0, 1e-3);
        let f_best = y.iter().cloned().fold(f64::MIN, f64::max);
        let out = art.run(&x, &y, &cand, f_best, ls, sv, nv).unwrap();

        let gp = Gp::fit(&x, &y, ls, sv, nv).unwrap();
        for (i, c) in cand.iter().enumerate() {
            let (m, v) = gp.predict(c);
            assert!(
                (m - out.mean[i]).abs() < 1e-3,
                "mean[{i}]: rust {m} vs pjrt {}",
                out.mean[i]
            );
            assert!(
                (v - out.var[i]).abs() < 1e-3,
                "var[{i}]: rust {v} vs pjrt {}",
                out.var[i]
            );
            let ei = expected_improvement(m, v, f_best);
            assert!(
                (ei - out.ei[i]).abs() < 1e-3,
                "ei[{i}]: rust {ei} vs pjrt {}",
                out.ei[i]
            );
        }
    }

    #[test]
    fn rejects_oversized_inputs() {
        if !artifacts_available() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let engine = Engine::cpu().unwrap();
        let art = GpEiArtifact::load(&engine).unwrap();
        let big: Vec<Vec<f64>> = (0..GP_N + 1).map(|_| vec![0.0; GP_D]).collect();
        let y = vec![0.0; GP_N + 1];
        assert!(art.run(&big, &y, &[], 0.0, 0.3, 1.0, 1e-3).is_err());
    }
}
