//! Real MLP training through AOT-compiled JAX/Pallas artifacts.
//!
//! The train step (`mlp_train_h{H}`) is a single SGD-with-momentum update
//! over one minibatch: forward (Pallas fused linear+ReLU kernels),
//! softmax cross-entropy, backward, parameter update — one HLO program.
//! Hyperparameters (learning rate for the current step, momentum) are
//! *runtime scalar inputs*, so one compiled artifact serves every
//! configuration in the PD1-style search space; the polynomial decay
//! schedule itself is computed here in Rust (L3) each step.
//!
//! Model state (parameters + momentum buffers) lives in Rust between
//! steps — trials can pause at a rung milestone and resume later on any
//! worker, exactly what the promotion-based schedulers need.

use super::artifact::{lit_f32, lit_i32, lit_scalar, scalar_f32, vec_f32, CompiledArtifact, Engine};
use crate::benchmarks::realtrain::{Dataset, RealTrainSpec, BATCH, CLASSES, FEATURES, VAL_N};
use crate::config::space::Config;
use crate::executor::pool::SharedEvaluator;
use crate::executor::Advance;
use crate::util::rng::{mix, Rng};
use crate::TrialId;
use anyhow::{anyhow, Result};
use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Parameter + momentum tensors of one trial (12 tensors, fixed order:
/// w1, b1, w2, b2, w3, b3, then momentum buffers in the same order).
#[derive(Clone, Debug)]
pub struct TrialState {
    pub tensors: Vec<Vec<f32>>,
    pub steps_done: u64,
}

/// SGD steps fused per PJRT call (must match `model.SCAN_K`): one
/// execution uploads the 12 state tensors once and scans 8 minibatches
/// on device — the §Perf transfer-amortization optimization.
pub const SCAN_K: usize = 8;

/// Shapes of the six parameter tensors for hidden width `h`.
pub fn param_shapes(h: usize) -> Vec<Vec<i64>> {
    vec![
        vec![FEATURES as i64, h as i64],
        vec![h as i64],
        vec![h as i64, h as i64],
        vec![h as i64],
        vec![h as i64, CLASSES as i64],
        vec![CLASSES as i64],
    ]
}

/// He-style initialization, deterministic in `seed`.
pub fn init_params(h: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(mix(&[seed, 0x1217]));
    let mut tensors = Vec::with_capacity(12);
    for (i, shape) in param_shapes(h).iter().enumerate() {
        let numel: i64 = shape.iter().product();
        if i % 2 == 0 {
            // weight: He normal with fan_in = shape[0]
            let sd = (2.0 / shape[0] as f64).sqrt();
            tensors.push(
                (0..numel)
                    .map(|_| (rng.normal() * sd) as f32)
                    .collect::<Vec<f32>>(),
            );
        } else {
            tensors.push(vec![0.0f32; numel as usize]);
        }
    }
    // momentum buffers
    for shape in param_shapes(h) {
        let numel: i64 = shape.iter().product();
        tensors.push(vec![0.0f32; numel as usize]);
    }
    tensors
}

/// The PJRT-backed trainer: owns the compiled artifacts, the dataset and
/// all per-trial state. Shared across worker threads.
pub struct MlpTrainer {
    train_step: Arc<CompiledArtifact>,
    /// Fused SCAN_K-step variant used on the epoch hot path.
    train_step_k: Arc<CompiledArtifact>,
    eval_step: Arc<CompiledArtifact>,
    pub spec: RealTrainSpec,
    pub dataset: Dataset,
    state: Mutex<HashMap<TrialId, TrialState>>,
    hidden: usize,
}

impl MlpTrainer {
    /// Load artifacts for hidden width `spec.hidden` (one compiled
    /// executable per model variant).
    pub fn new(engine: &Engine, spec: RealTrainSpec) -> Result<MlpTrainer> {
        let train_step = engine.load_named(&format!("mlp_train_h{}", spec.hidden))?;
        let train_step_k =
            engine.load_named(&format!("mlp_train{SCAN_K}_h{}", spec.hidden))?;
        let eval_step = engine.load_named(&format!("mlp_eval_h{}", spec.hidden))?;
        let dataset = Dataset::generate(spec.data_seed);
        Ok(MlpTrainer {
            train_step,
            train_step_k,
            eval_step,
            hidden: spec.hidden,
            spec,
            dataset,
            state: Mutex::new(HashMap::new()),
        })
    }

    fn all_shapes(&self) -> Vec<Vec<i64>> {
        let mut s = param_shapes(self.hidden);
        s.extend(param_shapes(self.hidden));
        s
    }

    /// One SGD-momentum step on minibatch (epoch, b). Returns the loss.
    fn step(
        &self,
        st: &mut TrialState,
        config: &Config,
        trial_seed: u64,
        epoch: u32,
        b: usize,
        total_steps: u64,
    ) -> Result<f32> {
        let (x, y) = self.dataset.minibatch(trial_seed, epoch, b);
        let lr = self.spec.lr_at(config, st.steps_done, total_steps) as f32;
        let mom = self.spec.momentum(config) as f32;
        let shapes = self.all_shapes();
        let mut inputs: Vec<xla::Literal> = Vec::with_capacity(16);
        for (t, shape) in st.tensors.iter().zip(&shapes) {
            inputs.push(lit_f32(t, shape)?);
        }
        inputs.push(lit_f32(&x, &[BATCH as i64, FEATURES as i64])?);
        inputs.push(lit_i32(&y, &[BATCH as i64])?);
        inputs.push(lit_scalar(lr));
        inputs.push(lit_scalar(mom));
        let outputs = self.train_step.run(&inputs)?;
        if outputs.len() != 13 {
            return Err(anyhow!("train step returned {} outputs", outputs.len()));
        }
        for (t, o) in st.tensors.iter_mut().zip(&outputs[..12]) {
            *t = vec_f32(o)?;
        }
        st.steps_done += 1;
        scalar_f32(&outputs[12])
    }

    /// SCAN_K fused SGD steps in one PJRT execution, starting at
    /// minibatch `b0` of `epoch`. Returns the mean loss over the chunk.
    fn step_k(
        &self,
        st: &mut TrialState,
        config: &Config,
        trial_seed: u64,
        epoch: u32,
        b0: usize,
        total_steps: u64,
    ) -> Result<f32> {
        let mut xs = Vec::with_capacity(SCAN_K * BATCH * FEATURES);
        let mut ys = Vec::with_capacity(SCAN_K * BATCH);
        let mut lrs = Vec::with_capacity(SCAN_K);
        for i in 0..SCAN_K {
            let (x, y) = self.dataset.minibatch(trial_seed, epoch, b0 + i);
            xs.extend_from_slice(&x);
            ys.extend_from_slice(&y);
            lrs.push(self.spec.lr_at(config, st.steps_done + i as u64, total_steps) as f32);
        }
        let mom = self.spec.momentum(config) as f32;
        let shapes = self.all_shapes();
        let mut inputs: Vec<xla::Literal> = Vec::with_capacity(16);
        for (t, shape) in st.tensors.iter().zip(&shapes) {
            inputs.push(lit_f32(t, shape)?);
        }
        inputs.push(lit_f32(
            &xs,
            &[SCAN_K as i64, BATCH as i64, FEATURES as i64],
        )?);
        inputs.push(lit_i32(&ys, &[SCAN_K as i64, BATCH as i64])?);
        inputs.push(lit_f32(&lrs, &[SCAN_K as i64])?);
        inputs.push(lit_scalar(mom));
        let outputs = self.train_step_k.run(&inputs)?;
        if outputs.len() != 13 {
            return Err(anyhow!("train_step_k returned {} outputs", outputs.len()));
        }
        for (t, o) in st.tensors.iter_mut().zip(&outputs[..12]) {
            *t = vec_f32(o)?;
        }
        st.steps_done += SCAN_K as u64;
        scalar_f32(&outputs[12])
    }

    /// Validation (loss, accuracy%) for a parameter set.
    pub fn evaluate(&self, params: &[Vec<f32>]) -> Result<(f64, f64)> {
        let shapes = param_shapes(self.hidden);
        let mut inputs: Vec<xla::Literal> = Vec::with_capacity(8);
        for (t, shape) in params.iter().take(6).zip(&shapes) {
            inputs.push(lit_f32(t, shape)?);
        }
        inputs.push(lit_f32(
            &self.dataset.val_x,
            &[VAL_N as i64, FEATURES as i64],
        )?);
        inputs.push(lit_i32(&self.dataset.val_y, &[VAL_N as i64])?);
        let outputs = self.eval_step.run(&inputs)?;
        let loss = scalar_f32(&outputs[0])? as f64;
        let acc = scalar_f32(&outputs[1])? as f64 * 100.0;
        Ok((loss, acc))
    }

    /// Train `trial` from epoch `from` to `to`, returning per-epoch
    /// validation accuracy (%) — the trainer-side implementation of
    /// [`Evaluator::advance`].
    pub fn train_epochs(
        &self,
        trial: TrialId,
        config: &Config,
        from: u32,
        to: u32,
    ) -> Result<Vec<f64>> {
        let trial_seed = mix(&[self.spec.data_seed, trial as u64]);
        let mut st = {
            let mut map = self.state.lock().unwrap();
            map.remove(&trial)
                .unwrap_or_else(|| TrialState {
                    tensors: init_params(self.hidden, trial_seed),
                    steps_done: 0,
                })
        };
        debug_assert_eq!(
            st.steps_done,
            from as u64 * self.dataset.batches_per_epoch() as u64,
            "resume point mismatch"
        );
        let total_steps =
            self.spec.max_epochs as u64 * self.dataset.batches_per_epoch() as u64;
        let mut accs = Vec::with_capacity((to - from) as usize);
        let bpe = self.dataset.batches_per_epoch();
        for epoch in from + 1..=to {
            // fused SCAN_K-step chunks; tail handled by single steps
            let mut b = 0usize;
            while b + SCAN_K <= bpe {
                self.step_k(&mut st, config, trial_seed, epoch, b, total_steps)?;
                b += SCAN_K;
            }
            while b < bpe {
                self.step(&mut st, config, trial_seed, epoch, b, total_steps)?;
                b += 1;
            }
            let (_, acc) = self.evaluate(&st.tensors)?;
            accs.push(acc);
        }
        self.state.lock().unwrap().insert(trial, st);
        Ok(accs)
    }

    /// Phase-2 retraining from scratch: fresh parameters, full budget;
    /// returns final validation accuracy (%).
    pub fn retrain(&self, config: &Config, epochs: u32) -> Result<f64> {
        let seed = mix(&[self.spec.data_seed, 0x2E72A17]);
        let mut st = TrialState {
            tensors: init_params(self.hidden, seed),
            steps_done: 0,
        };
        let total_steps = epochs as u64 * self.dataset.batches_per_epoch() as u64;
        let bpe = self.dataset.batches_per_epoch();
        let mut last = 0.0;
        for epoch in 1..=epochs {
            let mut b = 0usize;
            while b + SCAN_K <= bpe {
                self.step_k(&mut st, config, seed, epoch, b, total_steps)?;
                b += SCAN_K;
            }
            while b < bpe {
                self.step(&mut st, config, seed, epoch, b, total_steps)?;
                b += 1;
            }
            let (_, acc) = self.evaluate(&st.tensors)?;
            last = acc;
        }
        Ok(last)
    }

    /// Drop a trial's state (after the tuner finishes with it).
    pub fn release(&self, trial: TrialId) {
        self.state.lock().unwrap().remove(&trial);
    }

    pub fn num_live_trials(&self) -> usize {
        self.state.lock().unwrap().len()
    }
}

impl SharedEvaluator for MlpTrainer {
    fn advance(&self, trial: TrialId, config: &Config, from: u32, to: u32) -> Advance {
        let t0 = Instant::now();
        let accs = self
            .train_epochs(trial, config, from, to)
            .unwrap_or_else(|e| panic!("training failed for trial {trial}: {e}"));
        Advance {
            accs,
            cost_seconds: t0.elapsed().as_secs_f64(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::space::ParamValue as P;
    use crate::runtime::artifact::artifacts_available;

    fn good_config() -> Config {
        Config::new(vec![
            P::Float(0.1),  // lr
            P::Float(0.1),  // 1 - momentum = 0.1 → momentum 0.9
            P::Float(1.0),  // decay power
            P::Float(0.8),  // decay fraction
        ])
    }

    #[test]
    fn param_shapes_consistent() {
        let shapes = param_shapes(64);
        assert_eq!(shapes.len(), 6);
        assert_eq!(shapes[0], vec![32, 64]);
        assert_eq!(shapes[5], vec![10]);
        let p = init_params(64, 0);
        assert_eq!(p.len(), 12);
        for (t, s) in p.iter().take(6).zip(&shapes) {
            let numel: i64 = s.iter().product();
            assert_eq!(t.len(), numel as usize);
        }
        // momentum buffers zero-initialized
        assert!(p[6..].iter().all(|t| t.iter().all(|&v| v == 0.0)));
    }

    #[test]
    fn init_deterministic_nonzero() {
        let a = init_params(64, 7);
        let b = init_params(64, 7);
        assert_eq!(a[0], b[0]);
        assert!(a[0].iter().any(|&v| v != 0.0));
        let c = init_params(64, 8);
        assert_ne!(a[0], c[0]);
    }

    #[test]
    fn trains_and_learns_via_pjrt() {
        if !artifacts_available() {
            eprintln!("skipping: artifacts not built (run `make artifacts`)");
            return;
        }
        let engine = Engine::cpu().unwrap();
        let spec = RealTrainSpec {
            hidden: 64,
            max_epochs: 3,
            data_seed: 0,
        };
        let trainer = MlpTrainer::new(&engine, spec).unwrap();
        let accs = trainer.train_epochs(0, &good_config(), 0, 2).unwrap();
        assert_eq!(accs.len(), 2);
        // a learnable task: accuracy must beat chance (10%) after 2 epochs
        assert!(
            accs[1] > 30.0,
            "model should learn: epoch accs {accs:?}"
        );
        // pause/resume: continue to epoch 3 without reinitializing
        let more = trainer.train_epochs(0, &good_config(), 2, 3).unwrap();
        assert_eq!(more.len(), 1);
        assert!(more[0] > accs[0], "continued training improves");
        assert_eq!(trainer.num_live_trials(), 1);
        trainer.release(0);
        assert_eq!(trainer.num_live_trials(), 0);
    }

    #[test]
    fn bad_lr_fails_to_learn() {
        if !artifacts_available() {
            eprintln!("skipping: artifacts not built (run `make artifacts`)");
            return;
        }
        let engine = Engine::cpu().unwrap();
        let spec = RealTrainSpec {
            hidden: 64,
            max_epochs: 2,
            data_seed: 0,
        };
        let trainer = MlpTrainer::new(&engine, spec).unwrap();
        let tiny_lr = Config::new(vec![
            P::Float(1e-5),
            P::Float(0.5),
            P::Float(1.0),
            P::Float(0.5),
        ]);
        let accs = trainer.train_epochs(1, &tiny_lr, 0, 1).unwrap();
        let good = trainer.train_epochs(2, &good_config(), 0, 1).unwrap();
        assert!(
            good[0] > accs[0],
            "good lr {} must beat tiny lr {}",
            good[0],
            accs[0]
        );
    }
}
