//! PJRT runtime: loads AOT-compiled HLO-text artifacts (produced by
//! `python/compile/aot.py`) and executes them from the Rust hot path.
//!
//! Interchange is HLO *text*, not serialized `HloModuleProto` — the
//! image's xla_extension 0.5.1 rejects jax ≥ 0.5 protos with 64-bit
//! instruction ids, while the text parser reassigns ids and round-trips
//! cleanly (see DESIGN.md and /opt/xla-example/load_hlo/).

pub mod artifact;
pub mod gp;
pub mod knn;
pub mod trainer;
