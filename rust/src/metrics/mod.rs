//! Aggregation of repeated tuning runs into the paper's table rows.

use crate::tuner::TuneResult;
use crate::util::stats::Agg;

/// One approach-row of a results table, aggregated over repetitions:
/// `Accuracy (%) | Runtime | Speedup factor | Max resources`.
#[derive(Clone, Debug)]
pub struct Row {
    pub approach: String,
    pub accuracy: Agg,
    pub runtime: Agg,
    pub max_resources: Agg,
    pub total_epochs: Agg,
}

impl Row {
    pub fn from_results(approach: &str, results: &[TuneResult]) -> Row {
        Row {
            approach: approach.to_string(),
            accuracy: Agg::from(
                &results
                    .iter()
                    .map(|r| r.retrain_accuracy)
                    .collect::<Vec<_>>(),
            ),
            runtime: Agg::from(
                &results
                    .iter()
                    .map(|r| r.runtime_seconds)
                    .collect::<Vec<_>>(),
            ),
            max_resources: Agg::from(
                &results
                    .iter()
                    .map(|r| r.max_resources as f64)
                    .collect::<Vec<_>>(),
            ),
            total_epochs: Agg::from(
                &results
                    .iter()
                    .map(|r| r.total_epochs as f64)
                    .collect::<Vec<_>>(),
            ),
        }
    }

    /// Speedup factor relative to a reference (ASHA) runtime; the paper
    /// prints `N/A` for the zero-cost random baseline.
    pub fn speedup_cell(&self, reference_runtime: f64) -> String {
        let rt = self.runtime.mean();
        if rt <= 0.0 {
            "N/A".to_string()
        } else {
            format!("{:.1}x", reference_runtime / rt)
        }
    }

    /// The four standard cells.
    pub fn cells(&self, reference_runtime: f64) -> Vec<String> {
        vec![
            self.approach.clone(),
            self.accuracy.cell(2),
            self.runtime.cell_hours(),
            self.speedup_cell(reference_runtime),
            self.max_resources.cell(1),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn result(acc: f64, rt: f64, max_r: u32) -> TuneResult {
        TuneResult {
            scheduler_name: "x".into(),
            best_config: None,
            best_metric: acc,
            retrain_accuracy: acc,
            runtime_seconds: rt,
            max_resources: max_r,
            configs_sampled: 0,
            total_epochs: 0,
            jobs: 0,
            cancelled_jobs: 0,
            stopped_trials: 0,
            eps_history: vec![],
        }
    }

    #[test]
    fn row_aggregates() {
        let rs = vec![result(90.0, 3600.0, 27), result(92.0, 7200.0, 81)];
        let row = Row::from_results("PASHA", &rs);
        assert_eq!(row.accuracy.cell(2), "91.00 ± 1.41");
        assert_eq!(row.runtime.cell_hours(), "1.5h ± 0.7h");
        assert_eq!(row.speedup_cell(10800.0), "2.0x");
    }

    #[test]
    fn zero_runtime_speedup_na() {
        let rs = vec![result(50.0, 0.0, 0)];
        let row = Row::from_results("Random baseline", &rs);
        assert_eq!(row.speedup_cell(3600.0), "N/A");
    }

    #[test]
    fn cells_shape() {
        let rs = vec![result(90.0, 3600.0, 27)];
        let row = Row::from_results("ASHA", &rs);
        let cells = row.cells(3600.0);
        assert_eq!(cells.len(), 5);
        assert_eq!(cells[0], "ASHA");
        assert_eq!(cells[3], "1.0x");
    }
}
