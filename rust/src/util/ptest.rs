//! A tiny property-based testing harness (the image has no `proptest`).
//!
//! [`check`] runs a property over `cases` randomly generated inputs from a
//! seeded generator; on failure it reports the seed and case index so the
//! exact input can be regenerated. No shrinking — generators are kept
//! small enough that raw failing inputs are readable.
//!
//! ```no_run
//! # // no_run: doctest binaries don't get the xla rpath link flag
//! use pasha::util::ptest::{check, Gen};
//! check("sort is idempotent", 200, |g: &mut Gen| {
//!     let mut v = g.vec_f64(0, 32, -1e3, 1e3);
//!     v.sort_by(|a, b| a.partial_cmp(b).unwrap());
//!     let w = {
//!         let mut w = v.clone();
//!         w.sort_by(|a, b| a.partial_cmp(b).unwrap());
//!         w
//!     };
//!     assert_eq!(v, w);
//! });
//! ```

use super::rng::Rng;

/// Input generator handed to each property case.
pub struct Gen {
    rng: Rng,
    /// Case index (0-based), exposed so properties can scale size with it.
    pub case: usize,
}

impl Gen {
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }

    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.uniform(lo, hi)
    }

    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        self.rng.int_range(lo as i64, hi as i64) as usize
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    pub fn u64(&mut self) -> u64 {
        self.rng.next_u64()
    }

    /// Vector of uniform doubles with length in [min_len, max_len].
    pub fn vec_f64(&mut self, min_len: usize, max_len: usize, lo: f64, hi: f64) -> Vec<f64> {
        let n = self.usize(min_len, max_len);
        (0..n).map(|_| self.f64(lo, hi)).collect()
    }

    /// Strictly increasing positive sequence (useful for resource levels).
    pub fn increasing(&mut self, len: usize, start: f64, max_step: f64) -> Vec<f64> {
        let mut v = Vec::with_capacity(len);
        let mut x = start;
        for _ in 0..len {
            x += self.f64(1e-9, max_step);
            v.push(x);
        }
        v
    }

    /// Random permutation of 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut v: Vec<usize> = (0..n).collect();
        self.rng.shuffle(&mut v);
        v
    }
}

/// Fixed default seed; override with env `PASHA_PTEST_SEED` to replay.
fn base_seed() -> u64 {
    std::env::var("PASHA_PTEST_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEE)
}

/// Run `prop` over `cases` generated inputs. Panics (with seed + case id)
/// if the property panics for any case.
pub fn check<F: Fn(&mut Gen) + std::panic::RefUnwindSafe>(name: &str, cases: usize, prop: F) {
    let seed = base_seed();
    for case in 0..cases {
        let case_seed = super::rng::mix(&[seed, case as u64]);
        let result = std::panic::catch_unwind(|| {
            let mut g = Gen {
                rng: Rng::new(case_seed),
                case,
            };
            prop(&mut g);
        });
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".to_string());
            panic!(
                "property '{name}' failed at case {case} \
                 (replay with PASHA_PTEST_SEED={seed}): {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        check("reverse twice is identity", 50, |g| {
            let v = g.vec_f64(0, 16, -1.0, 1.0);
            let mut w = v.clone();
            w.reverse();
            w.reverse();
            assert_eq!(v, w);
        });
    }

    #[test]
    fn reports_failure_with_case() {
        let r = std::panic::catch_unwind(|| {
            check("always fails", 3, |_g| {
                panic!("boom");
            });
        });
        let payload = r.unwrap_err();
        let msg = payload
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_else(|| format!("{payload:?}"));
        assert!(msg.contains("always fails"), "{msg}");
        assert!(msg.contains("case 0"), "{msg}");
    }

    #[test]
    fn generator_ranges_hold() {
        check("gen ranges", 100, |g| {
            let x = g.f64(2.0, 3.0);
            assert!((2.0..3.0).contains(&x));
            let n = g.usize(1, 5);
            assert!((1..=5).contains(&n));
            let inc = g.increasing(10, 0.0, 2.0);
            for w in inc.windows(2) {
                assert!(w[0] < w[1]);
            }
            let p = g.permutation(8);
            let mut q = p.clone();
            q.sort();
            assert_eq!(q, (0..8).collect::<Vec<_>>());
        });
    }

    #[test]
    fn deterministic_given_seed() {
        // Two runs of the same property observe identical inputs.
        use std::sync::Mutex;
        static SEEN: Mutex<Vec<f64>> = Mutex::new(Vec::new());
        SEEN.lock().unwrap().clear();
        for _ in 0..2 {
            check("record", 5, |g| {
                SEEN.lock().unwrap().push(g.f64(0.0, 1.0));
            });
        }
        let seen = SEEN.lock().unwrap();
        assert_eq!(seen.len(), 10);
        assert_eq!(seen[..5], seen[5..]);
    }
}
