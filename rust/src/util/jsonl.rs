//! Shared append-only JSONL file discipline.
//!
//! Both the service write-ahead journal ([`crate::service::journal`]) and
//! the persistent trial store ([`crate::store`]) are sequences of whole
//! JSON lines that must survive a process dying mid-append. This module
//! holds the one implementation of that discipline:
//!
//! * [`read_jsonl`] — strict read tolerating a *torn tail*: a final line
//!   with no newline, or a newline-terminated final line that fails to
//!   parse, is a crash artifact and is dropped (its byte offset is
//!   reported so the writer can truncate before appending). A malformed
//!   line in the *middle* of the file is corruption and is an error.
//! * [`read_jsonl_lenient`] — best-effort read for files that are an
//!   optimization rather than ground truth (snapshot sidecars): corrupt
//!   or torn lines are skipped, a missing file reads as empty.
//! * [`append_line`] — self-repairing append: the file is first truncated
//!   back to its whole-line prefix so a new record can never merge with
//!   torn bytes left by an earlier crash.
//! * [`rewrite_atomic`] — whole-file replacement via a `.tmp` sibling and
//!   rename, for compaction.

use crate::util::json::{parse, Json};
use std::fs::{File, OpenOptions};
use std::io::{self, Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};

/// Result of a strict [`read_jsonl`].
pub struct JsonlRead {
    /// Whole records, in append order.
    pub records: Vec<Json>,
    /// Byte length of the whole-line prefix (what a re-opened file must
    /// be truncated to before appending).
    pub valid_len: u64,
    /// Bytes of a partial trailing line dropped as a crash artifact.
    pub truncated_bytes: usize,
}

/// Read a JSONL file, tolerating a partial final line. Offsets are
/// byte-accurate (the file is scanned as raw bytes, so a crash that cut a
/// multi-byte character cannot skew `valid_len`).
pub fn read_jsonl(path: &Path) -> io::Result<JsonlRead> {
    let mut buf = Vec::new();
    File::open(path)?.read_to_end(&mut buf)?;
    let mut records: Vec<Json> = Vec::new();
    let mut valid_len = 0u64;
    let mut start = 0usize;
    let done = |records: Vec<Json>, valid_len: u64| JsonlRead {
        truncated_bytes: buf.len() - valid_len as usize,
        records,
        valid_len,
    };
    while start < buf.len() {
        let Some(rel) = buf[start..].iter().position(|&b| b == b'\n') else {
            // No newline: the final append was cut short — a crash
            // artifact, dropped.
            return Ok(done(records, valid_len));
        };
        let end = start + rel;
        let next = end + 1;
        let at_eof = next == buf.len();
        let line = &buf[start..end];
        if line.is_empty() {
            valid_len = next as u64;
            start = next;
            continue;
        }
        let parsed: Result<Json, String> = match std::str::from_utf8(line) {
            Ok(s) => parse(s),
            Err(e) => Err(format!("invalid utf-8: {e}")),
        };
        match parsed {
            Ok(ev) => {
                records.push(ev);
                valid_len = next as u64;
            }
            // A newline-terminated but unparseable *final* line is also
            // treated as a crash artifact (a torn multi-chunk write);
            // anywhere else it is corruption.
            Err(_) if at_eof => return Ok(done(records, valid_len)),
            Err(e) => {
                return Err(io::Error::new(
                    io::ErrorKind::InvalidData,
                    format!(
                        "corrupt journal {}: event {} unparseable: {e}",
                        path.display(),
                        records.len()
                    ),
                ));
            }
        }
        start = next;
    }
    Ok(done(records, valid_len))
}

/// Read every parseable line, skipping anything torn or corrupt. A
/// missing file reads as empty. For files that are an optimization, not
/// ground truth — a bad line is dropped, never fatal.
pub fn read_jsonl_lenient(path: &Path) -> Vec<Json> {
    let mut buf = Vec::new();
    match File::open(path) {
        Ok(mut f) => {
            if f.read_to_end(&mut buf).is_err() {
                return Vec::new();
            }
        }
        Err(_) => return Vec::new(),
    }
    let mut lines = Vec::new();
    let mut start = 0usize;
    while start < buf.len() {
        // only newline-terminated lines count: a torn final append is
        // incomplete by definition
        let Some(rel) = buf[start..].iter().position(|&b| b == b'\n') else {
            break;
        };
        let end = start + rel;
        if let Ok(s) = std::str::from_utf8(&buf[start..end]) {
            if let Ok(v) = parse(s) {
                lines.push(v);
            }
        }
        start = end + 1;
    }
    lines
}

/// Append one JSON line to `path`, creating the file (and parent
/// directory) if needed. A previous crash can have left a torn final
/// line; the file is first truncated back to its whole-line prefix so
/// the new record can never merge with torn bytes — without this, one
/// crash mid-append would silently corrupt every later record on the
/// same line.
pub fn append_line(path: &Path, event: &Json) -> io::Result<()> {
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    let mut file = OpenOptions::new()
        .create(true)
        .read(true)
        .write(true)
        .open(path)?;
    let mut buf = Vec::new();
    file.read_to_end(&mut buf)?;
    let valid = match buf.iter().rposition(|&b| b == b'\n') {
        Some(i) => (i + 1) as u64,
        None => 0,
    };
    if valid != buf.len() as u64 {
        file.set_len(valid)?;
    }
    file.seek(SeekFrom::Start(valid))?;
    let mut line = event.to_string_compact();
    line.push('\n');
    file.write_all(line.as_bytes())
}

/// Atomically replace `path` with the given lines: write a sibling
/// `.tmp` file, then rename over the target. A crash before the rename
/// leaves the original untouched; after, the replacement is complete.
pub fn rewrite_atomic(path: &Path, lines: &[Json]) -> io::Result<()> {
    let tmp = PathBuf::from(format!("{}.tmp", path.display()));
    {
        let mut file = File::create(&tmp)?;
        let mut out = String::new();
        for l in lines {
            out.push_str(&l.to_string_compact());
            out.push('\n');
        }
        file.write_all(out.as_bytes())?;
    }
    std::fs::rename(&tmp, path)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("pasha-jsonl-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    fn rec(n: usize) -> Json {
        let mut o = Json::obj();
        o.set("n", n);
        o
    }

    #[test]
    fn strict_read_round_trips_whole_lines() {
        let path = tmp("strict.jsonl");
        let _ = std::fs::remove_file(&path);
        for i in 0..4 {
            append_line(&path, &rec(i)).unwrap();
        }
        let r = read_jsonl(&path).unwrap();
        assert_eq!(r.records.len(), 4);
        assert_eq!(r.truncated_bytes, 0);
        assert_eq!(r.valid_len, std::fs::metadata(&path).unwrap().len());
    }

    #[test]
    fn torn_tail_is_dropped_and_repaired_on_append() {
        let path = tmp("torn.jsonl");
        let _ = std::fs::remove_file(&path);
        append_line(&path, &rec(0)).unwrap();
        append_line(&path, &rec(1)).unwrap();
        let full = std::fs::read(&path).unwrap();
        std::fs::write(&path, &full[..full.len() - 3]).unwrap();
        let r = read_jsonl(&path).unwrap();
        assert_eq!(r.records.len(), 1);
        assert!(r.truncated_bytes > 0);
        // appending over the torn tail truncates it first
        append_line(&path, &rec(2)).unwrap();
        let r2 = read_jsonl(&path).unwrap();
        assert_eq!(r2.records.len(), 2);
        assert_eq!(r2.records[1], rec(2));
    }

    #[test]
    fn mid_file_corruption_is_invalid_data() {
        let path = tmp("midcorrupt.jsonl");
        std::fs::write(&path, "{\"n\":0}\nnope\n{\"n\":1}\n").unwrap();
        let err = read_jsonl(&path).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
        // the lenient reader skips it instead
        let lines = read_jsonl_lenient(&path);
        assert_eq!(lines.len(), 2);
    }

    #[test]
    fn lenient_read_missing_file_is_empty() {
        let path = tmp("lenient-missing.jsonl");
        let _ = std::fs::remove_file(&path);
        assert!(read_jsonl_lenient(&path).is_empty());
    }

    #[test]
    fn rewrite_atomic_replaces_and_cleans_tmp() {
        let path = tmp("rewrite.jsonl");
        std::fs::write(&path, "old\n").unwrap();
        rewrite_atomic(&path, &[rec(7)]).unwrap();
        let r = read_jsonl(&path).unwrap();
        assert_eq!(r.records.len(), 1);
        assert_eq!(r.records[0], rec(7));
        assert!(!PathBuf::from(format!("{}.tmp", path.display())).exists());
    }
}
