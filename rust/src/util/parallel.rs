//! Deterministic fork/join over scoped OS threads (the offline image has
//! no `rayon`).
//!
//! [`par_map`] fans a work list across up to `threads` scoped workers
//! pulling indices from a shared atomic counter, and returns results in
//! **input order** regardless of which worker ran which item — so any
//! caller whose per-item function is deterministic gets output identical
//! to a serial map. This is what lets the experiment-grid driver promise
//! "same tables, just faster".

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Usable hardware parallelism (≥ 1).
pub fn available_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Apply `f` to every item on up to `threads` scoped workers; results
/// come back in input order. `f` receives `(index, &item)`. Falls back to
/// a plain serial map when a single thread suffices. Panics in `f`
/// propagate to the caller (the scope joins all workers first).
pub fn par_map<I, T, F>(items: &[I], threads: usize, f: F) -> Vec<T>
where
    I: Sync,
    T: Send,
    F: Fn(usize, &I) -> T + Sync,
{
    let n = items.len();
    let threads = threads.max(1).min(n.max(1));
    if threads <= 1 || n <= 1 {
        return items.iter().enumerate().map(|(i, it)| f(i, it)).collect();
    }
    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<T>>> = (0..n).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let v = f(i, &items[i]);
                *slots[i].lock().unwrap() = Some(v);
            });
        }
    });
    slots
        .into_iter()
        .map(|m| {
            m.into_inner()
                .unwrap()
                .expect("scoped worker filled every claimed slot")
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize as Counter;

    #[test]
    fn preserves_input_order() {
        let items: Vec<usize> = (0..100).collect();
        let out = par_map(&items, 8, |i, &x| {
            // stagger completion order
            if x % 7 == 0 {
                std::thread::sleep(std::time::Duration::from_micros(200));
            }
            assert_eq!(i, x);
            x * 2
        });
        assert_eq!(out, items.iter().map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn matches_serial_map() {
        let items: Vec<u64> = (0..37).collect();
        let serial = par_map(&items, 1, |_, &x| x.wrapping_mul(2654435761) % 97);
        let parallel = par_map(&items, 6, |_, &x| x.wrapping_mul(2654435761) % 97);
        assert_eq!(serial, parallel);
    }

    #[test]
    fn runs_every_item_exactly_once() {
        let calls = Counter::new(0);
        let items: Vec<usize> = (0..50).collect();
        let out = par_map(&items, 4, |_, &x| {
            calls.fetch_add(1, Ordering::Relaxed);
            x
        });
        assert_eq!(out.len(), 50);
        assert_eq!(calls.load(Ordering::Relaxed), 50);
    }

    #[test]
    fn empty_and_single() {
        let none: Vec<usize> = vec![];
        assert!(par_map(&none, 4, |_, &x| x).is_empty());
        assert_eq!(par_map(&[41usize], 4, |_, &x| x + 1), vec![42]);
    }

    #[test]
    fn actually_uses_multiple_threads() {
        // 8 items × 20 ms on 8 threads must finish well under 8×20 ms.
        let items: Vec<usize> = (0..8).collect();
        let t0 = std::time::Instant::now();
        par_map(&items, 8, |_, _| {
            std::thread::sleep(std::time::Duration::from_millis(20))
        });
        assert!(
            t0.elapsed().as_millis() < 120,
            "took {:?} — not parallel",
            t0.elapsed()
        );
    }

    #[test]
    fn available_threads_positive() {
        assert!(available_threads() >= 1);
    }
}
