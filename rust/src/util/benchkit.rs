//! Minimal benchmarking harness (the offline image has no `criterion`).
//!
//! `cargo bench` binaries use `harness = false` and drive [`bench`] /
//! [`bench_n`] directly: warmup, then timed batches until a minimum
//! measurement window is reached, reporting mean ± σ per iteration.

use std::time::{Duration, Instant};

/// One benchmark measurement.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub sd_ns: f64,
}

impl Measurement {
    pub fn per_iter(&self) -> Duration {
        Duration::from_nanos(self.mean_ns as u64)
    }

    /// `name  123.4 µs/iter (± 5.6 µs, n=1000)` style line.
    pub fn line(&self) -> String {
        fn fmt(ns: f64) -> String {
            if ns < 1e3 {
                format!("{ns:.1} ns")
            } else if ns < 1e6 {
                format!("{:.1} µs", ns / 1e3)
            } else if ns < 1e9 {
                format!("{:.2} ms", ns / 1e6)
            } else {
                format!("{:.3} s", ns / 1e9)
            }
        }
        format!(
            "{:<52} {:>12}/iter  (± {}, n={})",
            self.name,
            fmt(self.mean_ns),
            fmt(self.sd_ns),
            self.iters
        )
    }
}

/// Benchmark a closure: warm up, then run timed batches for at least
/// `min_total` wall time (default 300 ms when using [`bench`]).
pub fn bench_n(name: &str, min_total: Duration, mut f: impl FnMut()) -> Measurement {
    // warmup: a few iterations or 50 ms, whichever first
    let warm_start = Instant::now();
    let mut warm_iters = 0u64;
    while warm_iters < 3 || (warm_start.elapsed() < Duration::from_millis(50) && warm_iters < 1000)
    {
        f();
        warm_iters += 1;
    }
    // choose batch size so one batch ≈ 10 ms
    let per = warm_start.elapsed().as_nanos() as f64 / warm_iters as f64;
    let batch = ((10e6 / per.max(1.0)).ceil() as u64).clamp(1, 1_000_000);

    let mut samples: Vec<f64> = Vec::new();
    let mut total_iters = 0u64;
    let start = Instant::now();
    while start.elapsed() < min_total || samples.len() < 3 {
        let t0 = Instant::now();
        for _ in 0..batch {
            f();
        }
        let dt = t0.elapsed().as_nanos() as f64 / batch as f64;
        samples.push(dt);
        total_iters += batch;
        if samples.len() > 500 {
            break;
        }
    }
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let var = samples
        .iter()
        .map(|s| (s - mean) * (s - mean))
        .sum::<f64>()
        / samples.len().max(1) as f64;
    let m = Measurement {
        name: name.to_string(),
        iters: total_iters,
        mean_ns: mean,
        sd_ns: var.sqrt(),
    };
    println!("{}", m.line());
    m
}

/// [`bench_n`] with the default 300 ms measurement window.
pub fn bench(name: &str, f: impl FnMut()) -> Measurement {
    bench_n(name, Duration::from_millis(300), f)
}

/// Time a one-shot (non-repeatable) workload.
pub fn once<T>(name: &str, f: impl FnOnce() -> T) -> (T, Duration) {
    let t0 = Instant::now();
    let out = f();
    let dt = t0.elapsed();
    println!("{:<52} {:>12.3} s  (one-shot)", name, dt.as_secs_f64());
    (out, dt)
}

/// Section header for bench output.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let m = bench_n("noop-ish", Duration::from_millis(20), || {
            std::hint::black_box(1 + 1);
        });
        assert!(m.mean_ns >= 0.0);
        assert!(m.iters > 0);
    }

    #[test]
    fn bench_scales_with_work() {
        // black_box inside the loop so release-mode LLVM cannot
        // const-fold the sum into a closed form
        let fast = bench_n("fast", Duration::from_millis(20), || {
            let mut acc = 0u64;
            for i in 0..10u64 {
                acc = acc.wrapping_add(std::hint::black_box(i));
            }
            std::hint::black_box(acc);
        });
        let slow = bench_n("slow", Duration::from_millis(20), || {
            let mut acc = 0u64;
            for i in 0..50_000u64 {
                acc = acc.wrapping_add(std::hint::black_box(i));
            }
            std::hint::black_box(acc);
        });
        assert!(slow.mean_ns > fast.mean_ns * 5.0);
    }

    #[test]
    fn once_returns_value() {
        let (v, dt) = once("compute", || 42);
        assert_eq!(v, 42);
        assert!(dt.as_nanos() > 0);
    }

    #[test]
    fn line_formats_units() {
        let m = Measurement {
            name: "x".into(),
            iters: 10,
            mean_ns: 2_500_000.0,
            sd_ns: 100.0,
        };
        assert!(m.line().contains("ms/iter"));
    }
}
