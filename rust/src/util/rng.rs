//! Deterministic pseudo-random number generation.
//!
//! The offline image does not ship the `rand` crate, so we implement the
//! two small generators the framework needs ourselves:
//!
//! * [`splitmix64`] — a stateless 64-bit mixer used to derive independent
//!   streams from `(seed, stream-id)` pairs. Every benchmark surrogate keys
//!   its per-configuration randomness off `splitmix64` hashes so that a
//!   configuration's learning curve is a pure function of
//!   `(benchmark, config, seed)` regardless of query order.
//! * [`Rng`] — xoshiro256++, a fast, high-quality, small-state generator
//!   (Blackman & Vigna), used wherever a sequential stream is needed
//!   (searchers, samplers, the property-test harness).

/// One round of the splitmix64 output function: a bijective 64-bit mixer.
#[inline]
pub fn splitmix64(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// Mix an arbitrary number of 64-bit words into a single hash.
///
/// Used to derive per-entity seeds, e.g. `mix(&[bench_seed, arch_id, epoch])`.
#[inline]
pub fn mix(words: &[u64]) -> u64 {
    let mut h = 0x243f_6a88_85a3_08d3u64; // pi digits
    for &w in words {
        h = splitmix64(h ^ w);
    }
    h
}

/// xoshiro256++ sequential generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator seeded via splitmix64 (as recommended by the
    /// xoshiro authors: never seed the state directly).
    pub fn new(seed: u64) -> Self {
        let mut z = seed;
        let mut s = [0u64; 4];
        for slot in s.iter_mut() {
            z = z.wrapping_add(0x9e37_79b9_7f4a_7c15);
            *slot = splitmix64(z);
        }
        // All-zero state is the one invalid state.
        if s == [0, 0, 0, 0] {
            s[0] = 1;
        }
        Rng { s }
    }

    /// Derive an independent sub-stream (for parallel/deterministic use).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(mix(&[self.next_u64(), stream]))
    }

    /// The raw generator state, for snapshot serialization
    /// ([`crate::scheduler::state`]). Restoring via [`Rng::from_state`]
    /// continues the exact stream.
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild a generator from [`Rng::state`] output. The all-zero state
    /// is invalid for xoshiro and is nudged exactly as [`Rng::new`] does.
    pub fn from_state(mut s: [u64; 4]) -> Rng {
        if s == [0, 0, 0, 0] {
            s[0] = 1;
        }
        Rng { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        // 53 top bits -> [0,1) with full double precision.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Uniform integer in [0, n). Uses Lemire's multiply-shift rejection-free
    /// approximation (bias < 2^-64, irrelevant at our scales).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform integer in [lo, hi] inclusive.
    #[inline]
    pub fn int_range(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as i64
    }

    /// Standard normal via Box–Muller (one value per call; simple > fast here).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            if u1 > 1e-300 {
                let u2 = self.next_f64();
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Normal with given mean and standard deviation.
    #[inline]
    pub fn normal_ms(&mut self, mean: f64, sd: f64) -> f64 {
        mean + sd * self.normal()
    }

    /// Log-uniform in [lo, hi) (both > 0).
    #[inline]
    pub fn log_uniform(&mut self, lo: f64, hi: f64) -> f64 {
        debug_assert!(lo > 0.0 && hi > lo);
        (self.uniform(lo.ln(), hi.ln())).exp()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        assert!(!xs.is_empty());
        &xs[self.below(xs.len() as u64) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_is_deterministic_and_mixing() {
        assert_eq!(splitmix64(0), splitmix64(0));
        assert_ne!(splitmix64(0), splitmix64(1));
        // avalanche sanity: flipping one input bit flips ~half the output bits
        let a = splitmix64(0x1234);
        let b = splitmix64(0x1235);
        let flipped = (a ^ b).count_ones();
        assert!((16..=48).contains(&flipped), "weak avalanche: {flipped}");
    }

    #[test]
    fn mix_order_sensitive() {
        assert_ne!(mix(&[1, 2]), mix(&[2, 1]));
        assert_eq!(mix(&[1, 2, 3]), mix(&[1, 2, 3]));
    }

    #[test]
    fn rng_reproducible_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_in_range_and_roughly_uniform() {
        let mut r = Rng::new(7);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(11);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 200_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn log_uniform_within_bounds() {
        let mut r = Rng::new(5);
        for _ in 0..1000 {
            let x = r.log_uniform(1e-5, 10.0);
            assert!((1e-5..10.0).contains(&x));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn int_range_inclusive() {
        let mut r = Rng::new(13);
        let (mut lo_seen, mut hi_seen) = (false, false);
        for _ in 0..2000 {
            let v = r.int_range(-3, 3);
            assert!((-3..=3).contains(&v));
            lo_seen |= v == -3;
            hi_seen |= v == 3;
        }
        assert!(lo_seen && hi_seen);
    }

    #[test]
    fn state_roundtrip_continues_stream() {
        let mut a = Rng::new(99);
        for _ in 0..17 {
            a.next_u64();
        }
        let mut b = Rng::from_state(a.state());
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        // the invalid all-zero state is nudged, not propagated
        let mut z = Rng::from_state([0, 0, 0, 0]);
        assert_eq!(z.state(), [1, 0, 0, 0]);
        z.next_u64();
    }

    #[test]
    fn fork_streams_diverge() {
        let mut base = Rng::new(1);
        let mut a = base.fork(0);
        let mut b = base.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }
}
