//! Small statistics helpers used throughout the experiment harness:
//! mean/std aggregation for the paper-style `x ± y` cells, percentiles for
//! the ε-estimation rule (§4.2, N-th percentile of pair distances), and
//! rank utilities.

/// Mean of a slice; 0.0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation (n-1 denominator); 0.0 for fewer than 2 points.
pub fn std(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    let var = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64;
    var.sqrt()
}

/// Population standard deviation (n denominator).
pub fn pstd(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// q-th percentile (q in [0, 100]) using linear interpolation between order
/// statistics (numpy's default "linear" method). Panics on empty input.
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    assert!(!xs.is_empty(), "percentile of empty slice");
    assert!((0.0..=100.0).contains(&q));
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_sorted(&v, q)
}

/// Percentile of an already ascending-sorted slice.
pub fn percentile_sorted(v: &[f64], q: f64) -> f64 {
    let n = v.len();
    if n == 1 {
        return v[0];
    }
    let pos = q / 100.0 * (n - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let frac = pos - lo as f64;
        v[lo] * (1.0 - frac) + v[hi] * frac
    }
}

/// Median (50th percentile).
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Aggregate of repeated experiment measurements, rendered `mean ± std`.
#[derive(Clone, Debug, Default)]
pub struct Agg {
    pub values: Vec<f64>,
}

impl Agg {
    pub fn new() -> Self {
        Agg { values: Vec::new() }
    }

    pub fn from(values: &[f64]) -> Self {
        Agg {
            values: values.to_vec(),
        }
    }

    pub fn push(&mut self, v: f64) {
        self.values.push(v);
    }

    pub fn mean(&self) -> f64 {
        mean(&self.values)
    }

    pub fn std(&self) -> f64 {
        std(&self.values)
    }

    pub fn n(&self) -> usize {
        self.values.len()
    }

    /// `93.85 ± 0.25` style cell with the given number of decimals.
    pub fn cell(&self, decimals: usize) -> String {
        format!(
            "{:.d$} ± {:.d$}",
            self.mean(),
            self.std(),
            d = decimals
        )
    }

    /// Hours cell: `3.0h ± 0.6h` from values in seconds.
    pub fn cell_hours(&self) -> String {
        format!(
            "{:.1}h ± {:.1}h",
            self.mean() / 3600.0,
            self.std() / 3600.0
        )
    }
}

/// NaN-safe descending comparator: NaN sorts last (treated as −∞), and
/// the order is total (required by `sort_by` since Rust 1.81's
/// order-violation panics).
#[inline]
pub fn desc_cmp(a: f64, b: f64) -> std::cmp::Ordering {
    let ka = if a.is_nan() { f64::NEG_INFINITY } else { a };
    let kb = if b.is_nan() { f64::NEG_INFINITY } else { b };
    kb.total_cmp(&ka)
}

/// Ranks (0 = best) of items sorted descending by score. Ties broken by
/// index for determinism.
pub fn rank_descending(scores: &[f64]) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..scores.len()).collect();
    idx.sort_by(|&a, &b| desc_cmp(scores[a], scores[b]).then(a.cmp(&b)));
    let mut ranks = vec![0usize; scores.len()];
    for (rank, &i) in idx.iter().enumerate() {
        ranks[i] = rank;
    }
    ranks
}

/// Spearman rank correlation between two paired score vectors.
pub fn spearman(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    let n = a.len();
    if n < 2 {
        return 1.0;
    }
    let ra: Vec<f64> = rank_descending(a).iter().map(|&r| r as f64).collect();
    let rb: Vec<f64> = rank_descending(b).iter().map(|&r| r as f64).collect();
    pearson(&ra, &rb)
}

/// Pearson correlation.
pub fn pearson(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    let ma = mean(a);
    let mb = mean(b);
    let mut num = 0.0;
    let mut da = 0.0;
    let mut db = 0.0;
    for i in 0..a.len() {
        let xa = a[i] - ma;
        let xb = b[i] - mb;
        num += xa * xb;
        da += xa * xa;
        db += xb * xb;
    }
    if da == 0.0 || db == 0.0 {
        return 0.0;
    }
    num / (da * db).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std_basics() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((pstd(&xs) - 2.0).abs() < 1e-12);
        assert!((std(&xs) - 2.13809).abs() < 1e-4);
    }

    #[test]
    fn empty_and_single() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(std(&[]), 0.0);
        assert_eq!(std(&[3.0]), 0.0);
        assert_eq!(percentile(&[3.0], 90.0), 3.0);
    }

    #[test]
    fn percentile_linear_interpolation() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-12);
        assert!((percentile(&xs, 100.0) - 4.0).abs() < 1e-12);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
        // numpy.percentile([1,2,3,4], 90) == 3.7
        assert!((percentile(&xs, 90.0) - 3.7).abs() < 1e-12);
    }

    #[test]
    fn percentile_unsorted_input() {
        let xs = [4.0, 1.0, 3.0, 2.0];
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn median_odd() {
        assert_eq!(median(&[5.0, 1.0, 3.0]), 3.0);
    }

    #[test]
    fn agg_cell_format() {
        let a = Agg::from(&[93.6, 94.1]);
        assert_eq!(a.cell(2), "93.85 ± 0.35");
        let hrs = Agg::from(&[3600.0 * 3.0, 3600.0 * 3.0]);
        assert_eq!(hrs.cell_hours(), "3.0h ± 0.0h");
    }

    #[test]
    fn rank_descending_orders_best_first() {
        let scores = [0.3, 0.9, 0.5];
        assert_eq!(rank_descending(&scores), vec![2, 0, 1]);
    }

    #[test]
    fn rank_ties_deterministic() {
        let scores = [0.5, 0.5, 0.5];
        assert_eq!(rank_descending(&scores), vec![0, 1, 2]);
    }

    #[test]
    fn spearman_perfect_and_inverted() {
        let a = [1.0, 2.0, 3.0, 4.0];
        let b = [10.0, 20.0, 30.0, 40.0];
        assert!((spearman(&a, &b) - 1.0).abs() < 1e-12);
        let c = [40.0, 30.0, 20.0, 10.0];
        assert!((spearman(&a, &c) + 1.0).abs() < 1e-12);
    }
}
