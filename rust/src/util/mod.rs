//! Foundation utilities: deterministic RNG, statistics, JSON, table
//! rendering, scoped-thread parallel map, and the property-test harness.
//! These replace the crates (`rand`, `serde`, `rayon`, `proptest`) that
//! are unavailable in the offline build image — see DESIGN.md
//! §Substitutions.

pub mod benchkit;
pub mod json;
pub mod jsonl;
pub mod log;
pub mod parallel;
#[cfg(unix)]
pub mod poll;
pub mod ptest;
pub mod rng;
pub mod stats;
pub mod table;
