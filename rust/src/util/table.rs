//! Paper-style table rendering: aligned plain-text and GitHub markdown,
//! used by the experiment harness to print rows directly comparable to the
//! paper's Tables 1–15, and CSV emission for the figure series.

/// A simple column-aligned table builder.
#[derive(Clone, Debug, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.headers.len(),
            "row width mismatch in table '{}'",
            self.title
        );
        self.rows.push(cells.to_vec());
        self
    }

    pub fn row_str(&mut self, cells: &[&str]) -> &mut Self {
        let owned: Vec<String> = cells.iter().map(|s| s.to_string()).collect();
        self.row(&owned)
    }

    fn widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> = self.headers.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                w[i] = w[i].max(c.chars().count());
            }
        }
        w
    }

    /// Aligned plain-text rendering (for terminal output).
    pub fn to_text(&self) -> String {
        let w = self.widths();
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&self.title);
            out.push('\n');
        }
        let fmt_row = |cells: &[String]| -> String {
            let mut line = String::new();
            for (i, c) in cells.iter().enumerate() {
                if i > 0 {
                    line.push_str("  ");
                }
                line.push_str(c);
                for _ in c.chars().count()..w[i] {
                    line.push(' ');
                }
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        let total: usize = w.iter().sum::<usize>() + 2 * (w.len().saturating_sub(1));
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    /// GitHub-flavored markdown rendering (for EXPERIMENTS.md).
    pub fn to_markdown(&self) -> String {
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("**{}**\n\n", self.title));
        }
        out.push_str(&format!("| {} |\n", self.headers.join(" | ")));
        out.push_str(&format!(
            "|{}\n",
            self.headers.iter().map(|_| "---|").collect::<String>()
        ));
        for row in &self.rows {
            out.push_str(&format!("| {} |\n", row.join(" | ")));
        }
        out
    }

    /// CSV rendering (quotes cells containing commas).
    pub fn to_csv(&self) -> String {
        let esc = |c: &str| {
            if c.contains(',') || c.contains('"') || c.contains('\n') {
                format!("\"{}\"", c.replace('"', "\"\""))
            } else {
                c.to_string()
            }
        };
        let mut out = String::new();
        out.push_str(
            &self
                .headers
                .iter()
                .map(|h| esc(h))
                .collect::<Vec<_>>()
                .join(","),
        );
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }
}

/// Write a set of (x, series...) points as CSV, used for figure data.
pub fn series_csv(headers: &[&str], columns: &[Vec<f64>]) -> String {
    assert_eq!(headers.len(), columns.len());
    assert!(!columns.is_empty());
    let n = columns[0].len();
    for c in columns {
        assert_eq!(c.len(), n, "ragged series");
    }
    let mut out = String::new();
    out.push_str(&headers.join(","));
    out.push('\n');
    for i in 0..n {
        let row: Vec<String> = columns.iter().map(|c| format!("{}", c[i])).collect();
        out.push_str(&row.join(","));
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Table {
        let mut t = Table::new("Table X", &["Approach", "Accuracy (%)", "Speedup"]);
        t.row_str(&["ASHA", "93.85 ± 0.25", "1.0x"]);
        t.row_str(&["PASHA", "93.57 ± 0.75", "2.3x"]);
        t
    }

    #[test]
    fn text_alignment() {
        let txt = sample().to_text();
        let lines: Vec<&str> = txt.lines().collect();
        assert_eq!(lines[0], "Table X");
        assert!(lines[1].starts_with("Approach"));
        // both data rows start their second column at the same offset
        let off_a = lines[3].find("93.85").unwrap();
        let off_b = lines[4].find("93.57").unwrap();
        assert_eq!(off_a, off_b);
    }

    #[test]
    fn markdown_shape() {
        let md = sample().to_markdown();
        assert!(md.contains("| Approach | Accuracy (%) | Speedup |"));
        assert!(md.contains("|---|---|---|"));
        assert!(md.contains("| PASHA | 93.57 ± 0.75 | 2.3x |"));
    }

    #[test]
    fn csv_escaping() {
        let mut t = Table::new("", &["a", "b"]);
        t.row_str(&["x,y", "q\"z"]);
        let csv = t.to_csv();
        assert!(csv.contains("\"x,y\""));
        assert!(csv.contains("\"q\"\"z\""));
    }

    #[test]
    #[should_panic]
    fn ragged_row_panics() {
        let mut t = Table::new("", &["a", "b"]);
        t.row_str(&["only-one"]);
    }

    #[test]
    fn series_csv_format() {
        let csv = series_csv(&["epoch", "acc"], &[vec![1.0, 2.0], vec![0.5, 0.7]]);
        assert_eq!(csv, "epoch,acc\n1,0.5\n2,0.7\n");
    }
}
