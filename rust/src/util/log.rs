//! Tiny leveled stderr logger (the offline image has no `log` /
//! `env_logger`; see DESIGN.md §Substitutions).
//!
//! The level comes from the `PASHA_LOG` environment variable
//! (`error|warn|info|debug`, default `warn`), read once on first use.
//! Every record is emitted with a single locked `writeln!`, so a
//! 1000-connection stress run cannot interleave half-lines on stderr.
//!
//! Every record carries a monotonic elapsed-seconds timestamp (measured
//! from first logger use — wall-clock-free, so log output stays
//! reproducible across runs) and the emitting module path:
//!
//! ```text
//! pasha[warn] +0.412s pasha::service::eventloop: serve: accept error: ...
//! ```
//!
//! `PASHA_LOG_FORMAT=json` switches to one JSON object per line for
//! machine ingestion (same fields: `elapsed_s`, `level`, `target`,
//! `msg`), read once on first use like the level.
//!
//! Use through the crate-root macros, which capture `module_path!()`
//! as the target:
//!
//! ```ignore
//! crate::log_warn!("pasha serve: connection error: {e}");
//! crate::log_debug!("shard {shard}: committed {n} ops");
//! ```

use std::io::Write;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

/// Log severity, ordered from most to least important.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

impl Level {
    /// The lowercase tag printed in the record prefix.
    pub fn as_str(self) -> &'static str {
        match self {
            Level::Error => "error",
            Level::Warn => "warn",
            Level::Info => "info",
            Level::Debug => "debug",
        }
    }

    /// Parse a `PASHA_LOG` value (case-insensitive).
    pub fn parse(s: &str) -> Option<Level> {
        match s.trim().to_ascii_lowercase().as_str() {
            "error" => Some(Level::Error),
            "warn" | "warning" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            _ => None,
        }
    }
}

/// Sentinel meaning "not initialized yet".
const UNSET: usize = usize::MAX;

static LEVEL: AtomicUsize = AtomicUsize::new(UNSET);

fn current_level() -> usize {
    let v = LEVEL.load(Ordering::Relaxed);
    if v != UNSET {
        return v;
    }
    let parsed = std::env::var("PASHA_LOG")
        .ok()
        .and_then(|s| Level::parse(&s))
        .unwrap_or(Level::Warn) as usize;
    LEVEL.store(parsed, Ordering::Relaxed);
    parsed
}

/// Override the level programmatically (tests, embedders). Wins over
/// `PASHA_LOG` from this point on.
pub fn set_level(level: Level) {
    LEVEL.store(level as usize, Ordering::Relaxed);
}

/// Would a record at `level` be emitted right now? Lets callers skip
/// building expensive messages.
pub fn enabled(level: Level) -> bool {
    (level as usize) <= current_level()
}

/// Output shape for records: human text (default) or one JSON object
/// per line (`PASHA_LOG_FORMAT=json`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Format {
    Text = 0,
    Json = 1,
}

static FORMAT: AtomicUsize = AtomicUsize::new(UNSET);

fn current_format() -> Format {
    let v = FORMAT.load(Ordering::Relaxed);
    if v != UNSET {
        return if v == Format::Json as usize {
            Format::Json
        } else {
            Format::Text
        };
    }
    let parsed = match std::env::var("PASHA_LOG_FORMAT") {
        Ok(s) if s.trim().eq_ignore_ascii_case("json") => Format::Json,
        _ => Format::Text,
    };
    FORMAT.store(parsed as usize, Ordering::Relaxed);
    parsed
}

/// Override the output format programmatically. Wins over
/// `PASHA_LOG_FORMAT` from this point on.
pub fn set_format(format: Format) {
    FORMAT.store(format as usize, Ordering::Relaxed);
}

/// Seconds since the logger was first used — a monotonic clock, so
/// records order correctly even if the wall clock steps.
fn elapsed_s() -> f64 {
    static T0: OnceLock<Instant> = OnceLock::new();
    T0.get_or_init(Instant::now).elapsed().as_secs_f64()
}

/// Emit one record. Prefer the `log_*!` macros, which capture
/// `module_path!()` and build the `format_args!` for you.
pub fn write(level: Level, target: &str, args: std::fmt::Arguments<'_>) {
    if !enabled(level) {
        return;
    }
    let elapsed = elapsed_s();
    let stderr = std::io::stderr();
    let mut handle = stderr.lock();
    match current_format() {
        Format::Text => {
            let _ = writeln!(
                handle,
                "pasha[{}] +{elapsed:.3}s {target}: {args}",
                level.as_str()
            );
        }
        Format::Json => {
            // Build through util::json so the message is escaped
            // correctly no matter what it contains.
            let mut rec = crate::util::json::Json::obj();
            rec.set("elapsed_s", (elapsed * 1000.0).round() / 1000.0)
                .set("level", level.as_str())
                .set("target", target)
                .set("msg", args.to_string());
            let _ = writeln!(handle, "{}", rec.to_string_compact());
        }
    }
}

/// Log at `error` level (always emitted unless the writer fails).
#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => {
        $crate::util::log::write(
            $crate::util::log::Level::Error,
            module_path!(),
            format_args!($($arg)*),
        )
    };
}

/// Log at `warn` level (the default threshold).
#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        $crate::util::log::write(
            $crate::util::log::Level::Warn,
            module_path!(),
            format_args!($($arg)*),
        )
    };
}

/// Log at `info` level (`PASHA_LOG=info` or lower).
#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        $crate::util::log::write(
            $crate::util::log::Level::Info,
            module_path!(),
            format_args!($($arg)*),
        )
    };
}

/// Log at `debug` level (`PASHA_LOG=debug`).
#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        $crate::util::log::write(
            $crate::util::log::Level::Debug,
            module_path!(),
            format_args!($($arg)*),
        )
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_levels() {
        assert_eq!(Level::parse("warn"), Some(Level::Warn));
        assert_eq!(Level::parse("WARNING"), Some(Level::Warn));
        assert_eq!(Level::parse(" debug "), Some(Level::Debug));
        assert_eq!(Level::parse("Info"), Some(Level::Info));
        assert_eq!(Level::parse("error"), Some(Level::Error));
        assert_eq!(Level::parse("verbose"), None);
    }

    #[test]
    fn threshold_gates_levels() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        assert!(!enabled(Level::Debug));
        set_level(Level::Debug);
        assert!(enabled(Level::Debug));
        // emitting must not panic regardless of level or format
        write(Level::Debug, module_path!(), format_args!("logger self-test {}", 42));
        set_format(Format::Json);
        write(Level::Debug, module_path!(), format_args!("json \"quoted\" {}", 42));
        set_format(Format::Text);
        set_level(Level::Warn);
    }

    #[test]
    fn elapsed_is_monotonic() {
        let a = super::elapsed_s();
        let b = super::elapsed_s();
        assert!(b >= a);
        assert!(a >= 0.0);
    }

    #[test]
    fn json_record_shape_round_trips() {
        // Mirror the record construction in `write` and confirm the
        // line parses back with every field intact, including a message
        // that needs escaping.
        let mut rec = crate::util::json::Json::obj();
        rec.set("elapsed_s", 1.5)
            .set("level", Level::Warn.as_str())
            .set("target", module_path!())
            .set("msg", "quote \" backslash \\ newline \n done");
        let line = rec.to_string_compact();
        let back = crate::util::json::parse(&line).expect("json log line parses");
        assert_eq!(back.get("level").unwrap().as_str(), Some("warn"));
        assert_eq!(back.get("elapsed_s").unwrap().as_f64(), Some(1.5));
        assert_eq!(
            back.get("msg").unwrap().as_str(),
            Some("quote \" backslash \\ newline \n done")
        );
    }
}
