//! Tiny leveled stderr logger (the offline image has no `log` /
//! `env_logger`; see DESIGN.md §Substitutions).
//!
//! The level comes from the `PASHA_LOG` environment variable
//! (`error|warn|info|debug`, default `warn`), read once on first use.
//! Every record is emitted with a single locked `writeln!`, so a
//! 1000-connection stress run cannot interleave half-lines on stderr.
//!
//! Use through the crate-root macros:
//!
//! ```ignore
//! crate::log_warn!("pasha serve: connection error: {e}");
//! crate::log_debug!("shard {shard}: committed {n} ops");
//! ```

use std::io::Write;
use std::sync::atomic::{AtomicUsize, Ordering};

/// Log severity, ordered from most to least important.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

impl Level {
    /// The lowercase tag printed in the record prefix.
    pub fn as_str(self) -> &'static str {
        match self {
            Level::Error => "error",
            Level::Warn => "warn",
            Level::Info => "info",
            Level::Debug => "debug",
        }
    }

    /// Parse a `PASHA_LOG` value (case-insensitive).
    pub fn parse(s: &str) -> Option<Level> {
        match s.trim().to_ascii_lowercase().as_str() {
            "error" => Some(Level::Error),
            "warn" | "warning" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            _ => None,
        }
    }
}

/// Sentinel meaning "not initialized yet".
const UNSET: usize = usize::MAX;

static LEVEL: AtomicUsize = AtomicUsize::new(UNSET);

fn current_level() -> usize {
    let v = LEVEL.load(Ordering::Relaxed);
    if v != UNSET {
        return v;
    }
    let parsed = std::env::var("PASHA_LOG")
        .ok()
        .and_then(|s| Level::parse(&s))
        .unwrap_or(Level::Warn) as usize;
    LEVEL.store(parsed, Ordering::Relaxed);
    parsed
}

/// Override the level programmatically (tests, embedders). Wins over
/// `PASHA_LOG` from this point on.
pub fn set_level(level: Level) {
    LEVEL.store(level as usize, Ordering::Relaxed);
}

/// Would a record at `level` be emitted right now? Lets callers skip
/// building expensive messages.
pub fn enabled(level: Level) -> bool {
    (level as usize) <= current_level()
}

/// Emit one record. Prefer the `log_*!` macros, which build the
/// `format_args!` for you.
pub fn write(level: Level, args: std::fmt::Arguments<'_>) {
    if !enabled(level) {
        return;
    }
    let stderr = std::io::stderr();
    let mut handle = stderr.lock();
    let _ = writeln!(handle, "pasha[{}] {}", level.as_str(), args);
}

/// Log at `error` level (always emitted unless the writer fails).
#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => {
        $crate::util::log::write($crate::util::log::Level::Error, format_args!($($arg)*))
    };
}

/// Log at `warn` level (the default threshold).
#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        $crate::util::log::write($crate::util::log::Level::Warn, format_args!($($arg)*))
    };
}

/// Log at `info` level (`PASHA_LOG=info` or lower).
#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        $crate::util::log::write($crate::util::log::Level::Info, format_args!($($arg)*))
    };
}

/// Log at `debug` level (`PASHA_LOG=debug`).
#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        $crate::util::log::write($crate::util::log::Level::Debug, format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_levels() {
        assert_eq!(Level::parse("warn"), Some(Level::Warn));
        assert_eq!(Level::parse("WARNING"), Some(Level::Warn));
        assert_eq!(Level::parse(" debug "), Some(Level::Debug));
        assert_eq!(Level::parse("Info"), Some(Level::Info));
        assert_eq!(Level::parse("error"), Some(Level::Error));
        assert_eq!(Level::parse("verbose"), None);
    }

    #[test]
    fn threshold_gates_levels() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        assert!(!enabled(Level::Debug));
        set_level(Level::Debug);
        assert!(enabled(Level::Debug));
        // emitting must not panic regardless of level
        write(Level::Debug, format_args!("logger self-test {}", 42));
        set_level(Level::Warn);
    }
}
