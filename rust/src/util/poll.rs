//! Thin readiness poller over raw `epoll` (no tokio/mio/libc — the
//! syscalls are declared by hand, keeping the dependency-free stance).
//!
//! The service event loop registers non-blocking sockets with a
//! `usize` token and asks "which of these can make progress?" instead
//! of sleeping between accept attempts or burning a 100ms read timeout
//! per connection. On Linux this is level-triggered `epoll`; on other
//! Unix targets a portable fallback reports every registered fd as
//! ready on a short cadence, which is *spuriously ready* but correct:
//! all sockets behind it are non-blocking, so a not-actually-ready fd
//! costs one `WouldBlock` syscall, never a stall.
//!
//! Level-triggered on purpose: handlers may stop short of draining a
//! socket (e.g. backpressure pauses reads) and the next `poll` call
//! re-reports the fd, so no readiness is ever lost.

use std::io;
use std::time::Duration;

/// A readiness report for one registered file descriptor.
#[derive(Clone, Copy, Debug)]
pub struct Event {
    /// The token passed at registration.
    pub token: usize,
    /// Readable, or in an error/hangup state (read to observe it).
    pub readable: bool,
    /// Writable, or in an error/hangup state (write to observe it).
    pub writable: bool,
}

#[cfg(target_os = "linux")]
mod sys {
    use super::Event;
    use std::io;
    use std::os::unix::io::RawFd;
    use std::time::Duration;

    const EPOLL_CLOEXEC: i32 = 0o2000000;
    const EPOLL_CTL_ADD: i32 = 1;
    const EPOLL_CTL_DEL: i32 = 2;
    const EPOLL_CTL_MOD: i32 = 3;
    const EPOLLIN: u32 = 0x001;
    const EPOLLOUT: u32 = 0x004;
    const EPOLLERR: u32 = 0x008;
    const EPOLLHUP: u32 = 0x010;
    const EPOLLRDHUP: u32 = 0x2000;

    /// `struct epoll_event`. Packed on x86-64, where the kernel ABI
    /// lays the 64-bit data field at offset 4.
    #[repr(C)]
    #[cfg_attr(target_arch = "x86_64", repr(packed))]
    #[derive(Clone, Copy)]
    struct EpollEvent {
        events: u32,
        data: u64,
    }

    extern "C" {
        fn epoll_create1(flags: i32) -> i32;
        fn epoll_ctl(epfd: i32, op: i32, fd: i32, event: *mut EpollEvent) -> i32;
        fn epoll_wait(epfd: i32, events: *mut EpollEvent, maxevents: i32, timeout: i32) -> i32;
        fn close(fd: i32) -> i32;
    }

    /// Level-triggered epoll instance.
    pub struct Poller {
        epfd: i32,
        buf: Vec<EpollEvent>,
    }

    impl Poller {
        pub fn new() -> io::Result<Poller> {
            let epfd = unsafe { epoll_create1(EPOLL_CLOEXEC) };
            if epfd < 0 {
                return Err(io::Error::last_os_error());
            }
            Ok(Poller {
                epfd,
                buf: vec![EpollEvent { events: 0, data: 0 }; 1024],
            })
        }

        fn ctl(&self, op: i32, fd: RawFd, token: usize, readable: bool, writable: bool) -> io::Result<()> {
            let mut events = EPOLLRDHUP;
            if readable {
                events |= EPOLLIN;
            }
            if writable {
                events |= EPOLLOUT;
            }
            let mut ev = EpollEvent {
                events,
                data: token as u64,
            };
            let rc = unsafe { epoll_ctl(self.epfd, op, fd, &mut ev) };
            if rc < 0 {
                return Err(io::Error::last_os_error());
            }
            Ok(())
        }

        pub fn register(&self, fd: RawFd, token: usize, readable: bool, writable: bool) -> io::Result<()> {
            self.ctl(EPOLL_CTL_ADD, fd, token, readable, writable)
        }

        pub fn reregister(&self, fd: RawFd, token: usize, readable: bool, writable: bool) -> io::Result<()> {
            self.ctl(EPOLL_CTL_MOD, fd, token, readable, writable)
        }

        pub fn deregister(&self, fd: RawFd) -> io::Result<()> {
            let mut ev = EpollEvent { events: 0, data: 0 };
            let rc = unsafe { epoll_ctl(self.epfd, EPOLL_CTL_DEL, fd, &mut ev) };
            if rc < 0 {
                return Err(io::Error::last_os_error());
            }
            Ok(())
        }

        pub fn poll(&mut self, out: &mut Vec<Event>, timeout: Option<Duration>) -> io::Result<()> {
            out.clear();
            let timeout_ms: i32 = match timeout {
                // round up so a 100µs request does not busy-spin at 0ms
                Some(d) => d.as_millis().max(1).min(i32::MAX as u128) as i32,
                None => -1,
            };
            let n = loop {
                let rc = unsafe {
                    epoll_wait(self.epfd, self.buf.as_mut_ptr(), self.buf.len() as i32, timeout_ms)
                };
                if rc >= 0 {
                    break rc as usize;
                }
                let err = io::Error::last_os_error();
                if err.kind() != io::ErrorKind::Interrupted {
                    return Err(err);
                }
            };
            for i in 0..n {
                // copy out of the (possibly packed) struct before use
                let ev = self.buf[i];
                let bits = ev.events;
                let hup = bits & (EPOLLERR | EPOLLHUP | EPOLLRDHUP) != 0;
                out.push(Event {
                    token: ev.data as usize,
                    readable: bits & EPOLLIN != 0 || hup,
                    writable: bits & EPOLLOUT != 0 || hup,
                });
            }
            Ok(())
        }
    }

    impl Drop for Poller {
        fn drop(&mut self) {
            unsafe {
                close(self.epfd);
            }
        }
    }
}

#[cfg(not(target_os = "linux"))]
mod sys {
    use super::Event;
    use std::io;
    use std::os::unix::io::RawFd;
    use std::time::Duration;

    struct Entry {
        fd: RawFd,
        token: usize,
        readable: bool,
        writable: bool,
    }

    /// Portable fallback: every registered fd is reported ready (per
    /// its interest set) after a short sleep. Spurious readiness is
    /// harmless with non-blocking sockets; real readiness is never
    /// missed. Interior mutability keeps the API identical to the
    /// epoll build (`register` on `&self`).
    pub struct Poller {
        entries: std::sync::Mutex<Vec<Entry>>,
    }

    impl Poller {
        pub fn new() -> io::Result<Poller> {
            Ok(Poller {
                entries: std::sync::Mutex::new(Vec::new()),
            })
        }

        pub fn register(&self, fd: RawFd, token: usize, readable: bool, writable: bool) -> io::Result<()> {
            let mut entries = self.entries.lock().expect("poller lock");
            if entries.iter().any(|e| e.fd == fd) {
                return Err(io::Error::new(io::ErrorKind::AlreadyExists, "fd already registered"));
            }
            entries.push(Entry { fd, token, readable, writable });
            Ok(())
        }

        pub fn reregister(&self, fd: RawFd, token: usize, readable: bool, writable: bool) -> io::Result<()> {
            let mut entries = self.entries.lock().expect("poller lock");
            for e in entries.iter_mut() {
                if e.fd == fd {
                    e.token = token;
                    e.readable = readable;
                    e.writable = writable;
                    return Ok(());
                }
            }
            Err(io::Error::new(io::ErrorKind::NotFound, "fd not registered"))
        }

        pub fn deregister(&self, fd: RawFd) -> io::Result<()> {
            let mut entries = self.entries.lock().expect("poller lock");
            let before = entries.len();
            entries.retain(|e| e.fd != fd);
            if entries.len() == before {
                return Err(io::Error::new(io::ErrorKind::NotFound, "fd not registered"));
            }
            Ok(())
        }

        pub fn poll(&mut self, out: &mut Vec<Event>, timeout: Option<Duration>) -> io::Result<()> {
            out.clear();
            let nap = timeout.unwrap_or(Duration::from_millis(1)).min(Duration::from_millis(1));
            std::thread::sleep(nap);
            let entries = self.entries.lock().expect("poller lock");
            for e in entries.iter() {
                if e.readable || e.writable {
                    out.push(Event {
                        token: e.token,
                        readable: e.readable,
                        writable: e.writable,
                    });
                }
            }
            Ok(())
        }
    }
}

pub use sys::Poller;

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;
    use std::net::{TcpListener, TcpStream};
    use std::os::unix::io::AsRawFd;
    use std::time::Instant;

    fn wait_for(
        poller: &mut Poller,
        pred: impl Fn(&Event) -> bool,
        what: &str,
    ) -> Event {
        let deadline = Instant::now() + Duration::from_secs(5);
        let mut events = Vec::new();
        while Instant::now() < deadline {
            poller
                .poll(&mut events, Some(Duration::from_millis(10)))
                .expect("poll");
            if let Some(ev) = events.iter().find(|e| pred(e)) {
                return *ev;
            }
        }
        panic!("timed out waiting for {what}");
    }

    #[test]
    fn reports_readable_after_peer_writes() {
        let listener = TcpListener::bind("127.0.0.1:0").expect("bind");
        let addr = listener.local_addr().expect("addr");
        let mut client = TcpStream::connect(addr).expect("connect");
        let (server, _) = listener.accept().expect("accept");
        server.set_nonblocking(true).expect("nonblocking");

        let mut poller = Poller::new().expect("poller");
        poller
            .register(server.as_raw_fd(), 7, true, false)
            .expect("register");

        client.write_all(b"hello\n").expect("write");
        let ev = wait_for(&mut poller, |e| e.token == 7 && e.readable, "readable event");
        assert!(ev.readable);

        poller.deregister(server.as_raw_fd()).expect("deregister");
    }

    #[test]
    fn reregister_switches_interest_to_writable() {
        let listener = TcpListener::bind("127.0.0.1:0").expect("bind");
        let addr = listener.local_addr().expect("addr");
        let _client = TcpStream::connect(addr).expect("connect");
        let (server, _) = listener.accept().expect("accept");
        server.set_nonblocking(true).expect("nonblocking");

        let mut poller = Poller::new().expect("poller");
        poller
            .register(server.as_raw_fd(), 3, true, false)
            .expect("register");
        poller
            .reregister(server.as_raw_fd(), 3, false, true)
            .expect("reregister");
        // an idle healthy socket is immediately writable
        let ev = wait_for(&mut poller, |e| e.token == 3 && e.writable, "writable event");
        assert!(ev.writable);
    }

    #[test]
    fn listener_readable_on_pending_accept() {
        let listener = TcpListener::bind("127.0.0.1:0").expect("bind");
        listener.set_nonblocking(true).expect("nonblocking");
        let addr = listener.local_addr().expect("addr");

        let mut poller = Poller::new().expect("poller");
        poller
            .register(listener.as_raw_fd(), 0, true, false)
            .expect("register");

        let _client = TcpStream::connect(addr).expect("connect");
        let ev = wait_for(&mut poller, |e| e.token == 0 && e.readable, "accept readiness");
        assert!(ev.readable);
        let (conn, _) = listener.accept().expect("accept");
        drop(conn);
    }
}
