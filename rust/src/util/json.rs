//! Minimal JSON support (the image has no `serde`): a writer with proper
//! string escaping plus a small recursive-descent parser, used for result
//! files, the artifact manifest, and experiment configuration.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are kept sorted (BTreeMap) so output is
/// deterministic and diffs are stable.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn set(&mut self, key: &str, value: impl Into<Json>) -> &mut Self {
        if let Json::Obj(m) = self {
            m.insert(key.to_string(), value.into());
        } else {
            panic!("Json::set on non-object");
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Serialize compactly.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Serialize with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.is_finite() {
                    if n.fract() == 0.0 && n.abs() < 1e15 {
                        out.push_str(&format!("{}", *n as i64));
                    } else {
                        out.push_str(&format!("{}", n));
                    }
                } else {
                    out.push_str("null"); // JSON has no NaN/Inf
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !a.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !m.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<f64> for Json {
    fn from(v: f64) -> Json {
        Json::Num(v)
    }
}
impl From<i64> for Json {
    fn from(v: i64) -> Json {
        Json::Num(v as f64)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Json {
        Json::Num(v as f64)
    }
}
impl From<u32> for Json {
    fn from(v: u32) -> Json {
        Json::Num(v as f64)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Json {
        Json::Bool(v)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Json {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Json {
        Json::Str(v)
    }
}
impl From<Vec<f64>> for Json {
    fn from(v: Vec<f64>) -> Json {
        Json::Arr(v.into_iter().map(Json::Num).collect())
    }
}
impl From<Vec<Json>> for Json {
    fn from(v: Vec<Json>) -> Json {
        Json::Arr(v)
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string_compact())
    }
}

/// Parse a JSON document. Returns an error message with byte offset on
/// malformed input.
pub fn parse(input: &str) -> Result<Json, String> {
    let bytes = input.as_bytes();
    let mut p = Parser { b: bytes, i: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.i != bytes.len() {
        return Err(format!("trailing data at byte {}", p.i));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!(
                "expected '{}' at byte {}, found {:?}",
                c as char,
                self.i,
                self.peek().map(|b| b as char)
            ))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other.map(|b| b as char), self.i)),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-') {
                self.i += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {}", start))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| "bad \\u escape")?;
                            let code =
                                u32::from_str_radix(hex, 16).map_err(|_| "bad \\u escape")?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        other => return Err(format!("bad escape {:?}", other)),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // advance over one UTF-8 char
                    let rest = &self.b[self.i..];
                    let ch_len = utf8_len(rest[0]);
                    let chunk = std::str::from_utf8(&rest[..ch_len.min(rest.len())])
                        .map_err(|_| "bad utf8")?;
                    s.push_str(chunk);
                    self.i += ch_len;
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                other => return Err(format!("expected ',' or ']', got {:?}", other)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                other => return Err(format!("expected ',' or '}}', got {:?}", other)),
            }
        }
    }
}

fn utf8_len(b: u8) -> usize {
    if b < 0x80 {
        1
    } else if b >> 5 == 0b110 {
        2
    } else if b >> 4 == 0b1110 {
        3
    } else {
        4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_object() {
        let mut j = Json::obj();
        j.set("name", "pasha").set("eta", 3i64).set("ok", true);
        let s = j.to_string_compact();
        assert_eq!(parse(&s).unwrap(), j);
    }

    #[test]
    fn roundtrip_nested() {
        let src = r#"{"a":[1,2.5,null,{"b":"x\"y"}],"c":false}"#;
        let v = parse(src).unwrap();
        let re = parse(&v.to_string_compact()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn escapes() {
        let v = Json::Str("line\nquote\"tab\t".into());
        let s = v.to_string_compact();
        assert_eq!(s, "\"line\\nquote\\\"tab\\t\"");
        assert_eq!(parse(&s).unwrap(), v);
    }

    #[test]
    fn unicode_passthrough() {
        let v = parse("\"héllo ✓\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo ✓");
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(parse(r#""A""#).unwrap().as_str().unwrap(), "A");
    }

    #[test]
    fn numbers() {
        assert_eq!(parse("-3.5e2").unwrap().as_f64().unwrap(), -350.0);
        assert_eq!(parse("0").unwrap().as_f64().unwrap(), 0.0);
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(parse("{} x").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{\"a\"}").is_err());
    }

    #[test]
    fn pretty_is_reparseable() {
        let mut j = Json::obj();
        j.set("arr", vec![Json::Num(1.0), Json::Str("two".into())]);
        assert_eq!(parse(&j.to_string_pretty()).unwrap(), j);
    }

    #[test]
    fn nan_becomes_null() {
        assert_eq!(Json::Num(f64::NAN).to_string_compact(), "null");
    }

    #[test]
    fn getters() {
        let v = parse(r#"{"x": 1, "s": "a", "b": true, "a": [1]}"#).unwrap();
        assert_eq!(v.get("x").unwrap().as_f64(), Some(1.0));
        assert_eq!(v.get("s").unwrap().as_str(), Some("a"));
        assert_eq!(v.get("b").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 1);
        assert!(v.get("missing").is_none());
    }
}
