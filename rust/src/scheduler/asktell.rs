//! Ask/tell adapter: drive any [`Scheduler`] + [`Searcher`] by *pull*.
//!
//! The event-driven engine ([`crate::executor::engine`]) owns the driver
//! loop: it decides when to call `next_job` and pushes results at the
//! scheduler. The service layer ([`crate::service`]) inverts that control
//! flow — external workers poll for work and report results whenever they
//! have them — without consuming the engine or duplicating scheduler
//! logic:
//!
//! * [`AskTell::ask`] — hand the polling worker a [`TrialAssignment`]: a
//!   training [`Job`], a pending Stop/Pause directive for a trial that
//!   worker is running, `Wait` (poll again) or `Done` (session drained).
//! * [`AskTell::tell`] — absorb one per-epoch observation. Epochs are
//!   buffered until the job's milestone, then committed as a single
//!   [`JobOutcome`] — exactly the engine's delivery granularity, so a
//!   session driven by one worker reproduces `run_engine` byte for byte.
//! * Stop/Pause decisions ([`TrialAction`]) against in-flight trials mark
//!   the job discarded: its buffered epochs are dropped, the scheduler's
//!   dispatch frontier is rewound ([`Scheduler::on_cancelled`]), and the
//!   worker learns on its next `tell` (ack [`TellAck::Abandon`]) or `ask`
//!   (a `Stop`/`Pause` assignment) — the pull-model equivalent of backend
//!   cancellation.
//!
//! Everything here is deterministic: given the same construction seeds
//! and the same sequence of `ask`/`tell`/`fail` calls, the adapter
//! traverses the same states and returns the same answers. The service
//! journal relies on this to recover crashed sessions by replay.

use crate::config::space::{Config, SearchSpace};
use crate::executor::engine::{EngineSnapshot, StoppingRule};
use crate::scheduler::state::{
    action_from, action_json, curve_from, curve_json, field, job_from, job_json, trial_ids_from,
    trial_set_json, u64_from, u64_json, usize_field,
};
use crate::scheduler::{BestTrial, Job, JobOutcome, SchedCtx, Scheduler, TrialAction, TrialInfo};
use crate::searcher::Searcher;
use crate::util::json::Json;
use crate::TrialId;
use std::collections::{HashMap, HashSet, VecDeque};
use std::sync::Arc;

/// What `ask` hands a polling worker.
#[derive(Clone, Debug, PartialEq)]
pub enum TrialAssignment {
    /// Train `job.config` from `job.from_epoch` to `job.milestone`,
    /// telling each epoch's metric as it is observed.
    Run(Job),
    /// The trial this worker was running has been terminated: abandon it.
    Stop(TrialId),
    /// The trial this worker was running has been suspended (resumable
    /// later, possibly on another worker): abandon it.
    Pause(TrialId),
    /// Nothing to run right now, but in-flight work may unlock more.
    Wait,
    /// The session is complete: budget drained and nothing in flight.
    Done,
}

impl TrialAssignment {
    /// Whether handing out this assignment itself mutated adapter state.
    /// `Wait`/`Done` answers are usually pure reads — but an `ask` can
    /// park a scheduler-emitted resume and still answer `Wait`, so the
    /// journal layer additionally compares [`AskTell::mutation_count`]
    /// across the call rather than trusting this alone.
    pub fn is_mutation(&self) -> bool {
        !matches!(self, TrialAssignment::Wait | TrialAssignment::Done)
    }
}

/// Acknowledgement of one `tell`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TellAck {
    /// Observation recorded; keep training toward the milestone.
    Continue,
    /// The milestone was reached and the job committed; ask for new work.
    JobComplete,
    /// The job was cancelled (trial stopped/paused/failed meanwhile):
    /// drop it and ask for new work. The told epoch was discarded.
    Abandon,
}

impl TellAck {
    pub fn as_str(&self) -> &'static str {
        match self {
            TellAck::Continue => "continue",
            TellAck::JobComplete => "job-complete",
            TellAck::Abandon => "abandon",
        }
    }

    pub fn parse(s: &str) -> Option<TellAck> {
        match s {
            "continue" => Some(TellAck::Continue),
            "job-complete" => Some(TellAck::JobComplete),
            "abandon" => Some(TellAck::Abandon),
            _ => None,
        }
    }
}

/// One assigned job awaiting epoch reports.
struct InFlight {
    worker: String,
    job: Job,
    /// Metrics for epochs `from_epoch+1 ..= from_epoch+curve.len()`.
    curve: Vec<f64>,
    /// Cancelled by a scheduler decision or worker failure: buffered
    /// epochs are dropped and the next tell retires the job.
    discarded: bool,
}

/// Per-session scheduler telemetry ([`crate::obs`]): ask/tell counters
/// plus gauges refreshed from scheduler state after every mutation —
/// including `pasha_max_resource_epochs`, the live view of PASHA's
/// progressive resource cap (grows on ranking instability, flat for
/// ASHA). Observe-only: never consulted for decisions, never part of
/// snapshots, so attaching it cannot perturb replay determinism.
struct SchedObs {
    asks: Arc<crate::obs::Counter>,
    tells: Arc<crate::obs::Counter>,
    stops: Arc<crate::obs::Gauge>,
    pauses: Arc<crate::obs::Gauge>,
    promotions: Arc<crate::obs::Gauge>,
    cap_epochs: Arc<crate::obs::Gauge>,
    max_used: Arc<crate::obs::Gauge>,
    in_flight: Arc<crate::obs::Gauge>,
}

/// Aggregate progress counters mirroring [`crate::executor::EngineStats`]
/// for the pull-driven path.
#[derive(Clone, Debug, Default)]
pub struct AskTellStats {
    pub cancelled_jobs: usize,
    pub failed_jobs: usize,
    pub stopped_trials: usize,
    pub paused_trials: usize,
}

/// The pull-driven counterpart of `run_engine`: same scheduler protocol
/// (`next_job` / `on_result` / `drain_actions` / `on_cancelled`), same
/// stopping-rule composition, but workers call in instead of the loop
/// calling out.
pub struct AskTell {
    scheduler: Box<dyn Scheduler>,
    searcher: Box<dyn Searcher>,
    space: SearchSpace,
    rules: Vec<Box<dyn StoppingRule>>,
    snap: EngineSnapshot,
    in_flight: HashMap<TrialId, InFlight>,
    /// Jobs emitted by the scheduler for trials whose discarded job has
    /// not retired yet (same parking rule as the engine's deferred
    /// cancellation path).
    parked: Vec<Job>,
    /// Stop/Pause notices awaiting delivery to the worker that holds (or
    /// held) the affected trial.
    directives: VecDeque<(String, TrialAction)>,
    stopped: HashSet<TrialId>,
    paused: HashSet<TrialId>,
    stats: AskTellStats,
    /// Bumped on every state change inside `ask` (dispatch *or* parking a
    /// scheduler-emitted resume). The journal layer compares it across a
    /// call to decide whether the ask must be logged — a `Wait` answer
    /// that parked a job still mutated the scheduler's frontier and must
    /// replay, or recovery would diverge.
    mutations: u64,
    /// Telemetry instruments, attached by the service session layer.
    obs: Option<SchedObs>,
}

impl AskTell {
    pub fn new(
        scheduler: Box<dyn Scheduler>,
        searcher: Box<dyn Searcher>,
        space: SearchSpace,
        rules: Vec<Box<dyn StoppingRule>>,
    ) -> Self {
        AskTell {
            scheduler,
            searcher,
            space,
            rules,
            snap: EngineSnapshot::default(),
            in_flight: HashMap::new(),
            parked: Vec::new(),
            directives: VecDeque::new(),
            stopped: HashSet::new(),
            paused: HashSet::new(),
            stats: AskTellStats::default(),
            mutations: 0,
            obs: None,
        }
    }

    /// Monotonic count of state mutations performed by `ask` calls.
    pub fn mutation_count(&self) -> u64 {
        self.mutations
    }

    /// Register this adapter's telemetry under `session=<id>` labels and
    /// publish the initial gauge values. Idempotent per label set (the
    /// registry hands back the same instruments), so recovery re-attaches
    /// to the counters the pre-crash incarnation was bumping.
    pub fn attach_obs(&mut self, session: &str) {
        let l: &[(&str, &str)] = &[("session", session)];
        self.obs = Some(SchedObs {
            asks: crate::obs::counter("pasha_sched_asks_total", l),
            tells: crate::obs::counter("pasha_sched_tells_total", l),
            stops: crate::obs::gauge("pasha_sched_stopped_trials", l),
            pauses: crate::obs::gauge("pasha_sched_paused_trials", l),
            promotions: crate::obs::gauge("pasha_sched_promotions", l),
            cap_epochs: crate::obs::gauge("pasha_max_resource_epochs", l),
            max_used: crate::obs::gauge("pasha_sched_max_resources_used_epochs", l),
            in_flight: crate::obs::gauge("pasha_sched_inflight_jobs", l),
        });
        self.refresh_obs();
    }

    /// Re-derive every gauge from current scheduler state. Read-only.
    fn refresh_obs(&self) {
        let Some(o) = &self.obs else { return };
        o.stops.set(self.stats.stopped_trials as i64);
        o.pauses.set(self.stats.paused_trials as i64);
        let promotions: usize = self
            .scheduler
            .trials()
            .iter()
            .map(|t| t.top_rung.unwrap_or(0))
            .sum();
        o.promotions.set(promotions as i64);
        if let Some(cap) = self.scheduler.resource_cap() {
            o.cap_epochs.set(cap as i64);
        }
        o.max_used.set(self.scheduler.max_resources_used() as i64);
        o.in_flight.set(self.in_flight.len() as i64);
    }

    /// Request work on behalf of `worker`. Mirrors the engine's dispatch
    /// phase: pending directives first, then parked (already-emitted)
    /// jobs whose predecessor retired, then the scheduler under the
    /// stopping rules' draw allowance.
    pub fn ask(&mut self, worker: &str) -> TrialAssignment {
        let assignment = self.ask_inner(worker);
        if let Some(o) = &self.obs {
            o.asks.inc();
            self.refresh_obs();
        }
        assignment
    }

    fn ask_inner(&mut self, worker: &str) -> TrialAssignment {
        if let Some(pos) = self.directives.iter().position(|(w, _)| w.as_str() == worker) {
            let (_, action) = self
                .directives
                .remove(pos)
                .expect("position came from the same queue");
            return match action {
                TrialAction::Stop(t) => TrialAssignment::Stop(t),
                TrialAction::Pause(t) => TrialAssignment::Pause(t),
            };
        }
        loop {
            // Parked jobs were already emitted by the scheduler, so they
            // dispatch even once the rules say "drain" (engine parity).
            if let Some(i) = self
                .parked
                .iter()
                .position(|j| !self.in_flight.contains_key(&j.trial))
            {
                let job = self.parked.remove(i);
                return self.dispatch(worker, job);
            }
            if self
                .rules
                .iter()
                .any(|r| r.should_drain(&self.snap) || r.should_halt(&self.snap))
            {
                return self.idle_assignment();
            }
            let draws = self
                .rules
                .iter()
                .filter_map(|r| r.draw_allowance(&self.snap))
                .min()
                .unwrap_or(usize::MAX);
            let mut ctx = SchedCtx {
                space: &self.space,
                searcher: self.searcher.as_mut(),
                configs_sampled: self.snap.configs_sampled,
                draws_remaining: draws,
            };
            let job = self.scheduler.next_job(&mut ctx);
            self.snap.configs_sampled = ctx.configs_sampled;
            match job {
                None => return self.idle_assignment(),
                Some(job) if self.in_flight.contains_key(&job.trial) => {
                    // A resume for a trial whose cancelled job has not
                    // retired: park it and ask the scheduler again. The
                    // scheduler's frontier advanced, so this counts as a
                    // mutation even if the call ends up answering Wait.
                    self.mutations += 1;
                    self.parked.push(job);
                }
                Some(job) => return self.dispatch(worker, job),
            }
        }
    }

    fn dispatch(&mut self, worker: &str, job: Job) -> TrialAssignment {
        self.mutations += 1;
        self.snap.jobs_dispatched += 1;
        self.snap.epochs_dispatched += (job.milestone - job.from_epoch) as u64;
        self.in_flight.insert(
            job.trial,
            InFlight {
                worker: worker.to_string(),
                job: job.clone(),
                curve: Vec::new(),
                discarded: false,
            },
        );
        TrialAssignment::Run(job)
    }

    fn idle_assignment(&self) -> TrialAssignment {
        if self.in_flight.is_empty() && self.parked.is_empty() {
            TrialAssignment::Done
        } else {
            TrialAssignment::Wait
        }
    }

    /// Report the metric observed after training `trial` to `epoch`
    /// (1-based, consecutive within the assigned job). Observations are
    /// buffered until the milestone, then committed as one [`JobOutcome`].
    ///
    /// Errors (unknown trial, out-of-order epoch) never mutate state, so
    /// a failed tell is a no-op for journal replay too.
    pub fn tell(&mut self, trial: TrialId, epoch: u32, metric: f64) -> Result<TellAck, String> {
        let ack = self.tell_inner(trial, epoch, metric);
        if let Some(o) = &self.obs {
            o.tells.inc();
            self.refresh_obs();
        }
        ack
    }

    fn tell_inner(&mut self, trial: TrialId, epoch: u32, metric: f64) -> Result<TellAck, String> {
        {
            let fl = match self.in_flight.get_mut(&trial) {
                Some(fl) => fl,
                None => return Err(format!("trial {trial} has no job in flight")),
            };
            if fl.discarded {
                // The cancelled job retires here: buffered epochs are
                // dropped and any parked resume becomes dispatchable.
                self.in_flight.remove(&trial);
                return Ok(TellAck::Abandon);
            }
            let expect = fl.job.from_epoch + fl.curve.len() as u32 + 1;
            if epoch != expect {
                return Err(format!(
                    "out-of-order tell for trial {trial}: epoch {epoch}, expected {expect}"
                ));
            }
            fl.curve.push(metric);
            if epoch < fl.job.milestone {
                return Ok(TellAck::Continue);
            }
        }
        // Milestone reached: commit the job, engine-style (searcher sees
        // the result first, then the scheduler, then its decisions).
        let fl = self
            .in_flight
            .remove(&trial)
            .expect("checked in flight above");
        let outcome = JobOutcome {
            trial,
            rung: fl.job.rung,
            milestone: fl.job.milestone,
            metric,
            curve_segment: fl.curve,
        };
        self.snap.jobs_completed += 1;
        self.snap.epochs_completed += outcome.curve_segment.len() as u64;
        self.searcher
            .on_report(&fl.job.config, outcome.milestone, outcome.metric);
        self.scheduler.on_result(&outcome);
        for action in self.scheduler.drain_actions() {
            let t = action.trial();
            match action {
                TrialAction::Stop(_) => {
                    self.stopped.insert(t);
                    self.stats.stopped_trials = self.stopped.len();
                    // A parked resume must die with the trial.
                    self.parked.retain(|j| j.trial != t);
                }
                TrialAction::Pause(_) => {
                    self.paused.insert(t);
                    self.stats.paused_trials = self.paused.len();
                }
            }
            if let Some(infl) = self.in_flight.get_mut(&t) {
                if !infl.discarded {
                    infl.discarded = true;
                    self.stats.cancelled_jobs += 1;
                    self.directives.push_back((infl.worker.clone(), action));
                    // The discarded job's epochs were never trained.
                    self.scheduler.on_cancelled(t);
                }
            }
        }
        Ok(TellAck::JobComplete)
    }

    /// A worker failed while running `trial` (crash, panic, lost
    /// connection): the exact job is re-queued and handed to the next
    /// asking worker. The scheduler's bookkeeping is untouched — it
    /// already counts the job as dispatched, and the retry completes it
    /// as if nothing happened. (A job whose trial was meanwhile
    /// stopped/paused was already rewound when it was cancelled and is
    /// not re-queued.) A config that reliably kills workers will loop;
    /// that is the operator's cue to `close` the session.
    pub fn fail(&mut self, trial: TrialId) -> Result<(), String> {
        let r = match self.in_flight.remove(&trial) {
            None => Err(format!("trial {trial} has no job in flight")),
            Some(fl) => {
                self.stats.failed_jobs += 1;
                if !fl.discarded {
                    self.parked.push(fl.job);
                }
                Ok(())
            }
        };
        self.refresh_obs();
        r
    }

    /// Re-queue every in-flight job — used after a server restart when
    /// the previously-connected workers are known to be gone. Pending
    /// directives for dead workers are dropped. Trials are processed in
    /// id order so the resulting queue (and therefore the post-expire
    /// `ask` stream) is deterministic — journal replay depends on it.
    pub fn expire_workers(&mut self) -> usize {
        let mut trials: Vec<TrialId> = self.in_flight.keys().copied().collect();
        trials.sort_unstable();
        let n = trials.len();
        for t in trials {
            let _ = self.fail(t);
        }
        self.directives.clear();
        n
    }

    /// Re-queue the jobs held by one crashed worker, leaving every other
    /// worker's leases intact — the per-shard lease-expiry tick uses this
    /// so a single dead worker cannot stall the session. Trials are
    /// processed in id order for the same determinism reason as
    /// [`AskTell::expire_workers`]; the worker's pending directives are
    /// dropped (it will never poll again to receive them).
    pub fn expire_worker(&mut self, worker: &str) -> usize {
        let mut trials: Vec<TrialId> = self
            .in_flight
            .iter()
            .filter(|(_, fl)| fl.worker == worker)
            .map(|(t, _)| *t)
            .collect();
        trials.sort_unstable();
        let n = trials.len();
        for t in trials {
            let _ = self.fail(t);
        }
        self.directives.retain(|(w, _)| w != worker);
        self.refresh_obs();
        n
    }

    /// The worker holding `trial`'s live job, if any.
    pub fn worker_of(&self, trial: TrialId) -> Option<&str> {
        self.in_flight.get(&trial).map(|fl| fl.worker.as_str())
    }

    /// Does `worker` hold any in-flight job or undelivered directive?
    /// (An idle polling worker holds nothing — expiring it would be a
    /// journaled no-op, so the expiry tick skips it.)
    pub fn worker_busy(&self, worker: &str) -> bool {
        self.in_flight.values().any(|fl| fl.worker == worker)
            || self.directives.iter().any(|(w, _)| w == worker)
    }

    /// The session is drained: nothing in flight, nothing the scheduler
    /// can launch. (A `Wait` answer from `ask` does not count as done.)
    pub fn is_done(&self) -> bool {
        // Cheap pre-check: anything in flight means not done.
        if !self.in_flight.is_empty() || !self.parked.is_empty() || !self.directives.is_empty() {
            return false;
        }
        // Probing the scheduler would mutate it; rely on rules instead:
        // drained rules + empty in-flight is the engine's exit condition.
        self.rules
            .iter()
            .any(|r| r.should_drain(&self.snap) || r.should_halt(&self.snap))
            || self.no_draws_left()
    }

    fn no_draws_left(&self) -> bool {
        self.rules
            .iter()
            .filter_map(|r| r.draw_allowance(&self.snap))
            .min()
            .map(|d| d == 0)
            .unwrap_or(false)
    }

    pub fn snapshot(&self) -> EngineSnapshot {
        self.snap.clone()
    }

    pub fn stats(&self) -> &AskTellStats {
        &self.stats
    }

    pub fn best(&self) -> Option<BestTrial> {
        self.scheduler.best()
    }

    pub fn max_resources_used(&self) -> u32 {
        self.scheduler.max_resources_used()
    }

    pub fn trials(&self) -> &[TrialInfo] {
        self.scheduler.trials()
    }

    pub fn scheduler_name(&self) -> String {
        self.scheduler.name()
    }

    pub fn space(&self) -> &SearchSpace {
        &self.space
    }

    /// Trials with a live (non-discarded) job assigned right now.
    pub fn in_flight_count(&self) -> usize {
        self.in_flight.values().filter(|f| !f.discarded).count()
    }

    /// Serialize the adapter's full state — progress counters, in-flight
    /// jobs with their buffered curves, parked resumes, pending
    /// directives, and the nested scheduler/searcher states — as one JSON
    /// value ([`crate::scheduler::state`] codecs). Returns `None` when
    /// the scheduler or searcher does not support snapshots; the service
    /// then falls back to full journal replay.
    pub fn save_state(&self) -> Option<Json> {
        let scheduler = self.scheduler.save_state()?;
        let searcher = self.searcher.save_state()?;
        let mut snap = Json::obj();
        snap.set("configs_sampled", self.snap.configs_sampled)
            .set("jobs_dispatched", self.snap.jobs_dispatched)
            .set("jobs_completed", self.snap.jobs_completed)
            .set("epochs_dispatched", u64_json(self.snap.epochs_dispatched))
            .set("epochs_completed", u64_json(self.snap.epochs_completed));
        // in-flight entries sorted by trial id for deterministic bytes;
        // restoring into a HashMap is safe because no decision path
        // iterates the map in hash order (expire sorts, parked scans a Vec)
        let mut trials: Vec<&TrialId> = self.in_flight.keys().collect();
        trials.sort_unstable();
        let in_flight: Vec<Json> = trials
            .into_iter()
            .map(|t| {
                let fl = &self.in_flight[t];
                let mut o = Json::obj();
                o.set("worker", fl.worker.as_str())
                    .set("job", job_json(&fl.job))
                    .set("curve", curve_json(&fl.curve))
                    .set("discarded", fl.discarded);
                o
            })
            .collect();
        let directives: Vec<Json> = self
            .directives
            .iter()
            .map(|(w, a)| {
                let mut o = Json::obj();
                o.set("worker", w.as_str()).set("action", action_json(a));
                o
            })
            .collect();
        let mut stats = Json::obj();
        stats
            .set("cancelled_jobs", self.stats.cancelled_jobs)
            .set("failed_jobs", self.stats.failed_jobs)
            .set("stopped_trials", self.stats.stopped_trials)
            .set("paused_trials", self.stats.paused_trials);
        let mut o = Json::obj();
        o.set("snap", snap)
            .set("in_flight", Json::Arr(in_flight))
            .set("parked", Json::Arr(self.parked.iter().map(job_json).collect()))
            .set("directives", Json::Arr(directives))
            .set("stopped", trial_set_json(&self.stopped))
            .set("paused", trial_set_json(&self.paused))
            .set("stats", stats)
            .set("mutations", u64_json(self.mutations))
            .set("scheduler", scheduler)
            .set("searcher", searcher);
        Some(o)
    }

    /// Restore [`AskTell::save_state`] output into this freshly-built
    /// adapter (same construction recipe: scheduler builder, searcher
    /// kind, space, rules). The continuation is byte-identical to the
    /// adapter that was snapshotted.
    pub fn load_state(&mut self, state: &Json) -> Result<(), String> {
        self.scheduler.load_state(field(state, "scheduler")?)?;
        self.searcher.load_state(field(state, "searcher")?)?;
        let snap = field(state, "snap")?;
        self.snap = EngineSnapshot {
            configs_sampled: usize_field(snap, "configs_sampled")?,
            jobs_dispatched: usize_field(snap, "jobs_dispatched")?,
            jobs_completed: usize_field(snap, "jobs_completed")?,
            epochs_dispatched: u64_from(field(snap, "epochs_dispatched")?)?,
            epochs_completed: u64_from(field(snap, "epochs_completed")?)?,
            clock_seconds: 0.0,
        };
        self.in_flight.clear();
        for e in field(state, "in_flight")?
            .as_arr()
            .ok_or("in_flight must be an array")?
        {
            let fl = InFlight {
                worker: field(e, "worker")?
                    .as_str()
                    .ok_or("worker must be a string")?
                    .to_string(),
                job: job_from(field(e, "job")?)?,
                curve: curve_from(field(e, "curve")?)?,
                discarded: field(e, "discarded")?
                    .as_bool()
                    .ok_or("discarded must be a bool")?,
            };
            self.in_flight.insert(fl.job.trial, fl);
        }
        self.parked = field(state, "parked")?
            .as_arr()
            .ok_or("parked must be an array")?
            .iter()
            .map(job_from)
            .collect::<Result<_, _>>()?;
        self.directives.clear();
        for d in field(state, "directives")?
            .as_arr()
            .ok_or("directives must be an array")?
        {
            let worker = field(d, "worker")?
                .as_str()
                .ok_or("worker must be a string")?
                .to_string();
            self.directives.push_back((worker, action_from(field(d, "action")?)?));
        }
        self.stopped = trial_ids_from(field(state, "stopped")?)?.into_iter().collect();
        self.paused = trial_ids_from(field(state, "paused")?)?.into_iter().collect();
        let stats = field(state, "stats")?;
        self.stats = AskTellStats {
            cancelled_jobs: usize_field(stats, "cancelled_jobs")?,
            failed_jobs: usize_field(stats, "failed_jobs")?,
            stopped_trials: usize_field(stats, "stopped_trials")?,
            paused_trials: usize_field(stats, "paused_trials")?,
        };
        self.mutations = u64_from(field(state, "mutations")?)?;
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Wire format: the canonical JSON encoding of assignments and acks, shared
// by the journal, the TCP server and the loopback client. Object keys are
// BTreeMap-sorted, so `assignment_json(..).to_string_compact()` is a
// canonical byte string — what the journal-recovery property compares.
// ---------------------------------------------------------------------------

/// Encode a configuration as a JSON array of numbers (categorical/int
/// values as integers, floats via Rust's shortest-roundtrip formatting).
pub fn config_json(c: &Config) -> Json {
    use crate::config::space::ParamValue;
    Json::Arr(
        c.values
            .iter()
            .map(|v| match v {
                ParamValue::Float(x) => Json::Num(*x),
                ParamValue::Int(x) => Json::Num(*x as f64),
                ParamValue::Cat(x) => Json::Num(*x as f64),
            })
            .collect(),
    )
}

/// Decode a configuration from [`config_json`] output. The space supplies
/// the value kinds (the array alone cannot distinguish ints from floats).
pub fn config_from_json(space: &SearchSpace, j: &Json) -> Result<Config, String> {
    use crate::config::space::{Domain, ParamValue};
    let arr = j.as_arr().ok_or("config must be an array")?;
    if arr.len() != space.dim() {
        return Err(format!(
            "config has {} values, space has {}",
            arr.len(),
            space.dim()
        ));
    }
    let mut values = Vec::with_capacity(arr.len());
    for ((_, domain), v) in space.params.iter().zip(arr) {
        let x = v.as_f64().ok_or("config values must be numbers")?;
        let pv = match domain {
            Domain::Float { .. } | Domain::LogFloat { .. } => ParamValue::Float(x),
            Domain::Int { .. } | Domain::LogInt { .. } => ParamValue::Int(x as i64),
            Domain::Categorical { .. } => ParamValue::Cat(x as usize),
        };
        values.push(pv);
    }
    Ok(Config::new(values))
}

/// Canonical JSON encoding of a [`TrialAssignment`].
pub fn assignment_json(a: &TrialAssignment) -> Json {
    let mut o = Json::obj();
    match a {
        TrialAssignment::Run(job) => {
            o.set("type", "run")
                .set("trial", job.trial)
                .set("config", config_json(&job.config))
                .set("rung", job.rung)
                .set("from_epoch", job.from_epoch)
                .set("milestone", job.milestone);
        }
        TrialAssignment::Stop(t) => {
            o.set("type", "stop").set("trial", *t);
        }
        TrialAssignment::Pause(t) => {
            o.set("type", "pause").set("trial", *t);
        }
        TrialAssignment::Wait => {
            o.set("type", "wait");
        }
        TrialAssignment::Done => {
            o.set("type", "done");
        }
    }
    o
}

/// Decode a [`TrialAssignment`] from [`assignment_json`] output.
pub fn assignment_from_json(space: &SearchSpace, j: &Json) -> Result<TrialAssignment, String> {
    let ty = j
        .get("type")
        .and_then(|t| t.as_str())
        .ok_or("assignment missing 'type'")?;
    let trial = || -> Result<TrialId, String> {
        j.get("trial")
            .and_then(|t| t.as_f64())
            .map(|t| t as TrialId)
            .ok_or_else(|| "assignment missing 'trial'".to_string())
    };
    match ty {
        "run" => {
            let config = config_from_json(
                space,
                j.get("config").ok_or("run assignment missing 'config'")?,
            )?;
            let num = |key: &str| -> Result<f64, String> {
                j.get(key)
                    .and_then(|v| v.as_f64())
                    .ok_or_else(|| format!("run assignment missing '{key}'"))
            };
            Ok(TrialAssignment::Run(Job {
                trial: trial()?,
                config,
                rung: num("rung")? as usize,
                from_epoch: num("from_epoch")? as u32,
                milestone: num("milestone")? as u32,
            }))
        }
        "stop" => Ok(TrialAssignment::Stop(trial()?)),
        "pause" => Ok(TrialAssignment::Pause(trial()?)),
        "wait" => Ok(TrialAssignment::Wait),
        "done" => Ok(TrialAssignment::Done),
        other => Err(format!("unknown assignment type '{other}'")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::benchmarks::nasbench201::NasBench201;
    use crate::benchmarks::Benchmark;
    use crate::executor::engine::{run_engine, ConfigBudget};
    use crate::executor::sim::SimBackend;
    use crate::executor::SurrogateEvaluator;
    use crate::scheduler::asha::AshaBuilder;
    use crate::scheduler::lce::LceBuilder;
    use crate::scheduler::pasha::PashaBuilder;
    use crate::scheduler::stopping::{StopAshaBuilder, StopPashaBuilder};
    use crate::scheduler::SchedulerBuilder;
    use crate::searcher::random::RandomSearcher;

    fn asktell_for(builder: &dyn SchedulerBuilder, budget: usize, seed: u64) -> AskTell {
        let bench = NasBench201::cifar10();
        AskTell::new(
            builder.build(bench.max_epochs(), seed),
            Box::new(RandomSearcher::new(seed)),
            bench.space().clone(),
            vec![Box::new(ConfigBudget(budget))],
        )
    }

    /// Drive an AskTell session with one synchronous worker against the
    /// surrogate oracle, to completion.
    fn drive_single(at: &mut AskTell, bench: &NasBench201, bench_seed: u64) {
        loop {
            match at.ask("w0") {
                TrialAssignment::Run(job) => {
                    for e in job.from_epoch + 1..=job.milestone {
                        let m = bench.accuracy_at(&job.config, e, bench_seed);
                        if at.tell(job.trial, e, m).unwrap() == TellAck::Abandon {
                            break;
                        }
                    }
                }
                TrialAssignment::Stop(_) | TrialAssignment::Pause(_) => {}
                TrialAssignment::Wait => panic!("single worker can never wait"),
                TrialAssignment::Done => return,
            }
        }
    }

    #[test]
    fn single_worker_matches_engine_exactly() {
        // One pulling worker must reproduce run_engine's trajectory:
        // same configs sampled, same epochs, same best trial — across the
        // promotion and stopping families.
        let bench = NasBench201::cifar10();
        let builders: Vec<Box<dyn SchedulerBuilder>> = vec![
            Box::new(AshaBuilder::default()),
            Box::new(PashaBuilder::default()),
            Box::new(StopAshaBuilder::default()),
            Box::new(StopPashaBuilder::default()),
            Box::new(LceBuilder::default()),
        ];
        for builder in &builders {
            let mut at = asktell_for(builder.as_ref(), 32, 7);
            drive_single(&mut at, &bench, 0);

            let mut scheduler = builder.build(bench.max_epochs(), 7);
            let mut searcher = RandomSearcher::new(7);
            let mut evaluator = SurrogateEvaluator {
                bench: &bench,
                bench_seed: 0,
            };
            let mut backend = SimBackend::new(1, &mut evaluator);
            let rules: Vec<Box<dyn crate::executor::StoppingRule>> =
                vec![Box::new(ConfigBudget(32))];
            let stats = run_engine(
                scheduler.as_mut(),
                &mut searcher,
                bench.space(),
                &rules,
                &mut backend,
            );

            let snap = at.snapshot();
            assert_eq!(snap.configs_sampled, stats.configs_sampled, "{}", builder.name());
            assert_eq!(snap.jobs_completed, stats.jobs, "{}", builder.name());
            assert_eq!(snap.epochs_completed, stats.total_epochs, "{}", builder.name());
            let (a, b) = (at.best().unwrap(), scheduler.best().unwrap());
            assert_eq!(a.trial, b.trial, "{}", builder.name());
            assert_eq!(a.config, b.config, "{}", builder.name());
            assert_eq!(a.metric.to_bits(), b.metric.to_bits(), "{}", builder.name());
            assert_eq!(
                at.max_resources_used(),
                scheduler.max_resources_used(),
                "{}",
                builder.name()
            );
        }
    }

    #[test]
    fn out_of_order_and_unknown_tells_are_rejected_without_mutation() {
        let bench = NasBench201::cifar10();
        let mut at = asktell_for(&AshaBuilder::default(), 4, 0);
        assert!(at.tell(0, 1, 50.0).is_err(), "nothing asked yet");
        let job = match at.ask("w0") {
            TrialAssignment::Run(j) => j,
            other => panic!("expected a job, got {other:?}"),
        };
        assert!(at.tell(job.trial, job.milestone + 5, 50.0).is_err());
        // the failed tells left the job intact: the correct epoch works
        let m = bench.accuracy_at(&job.config, job.from_epoch + 1, 0);
        assert!(at.tell(job.trial, job.from_epoch + 1, m).is_ok());
    }

    #[test]
    fn fail_requeues_the_exact_job() {
        let mut at = asktell_for(&AshaBuilder::default(), 4, 1);
        let job = match at.ask("w0") {
            TrialAssignment::Run(j) => j,
            other => panic!("expected a job, got {other:?}"),
        };
        at.fail(job.trial).unwrap();
        assert_eq!(at.stats().failed_jobs, 1);
        // the next asking worker gets the identical job back
        let retry = match at.ask("w1") {
            TrialAssignment::Run(j) => j,
            other => panic!("expected a retry job, got {other:?}"),
        };
        assert_eq!(retry, job);
        assert!(at.fail(999).is_err(), "unknown trial fail is an error");
    }

    #[test]
    fn expire_workers_requeues_everything_in_flight_in_order() {
        let mut at = asktell_for(&AshaBuilder::default(), 8, 2);
        let mut jobs = Vec::new();
        for w in 0..3 {
            match at.ask(&format!("w{w}")) {
                TrialAssignment::Run(j) => jobs.push(j),
                other => panic!("expected a job, got {other:?}"),
            }
        }
        assert_eq!(at.in_flight_count(), 3);
        assert_eq!(at.expire_workers(), 3);
        assert_eq!(at.in_flight_count(), 0);
        assert_eq!(at.stats().failed_jobs, 3);
        // every job comes back out, in trial-id order (determinism)
        for expected in &jobs {
            let retry = match at.ask("w9") {
                TrialAssignment::Run(j) => j,
                other => panic!("expected a job, got {other:?}"),
            };
            assert_eq!(&retry, expected);
        }
    }

    #[test]
    fn wire_roundtrip_assignments() {
        let bench = NasBench201::cifar10();
        let space = bench.space();
        let mut at = asktell_for(&AshaBuilder::default(), 4, 3);
        let a = at.ask("w0");
        let j = assignment_json(&a);
        let back = assignment_from_json(space, &j).unwrap();
        assert_eq!(a, back);
        let s = j.to_string_compact();
        let reparsed = crate::util::json::parse(&s).unwrap();
        assert_eq!(assignment_from_json(space, &reparsed).unwrap(), a);
        for plain in [
            TrialAssignment::Stop(3),
            TrialAssignment::Pause(7),
            TrialAssignment::Wait,
            TrialAssignment::Done,
        ] {
            let j = assignment_json(&plain);
            assert_eq!(assignment_from_json(space, &j).unwrap(), plain);
        }
        assert!(!TrialAssignment::Wait.is_mutation());
        assert!(!TrialAssignment::Done.is_mutation());
        assert!(TrialAssignment::Stop(0).is_mutation());
    }

    #[test]
    fn wire_roundtrip_config_floats_exact() {
        // Float configs (PD1 space) must survive JSON byte-exactly: the
        // journal-recovery identity depends on it.
        use crate::config::space::SearchSpace;
        use crate::util::rng::Rng;
        let space = SearchSpace::pd1();
        let mut rng = Rng::new(11);
        for _ in 0..200 {
            let c = space.sample(&mut rng);
            let s = config_json(&c).to_string_compact();
            let parsed = crate::util::json::parse(&s).unwrap();
            let back = config_from_json(&space, &parsed).unwrap();
            for (a, b) in c.values.iter().zip(&back.values) {
                assert_eq!(a.as_f64().to_bits(), b.as_f64().to_bits());
            }
        }
    }

    #[test]
    fn tell_ack_string_roundtrip() {
        for ack in [TellAck::Continue, TellAck::JobComplete, TellAck::Abandon] {
            assert_eq!(TellAck::parse(ack.as_str()), Some(ack));
        }
        assert_eq!(TellAck::parse("nope"), None);
    }

    #[test]
    fn drained_session_reports_done() {
        let bench = NasBench201::cifar10();
        let mut at = asktell_for(&AshaBuilder::default(), 6, 4);
        drive_single(&mut at, &bench, 0);
        assert!(at.is_done());
        assert_eq!(at.ask("w0"), TrialAssignment::Done);
    }

    /// Round-robin multi-worker driver whose own cursor state (which
    /// worker holds which job at which epoch) can be cloned — so a
    /// snapshot cut mid-run can be continued identically on two adapters.
    #[derive(Clone)]
    struct Driver {
        jobs: Vec<Option<(Job, u32)>>,
        done: Vec<bool>,
    }

    impl Driver {
        fn new(workers: usize) -> Driver {
            Driver {
                jobs: vec![None; workers],
                done: vec![false; workers],
            }
        }

        fn finished(&self) -> bool {
            self.done.iter().all(|&d| d)
        }

        /// One round over all workers; every op's canonical encoding is
        /// appended to `trace`.
        fn round(&mut self, at: &mut AskTell, bench: &NasBench201, trace: &mut Vec<String>) {
            for w in 0..self.jobs.len() {
                if self.done[w] {
                    continue;
                }
                let name = format!("w{w}");
                match self.jobs[w].take() {
                    None => {
                        let a = at.ask(&name);
                        trace.push(assignment_json(&a).to_string_compact());
                        match a {
                            TrialAssignment::Run(job) => {
                                let from = job.from_epoch;
                                self.jobs[w] = Some((job, from + 1));
                            }
                            TrialAssignment::Done => self.done[w] = true,
                            _ => {}
                        }
                    }
                    Some((job, epoch)) => {
                        let m = bench.accuracy_at(&job.config, epoch, 0);
                        let ack = at.tell(job.trial, epoch, m).unwrap();
                        trace.push(format!("tell:{}:{}:{}", job.trial, epoch, ack.as_str()));
                        if ack == TellAck::Continue {
                            self.jobs[w] = Some((job, epoch + 1));
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn snapshot_mid_run_continues_byte_identically() {
        // Cut a three-worker session mid-run (jobs in flight, and for the
        // stopping family possibly parked resumes and pending
        // directives), restore the snapshot into a fresh adapter, and
        // require the remaining op trace to match byte for byte.
        let bench = NasBench201::cifar10();
        let builders: Vec<Box<dyn SchedulerBuilder>> = vec![
            Box::new(AshaBuilder::default()),
            Box::new(PashaBuilder::default()),
            Box::new(StopAshaBuilder::default()),
            Box::new(StopPashaBuilder::default()),
            Box::new(LceBuilder::default()),
        ];
        for builder in &builders {
            for cut_rounds in [3usize, 11, 29] {
                let mut live = asktell_for(builder.as_ref(), 20, 13);
                let mut driver = Driver::new(3);
                let mut head = Vec::new();
                for _ in 0..cut_rounds {
                    if driver.finished() {
                        break;
                    }
                    driver.round(&mut live, &bench, &mut head);
                }
                let state = live
                    .save_state()
                    .expect("all four schedulers support snapshots")
                    .to_string_compact();
                let mut restored = asktell_for(builder.as_ref(), 20, 13);
                restored
                    .load_state(&crate::util::json::parse(&state).unwrap())
                    .unwrap();
                let mut driver_b = driver.clone();
                let (mut tail_a, mut tail_b) = (Vec::new(), Vec::new());
                while !driver.finished() {
                    driver.round(&mut live, &bench, &mut tail_a);
                }
                while !driver_b.finished() {
                    driver_b.round(&mut restored, &bench, &mut tail_b);
                }
                assert_eq!(tail_a, tail_b, "{} cut {cut_rounds}", builder.name());
                let (a, b) = (live.best().unwrap(), restored.best().unwrap());
                assert_eq!(a.trial, b.trial, "{}", builder.name());
                assert_eq!(a.metric.to_bits(), b.metric.to_bits(), "{}", builder.name());
                assert_eq!(live.mutation_count(), restored.mutation_count());
            }
        }
    }

    #[test]
    fn save_state_none_for_unsupported_scheduler() {
        // Synchronous SH has no snapshot codec: the adapter must report
        // None (the service then falls back to full replay), not panic.
        let bench = NasBench201::cifar10();
        let builder = crate::scheduler::sh::SyncShBuilder {
            r_min: 1,
            eta: 3,
            n0: 9,
        };
        let at = AskTell::new(
            builder.build(bench.max_epochs(), 0),
            Box::new(RandomSearcher::new(0)),
            bench.space().clone(),
            vec![Box::new(ConfigBudget(9))],
        );
        assert!(at.save_state().is_none());
    }
}
