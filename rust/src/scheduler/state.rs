//! JSON codecs for serializable scheduler/searcher state.
//!
//! The tuning service recovers a crashed session by replaying its journal
//! against a fresh ask/tell core — O(history). Snapshots make recovery
//! O(tail): [`crate::scheduler::Scheduler::save_state`] /
//! [`crate::searcher::Searcher::save_state`] capture the full decision
//! state as a JSON value, and `load_state` restores it into a
//! freshly-built instance so the continuation is **byte-identical** to
//! never having stopped. This module holds the shared encoding helpers
//! those implementations use.
//!
//! Encoding rules that make the identity hold:
//!
//! * `f64` values ride as JSON numbers via Rust's shortest-roundtrip
//!   formatting (bit-exact for finite values); `NaN`/`±Inf`/`-0.0` —
//!   which JSON cannot represent — are spelled as the strings `"NaN"`,
//!   `"Inf"`, `"-Inf"`, `"-0"` ([`f64_json`] / [`f64_from`]).
//! * `u64`/`i64` values that may exceed 2^53 (RNG state, mutation
//!   counters) ride as decimal strings, never as lossy doubles.
//! * Hash containers are serialized in sorted order so snapshot bytes are
//!   deterministic; restored containers behave identically because no
//!   decision path iterates them in hash order.

use crate::config::space::{Config, ParamValue};
use crate::scheduler::core::ShCore;
use crate::scheduler::rung::{Rung, RungLevels};
use crate::scheduler::types::{Job, TrialAction, TrialInfo};
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::TrialId;
use std::collections::HashSet;

// ---------------------------------------------------------------------------
// Scalars
// ---------------------------------------------------------------------------

/// Encode one `f64` exactly (see module docs for the non-finite spelling).
pub fn f64_json(x: f64) -> Json {
    if x.is_nan() {
        Json::Str("NaN".into())
    } else if x == f64::INFINITY {
        Json::Str("Inf".into())
    } else if x == f64::NEG_INFINITY {
        Json::Str("-Inf".into())
    } else if x == 0.0 && x.is_sign_negative() {
        Json::Str("-0".into())
    } else {
        Json::Num(x)
    }
}

/// Decode [`f64_json`] output bit-exactly.
pub fn f64_from(j: &Json) -> Result<f64, String> {
    match j {
        Json::Num(x) => Ok(*x),
        Json::Str(s) => match s.as_str() {
            "NaN" => Ok(f64::NAN),
            "Inf" => Ok(f64::INFINITY),
            "-Inf" => Ok(f64::NEG_INFINITY),
            "-0" => Ok(-0.0),
            other => Err(format!("bad float literal '{other}'")),
        },
        other => Err(format!("expected a float, got {other}")),
    }
}

/// Encode a `u64` as a decimal string (doubles lose bits past 2^53).
pub fn u64_json(x: u64) -> Json {
    Json::Str(x.to_string())
}

/// Decode [`u64_json`] output.
pub fn u64_from(j: &Json) -> Result<u64, String> {
    let s = j
        .as_str()
        .ok_or_else(|| format!("expected a u64 string, got {j}"))?;
    s.parse::<u64>().map_err(|e| format!("bad u64 '{s}': {e}"))
}

/// Fetch a required field.
pub fn field<'a>(j: &'a Json, key: &str) -> Result<&'a Json, String> {
    j.get(key).ok_or_else(|| format!("missing field '{key}'"))
}

/// Fetch a required small non-negative integer field (exact below 2^53).
pub fn usize_field(j: &Json, key: &str) -> Result<usize, String> {
    let x = field(j, key)?
        .as_f64()
        .ok_or_else(|| format!("field '{key}' must be a number"))?;
    Ok(x as usize)
}

/// Fetch a required `u32` field.
pub fn u32_field(j: &Json, key: &str) -> Result<u32, String> {
    Ok(usize_field(j, key)? as u32)
}

// ---------------------------------------------------------------------------
// RNG
// ---------------------------------------------------------------------------

/// Encode a generator's full state.
pub fn rng_json(rng: &Rng) -> Json {
    Json::Arr(rng.state().iter().map(|&w| u64_json(w)).collect())
}

/// Decode [`rng_json`] output; the restored stream continues exactly.
pub fn rng_from(j: &Json) -> Result<Rng, String> {
    let arr = j.as_arr().ok_or("rng state must be an array")?;
    if arr.len() != 4 {
        return Err(format!("rng state must have 4 words, got {}", arr.len()));
    }
    let mut s = [0u64; 4];
    for (slot, w) in s.iter_mut().zip(arr) {
        *slot = u64_from(w)?;
    }
    Ok(Rng::from_state(s))
}

// ---------------------------------------------------------------------------
// Configurations and jobs
// ---------------------------------------------------------------------------

/// Encode one parameter value with its kind tag, so decoding needs no
/// search space: `{"f":x}` float, `{"i":"n"}` int, `{"c":n}` categorical.
pub fn param_value_json(v: &ParamValue) -> Json {
    let mut o = Json::obj();
    match v {
        ParamValue::Float(x) => o.set("f", f64_json(*x)),
        ParamValue::Int(x) => o.set("i", Json::Str(x.to_string())),
        ParamValue::Cat(c) => o.set("c", *c),
    };
    o
}

/// Decode [`param_value_json`] output.
pub fn param_value_from(j: &Json) -> Result<ParamValue, String> {
    if let Some(f) = j.get("f") {
        return Ok(ParamValue::Float(f64_from(f)?));
    }
    if let Some(i) = j.get("i") {
        let s = i.as_str().ok_or("int param must be a string")?;
        return Ok(ParamValue::Int(
            s.parse::<i64>().map_err(|e| format!("bad int '{s}': {e}"))?,
        ));
    }
    if let Some(c) = j.get("c") {
        let n = c.as_f64().ok_or("categorical param must be a number")?;
        return Ok(ParamValue::Cat(n as usize));
    }
    Err(format!("unrecognized param value {j}"))
}

/// Encode a configuration as a tagged value array (space-independent —
/// unlike [`crate::scheduler::asktell::config_json`], which is the wire
/// format and needs the space to decode).
pub fn config_state_json(c: &Config) -> Json {
    Json::Arr(c.values.iter().map(param_value_json).collect())
}

/// Decode [`config_state_json`] output.
pub fn config_state_from(j: &Json) -> Result<Config, String> {
    let arr = j.as_arr().ok_or("config state must be an array")?;
    let mut values = Vec::with_capacity(arr.len());
    for v in arr {
        values.push(param_value_from(v)?);
    }
    Ok(Config::new(values))
}

/// Encode a float series (learning curve, ε history) exactly.
pub fn curve_json(xs: &[f64]) -> Json {
    Json::Arr(xs.iter().map(|&x| f64_json(x)).collect())
}

/// Decode [`curve_json`] output.
pub fn curve_from(j: &Json) -> Result<Vec<f64>, String> {
    let arr = j.as_arr().ok_or("curve must be an array")?;
    arr.iter().map(f64_from).collect()
}

/// Encode a [`Job`].
pub fn job_json(job: &Job) -> Json {
    let mut o = Json::obj();
    o.set("trial", job.trial)
        .set("config", config_state_json(&job.config))
        .set("rung", job.rung)
        .set("from_epoch", job.from_epoch)
        .set("milestone", job.milestone);
    o
}

/// Decode [`job_json`] output.
pub fn job_from(j: &Json) -> Result<Job, String> {
    Ok(Job {
        trial: usize_field(j, "trial")?,
        config: config_state_from(field(j, "config")?)?,
        rung: usize_field(j, "rung")?,
        from_epoch: u32_field(j, "from_epoch")?,
        milestone: u32_field(j, "milestone")?,
    })
}

/// Encode a [`TrialAction`]: `{"stop":t}` or `{"pause":t}`.
pub fn action_json(a: &TrialAction) -> Json {
    let mut o = Json::obj();
    match a {
        TrialAction::Stop(t) => o.set("stop", *t),
        TrialAction::Pause(t) => o.set("pause", *t),
    };
    o
}

/// Decode [`action_json`] output.
pub fn action_from(j: &Json) -> Result<TrialAction, String> {
    let t = |v: &Json| -> Result<TrialId, String> {
        v.as_f64()
            .map(|x| x as TrialId)
            .ok_or_else(|| "action trial must be a number".to_string())
    };
    if let Some(v) = j.get("stop") {
        return Ok(TrialAction::Stop(t(v)?));
    }
    if let Some(v) = j.get("pause") {
        return Ok(TrialAction::Pause(t(v)?));
    }
    Err(format!("unrecognized trial action {j}"))
}

/// Encode a set of trial ids in sorted order (deterministic bytes).
pub fn trial_set_json(set: &HashSet<TrialId>) -> Json {
    let mut ids: Vec<TrialId> = set.iter().copied().collect();
    ids.sort_unstable();
    Json::Arr(ids.into_iter().map(Json::from).collect())
}

/// Decode a trial-id list (from [`trial_set_json`] or a plain list).
pub fn trial_ids_from(j: &Json) -> Result<Vec<TrialId>, String> {
    let arr = j.as_arr().ok_or("trial ids must be an array")?;
    arr.iter()
        .map(|v| {
            v.as_f64()
                .map(|x| x as TrialId)
                .ok_or_else(|| "trial id must be a number".to_string())
        })
        .collect()
}

// ---------------------------------------------------------------------------
// ShCore: the shared successive-halving state machine
// ---------------------------------------------------------------------------

fn rung_json(rung: &Rung) -> Json {
    let mut o = Json::obj();
    o.set(
        "entries",
        Json::Arr(
            rung.entries
                .iter()
                .map(|&(t, m)| Json::Arr(vec![Json::from(t), f64_json(m)]))
                .collect(),
        ),
    )
    .set("promoted", trial_set_json(&rung.promoted));
    o
}

fn rung_from(j: &Json) -> Result<Rung, String> {
    let mut rung = Rung::default();
    for e in field(j, "entries")?.as_arr().ok_or("entries must be an array")? {
        let pair = e.as_arr().ok_or("rung entry must be a pair")?;
        if pair.len() != 2 {
            return Err("rung entry must be a [trial, metric] pair".into());
        }
        let t = pair[0].as_f64().ok_or("rung entry trial must be a number")? as TrialId;
        rung.entries.push((t, f64_from(&pair[1])?));
    }
    for t in trial_ids_from(field(j, "promoted")?)? {
        rung.promoted.insert(t);
    }
    Ok(rung)
}

fn trial_info_json(t: &TrialInfo) -> Json {
    let mut o = Json::obj();
    o.set("config", config_state_json(&t.config))
        .set("dispatched", t.dispatched_epochs)
        .set("curve", curve_json(&t.curve));
    match t.top_rung {
        Some(k) => o.set("top_rung", k),
        None => o.set("top_rung", Json::Null),
    };
    o
}

fn trial_info_from(j: &Json) -> Result<TrialInfo, String> {
    let mut info = TrialInfo::new(config_state_from(field(j, "config")?)?);
    info.dispatched_epochs = u32_field(j, "dispatched")?;
    info.curve = curve_from(field(j, "curve")?)?;
    info.top_rung = match field(j, "top_rung")? {
        Json::Null => None,
        v => Some(v.as_f64().ok_or("top_rung must be a number or null")? as usize),
    };
    Ok(info)
}

/// Encode the full [`ShCore`] state (rung grid, trials, resource mark).
pub fn sh_core_json(core: &ShCore) -> Json {
    let mut levels = Json::obj();
    levels
        .set("r_min", core.levels.r_min)
        .set("eta", core.levels.eta)
        .set(
            "levels",
            Json::Arr(core.levels.levels.iter().map(|&l| Json::from(l)).collect()),
        );
    let mut o = Json::obj();
    o.set("levels", levels)
        .set("rungs", Json::Arr(core.rungs.iter().map(rung_json).collect()))
        .set(
            "trials",
            Json::Arr(core.trials.iter().map(trial_info_json).collect()),
        )
        .set("max_resources_used", core.max_resources_used);
    o
}

/// Restore [`sh_core_json`] output into a freshly-built core. The rung
/// grid recorded in the snapshot must match the core's (same benchmark +
/// builder parameters) — a mismatch means the snapshot belongs to a
/// different session recipe and is refused.
pub fn load_sh_core(core: &mut ShCore, j: &Json) -> Result<(), String> {
    let lv = field(j, "levels")?;
    let recorded = RungLevels {
        r_min: u32_field(lv, "r_min")?,
        eta: u32_field(lv, "eta")?,
        levels: field(lv, "levels")?
            .as_arr()
            .ok_or("levels must be an array")?
            .iter()
            .map(|v| v.as_f64().map(|x| x as u32).ok_or("level must be a number"))
            .collect::<Result<Vec<u32>, &str>>()
            .map_err(|e| e.to_string())?,
    };
    if recorded != core.levels {
        return Err(format!(
            "snapshot rung grid {:?} does not match session grid {:?}",
            recorded.levels, core.levels.levels
        ));
    }
    let rungs = field(j, "rungs")?.as_arr().ok_or("rungs must be an array")?;
    if rungs.len() != core.rungs.len() {
        return Err(format!(
            "snapshot has {} rungs, session grid has {}",
            rungs.len(),
            core.rungs.len()
        ));
    }
    core.rungs = rungs.iter().map(rung_from).collect::<Result<_, _>>()?;
    core.trials = field(j, "trials")?
        .as_arr()
        .ok_or("trials must be an array")?
        .iter()
        .map(trial_info_from)
        .collect::<Result<_, _>>()?;
    core.max_resources_used = u32_field(j, "max_resources_used")?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::types::{JobOutcome, SchedCtx};
    use crate::searcher::random::RandomSearcher;

    #[test]
    fn f64_roundtrip_exact() {
        for x in [
            0.0,
            -0.0,
            1.5,
            -3.25e-17,
            f64::NAN,
            f64::INFINITY,
            f64::NEG_INFINITY,
            f64::MIN_POSITIVE,
            1.0 / 3.0,
        ] {
            let j = f64_json(x);
            let s = j.to_string_compact();
            let back = f64_from(&crate::util::json::parse(&s).unwrap()).unwrap();
            assert_eq!(x.to_bits(), back.to_bits(), "{x}");
        }
        assert!(f64_from(&Json::Str("zero".into())).is_err());
        assert!(f64_from(&Json::Bool(true)).is_err());
    }

    #[test]
    fn u64_and_rng_roundtrip() {
        for x in [0u64, 1, u64::MAX, 1 << 60] {
            assert_eq!(u64_from(&u64_json(x)).unwrap(), x);
        }
        assert!(u64_from(&Json::Num(3.0)).is_err());
        let mut rng = Rng::new(7);
        for _ in 0..13 {
            rng.next_u64();
        }
        let j = rng_json(&rng);
        let s = j.to_string_compact();
        let mut back = rng_from(&crate::util::json::parse(&s).unwrap()).unwrap();
        let mut orig = rng.clone();
        for _ in 0..64 {
            assert_eq!(orig.next_u64(), back.next_u64());
        }
        assert!(rng_from(&Json::Arr(vec![u64_json(1)])).is_err());
    }

    #[test]
    fn config_and_job_roundtrip() {
        let config = Config::new(vec![
            ParamValue::Float(3.5e-4),
            ParamValue::Int(-12),
            ParamValue::Cat(7),
            ParamValue::Float(f64::NAN),
        ]);
        let j = config_state_json(&config);
        let s = j.to_string_compact();
        let back = config_state_from(&crate::util::json::parse(&s).unwrap()).unwrap();
        assert_eq!(back.values.len(), 4);
        for (a, b) in config.values.iter().zip(&back.values) {
            match (a, b) {
                (ParamValue::Float(x), ParamValue::Float(y)) => {
                    assert_eq!(x.to_bits(), y.to_bits())
                }
                _ => assert_eq!(a, b),
            }
        }
        let job = Job {
            trial: 4,
            config,
            rung: 2,
            from_epoch: 3,
            milestone: 9,
        };
        let back = job_from(&job_json(&job)).unwrap();
        assert_eq!(back.trial, job.trial);
        assert_eq!(back.rung, job.rung);
        assert_eq!(back.from_epoch, job.from_epoch);
        assert_eq!(back.milestone, job.milestone);
    }

    #[test]
    fn action_roundtrip() {
        for a in [TrialAction::Stop(3), TrialAction::Pause(11)] {
            assert_eq!(action_from(&action_json(&a)).unwrap(), a);
        }
        assert!(action_from(&Json::obj()).is_err());
    }

    #[test]
    fn sh_core_roundtrip_preserves_decisions() {
        // Build a core with promotions recorded, snapshot it, restore into
        // a fresh core, and require identical subsequent job decisions.
        let space = crate::config::space::SearchSpace::nas(1000);
        let mut searcher = RandomSearcher::new(3);
        let mut ctx = SchedCtx::with_budget(&space, &mut searcher, 0, 100);
        let mut core = ShCore::new(RungLevels::new(1, 3, 27));
        for i in 0..7 {
            let job = core.next_job_capped(&mut ctx, 3).unwrap();
            core.record(&JobOutcome {
                trial: job.trial,
                rung: job.rung,
                milestone: job.milestone,
                metric: 40.0 + i as f64,
                curve_segment: (job.from_epoch + 1..=job.milestone)
                    .map(|_| 40.0 + i as f64)
                    .collect(),
            });
        }
        let snap = sh_core_json(&core);
        let reparsed = crate::util::json::parse(&snap.to_string_compact()).unwrap();
        let mut restored = ShCore::new(RungLevels::new(1, 3, 27));
        load_sh_core(&mut restored, &reparsed).unwrap();
        assert_eq!(restored.trials.len(), core.trials.len());
        assert_eq!(restored.max_resources_used, core.max_resources_used);
        // identical decision surface: rankings, promotion candidates, best
        for k in 0..core.rungs.len() {
            assert_eq!(restored.ranking(k), core.ranking(k), "rung {k}");
            assert_eq!(
                restored.rungs[k].promotable(3),
                core.rungs[k].promotable(3),
                "rung {k}"
            );
        }
        let (a, b) = (core.best().unwrap(), restored.best().unwrap());
        assert_eq!(a.trial, b.trial);
        assert_eq!(a.metric.to_bits(), b.metric.to_bits());
        for (x, y) in core.trials.iter().zip(&restored.trials) {
            assert_eq!(x.dispatched_epochs, y.dispatched_epochs);
            assert_eq!(x.curve.len(), y.curve.len());
        }
        // grid mismatch is refused
        let mut wrong = ShCore::new(RungLevels::new(1, 3, 9));
        assert!(load_sh_core(&mut wrong, &reparsed).is_err());
    }
}
