//! ASHA — Asynchronous Successive Halving (Li et al., MLSys 2020),
//! promotion variant: the paper's main baseline.
//!
//! ASHA runs the asynchronous SH rule over the full rung grid `r·η^k ≤ R`:
//! whenever a worker frees up it promotes the best not-yet-promoted trial
//! from the highest rung that has one (top `1/η` fraction), otherwise it
//! starts a new configuration at the bottom rung. The maximum resource
//! level `R` is fixed up front — precisely the hyperparameter PASHA
//! removes the sensitivity to.

use super::core::ShCore;
use super::rung::RungLevels;
use super::state::{field, load_sh_core, sh_core_json};
use super::types::{
    BestTrial, Job, JobOutcome, SchedCtx, Scheduler, SchedulerBuilder, TrialInfo,
};
use crate::util::json::Json;

pub struct Asha {
    core: ShCore,
}

impl Asha {
    pub fn new(levels: RungLevels) -> Self {
        Asha {
            core: ShCore::new(levels),
        }
    }

    pub fn levels(&self) -> &RungLevels {
        &self.core.levels
    }
}

impl Scheduler for Asha {
    fn next_job(&mut self, ctx: &mut SchedCtx) -> Option<Job> {
        let cap = self.core.levels.top();
        self.core.next_job_capped(ctx, cap)
    }

    fn on_result(&mut self, outcome: &JobOutcome) {
        self.core.record(outcome);
    }

    fn on_cancelled(&mut self, trial: usize) {
        self.core.rewind_dispatch(trial);
    }

    fn max_resources_used(&self) -> u32 {
        self.core.max_resources_used
    }

    fn resource_cap(&self) -> Option<u32> {
        // Fixed `R` from the start — the flat line PASHA's growing cap
        // is compared against in the metrics.
        Some(self.core.levels.level(self.core.levels.top()))
    }

    fn best(&self) -> Option<BestTrial> {
        self.core.best()
    }

    fn trials(&self) -> &[TrialInfo] {
        &self.core.trials
    }

    fn save_state(&self) -> Option<Json> {
        let mut o = Json::obj();
        o.set("kind", "asha").set("core", sh_core_json(&self.core));
        Some(o)
    }

    fn load_state(&mut self, state: &Json) -> Result<(), String> {
        if state.get("kind").and_then(|k| k.as_str()) != Some("asha") {
            return Err("state is not an ASHA snapshot".into());
        }
        load_sh_core(&mut self.core, field(state, "core")?)
    }

    fn name(&self) -> String {
        "ASHA".into()
    }
}

/// Builder: `r`, `η` fixed; `R` supplied per benchmark.
#[derive(Clone, Debug)]
pub struct AshaBuilder {
    pub r_min: u32,
    pub eta: u32,
}

impl Default for AshaBuilder {
    fn default() -> Self {
        AshaBuilder { r_min: 1, eta: 3 }
    }
}

impl SchedulerBuilder for AshaBuilder {
    fn build(&self, max_epochs: u32, _seed: u64) -> Box<dyn Scheduler> {
        Box::new(Asha::new(RungLevels::new(self.r_min, self.eta, max_epochs)))
    }

    fn name(&self) -> String {
        "ASHA".into()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::space::SearchSpace;
    use crate::searcher::random::RandomSearcher;

    /// Drive ASHA with a synthetic oracle: metric is a deterministic
    /// function of (trial, milestone) so promotions are predictable.
    fn drive(n_configs: usize, metric: impl Fn(usize, u32) -> f64) -> Asha {
        let space = SearchSpace::nas(100_000);
        let mut searcher = RandomSearcher::new(7);
        let mut ctx = SchedCtx::with_budget(&space, &mut searcher, 0, n_configs);
        let mut asha = Asha::new(RungLevels::new(1, 3, 27));
        while let Some(job) = asha.next_job(&mut ctx) {
            let m = metric(job.trial, job.milestone);
            asha.on_result(&JobOutcome {
                trial: job.trial,
                rung: job.rung,
                milestone: job.milestone,
                metric: m,
                curve_segment: (job.from_epoch + 1..=job.milestone)
                    .map(|e| m - (job.milestone - e) as f64 * 0.01)
                    .collect(),
            });
        }
        asha
    }

    #[test]
    fn full_run_promotes_decreasing_fractions() {
        // Asynchronous promotion: rung occupancy decreases with height and
        // the top rung is reached. (Exact 1/η fractions hold only for the
        // synchronous variant — see sh.rs; with metrics increasing in
        // arrival order, async ASHA promotes aggressively by design.)
        let asha = drive(27, |t, m| t as f64 + m as f64 * 0.001);
        let sizes: Vec<usize> = asha.core.rungs.iter().map(|r| r.len()).collect();
        assert_eq!(sizes[0], 27);
        for w in sizes.windows(2) {
            assert!(w[1] <= w[0], "occupancy must not grow with rung: {sizes:?}");
        }
        assert!(sizes[3] >= 1);
        assert_eq!(asha.max_resources_used(), 27);
    }

    #[test]
    fn best_trial_wins_when_metrics_are_stable() {
        // trial id IS the quality: highest sampled trial ends up best
        let asha = drive(27, |t, m| t as f64 + m as f64 * 0.001);
        let best = asha.best().unwrap();
        assert_eq!(best.trial, 26);
        assert_eq!(best.at_epoch, 27, "best must have been trained to the top");
    }

    #[test]
    fn promoted_trials_subset_of_rung_members() {
        let asha = drive(30, |t, m| (t % 10) as f64 + m as f64 * 0.001);
        for k in 0..asha.core.rungs.len() - 1 {
            let members: std::collections::HashSet<_> = asha.core.rungs[k]
                .entries
                .iter()
                .map(|&(t, _)| t)
                .collect();
            for t in &asha.core.rungs[k].promoted {
                assert!(members.contains(t));
            }
            // everything in rung k+1 was promoted from rung k
            for &(t, _) in &asha.core.rungs[k + 1].entries {
                assert!(asha.core.rungs[k].promoted.contains(&t));
            }
        }
    }

    #[test]
    fn curves_cover_trained_epochs() {
        let asha = drive(20, |t, m| t as f64 + m as f64 * 0.01);
        for t in asha.trials() {
            assert_eq!(t.curve.len() as u32, t.trained_epochs());
            assert_eq!(t.dispatched_epochs, t.trained_epochs(), "drained run");
        }
    }

    #[test]
    fn builder_uses_benchmark_budget() {
        let b = AshaBuilder::default();
        let s = b.build(200, 0);
        assert_eq!(s.name(), "ASHA");
        let b2 = AshaBuilder { r_min: 1, eta: 2 };
        let _ = b2.build(50, 0);
    }
}
