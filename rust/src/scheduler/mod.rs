//! Multi-fidelity schedulers: the resource-allocation half of the tuner.
//!
//! * [`pasha`] — the paper's contribution: ASHA with progressive growth of
//!   the maximum resource level, driven by ranking stability.
//! * [`asha`] — asynchronous successive halving (Li et al. 2020), the main
//!   baseline.
//! * [`sh`] / [`hyperband`] — classical synchronous SH and Hyperband,
//!   context baselines.
//! * [`baselines`] — the paper's k-epoch and random baselines.

pub mod asha;
pub mod baselines;
pub mod core;
pub mod hyperband;
pub mod pasha;
pub mod rung;
pub mod sh;
pub mod types;

pub use types::{
    BestTrial, Job, JobOutcome, SchedCtx, Scheduler, SchedulerBuilder, TrialInfo,
};
