//! Multi-fidelity schedulers: the resource-allocation half of the tuner.
//!
//! * [`pasha`] — the paper's contribution: ASHA with progressive growth of
//!   the maximum resource level, driven by ranking stability
//!   (promotion-type).
//! * [`asha`] — asynchronous successive halving (Li et al. 2020), the main
//!   baseline (promotion-type).
//! * [`stopping`] — the stopping-type variants of both: trials keep
//!   training until a rung completion shows they are outside the top
//!   `1/η`, expressed through the engine's [`TrialAction`] decision
//!   layer (`Stop` terminates, `Pause` suspends until PASHA's cap grows).
//! * [`sh`] / [`hyperband`] — classical synchronous SH and Hyperband,
//!   context baselines.
//! * [`lce`] — learning-curve extrapolation: a stopping-type arm that
//!   stops predicted losers early and promotes on *extrapolated* rank
//!   under PASHA's growing cap, backed by [`crate::curvefit`].
//! * [`baselines`] — the paper's k-epoch and random baselines.
//! * [`asktell`] — the pull-mode adapter: any scheduler + searcher behind
//!   an `ask`/`tell` API for the tuning service ([`crate::service`]),
//!   where external workers drive trials instead of the engine loop.
//! * [`state`] — JSON codecs for serializable scheduler/searcher state:
//!   the snapshot format that makes service recovery O(tail) instead of
//!   O(history) (implemented by ASHA, PASHA, both stopping variants, and
//!   the random/BO searchers).
//!
//! All of them speak the same protocol to the execution engine
//! ([`crate::executor::engine`]): `next_job` fills free workers,
//! `on_result` absorbs completions, and `drain_actions` surfaces
//! stop/pause decisions for the engine to enact (cancelling in-flight
//! backend work where needed). How long a run goes on is the engine's
//! business, governed by pluggable stopping rules — schedulers only see
//! the per-dispatch draw allowance through [`SchedCtx`].

pub mod asha;
pub mod asktell;
pub mod baselines;
pub mod core;
pub mod hyperband;
pub mod lce;
pub mod pasha;
pub mod rung;
pub mod sh;
pub mod state;
pub mod stopping;
pub mod types;

pub use asktell::{AskTell, TellAck, TrialAssignment};
pub use types::{
    BestTrial, Job, JobOutcome, SchedCtx, Scheduler, SchedulerBuilder, TrialAction, TrialInfo,
};
