//! Stopping-type ASHA and PASHA (Li et al. 2020 §3.1; PASHA §4).
//!
//! The promotion variants in [`super::asha`]/[`super::pasha`] only ever
//! *add* work: a trial sits at a rung until it wins a promotion quota.
//! The stopping variants invert the default: every trial keeps training
//! rung-by-rung until a rung completion shows it is **not** in the top
//! `1/η` of that rung, at which point the scheduler emits a
//! [`TrialAction::Stop`] and the engine cancels any in-flight work for
//! it. This trades extra early-epoch training for decisions that never
//! leave a promising trial idle — the variant Ray Tune and syne-tune ship
//! as their default ASHA mode.
//!
//! PASHA-stop layers the progressive resource cap on top: trials that
//! complete the current cap rung are **paused** ([`TrialAction::Pause`]),
//! not stopped; when the top-two-rung ranking disagrees (the paper's
//! Algorithm 1 consistency check) the cap grows one rung and every paused
//! trial that passes the stopping test at the old cap resumes.

use super::core::ShCore;
use super::pasha::cap_ranking_consistent;
use super::rung::RungLevels;
use super::state::{
    action_from, action_json, curve_from, curve_json, field, load_sh_core, sh_core_json,
    trial_ids_from, usize_field,
};
use super::types::{
    BestTrial, Job, JobOutcome, SchedCtx, Scheduler, SchedulerBuilder, TrialAction, TrialInfo,
};
use crate::ranking::{RankingFunction, RankingSpec};
use crate::util::json::Json;
use crate::TrialId;
use std::collections::VecDeque;

/// Shared state machine of the stopping-type SH family. With
/// `ranking: None` the cap is fixed at the grid top (ASHA-stop); with a
/// ranking function the cap starts at rung 1 and grows on ranking
/// instability (PASHA-stop).
pub struct StoppingSh {
    core: ShCore,
    /// Current top-rung index: jobs may target rungs `0..=cap`.
    cap: usize,
    /// Progressive-growth machinery; `None` = ASHA-stop.
    ranking: Option<Box<dyn RankingFunction>>,
    /// Continuations waiting for a free worker: `(trial, target rung)`.
    ready: VecDeque<(TrialId, usize)>,
    /// Trials suspended at the current cap, resumable when it grows.
    paused: Vec<TrialId>,
    /// Stop/Pause decisions not yet drained by the engine.
    actions: Vec<TrialAction>,
    eps_history: Vec<f64>,
    growths: usize,
    name: String,
}

impl StoppingSh {
    /// Stopping-type ASHA: fixed maximum resource level `R`.
    pub fn asha(levels: RungLevels) -> Self {
        let cap = levels.top();
        StoppingSh {
            core: ShCore::new(levels),
            cap,
            ranking: None,
            ready: VecDeque::new(),
            paused: Vec::new(),
            actions: Vec::new(),
            eps_history: Vec::new(),
            growths: 0,
            name: "ASHA-stop".into(),
        }
    }

    /// Stopping-type PASHA: cap starts at rung 1 (`R_0 = η·r`) and grows
    /// on ranking inconsistency, exactly like promotion-type PASHA.
    pub fn pasha(levels: RungLevels, spec: &RankingSpec) -> Self {
        let cap = 1.min(levels.top());
        StoppingSh {
            core: ShCore::new(levels),
            cap,
            ranking: Some(spec.build()),
            ready: VecDeque::new(),
            paused: Vec::new(),
            actions: Vec::new(),
            eps_history: Vec::new(),
            growths: 0,
            name: format!("{}-stop", spec.label()),
        }
    }

    pub fn current_cap(&self) -> usize {
        self.cap
    }

    pub fn growths(&self) -> usize {
        self.growths
    }

    /// The stopping test: is `trial` in the top `1/η` of rung `k`?
    /// `max(1, len/η)` keeps the best entry alive even in a sparsely
    /// populated rung, so early trials are never stopped for lack of
    /// competition (they can still be stopped retroactively-in-effect:
    /// later, better arrivals push them out before their next rung).
    fn passes(&self, k: usize, trial: TrialId) -> bool {
        let len = self.core.rungs[k].len();
        let keep = (len / self.core.levels.eta as usize).max(1);
        match self.core.rank_in_rung(k, trial) {
            Some(rank) => rank < keep,
            None => false,
        }
    }
}

impl Scheduler for StoppingSh {
    fn next_job(&mut self, ctx: &mut SchedCtx) -> Option<Job> {
        if let Some((trial, rung)) = self.ready.pop_front() {
            return Some(self.core.continue_job(trial, rung));
        }
        self.core.start_new(ctx)
    }

    fn on_result(&mut self, outcome: &JobOutcome) {
        self.core.record(outcome);
        let trial = outcome.trial;
        let rung = outcome.rung;
        if rung == self.core.levels.top() {
            return; // trained to the safety net R: trial is complete
        }
        if rung < self.cap {
            // Intermediate rung: continue while in the top 1/η, stop
            // otherwise — the defining rule of the stopping variant.
            if self.passes(rung, trial) {
                self.core.rungs[rung].mark_promoted(trial);
                self.ready.push_back((trial, rung + 1));
            } else {
                self.actions.push(TrialAction::Stop(trial));
            }
            return;
        }
        // rung == cap < top: only reachable with progressive growth
        // (ASHA-stop's cap is the top rung, handled above).
        let grew = match self.ranking.as_mut() {
            Some(ranking) => !cap_ranking_consistent(
                &self.core,
                ranking.as_mut(),
                self.cap,
                &mut self.eps_history,
            ),
            None => false,
        };
        if grew {
            self.cap += 1;
            self.growths += 1;
            // The old cap rung is now intermediate: resume every paused
            // trial (including this one) that passes the stopping test at
            // the rung it last completed — paused trials from older cap
            // generations re-test at their own frontier; the rest stay
            // paused for the next growth.
            self.paused.push(trial);
            let candidates = std::mem::take(&mut self.paused);
            for t in candidates {
                let at = self.core.trials[t].top_rung.unwrap_or(0);
                if at < self.cap && self.passes(at, t) {
                    self.core.rungs[at].mark_promoted(t);
                    self.ready.push_back((t, at + 1));
                } else {
                    // Older paused trials already announced their pause;
                    // the just-reported trial suspends here for the
                    // first time and must tell the engine.
                    if t == trial {
                        self.actions.push(TrialAction::Pause(t));
                    }
                    self.paused.push(t);
                }
            }
        } else {
            self.paused.push(trial);
            self.actions.push(TrialAction::Pause(trial));
        }
    }

    fn drain_actions(&mut self) -> Vec<TrialAction> {
        std::mem::take(&mut self.actions)
    }

    fn on_cancelled(&mut self, trial: TrialId) {
        // Keeps a later resume gap-free whether the cancellation came
        // from our own actions or from an engine halt.
        self.core.rewind_dispatch(trial);
    }

    fn max_resources_used(&self) -> u32 {
        self.core.max_resources_used
    }

    fn resource_cap(&self) -> Option<u32> {
        Some(self.core.levels.level(self.cap))
    }

    fn best(&self) -> Option<BestTrial> {
        self.core.best()
    }

    fn trials(&self) -> &[TrialInfo] {
        &self.core.trials
    }

    fn epsilon_history(&self) -> &[f64] {
        &self.eps_history
    }

    fn save_state(&self) -> Option<Json> {
        // `ranking`/`name` come from the builder; the queues must ride
        // along in order — `ready` is the dispatch order and `paused` the
        // resume-scan order, both of which the byte-identity depends on.
        let mut o = Json::obj();
        o.set("kind", "stopping")
            .set("core", sh_core_json(&self.core))
            .set("cap", self.cap)
            .set(
                "ready",
                Json::Arr(
                    self.ready
                        .iter()
                        .map(|&(t, k)| Json::Arr(vec![Json::from(t), Json::from(k)]))
                        .collect(),
                ),
            )
            .set(
                "paused",
                Json::Arr(self.paused.iter().map(|&t| Json::from(t)).collect()),
            )
            .set(
                "actions",
                Json::Arr(self.actions.iter().map(action_json).collect()),
            )
            .set("eps_history", curve_json(&self.eps_history))
            .set("growths", self.growths);
        Some(o)
    }

    fn load_state(&mut self, state: &Json) -> Result<(), String> {
        if state.get("kind").and_then(|k| k.as_str()) != Some("stopping") {
            return Err("state is not a stopping-type snapshot".into());
        }
        load_sh_core(&mut self.core, field(state, "core")?)?;
        let cap = usize_field(state, "cap")?;
        if cap >= self.core.levels.num_rungs() {
            return Err(format!("snapshot cap {cap} outside the rung grid"));
        }
        self.cap = cap;
        self.ready.clear();
        for pair in field(state, "ready")?.as_arr().ok_or("ready must be an array")? {
            let p = pair.as_arr().ok_or("ready entry must be a pair")?;
            if p.len() != 2 {
                return Err("ready entry must be a [trial, rung] pair".into());
            }
            let t = p[0].as_f64().ok_or("ready trial must be a number")? as TrialId;
            let k = p[1].as_f64().ok_or("ready rung must be a number")? as usize;
            self.ready.push_back((t, k));
        }
        self.paused = trial_ids_from(field(state, "paused")?)?;
        self.actions = field(state, "actions")?
            .as_arr()
            .ok_or("actions must be an array")?
            .iter()
            .map(action_from)
            .collect::<Result<_, _>>()?;
        self.eps_history = curve_from(field(state, "eps_history")?)?;
        self.growths = usize_field(state, "growths")?;
        Ok(())
    }

    fn name(&self) -> String {
        self.name.clone()
    }
}

/// Builder for stopping-type ASHA.
#[derive(Clone, Debug)]
pub struct StopAshaBuilder {
    pub r_min: u32,
    pub eta: u32,
}

impl Default for StopAshaBuilder {
    fn default() -> Self {
        StopAshaBuilder { r_min: 1, eta: 3 }
    }
}

impl SchedulerBuilder for StopAshaBuilder {
    fn build(&self, max_epochs: u32, _seed: u64) -> Box<dyn Scheduler> {
        Box::new(StoppingSh::asha(RungLevels::new(
            self.r_min,
            self.eta,
            max_epochs,
        )))
    }

    fn name(&self) -> String {
        "ASHA-stop".into()
    }
}

/// Builder for stopping-type PASHA with a choice of ranking function.
#[derive(Clone, Debug)]
pub struct StopPashaBuilder {
    pub r_min: u32,
    pub eta: u32,
    pub ranking: RankingSpec,
}

impl Default for StopPashaBuilder {
    fn default() -> Self {
        StopPashaBuilder {
            r_min: 1,
            eta: 3,
            ranking: RankingSpec::default(),
        }
    }
}

impl SchedulerBuilder for StopPashaBuilder {
    fn build(&self, max_epochs: u32, _seed: u64) -> Box<dyn Scheduler> {
        Box::new(StoppingSh::pasha(
            RungLevels::new(self.r_min, self.eta, max_epochs),
            &self.ranking,
        ))
    }

    fn name(&self) -> String {
        format!("{}-stop", self.ranking.label())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::space::SearchSpace;
    use crate::searcher::random::RandomSearcher;
    use std::collections::HashSet;

    /// Serial driver: run the scheduler to exhaustion against a metric
    /// oracle, collecting the emitted actions and enforcing the engine's
    /// contract that stopped trials never receive another job.
    fn drive(
        sched: &mut StoppingSh,
        n_configs: usize,
        metric: impl Fn(usize, u32) -> f64,
    ) -> Vec<TrialAction> {
        let space = SearchSpace::nas(100_000);
        let mut searcher = RandomSearcher::new(3);
        let mut ctx = SchedCtx::with_budget(&space, &mut searcher, 0, n_configs);
        let mut actions = Vec::new();
        let mut stopped: HashSet<usize> = HashSet::new();
        while let Some(job) = sched.next_job(&mut ctx) {
            assert!(
                !stopped.contains(&job.trial),
                "job dispatched for stopped trial {}",
                job.trial
            );
            let m = metric(job.trial, job.milestone);
            sched.on_result(&JobOutcome {
                trial: job.trial,
                rung: job.rung,
                milestone: job.milestone,
                metric: m,
                curve_segment: (job.from_epoch + 1..=job.milestone)
                    .map(|e| metric(job.trial, e))
                    .collect(),
            });
            for a in sched.drain_actions() {
                if let TrialAction::Stop(t) = a {
                    stopped.insert(t);
                }
                actions.push(a);
            }
        }
        actions
    }

    #[test]
    fn asha_stop_continues_leaders_and_stops_laggards() {
        // metric = −trial id: every later arrival is worse than the
        // incumbent leader already recorded in rung 0, so it is stopped
        // at its first completion while trial 0 trains to R. (Stopping
        // decisions are made at completion time — a trial can only be
        // stopped once something better is on the board.)
        let mut s = StoppingSh::asha(RungLevels::new(1, 3, 27));
        let actions = drive(&mut s, 27, |t, _| -(t as f64));
        let stops = actions
            .iter()
            .filter(|a| matches!(a, TrialAction::Stop(_)))
            .count();
        assert!(stops >= 20, "laggards must be stopped, got {stops}");
        assert_eq!(
            actions.iter().filter(|a| matches!(a, TrialAction::Pause(_))).count(),
            0,
            "ASHA-stop never pauses"
        );
        assert_eq!(s.max_resources_used(), 27, "the leader reaches R");
        let best = s.best().unwrap();
        assert_eq!(best.trial, 0);
    }

    #[test]
    fn asha_stop_every_trial_runs_at_least_one_rung() {
        let mut s = StoppingSh::asha(RungLevels::new(1, 3, 27));
        drive(&mut s, 20, |t, _| (t % 7) as f64);
        for t in s.trials() {
            assert!(t.trained_epochs() >= 1, "stopping happens after rung 0");
        }
    }

    #[test]
    fn pasha_stop_stable_rankings_pause_at_initial_cap() {
        // Identical ordering at every resource level: the cap never grows,
        // survivors pause at rung 1, and nothing trains beyond η·r.
        let mut s = StoppingSh::pasha(RungLevels::new(1, 3, 200), &RankingSpec::Direct);
        let actions = drive(&mut s, 30, |t, _| t as f64);
        assert_eq!(s.current_cap(), 1);
        assert_eq!(s.growths(), 0);
        assert_eq!(s.max_resources_used(), 3);
        assert!(
            actions.iter().any(|a| matches!(a, TrialAction::Pause(_))),
            "cap completions must pause"
        );
    }

    #[test]
    fn pasha_stop_unstable_rankings_grow_and_resume_paused() {
        // Order flips at every rung level: the cap must keep growing to
        // the safety net, and paused trials resume on each growth.
        let levels = [1u32, 3, 9, 27, 81, 200];
        let mut s = StoppingSh::pasha(RungLevels::new(1, 3, 200), &RankingSpec::Direct);
        drive(&mut s, 300, move |t, m| {
            let k = levels.iter().position(|&l| l >= m).unwrap_or(0);
            if k % 2 == 0 {
                t as f64
            } else {
                -(t as f64)
            }
        });
        assert_eq!(s.current_cap(), RungLevels::new(1, 3, 200).top());
        assert_eq!(s.max_resources_used(), 200, "defaults to ASHA-stop's budget");
        assert!(s.growths() >= 2);
    }

    #[test]
    fn pasha_stop_uses_fewer_resources_than_asha_stop_when_stable() {
        let metric = |t: usize, _m: u32| (t % 11) as f64;
        let mut astop = StoppingSh::asha(RungLevels::new(1, 3, 81));
        drive(&mut astop, 40, metric);
        let mut pstop = StoppingSh::pasha(RungLevels::new(1, 3, 81), &RankingSpec::Direct);
        drive(&mut pstop, 40, metric);
        assert!(pstop.max_resources_used() <= astop.max_resources_used());
        let total = |s: &StoppingSh| -> u32 { s.trials().iter().map(|t| t.trained_epochs()).sum() };
        assert!(total(&pstop) < total(&astop), "cap must save epochs");
    }

    #[test]
    fn builder_names() {
        assert_eq!(StopAshaBuilder::default().name(), "ASHA-stop");
        assert_eq!(StopPashaBuilder::default().name(), "PASHA-stop");
        let b = StopPashaBuilder {
            ranking: RankingSpec::Direct,
            ..Default::default()
        };
        assert_eq!(b.name(), "PASHA direct ranking-stop");
        let s = b.build(27, 0);
        assert_eq!(s.name(), "PASHA direct ranking-stop");
    }

    #[test]
    fn degenerate_single_rung_grid() {
        let mut s = StoppingSh::pasha(RungLevels::new(1, 3, 1), &RankingSpec::default());
        let actions = drive(&mut s, 10, |t, _| t as f64);
        assert_eq!(s.current_cap(), 0);
        assert_eq!(s.max_resources_used(), 1);
        assert!(actions.is_empty(), "single-rung trials just complete");
    }
}
