//! PASHA — Progressive Asynchronous Successive Halving (the paper's
//! contribution, Algorithm 1).
//!
//! PASHA runs ASHA's asynchronous promotion rule but starts with a small
//! resource cap: only rungs 0 and 1 exist initially (`R_0 = η·r`,
//! `K_0 = 1`). Every time a job completes in the current top rung, the
//! ranking of the top two rungs is compared with a [`RankingFunction`];
//! if they disagree the cap grows by one rung (the "doubling trick":
//! `R_{t+1} = η·R_t`), up to the safety-net maximum `R`. When the ranking
//! has stabilized, the cap stops growing, no trial is ever trained beyond
//! it, and the search terminates after the configuration budget drains —
//! typically at a small fraction of ASHA's cost.

use super::core::ShCore;
use super::rung::RungLevels;
use super::state::{curve_from, curve_json, field, load_sh_core, sh_core_json, usize_field};
use super::types::{
    BestTrial, Job, JobOutcome, SchedCtx, Scheduler, SchedulerBuilder, TrialInfo,
};
use crate::ranking::{RankCtx, RankingFunction, RankingSpec};
use crate::util::json::Json;

/// The consistency check of Algorithm 1 lines 11–18: compare the current
/// top-rung ranking against the previous rung's ranking restricted to the
/// same trials. Returns `true` when the rankings agree (no growth needed);
/// records an ε estimate into `eps_history` when the ranking function
/// re-estimates one. Shared by promotion-type [`Pasha`] and the
/// stopping-type variant in [`super::stopping`].
pub(crate) fn cap_ranking_consistent(
    core: &ShCore,
    ranking: &mut dyn RankingFunction,
    cap: usize,
    eps_history: &mut Vec<f64>,
) -> bool {
    if cap == 0 {
        return true; // degenerate single-rung grid
    }
    let top = core.ranking(cap);
    if top.len() < 2 {
        // A single configuration cannot exhibit ranking instability.
        return true;
    }
    let prev = core.ranking_restricted(cap - 1, cap);
    debug_assert_eq!(top.len(), prev.len());
    let curves = core.top_rung_curves(cap);
    let ctx = RankCtx {
        top_curves: &curves,
    };
    let consistent = ranking.consistent(&top, &prev, &ctx);
    if let Some(eps) = ranking.epsilon() {
        eps_history.push(eps);
    }
    consistent
}

pub struct Pasha {
    core: ShCore,
    /// Current top-rung index K_t (jobs may target rungs 0..=cap).
    cap: usize,
    ranking: Box<dyn RankingFunction>,
    /// ε after each re-estimation (Figure 5) — soft-ranking variants only.
    eps_history: Vec<f64>,
    /// Number of cap-growth events (diagnostics).
    growths: usize,
}

impl Pasha {
    pub fn new(levels: RungLevels, spec: &RankingSpec) -> Self {
        // K_0 = ⌊log_η(R_0/r)⌋ with R_0 = η·r ⇒ start with rungs {0, 1}.
        let cap = 1.min(levels.top());
        Pasha {
            core: ShCore::new(levels),
            cap,
            ranking: spec.build(),
            eps_history: Vec::new(),
            growths: 0,
        }
    }

    pub fn current_cap(&self) -> usize {
        self.cap
    }

    pub fn current_max_resources(&self) -> u32 {
        self.core.levels.level(self.cap)
    }

    pub fn growths(&self) -> usize {
        self.growths
    }

    /// The consistency check of Algorithm 1 lines 11–18, run after a
    /// completed job in the current top rung.
    fn check_and_maybe_grow(&mut self) {
        if self.cap >= self.core.levels.top() {
            return; // already at the safety net R: PASHA degraded to ASHA
        }
        let consistent = cap_ranking_consistent(
            &self.core,
            self.ranking.as_mut(),
            self.cap,
            &mut self.eps_history,
        );
        if !consistent {
            self.cap += 1;
            self.growths += 1;
        }
    }
}

impl Scheduler for Pasha {
    fn next_job(&mut self, ctx: &mut SchedCtx) -> Option<Job> {
        let cap = self.cap;
        self.core.next_job_capped(ctx, cap)
    }

    fn on_result(&mut self, outcome: &JobOutcome) {
        self.core.record(outcome);
        if outcome.rung == self.cap {
            self.check_and_maybe_grow();
        }
    }

    fn on_cancelled(&mut self, trial: crate::TrialId) {
        self.core.rewind_dispatch(trial);
    }

    fn max_resources_used(&self) -> u32 {
        self.core.max_resources_used
    }

    fn resource_cap(&self) -> Option<u32> {
        Some(self.current_max_resources())
    }

    fn best(&self) -> Option<BestTrial> {
        self.core.best()
    }

    fn trials(&self) -> &[TrialInfo] {
        &self.core.trials
    }

    fn epsilon_history(&self) -> &[f64] {
        &self.eps_history
    }

    fn save_state(&self) -> Option<Json> {
        // The ranking function itself carries no decision state: every
        // consistency check recomputes ε from the rung data, so rebuilding
        // it fresh from the spec preserves byte-identical behavior.
        let mut o = Json::obj();
        o.set("kind", "pasha")
            .set("core", sh_core_json(&self.core))
            .set("cap", self.cap)
            .set("eps_history", curve_json(&self.eps_history))
            .set("growths", self.growths);
        Some(o)
    }

    fn load_state(&mut self, state: &Json) -> Result<(), String> {
        if state.get("kind").and_then(|k| k.as_str()) != Some("pasha") {
            return Err("state is not a PASHA snapshot".into());
        }
        load_sh_core(&mut self.core, field(state, "core")?)?;
        let cap = usize_field(state, "cap")?;
        if cap >= self.core.levels.num_rungs() {
            return Err(format!("snapshot cap {cap} outside the rung grid"));
        }
        self.cap = cap;
        self.eps_history = curve_from(field(state, "eps_history")?)?;
        self.growths = usize_field(state, "growths")?;
        Ok(())
    }

    fn name(&self) -> String {
        "PASHA".into()
    }
}

/// Builder for PASHA with a choice of ranking function.
#[derive(Clone, Debug)]
pub struct PashaBuilder {
    pub r_min: u32,
    pub eta: u32,
    pub ranking: RankingSpec,
}

impl Default for PashaBuilder {
    /// Paper defaults: r=1, η=3, noise-adaptive soft ranking at N=90%.
    fn default() -> Self {
        PashaBuilder {
            r_min: 1,
            eta: 3,
            ranking: RankingSpec::default(),
        }
    }
}

impl PashaBuilder {
    pub fn with_ranking(ranking: RankingSpec) -> Self {
        PashaBuilder {
            ranking,
            ..Default::default()
        }
    }
}

impl SchedulerBuilder for PashaBuilder {
    fn build(&self, max_epochs: u32, _seed: u64) -> Box<dyn Scheduler> {
        Box::new(Pasha::new(
            RungLevels::new(self.r_min, self.eta, max_epochs),
            &self.ranking,
        ))
    }

    fn name(&self) -> String {
        self.ranking.label()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::space::SearchSpace;
    use crate::searcher::random::RandomSearcher;

    /// Drive PASHA against a metric oracle until it stops asking for work.
    fn drive(
        spec: RankingSpec,
        n_configs: usize,
        max_epochs: u32,
        metric: impl Fn(usize, u32) -> f64,
    ) -> Pasha {
        let space = SearchSpace::nas(100_000);
        let mut searcher = RandomSearcher::new(3);
        let mut ctx = SchedCtx::with_budget(&space, &mut searcher, 0, n_configs);
        let mut p = Pasha::new(RungLevels::new(1, 3, max_epochs), &spec);
        while let Some(job) = p.next_job(&mut ctx) {
            let m = metric(job.trial, job.milestone);
            p.on_result(&JobOutcome {
                trial: job.trial,
                rung: job.rung,
                milestone: job.milestone,
                metric: m,
                curve_segment: (job.from_epoch + 1..=job.milestone)
                    .map(|e| metric(job.trial, e))
                    .collect(),
            });
        }
        p
    }

    #[test]
    fn starts_with_two_rungs() {
        let p = Pasha::new(RungLevels::new(1, 3, 200), &RankingSpec::default());
        assert_eq!(p.current_cap(), 1);
        assert_eq!(p.current_max_resources(), 3);
    }

    #[test]
    fn stable_rankings_never_grow() {
        // Metric = trial id, identical at every resource level ⇒ rankings
        // always consistent ⇒ cap stays at 1 and nothing trains beyond η·r.
        let p = drive(RankingSpec::Direct, 30, 200, |t, _| t as f64);
        assert_eq!(p.current_cap(), 1);
        assert_eq!(p.growths(), 0);
        assert_eq!(p.max_resources_used(), 3);
    }

    #[test]
    fn unstable_rankings_grow_to_safety_net() {
        // Metric order flips at every rung level ⇒ PASHA must keep
        // growing and eventually behave like ASHA (cap = top rung).
        let levels = [1u32, 3, 9, 27, 81, 200];
        let p = drive(RankingSpec::Direct, 300, 200, move |t, m| {
            let k = levels.iter().position(|&l| l >= m).unwrap_or(0);
            if k % 2 == 0 {
                t as f64
            } else {
                -(t as f64)
            }
        });
        assert_eq!(p.current_cap(), RungLevels::new(1, 3, 200).top());
        assert_eq!(p.max_resources_used(), 200, "defaults to ASHA's budget");
    }

    #[test]
    fn growth_is_one_rung_per_inconsistency() {
        // A single early flip then stability: cap should have grown but
        // stopped well short of the top.
        let p = drive(RankingSpec::Direct, 40, 200, |t, m| {
            // flip the order only between milestones 1 and 3
            if m <= 1 {
                -(t as f64)
            } else {
                t as f64
            }
        });
        assert!(p.current_cap() >= 2, "must grow past the flip");
        assert!(
            p.current_cap() < RungLevels::new(1, 3, 200).top(),
            "must stop once stable (cap={})",
            p.current_cap()
        );
    }

    #[test]
    fn soft_ranking_forgives_noise_and_stops_earlier() {
        // Near-tied trials with small noisy flips: direct ranking keeps
        // growing, generous soft ranking does not.
        let noisy = |t: usize, m: u32| {
            let base = (t % 5) as f64 * 10.0;
            // deterministic "noise" flips near-tied pairs at odd milestones
            let jitter = if m % 2 == 1 { (t % 2) as f64 * 0.4 } else { 0.0 };
            base + jitter
        };
        let direct = drive(RankingSpec::Direct, 30, 200, noisy);
        let soft = drive(RankingSpec::SoftFixed { epsilon: 1.0 }, 30, 200, noisy);
        assert!(
            soft.max_resources_used() <= direct.max_resources_used(),
            "soft {} vs direct {}",
            soft.max_resources_used(),
            direct.max_resources_used()
        );
        assert!(soft.growths() <= direct.growths());
    }

    #[test]
    fn jobs_never_exceed_cap() {
        let space = SearchSpace::nas(100_000);
        let mut searcher = RandomSearcher::new(5);
        let mut ctx = SchedCtx::with_budget(&space, &mut searcher, 0, 25);
        let mut p = Pasha::new(RungLevels::new(1, 3, 200), &RankingSpec::default());
        while let Some(job) = p.next_job(&mut ctx) {
            assert!(
                job.rung <= p.current_cap(),
                "job rung {} above cap {}",
                job.rung,
                p.current_cap()
            );
            assert!(job.milestone <= p.current_max_resources());
            let m = job.trial as f64;
            p.on_result(&JobOutcome {
                trial: job.trial,
                rung: job.rung,
                milestone: job.milestone,
                metric: m,
                curve_segment: (job.from_epoch + 1..=job.milestone).map(|_| m).collect(),
            });
        }
    }

    #[test]
    fn epsilon_history_recorded_for_noise_adaptive() {
        let p = drive(
            RankingSpec::NoiseAdaptive { percentile: 90.0 },
            30,
            200,
            |t, m| {
                let h = (m as u64)
                    .wrapping_mul(2654435761)
                    .wrapping_add(t as u64 * 97);
                (t % 7) as f64 + (h % 97) as f64 * 0.01
            },
        );
        assert!(
            !p.epsilon_history().is_empty(),
            "ε must be re-estimated on top-rung results"
        );
        assert!(p.epsilon_history().iter().all(|&e| e >= 0.0));
    }

    #[test]
    fn degenerate_single_rung_grid() {
        // R == r: only one rung exists; PASHA must not panic or grow.
        let p = drive(RankingSpec::default(), 10, 1, |t, _| t as f64);
        assert_eq!(p.current_cap(), 0);
        assert_eq!(p.max_resources_used(), 1);
    }

    #[test]
    fn builder_labels_match_paper() {
        assert_eq!(PashaBuilder::default().name(), "PASHA");
        assert_eq!(
            PashaBuilder::with_ranking(RankingSpec::Direct).name(),
            "PASHA direct ranking"
        );
    }
}
