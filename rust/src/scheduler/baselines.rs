//! The paper's non-adaptive baselines (§5.1, Appendix A):
//!
//! * **k-epoch baseline** — train every one of the N configurations for
//!   exactly `k` epochs, then select the best-performing one. The paper's
//!   "one-epoch baseline" is k=1; Appendix A adds k ∈ {2, 3, 5}.
//! * **random baseline** — select a configuration uniformly at random
//!   without any training.
//!
//! Both are implemented as schedulers so they run through the exact same
//! tuner/executor machinery (and therefore the same runtime accounting)
//! as ASHA and PASHA.

use super::types::{
    BestTrial, Job, JobOutcome, SchedCtx, Scheduler, SchedulerBuilder, TrialInfo,
};

/// Train every configuration for exactly `epochs` epochs.
pub struct FixedEpochBaseline {
    epochs: u32,
    trials: Vec<TrialInfo>,
    max_used: u32,
}

impl FixedEpochBaseline {
    pub fn new(epochs: u32) -> Self {
        assert!(epochs >= 1);
        FixedEpochBaseline {
            epochs,
            trials: Vec::new(),
            max_used: 0,
        }
    }
}

impl Scheduler for FixedEpochBaseline {
    fn next_job(&mut self, ctx: &mut SchedCtx) -> Option<Job> {
        let config = ctx.draw()?;
        let trial = self.trials.len();
        let mut info = TrialInfo::new(config.clone());
        info.dispatched_epochs = self.epochs;
        self.trials.push(info);
        Some(Job {
            trial,
            config,
            rung: 0,
            from_epoch: 0,
            milestone: self.epochs,
        })
    }

    fn on_result(&mut self, outcome: &JobOutcome) {
        let t = &mut self.trials[outcome.trial];
        t.curve.extend_from_slice(&outcome.curve_segment);
        t.top_rung = Some(0);
        self.max_used = self.max_used.max(outcome.milestone);
    }

    fn on_cancelled(&mut self, trial: usize) {
        let t = &mut self.trials[trial];
        t.dispatched_epochs = t.trained_epochs();
    }

    fn max_resources_used(&self) -> u32 {
        self.max_used
    }

    fn best(&self) -> Option<BestTrial> {
        self.trials
            .iter()
            .enumerate()
            .filter_map(|(id, t)| t.latest_metric().map(|m| (id, t, m)))
            .max_by(|a, b| a.2.partial_cmp(&b.2).unwrap_or(std::cmp::Ordering::Equal))
            .map(|(id, t, m)| BestTrial {
                trial: id,
                config: t.config.clone(),
                metric: m,
                at_epoch: t.trained_epochs(),
            })
    }

    fn trials(&self) -> &[TrialInfo] {
        &self.trials
    }

    fn name(&self) -> String {
        match self.epochs {
            1 => "One-epoch baseline".into(),
            2 => "Two-epoch baseline".into(),
            3 => "Three-epoch baseline".into(),
            5 => "Five-epoch baseline".into(),
            n => format!("{n}-epoch baseline"),
        }
    }
}

/// Builder for the k-epoch baseline.
#[derive(Clone, Debug)]
pub struct FixedEpochBuilder {
    pub epochs: u32,
}

impl SchedulerBuilder for FixedEpochBuilder {
    fn build(&self, _max_epochs: u32, _seed: u64) -> Box<dyn Scheduler> {
        Box::new(FixedEpochBaseline::new(self.epochs))
    }

    fn name(&self) -> String {
        FixedEpochBaseline::new(self.epochs).name()
    }
}

/// Select a configuration at random without training. Implemented as a
/// scheduler that samples all N configurations as zero-epoch jobs (zero
/// cost) and picks the first as "best" (a uniform choice, since the
/// searcher order is random).
pub struct RandomBaseline {
    trials: Vec<TrialInfo>,
}

impl RandomBaseline {
    pub fn new() -> Self {
        RandomBaseline { trials: Vec::new() }
    }
}

impl Default for RandomBaseline {
    fn default() -> Self {
        Self::new()
    }
}

impl Scheduler for RandomBaseline {
    fn next_job(&mut self, ctx: &mut SchedCtx) -> Option<Job> {
        let config = ctx.draw()?;
        let trial = self.trials.len();
        self.trials.push(TrialInfo::new(config.clone()));
        Some(Job {
            trial,
            config,
            rung: 0,
            from_epoch: 0,
            milestone: 0, // zero training
        })
    }

    fn on_result(&mut self, _outcome: &JobOutcome) {}

    fn max_resources_used(&self) -> u32 {
        0
    }

    fn best(&self) -> Option<BestTrial> {
        self.trials.first().map(|t| BestTrial {
            trial: 0,
            config: t.config.clone(),
            metric: f64::NAN,
            at_epoch: 0,
        })
    }

    fn trials(&self) -> &[TrialInfo] {
        &self.trials
    }

    fn name(&self) -> String {
        "Random baseline".into()
    }
}

/// Builder for the random baseline.
#[derive(Clone, Debug, Default)]
pub struct RandomBaselineBuilder;

impl SchedulerBuilder for RandomBaselineBuilder {
    fn build(&self, _max_epochs: u32, _seed: u64) -> Box<dyn Scheduler> {
        Box::new(RandomBaseline::new())
    }

    fn name(&self) -> String {
        "Random baseline".into()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::space::SearchSpace;
    use crate::searcher::random::RandomSearcher;

    fn run_fixed(epochs: u32, n: usize) -> FixedEpochBaseline {
        let space = SearchSpace::nas(1000);
        let mut searcher = RandomSearcher::new(1);
        let mut ctx = SchedCtx::with_budget(&space, &mut searcher, 0, n);
        let mut b = FixedEpochBaseline::new(epochs);
        while let Some(j) = b.next_job(&mut ctx) {
            assert_eq!(j.milestone, epochs);
            let m = (j.trial % 13) as f64;
            b.on_result(&JobOutcome {
                trial: j.trial,
                rung: 0,
                milestone: epochs,
                metric: m,
                curve_segment: (1..=epochs).map(|_| m).collect(),
            });
        }
        b
    }

    #[test]
    fn fixed_epoch_trains_everything_k_epochs() {
        let b = run_fixed(3, 20);
        assert_eq!(b.trials().len(), 20);
        assert!(b.trials().iter().all(|t| t.trained_epochs() == 3));
        assert_eq!(b.max_resources_used(), 3);
    }

    #[test]
    fn fixed_epoch_selects_argmax() {
        let b = run_fixed(1, 20);
        let best = b.best().unwrap();
        assert_eq!(best.metric, 12.0);
    }

    #[test]
    fn names_match_paper() {
        assert_eq!(FixedEpochBaseline::new(1).name(), "One-epoch baseline");
        assert_eq!(FixedEpochBaseline::new(5).name(), "Five-epoch baseline");
        assert_eq!(RandomBaseline::new().name(), "Random baseline");
    }

    #[test]
    fn random_baseline_zero_resources() {
        let space = SearchSpace::nas(1000);
        let mut searcher = RandomSearcher::new(2);
        let mut ctx = SchedCtx::with_budget(&space, &mut searcher, 0, 5);
        let mut b = RandomBaseline::new();
        let mut jobs = 0;
        while let Some(j) = b.next_job(&mut ctx) {
            assert_eq!(j.milestone, 0);
            jobs += 1;
        }
        assert_eq!(jobs, 5);
        assert_eq!(b.max_resources_used(), 0);
        let best = b.best().unwrap();
        assert_eq!(best.trial, 0, "uniform pick = first of a random stream");
        assert!(best.metric.is_nan());
    }
}
