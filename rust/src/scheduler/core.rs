//! Shared state machine of the asynchronous successive-halving family.
//!
//! [`ShCore`] owns the rung grid, trial bookkeeping and the promotion /
//! new-trial logic of asynchronous SH (promotion-type ASHA, Li et al.
//! 2020, Algorithm 2). ASHA uses it with the rung cap fixed at the top of
//! the grid; PASHA starts the cap at rung 1 and grows it (§4, Algorithm 1).

use super::rung::{Rung, RungLevels};
use super::types::{BestTrial, Job, JobOutcome, SchedCtx, TrialInfo};
use crate::TrialId;

/// Common state for ASHA/PASHA.
pub struct ShCore {
    pub levels: RungLevels,
    pub rungs: Vec<Rung>,
    pub trials: Vec<TrialInfo>,
    /// Highest milestone any trial has *completed* (paper's "Max resources").
    pub max_resources_used: u32,
}

impl ShCore {
    pub fn new(levels: RungLevels) -> Self {
        let n = levels.num_rungs();
        ShCore {
            levels,
            rungs: (0..n).map(|_| Rung::default()).collect(),
            trials: Vec::new(),
            max_resources_used: 0,
        }
    }

    /// The asynchronous SH job rule with rung cap `cap` (promotions may
    /// target rungs `1..=cap` only): scan rungs `cap−1 .. 0` for a
    /// promotable trial; otherwise grow the bottom rung with a new
    /// configuration from the searcher (paper Algorithm 1, `get_job`).
    pub fn next_job_capped(&mut self, ctx: &mut SchedCtx, cap: usize) -> Option<Job> {
        debug_assert!(cap < self.levels.num_rungs());
        for k in (0..cap).rev() {
            if let Some(trial) = self.rungs[k].promotable(self.levels.eta) {
                self.rungs[k].mark_promoted(trial);
                return Some(self.continue_job(trial, k + 1));
            }
        }
        // No promotable candidate: grow the bottom rung.
        self.start_new(ctx)
    }

    /// Start a fresh configuration at the bottom rung (the shared "grow
    /// the base" path of both the promotion and stopping variants).
    pub fn start_new(&mut self, ctx: &mut SchedCtx) -> Option<Job> {
        let config = ctx.draw()?;
        let trial = self.trials.len();
        let mut info = TrialInfo::new(config.clone());
        let milestone = self.levels.level(0);
        info.dispatched_epochs = milestone;
        self.trials.push(info);
        Some(Job {
            trial,
            config,
            rung: 0,
            from_epoch: 0,
            milestone,
        })
    }

    /// Continue `trial` from its dispatched frontier up to rung `k`'s
    /// milestone — promotions (promotion-type) and continuations
    /// (stopping-type) are the same job shape.
    pub fn continue_job(&mut self, trial: TrialId, k: usize) -> Job {
        let from = self.trials[trial].dispatched_epochs;
        let milestone = self.levels.level(k);
        debug_assert!(milestone > from, "continuation must add resources");
        self.trials[trial].dispatched_epochs = milestone;
        Job {
            trial,
            config: self.trials[trial].config.clone(),
            rung: k,
            from_epoch: from,
            milestone,
        }
    }

    /// Rewind a trial's dispatch frontier after the engine cancelled its
    /// in-flight job (the job's epochs were never trained).
    pub fn rewind_dispatch(&mut self, trial: TrialId) {
        let t = &mut self.trials[trial];
        t.dispatched_epochs = t.trained_epochs();
    }

    /// Record a completed job into trial + rung state.
    pub fn record(&mut self, outcome: &JobOutcome) {
        let t = &mut self.trials[outcome.trial];
        debug_assert_eq!(
            t.trained_epochs() + outcome.curve_segment.len() as u32,
            outcome.milestone,
            "curve segment must cover (from, milestone]"
        );
        t.curve.extend_from_slice(&outcome.curve_segment);
        t.top_rung = Some(t.top_rung.map_or(outcome.rung, |r| r.max(outcome.rung)));
        self.rungs[outcome.rung].record(outcome.trial, outcome.metric);
        self.max_resources_used = self.max_resources_used.max(outcome.milestone);
    }

    /// Best trial by latest observed metric (the configuration the paper
    /// retrains in phase 2).
    ///
    /// Returns `None` until at least one result has been delivered —
    /// trials that are merely dispatched are not selectable (previously
    /// this returned trial 0 with a `NaN` metric, which callers could
    /// mistake for a real selection). If results exist but every metric is
    /// non-finite (all trials diverged), the first *reported* trial is
    /// returned with `metric: f64::NAN` — the NaN metric is the explicit
    /// "selection is arbitrary" flag.
    pub fn best(&self) -> Option<BestTrial> {
        let mut best: Option<BestTrial> = None;
        for (id, t) in self.trials.iter().enumerate() {
            if let Some(m) = t.latest_metric() {
                // diverged/failed trials may report NaN — never select them
                if !m.is_finite() {
                    continue;
                }
                let better = match &best {
                    None => true,
                    Some(b) => m > b.metric,
                };
                if better {
                    best = Some(BestTrial {
                        trial: id,
                        config: t.config.clone(),
                        metric: m,
                        at_epoch: t.trained_epochs(),
                    });
                }
            }
        }
        best.or_else(|| {
            self.trials
                .iter()
                .enumerate()
                .find(|(_, t)| t.trained_epochs() > 0)
                .map(|(id, t)| BestTrial {
                    trial: id,
                    config: t.config.clone(),
                    metric: f64::NAN,
                    at_epoch: t.trained_epochs(),
                })
        })
    }

    /// Descending ranking of rung `k`.
    pub fn ranking(&self, k: usize) -> Vec<(TrialId, f64)> {
        self.rungs[k].sorted_desc()
    }

    /// 0-based position of `trial` in rung `k`'s descending ranking, or
    /// `None` if the trial has not reported in that rung. The
    /// stopping-type continue/stop test is `rank < max(1, len/η)`.
    ///
    /// Runs on every delivered result of a stopping-type run, so — per
    /// the same perf note as [`Rung::promotable`] — it counts the
    /// entries ordered before the trial with one linear scan instead of
    /// cloning and sorting the rung.
    pub fn rank_in_rung(&self, k: usize, trial: TrialId) -> Option<usize> {
        let rung = &self.rungs[k];
        let target = rung.metric_of(trial)?;
        let before = rung
            .entries
            .iter()
            .filter(|&&(t, m)| {
                t != trial
                    && crate::util::stats::desc_cmp(m, target).then(t.cmp(&trial))
                        == std::cmp::Ordering::Less
            })
            .count();
        Some(before)
    }

    /// Ranking of rung `k` restricted to the trials present in rung `top`
    /// (every top-rung trial necessarily has an entry in every lower rung).
    pub fn ranking_restricted(&self, k: usize, top: usize) -> Vec<(TrialId, f64)> {
        let members: Vec<TrialId> = self.rungs[top].entries.iter().map(|&(t, _)| t).collect();
        let mut v: Vec<(TrialId, f64)> = members
            .into_iter()
            .filter_map(|t| self.rungs[k].metric_of(t).map(|m| (t, m)))
            .collect();
        v.sort_by(|a, b| crate::util::stats::desc_cmp(a.1, b.1).then(a.0.cmp(&b.0)));
        v
    }

    /// Curves of every trial promoted *into* the current top rung `cap`
    /// (trained beyond the previous rung's milestone, including trials
    /// whose top-rung result is still in flight) — the eligible set for
    /// the ε noise estimator (§4.2).
    pub fn top_rung_curves(&self, cap: usize) -> Vec<(TrialId, &[f64])> {
        let prev_level = if cap == 0 {
            0
        } else {
            self.levels.level(cap - 1)
        };
        self.trials
            .iter()
            .enumerate()
            .filter(|(_, t)| t.trained_epochs() > prev_level)
            .map(|(id, t)| (id, t.curve.as_slice()))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::space::SearchSpace;
    use crate::searcher::random::RandomSearcher;

    fn ctx_parts() -> (SearchSpace, RandomSearcher) {
        (SearchSpace::nas(1000), RandomSearcher::new(0))
    }

    fn outcome(trial: TrialId, rung: usize, milestone: u32, from: u32, metric: f64) -> JobOutcome {
        JobOutcome {
            trial,
            rung,
            milestone,
            metric,
            curve_segment: (from + 1..=milestone)
                .map(|e| metric - (milestone - e) as f64 * 0.01)
                .collect(),
        }
    }

    #[test]
    fn first_jobs_fill_bottom_rung() {
        let (space, mut searcher) = ctx_parts();
        let mut ctx = SchedCtx::with_budget(&space, &mut searcher, 0, 10);
        let mut core = ShCore::new(RungLevels::new(1, 3, 27));
        for i in 0..4 {
            let j = core.next_job_capped(&mut ctx, 3).unwrap();
            assert_eq!(j.trial, i);
            assert_eq!(j.rung, 0);
            assert_eq!(j.milestone, 1);
            assert_eq!(j.from_epoch, 0);
        }
        assert_eq!(core.trials.len(), 4);
    }

    #[test]
    fn promotion_preferred_over_new_config() {
        let (space, mut searcher) = ctx_parts();
        let mut ctx = SchedCtx::with_budget(&space, &mut searcher, 0, 100);
        let mut core = ShCore::new(RungLevels::new(1, 3, 27));
        // fill bottom rung with 3 results: quota 1 promotable
        for i in 0..3 {
            let j = core.next_job_capped(&mut ctx, 3).unwrap();
            core.record(&outcome(j.trial, 0, 1, 0, 50.0 + i as f64 * 10.0));
        }
        let j = core.next_job_capped(&mut ctx, 3).unwrap();
        assert_eq!(j.rung, 1, "must promote");
        assert_eq!(j.trial, 2, "best trial promotes");
        assert_eq!(j.from_epoch, 1);
        assert_eq!(j.milestone, 3);
    }

    #[test]
    fn cap_limits_promotion_target() {
        let (space, mut searcher) = ctx_parts();
        let mut ctx = SchedCtx::with_budget(&space, &mut searcher, 0, 100);
        let mut core = ShCore::new(RungLevels::new(1, 3, 27)); // levels 1,3,9,27
        // create 3 results at rung 1 (by direct recording) so rung-1→2
        // promotion would be available without a cap
        for t in 0..3 {
            let j = core.next_job_capped(&mut ctx, 1).unwrap();
            core.record(&outcome(j.trial, 0, 1, 0, 40.0 + t as f64));
        }
        // promote best to rung 1 (allowed by cap=1)
        let j = core.next_job_capped(&mut ctx, 1).unwrap();
        assert_eq!(j.rung, 1);
        core.record(&outcome(j.trial, 1, 3, 1, 60.0));
        // with cap=1, no promotion into rung 2 even though rung 1 has a top
        // entry; instead a new bottom-rung config is drawn
        let j2 = core.next_job_capped(&mut ctx, 1).unwrap();
        assert_eq!(j2.rung, 0, "cap must block rung-2 promotion");
    }

    #[test]
    fn budget_exhaustion_returns_none() {
        let (space, mut searcher) = ctx_parts();
        let mut ctx = SchedCtx::with_budget(&space, &mut searcher, 0, 2);
        let mut core = ShCore::new(RungLevels::new(1, 3, 9));
        assert!(core.next_job_capped(&mut ctx, 2).is_some());
        assert!(core.next_job_capped(&mut ctx, 2).is_some());
        assert!(core.next_job_capped(&mut ctx, 2).is_none());
    }

    #[test]
    fn record_tracks_curve_and_max_resources() {
        let (space, mut searcher) = ctx_parts();
        let mut ctx = SchedCtx::with_budget(&space, &mut searcher, 0, 10);
        let mut core = ShCore::new(RungLevels::new(1, 3, 27));
        let j = core.next_job_capped(&mut ctx, 3).unwrap();
        core.record(&outcome(j.trial, 0, 1, 0, 50.0));
        assert_eq!(core.trials[j.trial].trained_epochs(), 1);
        assert_eq!(core.max_resources_used, 1);
        // promote through two rungs
        for _ in 0..2 {
            let j = core.next_job_capped(&mut ctx, 3).unwrap();
            core.record(&outcome(j.trial, j.rung, j.milestone, j.from_epoch, 55.0));
        }
    }

    #[test]
    fn best_is_argmax_latest_metric() {
        let (space, mut searcher) = ctx_parts();
        let mut ctx = SchedCtx::with_budget(&space, &mut searcher, 0, 10);
        let mut core = ShCore::new(RungLevels::new(1, 3, 9));
        for m in [30.0, 70.0, 50.0] {
            let j = core.next_job_capped(&mut ctx, 2).unwrap();
            core.record(&outcome(j.trial, 0, 1, 0, m));
        }
        let b = core.best().unwrap();
        assert_eq!(b.trial, 1);
        assert_eq!(b.metric, 70.0);
    }

    #[test]
    fn ranking_restricted_projects_top_members() {
        let (space, mut searcher) = ctx_parts();
        let mut ctx = SchedCtx::with_budget(&space, &mut searcher, 0, 20);
        let mut core = ShCore::new(RungLevels::new(1, 3, 9));
        // interleave: promotions may fire as soon as quota allows, so
        // always record with the job's actual rung/milestone
        let metrics = [10.0, 60.0, 30.0, 80.0, 20.0, 40.0];
        let mut next_metric = metrics.iter();
        let mut rung1 = 0;
        while rung1 < 2 {
            let j = core.next_job_capped(&mut ctx, 2).unwrap();
            let m = if j.rung == 0 {
                *next_metric.next().unwrap()
            } else {
                rung1 += 1;
                // invert the order at rung 1: previously-worse trial now better
                if j.trial == 3 {
                    61.0
                } else {
                    90.0
                }
            };
            core.record(&outcome(j.trial, j.rung, j.milestone, j.from_epoch, m));
        }
        let top = core.ranking(1);
        assert_eq!(top.len(), 2);
        let prev = core.ranking_restricted(0, 1);
        assert_eq!(prev.len(), 2);
        // prev ranking keeps bottom-rung order: trial 3 (80) above trial 1 (60)
        assert_eq!(prev[0].0, 3);
        assert_eq!(prev[1].0, 1);
        // top ranking inverted: trial 1 (90) above trial 3 (61)
        assert_eq!(top[0].0, 1);
    }

    #[test]
    fn rank_in_rung_matches_sorted_position() {
        // Ties included: the linear-scan rank must agree with the full
        // sort (metric desc, trial id asc) for every member.
        let mut core = ShCore::new(RungLevels::new(1, 3, 9));
        for (t, m) in [(0, 50.0), (1, 70.0), (2, 50.0), (3, 90.0), (4, 70.0)] {
            core.rungs[0].record(t, m);
        }
        let sorted = core.ranking(0);
        for (pos, &(t, _)) in sorted.iter().enumerate() {
            assert_eq!(core.rank_in_rung(0, t), Some(pos), "trial {t}");
        }
        assert_eq!(core.rank_in_rung(0, 99), None);
        assert_eq!(core.rank_in_rung(1, 0), None, "not reported in rung 1");
    }

    #[test]
    fn top_rung_curves_includes_in_flight() {
        let (space, mut searcher) = ctx_parts();
        let mut ctx = SchedCtx::with_budget(&space, &mut searcher, 0, 20);
        let mut core = ShCore::new(RungLevels::new(1, 3, 27));
        for m in [10.0, 60.0, 30.0] {
            let j = core.next_job_capped(&mut ctx, 2).unwrap();
            core.record(&outcome(j.trial, 0, 1, 0, m));
        }
        // trial 1 promoted to rung 1 (trained to 3)
        let j = core.next_job_capped(&mut ctx, 2).unwrap();
        assert_eq!((j.trial, j.rung), (1, 1));
        core.record(&outcome(1, 1, 3, 1, 65.0));
        // eligible set for cap=1: trained beyond level(0)=1 → only trial 1
        let curves = core.top_rung_curves(1);
        assert_eq!(curves.len(), 1);
        assert_eq!(curves[0].0, 1);
        assert_eq!(curves[0].1.len(), 3);
    }
}
