//! Core types shared by all schedulers: jobs, trial bookkeeping, trial
//! actions (the decision layer of the event-driven engine), and the
//! scheduler trait itself.

use crate::config::space::{Config, SearchSpace};
use crate::searcher::Searcher;
use crate::util::json::Json;
use crate::TrialId;

/// A unit of work handed to a worker: continue training `trial` from
/// `from_epoch` up to `milestone` epochs, then report the validation
/// metric. `rung` is the rung index the result will be recorded in.
#[derive(Clone, Debug, PartialEq)]
pub struct Job {
    pub trial: TrialId,
    pub config: Config,
    pub rung: usize,
    pub from_epoch: u32,
    pub milestone: u32,
}

/// Completion record delivered back to the scheduler.
#[derive(Clone, Debug)]
pub struct JobOutcome {
    pub trial: TrialId,
    pub rung: usize,
    pub milestone: u32,
    /// Validation accuracy (%) at the milestone.
    pub metric: f64,
    /// Per-epoch validation accuracies for epochs `from_epoch+1 ..= milestone`
    /// (the per-epoch statistics §4.2's ε-estimator consumes).
    pub curve_segment: Vec<f64>,
}

/// Scheduler-side bookkeeping for one trial.
#[derive(Clone, Debug)]
pub struct TrialInfo {
    pub config: Config,
    /// Epochs trained so far (== `curve.len()`), including in-flight work
    /// that has been dispatched but not yet reported.
    pub dispatched_epochs: u32,
    /// Observed validation accuracy for epochs 1..=n (completed only).
    pub curve: Vec<f64>,
    /// Highest rung this trial has reported a result in (None before the
    /// first report).
    pub top_rung: Option<usize>,
}

impl TrialInfo {
    pub fn new(config: Config) -> Self {
        TrialInfo {
            config,
            dispatched_epochs: 0,
            curve: Vec::new(),
            top_rung: None,
        }
    }

    /// Completed (reported) epochs.
    pub fn trained_epochs(&self) -> u32 {
        self.curve.len() as u32
    }

    /// Latest observed metric, if any.
    pub fn latest_metric(&self) -> Option<f64> {
        self.curve.last().copied()
    }
}

/// The best configuration identified so far.
#[derive(Clone, Debug)]
pub struct BestTrial {
    pub trial: TrialId,
    pub config: Config,
    pub metric: f64,
    pub at_epoch: u32,
}

/// A decision a scheduler takes about a trial *outside* the free-worker
/// job cycle. Promotion-type schedulers never emit these (a promotion is
/// just the next [`Job`]); the stopping-type ASHA/PASHA variants (Li et
/// al. 2020 §3.1, PASHA §4) use them to terminate or suspend trials, and
/// the engine translates them into backend cancellation of any in-flight
/// work. Drained by the engine via [`Scheduler::drain_actions`] after
/// every delivered result.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum TrialAction {
    /// Terminate the trial: cancel in-flight work, never run it again.
    Stop(TrialId),
    /// Suspend the trial: cancel in-flight work but keep it resumable —
    /// a later [`Job`] may continue it (PASHA-stop resumes paused trials
    /// when the resource cap grows). If the pause cancelled an in-flight
    /// job, the engine reports it via [`Scheduler::on_cancelled`] so the
    /// scheduler can rewind its dispatch frontier, and — on backends
    /// that cannot preempt (the thread pool) — parks any resume job
    /// until the cancelled job retires, so the job accounting is safe on
    /// every backend. Caveat for *stateful* evaluators on the pool: the
    /// discarded job's worker still ran, so a per-trial model may have
    /// advanced past the rewound frontier; such evaluators must tolerate
    /// `advance` being asked to (re)train from an earlier epoch, or
    /// schedulers should only pause trials with no job in flight (what
    /// the built-in stopping schedulers do).
    Pause(TrialId),
}

impl TrialAction {
    pub fn trial(&self) -> TrialId {
        match *self {
            TrialAction::Stop(t) | TrialAction::Pause(t) => t,
        }
    }
}

/// Context handed to [`Scheduler::next_job`]: draws new configurations
/// through the searcher. How many draws are still permitted is decided by
/// the engine's stopping rules (§5.1's N-configuration budget is the
/// `ConfigBudget` rule) rather than a budget hardwired into the context.
pub struct SchedCtx<'a> {
    pub space: &'a SearchSpace,
    pub searcher: &'a mut dyn Searcher,
    pub configs_sampled: usize,
    /// Additional configurations the engine's stopping rules still allow
    /// this dispatch cycle (`usize::MAX` when unconstrained).
    pub draws_remaining: usize,
}

impl<'a> SchedCtx<'a> {
    /// A context that allows exactly `budget - configs_sampled` more draws
    /// — the classic N-configuration protocol, used directly by tests and
    /// by the engine when only a `ConfigBudget` rule is active.
    pub fn with_budget(
        space: &'a SearchSpace,
        searcher: &'a mut dyn Searcher,
        configs_sampled: usize,
        config_budget: usize,
    ) -> Self {
        SchedCtx {
            space,
            searcher,
            configs_sampled,
            draws_remaining: config_budget.saturating_sub(configs_sampled),
        }
    }

    /// Draw a new configuration if the stopping rules allow.
    pub fn draw(&mut self) -> Option<Config> {
        if self.draws_remaining == 0 {
            return None;
        }
        self.draws_remaining -= 1;
        self.configs_sampled += 1;
        Some(self.searcher.suggest(self.space))
    }

    pub fn budget_left(&self) -> usize {
        self.draws_remaining
    }
}

/// A multi-fidelity scheduler: decides which trial to advance to which
/// milestone (promotion), when to start new trials, and — for PASHA —
/// when to grow the maximum resource level.
pub trait Scheduler: Send {
    /// Work for a free worker, or `None` if nothing can run right now
    /// (budget exhausted and no promotable candidate; for synchronous
    /// schedulers also "waiting for stragglers").
    fn next_job(&mut self, ctx: &mut SchedCtx) -> Option<Job>;

    /// Deliver a completed job.
    fn on_result(&mut self, outcome: &JobOutcome);

    /// Trial actions decided since the last drain (typically during
    /// [`Scheduler::on_result`]). The engine applies them — cancelling
    /// in-flight backend work for stopped/paused trials — immediately
    /// after each delivered result. Promotion-type schedulers keep the
    /// default empty implementation.
    fn drain_actions(&mut self) -> Vec<TrialAction> {
        Vec::new()
    }

    /// The engine discarded work for `trial` without running it to
    /// completion: a drained [`TrialAction`] cancelled its in-flight
    /// job, or a stopping-rule halt cancelled it (or dropped it before
    /// dispatch). The job's epochs were never trained and its result
    /// will never arrive. Schedulers must rewind their dispatch frontier
    /// here (e.g. reset `dispatched_epochs` to the trained epochs) so
    /// state stays consistent and a later resume leaves no curve gap.
    fn on_cancelled(&mut self, trial: TrialId) {
        let _ = trial;
    }

    /// Largest milestone any trial has been trained to so far (the paper's
    /// "Max resources" column).
    fn max_resources_used(&self) -> u32;

    /// The current maximum resource level (epochs) this scheduler will
    /// allocate to any trial — PASHA's progressively growing cap, a
    /// constant `R` for fixed-budget schedulers, `None` when the concept
    /// does not apply. Telemetry only (`pasha_max_resource_epochs`):
    /// never consulted for decisions.
    fn resource_cap(&self) -> Option<u32> {
        None
    }

    /// Best configuration identified so far (the paper selects this for
    /// the phase-2 retraining).
    fn best(&self) -> Option<BestTrial>;

    /// Trial bookkeeping (read access for reporting/diagnostics).
    fn trials(&self) -> &[TrialInfo];

    /// ε values recorded after each ranking-noise re-estimation, if this
    /// scheduler uses the noise-adaptive soft ranking (Figure 5).
    fn epsilon_history(&self) -> &[f64] {
        &[]
    }

    /// Serialize the full decision state for a snapshot
    /// ([`crate::scheduler::state`]), or `None` if this scheduler does
    /// not support snapshots (the service then falls back to full journal
    /// replay). Restoring the returned value into a freshly-built
    /// instance via [`Scheduler::load_state`] must yield byte-identical
    /// subsequent decisions.
    fn save_state(&self) -> Option<Json> {
        None
    }

    /// Restore [`Scheduler::save_state`] output into this freshly-built
    /// instance. Errors when the state belongs to a different scheduler
    /// kind or rung grid.
    fn load_state(&mut self, state: &Json) -> Result<(), String> {
        let _ = state;
        Err(format!("scheduler '{}' does not support snapshots", self.name()))
    }

    fn name(&self) -> String;
}

/// Builders produce a fresh scheduler per repetition.
pub trait SchedulerBuilder: Send + Sync {
    fn build(&self, max_epochs: u32, seed: u64) -> Box<dyn Scheduler>;
    fn name(&self) -> String;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::searcher::random::RandomSearcher;

    #[test]
    fn ctx_enforces_budget() {
        let space = SearchSpace::pd1();
        let mut searcher = RandomSearcher::new(0);
        let mut ctx = SchedCtx::with_budget(&space, &mut searcher, 0, 3);
        assert!(ctx.draw().is_some());
        assert!(ctx.draw().is_some());
        assert_eq!(ctx.budget_left(), 1);
        assert!(ctx.draw().is_some());
        assert!(ctx.draw().is_none());
        assert_eq!(ctx.configs_sampled, 3);
    }

    #[test]
    fn ctx_with_budget_handles_partial_progress() {
        let space = SearchSpace::pd1();
        let mut searcher = RandomSearcher::new(0);
        let mut ctx = SchedCtx::with_budget(&space, &mut searcher, 2, 3);
        assert_eq!(ctx.budget_left(), 1);
        assert!(ctx.draw().is_some());
        assert!(ctx.draw().is_none());
        // sampled beyond budget (rules tightened mid-run): no draws left
        let mut ctx = SchedCtx::with_budget(&space, &mut searcher, 5, 3);
        assert_eq!(ctx.budget_left(), 0);
        assert!(ctx.draw().is_none());
    }

    #[test]
    fn trial_action_accessor() {
        assert_eq!(TrialAction::Stop(3).trial(), 3);
        assert_eq!(TrialAction::Pause(7).trial(), 7);
        assert_ne!(TrialAction::Stop(1), TrialAction::Pause(1));
    }

    #[test]
    fn trial_info_tracks_epochs() {
        let mut t = TrialInfo::new(Config::cat(0));
        assert_eq!(t.trained_epochs(), 0);
        assert!(t.latest_metric().is_none());
        t.curve.extend_from_slice(&[10.0, 20.0]);
        assert_eq!(t.trained_epochs(), 2);
        assert_eq!(t.latest_metric(), Some(20.0));
    }
}
